//! Aaren-Hawkes-Process on event forecasting (paper §4.2): simulate a
//! marked Hawkes event stream (the Reddit preset), train both the
//! Transformer-Hawkes-Process baseline and its Aaren variant with a
//! log-normal mixture head, and report NLL / RMSE / mark accuracy.
//!
//!     cargo run --release --example event_forecasting -- artifacts 300

use aaren::coordinator::experiments::{run_ef, Kind};
use aaren::data::events::EfDataset;
use aaren::runtime::exec::Engine;
use anyhow::Result;

fn main() -> Result<()> {
    let mut argv = std::env::args().skip(1);
    let artifacts = std::path::PathBuf::from(argv.next().unwrap_or_else(|| "artifacts".into()));
    let steps: usize = argv.next().and_then(|s| s.parse().ok()).unwrap_or(300);

    let mut engine = Engine::new(&artifacts)?;
    for ds in [EfDataset::Reddit, EfDataset::Sin] {
        println!("\ndataset {} ({} marks)…", ds.name(), ds.n_marks());
        for kind in [Kind::Tf, Kind::Aaren] {
            let r = run_ef(&mut engine, kind, ds, steps, 11)?;
            match r.acc {
                Some(acc) => println!(
                    "  {:<12} NLL {:>6.3}  RMSE {:>6.3}  mark-acc {:>5.1}%",
                    kind.display(),
                    r.nll,
                    r.rmse,
                    acc
                ),
                None => println!(
                    "  {:<12} NLL {:>6.3}  RMSE {:>6.3}  (unmarked dataset)",
                    kind.display(),
                    r.nll,
                    r.rmse
                ),
            }
        }
    }
    println!(
        "\nEvents arrive as an irregular stream — exactly the setting where\n\
         Aaren's O(1) updates beat recomputing attention per event (paper §4.2)."
    );
    Ok(())
}
