//! End-to-end training driver (the repo's full-stack validation): train
//! the Aaren stream model for several hundred steps on a synthetic
//! multi-channel series, logging the loss curve, then prove all layers
//! compose by (a) checkpointing the trained weights, (b) hot-loading them
//! into a *streaming* session, and (c) showing the streamed predictions
//! match the trained parallel forward pass.
//!
//!     cargo run --release --example train_e2e -- artifacts 400
//!
//! The loss curve and wall-clock are recorded in EXPERIMENTS.md.

use aaren::coordinator::Trainer;
use aaren::data::tsf;
use aaren::runtime::exec::{literal_to_f32, Engine, HostTensor};
use aaren::runtime::manifest::Role;
use aaren::serve::session::{Session, StreamModel};
use aaren::util::rng::Rng;
use anyhow::Result;
use std::time::Instant;

fn main() -> Result<()> {
    let mut argv = std::env::args().skip(1);
    let artifacts = std::path::PathBuf::from(argv.next().unwrap_or_else(|| "artifacts".into()));
    let steps: usize = argv.next().and_then(|s| s.parse().ok()).unwrap_or(400);

    let mut engine = Engine::new(&artifacts)?;
    let train_mod = engine.load("stream_aaren_train")?;
    let b = train_mod.manifest.meta_usize("batch", 8);
    let n = train_mod.manifest.meta_usize("seq", 64);
    let c = train_mod.manifest.meta_usize("channels", 8);
    println!(
        "training stream_aaren ({} params) on synthetic series: B={b} N={n} C={c}",
        train_mod.manifest.param_elements()
    );

    // synthetic stream data: a seasonal series cut into N-token windows,
    // channel count padded from the TSF generator's 7 up to `c`
    let series = tsf::generate(tsf::TsfDataset::Ettm1, 20_000, 99);
    let mut rng = Rng::new(3);
    let batch = |rng: &mut Rng| -> Vec<f32> {
        let mut xs = Vec::with_capacity(b * n * c);
        for _ in 0..b {
            let start = rng.below(series.len - n);
            for t in 0..n {
                let row = series.at(start + t);
                for ch in 0..c {
                    xs.push(if ch < tsf::CHANNELS { row[ch] } else { 0.0 });
                }
            }
        }
        xs
    };

    let mut trainer = Trainer::new(train_mod)?;
    let t0 = Instant::now();
    for step in 0..steps {
        let xs = batch(&mut rng);
        let loss = trainer.step(&[HostTensor::F32(vec![b, n, c], xs)])?;
        if step % 50 == 0 || step + 1 == steps {
            println!(
                "  step {:>4}  loss {:.4}  ({:.1} steps/s)",
                step,
                loss,
                (step + 1) as f64 / t0.elapsed().as_secs_f64()
            );
        }
    }
    let first = trainer.losses[..20.min(trainer.losses.len())]
        .iter()
        .sum::<f32>()
        / 20.0f32.min(trainer.losses.len() as f32);
    let last = trainer.recent_loss(20);
    println!("loss: first-20 mean {first:.4} -> last-20 mean {last:.4}");
    assert!(last < first, "training did not reduce the loss");

    // checkpoint + hot-load into the serving path
    let trained = trainer.sync_store()?;
    let ckpt = artifacts.join("stream_aaren.trained.bin");
    trained.save(&ckpt)?;
    println!("checkpointed trained params to {ckpt:?}");

    let mut model = StreamModel::load_aaren(&mut engine)?;
    model.set_params(&trained)?;

    // trained parallel forward == trained streaming session
    let fwd = engine.load("stream_aaren_fwd")?;
    let xs = {
        let mut xs = Vec::with_capacity(n * c);
        let start = 17;
        for t in 0..n {
            let row = series.at(start + t);
            for ch in 0..c {
                xs.push(if ch < tsf::CHANNELS { row[ch] } else { 0.0 });
            }
        }
        xs
    };
    let mut args = Vec::new();
    let mut pi = 0;
    for arg in &fwd.manifest.args {
        match arg.role {
            Role::Param => {
                args.push(
                    HostTensor::F32(arg.shape.clone(), trained.params[pi].clone())
                        .to_literal()?,
                );
                pi += 1;
            }
            _ => args.push(HostTensor::F32(vec![1, n, c], xs.clone()).to_literal()?),
        }
    }
    let parallel = literal_to_f32(&fwd.execute(&args)?[0])?;

    let mut session = Session::new_aaren(&model)?;
    let mut max_err = 0.0f32;
    for t in 0..n {
        let y = session.step(&model, &xs[t * c..(t + 1) * c])?;
        for (a, bb) in y.iter().zip(&parallel[t * c..(t + 1) * c]) {
            max_err = max_err.max((a - bb).abs());
        }
    }
    println!("trained streaming == trained parallel: max err {max_err:.2e}");
    assert!(max_err < 1e-3);
    println!("e2e OK: train -> checkpoint -> serve all compose");
    Ok(())
}
