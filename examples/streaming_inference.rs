//! Streaming-inference comparison (the paper's §4.5 / Figure 5 story as a
//! demo): open one Aaren session and one Transformer+KV-cache session,
//! stream the same tokens through both, and print memory + cumulative
//! time side by side. Watch the Aaren column stay flat while the KV cache
//! grows and migrates through buckets.
//!
//!     cargo run --release --example streaming_inference -- artifacts 256

use aaren::runtime::exec::Engine;
use aaren::serve::session::{Session, StreamModel};
use aaren::util::rng::Rng;
use anyhow::Result;
use std::time::Instant;

fn main() -> Result<()> {
    let mut argv = std::env::args().skip(1);
    let artifacts = std::path::PathBuf::from(argv.next().unwrap_or_else(|| "artifacts".into()));
    let n_tokens: usize = argv.next().and_then(|s| s.parse().ok()).unwrap_or(256);

    let mut engine = Engine::new(&artifacts)?;
    let aaren_model = StreamModel::load_aaren(&mut engine)?;
    let tf_model = StreamModel::load_tf(&mut engine)?;
    let channels = aaren_model.channels;

    let mut aaren = Session::new_aaren(&aaren_model)?;
    let mut tf = Session::new_tf(&tf_model)?;
    let mut rng = Rng::new(7);

    println!(
        "{:>6}  {:>14} {:>14}  {:>14} {:>14}",
        "token", "aaren state B", "kv state B", "aaren cum ms", "tf cum ms"
    );
    let (mut a_ms, mut t_ms) = (0.0f64, 0.0f64);
    for t in 0..n_tokens {
        let mut x = vec![0.0f32; channels];
        rng.fill_gaussian(&mut x, 1.0);

        let t0 = Instant::now();
        let ya = aaren.step(&aaren_model, &x)?;
        a_ms += t0.elapsed().as_secs_f64() * 1e3;

        let t0 = Instant::now();
        let yt = tf.step(&tf_model, &x)?;
        t_ms += t0.elapsed().as_secs_f64() * 1e3;

        if (t + 1).is_power_of_two() || t + 1 == n_tokens {
            println!(
                "{:>6}  {:>14} {:>14}  {:>14.2} {:>14.2}",
                t + 1,
                aaren.state_bytes(),
                tf.state_bytes(),
                a_ms,
                t_ms
            );
        }
        // both models predict the next token — show one pair at the end
        if t + 1 == n_tokens {
            println!("\nfinal predictions (first 4 channels):");
            println!("  aaren: {:?}", &ya[..4.min(ya.len())]);
            println!("  tf:    {:?}", &yt[..4.min(yt.len())]);
        }
    }
    println!(
        "\nAaren held {} bytes regardless of stream length (paper: constant memory);\n\
         the KV cache reached {} bytes and its per-token cost grew with each bucket.",
        aaren.state_bytes(),
        tf.state_bytes()
    );
    Ok(())
}
