//! Streaming-inference comparison (the paper's §4.5 / Figure 5 story as a
//! demo), on the rust-native tier — no XLA, no artifacts: open one Aaren
//! session and one Transformer+KV-cache session through the shared
//! `StreamSession` trait, stream the same tokens through both, and print
//! memory + cumulative time side by side. Watch the Aaren column stay
//! flat while the KV cache migrates through its buckets and then keeps
//! doubling geometrically — the default stream length runs past the
//! largest bucket on purpose, the regime where tf streams used to die.
//!
//!     cargo run --release --example streaming_inference -- 8 600
//!
//! (args: channels, tokens). With `--features pjrt` the same trait is
//! served by compiled-HLO sessions through `aaren serve` instead.

use std::time::Instant;

use aaren::serve::session::{NativeAarenSession, NativeTfSession, StreamSession};
use aaren::util::rng::Rng;
use anyhow::Result;

fn main() -> Result<()> {
    let mut argv = std::env::args().skip(1);
    let channels: usize = argv.next().and_then(|s| s.parse().ok()).unwrap_or(8);
    let n_tokens: usize = argv.next().and_then(|s| s.parse().ok()).unwrap_or(600);

    let mut aaren: Box<dyn StreamSession> = Box::new(NativeAarenSession::new(channels));
    let mut tf: Box<dyn StreamSession> = Box::new(NativeTfSession::new(channels));
    let mut rng = Rng::new(7);

    println!(
        "{:>6}  {:>14} {:>14}  {:>14} {:>14}",
        "token", "aaren state B", "kv state B", "aaren cum ms", "tf cum ms"
    );
    let (mut a_ms, mut t_ms) = (0.0f64, 0.0f64);
    let mut last = (Vec::new(), Vec::new());
    for t in 0..n_tokens {
        let mut x = vec![0.0f32; channels];
        rng.fill_gaussian(&mut x, 1.0);

        let t0 = Instant::now();
        let ya = aaren.step(&x)?;
        a_ms += t0.elapsed().as_secs_f64() * 1e3;

        let t0 = Instant::now();
        let yt = tf.step(&x)?;
        t_ms += t0.elapsed().as_secs_f64() * 1e3;

        if (t + 1).is_power_of_two() || t + 1 == n_tokens {
            println!(
                "{:>6}  {:>14} {:>14}  {:>14.2} {:>14.2}",
                t + 1,
                aaren.state_bytes(),
                tf.state_bytes(),
                a_ms,
                t_ms
            );
        }
        if t + 1 == n_tokens {
            last = (ya, yt);
        }
    }
    println!("\nfinal predictions (first 4 channels):");
    println!("  aaren: {:?}", &last.0[..4.min(last.0.len())]);
    println!("  tf:    {:?}", &last.1[..4.min(last.1.len())]);
    println!(
        "\nAaren held {} bytes regardless of stream length (paper: constant memory);\n\
         the KV cache reached {} bytes — past the largest 512-token bucket it keeps\n\
         doubling instead of killing the stream, and its per-token cost keeps growing.",
        aaren.state_bytes(),
        tf.state_bytes()
    );
    Ok(())
}
