//! Quickstart: load the Aaren streaming model, feed it a short token
//! stream, and verify the paper's core equivalence live — running the
//! O(1)-state recurrent step token-by-token produces exactly the same
//! outputs as the parallel (prefix-scan) forward pass over the whole
//! sequence.
//!
//!     make artifacts && cargo run --example quickstart
//!
//! This is DESIGN.md contract 5 as a demo; rust/tests/integration.rs
//! enforces it as a test.

use aaren::runtime::exec::{literal_to_f32, Engine, HostTensor};
use aaren::serve::session::{Session, StreamModel};
use aaren::util::rng::Rng;
use anyhow::Result;

fn main() -> Result<()> {
    let artifacts = std::path::PathBuf::from(
        std::env::args().nth(1).unwrap_or_else(|| "artifacts".to_string()),
    );
    let mut engine = Engine::new(&artifacts)?;

    // 1. parallel forward over the whole sequence (training-style path)
    let fwd = engine.load("stream_aaren_fwd")?;
    let channels = fwd.manifest.meta_usize("channels", 8);
    let seq = fwd.manifest.meta_usize("seq", 64);
    let mut rng = Rng::new(42);
    let mut xs = vec![0.0f32; seq * channels];
    rng.fill_gaussian(&mut xs, 1.0);

    let mut args = Vec::new();
    let store = aaren::runtime::params::ParamStore::load(&fwd.manifest)?;
    let mut pi = 0;
    for arg in &fwd.manifest.args {
        match arg.role {
            aaren::runtime::manifest::Role::Param => {
                args.push(HostTensor::F32(arg.shape.clone(), store.params[pi].clone()).to_literal()?);
                pi += 1;
            }
            _ => args.push(HostTensor::F32(vec![1, seq, channels], xs.clone()).to_literal()?),
        }
    }
    let parallel_out = literal_to_f32(&fwd.execute(&args)?[0])?; // (1, seq, C)
    println!("parallel forward: {} outputs of {} channels", seq, channels);

    // 2. the same sequence, streamed token-by-token in O(1) memory
    let model = StreamModel::load_aaren(&mut engine)?;
    let mut session = Session::new_aaren(&model)?;
    let mut max_err = 0.0f32;
    for t in 0..seq {
        let y = session.step(&model, &xs[t * channels..(t + 1) * channels])?;
        for (a, b) in y.iter().zip(&parallel_out[t * channels..(t + 1) * channels]) {
            max_err = max_err.max((a - b).abs());
        }
    }
    println!(
        "streamed {} tokens with constant state = {} bytes; \
         max |streamed - parallel| = {max_err:.2e}",
        seq,
        session.state_bytes()
    );
    assert!(max_err < 1e-4, "streaming != parallel");
    println!("OK: attention as an RNN — streaming == parallel (paper §3.2/§3.3)");
    Ok(())
}
