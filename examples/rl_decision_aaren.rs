//! Decision-Aaren on offline RL (paper §4.1): generate a Medium-Expert
//! dataset on the simulated Hopper environment, train the Aaren variant of
//! the Decision Transformer, and roll it out online conditioned on an
//! expert return-to-go — printing the D4RL-style normalised score.
//!
//!     cargo run --release --example rl_decision_aaren -- artifacts 300

use aaren::coordinator::experiments::{run_rl, Kind};
use aaren::data::rl::{EnvId, Tier};
use aaren::runtime::exec::Engine;
use anyhow::Result;

fn main() -> Result<()> {
    let mut argv = std::env::args().skip(1);
    let artifacts = std::path::PathBuf::from(argv.next().unwrap_or_else(|| "artifacts".into()));
    let steps: usize = argv.next().and_then(|s| s.parse().ok()).unwrap_or(300);

    let mut engine = Engine::new(&artifacts)?;
    println!("training Decision-Aaren on Hopper Medium-Expert ({steps} steps)…");
    for kind in [Kind::Tf, Kind::Aaren] {
        let r = run_rl(
            &mut engine,
            kind,
            EnvId::Hopper,
            Tier::MediumExpert,
            steps,
            60, // offline episodes
            5,  // eval rollouts
            7,
        )?;
        println!(
            "{:<12} normalised score {:>6.1}  (raw return {:.2}, final train loss {:.4})",
            kind.display(),
            r.normalised_score,
            r.raw_return,
            r.final_train_loss
        );
    }
    println!(
        "\nBoth models see identical data and hyperparameters (paper Appendix E);\n\
         Aaren additionally supports O(1) online updates per environment step."
    );
    Ok(())
}
