//! Attention oracles in pure Rust — the paper's §3.1 formulations plus the
//! Appendix-A block-by-block variant. These mirror python's kernels/ref.py
//! and serve three roles:
//!   1. executable specification for the scan module's property tests,
//!   2. the rust-native fallback path for the streaming session manager,
//!   3. microbench baselines for fig5 (many-to-one recompute vs O(1) fold).
//!
//! Layout convention: `k`/`v` are row-major (n, d) flat slices.
//!
//! The prefix (many-to-many) paths are fused onto the SoA scan engine:
//! scores are computed inline while filling the flat `ScanBuffer` (or the
//! O(1) `Muw` accumulator), so per-token leaf tuples are never
//! materialized — no `Vec<Muw>` and no intermediate score vector on the
//! hot path.

use crate::scan::{self, fold_token, BatchScanBuffer, Muw, ScanBuffer, MASK_FILL};

/// Which prefix-scan engine computes the many-to-many outputs.
/// See `crate::scan` module docs for the work/depth trade-offs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScanStrategy {
    /// O(N) single-core left fold — lowest constant.
    Sequential,
    /// O(N log N) work / log N depth (the paper's Algorithm 1).
    HillisSteele,
    /// O(N) work / 2 log N depth tree scan.
    Blelloch,
    /// Multi-threaded chunked scan with this many chunks.
    Chunked(usize),
    /// Chunked with one chunk per available core.
    ChunkedAuto,
}

#[inline]
fn dot_scaled(q: &[f32], k_row: &[f32], scale: f32) -> f32 {
    q.iter().zip(k_row.iter()).map(|(a, b)| a * b).sum::<f32>() * scale
}

/// s_i = <q, k_i>/sqrt(d) with optional {0,1} mask (masked -> MASK_FILL).
pub fn scores(q: &[f32], k: &[f32], mask: Option<&[f32]>) -> Vec<f32> {
    let d = q.len();
    let n = if d == 0 { 0 } else { k.len() / d };
    let scale = 1.0 / (d as f32).sqrt();
    (0..n)
        .map(|i| {
            if let Some(m) = mask {
                if m[i] <= 0.0 {
                    return MASK_FILL;
                }
            }
            dot_scaled(q, &k[i * d..(i + 1) * d], scale)
        })
        .collect()
}

/// Fill a flat SoA leaf buffer with (s_i, 1, v_i) tuples, computing the
/// scores inline — the leaves exist only as rows of the returned
/// `ScanBuffer`, never as owned per-token tuples.
pub fn leaf_buffer(q: &[f32], k: &[f32], v: &[f32], mask: Option<&[f32]>) -> ScanBuffer {
    let d = q.len();
    let n = if d == 0 { 0 } else { k.len() / d };
    let dv = if n == 0 { 0 } else { v.len() / n };
    let scale = 1.0 / (d as f32).sqrt();
    let mut buf = ScanBuffer::with_capacity(dv, n);
    for i in 0..n {
        let masked = mask.is_some_and(|m| m[i] <= 0.0);
        let s = if masked { MASK_FILL } else { dot_scaled(q, &k[i * d..(i + 1) * d], scale) };
        buf.push_leaf(s, &v[i * dv..(i + 1) * dv]);
    }
    buf
}

/// Conventional many-to-one attention: softmax(s) @ v over the whole
/// context — O(N) memory, one output (paper Figure 1a).
pub fn many_to_one(q: &[f32], k: &[f32], v: &[f32], mask: Option<&[f32]>) -> Vec<f32> {
    let d = q.len();
    let n = if d == 0 { 0 } else { k.len() / d };
    if n == 0 {
        // empty context: nothing to attend over — mirror prefix_recurrent
        // and return an empty output instead of dividing by zero
        return Vec::new();
    }
    let dv = v.len() / n;
    let s = scores(q, k, mask);
    let mx = s.iter().cloned().fold(f32::MIN, f32::max);
    let exps: Vec<f32> = s.iter().map(|x| (x - mx).exp()).collect();
    let z: f32 = exps.iter().sum();
    let mut out = vec![0.0f32; dv];
    for i in 0..n {
        let w = exps[i] / z;
        for (o, x) in out.iter_mut().zip(v[i * dv..(i + 1) * dv].iter()) {
            *o += w * x;
        }
    }
    out
}

/// Many-to-many prefix attention via the recurrent O(1)-state fold
/// (§3.1's RNN cell applied token-by-token). Score computation is fused
/// into the fold loop — no score vector, no leaf tuples, one `Muw`
/// accumulator and the preallocated output. Returns (n, dv) flat.
pub fn prefix_recurrent(q: &[f32], k: &[f32], v: &[f32], mask: Option<&[f32]>) -> Vec<f32> {
    let d = q.len();
    let n = if d == 0 { 0 } else { k.len() / d };
    if n == 0 {
        return Vec::new();
    }
    let dv = v.len() / n;
    let scale = 1.0 / (d as f32).sqrt();
    let mut acc = Muw::identity(dv);
    let mut out = vec![0.0f32; n * dv];
    for i in 0..n {
        let masked = mask.is_some_and(|m| m[i] <= 0.0);
        let s = if masked { MASK_FILL } else { dot_scaled(q, &k[i * d..(i + 1) * d], scale) };
        fold_token(&mut acc, s, &v[i * dv..(i + 1) * dv]);
        acc.output_into(&mut out[i * dv..(i + 1) * dv]);
    }
    out
}

/// Many-to-many prefix attention through a parallel prefix scan over the
/// flat SoA buffer (§5: any prefix-scan algorithm computes Aaren's
/// outputs). Returns (n, dv) flat.
pub fn prefix_scan(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    mask: Option<&[f32]>,
    strategy: ScanStrategy,
) -> Vec<f32> {
    let mut leaves = leaf_buffer(q, k, v, mask);
    let scanned = match strategy {
        ScanStrategy::Sequential => {
            scan::sequential_inplace(&mut leaves);
            leaves
        }
        ScanStrategy::HillisSteele => scan::hillis_steele(&leaves),
        ScanStrategy::Blelloch => scan::blelloch(&leaves),
        ScanStrategy::Chunked(c) => scan::chunked_parallel(&leaves, c),
        ScanStrategy::ChunkedAuto => scan::chunked_parallel_auto(&leaves),
    };
    scanned.outputs()
}

/// Batched multi-query prefix attention: `nq` queries (rows of the
/// (nq, d) flat `qs`) share one (k, v) context and an optional mask. All
/// nq lanes live in a single flat [`BatchScanBuffer`] and are scanned
/// together — one allocation and one sweep for the whole bundle instead
/// of one `ScanBuffer` per query, which was the per-head allocation
/// hotspot of multi-head serving. `chunks > 1` runs the scan on the
/// shared `ScanPool` (chunked over time, all lanes per chunk).
///
/// Per lane the result is bitwise identical to
/// [`prefix_scan`] with [`ScanStrategy::Sequential`] (`chunks <= 1`) or
/// [`ScanStrategy::Chunked`] with the same chunk count. Returns
/// (nq, n, dv) flat, lane-major (query q's outputs are contiguous).
pub fn prefix_scan_multi(
    qs: &[f32],
    d: usize,
    k: &[f32],
    v: &[f32],
    mask: Option<&[f32]>,
    chunks: usize,
) -> Vec<f32> {
    let nq = if d == 0 { 0 } else { qs.len() / d };
    let n = if d == 0 { 0 } else { k.len() / d };
    if nq == 0 || n == 0 {
        return Vec::new();
    }
    let dv = v.len() / n;
    let scale = 1.0 / (d as f32).sqrt();
    let mut lanes = BatchScanBuffer::with_capacity(nq, dv, n);
    for t in 0..n {
        let masked = mask.is_some_and(|m| m[t] <= 0.0);
        let k_row = &k[t * d..(t + 1) * d];
        let v_row = &v[t * dv..(t + 1) * dv];
        for q in 0..nq {
            let s = if masked {
                MASK_FILL
            } else {
                dot_scaled(&qs[q * d..(q + 1) * d], k_row, scale)
            };
            lanes.push_leaf_lane(q, s, v_row);
        }
    }
    if chunks > 1 {
        lanes.scan_chunked(chunks);
    } else {
        lanes.scan_inplace();
    }
    let mut out = vec![0.0f32; nq * n * dv];
    for q in 0..nq {
        for t in 0..n {
            let start = (q * n + t) * dv;
            lanes.lane_output_into(t, q, &mut out[start..start + dv]);
        }
    }
    out
}

/// Many-to-many prefix attention the naive way: one full softmax per
/// prefix — O(N^2) work, the "recompute from scratch" strategy the paper
/// ascribes to Transformers handling streams.
pub fn prefix_naive(q: &[f32], k: &[f32], v: &[f32], mask: Option<&[f32]>) -> Vec<f32> {
    let d = q.len();
    let n = if d == 0 { 0 } else { k.len() / d };
    if n == 0 {
        return Vec::new();
    }
    let dv = v.len() / n;
    let mut out = Vec::with_capacity(n * dv);
    for i in 0..n {
        let kk = &k[..(i + 1) * d];
        let vv = &v[..(i + 1) * dv];
        let mm = mask.map(|m| &m[..(i + 1)]);
        out.extend(many_to_one(q, kk, vv, mm));
    }
    out
}

/// Appendix A: block-by-block attention with O(b) memory. Processes the
/// context in blocks of size `b`, carrying (a, c, m) between blocks, and
/// emits the final many-to-one output. With b == 1 this degenerates to
/// the token-by-token RNN; with b == n it is the conventional method.
pub fn many_to_one_blocked(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    mask: Option<&[f32]>,
    b: usize,
) -> Vec<f32> {
    assert!(b >= 1, "block size must be >= 1");
    let d = q.len();
    let n = if d == 0 { 0 } else { k.len() / d };
    if n == 0 {
        return Vec::new();
    }
    let dv = v.len() / n;
    let s = scores(q, k, mask);

    let mut a = vec![0.0f32; dv];
    let mut c = 0.0f32;
    let mut m = MASK_FILL;
    let mut i = 0usize;
    while i < n {
        let hi = (i + b).min(n);
        // m_{i+b} = max(m_i, s_{i+1..i+b})
        let m_blk = s[i..hi].iter().cloned().fold(m, f32::max);
        let carry = (m - m_blk).exp();
        for x in a.iter_mut() {
            *x *= carry;
        }
        c *= carry;
        for j in i..hi {
            let e = (s[j] - m_blk).exp();
            c += e;
            for (x, vv) in a.iter_mut().zip(v[j * dv..(j + 1) * dv].iter()) {
                *x += e * vv;
            }
        }
        m = m_blk;
        i = hi;
    }
    a.iter().map(|x| x / c).collect()
}

/// Standard causal self-attention with explicit dims (n tokens, d model)
/// — the Transformer baseline. q/k are (n, d) flat; returns (n, dv).
pub fn causal_self_attention_nd(q: &[f32], k: &[f32], v: &[f32], n: usize, d: usize) -> Vec<f32> {
    if n == 0 {
        return Vec::new();
    }
    let dv = v.len() / n;
    let scale = 1.0 / (d as f32).sqrt();
    let mut out = Vec::with_capacity(n * dv);
    for i in 0..n {
        let qi = &q[i * d..(i + 1) * d];
        let mut s: Vec<f32> = (0..=i)
            .map(|j| dot_scaled(qi, &k[j * d..(j + 1) * d], scale))
            .collect();
        let mx = s.iter().cloned().fold(f32::MIN, f32::max);
        let mut z = 0.0f32;
        for x in s.iter_mut() {
            *x = (*x - mx).exp();
            z += *x;
        }
        let mut o = vec![0.0f32; dv];
        for (j, w) in s.iter().enumerate() {
            for (od, vd) in o.iter_mut().zip(v[j * dv..(j + 1) * dv].iter()) {
                *od += w / z * vd;
            }
        }
        out.extend(o);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn randv(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.gaussian() as f32).collect()
    }

    const STRATEGIES: [ScanStrategy; 5] = [
        ScanStrategy::Sequential,
        ScanStrategy::HillisSteele,
        ScanStrategy::Blelloch,
        ScanStrategy::Chunked(4),
        ScanStrategy::ChunkedAuto,
    ];

    #[test]
    fn recurrent_prefix_matches_naive() {
        prop::check("prefix_recurrent == prefix_naive", 64, |rng| {
            let (n, d) = (1 + rng.below(48), 1 + rng.below(8));
            let q = randv(rng, d);
            let k = randv(rng, n * d);
            let v = randv(rng, n * d);
            prop::assert_close(
                &prefix_recurrent(&q, &k, &v, None),
                &prefix_naive(&q, &k, &v, None),
                1e-4,
            )
        });
    }

    #[test]
    fn recurrent_prefix_matches_naive_with_mask() {
        prop::check("masked prefix", 64, |rng| {
            let (n, d) = (2 + rng.below(32), 4);
            let q = randv(rng, d);
            let k = randv(rng, n * d);
            let v = randv(rng, n * d);
            let mask: Vec<f32> = (0..n)
                .map(|_| if rng.uniform() < 0.7 { 1.0 } else { 0.0 })
                .collect();
            prop::assert_close(
                &prefix_recurrent(&q, &k, &v, Some(&mask)),
                &prefix_naive(&q, &k, &v, Some(&mask)),
                1e-4,
            )
        });
    }

    #[test]
    fn prefix_scan_matches_naive_for_every_strategy() {
        prop::check("prefix_scan == prefix_naive", 48, |rng| {
            let (n, d) = (1 + rng.below(48), 1 + rng.below(8));
            let q = randv(rng, d);
            let k = randv(rng, n * d);
            let v = randv(rng, n * d);
            let want = prefix_naive(&q, &k, &v, None);
            for strategy in STRATEGIES {
                prop::assert_close(&prefix_scan(&q, &k, &v, None, strategy), &want, 1e-4)
                    .map_err(|e| format!("{strategy:?}: {e}"))?;
            }
            Ok(())
        });
    }

    #[test]
    fn prefix_scan_matches_naive_with_mask() {
        prop::check("masked prefix_scan", 48, |rng| {
            let (n, d) = (2 + rng.below(32), 4);
            let q = randv(rng, d);
            let k = randv(rng, n * d);
            let v = randv(rng, n * d);
            let mask: Vec<f32> = (0..n)
                .map(|_| if rng.uniform() < 0.7 { 1.0 } else { 0.0 })
                .collect();
            let want = prefix_naive(&q, &k, &v, Some(&mask));
            for strategy in STRATEGIES {
                prop::assert_close(&prefix_scan(&q, &k, &v, Some(&mask), strategy), &want, 1e-4)
                    .map_err(|e| format!("{strategy:?}: {e}"))?;
            }
            Ok(())
        });
    }

    #[test]
    fn multi_query_prefix_matches_per_query_scans() {
        // satellite property: the batched lanes engine must agree with
        // running each query through its own single-lane ScanBuffer —
        // sequential and pool-chunked alike.
        prop::check("prefix_scan_multi == per-query prefix_scan", 32, |rng| {
            let (nq, n, d) = (1 + rng.below(5), 1 + rng.below(40), 1 + rng.below(6));
            let chunks = 1 + rng.below(6);
            let qs = randv(rng, nq * d);
            let k = randv(rng, n * d);
            let v = randv(rng, n * d);
            let seq = prefix_scan_multi(&qs, d, &k, &v, None, 1);
            let par = prefix_scan_multi(&qs, d, &k, &v, None, chunks);
            if seq.len() != nq * n * d || par.len() != nq * n * d {
                return Err(format!("bad output length {} / {}", seq.len(), par.len()));
            }
            for q in 0..nq {
                let qv = &qs[q * d..(q + 1) * d];
                let lane = &seq[q * n * d..(q + 1) * n * d];
                let want_seq = prefix_scan(qv, &k, &v, None, ScanStrategy::Sequential);
                prop::assert_close(lane, &want_seq, 1e-6)
                    .map_err(|e| format!("sequential lane {q}: {e}"))?;
                let lane_par = &par[q * n * d..(q + 1) * n * d];
                let want_par = prefix_scan(qv, &k, &v, None, ScanStrategy::Chunked(chunks));
                prop::assert_close(lane_par, &want_par, 1e-6)
                    .map_err(|e| format!("chunked({chunks}) lane {q}: {e}"))?;
            }
            Ok(())
        });
    }

    #[test]
    fn multi_query_prefix_respects_masks_and_edges() {
        let mut rng = Rng::new(13);
        let (nq, n, d) = (3, 17, 4);
        let qs = randv(&mut rng, nq * d);
        let k = randv(&mut rng, n * d);
        let v = randv(&mut rng, n * d);
        let mask: Vec<f32> = (0..n).map(|i| (i % 3 != 0) as u8 as f32).collect();
        let got = prefix_scan_multi(&qs, d, &k, &v, Some(&mask), 3);
        for q in 0..nq {
            let want =
                prefix_scan(&qs[q * d..(q + 1) * d], &k, &v, Some(&mask), ScanStrategy::Chunked(3));
            prop::assert_close(&got[q * n * d..(q + 1) * n * d], &want, 1e-6).unwrap();
        }
        // degenerate shapes are empty, not a panic
        assert!(prefix_scan_multi(&[], 4, &k, &v, None, 1).is_empty());
        assert!(prefix_scan_multi(&qs, 0, &[], &[], None, 1).is_empty());
        assert!(prefix_scan_multi(&qs, 4, &[], &[], None, 1).is_empty());
    }

    #[test]
    fn fully_masked_prefix_is_finite_and_matches_naive() {
        // regression for the u == 0 output guard: an all-masked context
        // must stay finite on every path and agree with the naive oracle.
        let mut rng = Rng::new(21);
        let (n, d) = (12, 4);
        let q = randv(&mut rng, d);
        let k = randv(&mut rng, n * d);
        let v = randv(&mut rng, n * d);
        let mask = vec![0.0f32; n];
        let want = prefix_naive(&q, &k, &v, Some(&mask));
        let got = prefix_recurrent(&q, &k, &v, Some(&mask));
        assert!(got.iter().all(|x| x.is_finite()), "masked prefix produced non-finite");
        prop::assert_close(&got, &want, 1e-4).unwrap();
        for strategy in STRATEGIES {
            let got = prefix_scan(&q, &k, &v, Some(&mask), strategy);
            assert!(got.iter().all(|x| x.is_finite()), "{strategy:?} non-finite");
            prop::assert_close(&got, &want, 1e-4).unwrap();
        }
    }

    #[test]
    fn empty_context_attention_is_empty_not_a_panic() {
        // regression: `v.len() / n` used to divide by zero on n == 0
        assert!(many_to_one(&[1.0, 2.0], &[], &[], None).is_empty());
        assert!(prefix_naive(&[1.0], &[], &[], None).is_empty());
        assert!(many_to_one_blocked(&[1.0, 2.0], &[], &[], None, 4).is_empty());
        assert!(causal_self_attention_nd(&[], &[], &[], 0, 3).is_empty());
        assert!(prefix_recurrent(&[1.0], &[], &[], None).is_empty());
        assert!(prefix_scan(&[1.0], &[], &[], None, ScanStrategy::Sequential).is_empty());
    }

    #[test]
    fn empty_query_attention_is_empty_not_a_panic() {
        // regression: with d == 0, `k.len() / d` was a 0/0 panic on the
        // scores/leaf_buffer/prefix paths too, not just many_to_one
        assert!(many_to_one(&[], &[], &[], None).is_empty());
        assert!(scores(&[], &[], None).is_empty());
        assert!(leaf_buffer(&[], &[], &[], None).is_empty());
        assert!(prefix_recurrent(&[], &[], &[], None).is_empty());
        assert!(prefix_naive(&[], &[], &[], None).is_empty());
        for strategy in STRATEGIES {
            assert!(prefix_scan(&[], &[], &[], None, strategy).is_empty());
        }
    }

    #[test]
    fn blocked_matches_full_for_every_block_size() {
        // Appendix A: any block size gives the same many-to-one output.
        prop::check("block-by-block == full", 48, |rng| {
            let (n, d) = (1 + rng.below(40), 4);
            let q = randv(rng, d);
            let k = randv(rng, n * d);
            let v = randv(rng, n * d);
            let want = many_to_one(&q, &k, &v, None);
            for b in [1usize, 2, 3, 5, 8, n.max(1)] {
                let got = many_to_one_blocked(&q, &k, &v, None, b);
                prop::assert_close(&got, &want, 1e-4).map_err(|e| format!("b={b}: {e}"))?;
            }
            Ok(())
        });
    }

    #[test]
    fn last_prefix_output_equals_many_to_one() {
        prop::check("prefix[-1] == many_to_one", 48, |rng| {
            let (n, d) = (1 + rng.below(32), 6);
            let q = randv(rng, d);
            let k = randv(rng, n * d);
            let v = randv(rng, n * d);
            let pre = prefix_recurrent(&q, &k, &v, None);
            let one = many_to_one(&q, &k, &v, None);
            prop::assert_close(&pre[(n - 1) * d..], &one, 1e-4)
        });
    }

    #[test]
    fn extreme_scores_stay_finite() {
        let mut rng = Rng::new(99);
        let (n, d) = (32, 4);
        let q: Vec<f32> = (0..d).map(|_| rng.range(-20.0, 20.0) as f32).collect();
        let k: Vec<f32> = (0..n * d).map(|_| rng.range(-20.0, 20.0) as f32).collect();
        let v = randv(&mut rng, n * d);
        for x in prefix_recurrent(&q, &k, &v, None) {
            assert!(x.is_finite());
        }
        for x in prefix_scan(&q, &k, &v, None, ScanStrategy::ChunkedAuto) {
            assert!(x.is_finite());
        }
    }

    #[test]
    fn leaf_buffer_matches_scores() {
        let mut rng = Rng::new(8);
        let (n, d) = (20, 4);
        let q = randv(&mut rng, d);
        let k = randv(&mut rng, n * d);
        let v = randv(&mut rng, n * d);
        let mask: Vec<f32> = (0..n).map(|i| (i % 3 != 0) as u8 as f32).collect();
        let buf = leaf_buffer(&q, &k, &v, Some(&mask));
        let s = scores(&q, &k, Some(&mask));
        assert_eq!(buf.len(), n);
        for i in 0..n {
            let (m, u, w) = buf.row(i);
            assert_eq!(m, s[i]);
            assert_eq!(u, 1.0);
            assert_eq!(w, &v[i * d..(i + 1) * d]);
        }
    }

    #[test]
    fn causal_self_attention_first_row_is_v0() {
        let mut rng = Rng::new(4);
        let (n, d) = (5, 3);
        let q = randv(&mut rng, n * d);
        let k = randv(&mut rng, n * d);
        let v = randv(&mut rng, n * d);
        let out = causal_self_attention_nd(&q, &k, &v, n, d);
        prop::assert_close(&out[..d], &v[..d], 1e-6).unwrap();
    }

    #[test]
    fn softmax_weights_uniform_when_scores_equal() {
        let q = vec![0.0, 0.0];
        let k = vec![1.0, 0.0, 0.0, 1.0, -1.0, 0.5]; // scores all 0 with q=0
        let v = vec![1.0, 0.0, 3.0, 0.0, 5.0, 0.0];
        let out = many_to_one(&q, &k, &v, None);
        assert!((out[0] - 3.0).abs() < 1e-5);
    }
}
