//! `aaren` — CLI launcher for the Attention-as-an-RNN reproduction.
//!
//! The binary builds with the default (pure-Rust, no XLA) feature set:
//! `serve` and `bench fig5` run everywhere over the rust-native sessions,
//! while the HLO-driven subcommands (`check`, `info`, `train`, the paper
//! tables) are compiled in with `--features pjrt`.
//!
//! Subcommands:
//!   serve   --addr host:port   streaming inference server (line-JSON protocol)
//!           --channels N --shards N  native session width / executor pool size
//!           --session-ttl-secs N     evict sessions idle longer than N seconds
//!           --spill-dir DIR          spill evicted sessions to disk instead of dropping
//!           --max-resident-sessions N  LRU-spill beyond N resident (needs --spill-dir)
//!           --scatter-drain          disable resident lanes (gather/scatter drains)
//!           --metrics-interval-secs N  print a per-op latency digest every N seconds
//!           --no-telemetry           disable histograms/spans/flight recorder
//!           --smoke            loopback create/step/steps/stats/metrics round-trip, then exit
//!   fleet   --addr host:port --members H1:P1,H2:P2,...   consistent-hash router
//!           --weights W1,W2,...      per-member ring weights (default 1 each)
//!           --spill-dir DIR          shared spill dir (the failover replay source)
//!           --hb-interval-ms N --hb-timeout-ms N --hb-misses N   failure detector
//!           --migrate-budget N       max sessions migrated per maintenance tick
//!   state   export --addr H:P --id N --out FILE   snapshot a live session to a file
//!           import --addr H:P --file FILE [--id N]  restore a snapshot as a new session
//!           inspect --file FILE                   decode a snapshot offline
//!   load    capacity harness: seeded open-loop traffic replay
//!           --addr H:P               target a live server/fleet (default: self-spawn loopback)
//!           --quick                  CI smoke shape (2k sessions; default is 120k)
//!           --sessions N --workers N --bursts N --batch N --channels N
//!           --trace poisson|onoff    arrival process   --seed N   deterministic replay
//!           --out FILE               merge capacity_* records into this BENCH trail
//!   bench   fig5 [+ table1..table4|params|all with pjrt]
//!   check                      verify artifacts load + run (pjrt)
//!   train   --domain …         train one model/dataset cell (pjrt)
//!   info                       list artifacts (pjrt)
//!
//! Common flags: --artifacts DIR (default ./artifacts), --seeds N,
//! --steps N, --limit K (restrict #datasets), --horizons a,b,c.

use std::path::PathBuf;

use anyhow::Result;

use aaren::serve::server::{self, ServeConfig};
use aaren::util::cli::Args;

#[cfg(feature = "pjrt")]
use pjrt_cli::{bench_cmd, hlo_cmd};

fn main() {
    let args = match Args::from_env() {
        Ok(args) => args,
        Err(e) => {
            eprintln!("error: {e}");
            eprintln!("run `aaren help` for usage");
            std::process::exit(2);
        }
    };
    if let Err(e) = run(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(args: &Args) -> Result<()> {
    let cmd = args.positional.first().map(String::as_str).unwrap_or("help");
    match cmd {
        "serve" => serve_cmd(args),
        "fleet" => fleet_cmd(args),
        "state" => state_cmd(args),
        "load" => load_cmd(args),
        "bench" => {
            let which = args.positional.get(1).map(String::as_str).unwrap_or("all");
            bench_cmd(which, args)
        }
        "check" | "info" | "train" => hlo_cmd(cmd, args),
        _ => {
            help();
            Ok(())
        }
    }
}

fn serve_cmd(args: &Args) -> Result<()> {
    let defaults = ServeConfig::default();
    // the HLO backend exists only in pjrt builds; native serving needs no
    // artifacts at all. Offer it only when --artifacts was given or the
    // default dir exists — otherwise the router's "pass --artifacts DIR"
    // error stays reachable instead of a dead HLO executor swallowing it.
    let artifacts = if cfg!(feature = "pjrt") {
        let dir = PathBuf::from(args.str("artifacts", "artifacts"));
        (args.flags.contains_key("artifacts") || dir.is_dir()).then_some(dir)
    } else {
        if args.flags.contains_key("artifacts") {
            eprintln!(
                "warning: --artifacts ignored — this build has no HLO backend \
                 (rebuild with --features pjrt)"
            );
        }
        None
    };
    let ttl_secs = args.u64("session-ttl-secs", 0);
    let max_resident = args.usize("max-resident-sessions", 0);
    let max_conns = args.usize("max-conns", 0);
    let io_timeout_secs = args.u64("io-timeout-secs", 0);
    let metrics_secs = args.u64("metrics-interval-secs", 0);
    // chaos testing only: a seeded fault-injection plan like
    // "seed=7,io=0.05,torn=0.2,panic=0.01,delay=0.5,delay-ms=2,panic-id=3"
    let fault = match args.flags.get("fault-plan") {
        Some(spec) => Some(aaren::fault::FaultPlan::parse(spec)?),
        None => None,
    };
    let cfg = ServeConfig {
        addr: args.str("addr", &defaults.addr),
        channels: args.usize("channels", defaults.channels),
        shards: args.usize("shards", defaults.shards),
        // 0 (the default) keeps sessions until an explicit close
        session_ttl: (ttl_secs > 0).then(|| std::time::Duration::from_secs(ttl_secs)),
        spill_dir: args.flags.get("spill-dir").map(PathBuf::from),
        // 0 (the default) leaves resident count unbounded
        max_resident_sessions: (max_resident > 0).then_some(max_resident),
        // escape hatch: fall back to the PR 3 gather/scatter drain
        // (kept for A/B benchmarking; resident lanes are the default)
        resident_lanes: !args.bool("scatter-drain"),
        artifacts,
        queue_depth: args.usize("queue-depth", defaults.queue_depth),
        // 0 (the default) leaves admission unbounded
        max_conns: (max_conns > 0).then_some(max_conns),
        // 0 (the default) blocks forever, the pre-containment behaviour
        io_timeout: (io_timeout_secs > 0)
            .then(|| std::time::Duration::from_secs(io_timeout_secs)),
        max_frame_bytes: args.usize("max-frame-bytes", defaults.max_frame_bytes),
        fault,
        // telemetry is on by default; --no-telemetry turns every
        // histogram/span/event site into a runtime no-op
        telemetry: !args.bool("no-telemetry"),
        // 0 (the default) prints no periodic digest
        metrics_interval: (metrics_secs > 0)
            .then(|| std::time::Duration::from_secs(metrics_secs)),
    };
    if cfg.max_resident_sessions.is_some() && cfg.spill_dir.is_none() {
        anyhow::bail!(
            "--max-resident-sessions needs --spill-dir (spilled sessions must go somewhere)"
        );
    }
    if args.bool("smoke") {
        return server::run_smoke(&cfg);
    }
    server::serve(&cfg)
}

/// `aaren fleet` — the consistent-hash router over N `aaren serve`
/// backends: heartbeat failure detection, failover replay from the
/// shared spill dir, budgeted live rebalancing.
fn fleet_cmd(args: &Args) -> Result<()> {
    use aaren::fleet::{serve_fleet, FleetConfig};

    let defaults = FleetConfig::default();
    let members: Vec<String> = args
        .str("members", "")
        .split(',')
        .map(str::trim)
        .filter(|s| !s.is_empty())
        .map(String::from)
        .collect();
    anyhow::ensure!(
        !members.is_empty(),
        "fleet needs --members H1:P1,H2:P2,... (the backend `aaren serve` addresses)"
    );
    let weights: Vec<u32> = args
        .str("weights", "")
        .split(',')
        .map(str::trim)
        .filter(|s| !s.is_empty())
        .map(|s| {
            s.parse::<u32>()
                .ok()
                .filter(|&w| w >= 1)
                .ok_or_else(|| anyhow::anyhow!("--weights entries must be positive integers"))
        })
        .collect::<Result<_>>()?;
    anyhow::ensure!(
        weights.is_empty() || weights.len() == members.len(),
        "--weights must list one weight per --members entry ({} != {})",
        weights.len(),
        members.len()
    );
    let hb_interval_ms = args.u64("hb-interval-ms", defaults.hb_interval.as_millis() as u64);
    let hb_timeout_ms = args.u64("hb-timeout-ms", defaults.hb_timeout.as_millis() as u64);
    let io_timeout_secs = args.u64("io-timeout-secs", 0);
    let fault = match args.flags.get("fault-plan") {
        Some(spec) => Some(aaren::fault::FaultPlan::parse(spec)?),
        None => None,
    };
    let cfg = FleetConfig {
        addr: args.str("addr", &defaults.addr),
        members,
        weights,
        spill_dir: args.flags.get("spill-dir").map(PathBuf::from),
        hb_interval: std::time::Duration::from_millis(hb_interval_ms.max(1)),
        hb_timeout: std::time::Duration::from_millis(hb_timeout_ms.max(1)),
        hb_misses: args.u64("hb-misses", defaults.hb_misses as u64).max(1) as u32,
        migrate_budget: args.usize("migrate-budget", defaults.migrate_budget).max(1),
        vnodes_per_weight: args.usize("vnodes", defaults.vnodes_per_weight).max(1),
        max_frame_bytes: args.usize("max-frame-bytes", defaults.max_frame_bytes),
        io_timeout: (io_timeout_secs > 0)
            .then(|| std::time::Duration::from_secs(io_timeout_secs)),
        fault,
    };
    if cfg.spill_dir.is_none() {
        eprintln!(
            "warning: no --spill-dir — a dead member's sessions cannot be replayed \
             (point it at the directory every backend spills to)"
        );
    }
    serve_fleet(&cfg)
}

/// `aaren load` — the million-session capacity harness: replay a seeded
/// open-loop arrival trace (Poisson or bursty ON-OFF) over a large
/// session population cycling create → steps → idle → spill → restore
/// → close, against `--addr` or a self-spawned loopback server sized to
/// force residency churn. Results land as `capacity_*` records merged
/// into the `BENCH_serve.json` perf trail (serve_loopback's records are
/// preserved).
fn load_cmd(args: &Args) -> Result<()> {
    use aaren::loadgen::{ArrivalKind, LoadConfig};
    use aaren::util::bench::merge_records;

    let mut cfg = if args.bool("quick") { LoadConfig::quick() } else { LoadConfig::full() };
    cfg.addr = args.flags.get("addr").cloned();
    cfg.sessions = args.usize("sessions", cfg.sessions).max(1);
    cfg.workers = args.usize("workers", cfg.workers).max(1);
    cfg.bursts = args.usize("bursts", cfg.bursts).max(1);
    cfg.batch = args.usize("batch", cfg.batch).clamp(1, aaren::serve::MAX_STEPS_TOKENS);
    cfg.channels = args.usize("channels", cfg.channels).max(1);
    cfg.seed = args.u64("seed", cfg.seed);
    cfg.keep_every = args.usize("keep-every", cfg.keep_every);
    let trace = args.str("trace", cfg.kind.name());
    cfg.kind = ArrivalKind::from_name(&trace)
        .ok_or_else(|| anyhow::anyhow!("unknown --trace {trace:?} (poisson|onoff)"))?;
    let max_resident = args.usize("max-resident-sessions", 0);
    cfg.max_resident = (max_resident > 0).then_some(max_resident);

    let report = aaren::loadgen::run(&cfg)?;
    report.print();
    let records = report.capacity_records();
    let out = PathBuf::from(args.str("out", "BENCH_serve.json"));
    aaren::util::bench::print_table(
        "capacity records",
        &["record", "n", "ns_per_iter"],
        &records
            .iter()
            .map(|r| vec![r.name.clone(), r.n.to_string(), format!("{:.0}", r.ns_per_iter)])
            .collect::<Vec<_>>(),
    );
    merge_records(&out, "capacity_", &records)?;
    println!("merged {} capacity_* records into {}", records.len(), out.display());
    Ok(())
}

/// `aaren state export|import|inspect` — offline snapshot handling over
/// the `snapshot`/`restore` wire ops and the `persist::codec` framing.
fn state_cmd(args: &Args) -> Result<()> {
    use aaren::persist::codec;
    use aaren::serve::server::Client;
    use aaren::util::b64;

    let action = args.positional.get(1).map(String::as_str).unwrap_or("");
    match action {
        "export" => {
            let id = args.usize("id", 0);
            anyhow::ensure!(id > 0, "state export needs --id N (a live session id)");
            let addr: std::net::SocketAddr =
                args.str("addr", "127.0.0.1:7878").parse()?;
            let mut client = Client::connect(&addr)?;
            let reply = client.call(&format!(r#"{{"op":"snapshot","id":{id}}}"#))?;
            let blob = b64::decode(reply.str_field("state")?)?;
            let out = args.str("out", &format!("aaren-session-{id}.snap"));
            std::fs::write(&out, &blob)?;
            println!(
                "exported session {id} ({} at t={}, {} channels, {} bytes) -> {out}",
                reply.str_field("kind")?,
                reply.usize_field("t")?,
                reply.usize_field("channels")?,
                blob.len()
            );
            Ok(())
        }
        "import" => {
            let file = args.str("file", "");
            anyhow::ensure!(!file.is_empty(), "state import needs --file SNAPSHOT");
            let blob = std::fs::read(&file)?;
            // validate locally first: a corrupt file should fail here,
            // not as a confusing server-side reply
            codec::meta(&blob)?;
            let addr: std::net::SocketAddr =
                args.str("addr", "127.0.0.1:7878").parse()?;
            let mut client = Client::connect(&addr)?;
            // --id N asks the server to restore AT that id (refused if it
            // already exists); without it the server assigns a fresh one.
            // Parsed strictly: a malformed or zero --id must fail here,
            // not silently degrade into a fresh-id import
            let line = match args.flags.get("id") {
                None => format!(r#"{{"op":"restore","state":"{}"}}"#, b64::encode(&blob)),
                Some(raw) => {
                    let id: u64 = raw
                        .parse()
                        .ok()
                        .filter(|&id| id >= 1)
                        .ok_or_else(|| {
                            anyhow::anyhow!("--id must be a positive integer, got {raw:?}")
                        })?;
                    format!(r#"{{"op":"restore","state":"{}","id":{id}}}"#, b64::encode(&blob))
                }
            };
            let reply = client.call(&line)?;
            println!(
                "imported {file} as session {} ({} at t={}, {} channels)",
                reply.usize_field("id")?,
                reply.str_field("kind")?,
                reply.usize_field("t")?,
                reply.usize_field("channels")?
            );
            Ok(())
        }
        "inspect" => {
            let file = args.str("file", "");
            anyhow::ensure!(!file.is_empty(), "state inspect needs --file SNAPSHOT");
            let blob = std::fs::read(&file)?;
            let meta = codec::meta(&blob)?;
            println!(
                "{file}: {} session snapshot, codec v{}, {} channels, t={}, {} state floats, \
                 {} bytes, crc ok",
                meta.backend.kind(),
                codec::VERSION,
                meta.channels,
                meta.tokens_seen,
                meta.state_len,
                blob.len()
            );
            Ok(())
        }
        other => anyhow::bail!(
            "unknown state action {other:?} (export|import|inspect); run `aaren help`"
        ),
    }
}

#[cfg(not(feature = "pjrt"))]
fn bench_cmd(which: &str, args: &Args) -> Result<()> {
    match which {
        "fig5" | "all" => {
            if which == "all" {
                println!(
                    "note: table1-table4/params drive compiled HLO and need --features pjrt \
                     — running the rust-native fig5 bench only"
                );
            }
            let tokens = args.usize("tokens", 512);
            let channels = args.usize("channels", 8);
            aaren::bench_harness::run_fig5_native(tokens, channels).map(|_| ())
        }
        "table1" | "table2" | "table3" | "table4" | "params" => {
            anyhow::bail!("bench {which:?} drives compiled HLO — rebuild with `--features pjrt`")
        }
        other => anyhow::bail!("unknown bench {other:?}"),
    }
}

#[cfg(not(feature = "pjrt"))]
fn hlo_cmd(cmd: &str, _args: &Args) -> Result<()> {
    anyhow::bail!(
        "`{cmd}` executes compiled HLO artifacts — rebuild with `--features pjrt` on a \
         machine with XLA"
    )
}

fn help() {
    println!(
        "aaren — Attention as an RNN (Feng et al., 2024) reproduction\n\n\
         usage: aaren <command> [flags]\n\n\
         commands (default build, no XLA needed):\n  \
         serve --addr H:P      streaming inference server (line-JSON protocol)\n                        \
         --channels N   native session width (default 8)\n                        \
         --shards N     native executor pool size (default: cores, max 8)\n                        \
         --session-ttl-secs N  evict sessions idle > N seconds (default: never)\n                        \
         --spill-dir DIR       spill evicted sessions to disk, restore on touch\n                        \
         --max-resident-sessions N  LRU-spill beyond N resident (needs --spill-dir)\n                        \
         --scatter-drain       disable resident lanes (PR 3 gather/scatter drains)\n                        \
         --queue-depth N       bound each shard's queue; full = overloaded reply (default 256)\n                        \
         --max-conns N         cap concurrent connections (default: unbounded)\n                        \
         --io-timeout-secs N   per-connection read/write timeout (default: none)\n                        \
         --max-frame-bytes N   hard request-line size limit (default 16 MiB)\n                        \
         --fault-plan SPEC     seeded fault injection (chaos testing), e.g.\n                        \
                       seed=7,io=0.05,torn=0.2,panic=0.01,delay=0.5,delay-ms=2\n                        \
         --metrics-interval-secs N  per-op latency digest to stderr every N seconds\n                        \
         --no-telemetry        disable latency histograms + flight recorder\n                        \
         --smoke        loopback self-test, then exit\n                        \
         ops: create/step/steps/snapshot/restore/close/drain/ping/stats/metrics/shutdown\n                        \
         protocol: {{\"op\":\"create\",\"kind\":\"aaren\"|\"mingru\"|\"minlstm\"|\"avg_attn\"|\"tf\"\n                        \
                   [,\"backend\":\"native\"|\"hlo\"|<kernel>]}}\n  \
         fleet --addr H:P      consistent-hash router over N serve backends\n                        \
         --members H1:P1,H2:P2,...  backend addresses (required)\n                        \
         --weights W1,W2,...   per-member ring weights (default 1 each)\n                        \
         --spill-dir DIR       shared spill dir — the failover replay source\n                        \
         --hb-interval-ms N    heartbeat period (default 500)\n                        \
         --hb-timeout-ms N     per-probe timeout (default 1000)\n                        \
         --hb-misses N         misses before a member is dead (default 3)\n                        \
         --migrate-budget N    sessions migrated per tick (default 8)\n                        \
         --vnodes N            ring points per unit weight (default 64)\n                        \
         extra ops: ping/fleet_stats/fleet_join/fleet_leave/metrics\n  \
         state export --addr H:P --id N [--out F]   snapshot a live session to a file\n  \
         state import --addr H:P --file F [--id N]  restore a snapshot as a new session\n  \
         state inspect --file F                     decode a snapshot offline\n  \
         load                  capacity harness: seeded open-loop traffic replay\n                        \
         --addr H:P     target a live server/fleet (default: self-spawn loopback)\n                        \
         --quick        CI smoke shape, 2k sessions (default: 120k)\n                        \
         --sessions N --workers N --bursts N --batch N --channels N\n                        \
         --trace poisson|onoff  arrival process   --seed N  deterministic replay\n                        \
         --out FILE     merge capacity_* records into this trail (BENCH_serve.json)\n  \
         bench fig5            streaming memory/time shape (rust-native sessions)\n\n\
         commands needing --features pjrt + compiled artifacts:\n  \
         check                 smoke-run every artifact family\n  \
         info                  list artifacts\n  \
         train --domain D      train one cell (domains: tsf tsc ef rl stream)\n  \
         bench <table1|table2|table3|table4|params|all>\n\n\
         flags: --artifacts DIR  --model aaren|tf  --seeds N  --steps N\n       \
         --limit K  --horizons 96,192  --dataset NAME  --tokens N"
    );
}

#[cfg(feature = "pjrt")]
mod pjrt_cli {
    use std::path::PathBuf;

    use anyhow::{bail, Result};

    use aaren::bench_harness::{self, BenchOpts};
    use aaren::coordinator::experiments::{self, Kind};
    use aaren::data::{events, rl, tsc, tsf};
    use aaren::runtime::exec::Engine;
    use aaren::util::cli::Args;

    fn opts(args: &Args) -> BenchOpts {
        BenchOpts {
            seeds: args.u64("seeds", 2),
            train_steps: args.usize("steps", 150),
            limit: args.usize("limit", 0),
            artifacts: PathBuf::from(args.str("artifacts", "artifacts")),
        }
    }

    fn kind_of(args: &Args) -> Result<Kind> {
        match args.str("model", "aaren").as_str() {
            "aaren" => Ok(Kind::Aaren),
            "tf" | "transformer" => Ok(Kind::Tf),
            other => bail!("unknown --model {other:?} (aaren|tf)"),
        }
    }

    pub fn bench_cmd(which: &str, args: &Args) -> Result<()> {
        let o = opts(args);
        let horizons: Vec<usize> = args
            .str("horizons", "96,192,336,720")
            .split(',')
            .filter_map(|s| s.trim().parse().ok())
            .collect();
        match which {
            "table1" => bench_harness::run_table1(&o),
            "table2" => bench_harness::run_table2(&o),
            "table3" => bench_harness::run_table3(&o, &horizons),
            "table4" => bench_harness::run_table4(&o),
            "fig5" => bench_harness::run_fig5(&o.artifacts, args.usize("tokens", 512)).map(|_| ()),
            "params" => bench_harness::run_params(&o.artifacts),
            "all" => {
                bench_harness::run_table1(&o)?;
                bench_harness::run_table2(&o)?;
                bench_harness::run_table3(&o, &horizons)?;
                bench_harness::run_table4(&o)?;
                bench_harness::run_fig5(&o.artifacts, args.usize("tokens", 512))?;
                bench_harness::run_params(&o.artifacts)
            }
            other => bail!("unknown bench {other:?}"),
        }
    }

    pub fn hlo_cmd(cmd: &str, args: &Args) -> Result<()> {
        let o = opts(args);
        match cmd {
            "check" => bench_harness::tables::run_smoke(&o),
            "info" => info(&o),
            "train" => train(args, &o),
            other => bail!("unknown command {other:?}"),
        }
    }

    fn info(o: &BenchOpts) -> Result<()> {
        let mut names: Vec<String> = std::fs::read_dir(&o.artifacts)?
            .filter_map(|e| e.ok())
            .filter_map(|e| {
                e.file_name()
                    .to_str()
                    .and_then(|n| n.strip_suffix(".manifest.json").map(String::from))
            })
            .collect();
        names.sort();
        println!("{} artifacts in {:?}:", names.len(), o.artifacts);
        for name in names {
            let m = aaren::runtime::manifest::Manifest::load(&o.artifacts, &name)?;
            println!(
                "  {:<28} kind={:<5} args={:<3} params={:>8} state_bytes={}",
                m.name,
                m.kind,
                m.args.len(),
                m.param_elements(),
                m.state_bytes()
            );
        }
        Ok(())
    }

    fn train(args: &Args, o: &BenchOpts) -> Result<()> {
        let mut engine = Engine::new(&o.artifacts)?;
        let kind = kind_of(args)?;
        let seed = args.u64("seed", 1);
        let steps = o.train_steps;
        match args.str("domain", "tsf").as_str() {
            "tsf" => {
                let horizon = args.usize("horizon", 96);
                let ds = tsf::ALL
                    .into_iter()
                    .find(|d| d.name().eq_ignore_ascii_case(&args.str("dataset", "ETTh1")))
                    .unwrap_or(tsf::TsfDataset::Etth1);
                let r = experiments::run_tsf(&mut engine, kind, ds, horizon, steps, seed)?;
                println!(
                    "{} {} T={horizon}: MSE {:.3} MAE {:.3} (final train loss {:.4})",
                    kind.display(),
                    ds.name(),
                    r.mse,
                    r.mae,
                    r.final_train_loss
                );
            }
            "tsc" => {
                let ds = tsc::ALL
                    .into_iter()
                    .find(|d| d.name().eq_ignore_ascii_case(&args.str("dataset", "ArabicDigits")))
                    .unwrap_or(tsc::TscDataset::ArabicDigits);
                let r = experiments::run_tsc(&mut engine, kind, ds, steps, seed)?;
                println!(
                    "{} {}: Acc {:.2}% (final train loss {:.4})",
                    kind.display(),
                    ds.name(),
                    r.acc,
                    r.final_train_loss
                );
            }
            "ef" => {
                let ds = events::ALL
                    .into_iter()
                    .find(|d| d.name().eq_ignore_ascii_case(&args.str("dataset", "Sin")))
                    .unwrap_or(events::EfDataset::Sin);
                let r = experiments::run_ef(&mut engine, kind, ds, steps, seed)?;
                println!(
                    "{} {}: NLL {:.3} RMSE {:.3} Acc {:?} (final train loss {:.4})",
                    kind.display(),
                    ds.name(),
                    r.nll,
                    r.rmse,
                    r.acc,
                    r.final_train_loss
                );
            }
            "rl" => {
                let env = rl::ALL_ENVS
                    .into_iter()
                    .find(|e| e.name().eq_ignore_ascii_case(&args.str("dataset", "Hopper")))
                    .unwrap_or(rl::EnvId::Hopper);
                let tier = match args.str("tier", "medium").as_str() {
                    "medium" => rl::Tier::Medium,
                    "medium-replay" | "replay" => rl::Tier::MediumReplay,
                    "medium-expert" | "expert" => rl::Tier::MediumExpert,
                    other => bail!("unknown tier {other:?}"),
                };
                let r = experiments::run_rl(
                    &mut engine,
                    kind,
                    env,
                    tier,
                    steps,
                    args.usize("episodes", 40),
                    args.usize("rollouts", 3),
                    seed,
                )?;
                println!(
                    "{} {} {}: normalised score {:.1} (raw return {:.2}, final loss {:.4})",
                    kind.display(),
                    env.name(),
                    tier.name(),
                    r.normalised_score,
                    r.raw_return,
                    r.final_train_loss
                );
            }
            other => bail!("unknown --domain {other:?}"),
        }
        Ok(())
    }
}
