//! Line-delimited JSON streaming server over [`StreamSession`] trait
//! objects — the rust-native serving stack, no XLA required.
//!
//! Protocol (one JSON object per line):
//!   -> {"op":"create","kind":"aaren"|"tf"[,"backend":"native"|"hlo"]} <- {"id":N}
//!   -> {"op":"step","id":N,"x":[f32;channels]}   <- {"y":[...],"state_bytes":B,"t":T}
//!   -> {"op":"close","id":N}                     <- {"ok":true}
//!   -> {"op":"stats"}                            <- {"sessions":K,"total_state_bytes":B}
//!   -> {"op":"shutdown"}                         <- {"ok":true}
//!
//! Architecture: connection handler threads parse requests and hand them
//! to a [`Router`], which forwards each to an executor over an mpsc
//! channel and waits on a per-request reply channel. Native sessions are
//! plain `Send` Rust data, so they are served by a **sharded executor
//! pool** — `shards` worker threads, each owning the sessions pinned to
//! it by `id % shards` — instead of the single-executor bottleneck the
//! PJRT tier needs. HLO sessions (whose PJRT handles are not `Send`,
//! `pjrt` builds only) stay on one dedicated executor thread; the session
//! id's namespace encodes the route, so no shared routing table exists.

use std::collections::BTreeMap;
use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{IpAddr, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc};

use anyhow::{anyhow, bail, ensure, Result};

use crate::serve::session::{NativeAarenSession, NativeTfSession, StreamSession};
use crate::util::json::Json;

/// A request as an executor sees it (ids are assigned by the router
/// before dispatch, so `Create` already carries one).
pub enum Request {
    Create { id: u64, kind: String },
    Step { id: u64, x: Vec<f32> },
    Close { id: u64 },
    Stats,
    Shutdown,
}

/// What an executor sends back. Shutdown is a first-class variant of the
/// reply path — not an error-message sentinel to be string-matched.
pub enum Response {
    /// The wire-level reply body.
    Value(Json),
    /// Per-shard stats, aggregated by the router before hitting the wire.
    Stats { sessions: usize, state_bytes: usize },
    /// The executor acknowledges shutdown and exits its loop.
    ShuttingDown,
}

pub type Reply = Result<Response>;

/// A request plus the channel its reply goes back on.
pub type Envelope = (Request, mpsc::Sender<Reply>);
pub type ReqTx = mpsc::Sender<Envelope>;
pub type ReqRx = mpsc::Receiver<Envelope>;

/// Which executor family a `create` lands on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// Rust-native sessions on the sharded executor pool (default).
    Native,
    /// Compiled-HLO sessions on the dedicated PJRT executor (`pjrt`
    /// builds started with artifacts).
    Hlo,
}

/// Session-id namespace split: ids below the base are native (routed to
/// shard `id % shards`), ids at or above it belong to the HLO executor —
/// the route is a pure function of the id.
const HLO_ID_BASE: u64 = 1 << 32;

/// Creates the sessions one executor owns; each executor family brings
/// its own factory (native widths vs loaded HLO models).
pub trait SessionFactory {
    fn create(&mut self, kind: &str) -> Result<Box<dyn StreamSession>>;
}

/// Factory for the rust-native tier: sessions over `channels`-dim tokens.
pub struct NativeFactory {
    pub channels: usize,
}

impl SessionFactory for NativeFactory {
    fn create(&mut self, kind: &str) -> Result<Box<dyn StreamSession>> {
        match kind {
            "aaren" => Ok(Box::new(NativeAarenSession::new(self.channels))),
            "tf" => Ok(Box::new(NativeTfSession::new(self.channels))),
            other => Err(anyhow!("unknown kind {other:?} (aaren|tf)")),
        }
    }
}

fn obj(entries: Vec<(&str, Json)>) -> Json {
    Json::Obj(entries.into_iter().map(|(k, v)| (k.to_string(), v)).collect::<BTreeMap<_, _>>())
}

/// One executor shard: owns a private id → session map and serves
/// requests from its channel until a `Shutdown` request arrives
/// (acknowledged with [`Response::ShuttingDown`]).
pub fn run_executor<F: SessionFactory>(mut factory: F, rx: ReqRx) {
    let mut sessions: HashMap<u64, Box<dyn StreamSession>> = HashMap::new();
    while let Ok((req, reply)) = rx.recv() {
        let resp: Reply = match req {
            Request::Create { id, kind } => factory.create(&kind).map(|session| {
                sessions.insert(id, session);
                Response::Value(obj(vec![("id", Json::Num(id as f64))]))
            }),
            Request::Step { id, x } => step_session(&mut sessions, id, &x),
            Request::Close { id } => sessions
                .remove(&id)
                .map(|_| Response::Value(obj(vec![("ok", Json::Bool(true))])))
                .ok_or_else(|| anyhow!("no session {id}")),
            Request::Stats => Ok(Response::Stats {
                sessions: sessions.len(),
                state_bytes: sessions.values().map(|s| s.state_bytes()).sum(),
            }),
            Request::Shutdown => Ok(Response::ShuttingDown),
        };
        let shutting_down = matches!(resp, Ok(Response::ShuttingDown));
        let _ = reply.send(resp);
        if shutting_down {
            break;
        }
    }
}

fn step_session(sessions: &mut HashMap<u64, Box<dyn StreamSession>>, id: u64, x: &[f32]) -> Reply {
    let session = sessions.get_mut(&id).ok_or_else(|| anyhow!("no session {id}"))?;
    let y = session.step(x)?;
    Ok(Response::Value(obj(vec![
        ("y", Json::Arr(y.into_iter().map(|v| Json::Num(v as f64)).collect())),
        ("state_bytes", Json::Num(session.state_bytes() as f64)),
        ("t", Json::Num(session.tokens_seen() as f64)),
    ])))
}

/// Server configuration; `Default` serves rust-native sessions on
/// 127.0.0.1:7878 with one shard per core (capped).
#[derive(Clone, Debug)]
pub struct ServeConfig {
    pub addr: String,
    /// channel width of rust-native sessions created by this server
    pub channels: usize,
    /// number of native executor shards (worker threads)
    pub shards: usize,
    /// artifacts dir enabling the compiled-HLO backend (`pjrt` builds
    /// only; ignored otherwise)
    pub artifacts: Option<std::path::PathBuf>,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            addr: "127.0.0.1:7878".to_string(),
            channels: 8,
            shards: std::thread::available_parallelism().map(|t| t.get().min(8)).unwrap_or(4),
            artifacts: None,
        }
    }
}

/// Routes wire requests to executor shards and aggregates fan-out ops.
pub struct Router {
    shards: Vec<ReqTx>,
    hlo: Option<ReqTx>,
    next_native_id: AtomicU64,
    next_hlo_id: AtomicU64,
    shutdown: AtomicBool,
}

fn call_on(tx: &ReqTx, req: Request) -> Reply {
    let (rtx, rrx) = mpsc::channel();
    tx.send((req, rtx)).map_err(|_| anyhow!("executor thread gone"))?;
    rrx.recv().map_err(|_| anyhow!("executor dropped reply"))?
}

impl Router {
    /// Spawn the executor pool described by `cfg` and return the router
    /// over it.
    pub fn start(cfg: &ServeConfig) -> Result<Router> {
        let nshards = cfg.shards.max(1);
        let mut shards = Vec::with_capacity(nshards);
        for s in 0..nshards {
            let (tx, rx) = mpsc::channel();
            let channels = cfg.channels;
            std::thread::Builder::new()
                .name(format!("serve-exec-{s}"))
                .spawn(move || run_executor(NativeFactory { channels }, rx))?;
            shards.push(tx);
        }
        #[cfg(feature = "pjrt")]
        let hlo = match &cfg.artifacts {
            Some(dir) => {
                let (tx, rx) = mpsc::channel();
                let dir = dir.clone();
                std::thread::Builder::new().name("serve-exec-hlo".to_string()).spawn(
                    move || match hlo_backend::HloFactory::new(&dir) {
                        Ok(factory) => run_executor(factory, rx),
                        // dropping rx makes every later hlo request fail
                        // with "executor thread gone" instead of hanging
                        Err(e) => eprintln!("[serve] hlo backend unavailable: {e:#}"),
                    },
                )?;
                Some(tx)
            }
            None => None,
        };
        #[cfg(not(feature = "pjrt"))]
        let hlo: Option<ReqTx> = None;
        Ok(Router {
            shards,
            hlo,
            next_native_id: AtomicU64::new(1),
            next_hlo_id: AtomicU64::new(0),
            shutdown: AtomicBool::new(false),
        })
    }

    pub fn is_shutdown(&self) -> bool {
        self.shutdown.load(Ordering::SeqCst)
    }

    fn create_target(&self, backend: Backend) -> Result<(&ReqTx, u64)> {
        match backend {
            Backend::Native => {
                let id = self.next_native_id.fetch_add(1, Ordering::Relaxed);
                Ok((&self.shards[(id as usize) % self.shards.len()], id))
            }
            Backend::Hlo => {
                let msg = if cfg!(feature = "pjrt") {
                    "server started without HLO artifacts (pass --artifacts DIR)"
                } else {
                    "this build has no HLO backend (rebuild with --features pjrt)"
                };
                let tx = self.hlo.as_ref().ok_or_else(|| anyhow!(msg))?;
                let id = HLO_ID_BASE + self.next_hlo_id.fetch_add(1, Ordering::Relaxed);
                Ok((tx, id))
            }
        }
    }

    fn route(&self, id: u64) -> Result<&ReqTx> {
        if id >= HLO_ID_BASE {
            self.hlo.as_ref().ok_or_else(|| anyhow!("no session {id}"))
        } else {
            Ok(&self.shards[(id as usize) % self.shards.len()])
        }
    }

    fn targets(&self) -> impl Iterator<Item = &ReqTx> + '_ {
        self.shards.iter().chain(self.hlo.iter())
    }

    /// Execute one wire request, fanning out / aggregating where the op
    /// spans shards (`stats`, `shutdown`).
    pub fn dispatch(&self, op: WireOp) -> Result<Json> {
        match op {
            WireOp::Create { kind, backend } => {
                let (tx, id) = self.create_target(backend)?;
                match call_on(tx, Request::Create { id, kind })? {
                    Response::Value(j) => Ok(j),
                    _ => bail!("unexpected reply to create"),
                }
            }
            WireOp::Step { id, x } => match call_on(self.route(id)?, Request::Step { id, x })? {
                Response::Value(j) => Ok(j),
                _ => bail!("unexpected reply to step"),
            },
            WireOp::Close { id } => match call_on(self.route(id)?, Request::Close { id })? {
                Response::Value(j) => Ok(j),
                _ => bail!("unexpected reply to close"),
            },
            WireOp::Stats => {
                let (mut count, mut bytes) = (0usize, 0usize);
                for tx in self.targets() {
                    // a dead executor contributes nothing instead of
                    // failing the whole aggregate
                    if let Ok(Response::Stats { sessions, state_bytes }) =
                        call_on(tx, Request::Stats)
                    {
                        count += sessions;
                        bytes += state_bytes;
                    }
                }
                Ok(obj(vec![
                    ("sessions", Json::Num(count as f64)),
                    ("total_state_bytes", Json::Num(bytes as f64)),
                ]))
            }
            WireOp::Shutdown => {
                for tx in self.targets() {
                    let _ = call_on(tx, Request::Shutdown);
                }
                self.shutdown.store(true, Ordering::SeqCst);
                Ok(obj(vec![("ok", Json::Bool(true))]))
            }
        }
    }
}

/// A request as it arrives on the wire, before the router assigns ids.
pub enum WireOp {
    Create { kind: String, backend: Backend },
    Step { id: u64, x: Vec<f32> },
    Close { id: u64 },
    Stats,
    Shutdown,
}

fn parse_request(line: &str) -> Result<WireOp> {
    let j = Json::parse(line).map_err(|e| anyhow!("bad json: {e}"))?;
    match j.str_field("op")? {
        "create" => {
            let backend = match j.get("backend").and_then(Json::as_str) {
                None | Some("native") => Backend::Native,
                Some("hlo") => Backend::Hlo,
                Some(other) => bail!("unknown backend {other:?} (native|hlo)"),
            };
            Ok(WireOp::Create { kind: j.str_field("kind")?.to_string(), backend })
        }
        "step" => {
            let id = j.usize_field("id")? as u64;
            let arr = j.get("x").and_then(Json::as_arr).ok_or_else(|| anyhow!("missing x"))?;
            let mut x = Vec::with_capacity(arr.len());
            for (i, v) in arr.iter().enumerate() {
                // reject instead of coercing to NaN/inf: one such value
                // would poison the session's (m, u, w) state for every
                // later step and make the reply line unprintable as JSON.
                // Validate AFTER the f32 cast — a finite f64 like 1e40
                // still saturates to +inf in f32.
                let f = v.as_f64().ok_or_else(|| anyhow!("x[{i}] is not a number"))? as f32;
                if !f.is_finite() {
                    bail!("x[{i}] is not a finite f32");
                }
                x.push(f);
            }
            Ok(WireOp::Step { id, x })
        }
        "close" => Ok(WireOp::Close { id: j.usize_field("id")? as u64 }),
        "stats" => Ok(WireOp::Stats),
        "shutdown" => Ok(WireOp::Shutdown),
        other => Err(anyhow!("unknown op {other:?}")),
    }
}

fn handle_conn(stream: TcpStream, router: &Router, wake_addr: Option<SocketAddr>) {
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = match line {
            Ok(l) => l,
            Err(_) => break,
        };
        if line.trim().is_empty() {
            continue;
        }
        let resp = parse_request(&line).and_then(|op| router.dispatch(op));
        let body = match resp {
            Ok(j) => j.to_string(),
            Err(e) => obj(vec![("error", Json::Str(format!("{e:#}")))]).to_string(),
        };
        if writer.write_all(body.as_bytes()).is_err() || writer.write_all(b"\n").is_err() {
            break;
        }
        if router.is_shutdown() {
            break;
        }
    }
    if router.is_shutdown() {
        // wake the accept loop so Server::run can observe the flag; a
        // listener bound to the unspecified address (0.0.0.0 / ::) is not
        // connectable on every platform, so rewrite to its loopback
        if let Some(mut addr) = wake_addr {
            if addr.ip().is_unspecified() {
                addr.set_ip(match addr.ip() {
                    IpAddr::V4(_) => IpAddr::V4(std::net::Ipv4Addr::LOCALHOST),
                    IpAddr::V6(_) => IpAddr::V6(std::net::Ipv6Addr::LOCALHOST),
                });
            }
            let _ = TcpStream::connect(addr);
        }
    }
}

/// A bound listener plus its executor pool. `run` serves until a
/// `shutdown` request arrives.
pub struct Server {
    listener: TcpListener,
    router: Arc<Router>,
}

impl Server {
    pub fn bind(cfg: &ServeConfig) -> Result<Server> {
        let listener = TcpListener::bind(cfg.addr.as_str())?;
        let router = Arc::new(Router::start(cfg)?);
        Ok(Server { listener, router })
    }

    pub fn local_addr(&self) -> Result<SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Accept connections (one handler thread each) until shutdown.
    pub fn run(&self) -> Result<()> {
        let wake_addr = self.listener.local_addr().ok();
        for stream in self.listener.incoming() {
            if self.router.is_shutdown() {
                break;
            }
            match stream {
                Ok(s) => {
                    let router = Arc::clone(&self.router);
                    std::thread::spawn(move || handle_conn(s, &router, wake_addr));
                }
                Err(e) => eprintln!("[serve] accept error: {e}"),
            }
        }
        Ok(())
    }
}

/// Serve forever on `cfg.addr` (e.g. "127.0.0.1:7878").
pub fn serve(cfg: &ServeConfig) -> Result<()> {
    let server = Server::bind(cfg)?;
    println!(
        "[serve] listening on {} ({} native executor shard(s); line-delimited JSON; \
         ops: create/step/close/stats/shutdown)",
        server.local_addr()?,
        cfg.shards.max(1)
    );
    server.run()
}

/// Minimal blocking line-JSON client over one TCP connection — used by
/// the CLI `serve --smoke` self-test, the loopback integration tests and
/// the `serve_loopback` bench.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: &SocketAddr) -> Result<Client> {
        let stream = TcpStream::connect(addr)?;
        let writer = stream.try_clone()?;
        Ok(Client { reader: BufReader::new(stream), writer })
    }

    /// Send one request line, read one reply line, parse it. Replies
    /// carrying an `"error"` field become `Err`.
    pub fn call(&mut self, line: &str) -> Result<Json> {
        let reply = self.call_raw(line)?;
        if let Some(e) = reply.get("error").and_then(Json::as_str) {
            bail!("server error: {e}");
        }
        Ok(reply)
    }

    /// Like [`call`](Client::call) but returns error replies as plain
    /// objects (protocol tests inspect them).
    pub fn call_raw(&mut self, line: &str) -> Result<Json> {
        self.writer.write_all(line.as_bytes())?;
        self.writer.write_all(b"\n")?;
        let mut buf = String::new();
        if self.reader.read_line(&mut buf)? == 0 {
            bail!("server closed the connection");
        }
        Json::parse(buf.trim()).map_err(|e| anyhow!("bad reply {buf:?}: {e}"))
    }
}

/// One loopback self-test for CI: bind an ephemeral port, run a
/// create/step/stats/shutdown round-trip over both native session kinds,
/// and shut the server down. Errors if any reply is wrong.
pub fn run_smoke(base: &ServeConfig) -> Result<()> {
    let mut cfg = base.clone();
    cfg.addr = "127.0.0.1:0".to_string();
    let channels = cfg.channels;
    let server = Server::bind(&cfg)?;
    let addr = server.local_addr()?;
    let run = std::thread::spawn(move || server.run());

    let mut client = Client::connect(&addr)?;
    let xs: Vec<String> = (0..channels).map(|i| format!("{}.5", i % 3)).collect();
    let x = xs.join(",");
    let aaren = client.call(r#"{"op":"create","kind":"aaren"}"#)?.usize_field("id")?;
    let tf = client.call(r#"{"op":"create","kind":"tf"}"#)?.usize_field("id")?;
    let mut aaren_bytes = Vec::new();
    for _ in 0..8 {
        let r = client.call(&format!(r#"{{"op":"step","id":{aaren},"x":[{x}]}}"#))?;
        aaren_bytes.push(r.usize_field("state_bytes")?);
        client.call(&format!(r#"{{"op":"step","id":{tf},"x":[{x}]}}"#))?;
    }
    ensure!(
        aaren_bytes.windows(2).all(|w| w[0] == w[1]),
        "aaren state must be constant, got {aaren_bytes:?}"
    );
    let stats = client.call(r#"{"op":"stats"}"#)?;
    ensure!(stats.usize_field("sessions")? == 2, "expected 2 live sessions");
    client.call(r#"{"op":"shutdown"}"#)?;
    run.join().map_err(|_| anyhow!("server thread panicked"))??;
    println!(
        "[serve] smoke ok: aaren + tf sessions served on {addr}, aaren state constant at {} bytes",
        aaren_bytes[0]
    );
    Ok(())
}

#[cfg(feature = "pjrt")]
mod hlo_backend {
    use std::rc::Rc;

    use anyhow::{anyhow, Result};

    use super::SessionFactory;
    use crate::runtime::exec::Engine;
    use crate::serve::session::{BoundSession, StreamModel, StreamSession};

    /// Factory for the compiled-HLO tier: loads both stream models once
    /// and binds every created session to them. Lives (with its engine)
    /// on the dedicated HLO executor thread — PJRT handles are not Send.
    pub struct HloFactory {
        _engine: Engine,
        aaren: Rc<StreamModel>,
        tf: Rc<StreamModel>,
    }

    impl HloFactory {
        pub fn new(artifacts: &std::path::Path) -> Result<HloFactory> {
            let mut engine = Engine::new(artifacts)?;
            let aaren = Rc::new(StreamModel::load_aaren(&mut engine)?);
            let tf = Rc::new(StreamModel::load_tf(&mut engine)?);
            Ok(HloFactory { _engine: engine, aaren, tf })
        }
    }

    impl SessionFactory for HloFactory {
        fn create(&mut self, kind: &str) -> Result<Box<dyn StreamSession>> {
            match kind {
                "aaren" => Ok(Box::new(BoundSession::new_aaren(Rc::clone(&self.aaren))?)),
                "tf" => Ok(Box::new(BoundSession::new_tf(Rc::clone(&self.tf))?)),
                other => Err(anyhow!("unknown kind {other:?} (aaren|tf)")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_protocol_requests() {
        match parse_request(r#"{"op":"create","kind":"aaren"}"#).unwrap() {
            WireOp::Create { kind, backend } => {
                assert_eq!(kind, "aaren");
                assert_eq!(backend, Backend::Native);
            }
            _ => panic!("wrong variant"),
        }
        match parse_request(r#"{"op":"create","kind":"tf","backend":"hlo"}"#).unwrap() {
            WireOp::Create { backend, .. } => assert_eq!(backend, Backend::Hlo),
            _ => panic!("wrong variant"),
        }
        match parse_request(r#"{"op":"step","id":3,"x":[1.0,-2.5]}"#).unwrap() {
            WireOp::Step { id, x } => {
                assert_eq!(id, 3);
                assert_eq!(x, vec![1.0, -2.5]);
            }
            _ => panic!("wrong variant"),
        }
        assert!(parse_request(r#"{"op":"create","kind":"aaren","backend":"tpu"}"#).is_err());
        assert!(parse_request(r#"{"op":"bogus"}"#).is_err());
        assert!(parse_request("not json").is_err());
        // non-numeric / non-finite-in-f32 token elements are rejected,
        // not coerced to NaN or saturated to infinity
        assert!(parse_request(r#"{"op":"step","id":1,"x":[1.0,null]}"#).is_err());
        assert!(parse_request(r#"{"op":"step","id":1,"x":[1.0,"2.0"]}"#).is_err());
        assert!(parse_request(r#"{"op":"step","id":1,"x":[1e40]}"#).is_err());
    }

    #[test]
    fn obj_builder_emits_valid_json() {
        let j = obj(vec![("a", Json::Num(1.0)), ("b", Json::Bool(true))]);
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.usize_field("a").unwrap(), 1);
    }

    fn test_router(shards: usize) -> Router {
        let cfg = ServeConfig { addr: String::new(), channels: 4, shards, artifacts: None };
        Router::start(&cfg).unwrap()
    }

    #[test]
    fn router_shards_sessions_and_aggregates_stats() {
        let router = test_router(3);
        let mut ids = Vec::new();
        for _ in 0..5 {
            let r = router
                .dispatch(WireOp::Create { kind: "aaren".into(), backend: Backend::Native })
                .unwrap();
            ids.push(r.usize_field("id").unwrap() as u64);
        }
        // ids are distinct and deterministically pinned across shards
        for (i, id) in ids.iter().enumerate() {
            assert_eq!(*id, i as u64 + 1);
        }
        for &id in &ids {
            let r = router.dispatch(WireOp::Step { id, x: vec![0.5; 4] }).unwrap();
            assert_eq!(r.usize_field("t").unwrap(), 1);
        }
        let stats = router.dispatch(WireOp::Stats).unwrap();
        assert_eq!(stats.usize_field("sessions").unwrap(), 5);
        assert!(stats.usize_field("total_state_bytes").unwrap() > 0);
        router.dispatch(WireOp::Close { id: ids[0] }).unwrap();
        let stats = router.dispatch(WireOp::Stats).unwrap();
        assert_eq!(stats.usize_field("sessions").unwrap(), 4);
        assert!(router.dispatch(WireOp::Step { id: ids[0], x: vec![0.5; 4] }).is_err());
        router.dispatch(WireOp::Shutdown).unwrap();
        assert!(router.is_shutdown());
    }

    #[test]
    fn hlo_backend_unavailable_without_artifacts() {
        let router = test_router(1);
        let err = router
            .dispatch(WireOp::Create { kind: "aaren".into(), backend: Backend::Hlo })
            .unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("pjrt") || msg.contains("artifacts"), "got: {msg}");
        router.dispatch(WireOp::Shutdown).unwrap();
    }

    #[test]
    fn unknown_kind_is_reported_not_fatal() {
        let router = test_router(1);
        assert!(router
            .dispatch(WireOp::Create { kind: "mamba".into(), backend: Backend::Native })
            .is_err());
        // the executor is still alive and serving
        let r = router
            .dispatch(WireOp::Create { kind: "tf".into(), backend: Backend::Native })
            .unwrap();
        assert!(r.usize_field("id").unwrap() >= 1);
        router.dispatch(WireOp::Shutdown).unwrap();
    }
}
