//! Line-delimited JSON streaming server.
//!
//! Protocol (one JSON object per line):
//!   -> {"op":"create","kind":"aaren"|"tf"}          <- {"id":N}
//!   -> {"op":"step","id":N,"x":[f32;channels]}      <- {"y":[...],"state_bytes":B,"t":T}
//!   -> {"op":"close","id":N}                        <- {"ok":true}
//!   -> {"op":"stats"}                                <- {"sessions":K,"total_state_bytes":B}
//!
//! PJRT handles are single-threaded, so one executor thread owns the
//! engine + sessions; connection handler threads forward requests over an
//! mpsc channel and wait on a per-request reply channel (a minimal
//! router/worker split, the shape vLLM-style serving uses).

use std::collections::BTreeMap;
use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::path::Path;
use std::sync::mpsc;

use anyhow::{anyhow, Result};

use crate::runtime::exec::Engine;
use crate::serve::session::{Session, StreamModel};
use crate::util::json::Json;

pub enum Request {
    Create { kind: String },
    Step { id: u64, x: Vec<f32> },
    Close { id: u64 },
    Stats,
    Shutdown,
}

pub type Reply = Result<Json>;

pub struct ServerHandle {
    pub tx: mpsc::Sender<(Request, mpsc::Sender<Reply>)>,
}

impl ServerHandle {
    pub fn call(&self, req: Request) -> Reply {
        let (rtx, rrx) = mpsc::channel();
        self.tx
            .send((req, rtx))
            .map_err(|_| anyhow!("executor thread gone"))?;
        rrx.recv().map_err(|_| anyhow!("executor dropped reply"))?
    }
}

fn obj(entries: Vec<(&str, Json)>) -> Json {
    Json::Obj(entries.into_iter().map(|(k, v)| (k.to_string(), v)).collect::<BTreeMap<_, _>>())
}

/// The executor: owns engine, models and all sessions. Runs until a
/// Shutdown request arrives.
pub fn run_executor(
    artifacts: &Path,
    rx: mpsc::Receiver<(Request, mpsc::Sender<Reply>)>,
) -> Result<()> {
    let mut engine = Engine::new(artifacts)?;
    let aaren = StreamModel::load_aaren(&mut engine)?;
    let tf = StreamModel::load_tf(&mut engine)?;
    let mut sessions: HashMap<u64, (Session, bool)> = HashMap::new(); // bool: is_aaren
    let mut next_id = 1u64;

    while let Ok((req, reply)) = rx.recv() {
        let resp: Reply = (|| match req {
            Request::Create { kind } => {
                let (session, is_aaren) = match kind.as_str() {
                    "aaren" => (Session::new_aaren(&aaren)?, true),
                    "tf" => (Session::new_tf(&tf)?, false),
                    other => return Err(anyhow!("unknown kind {other:?}")),
                };
                let id = next_id;
                next_id += 1;
                sessions.insert(id, (session, is_aaren));
                Ok(obj(vec![("id", Json::Num(id as f64))]))
            }
            Request::Step { id, x } => {
                let (session, is_aaren) =
                    sessions.get_mut(&id).ok_or_else(|| anyhow!("no session {id}"))?;
                let model = if *is_aaren { &aaren } else { &tf };
                let y = session.step(model, &x)?;
                Ok(obj(vec![
                    ("y", Json::Arr(y.into_iter().map(|v| Json::Num(v as f64)).collect())),
                    ("state_bytes", Json::Num(session.state_bytes() as f64)),
                    ("t", Json::Num(session.tokens_seen() as f64)),
                ]))
            }
            Request::Close { id } => {
                sessions
                    .remove(&id)
                    .ok_or_else(|| anyhow!("no session {id}"))?;
                Ok(obj(vec![("ok", Json::Bool(true))]))
            }
            Request::Stats => {
                let total: usize = sessions.values().map(|(s, _)| s.state_bytes()).sum();
                Ok(obj(vec![
                    ("sessions", Json::Num(sessions.len() as f64)),
                    ("total_state_bytes", Json::Num(total as f64)),
                ]))
            }
            Request::Shutdown => Err(anyhow!("__shutdown__")),
        })();
        match &resp {
            Err(e) if e.to_string() == "__shutdown__" => {
                let _ = reply.send(Ok(obj(vec![("ok", Json::Bool(true))])));
                break;
            }
            _ => {
                let _ = reply.send(resp);
            }
        }
    }
    Ok(())
}

fn parse_request(line: &str) -> Result<Request> {
    let j = Json::parse(line).map_err(|e| anyhow!("bad json: {e}"))?;
    match j.str_field("op")? {
        "create" => Ok(Request::Create { kind: j.str_field("kind")?.to_string() }),
        "step" => {
            let id = j.usize_field("id")? as u64;
            let x = j
                .get("x")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("missing x"))?
                .iter()
                .map(|v| v.as_f64().unwrap_or(f64::NAN) as f32)
                .collect();
            Ok(Request::Step { id, x })
        }
        "close" => Ok(Request::Close { id: j.usize_field("id")? as u64 }),
        "stats" => Ok(Request::Stats),
        "shutdown" => Ok(Request::Shutdown),
        other => Err(anyhow!("unknown op {other:?}")),
    }
}

fn handle_conn(stream: TcpStream, handle: &ServerHandle) {
    let peer = stream.peer_addr().ok();
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = match line {
            Ok(l) => l,
            Err(_) => break,
        };
        if line.trim().is_empty() {
            continue;
        }
        let resp = parse_request(&line).and_then(|req| handle.call(req));
        let body = match resp {
            Ok(j) => j.to_string(),
            Err(e) => obj(vec![("error", Json::Str(format!("{e}")))]).to_string(),
        };
        if writer.write_all(body.as_bytes()).is_err()
            || writer.write_all(b"\n").is_err()
        {
            break;
        }
    }
    let _ = peer;
}

/// Serve forever on `addr` (e.g. "127.0.0.1:7878").
pub fn serve(artifacts: &Path, addr: &str) -> Result<()> {
    let (tx, rx) = mpsc::channel();
    let handle = ServerHandle { tx };
    let dir = artifacts.to_path_buf();
    let executor = std::thread::spawn(move || run_executor(&dir, rx));

    let listener = TcpListener::bind(addr)?;
    println!("[serve] listening on {addr} (line-delimited JSON; ops: create/step/close/stats)");
    for stream in listener.incoming() {
        match stream {
            Ok(s) => {
                let h = ServerHandle { tx: handle.tx.clone() };
                std::thread::spawn(move || handle_conn(s, &h));
            }
            Err(e) => eprintln!("[serve] accept error: {e}"),
        }
    }
    drop(handle);
    executor.join().ok();
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_protocol_requests() {
        assert!(matches!(
            parse_request(r#"{"op":"create","kind":"aaren"}"#).unwrap(),
            Request::Create { .. }
        ));
        match parse_request(r#"{"op":"step","id":3,"x":[1.0,-2.5]}"#).unwrap() {
            Request::Step { id, x } => {
                assert_eq!(id, 3);
                assert_eq!(x, vec![1.0, -2.5]);
            }
            _ => panic!("wrong variant"),
        }
        assert!(parse_request(r#"{"op":"bogus"}"#).is_err());
        assert!(parse_request("not json").is_err());
    }

    #[test]
    fn obj_builder_emits_valid_json() {
        let j = obj(vec![("a", Json::Num(1.0)), ("b", Json::Bool(true))]);
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.usize_field("a").unwrap(), 1);
    }
}
