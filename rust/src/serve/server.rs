//! Line-delimited JSON streaming server over [`StreamSession`] trait
//! objects — the rust-native serving stack, no XLA required.
//!
//! Protocol (one JSON object per line):
//!   -> {"op":"create","kind":"aaren"|"mingru"|"minlstm"|"avg_attn"|"tf"
//!                     [,"backend":"native"|"hlo"|<kernel name>][,"id":N]} <- {"id":N}
//!      (a kernel name as "backend" is shorthand for the native tier
//!       running that kernel; "kind" may then be omitted)
//!   -> {"op":"step","id":N,"x":[f32;channels]}   <- {"y":[...],"state_bytes":B,"t":T}
//!   -> {"op":"steps","id":N,"xs":[[f32;channels];n]} <- {"ys":[[...];n],"state_bytes":B,"t":T}
//!      (n > STEPS_REPLY_BLOCK streams several reply lines, all but the
//!       last carrying "partial":true)
//!   -> {"op":"snapshot","id":N}                  <- {"state":"<base64>","kind":K,"channels":D,"t":T,"bytes":B}
//!   -> {"op":"restore","state":"<base64>"[,"id":M]} <- {"id":M,"kind":K,"channels":D,"t":T}
//!   -> {"op":"close","id":N}                     <- {"ok":true}
//!   -> {"op":"stats"}                            <- {"sessions":K,"total_state_bytes":B,"spilled":S,
//!                                                    "quarantined":Q,"corrupt_snapshots":C,
//!                                                    "spills":V,"restores":R,
//!                                                    "overloaded_rejects":O,"accept_errors":A,
//!                                                    "backends":{<name>:{"resident":R,"spilled":P},…}}
//!      ("spills"/"restores" are cumulative spill-tier traffic since
//!       start; "spilled" is the store's current population)
//!   -> {"op":"metrics"}                          <- {"histograms":{<stage>:{"count":N,"p50_ns":…,
//!                                                    "p99_ns":…,"max_ns":…,"buckets":{…}},…},
//!                                                    "counters":{…},"events":[{"seq":…,"ts_ms":…,
//!                                                    "kind":K,"id":N,"shard":S},…]}
//!   -> {"op":"shutdown"}                         <- {"ok":true}
//!
//! Error replies are structured:
//!   {"error":{"kind":K,"message":M[,"retry_after_ms":N]}}
//! with kind ∈ {"quarantined","overloaded","corrupt_snapshot",
//! "frame_too_large","no_session","error"}; `retry_after_ms` rides on
//! `overloaded` only. See `serve/mod.rs` for the full wire contract.
//!
//! Architecture: connection handler threads parse requests and hand them
//! to a [`Router`], which forwards each to an executor over an mpsc
//! channel and waits on a per-request reply channel. Native sessions are
//! plain `Send` Rust data, so they are served by a **sharded executor
//! pool** — `shards` worker threads, each owning the sessions pinned to
//! it by `id % shards` — instead of the single-executor bottleneck the
//! PJRT tier needs. HLO sessions (whose PJRT handles are not `Send`,
//! `pjrt` builds only) stay on one dedicated executor thread; the session
//! id's namespace encodes the route, so no shared routing table exists.
//!
//! Executors COALESCE: each iteration drains its whole request queue and
//! serves every pending `step`/`steps` in one pass, and a `steps` block
//! of n tokens costs one executor round-trip instead of n. Native scan
//! sessions — any fold-kernel backend: aaren, mingru, minlstm, avg_attn
//! — are **resident**: each shard owns a [`LaneMap`] of long-lived
//! [`LaneSet`]s keyed by (kernel, channel width), every session holds a
//! stable lane in the set matching its kernel, and drain work folds
//! tokens into the lanes IN PLACE
//! ([`ResidentScanSession::step_many`], one isolated unit per session —
//! see FAULT CONTAINMENT below) — no per-drain export/import of kernel
//! state. A restored blob whose kernel or width differs from anything
//! already resident simply gets its own lane set, so cross-server
//! migration keeps lane residency. Lanes are released on
//! close/evict/spill/quarantine and each set compacts itself (moving
//! high lanes into holes, re-pointing the moved sessions) when its
//! fragmentation exceeds its live count.
//! `ServeConfig::resident_lanes = false` falls back to the PR 3
//! gather/scatter sessions (self-contained state, no lane residency) —
//! the `resident_vs_scatter` A/B baseline in `BENCH_serve.json` and an
//! escape hatch. The drain is also where idle sessions are swept: with a
//! session TTL configured (`--session-ttl-secs`), sessions idle past it
//! are evicted, so a client that disconnected without `close` cannot
//! leak its sessions forever.
//!
//! With a SPILL TIER configured (`--spill-dir`), eviction stops being
//! destruction: the sweep snapshots each idle native session through the
//! `persist::codec` framing into a [`SnapshotStore`] and drops only the
//! resident copy; the session's next `step`/`steps`/`snapshot` restores
//! it lazily on its owning shard, resuming the stream bitwise where it
//! left off. `--max-resident-sessions` additionally LRU-spills the
//! coldest resident sessions after each drain, so a shard's resident
//! count is bounded independent of how many sessions exist in total —
//! the paper's constant-bytes-per-stream claim turned into a
//! more-sessions-than-RAM serving capability. Sessions whose backend
//! cannot snapshot (the compiled-HLO tier) fall back to plain eviction.
//!
//! FAULT CONTAINMENT (see `ARCHITECTURE.md` § Failure modes):
//!
//! * Drain work runs under `catch_unwind`; a panic — or a non-finite
//!   (poisoned) output — QUARANTINES the implicated session(s): lanes
//!   are released, later ops on the id get a structured `quarantined`
//!   error, and `close` frees the id. The shard thread and every other
//!   resident session keep serving. Resident runs sharing a (kernel,
//!   width) lane set normally execute as one sorted-lane engine pass
//!   ([`step_many_resident`] — still zero state copies, bitwise
//!   identical to per-session execution since each fold touches only
//!   its own lane); a panic mid-engine quarantines the whole group
//!   (unattributable), while the poison gate stays per-session. With a
//!   fault plan active the drain falls back to strict per-session
//!   execution so each injected panic blames exactly one session.
//! * Executor queues are BOUNDED (`ServeConfig::queue_depth`): a full
//!   queue sheds data-plane requests with a structured `overloaded`
//!   reply carrying a `retry_after_ms` hint, instead of queueing without
//!   limit. `--max-conns` caps concurrent connections at the accept
//!   loop, per-connection IO timeouts (`--io-timeout-secs`) unwedge
//!   stalled peers, and `--max-frame-bytes` bounds a single request
//!   line.
//! * A spilled blob that fails its integrity check is quarantined by the
//!   store (`.snap.corrupt`), counted, and reported as a structured
//!   `corrupt_snapshot` error — the id is tombstoned, not wedged.
//! * `--fault-plan` threads a seeded [`FaultPlan`] through the spill
//!   stores and the executor step path (injected IO errors, torn writes,
//!   delays, forced panics) — the deterministic chaos harness
//!   `tests/chaos.rs` drives.

use std::collections::BTreeMap;
use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{IpAddr, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, ensure, Result};

use crate::fault::{
    FaultPlan, FaultSite, FaultingStore, Kinded, KIND_CORRUPT_SNAPSHOT, KIND_QUARANTINED,
};
use crate::obs::{self, Stage, Telemetry};
use crate::persist::codec;
use crate::persist::store::{DirStore, SnapshotStore};
use crate::scan::{KernelKind, LaneSet};
use crate::serve::session::{
    step_many_resident, NativeScanSession, NativeTfSession, ResidentLane, ResidentScanSession,
    StreamSession,
};
use crate::util::b64;
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Hard ceiling on the token count of one `steps` request: an absurd `n`
/// is refused with a clean error reply at parse time, before any
/// session-width allocation is attempted.
pub const MAX_STEPS_TOKENS: usize = 1 << 20;

/// `steps` replies are streamed in blocks of at most this many tokens:
/// a request with n > STEPS_REPLY_BLOCK produces several reply lines
/// (each but the last tagged `"partial":true`), so reply memory is
/// bounded by the block size instead of n.
pub const STEPS_REPLY_BLOCK: usize = 512;

/// The FLOOR of the `retry_after_ms` hint attached to `overloaded`
/// replies — long enough for a drain to free queue slots, short enough
/// that a backing-off client barely notices. The actual hint is priced
/// from the shedding shard's occupancy by [`retry_hint_ms`] and never
/// drops below this.
pub const RETRY_AFTER_MS: u64 = 25;

/// Ceiling of the occupancy-priced `retry_after_ms` hint: even a deeply
/// backlogged shard never pushes a client further than this, so retry
/// loops stay responsive once the backlog clears.
pub const RETRY_AFTER_CAP_MS: u64 = 400;

/// Price the `retry_after_ms` hint on an `overloaded` shed from the
/// shedding shard's occupancy (requests enqueued or executing, `depth`
/// being the queue bound): an exactly-full queue keeps the
/// [`RETRY_AFTER_MS`] floor, and every extra quarter-queue of requests
/// already waiting beyond the bound doubles the hint, up to
/// [`RETRY_AFTER_CAP_MS`] — a deep backlog pushes clients further away
/// instead of inviting the whole herd back in 25 ms.
pub fn retry_hint_ms(occupancy: usize, depth: usize) -> u64 {
    let depth = depth.max(1) as u64;
    let over = (occupancy as u64).saturating_sub(depth);
    let doublings = ((4 * over) / depth).min(4) as u32;
    (RETRY_AFTER_MS << doublings).min(RETRY_AFTER_CAP_MS)
}

/// Default hard cap on one request frame (line) in bytes; see
/// `ServeConfig::max_frame_bytes`.
pub const DEFAULT_MAX_FRAME_BYTES: usize = 1 << 24;

/// Default bound on each executor shard's request queue; see
/// `ServeConfig::queue_depth`.
pub const DEFAULT_QUEUE_DEPTH: usize = 256;

/// First accept-error sleep, in ms; each CONSECUTIVE error doubles it.
const ACCEPT_BACKOFF_FLOOR_MS: u64 = 5;

/// Accept-error sleep ceiling, in ms — a persistent condition (EMFILE
/// for minutes) degrades to slow accepting, never to an unbounded stall.
const ACCEPT_BACKOFF_CAP_MS: u64 = 500;

/// How long the accept loop sleeps after its `consecutive_errors`-th
/// `accept(2)` error in a row: capped exponential from
/// [`ACCEPT_BACKOFF_FLOOR_MS`] doubling to [`ACCEPT_BACKOFF_CAP_MS`],
/// plus jitter in `[0, base)` drawn from the caller's seeded [`Rng`] —
/// deterministic for a given seed (chaos runs replay exactly), while a
/// fleet of processes herding on one shared condition decorrelates.
pub fn accept_backoff(consecutive_errors: u32, rng: &mut Rng) -> Duration {
    let n = consecutive_errors.max(1) - 1;
    let base =
        ACCEPT_BACKOFF_FLOOR_MS.saturating_mul(1u64 << n.min(16)).min(ACCEPT_BACKOFF_CAP_MS);
    Duration::from_millis(base + rng.below(base as usize) as u64)
}

/// A request as an executor sees it (ids are assigned by the router
/// before dispatch, so `Create` already carries one).
pub enum Request {
    Create { id: u64, kind: String },
    Step { id: u64, x: Vec<f32> },
    /// `n` tokens for one session as a flat (n, channels) block — one
    /// round-trip, n outputs.
    Steps { id: u64, xs: Vec<f32>, n: usize },
    /// Serialize the session's live state as a codec blob (resident or
    /// spilled — a spilled session is answered from the store without
    /// restoring it).
    Snapshot { id: u64 },
    /// Create a session at `id` from a codec blob (the migration path).
    Restore { id: u64, blob: Vec<u8> },
    Close { id: u64 },
    /// Spill the session to the store and release its residency on
    /// demand — a TTL eviction a caller (the fleet rebalancer) asks for,
    /// with a structured reply instead of the sweep's silence.
    Drain { id: u64 },
    Stats,
    Shutdown,
}

/// What an executor sends back. Shutdown is a first-class variant of the
/// reply path — not an error-message sentinel to be string-matched.
pub enum Response {
    /// The wire-level reply body.
    Value(Json),
    /// Per-shard stats, aggregated by the router before hitting the wire.
    /// `quarantined`, `corrupt_snapshots`, `spills` and `restores` are
    /// CUMULATIVE totals since the executor started (a closed
    /// quarantined id stays counted); `spilled` is the CURRENT store
    /// population.
    Stats {
        sessions: usize,
        state_bytes: usize,
        spilled: usize,
        quarantined: usize,
        corrupt_snapshots: usize,
        /// sessions ever spilled to the store (TTL sweep, LRU cap,
        /// `drain` op, graceful shutdown) — the capacity harness reads
        /// spill/restore RATES off this without needing telemetry on
        spills: usize,
        /// sessions ever lazily restored from the store on a touch (the
        /// `restore` wire op — a client-supplied blob — is not counted)
        restores: usize,
        /// Per-backend `(resident, spilled)` session counts, keyed by the
        /// wire backend name (`aaren`/`mingru`/`minlstm`/`avg_attn`/`tf`/
        /// `hlo`); spilled counts come from each blob's codec header.
        backends: BTreeMap<String, (usize, usize)>,
    },
    /// The executor acknowledges shutdown and exits its loop.
    ShuttingDown,
}

pub type Reply = Result<Response>;

/// A request plus the channel its reply goes back on and the instant it
/// was enqueued (the executor prices its `queue_wait` histogram off the
/// gap between that and the drain that picks it up). Executor queues
/// are BOUNDED (`ServeConfig::queue_depth`): the router data-plane path
/// uses `try_send` and sheds with a structured `overloaded` reply when
/// the queue is full, so a stalled shard back-pressures its clients
/// instead of buffering unboundedly.
pub type Envelope = (Request, mpsc::Sender<Reply>, Instant);
pub type ReqTx = mpsc::SyncSender<Envelope>;
pub type ReqRx = mpsc::Receiver<Envelope>;

/// Which executor family a `create` lands on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// Rust-native sessions on the sharded executor pool (default).
    Native,
    /// Compiled-HLO sessions on the dedicated PJRT executor (`pjrt`
    /// builds started with artifacts).
    Hlo,
}

/// Session-id namespace split: ids below the base are native (routed to
/// shard `id % shards`), ids at or above it belong to the HLO executor —
/// the route is a pure function of the id.
const HLO_ID_BASE: u64 = 1 << 32;

/// Creates the sessions one executor owns; each executor family brings
/// its own factory (native widths vs loaded HLO models).
pub trait SessionFactory {
    fn create(&mut self, kind: &str) -> Result<Box<dyn StreamSession>>;

    /// Rebuild a session from a `persist::codec` blob — the object-safe
    /// restore hook pairing `StreamSession::snapshot` (a trait method
    /// could not return `Self` behind `dyn`). Backs both the lazy
    /// un-spill on a session's next touch and the `restore` wire op. The
    /// default refuses: backends without snapshot support can't restore
    /// either.
    fn restore(&mut self, blob: &[u8]) -> Result<Box<dyn StreamSession>> {
        let _ = blob;
        Err(anyhow!("this backend cannot restore sessions from snapshots"))
    }
}

/// Factory for the rust-native tier: sessions over `channels`-dim tokens.
pub struct NativeFactory {
    pub channels: usize,
}

impl SessionFactory for NativeFactory {
    fn create(&mut self, kind: &str) -> Result<Box<dyn StreamSession>> {
        if kind == "tf" {
            return Ok(Box::new(NativeTfSession::new(self.channels)));
        }
        match KernelKind::from_wire(kind) {
            Some(k) => Ok(Box::new(NativeScanSession::new_kernel(k, self.channels))),
            None => Err(anyhow!("unknown kind {kind:?} (aaren|mingru|minlstm|avg_attn|tf)")),
        }
    }

    fn restore(&mut self, blob: &[u8]) -> Result<Box<dyn StreamSession>> {
        // snapshots are self-describing: a blob restored here keeps ITS
        // channel width — and its kernel — even if they differ from this
        // server's --channels (that is what makes cross-server migration
        // work)
        let snap = codec::decode(blob)?;
        Ok(match snap.backend {
            codec::BackendTag::Tf => Box::new(NativeTfSession::import_state(&snap)?),
            _ => Box::new(NativeScanSession::import_state(&snap)?),
        })
    }
}

/// The executor-side spill tier: where evicted sessions go instead of
/// dying, plus the optional resident-count cap.
pub struct SpillTier {
    pub store: Box<dyn SnapshotStore>,
    /// After each drain, LRU-spill resident sessions beyond this count;
    /// `None` spills only on TTL expiry.
    pub max_resident: Option<usize>,
}

pub(crate) fn obj(entries: Vec<(&str, Json)>) -> Json {
    Json::Obj(entries.into_iter().map(|(k, v)| (k.to_string(), v)).collect::<BTreeMap<_, _>>())
}

/// The wire shape of an error reply:
/// `{"error":{"kind":K,"message":M[,"retry_after_ms":N]}}`. The kind is
/// the [`Kinded`] tag when the error carries one (`quarantined`,
/// `overloaded`, `corrupt_snapshot`, `frame_too_large`, `no_session`)
/// and the generic `"error"` otherwise, so clients can branch on kind
/// without parsing prose.
pub(crate) fn error_body(e: &anyhow::Error) -> Json {
    let mut fields = vec![
        ("kind", Json::Str(Kinded::kind_of(e).to_string())),
        ("message", Json::Str(format!("{e:#}"))),
    ];
    if let Some(ms) = Kinded::of(e).and_then(|k| k.retry_after_ms) {
        fields.push(("retry_after_ms", Json::Num(ms as f64)));
    }
    obj(vec![("error", obj(fields))])
}

/// Pull `(kind, message)` out of a reply object if it is an error —
/// handles both the structured object form and the legacy plain-string
/// form (pre-containment servers / hand-rolled tests).
pub fn wire_error(reply: &Json) -> Option<(String, String)> {
    let e = reply.get("error")?;
    if let Some(msg) = e.as_str() {
        return Some(("error".to_string(), msg.to_string()));
    }
    let kind = e.get("kind").and_then(Json::as_str).unwrap_or("error").to_string();
    let msg = e.get("message").and_then(Json::as_str).unwrap_or_default().to_string();
    Some((kind, msg))
}

/// How an executor holds one session: native scan sessions (any fold
/// kernel) normally live as **resident lane views** over the shard's
/// [`LaneMap`] (their accumulator is a lane of the set matching their
/// kernel and width, advanced in place); every other backend — tf KV
/// caches, compiled HLO, plus scatter-mode scan sessions — stays a
/// self-contained trait object.
enum SessionSlot {
    Resident(ResidentScanSession),
    Boxed(Box<dyn StreamSession>),
}

impl SessionSlot {
    fn channels(&self) -> usize {
        match self {
            SessionSlot::Resident(r) => r.channels(),
            SessionSlot::Boxed(s) => s.channels(),
        }
    }

    fn state_bytes(&self) -> usize {
        match self {
            SessionSlot::Resident(r) => r.state_bytes(),
            SessionSlot::Boxed(s) => s.state_bytes(),
        }
    }

    fn tokens_seen(&self) -> usize {
        match self {
            SessionSlot::Resident(r) => r.tokens_seen(),
            SessionSlot::Boxed(s) => s.tokens_seen(),
        }
    }

    /// The wire backend name `stats` groups this session under.
    fn backend(&self) -> &'static str {
        match self {
            SessionSlot::Resident(r) => r.kernel().wire_name(),
            SessionSlot::Boxed(s) => s.backend(),
        }
    }

    /// The session's full state as a codec blob; a resident session
    /// serializes straight from its lane, so the blob is byte-identical
    /// to its boxed twin's.
    fn snapshot(&self, lanes: &LaneMap) -> Result<Vec<u8>> {
        match self {
            SessionSlot::Resident(r) => r.snapshot(lanes.set_of(r)),
            SessionSlot::Boxed(s) => s.snapshot(),
        }
    }

    /// Drop the session, returning its lane to its shard set if it held
    /// one — the close/evict/spill terminal step.
    fn release(self, lanes: &mut LaneMap) {
        match self {
            SessionSlot::Resident(r) => {
                let set = lanes.set_for(r.kernel(), r.channels());
                r.release(set);
            }
            SessionSlot::Boxed(_) => {}
        }
    }
}

/// A session an executor owns, plus the idle timestamp the TTL sweep
/// reads.
struct Held {
    slot: SessionSlot,
    last_used: Instant,
}

/// A shard's lane sets, one per (kernel, channel width): every native
/// scan session becomes resident in the set matching its kernel and
/// width, created on first use. A restored blob with a foreign kernel or
/// width therefore gets lane residency too, instead of staying boxed
/// (the pre-fold-kernel servers kept one set per shard and boxed every
/// mismatch).
struct LaneMap {
    sets: HashMap<(KernelKind, usize), LaneSet>,
}

impl LaneMap {
    fn new() -> LaneMap {
        LaneMap { sets: HashMap::new() }
    }

    /// The set for `(kind, d)`, created empty on first use.
    fn set_for(&mut self, kind: KernelKind, d: usize) -> &mut LaneSet {
        self.sets.entry((kind, d)).or_insert_with(|| LaneSet::new_kernel(kind, d))
    }

    /// The set a resident session's lane lives in. The session was
    /// adopted through [`LaneMap::set_for`], so the set must exist.
    fn set_of(&self, r: &ResidentScanSession) -> &LaneSet {
        self.sets.get(&(r.kernel(), r.channels())).expect("resident session's lane set exists")
    }
}

/// Wrap a freshly created/restored session for the map: native scan
/// sessions are adopted into a lane of their (kernel, width) set in the
/// shard [`LaneMap`] (when `resident` mode is on), everything else
/// stays boxed.
fn hold(
    mut session: Box<dyn StreamSession>,
    resident: bool,
    lanes: &mut LaneMap,
    now: Instant,
) -> Held {
    let adopt_key = match session.as_native_scan() {
        Some(native) if resident => Some((native.kernel(), native.channels())),
        _ => None,
    };
    let slot = match adopt_key {
        Some((kind, d)) => {
            let native = session.as_native_scan().expect("downcast checked above");
            SessionSlot::Resident(ResidentScanSession::adopt(native, lanes.set_for(kind, d)))
        }
        None => SessionSlot::Boxed(session),
    };
    Held { slot, last_used: now }
}

/// One queued step-shaped request inside a drain: the flat token block,
/// its token count, whether the reply uses the single-step (`{"y":…}`)
/// or block (`{"ys":…}`) shape, and the channel the reply goes back on.
struct PendingSteps {
    id: u64,
    xs: Vec<f32>,
    n: usize,
    single: bool,
    reply: mpsc::Sender<Reply>,
}

/// Per-shard fault-containment state: the quarantine tombstones plus the
/// cumulative counters `stats` reports.
///
/// A tombstoned id answers every op except `close` with a structured
/// `quarantined` error — the session's state is suspect (a panic may
/// have left a partial fold) so it is neither served nor spilled.
/// `close` drops the tombstone (and any stale spilled blob), freeing the
/// id for reuse; the TTL sweep also expires tombstones so an abandoned
/// quarantined id does not leak forever.
struct Containment {
    tombstones: HashMap<u64, (String, Instant)>,
    /// sessions ever quarantined on this shard (cumulative)
    quarantined_total: usize,
    /// spilled blobs that failed verification on this shard (cumulative)
    corrupt_snapshots: usize,
    /// sessions ever spilled to the store on this shard (cumulative)
    spills_total: usize,
    /// sessions ever lazily restored from the store on this shard
    /// (cumulative; the `restore` wire op is not counted)
    restores_total: usize,
}

impl Containment {
    fn new() -> Containment {
        Containment {
            tombstones: HashMap::new(),
            quarantined_total: 0,
            corrupt_snapshots: 0,
            spills_total: 0,
            restores_total: 0,
        }
    }

    fn quarantine(&mut self, id: u64, reason: String, now: Instant) {
        if self.tombstones.insert(id, (reason, now)).is_none() {
            self.quarantined_total += 1;
        }
    }

    /// The structured error a tombstoned id's ops get, `None` when live.
    fn error_for(&self, id: u64) -> Option<anyhow::Error> {
        self.tombstones.get(&id).map(|(reason, _)| {
            Kinded::quarantined(format!("session {id} is quarantined: {reason}"))
        })
    }
}

/// Run one session's drain work under panic isolation: a panic — whether
/// a real bug or an injected fault — comes back as a `quarantined`-kinded
/// error instead of unwinding (and killing) the shard thread. The
/// `AssertUnwindSafe` is justified by what the caller does on `Err`: the
/// session whose work panicked is REMOVED and tombstoned, never observed
/// again, and its lane is released (the `LaneSet` free-list itself is
/// only mutated on alloc/release, not mid-fold, so a mid-fold panic
/// leaves other lanes untouched).
fn isolate<T>(f: impl FnOnce() -> Result<T>) -> Result<T> {
    match std::panic::catch_unwind(std::panic::AssertUnwindSafe(f)) {
        Ok(r) => r,
        Err(payload) => {
            let msg = payload
                .downcast_ref::<&str>()
                .map(|s| s.to_string())
                .or_else(|| payload.downcast_ref::<String>().cloned())
                .unwrap_or_else(|| "opaque panic payload".to_string());
            Err(Kinded::quarantined(format!("step work panicked: {msg}")))
        }
    }
}

/// Move one session out of the resident map — into the spill store when
/// one is configured and the session can snapshot, otherwise dropping it
/// (the pre-spill TTL behaviour, still what the HLO tier gets). Either
/// way its lane, if it held one, returns to the shard set.
fn evict_session(
    sessions: &mut HashMap<u64, Held>,
    lanes: &mut LaneMap,
    spill: Option<&mut SpillTier>,
    containment: &mut Containment,
    tel: &Telemetry,
    id: u64,
) {
    let Some(held) = sessions.remove(&id) else {
        return;
    };
    if let Some(tier) = spill {
        let blob = {
            crate::obs::span!(tel, Stage::SpillEncode);
            held.slot.snapshot(lanes)
        };
        let stored = blob.and_then(|blob| {
            crate::obs::span!(tel, Stage::SpillWrite);
            tier.store.put(id, &blob)
        });
        match stored {
            Ok(()) => {
                containment.spills_total += 1;
                tel.event("spill", id);
            }
            Err(e) => {
                tel.event("evict", id);
                eprintln!("[serve] session {id} could not spill, dropping: {e:#}");
            }
        }
    } else {
        tel.event("evict", id);
    }
    held.slot.release(lanes);
}

/// What [`ensure_resident`] found for an id.
enum Presence {
    /// The session is in the map (already, or lazily restored from the
    /// spill store — the restored copy becomes authoritative and leaves
    /// the store).
    Ready,
    /// No such session exists, live or spilled.
    Missing,
    /// A spilled blob exists but could not become a session — the
    /// caller's reply, never a silent drop. Corruption (a blob failing
    /// verification or decode) additionally tombstones the id and drops
    /// the damaged blob, so the failure is structured and SINGULAR:
    /// the id answers `quarantined` afterwards until closed, instead of
    /// failing the same way on every touch forever. Transient failures
    /// (an injected or real IO error on the read path) do NOT
    /// quarantine — a retry may succeed.
    Failed(anyhow::Error),
}

/// Make `id` resident if it can be; see [`Presence`].
#[allow(clippy::too_many_arguments)]
fn ensure_resident<F: SessionFactory>(
    sessions: &mut HashMap<u64, Held>,
    spill: &mut Option<SpillTier>,
    factory: &mut F,
    resident: bool,
    lanes: &mut LaneMap,
    containment: &mut Containment,
    tel: &Telemetry,
    id: u64,
    now: Instant,
) -> Presence {
    if sessions.contains_key(&id) {
        return Presence::Ready;
    }
    let Some(tier) = spill.as_mut() else {
        return Presence::Missing;
    };
    let read = {
        crate::obs::span!(tel, Stage::RestoreRead);
        tier.store.get(id)
    };
    let blob = match read {
        Ok(Some(blob)) => blob,
        Ok(None) => return Presence::Missing,
        Err(e) => {
            if Kinded::of(&e).is_some_and(|k| k.kind == KIND_CORRUPT_SNAPSHOT) {
                // the store already quarantined the damaged file itself
                containment.corrupt_snapshots += 1;
                containment.quarantine(id, "spilled snapshot failed verification".into(), now);
                tel.event("quarantine", id);
            }
            return Presence::Failed(e);
        }
    };
    let restored = {
        crate::obs::span!(tel, Stage::RestoreDecode);
        factory.restore(&blob)
    };
    match restored {
        Ok(session) => {
            if let Err(e) = tier.store.remove(id) {
                // the restored copy is authoritative; a blob the store
                // failed to delete must not resurrect as a stale twin
                // after this copy advances, so refuse to serve instead
                return Presence::Failed(e.context(format!(
                    "session {id} restored but its spilled blob could not be retired"
                )));
            }
            sessions.insert(id, hold(session, resident, lanes, now));
            containment.restores_total += 1;
            tel.event("restore", id);
            Presence::Ready
        }
        Err(e) => {
            // an undecodable blob through a store that does not verify
            // (MemStore, a torn write the disk lied about): same
            // containment as store-level corruption — count, drop the
            // damaged blob, tombstone the id
            let _ = tier.store.remove(id);
            containment.corrupt_snapshots += 1;
            containment.quarantine(id, format!("spilled snapshot failed to restore: {e:#}"), now);
            tel.event("quarantine", id);
            Presence::Failed(Kinded::corrupt_snapshot(format!(
                "session {id} snapshot is corrupt: {e:#}"
            )))
        }
    }
}

/// How one executor shard runs; everything [`run_executor`] needs beyond
/// its factory and queue.
pub struct ExecutorOpts {
    /// evict (or spill) sessions idle longer than this
    pub session_ttl: Option<Duration>,
    /// where evicted sessions go instead of dying
    pub spill: Option<SpillTier>,
    /// serve native scan sessions as resident lanes (the default)
    pub resident: bool,
    /// this shard's seeded fault-injection site (chaos runs only)
    pub fault: Option<FaultSite>,
    /// this shard's telemetry domain: stage histograms plus the flight
    /// recorder. The router keeps a clone and merges every shard's
    /// snapshots on a `metrics` op. The default is a disabled instance
    /// (spans never read the clock) so bare executors pay nothing.
    pub telemetry: Arc<Telemetry>,
}

impl Default for ExecutorOpts {
    fn default() -> ExecutorOpts {
        ExecutorOpts {
            session_ttl: None,
            spill: None,
            resident: true,
            fault: None,
            telemetry: Arc::new(Telemetry::disabled()),
        }
    }
}

/// One executor shard: owns a private id → session map plus the shard
/// [`LaneMap`] its resident scan sessions live in, and serves its
/// channel until a `Shutdown` request arrives (acknowledged with
/// [`Response::ShuttingDown`]; with a spill tier configured, every
/// session that can snapshot is spilled to the store first, so a
/// graceful shutdown loses no stream).
///
/// Each iteration DRAINS the queue: every request already waiting is
/// pulled in one go, maximal runs of `step`/`steps` are executed as one
/// coalesced batch ([`flush_steps`]) and — with `session_ttl` set —
/// sessions idle past the TTL are swept before the drain is served
/// (spilled to `spill`'s store when one is configured, dropped
/// otherwise). Request order is preserved: a `close` (or any other op)
/// between two step runs splits them, so a step never observes a later
/// op's effect. After the drain, the spill tier's `max_resident` cap is
/// enforced by LRU-spilling the coldest resident sessions, and each
/// lane set compacts itself when its released lanes outnumber both its
/// live count and a floor of 8 (hysteresis for small shards).
///
/// `ExecutorOpts::resident = false` disables lane residency: native
/// scan sessions stay boxed and advance through their own `step_many` —
/// the A/B baseline the `resident_vs_scatter` bench records compare
/// against.
///
/// FAULT CONTAINMENT: each session's step work runs under
/// [`isolate`]; a panicking or output-poisoned (non-finite) session is
/// quarantined — removed from the map, lane released, id tombstoned in
/// [`Containment`] — and every other session keeps streaming. With
/// `ExecutorOpts::fault` set, the seeded [`FaultSite`] injects step
/// panics and delays at the same points a real fault would hit.
pub fn run_executor<F: SessionFactory>(mut factory: F, rx: ReqRx, opts: ExecutorOpts) {
    let ExecutorOpts { session_ttl, mut spill, resident, mut fault, telemetry: tel } = opts;
    let mut sessions: HashMap<u64, Held> = HashMap::new();
    let mut lanes = LaneMap::new();
    let mut containment = Containment::new();
    'serve: loop {
        // with a TTL configured, an idle shard must still wake up to
        // sweep: bound the blocking wait so sessions of disconnected
        // clients are reaped even when no request ever arrives here again
        let first = match session_ttl {
            Some(ttl) => match rx.recv_timeout(ttl.min(Duration::from_secs(5))) {
                Ok(envelope) => Some(envelope),
                Err(mpsc::RecvTimeoutError::Timeout) => None,
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            },
            None => match rx.recv() {
                Ok(envelope) => Some(envelope),
                Err(_) => break, // router gone: no more work can arrive
            },
        };
        let mut batch: Vec<Envelope> = first.into_iter().collect();
        while let Ok(envelope) = rx.try_recv() {
            batch.push(envelope);
        }
        // an empty batch is an idle wake (TTL timer, nobody waiting):
        // the cheapest moment to pay for background lane compaction below
        let idle = batch.is_empty();
        let now = Instant::now();
        // queue wait: the gap between a request's enqueue and the drain
        // that picked it up — the congestion the retry hints price
        for (_, _, enq) in &batch {
            tel.record(Stage::QueueWait, now.saturating_duration_since(*enq));
        }
        // time the whole drain (sweep, dispatch, flush, cap enforcement
        // and compaction); idle wakes are not drains
        let _drain_span = (!idle).then(|| tel.span(Stage::ExecDrain));
        if let Some(ttl) = session_ttl {
            // a request already in hand keeps its session alive: refresh
            // before sweeping, so a slow-but-connected client can never
            // lose its stream state between enqueue and execution
            for (req, _, _) in &batch {
                if let Request::Step { id, .. }
                | Request::Steps { id, .. }
                | Request::Snapshot { id }
                | Request::Close { id } = req
                {
                    if let Some(held) = sessions.get_mut(id) {
                        held.last_used = now;
                    }
                }
            }
            // the drain is the sweep point; idle shards wake on the
            // recv_timeout above so disconnected clients still get
            // reaped. With a spill tier, expiry means spill, not death.
            let expired: Vec<u64> = sessions
                .iter()
                .filter(|(_, held)| now.duration_since(held.last_used) > ttl)
                .map(|(&id, _)| id)
                .collect();
            for id in expired {
                evict_session(
                    &mut sessions,
                    &mut lanes,
                    spill.as_mut(),
                    &mut containment,
                    &tel,
                    id,
                );
            }
            // quarantine tombstones expire on the same clock, so an
            // abandoned (never-closed) quarantined id cannot leak forever
            containment.tombstones.retain(|_, entry| now.duration_since(entry.1) <= ttl);
        }
        let mut pending: Vec<PendingSteps> = Vec::new();
        for (req, reply, _) in batch {
            match req {
                Request::Step { id, x } => {
                    pending.push(PendingSteps { id, xs: x, n: 1, single: true, reply });
                }
                Request::Steps { id, xs, n } => {
                    pending.push(PendingSteps { id, xs, n, single: false, reply });
                }
                other => {
                    // anything that is not a step splits the batch: flush
                    // what came before it so ordering is preserved
                    flush_steps(
                        &mut sessions,
                        &mut pending,
                        &mut lanes,
                        &mut factory,
                        &mut spill,
                        &mut containment,
                        &mut fault,
                        &tel,
                        resident,
                        now,
                    );
                    let resp: Reply = match other {
                        Request::Create { id, kind } => {
                            // with a spill tier an id can be alive while
                            // not resident — clobbering it here would
                            // silently destroy a stream, so duplicates
                            // are a structured error instead; a
                            // quarantined id is blocked until closed
                            if let Some(e) = containment.error_for(id) {
                                Err(e)
                            } else if sessions.contains_key(&id)
                                || spill.as_ref().is_some_and(|t| t.store.contains(id))
                            {
                                Err(anyhow!("session {id} already exists"))
                            } else {
                                factory.create(&kind).map(|session| {
                                    sessions.insert(id, hold(session, resident, &mut lanes, now));
                                    tel.event("create", id);
                                    Response::Value(obj(vec![("id", Json::Num(id as f64))]))
                                })
                            }
                        }
                        Request::Snapshot { id } => {
                            if let Some(e) = containment.error_for(id) {
                                Err(e)
                            } else {
                                match sessions.get(&id) {
                                    Some(held) => {
                                        held.slot.snapshot(&lanes).and_then(snapshot_reply)
                                    }
                                    // a spilled session is served straight
                                    // from the store — no need to make it
                                    // resident just to read its state
                                    None => match spill.as_mut().map(|t| t.store.get(id)) {
                                        Some(Ok(Some(blob))) => snapshot_reply(blob),
                                        Some(Err(e)) => {
                                            if Kinded::of(&e)
                                                .is_some_and(|k| k.kind == KIND_CORRUPT_SNAPSHOT)
                                            {
                                                containment.corrupt_snapshots += 1;
                                                containment.quarantine(
                                                    id,
                                                    "spilled snapshot failed verification".into(),
                                                    now,
                                                );
                                                tel.event("quarantine", id);
                                            }
                                            Err(e)
                                        }
                                        Some(Ok(None)) | None => Err(Kinded::no_session(id)),
                                    },
                                }
                            }
                        }
                        Request::Restore { id, blob } => {
                            if let Some(e) = containment.error_for(id) {
                                Err(e)
                            } else if sessions.contains_key(&id)
                                || spill.as_ref().is_some_and(|t| t.store.contains(id))
                            {
                                Err(anyhow!("session {id} already exists"))
                            } else {
                                codec::meta(&blob).and_then(|meta| {
                                    let session = {
                                        crate::obs::span!(tel, Stage::RestoreDecode);
                                        factory.restore(&blob)?
                                    };
                                    sessions.insert(id, hold(session, resident, &mut lanes, now));
                                    tel.event("restore", id);
                                    Ok(Response::Value(obj(vec![
                                        ("id", Json::Num(id as f64)),
                                        ("kind", Json::Str(meta.backend.kind().to_string())),
                                        ("channels", Json::Num(meta.channels as f64)),
                                        ("t", Json::Num(meta.tokens_seen as f64)),
                                    ])))
                                })
                            }
                        }
                        Request::Close { id } => {
                            if containment.tombstones.remove(&id).is_some() {
                                // closing a quarantined id clears its
                                // tombstone and any stale spilled blob —
                                // the id is reusable again (the
                                // cumulative stats counter stays)
                                if let Some(t) = spill.as_mut() {
                                    let _ = t.store.remove(id);
                                }
                                Ok(Response::Value(obj(vec![("ok", Json::Bool(true))])))
                            } else if let Some(held) = sessions.remove(&id) {
                                held.slot.release(&mut lanes);
                                Ok(Response::Value(obj(vec![("ok", Json::Bool(true))])))
                            } else {
                                // a spilled session closes by deleting
                                // its snapshot
                                match spill.as_mut().map(|t| t.store.remove(id)) {
                                    Some(Ok(true)) => {
                                        Ok(Response::Value(obj(vec![("ok", Json::Bool(true))])))
                                    }
                                    Some(Err(e)) => Err(e),
                                    Some(Ok(false)) | None => Err(Kinded::no_session(id)),
                                }
                            }
                        }
                        Request::Drain { id } => {
                            if let Some(e) = containment.error_for(id) {
                                Err(e)
                            } else if sessions.contains_key(&id) {
                                if spill.is_none() {
                                    Err(anyhow!(
                                        "drain of session {id} needs a spill tier \
                                         (start the server with --spill-dir)"
                                    ))
                                } else {
                                    // same mechanics as a TTL eviction —
                                    // snapshot, store, release the lane —
                                    // but on demand, and the reply only
                                    // claims success if the blob actually
                                    // landed in the store
                                    evict_session(
                                        &mut sessions,
                                        &mut lanes,
                                        spill.as_mut(),
                                        &mut containment,
                                        &tel,
                                        id,
                                    );
                                    if spill.as_ref().is_some_and(|t| t.store.contains(id)) {
                                        Ok(Response::Value(obj(vec![
                                            ("ok", Json::Bool(true)),
                                            ("spilled", Json::Bool(true)),
                                        ])))
                                    } else {
                                        Err(anyhow!("session {id} failed to spill on drain"))
                                    }
                                }
                            } else if spill.as_ref().is_some_and(|t| t.store.contains(id)) {
                                // already spilled: drain is idempotent
                                Ok(Response::Value(obj(vec![
                                    ("ok", Json::Bool(true)),
                                    ("spilled", Json::Bool(false)),
                                ])))
                            } else {
                                Err(Kinded::no_session(id))
                            }
                        }
                        Request::Stats => {
                            let mut backends: BTreeMap<String, (usize, usize)> = BTreeMap::new();
                            for held in sessions.values() {
                                backends.entry(held.slot.backend().to_string()).or_default().0 += 1;
                            }
                            // spilled blobs carry their backend in the
                            // codec header; a blob that cannot be read
                            // here is skipped (it still counts in the
                            // flat `spilled` total)
                            if let Some(t) = spill.as_mut() {
                                for id in t.store.ids() {
                                    if let Ok(Some(blob)) = t.store.get(id) {
                                        if let Ok(meta) = codec::meta(&blob) {
                                            backends
                                                .entry(meta.backend.kind().to_string())
                                                .or_default()
                                                .1 += 1;
                                        }
                                    }
                                }
                            }
                            Ok(Response::Stats {
                                sessions: sessions.len(),
                                state_bytes: sessions.values().map(|h| h.slot.state_bytes()).sum(),
                                spilled: spill.as_ref().map_or(0, |t| t.store.len()),
                                quarantined: containment.quarantined_total,
                                corrupt_snapshots: containment.corrupt_snapshots,
                                spills: containment.spills_total,
                                restores: containment.restores_total,
                                backends,
                            })
                        }
                        Request::Shutdown => {
                            // graceful shutdown: with a spill tier, every
                            // resident session that can snapshot is
                            // spilled before the executor exits — a
                            // restart over the same --spill-dir resumes
                            // each stream where it stood, instead of
                            // dropping whatever was resident
                            if spill.is_some() {
                                let ids: Vec<u64> = sessions.keys().copied().collect();
                                for id in ids {
                                    evict_session(
                                        &mut sessions,
                                        &mut lanes,
                                        spill.as_mut(),
                                        &mut containment,
                                        &tel,
                                        id,
                                    );
                                }
                            }
                            Ok(Response::ShuttingDown)
                        }
                        Request::Step { .. } | Request::Steps { .. } => {
                            unreachable!("step-shaped requests are queued above")
                        }
                    };
                    let shutting_down = matches!(resp, Ok(Response::ShuttingDown));
                    let _ = reply.send(resp);
                    if shutting_down {
                        break 'serve;
                    }
                }
            }
        }
        flush_steps(
            &mut sessions,
            &mut pending,
            &mut lanes,
            &mut factory,
            &mut spill,
            &mut containment,
            &mut fault,
            &tel,
            resident,
            now,
        );
        // resident-count cap: LRU-spill the coldest sessions until the
        // shard is back under it. Just-touched sessions carry `now` and
        // are spilled last, so the cap cannot starve the live working set
        // of this drain (they may still spill when the cap is smaller
        // than the drain's distinct-session count).
        if let Some(cap) = spill.as_ref().and_then(|t| t.max_resident) {
            while sessions.len() > cap {
                let coldest = sessions
                    .iter()
                    .min_by_key(|(_, held)| held.last_used)
                    .map(|(&id, _)| id)
                    .expect("resident count exceeds the cap, so the map is nonempty");
                evict_session(
                    &mut sessions,
                    &mut lanes,
                    spill.as_mut(),
                    &mut containment,
                    &tel,
                    coldest,
                );
            }
        }
        compact_lanes(&mut sessions, &mut lanes, idle);
    }
}

/// Lane hygiene at a drain's trailing edge. On a busy drain a set
/// compacts once its released lanes outnumber BOTH its live count and a
/// small floor (8 — hysteresis so tiny shards don't churn); on an idle
/// wake (`idle` — the TTL timer fired with an empty queue) ANY
/// fragmentation is taken, so the worst case left by a mass eviction is
/// paid while nobody is waiting instead of at the front of the next busy
/// drain. Moved sessions are re-pointed at their new lanes in one pass
/// (states move bit-for-bit, nothing is recomputed); only sessions of
/// the compacting set's kernel and width are re-pointed — lanes in other
/// sets never move.
fn compact_lanes(sessions: &mut HashMap<u64, Held>, lanes: &mut LaneMap, idle: bool) {
    for (&(kind, d), set) in lanes.sets.iter_mut() {
        let due = if idle { set.frag() > 0 } else { set.frag() > set.live().max(8) };
        if due {
            let moves: HashMap<usize, usize> = set.compact().into_iter().collect();
            if !moves.is_empty() {
                for held in sessions.values_mut() {
                    if let SessionSlot::Resident(r) = &mut held.slot {
                        if r.kernel() == kind && r.channels() == d {
                            if let Some(&new) = moves.get(&r.lane()) {
                                r.set_lane(new);
                            }
                        }
                    }
                }
            }
        }
    }
    // a set whose lanes all trimmed away is dropped; first use of
    // that (kernel, width) again recreates it empty
    lanes.sets.retain(|_, set| set.lanes() > 0);
}

/// The `snapshot` op's reply body for one codec blob: the base64 state
/// plus the header metadata a client needs to route/inspect it.
fn snapshot_reply(blob: Vec<u8>) -> Result<Response> {
    let meta = codec::meta(&blob)?;
    Ok(Response::Value(obj(vec![
        ("state", Json::Str(b64::encode(&blob))),
        ("kind", Json::Str(meta.backend.kind().to_string())),
        ("channels", Json::Num(meta.channels as f64)),
        ("t", Json::Num(meta.tokens_seen as f64)),
        ("bytes", Json::Num(blob.len() as f64)),
    ])))
}

/// One session's share of a drain: its concatenated pending tokens and
/// the (work index, token count) segments they came from.
struct SessionRun {
    id: u64,
    d: usize,
    tokens: Vec<f32>,
    segments: Vec<(usize, usize)>,
}

/// Execute every queued step-shaped request of a drain as one coalesced
/// batch and reply to each. Requests are grouped per session (order
/// preserved within a session); **resident** scan sessions sharing a
/// (kernel, width) [`LaneSet`] then execute as one sorted-lane engine
/// pass under a single [`isolate`] ([`step_many_resident`]: units
/// sorted by lane id, one ascending `fold_all` walk per round, no state
/// copied in or out — bitwise identical to per-session execution since
/// every fold touches only its own lane), while boxed sessions (scatter
/// mode, tf KV cache, compiled HLO) and lone resident runs take their
/// own `step_many` as one isolated unit each.
/// Containment: on the per-session path, a panicking or output-poisoned
/// unit quarantines THAT session alone (removed, lane released, outputs
/// discarded). On the engine path the poison gate is still per-session,
/// but a mid-engine panic quarantines the whole group — a fallen round
/// cannot be attributed — which is why an active fault plan (injected
/// per-session panics) forces the per-session path for the entire
/// drain. A session that was spilled to the store is transparently
/// restored here, on its owning shard, before its first request of the
/// drain.
#[allow(clippy::too_many_arguments)]
fn flush_steps<F: SessionFactory>(
    sessions: &mut HashMap<u64, Held>,
    pending: &mut Vec<PendingSteps>,
    lanes: &mut LaneMap,
    factory: &mut F,
    spill: &mut Option<SpillTier>,
    containment: &mut Containment,
    fault: &mut Option<FaultSite>,
    tel: &Telemetry,
    resident: bool,
    now: Instant,
) {
    if pending.is_empty() {
        return;
    }
    let work = std::mem::take(pending);

    // group per session, preserving arrival order within each
    let mut runs: Vec<SessionRun> = Vec::new();
    let mut run_of: HashMap<u64, usize> = HashMap::new();
    let mut replies: Vec<Option<Reply>> = (0..work.len()).map(|_| None).collect();
    for (wi, p) in work.iter().enumerate() {
        if let Some(e) = containment.error_for(p.id) {
            replies[wi] = Some(Err(e));
            continue;
        }
        let presence =
            ensure_resident(sessions, spill, factory, resident, lanes, containment, tel, p.id, now);
        match presence {
            Presence::Ready => {}
            Presence::Missing => {
                replies[wi] = Some(Err(Kinded::no_session(p.id)));
                continue;
            }
            Presence::Failed(e) => {
                replies[wi] = Some(Err(e));
                continue;
            }
        }
        let held = sessions.get_mut(&p.id).expect("ensure_resident said resident");
        held.last_used = now;
        let d = held.slot.channels();
        if p.xs.len() != p.n * d {
            replies[wi] = Some(Err(anyhow!(
                "token block has {} floats, session {} expects {} × {d} channels",
                p.xs.len(),
                p.id,
                p.n
            )));
            continue;
        }
        let ri = match run_of.get(&p.id) {
            Some(&ri) => ri,
            None => {
                runs.push(SessionRun { id: p.id, d, tokens: Vec::new(), segments: Vec::new() });
                run_of.insert(p.id, runs.len() - 1);
                runs.len() - 1
            }
        };
        // single-request runs (the common case) execute straight from the
        // request's own block; `tokens` concatenates only when a second
        // request for the same session lands in one drain
        if !runs[ri].segments.is_empty() {
            if runs[ri].tokens.is_empty() {
                let (first_wi, _) = runs[ri].segments[0];
                let first = work[first_wi].xs.as_slice();
                runs[ri].tokens.extend_from_slice(first);
            }
            runs[ri].tokens.extend_from_slice(&p.xs);
        }
        runs[ri].segments.push((wi, p.n));
    }
    let token_views: Vec<&[f32]> = runs
        .iter()
        .map(|run| {
            if run.segments.len() == 1 {
                work[run.segments[0].0].xs.as_slice()
            } else {
                run.tokens.as_slice()
            }
        })
        .collect();

    // execute. Resident scan sessions fold straight into their lanes
    // (zero state copies per drain); boxed sessions (scatter mode, tf,
    // HLO) advance through their own step_many. Resident runs sharing a
    // (kernel, width) lane set execute as ONE sorted-lane engine pass
    // ([`step_many_resident`]: units sorted by lane id once, each round
    // one ascending `fold_all` walk over the state rows — bitwise
    // identical to the per-session path, property-tested) when no fault
    // plan is active; a fault plan forces the per-session path because
    // its injected panics need an exact per-session isolation domain.
    let mut outs: Vec<Vec<f32>> = (0..runs.len()).map(|_| Vec::new()).collect();
    let mut run_err: Vec<Option<anyhow::Error>> = (0..runs.len()).map(|_| None).collect();
    let mut solo: Vec<usize> = Vec::new();
    let mut groups: HashMap<(KernelKind, usize), Vec<usize>> = HashMap::new();
    if fault.is_none() {
        for (ri, run) in runs.iter().enumerate() {
            match sessions.get(&run.id).map(|h| &h.slot) {
                Some(SessionSlot::Resident(r)) => {
                    groups.entry((r.kernel(), r.channels())).or_default().push(ri);
                }
                _ => solo.push(ri),
            }
        }
        // a single-member group gains nothing from the engine; keep it on
        // the per-session path
        groups.retain(|_, ris| {
            if ris.len() >= 2 {
                true
            } else {
                solo.extend(ris.iter().copied());
                false
            }
        });
    } else {
        solo.extend(0..runs.len());
    }
    solo.sort_unstable();

    for (&(kind, d), ris) in groups.iter() {
        // take ownership of the group's sessions so every lane view can
        // be borrowed at once alongside the shard lane set
        let mut members: Vec<(usize, u64, ResidentScanSession, Instant)> =
            Vec::with_capacity(ris.len());
        for &ri in ris {
            let id = runs[ri].id;
            let held = sessions.remove(&id).expect("grouped runs were resident above");
            match held.slot {
                SessionSlot::Resident(r) => members.push((ri, id, r, held.last_used)),
                SessionSlot::Boxed(_) => unreachable!("grouped runs are resident"),
            }
        }
        let mut group_outs: Vec<Vec<f32>> = (0..members.len()).map(|_| Vec::new()).collect();
        let result = {
            // one kernel_fold sample per engine pass: the fused fold cost
            // of the whole group, queueing and reply excluded
            crate::obs::span!(tel, Stage::KernelFold);
            isolate(|| {
                let mut units: Vec<ResidentLane<'_>> = members
                    .iter_mut()
                    .map(|(ri, _, r, _)| (r, token_views[*ri]))
                    .collect();
                step_many_resident(&mut units, lanes.set_for(kind, d), &mut group_outs)
            })
        };
        match result {
            Ok(()) => {
                for (mi, (ri, id, r, last_used)) in members.into_iter().enumerate() {
                    let out = std::mem::take(&mut group_outs[mi]);
                    // the per-session poison gate still applies: the
                    // engine's rounds only touched this session's own
                    // lane, so a non-finite output condemns it alone
                    if out.iter().any(|v| !v.is_finite()) {
                        let reason = format!("session {id} produced non-finite outputs");
                        r.release(lanes.set_for(kind, d));
                        containment.quarantine(id, reason.clone(), now);
                        tel.event("quarantine", id);
                        run_err[ri] = Some(Kinded::quarantined(format!(
                            "session {id} is quarantined: {reason}"
                        )));
                    } else {
                        outs[ri] = out;
                        sessions.insert(id, Held { slot: SessionSlot::Resident(r), last_used });
                    }
                }
            }
            Err(e) if Kinded::of(&e).is_some_and(|k| k.kind == KIND_QUARANTINED) => {
                // a mid-engine panic is unattributable — any member's
                // fold may have fallen mid-round — so the whole group is
                // quarantined: the containment-correct call, and the
                // reason a fault plan (whose injected panics must blame
                // one session) disables grouping entirely
                let reason = format!("{e:#}");
                for (ri, id, r, _) in members {
                    r.release(lanes.set_for(kind, d));
                    containment.quarantine(id, reason.clone(), now);
                    tel.event("quarantine", id);
                    run_err[ri] = Some(Kinded::quarantined(format!(
                        "session {id} is quarantined: {reason}"
                    )));
                }
            }
            Err(e) => {
                // validation errors fail BEFORE any fold (the engine
                // checks every unit's block up front), so the sessions
                // are untouched: reinsert them and error every run with
                // zero tokens executed
                let reason = format!("{e:#}");
                for (ri, id, r, last_used) in members {
                    run_err[ri] = Some(anyhow!("{reason}"));
                    sessions.insert(id, Held { slot: SessionSlot::Resident(r), last_used });
                }
            }
        }
    }

    // the per-session path: boxed sessions, lone resident runs, and
    // every run of a fault-plan drain. The per-session boundary is the
    // isolation domain: a panic or poisoned output condemns exactly the
    // session that produced it.
    for ri in solo {
        let run = &runs[ri];
        let Some(held) = sessions.get_mut(&run.id) else {
            run_err[ri] = Some(Kinded::no_session(run.id));
            continue;
        };
        let xs = token_views[ri];
        let out = &mut outs[ri];
        let result = {
            // one kernel_fold sample per isolated unit: the pure fold
            // cost of a session's run, queueing and reply excluded
            crate::obs::span!(tel, Stage::KernelFold);
            isolate(|| {
                if let Some(site) = fault.as_mut() {
                    site.maybe_delay();
                    // inside the isolation boundary, exactly where a real
                    // bug would unwind from
                    site.maybe_step_panic(run.id);
                }
                match &mut held.slot {
                    SessionSlot::Resident(r) => {
                        let (kind, d) = (r.kernel(), r.channels());
                        r.step_many(lanes.set_for(kind, d), xs, out)
                    }
                    SessionSlot::Boxed(s) => s.step_many(xs, out),
                }
            })
        };
        // poison gate: parse already rejects non-finite INPUTS, so a
        // non-finite OUTPUT means the session's accumulator state went
        // bad (overflow, a backend bug) — every later step would be
        // garbage, so contain it now
        let poisoned = result.is_ok() && outs[ri].iter().any(|v| !v.is_finite());
        match result {
            Ok(()) if !poisoned => {}
            verdict => {
                let (quarantine, reason) = match verdict {
                    Ok(()) => (true, format!("session {} produced non-finite outputs", run.id)),
                    Err(ref e) if Kinded::of(e).is_some_and(|k| k.kind == KIND_QUARANTINED) => {
                        (true, format!("{e:#}"))
                    }
                    // ordinary validation errors (width mismatch, a tf
                    // refusal) keep their existing semantics: the run
                    // errors, the session lives on with the prefix that
                    // executed
                    Err(ref e) => (false, format!("{e:#}")),
                };
                if quarantine {
                    // state is suspect (a panic may have fallen mid-fold,
                    // poison is already in the accumulator): remove the
                    // session, free its lane, discard its outputs, and
                    // tombstone the id
                    let held = sessions.remove(&run.id).expect("present above");
                    held.slot.release(lanes);
                    containment.quarantine(run.id, reason.clone(), now);
                    tel.event("quarantine", run.id);
                    outs[ri].clear();
                    run_err[ri] = Some(Kinded::quarantined(format!(
                        "session {} is quarantined: {reason}",
                        run.id
                    )));
                } else {
                    run_err[ri] = verdict.err();
                }
            }
        }
    }

    // build one reply per original request, in arrival order
    for (ri, run) in runs.iter().enumerate() {
        let d = run.d;
        let (state_bytes, t_after) = match sessions.get(&run.id) {
            Some(h) => (h.slot.state_bytes(), h.slot.tokens_seen()),
            None => (0, 0),
        };
        // tokens of this run that actually executed: all of them on
        // success; on a mid-block failure, the folded prefix (the stream
        // HAS advanced by these, exactly as with individual `step`
        // calls). Earlier requests whose tokens all lie in that prefix
        // still get their success replies — sequential semantics — and
        // the rest get the error, stamped with the stream's actual
        // position so the client can resync instead of re-sending.
        let ok_tokens: usize = if run_err[ri].is_some() {
            if d == 0 {
                0
            } else {
                outs[ri].len() / d
            }
        } else {
            run.segments.iter().map(|&(_, n)| n).sum()
        };
        let mut off = 0usize;
        for &(wi, n) in &run.segments {
            let end = off + n;
            if end > ok_tokens {
                let e = run_err[ri].as_ref().expect("successful runs execute every token");
                // stamp the stream's actual position without destroying
                // the structured kind (clients branch on `quarantined`)
                let stamped = format!("{e:#} (stream at t={t_after})");
                replies[wi] = Some(Err(match Kinded::of(e) {
                    Some(k) => anyhow::Error::new(Kinded {
                        kind: k.kind,
                        message: stamped,
                        retry_after_ms: k.retry_after_ms,
                    }),
                    None => anyhow!("{stamped}"),
                }));
                off = end;
                continue;
            }
            let t_seg = t_after.saturating_sub(ok_tokens - end);
            let seg = &outs[ri][off * d..end * d];
            off = end;
            let num = |v: f32| Json::Num(v as f64);
            let body = if work[wi].single {
                obj(vec![
                    ("y", Json::Arr(seg.iter().copied().map(num).collect())),
                    ("state_bytes", Json::Num(state_bytes as f64)),
                    ("t", Json::Num(t_seg as f64)),
                ])
            } else {
                let ys: Vec<Json> = if d == 0 {
                    (0..n).map(|_| Json::Arr(Vec::new())).collect()
                } else {
                    seg.chunks_exact(d)
                        .map(|row| Json::Arr(row.iter().copied().map(num).collect()))
                        .collect()
                };
                obj(vec![
                    ("ys", Json::Arr(ys)),
                    ("state_bytes", Json::Num(state_bytes as f64)),
                    ("t", Json::Num(t_seg as f64)),
                ])
            };
            replies[wi] = Some(Ok(Response::Value(body)));
        }
    }
    for (p, r) in work.into_iter().zip(replies.into_iter()) {
        let resp = r.unwrap_or_else(|| Err(anyhow!("internal: request missed its reply")));
        let _ = p.reply.send(resp);
    }
}

/// Server configuration; `Default` serves rust-native sessions on
/// 127.0.0.1:7878 with one shard per core (capped).
#[derive(Clone, Debug)]
pub struct ServeConfig {
    pub addr: String,
    /// channel width of rust-native sessions created by this server
    pub channels: usize,
    /// number of native executor shards (worker threads)
    pub shards: usize,
    /// evict sessions idle longer than this (swept on executor drains);
    /// `None` keeps sessions until an explicit `close`
    pub session_ttl: Option<Duration>,
    /// spill directory for evicted native sessions: with this set, TTL
    /// expiry and the resident cap SPILL sessions (atomic snapshot
    /// files, restored lazily on next touch) instead of destroying them,
    /// and spilled sessions survive server restarts
    pub spill_dir: Option<std::path::PathBuf>,
    /// cap on resident native sessions across the whole pool (split
    /// evenly over the shards); requires `spill_dir`. `None` leaves
    /// resident count unbounded
    pub max_resident_sessions: Option<usize>,
    /// keep native Aaren sessions resident in each shard's [`LaneSet`]
    /// (the default): drains fold tokens into their lanes in place.
    /// `false` restores the PR 3 gather/scatter batching — the
    /// `resident_vs_scatter` bench baseline and a debugging escape hatch
    /// (`--scatter-drain`)
    pub resident_lanes: bool,
    /// artifacts dir enabling the compiled-HLO backend (`pjrt` builds
    /// only; ignored otherwise)
    pub artifacts: Option<std::path::PathBuf>,
    /// bound on each executor shard's request queue: when a shard is
    /// this far behind, further requests for it are refused with a
    /// structured `overloaded` error (plus a retry hint) instead of
    /// growing the queue without limit
    pub queue_depth: usize,
    /// accept-side cap on concurrent connections; over the cap the
    /// server replies with one `overloaded` error line and closes.
    /// `None` leaves admission unbounded
    pub max_conns: Option<usize>,
    /// per-connection read/write timeout, so an idle or wedged peer
    /// releases its handler thread; `None` blocks forever (the
    /// pre-containment behaviour)
    pub io_timeout: Option<Duration>,
    /// hard per-frame (line) size limit; an oversized frame gets a
    /// structured `frame_too_large` error and the connection closes
    pub max_frame_bytes: usize,
    /// deterministic fault-injection plan (chaos testing only): seeds
    /// injected IO errors / torn writes on the spill stores and delays
    /// / panics on the executor step path. `None` (the default) injects
    /// nothing
    pub fault: Option<FaultPlan>,
    /// record latency histograms, span timings and flight-recorder
    /// events (the default). `false` (`--no-telemetry`) turns every
    /// instrumentation site into a runtime no-op — spans never read the
    /// clock; the `obs-noop` cargo feature removes them at compile time
    pub telemetry: bool,
    /// with `Some(d)` (`--metrics-interval-secs`), a background thread
    /// prints a compact per-op latency digest line to stderr every `d`
    pub metrics_interval: Option<Duration>,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            addr: "127.0.0.1:7878".to_string(),
            channels: 8,
            shards: std::thread::available_parallelism().map(|t| t.get().min(8)).unwrap_or(4),
            session_ttl: None,
            spill_dir: None,
            max_resident_sessions: None,
            resident_lanes: true,
            artifacts: None,
            queue_depth: DEFAULT_QUEUE_DEPTH,
            max_conns: None,
            io_timeout: None,
            max_frame_bytes: DEFAULT_MAX_FRAME_BYTES,
            fault: None,
            telemetry: true,
            metrics_interval: None,
        }
    }
}

/// Containment counters kept outside the executors (connection- and
/// admission-level events never reach a shard thread). Shared between
/// the [`Server`] accept loop and the [`Router`], folded into `stats`.
#[derive(Debug, Default)]
pub struct ServeStats {
    /// requests or connections refused because a queue (or the
    /// connection cap) was full
    pub overloaded_rejects: AtomicU64,
    /// `accept()` failures (EMFILE, aborted handshakes) — each one also
    /// costs the accept loop a backoff sleep
    pub accept_errors: AtomicU64,
}

/// One executor shard as the router sees it: the bounded request channel
/// plus a gauge of requests enqueued or executing (incremented on a
/// successful send, decremented when the reply lands), which prices the
/// `retry_after_ms` hint when the queue sheds.
struct Shard {
    tx: ReqTx,
    in_flight: AtomicUsize,
}

impl Shard {
    fn new(tx: ReqTx) -> Shard {
        Shard { tx, in_flight: AtomicUsize::new(0) }
    }
}

/// Routes wire requests to executor shards and aggregates fan-out ops.
pub struct Router {
    shards: Vec<Shard>,
    hlo: Option<Shard>,
    queue_depth: usize,
    next_native_id: AtomicU64,
    next_hlo_id: AtomicU64,
    shutdown: AtomicBool,
    stats: Arc<ServeStats>,
    /// the router's own telemetry domain: whole-request wire latency
    /// per op, recorded by the connection handlers
    telemetry: Arc<Telemetry>,
    /// every executor's telemetry (native shards in order, then the HLO
    /// executor if one runs) — merged on a `metrics` op
    shard_tel: Vec<Arc<Telemetry>>,
}

/// Blocking send: waits for queue space. Reserved for the control ops
/// (`stats`, `shutdown`, `drain`) that must reach their shard even under
/// load.
fn call_on(tx: &ReqTx, req: Request) -> Reply {
    let (rtx, rrx) = mpsc::channel();
    tx.send((req, rtx, Instant::now())).map_err(|_| anyhow!("executor thread gone"))?;
    rrx.recv().map_err(|_| anyhow!("executor dropped reply"))?
}

/// Backpressured send: a full shard queue is refused on the spot with a
/// structured `overloaded` error (and counted) instead of blocking the
/// handler thread behind it — the hint scales with the shard's current
/// occupancy via [`retry_hint_ms`]. Session ops go through here.
fn try_call_on(shard: &Shard, depth: usize, req: Request, stats: &ServeStats) -> Reply {
    let (rtx, rrx) = mpsc::channel();
    match shard.tx.try_send((req, rtx, Instant::now())) {
        Ok(()) => {}
        Err(mpsc::TrySendError::Full(_)) => {
            stats.overloaded_rejects.fetch_add(1, Ordering::Relaxed);
            return Err(Kinded::overloaded(
                "executor queue full — back off and retry",
                retry_hint_ms(shard.in_flight.load(Ordering::Relaxed), depth),
            ));
        }
        Err(mpsc::TrySendError::Disconnected(_)) => return Err(anyhow!("executor thread gone")),
    }
    shard.in_flight.fetch_add(1, Ordering::Relaxed);
    let out = rrx.recv().map_err(|_| anyhow!("executor dropped reply"));
    shard.in_flight.fetch_sub(1, Ordering::Relaxed);
    out?
}

impl Router {
    /// Spawn the executor pool described by `cfg` and return the router
    /// over it.
    pub fn start(cfg: &ServeConfig) -> Result<Router> {
        let nshards = cfg.shards.max(1);
        // seed the id counter past any sessions already spilled on disk,
        // so a restarted server can never hand out an id that would
        // collide with (and be refused by) a surviving snapshot
        let mut first_native_id = 1u64;
        if let Some(dir) = &cfg.spill_dir {
            // foreign snapshot files beyond the native namespace are
            // ignored here: seeding past HLO_ID_BASE would make every
            // future create fail as exhausted
            let max = DirStore::open(dir)?.ids().into_iter().filter(|&id| id < HLO_ID_BASE).max();
            if let Some(max) = max {
                first_native_id = max + 1;
            }
        }
        // the global resident cap is split evenly across the shards —
        // each shard enforces its share locally, so the pool-wide
        // resident count stays within ~cap (rounded up per shard)
        let per_shard_cap =
            cfg.max_resident_sessions.map(|cap| cap.div_ceil(nshards).max(1));
        // only an *active* plan is threaded through (a parsed-but-empty
        // plan injects nothing and would just slow the step path)
        let fault_plan = cfg.fault.as_ref().filter(|p| p.is_active());
        let queue_depth = cfg.queue_depth.max(1);
        let mut shards = Vec::with_capacity(nshards);
        let mut shard_tel = Vec::with_capacity(nshards);
        for s in 0..nshards {
            let (tx, rx) = mpsc::sync_channel(queue_depth);
            let channels = cfg.channels;
            let resident = cfg.resident_lanes;
            let spill = match &cfg.spill_dir {
                Some(dir) => {
                    let store: Box<dyn SnapshotStore> =
                        Box::new(DirStore::open_partition(dir, s as u64, nshards as u64)?);
                    // each shard's store gets its own independently
                    // seeded fault site, so injected IO errors on one
                    // shard never perturb the others' sequences
                    let store = match fault_plan {
                        Some(plan) => {
                            Box::new(FaultingStore::new(store, plan.site(&format!("store-{s}"))))
                        }
                        None => store,
                    };
                    Some(SpillTier { store, max_resident: per_shard_cap })
                }
                None => None,
            };
            let tel = Arc::new(Telemetry::new(cfg.telemetry));
            shard_tel.push(Arc::clone(&tel));
            let opts = ExecutorOpts {
                session_ttl: cfg.session_ttl,
                spill,
                resident,
                fault: fault_plan.map(|plan| plan.site(&format!("exec-{s}"))),
                telemetry: tel,
            };
            std::thread::Builder::new()
                .name(format!("serve-exec-{s}"))
                .spawn(move || run_executor(NativeFactory { channels }, rx, opts))?;
            shards.push(Shard::new(tx));
        }
        #[cfg(feature = "pjrt")]
        let hlo = match &cfg.artifacts {
            Some(dir) => {
                let (tx, rx) = mpsc::sync_channel(queue_depth);
                let dir = dir.clone();
                let ttl = cfg.session_ttl;
                let tel = Arc::new(Telemetry::new(cfg.telemetry));
                shard_tel.push(Arc::clone(&tel));
                std::thread::Builder::new().name("serve-exec-hlo".to_string()).spawn(
                    // no spill tier: HLO sessions cannot snapshot (their
                    // state is device literals), so TTL expiry keeps its
                    // plain-eviction behaviour on this executor
                    move || match hlo_backend::HloFactory::new(&dir) {
                        // resident lanes are a native-Aaren feature; the
                        // HLO tier's sessions never downcast, so the flag
                        // is moot here
                        Ok(factory) => {
                            let opts = ExecutorOpts {
                                session_ttl: ttl,
                                resident: false,
                                telemetry: tel,
                                ..Default::default()
                            };
                            run_executor(factory, rx, opts)
                        }
                        // dropping rx makes every later hlo request fail
                        // with "executor thread gone" instead of hanging
                        Err(e) => eprintln!("[serve] hlo backend unavailable: {e:#}"),
                    },
                )?;
                Some(Shard::new(tx))
            }
            None => None,
        };
        #[cfg(not(feature = "pjrt"))]
        let hlo: Option<Shard> = None;
        Ok(Router {
            shards,
            hlo,
            queue_depth,
            next_native_id: AtomicU64::new(first_native_id),
            next_hlo_id: AtomicU64::new(0),
            shutdown: AtomicBool::new(false),
            stats: Arc::new(ServeStats::default()),
            telemetry: Arc::new(Telemetry::new(cfg.telemetry)),
            shard_tel,
        })
    }

    /// The connection/admission counters this router folds into `stats`
    /// replies. The accept loop shares this handle.
    pub fn stats(&self) -> Arc<ServeStats> {
        Arc::clone(&self.stats)
    }

    /// Every stage histogram, merged across the router's own domain
    /// (per-op wire latency) and all executor shards. Raw buckets
    /// merge; percentiles are re-derived from the merged buckets.
    fn merged_snapshots(&self) -> BTreeMap<String, crate::obs::HistSnapshot> {
        obs::merge_named(
            std::iter::once(self.telemetry.snapshots())
                .chain(self.shard_tel.iter().map(|t| t.snapshots())),
        )
    }

    /// The `metrics` op's reply: merged per-stage histograms, the
    /// admission/flight counters, and the newest flight-recorder events
    /// across all shards (each stamped with its shard index, ordered by
    /// timestamp then sequence, capped at [`METRICS_MAX_EVENTS`]).
    pub fn metrics_json(&self) -> Json {
        let merged = self.merged_snapshots();
        let (mut logged, mut dropped) = (0u64, 0u64);
        let mut tagged: Vec<(u64, u64, Json)> = Vec::new();
        for (s, tel) in self.shard_tel.iter().enumerate() {
            logged += tel.recorder().logged();
            dropped += tel.recorder().dropped();
            for e in tel.recorder().recent() {
                let Json::Obj(mut fields) = e.to_json() else {
                    continue;
                };
                fields.insert("shard".to_string(), Json::Num(s as f64));
                tagged.push((e.ts_ms, e.seq, Json::Obj(fields)));
            }
        }
        tagged.sort_by_key(|t| (t.0, t.1));
        if tagged.len() > METRICS_MAX_EVENTS {
            let cut = tagged.len() - METRICS_MAX_EVENTS;
            tagged.drain(..cut);
        }
        let events: Vec<Json> = tagged.into_iter().map(|(_, _, j)| j).collect();
        let counters = obj(vec![
            (
                "overloaded_rejects",
                Json::Num(self.stats.overloaded_rejects.load(Ordering::Relaxed) as f64),
            ),
            (
                "accept_errors",
                Json::Num(self.stats.accept_errors.load(Ordering::Relaxed) as f64),
            ),
            ("events_logged", Json::Num(logged as f64)),
            ("events_dropped", Json::Num(dropped as f64)),
        ]);
        obj(vec![
            ("histograms", obs::histograms_json(&merged)),
            ("counters", counters),
            ("events", Json::Arr(events)),
        ])
    }

    pub fn is_shutdown(&self) -> bool {
        self.shutdown.load(Ordering::SeqCst)
    }

    fn create_target(&self, backend: Backend) -> Result<(&Shard, u64)> {
        match backend {
            Backend::Native => {
                let id = self.next_native_id.fetch_add(1, Ordering::Relaxed);
                // an id at or past HLO_ID_BASE would route to the HLO
                // executor on every later request and be unreachable —
                // refuse loudly instead (only hit after an explicit id
                // claimed the top of the namespace)
                ensure!(id < HLO_ID_BASE, "native session id space exhausted");
                Ok((&self.shards[(id as usize) % self.shards.len()], id))
            }
            Backend::Hlo => {
                let msg = if cfg!(feature = "pjrt") {
                    "server started without HLO artifacts (pass --artifacts DIR)"
                } else {
                    "this build has no HLO backend (rebuild with --features pjrt)"
                };
                let tx = self.hlo.as_ref().ok_or_else(|| anyhow!(msg))?;
                let id = HLO_ID_BASE + self.next_hlo_id.fetch_add(1, Ordering::Relaxed);
                Ok((tx, id))
            }
        }
    }

    fn route(&self, id: u64) -> Result<&Shard> {
        if id >= HLO_ID_BASE {
            self.hlo.as_ref().ok_or_else(|| anyhow!("no session {id}"))
        } else {
            Ok(&self.shards[(id as usize) % self.shards.len()])
        }
    }

    fn targets(&self) -> impl Iterator<Item = &Shard> + '_ {
        self.shards.iter().chain(self.hlo.iter())
    }

    /// Execute one wire request, fanning out / aggregating where the op
    /// spans shards (`stats`, `shutdown`).
    pub fn dispatch(&self, op: WireOp) -> Result<Json> {
        match op {
            WireOp::Create { kind, backend, id } => {
                let (tx, id) = match id {
                    // client-chosen id (session-naming conventions,
                    // re-adopting a migrated id): routed like any other,
                    // refused by the executor if it already exists
                    Some(id) => {
                        ensure!(
                            backend == Backend::Native,
                            "explicit session ids are only supported on the native backend"
                        );
                        ensure!(
                            id >= 1 && id < HLO_ID_BASE,
                            "explicit id {id} is outside the native id range [1, {HLO_ID_BASE})"
                        );
                        // keep auto-assigned ids from ever landing on it
                        self.next_native_id.fetch_max(id + 1, Ordering::Relaxed);
                        (&self.shards[(id as usize) % self.shards.len()], id)
                    }
                    None => self.create_target(backend)?,
                };
                let req = Request::Create { id, kind };
                match try_call_on(tx, self.queue_depth, req, &self.stats)? {
                    Response::Value(j) => Ok(j),
                    _ => bail!("unexpected reply to create"),
                }
            }
            WireOp::Snapshot { id } => {
                match try_call_on(
                    self.route(id)?,
                    self.queue_depth,
                    Request::Snapshot { id },
                    &self.stats,
                )? {
                    Response::Value(j) => Ok(j),
                    _ => bail!("unexpected reply to snapshot"),
                }
            }
            WireOp::Restore { blob, id } => {
                // restored sessions land on the native tier — with a
                // fresh id by default (the blob is self-describing; the
                // id in force on the exporting server has no meaning
                // here), or at an explicit client-chosen target id (a
                // migration that keeps its session naming). A target id
                // that already exists — resident or spilled — is refused
                // by the executor with a structured "already exists"
                // error, exactly like a duplicate `create`.
                let id = match id {
                    Some(id) => {
                        ensure!(
                            id >= 1 && id < HLO_ID_BASE,
                            "explicit id {id} is outside the native id range [1, {HLO_ID_BASE})"
                        );
                        // keep auto-assigned ids from ever landing on it
                        self.next_native_id.fetch_max(id + 1, Ordering::Relaxed);
                        id
                    }
                    None => {
                        let id = self.next_native_id.fetch_add(1, Ordering::Relaxed);
                        ensure!(id < HLO_ID_BASE, "native session id space exhausted");
                        id
                    }
                };
                let tx = &self.shards[(id as usize) % self.shards.len()];
                match try_call_on(tx, self.queue_depth, Request::Restore { id, blob }, &self.stats)?
                {
                    Response::Value(j) => Ok(j),
                    _ => bail!("unexpected reply to restore"),
                }
            }
            WireOp::Step { id, x } => {
                match try_call_on(
                    self.route(id)?,
                    self.queue_depth,
                    Request::Step { id, x },
                    &self.stats,
                )? {
                    Response::Value(j) => Ok(j),
                    _ => bail!("unexpected reply to step"),
                }
            }
            WireOp::Steps { id, xs, n } => {
                match try_call_on(
                    self.route(id)?,
                    self.queue_depth,
                    Request::Steps { id, xs, n },
                    &self.stats,
                )? {
                    Response::Value(j) => Ok(j),
                    _ => bail!("unexpected reply to steps"),
                }
            }
            WireOp::Close { id } => {
                match try_call_on(
                    self.route(id)?,
                    self.queue_depth,
                    Request::Close { id },
                    &self.stats,
                )? {
                    Response::Value(j) => Ok(j),
                    _ => bail!("unexpected reply to close"),
                }
            }
            WireOp::Drain { id } => {
                // control-plane op (the fleet rebalancer's first
                // migration step): a blocking send, so a busy queue
                // delays the drain instead of shedding it
                match call_on(&self.route(id)?.tx, Request::Drain { id })? {
                    Response::Value(j) => Ok(j),
                    _ => bail!("unexpected reply to drain"),
                }
            }
            // answered by the router itself, no executor round-trip: a
            // heartbeat must stay cheap and must not be shed by a full
            // queue — reachability and capacity are different questions
            WireOp::Ping => Ok(obj(vec![("ok", Json::Bool(true))])),
            // also router-answered: the telemetry handles are shared
            // Arcs, so reading histograms never competes with the data
            // plane for executor queue space
            WireOp::Metrics => Ok(self.metrics_json()),
            WireOp::Stats => {
                let (mut count, mut bytes, mut on_disk) = (0usize, 0usize, 0usize);
                let (mut quarantined_total, mut corrupt_total) = (0usize, 0usize);
                let (mut spills_total, mut restores_total) = (0usize, 0usize);
                let mut backend_totals: BTreeMap<String, (usize, usize)> = BTreeMap::new();
                for shard in self.targets() {
                    // a dead executor contributes nothing instead of
                    // failing the whole aggregate
                    if let Ok(Response::Stats {
                        sessions,
                        state_bytes,
                        spilled,
                        quarantined,
                        corrupt_snapshots,
                        spills,
                        restores,
                        backends,
                    }) = call_on(&shard.tx, Request::Stats)
                    {
                        count += sessions;
                        bytes += state_bytes;
                        on_disk += spilled;
                        quarantined_total += quarantined;
                        corrupt_total += corrupt_snapshots;
                        spills_total += spills;
                        restores_total += restores;
                        for (name, (resident, spilled)) in backends {
                            let entry = backend_totals.entry(name).or_default();
                            entry.0 += resident;
                            entry.1 += spilled;
                        }
                    }
                }
                let backends_json = Json::Obj(
                    backend_totals
                        .into_iter()
                        .map(|(name, (resident, spilled))| {
                            (
                                name,
                                obj(vec![
                                    ("resident", Json::Num(resident as f64)),
                                    ("spilled", Json::Num(spilled as f64)),
                                ]),
                            )
                        })
                        .collect::<BTreeMap<_, _>>(),
                );
                Ok(obj(vec![
                    ("sessions", Json::Num(count as f64)),
                    ("total_state_bytes", Json::Num(bytes as f64)),
                    ("spilled", Json::Num(on_disk as f64)),
                    ("quarantined", Json::Num(quarantined_total as f64)),
                    ("corrupt_snapshots", Json::Num(corrupt_total as f64)),
                    ("spills", Json::Num(spills_total as f64)),
                    ("restores", Json::Num(restores_total as f64)),
                    ("backends", backends_json),
                    (
                        "overloaded_rejects",
                        Json::Num(self.stats.overloaded_rejects.load(Ordering::Relaxed) as f64),
                    ),
                    (
                        "accept_errors",
                        Json::Num(self.stats.accept_errors.load(Ordering::Relaxed) as f64),
                    ),
                ]))
            }
            WireOp::Shutdown => {
                for shard in self.targets() {
                    let _ = call_on(&shard.tx, Request::Shutdown);
                }
                self.shutdown.store(true, Ordering::SeqCst);
                Ok(obj(vec![("ok", Json::Bool(true))]))
            }
        }
    }
}

/// A request as it arrives on the wire, before the router assigns ids.
pub enum WireOp {
    Create { kind: String, backend: Backend, id: Option<u64> },
    Step { id: u64, x: Vec<f32> },
    Steps { id: u64, xs: Vec<f32>, n: usize },
    Snapshot { id: u64 },
    Restore { blob: Vec<u8>, id: Option<u64> },
    Close { id: u64 },
    /// Spill + release one session on demand (fleet rebalance step 1).
    Drain { id: u64 },
    /// Liveness probe, answered by the router without touching any
    /// executor — the fleet's heartbeat op.
    Ping,
    Stats,
    /// Telemetry dump: merged latency histograms, counters and recent
    /// flight-recorder events — router-answered, like `ping`.
    Metrics,
    Shutdown,
}

/// Flight-recorder events returned by one `metrics` reply at most —
/// bounds the reply line even when many shards' rings are all full.
pub const METRICS_MAX_EVENTS: usize = 128;

/// The wire-latency histogram a request records into.
fn op_stage(op: &WireOp) -> Stage {
    match op {
        WireOp::Create { .. } => Stage::OpCreate,
        WireOp::Step { .. } => Stage::OpStep,
        WireOp::Steps { .. } => Stage::OpSteps,
        WireOp::Snapshot { .. } => Stage::OpSnapshot,
        WireOp::Restore { .. } => Stage::OpRestore,
        WireOp::Close { .. } => Stage::OpClose,
        WireOp::Drain { .. } => Stage::OpDrain,
        WireOp::Ping => Stage::OpPing,
        WireOp::Stats => Stage::OpStats,
        WireOp::Metrics => Stage::OpMetrics,
        WireOp::Shutdown => Stage::OpShutdown,
    }
}

fn parse_request(line: &str) -> Result<WireOp> {
    let j = Json::parse(line).map_err(|e| anyhow!("bad json: {e}"))?;
    match j.str_field("op")? {
        "create" => {
            let mut kind = match j.get("kind") {
                None => None,
                Some(v) => Some(
                    v.as_str()
                        .ok_or_else(|| anyhow!("create kind must be a string"))?
                        .to_string(),
                ),
            };
            let backend = match j.get("backend").and_then(Json::as_str) {
                None | Some("native") => Backend::Native,
                Some("hlo") => Backend::Hlo,
                Some(other) => match KernelKind::from_wire(other) {
                    // a kernel name as "backend" is shorthand for the
                    // native tier running that kernel; "kind" may be
                    // omitted then, but must not contradict
                    Some(k) => {
                        match &kind {
                            Some(existing) if existing != k.wire_name() => {
                                bail!("backend {other:?} conflicts with kind {existing:?}")
                            }
                            _ => kind = Some(k.wire_name().to_string()),
                        }
                        Backend::Native
                    }
                    None => bail!(
                        "unknown backend {other:?} (native|hlo|aaren|mingru|minlstm|avg_attn)"
                    ),
                },
            };
            let id = match j.get("id") {
                None => None,
                Some(v) => Some(
                    v.as_usize().ok_or_else(|| anyhow!("create id must be a number"))? as u64,
                ),
            };
            let kind = match kind {
                Some(k) => k,
                // surface the standard missing-field error
                None => j.str_field("kind")?.to_string(),
            };
            Ok(WireOp::Create { kind, backend, id })
        }
        "snapshot" => Ok(WireOp::Snapshot { id: j.usize_field("id")? as u64 }),
        "restore" => {
            let blob = b64::decode(j.str_field("state")?)
                .map_err(|e| anyhow!("restore state is not valid base64: {e:#}"))?;
            let id = match j.get("id") {
                None => None,
                Some(v) => Some(
                    v.as_usize().ok_or_else(|| anyhow!("restore id must be a number"))? as u64,
                ),
            };
            Ok(WireOp::Restore { blob, id })
        }
        "step" => {
            let id = j.usize_field("id")? as u64;
            let arr = j.get("x").and_then(Json::as_arr).ok_or_else(|| anyhow!("missing x"))?;
            let mut x = Vec::with_capacity(arr.len());
            for (i, v) in arr.iter().enumerate() {
                // reject instead of coercing to NaN/inf: one such value
                // would poison the session's (m, u, w) state for every
                // later step and make the reply line unprintable as JSON.
                // Validate AFTER the f32 cast — a finite f64 like 1e40
                // still saturates to +inf in f32.
                let f = v.as_f64().ok_or_else(|| anyhow!("x[{i}] is not a number"))? as f32;
                if !f.is_finite() {
                    bail!("x[{i}] is not a finite f32");
                }
                x.push(f);
            }
            Ok(WireOp::Step { id, x })
        }
        "steps" => {
            // n tokens in one message, the outputs streamed back in
            // blocks of at most STEPS_REPLY_BLOCK tokens per reply line
            let id = j.usize_field("id")? as u64;
            let rows = j.get("xs").and_then(Json::as_arr).ok_or_else(|| anyhow!("missing xs"))?;
            let n = rows.len();
            // absurd block sizes are refused here, before the token
            // floats (or any reply buffer) are allocated
            ensure!(
                n <= MAX_STEPS_TOKENS,
                "steps block of {n} tokens exceeds the {MAX_STEPS_TOKENS}-token limit — \
                 split the stream into smaller requests"
            );
            let mut xs = Vec::new();
            let mut width: Option<usize> = None;
            for (r, row) in rows.iter().enumerate() {
                let arr = row.as_arr().ok_or_else(|| anyhow!("xs[{r}] is not an array"))?;
                match width {
                    None => width = Some(arr.len()),
                    Some(w) => ensure!(
                        arr.len() == w,
                        "xs[{r}] has {} elements, xs[0] has {w}",
                        arr.len()
                    ),
                }
                for (i, v) in arr.iter().enumerate() {
                    // same finiteness contract as `step`: reject rather
                    // than poison the session's (m, u, w) state
                    let f =
                        v.as_f64().ok_or_else(|| anyhow!("xs[{r}][{i}] is not a number"))? as f32;
                    if !f.is_finite() {
                        bail!("xs[{r}][{i}] is not a finite f32");
                    }
                    xs.push(f);
                }
            }
            Ok(WireOp::Steps { id, xs, n })
        }
        "close" => Ok(WireOp::Close { id: j.usize_field("id")? as u64 }),
        "drain" => Ok(WireOp::Drain { id: j.usize_field("id")? as u64 }),
        "ping" => Ok(WireOp::Ping),
        "stats" => Ok(WireOp::Stats),
        "metrics" => Ok(WireOp::Metrics),
        "shutdown" => Ok(WireOp::Shutdown),
        other => Err(anyhow!("unknown op {other:?}")),
    }
}

/// Serve one `steps` request whose reply would exceed the block bound:
/// the token block is executed in STEPS_REPLY_BLOCK-token slices, each
/// answered by its own reply line — all but the last carrying
/// `"partial":true` — so reply memory is bounded by the block size, not
/// by n. For the sending connection the semantics match one giant
/// reply: the same tokens advance the stream in order, each line's
/// `t`/`state_bytes` describe the stream after its slice, and an error
/// line (always final) leaves the stream advanced by the slices that
/// executed, exactly like a mid-block failure of a plain `steps` call.
/// One atomicity caveat: the slices are separate executor dispatches,
/// so ANOTHER connection's op on the same session (close, snapshot,
/// more steps) may land between slices — a concurrent close turns the
/// remainder into the error line, and a concurrent snapshot can observe
/// the stream mid-request. Clients sharing one session across
/// connections already needed external coordination; this widens the
/// window, it does not create it. Returns false if the connection died
/// mid-stream.
fn stream_steps_blocks(
    writer: &mut TcpStream,
    router: &Router,
    id: u64,
    xs: &[f32],
    n: usize,
) -> bool {
    let d = xs.len() / n.max(1);
    let mut off = 0usize;
    while off < n {
        let take = STEPS_REPLY_BLOCK.min(n - off);
        let block = xs[off * d..(off + take) * d].to_vec();
        let resp = router.dispatch(WireOp::Steps { id, xs: block, n: take });
        off += take;
        let failed = resp.is_err();
        let body = match resp {
            Ok(Json::Obj(mut fields)) => {
                if off < n {
                    fields.insert("partial".to_string(), Json::Bool(true));
                }
                Json::Obj(fields).to_string()
            }
            Ok(other) => other.to_string(),
            Err(e) => error_body(&e).to_string(),
        };
        if writer.write_all(body.as_bytes()).is_err() || writer.write_all(b"\n").is_err() {
            return false;
        }
        if failed {
            break; // the error line is final; remaining slices are not sent
        }
    }
    true
}

/// One frame off the wire, or the reason there isn't one.
pub(crate) enum Frame {
    Line(String),
    /// the line crossed `max_frame_bytes` before its newline — the rest
    /// of the frame is unread, so the connection cannot be resynced
    TooLong,
    Eof,
}

/// Read one newline-terminated frame with a hard byte cap. The cap is
/// enforced *while reading*: an attacker streaming an endless line is
/// cut off after `max` bytes instead of growing a String until OOM.
pub(crate) fn read_frame(reader: &mut BufReader<TcpStream>, max: usize) -> Frame {
    let mut line = Vec::new();
    loop {
        let buf = match reader.fill_buf() {
            Ok(b) => b,
            Err(_) => return Frame::Eof, // includes read-timeout expiry
        };
        if buf.is_empty() {
            // clean EOF; a non-empty unterminated tail is not a frame
            return Frame::Eof;
        }
        match buf.iter().position(|&b| b == b'\n') {
            Some(pos) => {
                if line.len() + pos > max {
                    return Frame::TooLong;
                }
                line.extend_from_slice(&buf[..pos]);
                reader.consume(pos + 1);
                return Frame::Line(String::from_utf8_lossy(&line).into_owned());
            }
            None => {
                let n = buf.len();
                if line.len() + n > max {
                    return Frame::TooLong;
                }
                line.extend_from_slice(buf);
                reader.consume(n);
            }
        }
    }
}

/// After a `TooLong` frame: consume up to the offending frame's newline
/// (or a hard byte cap) before the connection closes. Closing with the
/// tail still unread would turn the close into a TCP RST, which may
/// discard the structured `frame_too_large` reply from the peer's
/// receive queue before it reads it. The cap — together with the
/// connection's read timeout — bounds how long an abusive peer can hold
/// the handler thread; past it the socket closes RST and all.
pub(crate) fn drain_frame_tail(reader: &mut BufReader<TcpStream>) {
    let mut budget: usize = 1 << 20;
    while budget > 0 {
        let buf = match reader.fill_buf() {
            Ok(b) if !b.is_empty() => b,
            _ => return, // EOF, read error or timeout: nothing to drain
        };
        match buf.iter().position(|&b| b == b'\n') {
            Some(pos) => {
                reader.consume((pos + 1).min(budget));
                return;
            }
            None => {
                let n = buf.len().min(budget);
                reader.consume(n);
                budget -= n;
            }
        }
    }
}

fn handle_conn(
    stream: TcpStream,
    router: &Router,
    wake_addr: Option<SocketAddr>,
    max_frame_bytes: usize,
    io_timeout: Option<Duration>,
) {
    // a peer that stops reading or writing releases this thread at the
    // timeout instead of holding it (and its admission slot) forever
    let _ = stream.set_read_timeout(io_timeout);
    let _ = stream.set_write_timeout(io_timeout);
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let mut reader = BufReader::new(stream);
    loop {
        let line = match read_frame(&mut reader, max_frame_bytes) {
            Frame::Line(l) => l,
            Frame::Eof => break,
            Frame::TooLong => {
                // the oversized frame's tail is still in flight; there
                // is no way back to a frame boundary, so reply and close
                let e = Kinded::frame_too_large(format!(
                    "request frame exceeds the {max_frame_bytes}-byte limit"
                ));
                let body = error_body(&e).to_string();
                let _ = writer.write_all(body.as_bytes());
                let _ = writer.write_all(b"\n");
                drain_frame_tail(&mut reader);
                break;
            }
        };
        if line.trim().is_empty() {
            continue;
        }
        match parse_request(&line) {
            // a steps block too large for one bounded reply streams back
            // in partial lines instead of materializing a giant one
            Ok(WireOp::Steps { id, xs, n }) if n > STEPS_REPLY_BLOCK => {
                let alive = {
                    // whole-request wire latency, reply streaming included
                    crate::obs::span!(router.telemetry, Stage::OpSteps);
                    stream_steps_blocks(&mut writer, router, id, &xs, n)
                };
                if !alive {
                    break;
                }
            }
            parsed => {
                let resp = parsed.and_then(|op| {
                    crate::obs::span!(router.telemetry, op_stage(&op));
                    router.dispatch(op)
                });
                let body = match resp {
                    Ok(j) => j.to_string(),
                    Err(e) => error_body(&e).to_string(),
                };
                if writer.write_all(body.as_bytes()).is_err() || writer.write_all(b"\n").is_err()
                {
                    break;
                }
            }
        }
        if router.is_shutdown() {
            break;
        }
    }
    if router.is_shutdown() {
        // wake the accept loop so Server::run can observe the flag; a
        // listener bound to the unspecified address (0.0.0.0 / ::) is not
        // connectable on every platform, so rewrite to its loopback
        if let Some(mut addr) = wake_addr {
            if addr.ip().is_unspecified() {
                addr.set_ip(match addr.ip() {
                    IpAddr::V4(_) => IpAddr::V4(std::net::Ipv4Addr::LOCALHOST),
                    IpAddr::V6(_) => IpAddr::V6(std::net::Ipv6Addr::LOCALHOST),
                });
            }
            let _ = TcpStream::connect(addr);
        }
    }
}

/// A bound listener plus its executor pool. `run` serves until a
/// `shutdown` request arrives.
pub struct Server {
    listener: TcpListener,
    router: Arc<Router>,
    stats: Arc<ServeStats>,
    max_conns: Option<usize>,
    max_frame_bytes: usize,
    io_timeout: Option<Duration>,
}

impl Server {
    pub fn bind(cfg: &ServeConfig) -> Result<Server> {
        let listener = TcpListener::bind(cfg.addr.as_str())?;
        let router = Arc::new(Router::start(cfg)?);
        let stats = router.stats();
        Ok(Server {
            listener,
            router,
            stats,
            max_conns: cfg.max_conns,
            max_frame_bytes: cfg.max_frame_bytes.max(1),
            io_timeout: cfg.io_timeout,
        })
    }

    pub fn local_addr(&self) -> Result<SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Accept connections (one handler thread each) until shutdown.
    /// Admission control happens here: over `max_conns` the peer gets
    /// one structured `overloaded` line and is dropped; accept errors
    /// (EMFILE et al.) are counted and backed off instead of busy-spun.
    pub fn run(&self) -> Result<()> {
        let wake_addr = self.listener.local_addr().ok();
        let active = Arc::new(AtomicUsize::new(0));
        // seeded jitter source for the accept-error backoff: per-process
        // deterministic, so chaos runs replay while separate processes
        // herding on a shared condition (a full fd table, say) spread out
        let mut backoff_rng = Rng::new(0x0ACC_EB7E);
        let mut consecutive_errors = 0u32;
        for stream in self.listener.incoming() {
            if self.router.is_shutdown() {
                break;
            }
            match stream {
                Ok(mut s) => {
                    consecutive_errors = 0;
                    if let Some(cap) = self.max_conns {
                        // claim a slot up front — the CAS-free add is fine
                        // because over-claims are immediately released
                        if active.fetch_add(1, Ordering::AcqRel) >= cap {
                            active.fetch_sub(1, Ordering::AcqRel);
                            self.stats.overloaded_rejects.fetch_add(1, Ordering::Relaxed);
                            let e = Kinded::overloaded(
                                format!("server at its {cap}-connection limit"),
                                RETRY_AFTER_MS,
                            );
                            // best-effort courtesy line; never let a
                            // non-reading peer wedge the accept loop
                            let _ = s.set_write_timeout(Some(Duration::from_secs(1)));
                            let _ = s.write_all(error_body(&e).to_string().as_bytes());
                            let _ = s.write_all(b"\n");
                            continue;
                        }
                    } else {
                        active.fetch_add(1, Ordering::AcqRel);
                    }
                    let router = Arc::clone(&self.router);
                    let active = Arc::clone(&active);
                    let (max_frame, timeout) = (self.max_frame_bytes, self.io_timeout);
                    std::thread::spawn(move || {
                        handle_conn(s, &router, wake_addr, max_frame, timeout);
                        active.fetch_sub(1, Ordering::AcqRel);
                    });
                }
                Err(e) => {
                    consecutive_errors = consecutive_errors.saturating_add(1);
                    self.stats.accept_errors.fetch_add(1, Ordering::Relaxed);
                    eprintln!("[serve] accept error: {e}");
                    // EMFILE and friends persist for a while: sleeping
                    // beats spinning the core and flooding stderr, and
                    // the capped-exponential schedule backs further off
                    // the longer the condition lasts
                    std::thread::sleep(accept_backoff(consecutive_errors, &mut backoff_rng));
                }
            }
        }
        Ok(())
    }
}

/// Serve forever on `cfg.addr` (e.g. "127.0.0.1:7878").
pub fn serve(cfg: &ServeConfig) -> Result<()> {
    let server = Server::bind(cfg)?;
    let ttl = match cfg.session_ttl {
        Some(d) => format!("session ttl {}s", d.as_secs()),
        None => "no session ttl".to_string(),
    };
    let spill = match &cfg.spill_dir {
        Some(dir) => match cfg.max_resident_sessions {
            Some(cap) => format!("spill dir {} (max {cap} resident)", dir.display()),
            None => format!("spill dir {}", dir.display()),
        },
        None => "no spill tier".to_string(),
    };
    let conns = match cfg.max_conns {
        Some(cap) => format!("max {cap} conns"),
        None => "unbounded conns".to_string(),
    };
    let fault = match &cfg.fault {
        Some(p) if p.is_active() => format!("; FAULT INJECTION ACTIVE (seed {})", p.seed),
        _ => String::new(),
    };
    println!(
        "[serve] listening on {} ({} native executor shard(s); {ttl}; {spill}; {conns}, \
         queue depth {}, frame cap {} bytes{fault}; line-delimited JSON; \
         ops: create/step/steps/snapshot/restore/close/drain/ping/stats/metrics/shutdown)",
        server.local_addr()?,
        cfg.shards.max(1),
        cfg.queue_depth.max(1),
        cfg.max_frame_bytes.max(1)
    );
    if let Some(every) = cfg.metrics_interval {
        let router = Arc::clone(&server.router);
        std::thread::Builder::new().name("serve-metrics".to_string()).spawn(move || {
            while !router.is_shutdown() {
                std::thread::sleep(every);
                eprintln!("{}", metrics_digest(&router));
            }
        })?;
    }
    server.run()
}

/// One compact stderr line for the `--metrics-interval-secs` thread:
/// every non-empty per-op histogram's count, p50 and p99 (µs).
fn metrics_digest(router: &Router) -> String {
    let merged = router.merged_snapshots();
    let mut parts = Vec::new();
    for (name, snap) in &merged {
        if !name.starts_with("op_") {
            continue;
        }
        parts.push(format!(
            "{name} n={} p50={}us p99={}us",
            snap.count(),
            snap.percentile(0.50) / 1_000,
            snap.percentile(0.99) / 1_000
        ));
    }
    if parts.is_empty() {
        "[metrics] no requests served yet".to_string()
    } else {
        format!("[metrics] {}", parts.join("; "))
    }
}

/// Minimal blocking line-JSON client over one TCP connection — used by
/// the CLI `serve --smoke` self-test, the loopback integration tests and
/// the `serve_loopback` bench.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: &SocketAddr) -> Result<Client> {
        let stream = TcpStream::connect(addr)?;
        let writer = stream.try_clone()?;
        Ok(Client { reader: BufReader::new(stream), writer })
    }

    /// Bound every read/write on this connection — chaos tests use this
    /// so a hung server fails an assertion instead of hanging the test.
    pub fn set_io_timeout(&mut self, timeout: Option<Duration>) -> Result<()> {
        self.reader.get_ref().set_read_timeout(timeout)?;
        self.writer.set_write_timeout(timeout)?;
        Ok(())
    }

    /// Send one request line, read one reply line, parse it. Replies
    /// carrying an `"error"` field become `Err` as
    /// `"server error ({kind}): {message}"`.
    pub fn call(&mut self, line: &str) -> Result<Json> {
        let reply = self.call_raw(line)?;
        if let Some((kind, msg)) = wire_error(&reply) {
            bail!("server error ({kind}): {msg}");
        }
        Ok(reply)
    }

    /// Like [`call`](Client::call) but returns error replies as plain
    /// objects (protocol tests inspect them).
    pub fn call_raw(&mut self, line: &str) -> Result<Json> {
        self.writer.write_all(line.as_bytes())?;
        self.writer.write_all(b"\n")?;
        let mut buf = String::new();
        if self.reader.read_line(&mut buf)? == 0 {
            bail!("server closed the connection");
        }
        Json::parse(buf.trim()).map_err(|e| anyhow!("bad reply {buf:?}: {e}"))
    }

    /// Send one request and read reply lines until the final one (the
    /// first without `"partial":true`) — how a large `steps` block is
    /// consumed. Returns every reply object in order; an error reply
    /// (always final) becomes `Err` after any partial replies were
    /// already folded in by the caller's stream position.
    pub fn call_streamed(&mut self, line: &str) -> Result<Vec<Json>> {
        self.writer.write_all(line.as_bytes())?;
        self.writer.write_all(b"\n")?;
        let mut replies = Vec::new();
        loop {
            let mut buf = String::new();
            if self.reader.read_line(&mut buf)? == 0 {
                bail!("server closed the connection");
            }
            let j = Json::parse(buf.trim()).map_err(|e| anyhow!("bad reply {buf:?}: {e}"))?;
            if let Some((kind, msg)) = wire_error(&j) {
                bail!("server error ({kind}): {msg}");
            }
            let partial = matches!(j.get("partial"), Some(Json::Bool(true)));
            replies.push(j);
            if !partial {
                return Ok(replies);
            }
        }
    }
}

/// One loopback self-test for CI: bind an ephemeral port, run a
/// create/step/stats/shutdown round-trip over the aaren and tf native
/// session kinds plus one non-Aaren fold kernel (mingru, created via
/// the backend shorthand), and shut the server down. Errors if any
/// reply is wrong.
pub fn run_smoke(base: &ServeConfig) -> Result<()> {
    let mut cfg = base.clone();
    cfg.addr = "127.0.0.1:0".to_string();
    // the smoke asserts the telemetry layer reports real histograms, so
    // it must be on regardless of the caller's flags
    cfg.telemetry = true;
    let channels = cfg.channels;
    let server = Server::bind(&cfg)?;
    let addr = server.local_addr()?;
    let run = std::thread::spawn(move || server.run());

    let mut client = Client::connect(&addr)?;
    let xs: Vec<String> = (0..channels).map(|i| format!("{}.5", i % 3)).collect();
    let x = xs.join(",");
    let aaren = client.call(r#"{"op":"create","kind":"aaren"}"#)?.usize_field("id")?;
    let tf = client.call(r#"{"op":"create","kind":"tf"}"#)?.usize_field("id")?;
    let mut aaren_bytes = Vec::new();
    for _ in 0..8 {
        let r = client.call(&format!(r#"{{"op":"step","id":{aaren},"x":[{x}]}}"#))?;
        aaren_bytes.push(r.usize_field("state_bytes")?);
        client.call(&format!(r#"{{"op":"step","id":{tf},"x":[{x}]}}"#))?;
    }
    ensure!(
        aaren_bytes.windows(2).all(|w| w[0] == w[1]),
        "aaren state must be constant, got {aaren_bytes:?}"
    );
    // batched steps: 4 tokens in one message continue the same stream
    let r = client
        .call(&format!(r#"{{"op":"steps","id":{aaren},"xs":[[{x}],[{x}],[{x}],[{x}]]}}"#))?;
    let ys = r.get("ys").and_then(Json::as_arr).ok_or_else(|| anyhow!("steps reply missing ys"))?;
    ensure!(ys.len() == 4, "expected 4 outputs from steps, got {}", ys.len());
    ensure!(r.usize_field("t")? == 12, "steps must advance t to 12, got {}", r.usize_field("t")?);
    // one non-Aaren fold kernel round-trip: create through the backend
    // shorthand, stream a block, close
    let mingru = client.call(r#"{"op":"create","backend":"mingru"}"#)?.usize_field("id")?;
    let r = client.call(&format!(r#"{{"op":"steps","id":{mingru},"xs":[[{x}],[{x}],[{x}]]}}"#))?;
    ensure!(r.usize_field("t")? == 3, "mingru steps must advance t to 3");
    let stats = client.call(r#"{"op":"stats"}"#)?;
    ensure!(stats.usize_field("sessions")? == 3, "expected 3 live sessions");
    let resident_of = |name: &str| -> Result<usize> {
        stats
            .get("backends")
            .and_then(|b| b.get(name))
            .and_then(|e| e.get("resident"))
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow!("stats reply lacks backends.{name}.resident"))
    };
    for name in ["aaren", "mingru", "tf"] {
        ensure!(resident_of(name)? == 1, "expected 1 resident {name} session");
    }
    // the telemetry layer must report well-formed, non-empty histograms
    // for the traffic above: per-op and per-stage buckets present,
    // percentiles ordered, flight recorder holding the creates
    if !cfg!(feature = "obs-noop") {
        let metrics = client.call(r#"{"op":"metrics"}"#)?;
        let hist = |stage: &str| -> Result<&Json> {
            metrics
                .get("histograms")
                .and_then(|h| h.get(stage))
                .ok_or_else(|| anyhow!("metrics reply lacks histograms.{stage}"))
        };
        let steps = hist("op_steps")?;
        let count = steps.usize_field("count")?;
        ensure!(count >= 2, "op_steps histogram must hold the smoke's calls, got {count}");
        let (p50, p99) = (steps.usize_field("p50_ns")?, steps.usize_field("p99_ns")?);
        let max = steps.usize_field("max_ns")?;
        ensure!(
            p50 > 0 && p50 <= p99 && p99 <= max,
            "op_steps percentiles malformed: p50={p50} p99={p99} max={max}"
        );
        match steps.get("buckets") {
            Some(Json::Obj(b)) if !b.is_empty() => {}
            _ => bail!("op_steps histogram reports no buckets"),
        }
        for stage in ["queue_wait", "exec_drain", "kernel_fold"] {
            ensure!(hist(stage)?.usize_field("count")? > 0, "stage histogram {stage} is empty");
        }
        let logged = metrics
            .get("counters")
            .and_then(|c| c.get("events_logged"))
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow!("metrics reply lacks counters.events_logged"))?;
        ensure!(logged >= 3, "flight recorder must hold the smoke's creates, got {logged}");
    }
    client.call(&format!(r#"{{"op":"close","id":{mingru}}}"#))?;
    client.call(r#"{"op":"shutdown"}"#)?;
    run.join().map_err(|_| anyhow!("server thread panicked"))??;
    println!(
        "[serve] smoke ok: aaren + mingru + tf sessions served on {addr}, \
         aaren state constant at {} bytes, metrics histograms validated",
        aaren_bytes[0]
    );
    Ok(())
}

#[cfg(feature = "pjrt")]
mod hlo_backend {
    use std::rc::Rc;

    use anyhow::{anyhow, Result};

    use super::SessionFactory;
    use crate::runtime::exec::Engine;
    use crate::serve::session::{BoundSession, StreamModel, StreamSession};

    /// Factory for the compiled-HLO tier: loads both stream models once
    /// and binds every created session to them. Lives (with its engine)
    /// on the dedicated HLO executor thread — PJRT handles are not Send.
    pub struct HloFactory {
        _engine: Engine,
        aaren: Rc<StreamModel>,
        tf: Rc<StreamModel>,
    }

    impl HloFactory {
        pub fn new(artifacts: &std::path::Path) -> Result<HloFactory> {
            let mut engine = Engine::new(artifacts)?;
            let aaren = Rc::new(StreamModel::load_aaren(&mut engine)?);
            let tf = Rc::new(StreamModel::load_tf(&mut engine)?);
            Ok(HloFactory { _engine: engine, aaren, tf })
        }
    }

    impl SessionFactory for HloFactory {
        fn create(&mut self, kind: &str) -> Result<Box<dyn StreamSession>> {
            match kind {
                "aaren" => Ok(Box::new(BoundSession::new_aaren(Rc::clone(&self.aaren))?)),
                "tf" => Ok(Box::new(BoundSession::new_tf(Rc::clone(&self.tf))?)),
                other => Err(anyhow!("unknown kind {other:?} (aaren|tf)")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::session::NativeAarenSession;

    #[test]
    fn parses_steps_requests() {
        match parse_request(r#"{"op":"steps","id":7,"xs":[[1.0,2.0],[3.0,-4.0]]}"#).unwrap() {
            WireOp::Steps { id, xs, n } => {
                assert_eq!(id, 7);
                assert_eq!(n, 2);
                assert_eq!(xs, vec![1.0, 2.0, 3.0, -4.0]);
            }
            _ => panic!("wrong variant"),
        }
        // an empty block is a valid no-op request
        match parse_request(r#"{"op":"steps","id":1,"xs":[]}"#).unwrap() {
            WireOp::Steps { xs, n, .. } => {
                assert_eq!(n, 0);
                assert!(xs.is_empty());
            }
            _ => panic!("wrong variant"),
        }
        // ragged rows, non-numbers and non-finite-in-f32 values are rejected
        assert!(parse_request(r#"{"op":"steps","id":1,"xs":[[1.0],[1.0,2.0]]}"#).is_err());
        assert!(parse_request(r#"{"op":"steps","id":1,"xs":[[1.0],["x"]]}"#).is_err());
        assert!(parse_request(r#"{"op":"steps","id":1,"xs":[[1e40]]}"#).is_err());
        assert!(parse_request(r#"{"op":"steps","id":1,"xs":3}"#).is_err());
        assert!(parse_request(r#"{"op":"steps","id":1}"#).is_err());
    }

    /// Queue envelopes up front, then run the executor: the first `recv`
    /// plus the `try_recv` drain serves them as ONE coalesced batch —
    /// the deterministic way to exercise the batched path. Runs the
    /// default resident-lane mode.
    fn run_drained(requests: Vec<Request>, ttl: Option<Duration>) -> Vec<mpsc::Receiver<Reply>> {
        run_drained_mode(requests, ttl, None, true)
    }

    fn run_drained_mode(
        requests: Vec<Request>,
        ttl: Option<Duration>,
        spill: Option<SpillTier>,
        resident: bool,
    ) -> Vec<mpsc::Receiver<Reply>> {
        run_drained_opts(
            requests,
            ExecutorOpts { session_ttl: ttl, spill, resident, ..Default::default() },
        )
    }

    fn run_drained_opts(
        requests: Vec<Request>,
        opts: ExecutorOpts,
    ) -> Vec<mpsc::Receiver<Reply>> {
        // deep enough that a whole pre-queued test batch always fits
        let (tx, rx) = mpsc::sync_channel(1024);
        let mut receivers = Vec::new();
        for req in requests {
            let (rtx, rrx) = mpsc::channel();
            tx.send((req, rtx, Instant::now())).unwrap();
            receivers.push(rrx);
        }
        drop(tx);
        run_executor(NativeFactory { channels: 2 }, rx, opts);
        receivers
    }

    fn value_reply(rrx: &mpsc::Receiver<Reply>) -> Json {
        match rrx.recv().unwrap() {
            Ok(Response::Value(j)) => j,
            Ok(_) => panic!("non-value reply"),
            Err(e) => panic!("error reply: {e:#}"),
        }
    }

    fn ys_of(j: &Json) -> Vec<Vec<f64>> {
        j.get("ys")
            .and_then(Json::as_arr)
            .unwrap()
            .iter()
            .map(|row| row.as_arr().unwrap().iter().map(|v| v.as_f64().unwrap()).collect())
            .collect()
    }

    #[test]
    fn coalesced_drain_matches_sequential_sessions_and_preserves_order() {
        // two aaren sessions and a tf session advance inside ONE drain,
        // interleaved step/steps for the same session, a close splitting
        // the runs — replies must be what strictly sequential processing
        // would produce.
        let x1 = vec![0.5f32, -1.0];
        let x2 = vec![2.0f32, 0.25];
        let x3 = vec![-0.75f32, 1.5];
        let requests = vec![
            Request::Create { id: 1, kind: "aaren".into() },
            Request::Create { id: 2, kind: "aaren".into() },
            Request::Create { id: 3, kind: "tf".into() },
            Request::Step { id: 1, x: x1.clone() },
            Request::Steps { id: 2, xs: [x1.clone(), x2.clone()].concat(), n: 2 },
            Request::Step { id: 1, x: x2.clone() },
            Request::Steps { id: 3, xs: [x2.clone(), x3.clone()].concat(), n: 2 },
            Request::Step { id: 99, x: x1.clone() }, // unknown session
            Request::Close { id: 2 },
            Request::Step { id: 2, x: x3.clone() }, // after close: must fail
            Request::Steps { id: 1, xs: x3.clone(), n: 1 },
            Request::Shutdown,
        ];
        let replies = run_drained(requests, None);

        // reference: the same tokens through plain sessions
        let mut ref1 = NativeAarenSession::new(2);
        let mut ref2 = NativeAarenSession::new(2);
        let mut ref3 = NativeTfSession::new(2);
        let y1a = ref1.step(&x1).unwrap();
        let y2 = [ref2.step(&x1).unwrap(), ref2.step(&x2).unwrap()];
        let y1b = ref1.step(&x2).unwrap();
        let y3 = [ref3.step(&x2).unwrap(), ref3.step(&x3).unwrap()];
        let y1c = ref1.step(&x3).unwrap();

        let as_f64 = |v: &[f32]| v.iter().map(|x| *x as f64).collect::<Vec<_>>();
        for rrx in &replies[..3] {
            value_reply(rrx).usize_field("id").unwrap();
        }
        let r = value_reply(&replies[3]);
        let y = r.get("y").and_then(Json::as_arr).unwrap();
        let got: Vec<f64> = y.iter().map(|v| v.as_f64().unwrap()).collect();
        assert_eq!(got, as_f64(&y1a));
        assert_eq!(r.usize_field("t").unwrap(), 1);

        let r = value_reply(&replies[4]);
        assert_eq!(ys_of(&r), vec![as_f64(&y2[0]), as_f64(&y2[1])]);
        assert_eq!(r.usize_field("t").unwrap(), 2);

        let r = value_reply(&replies[5]);
        let y = r.get("y").and_then(Json::as_arr).unwrap();
        let got: Vec<f64> = y.iter().map(|v| v.as_f64().unwrap()).collect();
        assert_eq!(got, as_f64(&y1b));
        assert_eq!(r.usize_field("t").unwrap(), 2);

        let r = value_reply(&replies[6]);
        assert_eq!(ys_of(&r), vec![as_f64(&y3[0]), as_f64(&y3[1])]);

        assert!(replies[7].recv().unwrap().is_err(), "unknown session must error");
        value_reply(&replies[8]); // close ok
        assert!(replies[9].recv().unwrap().is_err(), "step after close must error");

        let r = value_reply(&replies[10]);
        assert_eq!(ys_of(&r), vec![as_f64(&y1c)]);
        assert_eq!(r.usize_field("t").unwrap(), 3);

        assert!(matches!(replies[11].recv().unwrap(), Ok(Response::ShuttingDown)));
    }

    #[test]
    fn executor_sweeps_idle_sessions_after_ttl() {
        // generous ttl-to-touch ratio (20x) so a CI scheduler stall
        // cannot spuriously evict the live session
        let ttl = Duration::from_millis(1000);
        let (tx, rx) = mpsc::sync_channel(64);
        let exec = std::thread::spawn(move || {
            run_executor(
                NativeFactory { channels: 2 },
                rx,
                ExecutorOpts { session_ttl: Some(ttl), ..Default::default() },
            )
        });
        let call = |req: Request| -> Reply {
            let (rtx, rrx) = mpsc::channel();
            tx.send((req, rtx, Instant::now())).unwrap();
            rrx.recv().unwrap()
        };
        call(Request::Create { id: 1, kind: "aaren".into() }).unwrap();
        // an active session survives: keep touching it within the ttl
        for _ in 0..4 {
            std::thread::sleep(Duration::from_millis(50));
            call(Request::Step { id: 1, x: vec![0.1, 0.2] }).unwrap();
        }
        match call(Request::Stats).unwrap() {
            Response::Stats { sessions, .. } => assert_eq!(sessions, 1, "live session swept"),
            _ => panic!("non-stats reply"),
        }
        // idle past the ttl: the next drain reaps it
        std::thread::sleep(Duration::from_millis(2200));
        match call(Request::Stats).unwrap() {
            Response::Stats { sessions, .. } => assert_eq!(sessions, 0, "idle session kept"),
            _ => panic!("non-stats reply"),
        }
        assert!(call(Request::Step { id: 1, x: vec![0.1, 0.2] }).is_err());
        let _ = call(Request::Shutdown);
        exec.join().unwrap();
    }

    fn mem_spill(max_resident: Option<usize>) -> Option<SpillTier> {
        Some(SpillTier { store: Box::new(crate::persist::MemStore::new()), max_resident })
    }

    #[test]
    fn drain_spills_on_demand_and_is_idempotent() {
        let x = vec![0.5f32, -1.0];
        let replies = run_drained_mode(
            vec![
                Request::Create { id: 3, kind: "aaren".into() },
                Request::Step { id: 3, x: x.clone() },
                Request::Drain { id: 3 },          // spills + releases
                Request::Stats,                    // 0 resident, 1 spilled
                Request::Drain { id: 3 },          // already spilled: still ok
                Request::Step { id: 3, x: x.clone() }, // lazy restore, t=2
                Request::Drain { id: 9 },          // no such session
                Request::Shutdown,
            ],
            None,
            mem_spill(None),
            true,
        );
        value_reply(&replies[0]);
        assert_eq!(value_reply(&replies[1]).usize_field("t").unwrap(), 1);
        let r = value_reply(&replies[2]);
        assert_eq!(r.get("spilled"), Some(&Json::Bool(true)));
        match replies[3].recv().unwrap().unwrap() {
            Response::Stats { sessions, spilled, .. } => {
                assert_eq!((sessions, spilled), (0, 1));
            }
            _ => panic!("expected stats"),
        }
        let r = value_reply(&replies[4]);
        assert_eq!(r.get("spilled"), Some(&Json::Bool(false)));
        assert_eq!(value_reply(&replies[5]).usize_field("t").unwrap(), 2);
        let (kind, _) = kind_of_reply(replies[6].recv().unwrap());
        assert_eq!(kind, crate::fault::KIND_NO_SESSION);
        assert!(matches!(replies[7].recv().unwrap(), Ok(Response::ShuttingDown)));
    }

    #[test]
    fn drain_without_a_spill_tier_refuses_and_spares_the_stream() {
        let replies = run_drained(
            vec![
                Request::Create { id: 1, kind: "aaren".into() },
                Request::Drain { id: 1 },
                Request::Step { id: 1, x: vec![0.5, -1.0] }, // stream unharmed
                Request::Shutdown,
            ],
            None,
        );
        value_reply(&replies[0]);
        let err = replies[1].recv().unwrap().unwrap_err();
        assert!(format!("{err:#}").contains("spill"), "got: {err:#}");
        assert_eq!(value_reply(&replies[2]).usize_field("t").unwrap(), 1);
    }

    #[test]
    fn duplicate_create_is_a_structured_error() {
        // a `create` landing on a live id must refuse, not clobber: the
        // original session keeps its stream position
        let x = vec![0.5f32, -1.0];
        let replies = run_drained(
            vec![
                Request::Create { id: 7, kind: "aaren".into() },
                Request::Step { id: 7, x: x.clone() },
                Request::Create { id: 7, kind: "tf".into() }, // duplicate
                Request::Step { id: 7, x: x.clone() },        // stream continues at t=2
                Request::Shutdown,
            ],
            None,
        );
        value_reply(&replies[0]);
        assert_eq!(value_reply(&replies[1]).usize_field("t").unwrap(), 1);
        let err = match replies[2].recv().unwrap() {
            Err(e) => format!("{e:#}"),
            Ok(_) => panic!("duplicate create must be refused"),
        };
        assert!(err.contains("already exists"), "got: {err}");
        assert_eq!(value_reply(&replies[3]).usize_field("t").unwrap(), 2, "state was clobbered");
    }

    #[test]
    fn ttl_sweep_spills_and_touch_restores() {
        // generous ttl (vs the instants between adjacent calls) so a CI
        // scheduler stall cannot spill a session the test expects
        // resident; the sleeps below are >2x the ttl so the sweeps the
        // test DOES expect are just as robust
        let ttl = Duration::from_millis(800);
        let (tx, rx) = mpsc::sync_channel(64);
        let exec = std::thread::spawn(move || {
            run_executor(
                NativeFactory { channels: 2 },
                rx,
                ExecutorOpts {
                    session_ttl: Some(ttl),
                    spill: mem_spill(None),
                    ..Default::default()
                },
            )
        });
        let call = |req: Request| -> Reply {
            let (rtx, rrx) = mpsc::channel();
            tx.send((req, rtx, Instant::now())).unwrap();
            rrx.recv().unwrap()
        };
        call(Request::Create { id: 1, kind: "aaren".into() }).unwrap();
        call(Request::Step { id: 1, x: vec![0.5, -0.25] }).unwrap();
        // idle past the ttl: the sweep must SPILL, not destroy
        std::thread::sleep(Duration::from_millis(2000));
        match call(Request::Stats).unwrap() {
            Response::Stats { sessions, spilled, .. } => {
                assert_eq!(sessions, 0, "idle session should no longer be resident");
                assert_eq!(spilled, 1, "idle session should be in the spill store");
            }
            _ => panic!("non-stats reply"),
        }
        // duplicate create against the SPILLED id must also refuse
        assert!(call(Request::Create { id: 1, kind: "aaren".into() }).is_err());
        // the next touch restores it with its stream position intact
        match call(Request::Step { id: 1, x: vec![0.5, -0.25] }).unwrap() {
            Response::Value(j) => assert_eq!(j.usize_field("t").unwrap(), 2),
            _ => panic!("non-value reply"),
        }
        match call(Request::Stats).unwrap() {
            Response::Stats { sessions, spilled, .. } => {
                assert_eq!((sessions, spilled), (1, 0), "restore must leave the store");
            }
            _ => panic!("non-stats reply"),
        }
        // close of a spilled session deletes the snapshot
        std::thread::sleep(Duration::from_millis(2000));
        assert!(call(Request::Close { id: 1 }).is_ok());
        match call(Request::Stats).unwrap() {
            Response::Stats { sessions, spilled, .. } => assert_eq!((sessions, spilled), (0, 0)),
            _ => panic!("non-stats reply"),
        }
        let _ = call(Request::Shutdown);
        exec.join().unwrap();
    }

    #[test]
    fn snapshot_and_restore_ops_work_inside_a_drain() {
        // snapshot a live session mid-drain, then restore the same blob
        // under a new id: the twin continues from the captured t
        let x = vec![1.0f32, 0.25];
        let first = run_drained(
            vec![
                Request::Create { id: 1, kind: "aaren".into() },
                Request::Step { id: 1, x: x.clone() },
                Request::Snapshot { id: 1 },
                Request::Shutdown,
            ],
            None,
        );
        value_reply(&first[0]);
        value_reply(&first[1]);
        let snap = value_reply(&first[2]);
        assert_eq!(snap.str_field("kind").unwrap(), "aaren");
        assert_eq!(snap.usize_field("t").unwrap(), 1);
        assert_eq!(snap.usize_field("channels").unwrap(), 2);
        let blob = b64::decode(snap.str_field("state").unwrap()).unwrap();
        assert_eq!(snap.usize_field("bytes").unwrap(), blob.len());

        let second = run_drained(
            vec![
                Request::Restore { id: 9, blob },
                Request::Step { id: 9, x: x.clone() },
                Request::Snapshot { id: 99 }, // unknown session
                Request::Shutdown,
            ],
            None,
        );
        let restored = value_reply(&second[0]);
        assert_eq!(restored.usize_field("id").unwrap(), 9);
        assert_eq!(restored.usize_field("t").unwrap(), 1);
        assert_eq!(value_reply(&second[1]).usize_field("t").unwrap(), 2);
        assert!(second[2].recv().unwrap().is_err());
    }

    #[test]
    fn restore_rejects_corrupt_blobs() {
        let mut session = NativeAarenSession::new(2);
        session.step(&[0.5, 0.5]).unwrap();
        let mut blob = StreamSession::snapshot(&session).unwrap();
        let n = blob.len();
        blob[n - 6] ^= 0xFF;
        let replies = run_drained(
            vec![Request::Restore { id: 5, blob }, Request::Shutdown],
            None,
        );
        let err = match replies[0].recv().unwrap() {
            Err(e) => format!("{e:#}"),
            Ok(_) => panic!("corrupt blob must be refused"),
        };
        assert!(err.contains("crc") || err.contains("corrupt"), "got: {err}");
    }

    #[test]
    fn lru_cap_enforced_between_drains() {
        let (tx, rx) = mpsc::sync_channel(64);
        let exec = std::thread::spawn(move || {
            run_executor(
                NativeFactory { channels: 2 },
                rx,
                ExecutorOpts { spill: mem_spill(Some(1)), ..Default::default() },
            )
        });
        let call = |req: Request| -> Reply {
            let (rtx, rrx) = mpsc::channel();
            tx.send((req, rtx, Instant::now())).unwrap();
            rrx.recv().unwrap()
        };
        for id in 1..=3u64 {
            call(Request::Create { id, kind: "aaren".into() }).unwrap();
            // separate calls = separate drains, so the cap runs after each
        }
        match call(Request::Stats).unwrap() {
            Response::Stats { sessions, spilled, .. } => {
                assert_eq!(sessions, 1, "cap must keep exactly one resident");
                assert_eq!(spilled, 2, "the two coldest must be spilled");
            }
            _ => panic!("non-stats reply"),
        }
        // every session still serves; restoring one spills another
        for id in 1..=3u64 {
            match call(Request::Step { id, x: vec![0.1, 0.2] }).unwrap() {
                Response::Value(j) => assert_eq!(j.usize_field("t").unwrap(), 1),
                _ => panic!("non-value reply"),
            }
        }
        match call(Request::Stats).unwrap() {
            Response::Stats { sessions, spilled, .. } => assert_eq!((sessions, spilled), (1, 2)),
            _ => panic!("non-stats reply"),
        }
        let _ = call(Request::Shutdown);
        exec.join().unwrap();
    }

    #[test]
    fn scatter_mode_drain_is_indistinguishable_from_resident_mode() {
        // the A/B guarantee behind `resident_vs_scatter`: the same drain,
        // served with resident lanes and with the PR 3 gather/scatter
        // path, must produce byte-identical reply bodies
        let x1 = vec![0.5f32, -1.0];
        let x2 = vec![2.0f32, 0.25];
        let requests = || {
            vec![
                Request::Create { id: 1, kind: "aaren".into() },
                Request::Create { id: 2, kind: "aaren".into() },
                Request::Create { id: 3, kind: "tf".into() },
                Request::Step { id: 1, x: x1.clone() },
                Request::Steps { id: 2, xs: [x1.clone(), x2.clone()].concat(), n: 2 },
                Request::Steps { id: 3, xs: x2.clone(), n: 1 },
                Request::Step { id: 2, x: x2.clone() },
                Request::Snapshot { id: 1 },
                Request::Close { id: 2 },
                Request::Shutdown,
            ]
        };
        let resident = run_drained_mode(requests(), None, None, true);
        let scatter = run_drained_mode(requests(), None, None, false);
        for (i, (a, b)) in resident.iter().zip(scatter.iter()).enumerate() {
            match (a.recv().unwrap(), b.recv().unwrap()) {
                (Ok(Response::Value(ja)), Ok(Response::Value(jb))) => {
                    assert_eq!(ja.to_string(), jb.to_string(), "reply {i} diverged across modes");
                }
                (Ok(Response::ShuttingDown), Ok(Response::ShuttingDown)) => {}
                (ra, rb) => {
                    assert_eq!(ra.is_err(), rb.is_err(), "reply {i} kind diverged across modes");
                }
            }
        }
    }

    #[test]
    fn lane_churn_compacts_and_surviving_sessions_keep_streaming() {
        // create 12 resident sessions, close the 10 interior ones (the
        // shard's lane set compacts once released lanes outnumber both
        // the live count and the floor of 8), then keep streaming the survivors and a newcomer: the
        // remapped lanes must carry their streams forward intact
        let (tx, rx) = mpsc::sync_channel(64);
        let exec = std::thread::spawn(move || {
            run_executor(NativeFactory { channels: 2 }, rx, ExecutorOpts::default())
        });
        let call = |req: Request| -> Reply {
            let (rtx, rrx) = mpsc::channel();
            tx.send((req, rtx, Instant::now())).unwrap();
            rrx.recv().unwrap()
        };
        for id in 1..=12u64 {
            call(Request::Create { id, kind: "aaren".into() }).unwrap();
            call(Request::Step { id, x: vec![0.5, -0.25] }).unwrap();
        }
        for id in 2..=11u64 {
            call(Request::Close { id }).unwrap();
        }
        for id in [1u64, 12] {
            match call(Request::Step { id, x: vec![1.5, 0.75] }).unwrap() {
                Response::Value(j) => {
                    assert_eq!(j.usize_field("t").unwrap(), 2, "session {id} lost its stream");
                }
                _ => panic!("non-value reply"),
            }
        }
        // a fresh session lands on a compacted (small) lane set and works
        call(Request::Create { id: 20, kind: "aaren".into() }).unwrap();
        match call(Request::Step { id: 20, x: vec![0.0, 1.0] }).unwrap() {
            Response::Value(j) => assert_eq!(j.usize_field("t").unwrap(), 1),
            _ => panic!("non-value reply"),
        }
        let _ = call(Request::Shutdown);
        exec.join().unwrap();
    }

    #[test]
    fn idle_compaction_remaps_bitwise_like_the_drain_edge_path() {
        // the ROADMAP gap this closes: mass evictions leave a lane set
        // fragmented until the next busy drain crosses the frag > live
        // threshold. Idle wakes now compact at ANY fragmentation — this
        // property pins down that the eager path is pure bookkeeping:
        // survivor snapshots are bitwise unchanged, further steps match a
        // boxed twin resumed from the pre-compaction snapshot, and the
        // idle path ends in exactly the state the drain-edge path does.
        let kinds = KernelKind::ALL;
        crate::util::prop::check("idle_compaction_remap", 32, |rng| {
            let seed = rng.next_u64();
            let run = |idle: bool| -> Result<Vec<(u64, Vec<u8>, Vec<u32>)>, String> {
                let mut rng = Rng::new(seed);
                let d = 2 + rng.below(3);
                let mut factory = NativeFactory { channels: d };
                let mut lanes = LaneMap::new();
                let mut sessions: HashMap<u64, Held> = HashMap::new();
                let now = Instant::now();
                let n = (6 + rng.below(10)) as u64;
                for id in 1..=n {
                    let kind = kinds[rng.below(kinds.len())];
                    let s = factory.create(kind.wire_name()).map_err(|e| e.to_string())?;
                    sessions.insert(id, hold(s, true, &mut lanes, now));
                }
                // advance every stream in place (exactly-representable
                // inputs, so any remap slip shows as a bit flip)
                for id in 1..=n {
                    for t in 0..1 + rng.below(4) {
                        let x: Vec<f32> = (0..d)
                            .map(|c| ((id as usize + t * 7 + c * 3) % 13) as f32 * 0.25 - 1.5)
                            .collect();
                        let held = sessions.get_mut(&id).unwrap();
                        match &mut held.slot {
                            SessionSlot::Resident(r) => {
                                let set =
                                    lanes.sets.get_mut(&(r.kernel(), r.channels())).unwrap();
                                r.step(set, &x).map_err(|e| e.to_string())?;
                            }
                            SessionSlot::Boxed(_) => unreachable!("scan kinds adopt lanes"),
                        }
                    }
                }
                // release a random subset — the mass-eviction shape
                for id in 1..=n {
                    if rng.below(2) == 0 && sessions.len() > 1 {
                        sessions.remove(&id).unwrap().slot.release(&mut lanes);
                    }
                }
                let mut pre: Vec<(u64, Vec<u8>)> = sessions
                    .iter()
                    .map(|(&id, h)| (id, h.slot.snapshot(&lanes).unwrap()))
                    .collect();
                pre.sort();
                compact_lanes(&mut sessions, &mut lanes, idle);
                let mut out = Vec::new();
                for (id, pre_blob) in &pre {
                    let held = sessions.get_mut(id).unwrap();
                    let post = held.slot.snapshot(&lanes).map_err(|e| e.to_string())?;
                    if &post != pre_blob {
                        return Err(format!("session {id}: snapshot changed across compaction"));
                    }
                    let x: Vec<f32> = (0..d)
                        .map(|c| ((c * 5 + *id as usize) % 13) as f32 * 0.25 - 1.5)
                        .collect();
                    let y = match &mut held.slot {
                        SessionSlot::Resident(r) => {
                            let set = lanes.sets.get_mut(&(r.kernel(), r.channels())).unwrap();
                            r.step(set, &x).map_err(|e| e.to_string())?
                        }
                        SessionSlot::Boxed(_) => unreachable!(),
                    };
                    let snap = codec::decode(pre_blob).map_err(|e| e.to_string())?;
                    let mut twin =
                        NativeScanSession::import_state(&snap).map_err(|e| e.to_string())?;
                    let ty = twin.step(&x).map_err(|e| e.to_string())?;
                    let bits: Vec<u32> = y.iter().map(|v| v.to_bits()).collect();
                    if bits != ty.iter().map(|v| v.to_bits()).collect::<Vec<u32>>() {
                        return Err(format!("session {id}: post-compaction step != boxed twin"));
                    }
                    out.push((*id, post, bits));
                }
                Ok(out)
            };
            let idle_path = run(true)?;
            let edge_path = run(false)?;
            if idle_path != edge_path {
                return Err("idle-path end state diverged from the drain-edge path".into());
            }
            Ok(())
        });
    }

    #[test]
    fn graceful_shutdown_spills_resident_sessions_to_the_store() {
        // ROADMAP PR 4 follow-up: a shutdown with a spill tier configured
        // must spill what is resident instead of dropping it
        use std::sync::atomic::{AtomicU64, Ordering};
        static SEQ: AtomicU64 = AtomicU64::new(0);
        let dir = std::env::temp_dir().join(format!(
            "aaren-shutdown-spill-{}-{}",
            std::process::id(),
            SEQ.fetch_add(1, Ordering::Relaxed)
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let spill = Some(SpillTier {
            store: Box::new(crate::persist::DirStore::open(&dir).unwrap()),
            max_resident: None,
        });
        let x = vec![0.5f32, -0.25];
        let replies = run_drained_mode(
            vec![
                Request::Create { id: 1, kind: "aaren".into() },
                Request::Create { id: 2, kind: "tf".into() },
                Request::Step { id: 1, x: x.clone() },
                Request::Step { id: 2, x: x.clone() },
                Request::Shutdown,
            ],
            None,
            spill,
            true,
        );
        for rrx in &replies[..4] {
            value_reply(rrx);
        }
        assert!(matches!(replies[4].recv().unwrap(), Ok(Response::ShuttingDown)));
        // both sessions survived shutdown as snapshots, streams intact
        let mut store = crate::persist::DirStore::open(&dir).unwrap();
        let mut kinds = Vec::new();
        for id in [1u64, 2] {
            let blob = store.get(id).unwrap().unwrap_or_else(|| panic!("session {id} dropped"));
            let meta = codec::meta(&blob).unwrap();
            assert_eq!(meta.tokens_seen, 1, "session {id} lost stream position");
            kinds.push(meta.backend.kind().to_string());
        }
        kinds.sort();
        assert_eq!(kinds, ["aaren", "tf"]);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn restore_accepts_an_explicit_target_id_and_refuses_collisions() {
        // ROADMAP PR 4 follow-up: `restore` can claim a client-chosen id;
        // a collision is a structured error, not a clobber
        let mut session = NativeAarenSession::new(4);
        session.step(&[0.5, 0.25, -0.5, 1.0]).unwrap();
        let blob = StreamSession::snapshot(&session).unwrap();
        let router = test_router(2);
        let r = router
            .dispatch(WireOp::Restore { blob: blob.clone(), id: Some(7) })
            .unwrap();
        assert_eq!(r.usize_field("id").unwrap(), 7);
        assert_eq!(r.usize_field("t").unwrap(), 1);
        // the claimed session serves at its id
        let r = router.dispatch(WireOp::Step { id: 7, x: vec![0.5; 4] }).unwrap();
        assert_eq!(r.usize_field("t").unwrap(), 2);
        // restoring onto the same id again is refused
        let err = router
            .dispatch(WireOp::Restore { blob: blob.clone(), id: Some(7) })
            .unwrap_err();
        assert!(format!("{err}").contains("already exists"), "got: {err}");
        // ...and so is a create naming it
        let err = router
            .dispatch(WireOp::Create {
                kind: "aaren".into(),
                backend: Backend::Native,
                id: Some(7),
            })
            .unwrap_err();
        assert!(format!("{err}").contains("already exists"), "got: {err}");
        // out-of-range target ids are refused at the router
        assert!(router.dispatch(WireOp::Restore { blob: blob.clone(), id: Some(0) }).is_err());
        assert!(router
            .dispatch(WireOp::Restore { blob, id: Some(HLO_ID_BASE) })
            .is_err());
        // auto-assigned ids skip past the claimed one
        let fresh = router
            .dispatch(WireOp::Create { kind: "aaren".into(), backend: Backend::Native, id: None })
            .unwrap()
            .usize_field("id")
            .unwrap();
        assert!(fresh > 7, "auto id {fresh} collides with the claimed range");
        router.dispatch(WireOp::Shutdown).unwrap();
    }

    #[test]
    fn parses_persistence_requests() {
        match parse_request(r#"{"op":"create","kind":"aaren","id":42}"#).unwrap() {
            WireOp::Create { id, .. } => assert_eq!(id, Some(42)),
            _ => panic!("wrong variant"),
        }
        assert!(parse_request(r#"{"op":"create","kind":"aaren","id":"x"}"#).is_err());
        match parse_request(r#"{"op":"snapshot","id":3}"#).unwrap() {
            WireOp::Snapshot { id } => assert_eq!(id, 3),
            _ => panic!("wrong variant"),
        }
        // restore round-trips a real codec blob through base64
        let blob = codec::encode(&codec::Snapshot {
            backend: codec::BackendTag::Aaren,
            channels: 2,
            tokens_seen: 4,
            state: vec![0.0; 6],
        });
        let line = format!(r#"{{"op":"restore","state":"{}"}}"#, b64::encode(&blob));
        match parse_request(&line).unwrap() {
            WireOp::Restore { blob: got, id } => {
                assert_eq!(got, blob);
                assert_eq!(id, None);
            }
            _ => panic!("wrong variant"),
        }
        // restore with an explicit target id (the migration-keeps-its-id
        // path)
        let line = format!(r#"{{"op":"restore","state":"{}","id":31}}"#, b64::encode(&blob));
        match parse_request(&line).unwrap() {
            WireOp::Restore { id, .. } => assert_eq!(id, Some(31)),
            _ => panic!("wrong variant"),
        }
        let line = format!(r#"{{"op":"restore","state":"{}","id":"x"}}"#, b64::encode(&blob));
        assert!(parse_request(&line).is_err());
        assert!(parse_request(r#"{"op":"restore","state":"!!!"}"#).is_err());
        assert!(parse_request(r#"{"op":"restore"}"#).is_err());
        // the fleet control-plane ops: on-demand spill and liveness probe
        match parse_request(r#"{"op":"drain","id":9}"#).unwrap() {
            WireOp::Drain { id } => assert_eq!(id, 9),
            _ => panic!("wrong variant"),
        }
        assert!(parse_request(r#"{"op":"drain"}"#).is_err());
        assert!(matches!(parse_request(r#"{"op":"ping"}"#).unwrap(), WireOp::Ping));
    }

    #[test]
    fn absurd_steps_blocks_are_rejected_at_parse() {
        // one token over the limit: rejected before any float conversion
        let rows = "[],".repeat(MAX_STEPS_TOKENS).trim_end_matches(',').to_string() + ",[]";
        let line = format!(r#"{{"op":"steps","id":1,"xs":[{rows}]}}"#);
        let err = parse_request(&line).unwrap_err();
        assert!(format!("{err}").contains("token limit"), "got: {err}");
        // exactly at the limit parses fine (empty rows: zero width)
        let rows = "[],".repeat(MAX_STEPS_TOKENS).trim_end_matches(',').to_string();
        let line = format!(r#"{{"op":"steps","id":1,"xs":[{rows}]}}"#);
        match parse_request(&line).unwrap() {
            WireOp::Steps { n, .. } => assert_eq!(n, MAX_STEPS_TOKENS),
            _ => panic!("wrong variant"),
        }
    }

    #[test]
    fn parses_protocol_requests() {
        match parse_request(r#"{"op":"create","kind":"aaren"}"#).unwrap() {
            WireOp::Create { kind, backend, id } => {
                assert_eq!(kind, "aaren");
                assert_eq!(backend, Backend::Native);
                assert_eq!(id, None);
            }
            _ => panic!("wrong variant"),
        }
        match parse_request(r#"{"op":"create","kind":"tf","backend":"hlo"}"#).unwrap() {
            WireOp::Create { backend, .. } => assert_eq!(backend, Backend::Hlo),
            _ => panic!("wrong variant"),
        }
        match parse_request(r#"{"op":"step","id":3,"x":[1.0,-2.5]}"#).unwrap() {
            WireOp::Step { id, x } => {
                assert_eq!(id, 3);
                assert_eq!(x, vec![1.0, -2.5]);
            }
            _ => panic!("wrong variant"),
        }
        assert!(parse_request(r#"{"op":"create","kind":"aaren","backend":"tpu"}"#).is_err());
        assert!(parse_request(r#"{"op":"bogus"}"#).is_err());
        assert!(parse_request("not json").is_err());
        // non-numeric / non-finite-in-f32 token elements are rejected,
        // not coerced to NaN or saturated to infinity
        assert!(parse_request(r#"{"op":"step","id":1,"x":[1.0,null]}"#).is_err());
        assert!(parse_request(r#"{"op":"step","id":1,"x":[1.0,"2.0"]}"#).is_err());
        assert!(parse_request(r#"{"op":"step","id":1,"x":[1e40]}"#).is_err());
    }

    #[test]
    fn obj_builder_emits_valid_json() {
        let j = obj(vec![("a", Json::Num(1.0)), ("b", Json::Bool(true))]);
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.usize_field("a").unwrap(), 1);
    }

    fn test_router(shards: usize) -> Router {
        let cfg =
            ServeConfig { addr: String::new(), channels: 4, shards, ..ServeConfig::default() };
        Router::start(&cfg).unwrap()
    }

    #[test]
    fn router_shards_sessions_and_aggregates_stats() {
        let router = test_router(3);
        let mut ids = Vec::new();
        for _ in 0..5 {
            let r = router
                .dispatch(WireOp::Create {
                    kind: "aaren".into(),
                    backend: Backend::Native,
                    id: None,
                })
                .unwrap();
            ids.push(r.usize_field("id").unwrap() as u64);
        }
        // ids are distinct and deterministically pinned across shards
        for (i, id) in ids.iter().enumerate() {
            assert_eq!(*id, i as u64 + 1);
        }
        for &id in &ids {
            let r = router.dispatch(WireOp::Step { id, x: vec![0.5; 4] }).unwrap();
            assert_eq!(r.usize_field("t").unwrap(), 1);
        }
        let stats = router.dispatch(WireOp::Stats).unwrap();
        assert_eq!(stats.usize_field("sessions").unwrap(), 5);
        assert!(stats.usize_field("total_state_bytes").unwrap() > 0);
        router.dispatch(WireOp::Close { id: ids[0] }).unwrap();
        let stats = router.dispatch(WireOp::Stats).unwrap();
        assert_eq!(stats.usize_field("sessions").unwrap(), 4);
        assert!(router.dispatch(WireOp::Step { id: ids[0], x: vec![0.5; 4] }).is_err());
        router.dispatch(WireOp::Shutdown).unwrap();
        assert!(router.is_shutdown());
    }

    #[test]
    fn native_id_space_exhaustion_is_loud_not_misrouted() {
        // regression: an explicit id at the top of the native namespace
        // used to push the auto-id counter into the HLO range, where the
        // next created session routed to the (absent) HLO executor on
        // every later request and became unreachable
        let router = test_router(1);
        let top = HLO_ID_BASE - 1;
        let r = router
            .dispatch(WireOp::Create {
                kind: "aaren".into(),
                backend: Backend::Native,
                id: Some(top),
            })
            .unwrap();
        assert_eq!(r.usize_field("id").unwrap() as u64, top);
        // the claimed session itself is fully reachable
        let r = router.dispatch(WireOp::Step { id: top, x: vec![0.5; 4] }).unwrap();
        assert_eq!(r.usize_field("t").unwrap(), 1);
        // the namespace is exhausted: plain creates now fail loudly
        // instead of minting unreachable ids
        let err = router
            .dispatch(WireOp::Create { kind: "aaren".into(), backend: Backend::Native, id: None })
            .unwrap_err();
        assert!(format!("{err}").contains("exhausted"), "got: {err}");
        // ids at or past the HLO base are refused outright
        assert!(router
            .dispatch(WireOp::Create {
                kind: "aaren".into(),
                backend: Backend::Native,
                id: Some(HLO_ID_BASE),
            })
            .is_err());
        router.dispatch(WireOp::Shutdown).unwrap();
    }

    #[test]
    fn hlo_backend_unavailable_without_artifacts() {
        let router = test_router(1);
        let err = router
            .dispatch(WireOp::Create { kind: "aaren".into(), backend: Backend::Hlo, id: None })
            .unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("pjrt") || msg.contains("artifacts"), "got: {msg}");
        router.dispatch(WireOp::Shutdown).unwrap();
    }

    #[test]
    fn unknown_kind_is_reported_not_fatal() {
        let router = test_router(1);
        assert!(router
            .dispatch(WireOp::Create { kind: "mamba".into(), backend: Backend::Native, id: None })
            .is_err());
        // the executor is still alive and serving
        let r = router
            .dispatch(WireOp::Create { kind: "tf".into(), backend: Backend::Native, id: None })
            .unwrap();
        assert!(r.usize_field("id").unwrap() >= 1);
        router.dispatch(WireOp::Shutdown).unwrap();
    }

    fn kind_of_reply(r: Reply) -> (String, String) {
        match r {
            Err(e) => (Kinded::kind_of(&e).to_string(), format!("{e:#}")),
            Ok(_) => panic!("expected an error reply"),
        }
    }

    #[test]
    fn forced_panic_quarantines_the_victim_and_spares_the_shard() {
        // the tentpole guarantee: a panic inside one session's step work
        // must not kill the shard thread or disturb the other resident
        // sessions' streams
        let x = vec![0.5f32, -0.25];
        let fault = Some(FaultPlan::new(1).panic_on_step(2).site("exec-test"));
        let replies = run_drained_opts(
            vec![
                Request::Create { id: 1, kind: "aaren".into() },
                Request::Create { id: 2, kind: "aaren".into() },
                Request::Create { id: 3, kind: "tf".into() },
                Request::Step { id: 1, x: x.clone() },
                Request::Step { id: 2, x: x.clone() }, // panics inside the fold
                Request::Step { id: 3, x: x.clone() },
                Request::Stats,
                Request::Step { id: 2, x: x.clone() }, // tombstoned now
                Request::Close { id: 2 },              // clears the tombstone
                Request::Create { id: 2, kind: "aaren".into() }, // id reusable
                Request::Step { id: 2, x: x.clone() },
                Request::Shutdown,
            ],
            ExecutorOpts { fault, ..Default::default() },
        );
        for rrx in &replies[..3] {
            value_reply(rrx);
        }
        // the survivors' outputs are bitwise what plain sessions produce
        let mut ref1 = NativeAarenSession::new(2);
        let mut ref3 = NativeTfSession::new(2);
        let as_f64 = |v: Vec<f32>| v.into_iter().map(|x| x as f64).collect::<Vec<_>>();
        let y_of = |j: &Json| {
            j.get("y")
                .and_then(Json::as_arr)
                .unwrap()
                .iter()
                .map(|v| v.as_f64().unwrap())
                .collect::<Vec<_>>()
        };
        let r = value_reply(&replies[3]);
        assert_eq!(y_of(&r), as_f64(ref1.step(&x).unwrap()));
        let (kind, msg) = kind_of_reply(replies[4].recv().unwrap());
        assert_eq!(kind, KIND_QUARANTINED, "got: {msg}");
        assert!(msg.contains("panicked"), "got: {msg}");
        let r = value_reply(&replies[5]);
        assert_eq!(y_of(&r), as_f64(ref3.step(&x).unwrap()));
        match replies[6].recv().unwrap().unwrap() {
            Response::Stats { sessions, quarantined, .. } => {
                assert_eq!(sessions, 2, "victim must be gone, survivors resident");
                assert_eq!(quarantined, 1);
            }
            _ => panic!("non-stats reply"),
        }
        let (kind, _) = kind_of_reply(replies[7].recv().unwrap());
        assert_eq!(kind, KIND_QUARANTINED);
        value_reply(&replies[8]); // close ok
        value_reply(&replies[9]); // re-create ok
        assert_eq!(value_reply(&replies[10]).usize_field("t").unwrap(), 1, "fresh stream");
        assert!(matches!(replies[11].recv().unwrap(), Ok(Response::ShuttingDown)));
    }

    #[test]
    fn mass_quarantine_releases_lanes_and_survivors_keep_streaming() {
        // 10 of 12 resident sessions panic: their lanes must actually be
        // released (the set compacts — same churn threshold as close) and
        // the survivors plus a newcomer stream on the remapped lanes
        let mut plan = FaultPlan::new(7);
        for id in 2..=11u64 {
            plan = plan.panic_on_step(id);
        }
        let (tx, rx) = mpsc::sync_channel(64);
        let exec = std::thread::spawn(move || {
            run_executor(
                NativeFactory { channels: 2 },
                rx,
                ExecutorOpts { fault: Some(plan.site("exec-test")), ..Default::default() },
            )
        });
        let call = |req: Request| -> Reply {
            let (rtx, rrx) = mpsc::channel();
            tx.send((req, rtx, Instant::now())).unwrap();
            rrx.recv().unwrap()
        };
        for id in 1..=12u64 {
            call(Request::Create { id, kind: "aaren".into() }).unwrap();
        }
        for id in 1..=12u64 {
            let r = call(Request::Step { id, x: vec![0.5, -0.25] });
            if (2..=11).contains(&id) {
                let (kind, _) = kind_of_reply(r);
                assert_eq!(kind, KIND_QUARANTINED, "session {id} should be quarantined");
            } else {
                r.unwrap();
            }
        }
        // survivors carry their streams forward on compacted lanes
        for id in [1u64, 12] {
            match call(Request::Step { id, x: vec![1.5, 0.75] }).unwrap() {
                Response::Value(j) => {
                    assert_eq!(j.usize_field("t").unwrap(), 2, "session {id} lost its stream");
                }
                _ => panic!("non-value reply"),
            }
        }
        call(Request::Create { id: 20, kind: "aaren".into() }).unwrap();
        match call(Request::Step { id: 20, x: vec![0.0, 1.0] }).unwrap() {
            Response::Value(j) => assert_eq!(j.usize_field("t").unwrap(), 1),
            _ => panic!("non-value reply"),
        }
        match call(Request::Stats).unwrap() {
            Response::Stats { sessions, quarantined, .. } => {
                assert_eq!(sessions, 3);
                assert_eq!(quarantined, 10);
            }
            _ => panic!("non-stats reply"),
        }
        let _ = call(Request::Shutdown);
        exec.join().unwrap();
    }

    #[test]
    fn poisoned_outputs_quarantine_the_session() {
        // inputs are finite f32s (they pass the parse-time gate) but the
        // accumulator overflows on the second fold: w doubles past
        // f32::MAX, the output goes infinite, and the session must be
        // contained rather than keep serving garbage
        let hot = vec![3.0e38f32, 3.0e38];
        let replies = run_drained_opts(
            vec![
                Request::Create { id: 1, kind: "aaren".into() },
                Request::Step { id: 1, x: hot.clone() }, // w = 3e38: finite, ok
                Request::Stats,                          // drain boundary
                Request::Step { id: 1, x: hot.clone() }, // w = 6e38 = inf
                Request::Stats,
                Request::Step { id: 1, x: vec![0.1, 0.2] }, // tombstoned
                Request::Close { id: 1 },
                Request::Create { id: 1, kind: "aaren".into() },
                Request::Step { id: 1, x: vec![0.1, 0.2] },
                Request::Shutdown,
            ],
            ExecutorOpts::default(),
        );
        value_reply(&replies[0]);
        assert_eq!(value_reply(&replies[1]).usize_field("t").unwrap(), 1);
        replies[2].recv().unwrap().unwrap();
        let (kind, msg) = kind_of_reply(replies[3].recv().unwrap());
        assert_eq!(kind, KIND_QUARANTINED, "got: {msg}");
        assert!(msg.contains("non-finite"), "got: {msg}");
        match replies[4].recv().unwrap().unwrap() {
            Response::Stats { sessions, quarantined, .. } => {
                assert_eq!((sessions, quarantined), (0, 1));
            }
            _ => panic!("non-stats reply"),
        }
        let (kind, _) = kind_of_reply(replies[5].recv().unwrap());
        assert_eq!(kind, KIND_QUARANTINED);
        value_reply(&replies[6]);
        value_reply(&replies[7]);
        assert_eq!(value_reply(&replies[8]).usize_field("t").unwrap(), 1);
        assert!(matches!(replies[9].recv().unwrap(), Ok(Response::ShuttingDown)));
    }

    #[test]
    fn full_queue_is_refused_with_a_structured_overloaded_error() {
        use crate::fault::KIND_OVERLOADED;
        let (tx, rx) = mpsc::sync_channel(1);
        let shard = Shard::new(tx);
        let stats = ServeStats::default();
        // wedge the queue: one envelope nobody drains
        let (rtx, _rrx) = mpsc::channel();
        shard.tx.try_send((Request::Stats, rtx, Instant::now())).unwrap();
        let err = try_call_on(&shard, 1, Request::Stats, &stats).unwrap_err();
        let k = Kinded::of(&err).expect("overload must carry a kind");
        assert_eq!(k.kind, KIND_OVERLOADED);
        // the hint is occupancy-priced: never below the floor, never
        // above the cap (here nothing is in flight, so it is the floor)
        assert_eq!(k.retry_after_ms, Some(RETRY_AFTER_MS));
        assert_eq!(stats.overloaded_rejects.load(Ordering::Relaxed), 1);
        // the wire body carries kind + retry hint
        let body = error_body(&err);
        let (kind, _) = wire_error(&body).unwrap();
        assert_eq!(kind, KIND_OVERLOADED);
        let hint = body
            .get("error")
            .and_then(|e| e.get("retry_after_ms"))
            .and_then(Json::as_f64)
            .expect("overload reply carries a hint") as u64;
        assert!((RETRY_AFTER_MS..=RETRY_AFTER_CAP_MS).contains(&hint), "hint {hint}");
        // a dead executor is a plain error, not an overload
        drop(rx);
        let err = try_call_on(&shard, 1, Request::Stats, &stats).unwrap_err();
        assert!(Kinded::of(&err).is_none(), "got: {err:#}");
        assert_eq!(stats.overloaded_rejects.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn retry_hint_scales_with_occupancy_and_stays_bounded() {
        let depth = 64;
        // at or below the queue bound the hint is exactly the floor
        assert_eq!(retry_hint_ms(0, depth), RETRY_AFTER_MS);
        assert_eq!(retry_hint_ms(depth, depth), RETRY_AFTER_MS);
        // every extra quarter-queue beyond the bound doubles the hint
        assert_eq!(retry_hint_ms(depth + depth / 4, depth), RETRY_AFTER_MS * 2);
        assert_eq!(retry_hint_ms(depth + depth / 2, depth), RETRY_AFTER_MS * 4);
        // monotone non-decreasing in occupancy, and capped
        let mut prev = 0;
        for occ in 0..depth * 8 {
            let hint = retry_hint_ms(occ, depth);
            assert!(hint >= prev, "occ {occ}: {hint} < {prev}");
            assert!((RETRY_AFTER_MS..=RETRY_AFTER_CAP_MS).contains(&hint));
            prev = hint;
        }
        assert_eq!(retry_hint_ms(depth * 8, depth), RETRY_AFTER_CAP_MS);
        // a zero depth cannot divide-by-zero
        assert!(retry_hint_ms(7, 0) <= RETRY_AFTER_CAP_MS);
    }

    #[test]
    fn accept_backoff_is_capped_exponential_with_deterministic_jitter() {
        // deterministic: the same seed yields the same schedule
        let schedule = |seed: u64| -> Vec<u128> {
            let mut rng = Rng::new(seed);
            (1..=16u32).map(|n| accept_backoff(n, &mut rng).as_millis()).collect()
        };
        assert_eq!(schedule(7), schedule(7));
        assert_ne!(schedule(7), schedule(8), "different seeds must jitter differently");
        // each sleep stays within [base, 2*base) for its doubling step,
        // and the whole schedule is bounded by twice the cap
        let mut rng = Rng::new(3);
        for n in 1..=24u32 {
            let base = ACCEPT_BACKOFF_FLOOR_MS
                .saturating_mul(1 << (n - 1).min(16))
                .min(ACCEPT_BACKOFF_CAP_MS);
            let ms = accept_backoff(n, &mut rng).as_millis() as u64;
            assert!(ms >= base && ms < base * 2, "n={n}: {ms} outside [{base}, {})", base * 2);
            assert!(ms < ACCEPT_BACKOFF_CAP_MS * 2);
        }
        // the very first error sleeps ~the floor, not the old fixed 50ms
        let mut rng = Rng::new(11);
        assert!(accept_backoff(1, &mut rng).as_millis() < (ACCEPT_BACKOFF_FLOOR_MS * 2) as u128);
    }

    #[test]
    fn corrupt_spill_blob_quarantines_with_a_structured_error() {
        // a spilled blob that fails to decode must come back as a
        // structured `corrupt_snapshot` error and tombstone the id —
        // close then heals it (MemStore stands in for a torn DirStore
        // file; DirStore's own quarantine path is covered in store.rs)
        let mut store = crate::persist::MemStore::new();
        store.put(5, b"definitely not a snapshot").unwrap();
        let spill = Some(SpillTier { store: Box::new(store), max_resident: None });
        let replies = run_drained_opts(
            vec![
                Request::Step { id: 5, x: vec![0.1, 0.2] },
                Request::Stats,
                Request::Close { id: 5 },
                Request::Create { id: 5, kind: "aaren".into() },
                Request::Step { id: 5, x: vec![0.1, 0.2] },
                Request::Shutdown,
            ],
            ExecutorOpts { spill, ..Default::default() },
        );
        let (kind, msg) = kind_of_reply(replies[0].recv().unwrap());
        assert_eq!(kind, KIND_CORRUPT_SNAPSHOT, "got: {msg}");
        match replies[1].recv().unwrap().unwrap() {
            Response::Stats { quarantined, corrupt_snapshots, spilled, .. } => {
                assert_eq!((quarantined, corrupt_snapshots), (1, 1));
                assert_eq!(spilled, 0, "the bad blob must be retired from the store");
            }
            _ => panic!("non-stats reply"),
        }
        value_reply(&replies[2]); // close clears the tombstone
        value_reply(&replies[3]); // the id is usable again
        assert_eq!(value_reply(&replies[4]).usize_field("t").unwrap(), 1);
        assert!(matches!(replies[5].recv().unwrap(), Ok(Response::ShuttingDown)));
    }
}
