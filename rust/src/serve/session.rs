//! Streaming inference sessions — the paper's efficiency claims made
//! executable (§3.3, §4.5, Figure 5).
//!
//! Two tiers live here:
//!
//! * the **HLO tier** (`pjrt` feature): `StreamModel`/`Session` execute
//!   compiled step modules through PJRT. Per-token state is the (a, c, m)
//!   tuple per (layer, head) for Aaren — **constant memory** — and a
//!   bucketed KV cache (32 → 64 → … → 512, with migration) for the
//!   Transformer baseline, so its cumulative time is quadratic.
//! * the **rust-native tier** (always compiled): [`NativeScanSession`]
//!   (one session per [`FoldKernel`] backend — Aaren, minGRU, minLSTM,
//!   average-attention — holding one kernel state row updated by the
//!   O(1) streaming fold; the Aaren instance is exactly the §3.1 RNN
//!   cell, bitwise the old `Muw` + `fold_token` path) and
//!   [`NativeTfSession`], the KV-cache baseline. These back
//!   `bench_harness::fig5` and the serve layer on builds without XLA.
//!   [`NativeAarenSession`] survives as a type alias for the Aaren
//!   instantiation.
//!
//! Both tiers implement [`StreamSession`], the trait the TCP server's
//! executors hold sessions through; the backend is chosen per `create`
//! request.
//!
//! HLO-tier state is kept as device-side literals returned by the
//! previous step — the hot loop never round-trips state through host
//! Vec<f32>.

use anyhow::{bail, ensure, Result};

use crate::attention;
use crate::persist::codec::{self, BackendTag, Snapshot};
use crate::scan::{BatchScanBuffer, FoldKernel, KernelKind, LaneSet};

/// The codec tag a `kind` scan session's snapshots carry — the ONE
/// mapping between the in-memory kernel registry and the on-disk backend
/// byte (its inverse is [`kernel_of_tag`]). Lives here, not in
/// `persist::codec`, so the codec stays ignorant of the scan layer.
pub fn backend_tag(kind: KernelKind) -> BackendTag {
    match kind {
        KernelKind::Aaren => BackendTag::Aaren,
        KernelKind::MinGru => BackendTag::MinGru,
        KernelKind::MinLstm => BackendTag::MinLstm,
        KernelKind::AvgAttn => BackendTag::AvgAttn,
    }
}

/// The fold kernel a codec backend tag names — `None` for [`BackendTag::Tf`],
/// the one backend that is a cache, not a scan.
pub fn kernel_of_tag(tag: BackendTag) -> Option<KernelKind> {
    Some(match tag {
        BackendTag::Aaren => KernelKind::Aaren,
        BackendTag::MinGru => KernelKind::MinGru,
        BackendTag::MinLstm => KernelKind::MinLstm,
        BackendTag::AvgAttn => KernelKind::AvgAttn,
        BackendTag::Tf => return None,
    })
}

/// Buckets must mirror aot.py FIG5_BUCKETS (shared by the HLO and native
/// Transformer baselines).
pub const TF_BUCKETS: [usize; 5] = [32, 64, 128, 256, 512];

/// Validate a flat token block against a session's channel width and
/// return its token count — the ONE definition of the `step_many` block
/// contract, shared by the trait default, the native fast path and the
/// cross-session batcher so their validation can never diverge.
fn check_token_block(d: usize, xs: &[f32]) -> Result<usize> {
    if xs.is_empty() {
        return Ok(0);
    }
    ensure!(d > 0, "zero-channel session cannot step a token block");
    ensure!(
        xs.len() % d == 0,
        "token block of {} floats is not a multiple of {d} channels",
        xs.len()
    );
    Ok(xs.len() / d)
}

/// Backend-agnostic streaming session: the contract the serve layer
/// programs against. One token in, one prediction out, plus the two
/// observables the paper's Figure-5 efficiency story is about — bytes of
/// state currently held and tokens folded in so far. Implemented by the
/// rust-native sessions (always compiled) and by the model-bound HLO
/// session (`pjrt` feature), so `serve::server` holds
/// `Box<dyn StreamSession>` trait objects and picks the backend per
/// `create` request.
pub trait StreamSession {
    /// Feed one token (used as key and value); returns this step's output.
    fn step(&mut self, x: &[f32]) -> Result<Vec<f32>>;
    /// Bytes of per-session state currently held.
    fn state_bytes(&self) -> usize;
    /// Number of tokens folded in so far.
    fn tokens_seen(&self) -> usize;
    /// Channel width of the tokens this session consumes.
    fn channels(&self) -> usize;

    /// Feed a flat (n, channels) token block in order, appending each
    /// step's output to `out` (also (n, channels) flat) — the `steps`
    /// wire op's entry point, amortizing one executor round-trip over n
    /// tokens. The default loops [`step`](Self::step); implementations
    /// may batch.
    fn step_many(&mut self, xs: &[f32], out: &mut Vec<f32>) -> Result<()> {
        let d = self.channels();
        if check_token_block(d, xs)? == 0 {
            return Ok(());
        }
        for x in xs.chunks_exact(d) {
            out.extend(self.step(x)?);
        }
        Ok(())
    }

    /// Short backend name for per-backend observability (`stats` wire
    /// op): a kernel wire name, `"tf"`, or `"hlo"` for the PJRT tier.
    fn backend(&self) -> &'static str {
        "other"
    }

    /// Downcast hook for the executor's residency/batching paths: native
    /// scan sessions (any fold kernel) opt in, everything else stays on
    /// the per-session [`step_many`](Self::step_many) path.
    fn as_native_scan(&mut self) -> Option<&mut NativeScanSession> {
        None
    }

    /// Serialize this session's full live state as a `persist::codec`
    /// blob — the spill tier's eviction path and the `snapshot` wire op.
    /// Restoring the blob (via `SessionFactory::restore`, the object-safe
    /// factory hook) yields a session whose future outputs are bitwise
    /// identical to this one's. The default refuses: backends whose state
    /// lives off-host (compiled-HLO device literals) don't snapshot yet,
    /// and the TTL sweep falls back to plain eviction for them.
    fn snapshot(&self) -> Result<Vec<u8>> {
        bail!("this session backend does not support snapshots")
    }
}

/// Rust-native fold-kernel streaming session: the O(1)-state tier, one
/// session per [`FoldKernel`] backend. Holds one kernel state row (for
/// Aaren, the (m, u, w) accumulator plus a fixed query vector; minGRU /
/// minLSTM carry their diagonal-affine (a, b) rows, average-attention a
/// (count, sum) row); each token is folded in with the kernel's
/// streaming `fold_leaf` (for Aaren, exactly the §3.1 RNN cell —
/// bitwise `fold_token`), so per-step cost and state size are constant
/// in the stream length.
pub struct NativeScanSession {
    kernel: KernelKind,
    d: usize,
    /// Aaren's fixed query (k = v = incoming token); empty for kernels
    /// whose leaves ignore the attention score
    q: Vec<f32>,
    /// the kernel state row: `kernel.state_width(d)` floats
    state: Vec<f32>,
    scale: f32,
    t: usize,
}

/// The Aaren instantiation of [`NativeScanSession`] — the pre-refactor
/// name, kept for the call sites (fig5, chaos, serve) that mean
/// specifically the paper's attention kernel.
pub type NativeAarenSession = NativeScanSession;

impl NativeScanSession {
    /// Aaren session over `channels`-dim tokens with the uniform (zero)
    /// query — outputs are running softmax-weighted value averages.
    pub fn new(channels: usize) -> NativeScanSession {
        Self::with_query(vec![0.0; channels])
    }

    /// Session running `kind`'s recurrence over `channels`-dim tokens
    /// (Aaren gets the uniform zero query, as [`new`](Self::new)).
    pub fn new_kernel(kind: KernelKind, channels: usize) -> NativeScanSession {
        if kind == KernelKind::Aaren {
            return Self::new(channels);
        }
        let mut state = vec![0.0; kind.state_width(channels)];
        kind.kernel().identity_into(channels, &mut state);
        NativeScanSession {
            kernel: kind,
            d: channels,
            q: Vec::new(),
            state,
            scale: 1.0 / (channels.max(1) as f32).sqrt(),
            t: 0,
        }
    }

    /// Aaren session with an explicit query vector (k = v = incoming
    /// token).
    pub fn with_query(q: Vec<f32>) -> NativeScanSession {
        let d = q.len();
        let mut state = vec![0.0; KernelKind::Aaren.state_width(d)];
        KernelKind::Aaren.kernel().identity_into(d, &mut state);
        NativeScanSession {
            kernel: KernelKind::Aaren,
            d,
            q,
            state,
            scale: 1.0 / (d.max(1) as f32).sqrt(),
            t: 0,
        }
    }

    /// The fold kernel this session runs.
    pub fn kernel(&self) -> KernelKind {
        self.kernel
    }

    #[inline]
    fn k(&self) -> &'static dyn FoldKernel {
        self.kernel.kernel()
    }

    pub fn channels(&self) -> usize {
        self.d
    }

    pub fn tokens_seen(&self) -> usize {
        self.t
    }

    /// Bytes of per-session state — constant: one kernel state row (for
    /// Aaren, the (m, u) scalars plus the d-dim w row).
    pub fn state_bytes(&self) -> usize {
        self.state.len() * std::mem::size_of::<f32>()
    }

    /// The attention score of token `x` against this session's query
    /// (0.0 for kernels without one — their leaves ignore it).
    #[inline]
    fn score(&self, x: &[f32]) -> f32 {
        self.q.iter().zip(x.iter()).map(|(a, b)| a * b).sum::<f32>() * self.scale
    }

    /// Feed one token (used as both key and value); returns the kernel's
    /// prefix output so far. O(1) work and memory per step.
    pub fn step(&mut self, x: &[f32]) -> Result<Vec<f32>> {
        if x.len() != self.d {
            bail!("token has {} channels, session expects {}", x.len(), self.d);
        }
        let s = self.score(x);
        self.k().fold_leaf(self.d, s, x, &mut self.state);
        self.t += 1;
        let mut out = vec![0.0; self.d];
        self.k().output_into(self.d, &self.state, &mut out);
        Ok(out)
    }

    /// Export the session's complete state as a codec [`Snapshot`]:
    /// payload = q (d floats, Aaren only) then the kernel state row.
    /// `scale` is derived from d and `tokens_seen` travels in the
    /// header, so this is the WHOLE session — for Aaren 2·d + 2 floats
    /// (byte-identical to the pre-refactor blob), constant in stream
    /// length either way, exactly the paper's §3.3 claim.
    pub fn export_state(&self) -> Snapshot {
        let mut state = Vec::with_capacity(self.q.len() + self.state.len());
        state.extend_from_slice(&self.q);
        state.extend_from_slice(&self.state);
        Snapshot {
            backend: backend_tag(self.kernel),
            channels: self.d,
            tokens_seen: self.t as u64,
            state,
        }
    }

    /// Rebuild a session from [`export_state`](Self::export_state)'s
    /// snapshot. Bitwise inverse: every f32 (query, state row) is
    /// adopted exactly, so the restored session's outputs continue the
    /// stream bit-for-bit.
    pub fn import_state(snap: &Snapshot) -> Result<NativeScanSession> {
        let Some(kind) = kernel_of_tag(snap.backend) else {
            bail!("snapshot holds a {:?} session", snap.backend)
        };
        let d = snap.channels;
        let qlen = if kind == KernelKind::Aaren { d } else { 0 };
        let width = kind.state_width(d);
        ensure!(
            snap.state.len() == qlen + width,
            "{} snapshot payload has {} floats, {d} channels need {}",
            snap.backend.kind(),
            snap.state.len(),
            qlen + width
        );
        Ok(NativeScanSession {
            kernel: kind,
            d,
            q: snap.state[..qlen].to_vec(),
            state: snap.state[qlen..].to_vec(),
            scale: 1.0 / (d.max(1) as f32).sqrt(),
            t: usize::try_from(snap.tokens_seen)?,
        })
    }

    /// Feed a flat (n, channels) token block; outputs are appended to
    /// `out` with one reservation — no per-step `Vec` on the hot path.
    pub fn step_many(&mut self, xs: &[f32], out: &mut Vec<f32>) -> Result<()> {
        let d = self.d;
        if check_token_block(d, xs)? == 0 {
            return Ok(());
        }
        out.reserve(xs.len());
        for x in xs.chunks_exact(d) {
            let s = self.score(x);
            self.k().fold_leaf(d, s, x, &mut self.state);
            self.t += 1;
            let start = out.len();
            out.resize(start + d, 0.0);
            self.k().output_into(d, &self.state, &mut out[start..]);
        }
        Ok(())
    }
}

impl StreamSession for NativeScanSession {
    fn step(&mut self, x: &[f32]) -> Result<Vec<f32>> {
        NativeScanSession::step(self, x)
    }

    fn state_bytes(&self) -> usize {
        NativeScanSession::state_bytes(self)
    }

    fn tokens_seen(&self) -> usize {
        NativeScanSession::tokens_seen(self)
    }

    fn channels(&self) -> usize {
        NativeScanSession::channels(self)
    }

    fn step_many(&mut self, xs: &[f32], out: &mut Vec<f32>) -> Result<()> {
        NativeScanSession::step_many(self, xs, out)
    }

    fn backend(&self) -> &'static str {
        self.kernel.wire_name()
    }

    fn as_native_scan(&mut self) -> Option<&mut NativeScanSession> {
        Some(self)
    }

    fn snapshot(&self) -> Result<Vec<u8>> {
        Ok(codec::encode(&self.export_state()))
    }
}

/// One batched drain unit: a native Aaren session plus its pending flat
/// (n, channels) token block.
pub type PendingLane<'a> = (&'a mut NativeScanSession, &'a [f32]);

/// Advance several native Aaren sessions through their pending token
/// blocks as lane-parallel rounds over one shared [`BatchScanBuffer`]
/// (the serve executor's per-drain coalescing engine): the sessions'
/// accumulators are gathered into B adjacent lanes of `scratch`, round r
/// folds token r of every lane that still has one — one linear walk over
/// the flat (B, d) row block per round, straight from the request
/// slices, no token copies — and the advanced states are scattered back.
/// Outputs for lane b are appended to `outs[b]` as a flat
/// (n_b, channels) block.
///
/// Bitwise identical to calling [`NativeAarenSession::step_many`] on
/// each session in turn: batching amortizes memory traffic and the
/// executor round-trip, it never changes numerics.
pub fn step_many_batched(
    lanes: &mut [PendingLane<'_>],
    scratch: &mut BatchScanBuffer,
    outs: &mut [Vec<f32>],
) -> Result<()> {
    assert_eq!(lanes.len(), outs.len(), "one output sink per lane");
    if lanes.is_empty() {
        return Ok(());
    }
    let nb = lanes.len();
    let d = lanes[0].0.channels();
    let mut counts = Vec::with_capacity(nb);
    for (s, xs) in lanes.iter() {
        ensure!(
            s.kernel() == KernelKind::Aaren,
            "the (m, u, w) batcher drains Aaren sessions, got {}",
            s.kernel().wire_name()
        );
        ensure!(s.channels() == d, "mixed channel widths in one batch");
        counts.push(check_token_block(d, xs)?);
    }

    // gather: one accumulator lane per session in the reused scratch
    scratch.reset(nb, d);
    scratch.push_identity_row();
    for (b, (s, _)) in lanes.iter().enumerate() {
        scratch.set_row(0, b, s.state[0], s.state[1], &s.state[2..]);
    }

    let max_n = counts.iter().copied().max().unwrap_or(0);
    for r in 0..max_n {
        // round r: one walk over the adjacent accumulator lanes, folding
        // straight from each request's token slice (lanes whose block is
        // exhausted are skipped)
        for (b, (s, xs)) in lanes.iter().enumerate() {
            if counts[b] <= r {
                continue;
            }
            let x = &xs[r * d..(r + 1) * d];
            scratch.fold_lane(b, s.score(x), x);
            let out = &mut outs[b];
            let start = out.len();
            out.resize(start + d, 0.0);
            scratch.lane_output_into(0, b, &mut out[start..]);
        }
    }

    // scatter the advanced accumulators back into their sessions
    for (b, (s, _)) in lanes.iter_mut().enumerate() {
        let (m, u, w) = scratch.row(0, b);
        s.state[0] = m;
        s.state[1] = u;
        s.state[2..].copy_from_slice(w);
        s.t += counts[b];
    }
    Ok(())
}

/// A native scan session whose kernel state row lives **inside** its
/// executor shard's [`LaneSet`] instead of in the session struct — the
/// resident-lane serving mode, for any fold kernel. The session keeps
/// only what is private to the stream (query, scale, token count) plus
/// its lane id; `steps` work folds tokens into the lane in place, so a
/// drain performs **zero** gather/scatter of kernel state (the copy
/// overhead of the PR 3 batched path). Every method that touches the
/// state takes the owning `LaneSet` explicitly — the buffer owns the
/// state, the session is a view.
///
/// Numerics and observables are those of [`NativeScanSession`] exactly:
/// the lane fold is the same streaming `fold_leaf` (for Aaren, bitwise
/// `fold_token`), `state_bytes` reports the same constant row width, and
/// [`export_state`](Self::export_state) emits a byte-identical
/// `persist::codec` payload (q, then the state row read straight from
/// the lane), so spill blobs and `snapshot` replies cannot tell the two
/// representations apart.
pub struct ResidentScanSession {
    kernel: KernelKind,
    d: usize,
    q: Vec<f32>,
    scale: f32,
    t: usize,
    lane: usize,
}

/// The Aaren instantiation of [`ResidentScanSession`] — the
/// pre-refactor name.
pub type ResidentAarenSession = ResidentScanSession;

impl ResidentScanSession {
    /// Move a boxed-style native session's state row into a freshly
    /// allocated lane of `lanes` and return the resident view. The
    /// native session is left empty (its query is taken); drop it.
    pub fn adopt(native: &mut NativeScanSession, lanes: &mut LaneSet) -> ResidentScanSession {
        assert_eq!(
            native.kernel(),
            lanes.kind(),
            "lane kernel must match the adopted session's"
        );
        assert_eq!(
            native.channels(),
            lanes.dim(),
            "lane width must match the adopted session's channels"
        );
        let lane = lanes.alloc();
        lanes.set_state(lane, &native.state);
        ResidentScanSession {
            kernel: native.kernel,
            d: native.d,
            q: std::mem::take(&mut native.q),
            scale: native.scale,
            t: native.t,
            lane,
        }
    }

    /// Rebuild a resident session from a codec [`Snapshot`] (the
    /// spill-restore and `restore`-wire paths), adopting every f32 of the
    /// payload bit-for-bit into a fresh lane — the exact inverse of
    /// [`export_state`](Self::export_state), and interchangeable with
    /// [`NativeScanSession::import_state`].
    pub fn from_snapshot(snap: &Snapshot, lanes: &mut LaneSet) -> Result<ResidentScanSession> {
        ensure!(
            snap.channels == lanes.dim(),
            "snapshot is {}-channel, lane set is {}",
            snap.channels,
            lanes.dim()
        );
        ensure!(
            kernel_of_tag(snap.backend) == Some(lanes.kind()),
            "snapshot holds a {} session, lane set runs {}",
            snap.backend.kind(),
            lanes.kind().wire_name()
        );
        // ONE validation/derivation path for scan snapshots: decode
        // through `import_state` (every fallible check happens there,
        // before any lane is touched), then move the state row into a
        // lane — so this can never diverge from the boxed restore path
        let mut native = NativeScanSession::import_state(snap)?;
        Ok(ResidentScanSession::adopt(&mut native, lanes))
    }

    /// The fold kernel this session runs.
    pub fn kernel(&self) -> KernelKind {
        self.kernel
    }

    /// The lane this session's accumulator occupies in its shard's set.
    pub fn lane(&self) -> usize {
        self.lane
    }

    /// Re-point the session after a [`LaneSet::compact`] move.
    pub fn set_lane(&mut self, lane: usize) {
        self.lane = lane;
    }

    /// Give the lane back to the set — the close/evict path. Consumes the
    /// session: a released view must not be touchable afterwards.
    pub fn release(self, lanes: &mut LaneSet) {
        lanes.release(self.lane);
    }

    pub fn channels(&self) -> usize {
        self.d
    }

    pub fn tokens_seen(&self) -> usize {
        self.t
    }

    /// Same constant as [`NativeScanSession::state_bytes`]: one kernel
    /// state row, wherever it lives.
    pub fn state_bytes(&self) -> usize {
        self.kernel.state_width(self.d) * std::mem::size_of::<f32>()
    }

    #[inline]
    fn score(&self, x: &[f32]) -> f32 {
        self.q.iter().zip(x.iter()).map(|(a, b)| a * b).sum::<f32>() * self.scale
    }

    /// Feed one token, folding straight into the resident lane.
    pub fn step(&mut self, lanes: &mut LaneSet, x: &[f32]) -> Result<Vec<f32>> {
        if x.len() != self.d {
            bail!("token has {} channels, session expects {}", x.len(), self.d);
        }
        lanes.fold(self.lane, self.score(x), x);
        self.t += 1;
        let mut out = vec![0.0; self.d];
        lanes.output_into(self.lane, &mut out);
        Ok(out)
    }

    /// Feed a flat (n, channels) token block, appending outputs to `out`
    /// — bitwise [`NativeScanSession::step_many`], minus the per-drain
    /// state copies.
    pub fn step_many(&mut self, lanes: &mut LaneSet, xs: &[f32], out: &mut Vec<f32>) -> Result<()> {
        let d = self.d;
        if check_token_block(d, xs)? == 0 {
            return Ok(());
        }
        out.reserve(xs.len());
        for x in xs.chunks_exact(d) {
            lanes.fold(self.lane, self.score(x), x);
            self.t += 1;
            let start = out.len();
            out.resize(start + d, 0.0);
            lanes.output_into(self.lane, &mut out[start..]);
        }
        Ok(())
    }

    /// Export the full session state as a codec [`Snapshot`], reading the
    /// state row straight from the lane: payload = q (Aaren only), then
    /// the row — byte-identical to [`NativeScanSession::export_state`]
    /// for the same stream.
    pub fn export_state(&self, lanes: &LaneSet) -> Snapshot {
        let row = lanes.state(self.lane);
        let mut state = Vec::with_capacity(self.q.len() + row.len());
        state.extend_from_slice(&self.q);
        state.extend_from_slice(row);
        Snapshot {
            backend: backend_tag(self.kernel),
            channels: self.d,
            tokens_seen: self.t as u64,
            state,
        }
    }

    /// [`export_state`](Self::export_state) through the codec framing —
    /// the blob the spill tier stores and the `snapshot` wire op returns.
    pub fn snapshot(&self, lanes: &LaneSet) -> Result<Vec<u8>> {
        Ok(codec::encode(&self.export_state(lanes)))
    }
}

/// One resident drain unit: a resident session plus its pending flat
/// (n, channels) token block.
pub type ResidentLane<'a> = (&'a mut ResidentScanSession, &'a [f32]);

/// Advance several resident sessions through their pending token blocks
/// as lane-parallel rounds over their OWN shard [`LaneSet`] — the
/// resident executor's drain engine. The units are sorted ONCE per drain
/// by lane id (an index permutation, so `outs[b]` keeps pairing with
/// `batch[b]`); round r then folds token r of every session that still
/// has one via a single ascending [`LaneSet::fold_all`] walk over the
/// state rows, instead of hopping through the buffer in session-arrival
/// order. There is no gather before and no scatter after, which is the
/// whole point of residency. Outputs for unit b are appended to
/// `outs[b]` as a flat (n_b, channels) block.
///
/// Bitwise identical to calling [`ResidentScanSession::step_many`] per
/// session (each fold touches only its own lane, so any within-round
/// order is the same computation), and therefore — for Aaren units — to
/// the PR 3 gather/scatter path [`step_many_batched`] too. Both claims
/// are property-tested below, including fragmented lane ids and shuffled
/// unit order.
pub fn step_many_resident(
    batch: &mut [ResidentLane<'_>],
    lanes: &mut LaneSet,
    outs: &mut [Vec<f32>],
) -> Result<()> {
    assert_eq!(batch.len(), outs.len(), "one output sink per drain unit");
    if batch.is_empty() {
        return Ok(());
    }
    let d = lanes.dim();
    let mut counts = Vec::with_capacity(batch.len());
    for (s, xs) in batch.iter() {
        ensure!(
            s.kernel() == lanes.kind(),
            "resident {} session drained against a {} lane set",
            s.kernel().wire_name(),
            lanes.kind().wire_name()
        );
        ensure!(
            s.channels() == d,
            "resident session has {} channels, lane set holds {d}",
            s.channels()
        );
        counts.push(check_token_block(d, xs)?);
    }
    // Each session owns a distinct lane, so sorting by lane id gives the
    // strictly ascending entry order fold_all requires.
    let mut order: Vec<usize> = (0..batch.len()).collect();
    order.sort_unstable_by_key(|&b| batch[b].0.lane);
    let max_n = counts.iter().copied().max().unwrap_or(0);
    let mut entries: Vec<(usize, f32, &[f32])> = Vec::with_capacity(batch.len());
    for r in 0..max_n {
        entries.clear();
        for &b in order.iter() {
            if counts[b] <= r {
                continue;
            }
            // copy the token-block ref out first: it lives for the
            // caller's lifetime, not the short `&mut` session borrow below
            let xs: &[f32] = batch[b].1;
            let x = &xs[r * d..(r + 1) * d];
            let s = &mut *batch[b].0;
            entries.push((s.lane, s.score(x), x));
            s.t += 1;
        }
        lanes.fold_all(&entries);
        for &b in order.iter() {
            if counts[b] <= r {
                continue;
            }
            let out = &mut outs[b];
            let start = out.len();
            out.resize(start + d, 0.0);
            lanes.output_into(batch[b].0.lane, &mut out[start..]);
        }
    }
    Ok(())
}

/// Rust-native Transformer-with-KV-cache baseline: caches every (k, v)
/// row and recomputes many-to-one attention (query = newest token) per
/// step — linear memory, O(t) per-token work, quadratic cumulative time.
/// Cache storage walks the same `TF_BUCKETS` ladder the HLO tier uses,
/// with a copy on each bucket migration, then keeps doubling capacity
/// geometrically past the last bucket so long-lived sessions degrade in
/// memory, not availability (the HLO tier, bound to compiled per-bucket
/// step modules, still ends at the largest bucket).
pub struct NativeTfSession {
    channels: usize,
    k: Vec<f32>,
    v: Vec<f32>,
    /// current cache capacity in tokens: a `TF_BUCKETS` entry, or a
    /// power-of-two multiple of the last one once the ladder is exhausted
    cap_tokens: usize,
    t: usize,
}

impl NativeTfSession {
    pub fn new(channels: usize) -> NativeTfSession {
        let cap_tokens = TF_BUCKETS[0];
        NativeTfSession {
            channels,
            k: Vec::with_capacity(cap_tokens * channels),
            v: Vec::with_capacity(cap_tokens * channels),
            cap_tokens,
            t: 0,
        }
    }

    pub fn channels(&self) -> usize {
        self.channels
    }

    pub fn tokens_seen(&self) -> usize {
        self.t
    }

    /// Bytes of per-session state: the full capacity of the current k/v
    /// cache bucket (what a serving system must reserve).
    pub fn state_bytes(&self) -> usize {
        2 * self.cap_tokens * self.channels * std::mem::size_of::<f32>()
    }

    /// The cache capacity a session that has folded `t` tokens holds:
    /// the smallest rung of the `TF_BUCKETS`-then-doubling ladder ≥ t
    /// (growth happens at step time when t reaches the current rung, so
    /// t == rung means the growth is still pending). Restores re-derive
    /// capacity with this instead of persisting it, keeping the codec
    /// payload pure content and the `state_bytes` observable identical
    /// between a restored session and a never-evicted twin.
    fn cap_for_tokens(t: usize) -> usize {
        let mut cap = TF_BUCKETS[0];
        while cap < t {
            cap = TF_BUCKETS
                .iter()
                .copied()
                .find(|&b| b > cap)
                .unwrap_or(2 * cap);
        }
        cap
    }

    /// Export the full live state: payload = the t·d live k rows then the
    /// t·d live v rows (contents only — reserved-but-unused cache
    /// capacity is re-derived on import).
    pub fn export_state(&self) -> Snapshot {
        let mut state = Vec::with_capacity(self.k.len() + self.v.len());
        state.extend_from_slice(&self.k);
        state.extend_from_slice(&self.v);
        Snapshot {
            backend: BackendTag::Tf,
            channels: self.channels,
            tokens_seen: self.t as u64,
            state,
        }
    }

    /// Rebuild from [`export_state`](Self::export_state)'s snapshot;
    /// bitwise inverse (outputs depend only on the k/v contents, which
    /// are adopted bit-for-bit).
    pub fn import_state(snap: &Snapshot) -> Result<NativeTfSession> {
        ensure!(snap.backend == BackendTag::Tf, "snapshot holds a {:?} session", snap.backend);
        let d = snap.channels;
        let t = usize::try_from(snap.tokens_seen)?;
        let rows = t
            .checked_mul(d)
            .filter(|&n| n.checked_mul(2) == Some(snap.state.len()))
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "tf snapshot payload has {} floats, t={t} × {d} channels need {}",
                    snap.state.len(),
                    2usize.saturating_mul(t.saturating_mul(d))
                )
            })?;
        let cap_tokens = Self::cap_for_tokens(t);
        let mut k = Vec::with_capacity(cap_tokens * d);
        k.extend_from_slice(&snap.state[..rows]);
        let mut v = Vec::with_capacity(cap_tokens * d);
        v.extend_from_slice(&snap.state[rows..]);
        Ok(NativeTfSession { channels: d, k, v, cap_tokens, t })
    }

    pub fn step(&mut self, x: &[f32]) -> Result<Vec<f32>> {
        if x.len() != self.channels {
            bail!("token has {} channels, session expects {}", x.len(), self.channels);
        }
        if self.t >= self.cap_tokens {
            // bucket migration: grow to the next TF_BUCKETS entry while
            // inside the ladder, then double geometrically past the last
            // one; reallocate at the new capacity and copy, mirroring the
            // HLO tier's migration cost
            let next = TF_BUCKETS
                .iter()
                .copied()
                .find(|&b| b > self.cap_tokens)
                .unwrap_or(2 * self.cap_tokens);
            let cap = next * self.channels;
            let mut k = Vec::with_capacity(cap);
            k.extend_from_slice(&self.k);
            let mut v = Vec::with_capacity(cap);
            v.extend_from_slice(&self.v);
            self.k = k;
            self.v = v;
            self.cap_tokens = next;
        }
        self.k.extend_from_slice(x);
        self.v.extend_from_slice(x);
        self.t += 1;
        Ok(attention::many_to_one(x, &self.k, &self.v, None))
    }
}

impl StreamSession for NativeTfSession {
    fn step(&mut self, x: &[f32]) -> Result<Vec<f32>> {
        NativeTfSession::step(self, x)
    }

    fn state_bytes(&self) -> usize {
        NativeTfSession::state_bytes(self)
    }

    fn tokens_seen(&self) -> usize {
        NativeTfSession::tokens_seen(self)
    }

    fn channels(&self) -> usize {
        NativeTfSession::channels(self)
    }

    fn backend(&self) -> &'static str {
        "tf"
    }

    /// tf KV snapshots grow with the stream (O(t·d) floats), so they go
    /// through [`codec::encode_auto`]: the delta+varint framing when it
    /// is smaller, raw otherwise. Scan-session blobs stay on the raw
    /// framing — their state is O(d) and byte-stability matters more
    /// than the few saved bytes.
    fn snapshot(&self) -> Result<Vec<u8>> {
        Ok(codec::encode_auto(&self.export_state()))
    }
}

#[cfg(feature = "pjrt")]
pub use hlo::{BoundSession, Session, StreamModel};

#[cfg(feature = "pjrt")]
mod hlo {
    use std::rc::Rc;

    use anyhow::{bail, Context, Result};

    use super::{StreamSession, TF_BUCKETS};
    use crate::runtime::exec::{literal_to_f32, Engine, HostTensor, Module};
    use crate::runtime::manifest::Role;
    use crate::runtime::params::ParamStore;

    /// Cached per-model assets shared by all sessions of one variant.
    ///
    /// Parameters are marshalled to literals ONCE and borrowed per step.
    /// (A device-resident PjRtBuffer variant via `execute_b` was measured
    /// during the perf pass but segfaults in the published xla 0.1.6 crate
    /// after ~70 repeated tuple-output executions — see EXPERIMENTS.md
    /// §Perf L3 for the analysis; the literal path is stable at 512+ tokens.)
    pub struct StreamModel {
        /// step module(s): aaren has one; tf has one per bucket
        modules: Vec<Rc<Module>>,
        /// parameter literals in manifest order (built once)
        param_literals: Vec<xla::Literal>,
        pub channels: usize,
    }

    impl StreamModel {
        pub fn load_aaren(engine: &mut Engine) -> Result<StreamModel> {
            let module = engine.load("stream_aaren_step")?;
            Self::build(vec![module])
        }

        pub fn load_tf(engine: &mut Engine) -> Result<StreamModel> {
            let mut modules = Vec::new();
            for b in TF_BUCKETS {
                modules.push(engine.load(&format!("stream_tf_step_c{b}"))?);
            }
            Self::build(modules)
        }

        fn build(modules: Vec<Rc<Module>>) -> Result<StreamModel> {
            let manifest = &modules[0].manifest;
            let store = ParamStore::load(manifest)?;
            let channels = manifest.meta_usize("channels", 8);
            let mut model = StreamModel { modules, param_literals: Vec::new(), channels };
            model.set_params(&store)?;
            Ok(model)
        }

        /// Marshal (trained) weights once (same params_key layout).
        pub fn set_params(&mut self, store: &ParamStore) -> Result<()> {
            let manifest = &self.modules[0].manifest;
            let mut literals = Vec::new();
            let mut pi = 0usize;
            for arg in &manifest.args {
                if arg.role == Role::Param {
                    literals.push(
                        HostTensor::F32(arg.shape.clone(), store.params[pi].clone())
                            .to_literal()?,
                    );
                    pi += 1;
                }
            }
            self.param_literals = literals;
            Ok(())
        }

        fn module_for_bucket(&self, bucket_idx: usize) -> &Rc<Module> {
            &self.modules[bucket_idx.min(self.modules.len() - 1)]
        }
    }

    /// A live streaming session: constant-state Aaren or KV-cache Transformer.
    pub enum Session {
        Aaren {
            /// state literals in manifest state order (a, c, m)
            state: Vec<xla::Literal>,
            t: i32,
        },
        Tf {
            state: Vec<xla::Literal>, // (k_cache, v_cache) for current bucket
            t: i32,
            bucket_idx: usize,
        },
    }

    impl Session {
        /// Fresh Aaren session: zero state per the §3.1 init (a=c=0, m=MASK_FILL).
        pub fn new_aaren(model: &StreamModel) -> Result<Session> {
            let manifest = &model.modules[0].manifest;
            let mut state = Vec::new();
            for arg in &manifest.args {
                if arg.role == Role::State {
                    let n: usize = arg.elements();
                    // m is initialised to MASK_FILL, a and c to zero
                    let fill =
                        if arg.name.ends_with(":m") { crate::scan::MASK_FILL } else { 0.0 };
                    state.push(HostTensor::F32(arg.shape.clone(), vec![fill; n]).to_literal()?);
                }
            }
            Ok(Session::Aaren { state, t: 0 })
        }

        pub fn new_tf(model: &StreamModel) -> Result<Session> {
            let manifest = &model.modules[0].manifest;
            let mut state = Vec::new();
            for arg in &manifest.args {
                if arg.role == Role::State {
                    state.push(
                        HostTensor::F32(arg.shape.clone(), vec![0.0; arg.elements()])
                            .to_literal()?,
                    );
                }
            }
            Ok(Session::Tf { state, t: 0, bucket_idx: 0 })
        }

        pub fn tokens_seen(&self) -> i32 {
            match self {
                Session::Aaren { t, .. } | Session::Tf { t, .. } => *t,
            }
        }

        /// Bytes of per-session state currently held — the Figure-5 (left)
        /// measurement, taken from the live literals.
        pub fn state_bytes(&self) -> usize {
            match self {
                Session::Aaren { state, .. } | Session::Tf { state, .. } => {
                    state.iter().map(|l| l.size_bytes()).sum()
                }
            }
        }

        /// Feed one token; returns the model's next-value prediction.
        pub fn step(&mut self, model: &StreamModel, x: &[f32]) -> Result<Vec<f32>> {
            if x.len() != model.channels {
                bail!("token has {} channels, model expects {}", x.len(), model.channels);
            }
            match self {
                Session::Aaren { state, t } => {
                    let module = &model.modules[0];
                    let y = run_step(module, model, state, *t, x)?;
                    *t += 1;
                    Ok(y)
                }
                Session::Tf { state, t, bucket_idx } => {
                    // migrate to the next bucket when the cache is full
                    let cur_bucket = TF_BUCKETS[*bucket_idx];
                    if *t as usize >= cur_bucket {
                        if *bucket_idx + 1 >= TF_BUCKETS.len() {
                            bail!("tf session exceeded the largest cache bucket");
                        }
                        migrate_kv(state, model, *bucket_idx, *bucket_idx + 1)
                            .context("kv bucket migration")?;
                        *bucket_idx += 1;
                    }
                    let module = model.module_for_bucket(*bucket_idx);
                    let y = run_step(module, model, state, *t, x)?;
                    *t += 1;
                    Ok(y)
                }
            }
        }
    }

    /// Execute a step module: args = params…, state…, t, x. Parameters are
    /// device-resident buffers (uploaded once); per-step we upload only the
    /// state + token tensors. Mutates `state` in place with the returned
    /// state literals and yields the prediction.
    fn run_step(
        module: &Rc<Module>,
        model: &StreamModel,
        state: &mut [xla::Literal],
        t: i32,
        x: &[f32],
    ) -> Result<Vec<f32>> {
        let manifest = &module.manifest;
        let t_lit = HostTensor::scalar_i32(t).to_literal()?;
        let x_lit = HostTensor::F32(vec![x.len()], x.to_vec()).to_literal()?;
        let mut args: Vec<&xla::Literal> = Vec::with_capacity(manifest.args.len());
        let (mut pi, mut si, mut ii) = (0usize, 0usize, 0usize);
        for arg in &manifest.args {
            match arg.role {
                Role::Param => {
                    args.push(&model.param_literals[pi]);
                    pi += 1;
                }
                Role::State => {
                    args.push(&state[si]);
                    si += 1;
                }
                Role::Input => {
                    args.push(if ii == 0 { &t_lit } else { &x_lit });
                    ii += 1;
                }
                other => bail!("unexpected role {other:?} in step module"),
            }
        }
        let outputs = module.execute_refs(&args)?;
        // outputs: state… then aux y
        let mut y = Vec::new();
        let mut si = 0usize;
        for (spec, lit) in manifest.outputs.iter().zip(outputs.into_iter()) {
            match spec.role {
                Role::State => {
                    state[si] = lit;
                    si += 1;
                }
                Role::Aux => y = literal_to_f32(&lit)?,
                _ => {}
            }
        }
        Ok(y)
    }

    /// A session bound to its shared per-model assets — the `pjrt` tier's
    /// [`StreamSession`] implementation, held as a trait object by the
    /// serve executor alongside the rust-native sessions. PJRT handles are
    /// not `Send`, so these live on the server's dedicated HLO executor
    /// thread rather than the sharded native pool.
    pub struct BoundSession {
        model: Rc<StreamModel>,
        inner: Session,
    }

    impl BoundSession {
        pub fn new_aaren(model: Rc<StreamModel>) -> Result<BoundSession> {
            let inner = Session::new_aaren(&model)?;
            Ok(BoundSession { model, inner })
        }

        pub fn new_tf(model: Rc<StreamModel>) -> Result<BoundSession> {
            let inner = Session::new_tf(&model)?;
            Ok(BoundSession { model, inner })
        }
    }

    impl StreamSession for BoundSession {
        fn step(&mut self, x: &[f32]) -> Result<Vec<f32>> {
            self.inner.step(&self.model, x)
        }

        fn state_bytes(&self) -> usize {
            self.inner.state_bytes()
        }

        fn tokens_seen(&self) -> usize {
            self.inner.tokens_seen() as usize
        }

        fn channels(&self) -> usize {
            self.model.channels
        }

        fn backend(&self) -> &'static str {
            "hlo"
        }
    }

    /// Copy a full (L, H, old, dh) cache into the prefix of a zeroed
    /// (L, H, new, dh) cache — validated against the JAX model in
    /// python/tests/test_model.py::test_kv_bucket_migration_preserves_outputs.
    fn migrate_kv(
        state: &mut [xla::Literal],
        model: &StreamModel,
        old_idx: usize,
        new_idx: usize,
    ) -> Result<()> {
        let old_manifest = &model.modules[old_idx].manifest;
        let new_manifest = &model.modules[new_idx].manifest;
        let old_specs: Vec<_> =
            old_manifest.args.iter().filter(|a| a.role == Role::State).collect();
        let new_specs: Vec<_> =
            new_manifest.args.iter().filter(|a| a.role == Role::State).collect();
        for (i, (os, ns)) in old_specs.iter().zip(new_specs.iter()).enumerate() {
            // shapes (L, H, ctx, dh)
            let (l, h, octx, dh) = (os.shape[0], os.shape[1], os.shape[2], os.shape[3]);
            let nctx = ns.shape[2];
            let old_data = literal_to_f32(&state[i])?;
            let mut new_data = vec![0.0f32; l * h * nctx * dh];
            for li in 0..l {
                for hi in 0..h {
                    for ci in 0..octx {
                        let src = ((li * h + hi) * octx + ci) * dh;
                        let dst = ((li * h + hi) * nctx + ci) * dh;
                        new_data[dst..dst + dh].copy_from_slice(&old_data[src..src + dh]);
                    }
                }
            }
            state[i] = HostTensor::F32(ns.shape.clone(), new_data).to_literal()?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    #[test]
    fn native_aaren_matches_prefix_recurrent() {
        // streaming the tokens one by one equals the many-to-many oracle
        // with the same query over the whole stream
        prop::check("native session == prefix_recurrent", 32, |rng| {
            let (n, d) = (1 + rng.below(40), 1 + rng.below(6));
            let q: Vec<f32> = (0..d).map(|_| rng.gaussian() as f32).collect();
            let xs: Vec<f32> = (0..n * d).map(|_| rng.gaussian() as f32).collect();
            let want = crate::attention::prefix_recurrent(&q, &xs, &xs, None);
            let mut session = NativeAarenSession::with_query(q);
            for t in 0..n {
                let y = session.step(&xs[t * d..(t + 1) * d]).map_err(|e| e.to_string())?;
                prop::assert_close(&y, &want[t * d..(t + 1) * d], 1e-4)
                    .map_err(|e| format!("t={t}: {e}"))?;
            }
            Ok(())
        });
    }

    #[test]
    fn native_aaren_state_is_constant() {
        let mut session = NativeAarenSession::new(8);
        let b0 = session.state_bytes();
        let mut rng = Rng::new(2);
        for _ in 0..100 {
            let x: Vec<f32> = (0..8).map(|_| rng.gaussian() as f32).collect();
            session.step(&x).unwrap();
            assert_eq!(session.state_bytes(), b0, "aaren session memory must be constant");
        }
        assert_eq!(session.tokens_seen(), 100);
    }

    #[test]
    fn native_tf_state_grows_through_buckets() {
        let mut session = NativeTfSession::new(4);
        let b0 = session.state_bytes();
        assert_eq!(b0, 2 * 32 * 4 * 4);
        let mut rng = Rng::new(3);
        for _ in 0..40 {
            let x: Vec<f32> = (0..4).map(|_| rng.gaussian() as f32).collect();
            let y = session.step(&x).unwrap();
            assert!(y.iter().all(|v| v.is_finite()));
        }
        // 40 tokens crossed the 32-bucket boundary: cache migrated + grew
        assert_eq!(session.state_bytes(), 2 * 64 * 4 * 4);
        assert_eq!(session.tokens_seen(), 40);
    }

    #[test]
    fn native_tf_survives_past_largest_bucket() {
        // regression: streams used to die at t == 512 with "exceeded the
        // largest cache bucket"; capacity now doubles geometrically, so a
        // long-lived session costs memory instead of availability
        let mut session = NativeTfSession::new(1);
        let largest = TF_BUCKETS[TF_BUCKETS.len() - 1];
        for _ in 0..largest {
            session.step(&[1.0]).unwrap();
        }
        assert_eq!(session.state_bytes(), 2 * largest * 4);
        let y = session.step(&[1.0]).unwrap();
        assert!(y[0].is_finite());
        assert_eq!(session.tokens_seen(), largest + 1);
        // first doubling past the bucket ladder
        assert_eq!(session.state_bytes(), 2 * (2 * largest) * 4);
        for _ in 0..largest {
            session.step(&[1.0]).unwrap();
        }
        // 2·largest + 1 tokens: one more doubling, still serving
        assert_eq!(session.tokens_seen(), 2 * largest + 1);
        assert_eq!(session.state_bytes(), 2 * (4 * largest) * 4);
    }

    #[test]
    fn native_sessions_reject_wrong_channel_count() {
        assert!(NativeAarenSession::new(3).step(&[1.0]).is_err());
        assert!(NativeTfSession::new(3).step(&[1.0]).is_err());
    }

    #[test]
    fn step_many_matches_individual_steps() {
        // both the aaren fast path and the tf trait-default loop must be
        // indistinguishable from stepping token by token
        prop::check("step_many == step loop", 24, |rng| {
            let (n, d) = (1 + rng.below(20), 1 + rng.below(6));
            let xs: Vec<f32> = (0..n * d).map(|_| rng.gaussian() as f32).collect();
            let sessions: [fn(usize) -> Box<dyn StreamSession>; 2] = [
                |d| Box::new(NativeAarenSession::new(d)),
                |d| Box::new(NativeTfSession::new(d)),
            ];
            for make in sessions {
                let mut one = make(d);
                let mut many = make(d);
                let mut want = Vec::new();
                for x in xs.chunks_exact(d) {
                    want.extend(one.step(x).map_err(|e| e.to_string())?);
                }
                let mut got = Vec::new();
                many.step_many(&xs, &mut got).map_err(|e| e.to_string())?;
                prop::assert_close(&got, &want, 0.0)?;
                if many.tokens_seen() != n || many.state_bytes() != one.state_bytes() {
                    return Err("t / state_bytes diverged".to_string());
                }
            }
            Ok(())
        });
    }

    #[test]
    fn step_many_rejects_ragged_blocks() {
        let mut s = NativeAarenSession::new(3);
        let mut out = Vec::new();
        assert!(s.step_many(&[1.0, 2.0], &mut out).is_err());
        assert_eq!(s.tokens_seen(), 0, "a rejected block must not advance the stream");
        assert!(s.step_many(&[], &mut out).is_ok());
        assert!(out.is_empty());
    }

    #[test]
    fn step_many_batched_is_bitwise_equal_to_sequential_step_many() {
        // the executor's coalescing engine: random lane counts, random
        // (possibly zero, possibly ragged-across-lanes) token counts
        prop::check("batched drain == per-session step_many", 24, |rng| {
            let nb = 1 + rng.below(6);
            let d = 1 + rng.below(8);
            let blocks: Vec<Vec<f32>> = (0..nb)
                .map(|_| {
                    let n = rng.below(9);
                    (0..n * d).map(|_| rng.gaussian() as f32).collect()
                })
                .collect();
            let mut batched: Vec<NativeAarenSession> =
                (0..nb).map(|_| NativeAarenSession::new(d)).collect();
            let mut sequential: Vec<NativeAarenSession> =
                (0..nb).map(|_| NativeAarenSession::new(d)).collect();
            // pre-warm both sides identically so the gather starts from a
            // non-identity state
            for (a, b) in batched.iter_mut().zip(sequential.iter_mut()) {
                let x: Vec<f32> = (0..d).map(|_| rng.gaussian() as f32).collect();
                a.step(&x).map_err(|e| e.to_string())?;
                b.step(&x).map_err(|e| e.to_string())?;
            }
            let mut lanes: Vec<PendingLane<'_>> = batched
                .iter_mut()
                .zip(blocks.iter())
                .map(|(s, xs)| (s, xs.as_slice()))
                .collect();
            let mut scratch = BatchScanBuffer::new(0, 0);
            let mut outs: Vec<Vec<f32>> = vec![Vec::new(); nb];
            step_many_batched(&mut lanes, &mut scratch, &mut outs)
                .map_err(|e| e.to_string())?;
            for b in 0..nb {
                let mut want = Vec::new();
                sequential[b]
                    .step_many(&blocks[b], &mut want)
                    .map_err(|e| e.to_string())?;
                prop::assert_close(&outs[b], &want, 0.0)
                    .map_err(|e| format!("lane {b}: {e}"))?;
                if batched[b].tokens_seen() != sequential[b].tokens_seen() {
                    return Err(format!("lane {b}: t diverged"));
                }
                for (x, y) in batched[b].state.iter().zip(sequential[b].state.iter()) {
                    if x.to_bits() != y.to_bits() {
                        return Err(format!("lane {b}: accumulator state diverged"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn batched_drain_refuses_non_aaren_sessions() {
        // the (m, u, w) gather/scatter batcher is Aaren-layout-specific;
        // other kernels drain resident or via per-session step_many
        let mut s = NativeScanSession::new_kernel(KernelKind::MinGru, 2);
        let xs = [0.1, 0.2];
        let mut lanes: Vec<PendingLane<'_>> = vec![(&mut s, &xs[..])];
        let mut scratch = BatchScanBuffer::new(0, 0);
        let mut outs = vec![Vec::new()];
        assert!(step_many_batched(&mut lanes, &mut scratch, &mut outs).is_err());
        assert_eq!(s.tokens_seen(), 0);
    }

    #[test]
    fn snapshot_restore_resumes_bitwise_for_every_backend() {
        // the persistence tentpole's core property, at the session layer:
        // snapshot → codec blob → restore, then feed both twins the same
        // tail — every output f32 must be bit-identical, as must t and
        // state_bytes, at every step
        prop::check("snapshot/restore == uninterrupted stream", 24, |rng| {
            let d = 1 + rng.below(8);
            let warm = rng.below(48);
            let tail = 1 + rng.below(24);
            let makes: [fn(usize) -> Box<dyn StreamSession>; 5] = [
                |d| Box::new(NativeAarenSession::new(d)),
                |d| Box::new(NativeTfSession::new(d)),
                |d| Box::new(NativeScanSession::new_kernel(KernelKind::MinGru, d)),
                |d| Box::new(NativeScanSession::new_kernel(KernelKind::MinLstm, d)),
                |d| Box::new(NativeScanSession::new_kernel(KernelKind::AvgAttn, d)),
            ];
            for make in makes {
                let mut original = make(d);
                for _ in 0..warm {
                    let x: Vec<f32> = (0..d).map(|_| rng.gaussian() as f32).collect();
                    original.step(&x).map_err(|e| e.to_string())?;
                }
                let blob = original.snapshot().map_err(|e| e.to_string())?;
                let snap = codec::decode(&blob).map_err(|e| e.to_string())?;
                let mut restored: Box<dyn StreamSession> = match snap.backend {
                    BackendTag::Tf => Box::new(
                        NativeTfSession::import_state(&snap).map_err(|e| e.to_string())?,
                    ),
                    _ => Box::new(
                        NativeScanSession::import_state(&snap).map_err(|e| e.to_string())?,
                    ),
                };
                if restored.tokens_seen() != original.tokens_seen()
                    || restored.state_bytes() != original.state_bytes()
                    || restored.channels() != d
                {
                    return Err("restored observables diverged".to_string());
                }
                for s in 0..tail {
                    let x: Vec<f32> = (0..d).map(|_| rng.gaussian() as f32).collect();
                    let a = original.step(&x).map_err(|e| e.to_string())?;
                    let b = restored.step(&x).map_err(|e| e.to_string())?;
                    for (i, (ya, yb)) in a.iter().zip(b.iter()).enumerate() {
                        if ya.to_bits() != yb.to_bits() {
                            return Err(format!("tail step {s}, channel {i}: bits diverged"));
                        }
                    }
                    if restored.state_bytes() != original.state_bytes() {
                        return Err(format!("tail step {s}: state_bytes diverged"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn import_rejects_mismatched_snapshots() {
        let mut aaren = NativeAarenSession::new(3);
        aaren.step(&[0.5, -0.5, 1.0]).unwrap();
        let mut snap = aaren.export_state();
        // wrong backend for the importer
        assert!(NativeTfSession::import_state(&snap).is_err());
        // payload length inconsistent with channels
        snap.state.pop();
        assert!(NativeAarenSession::import_state(&snap).is_err());
        // tf payload inconsistent with tokens_seen
        let mut tf = NativeTfSession::new(2);
        tf.step(&[1.0, 2.0]).unwrap();
        let mut snap = tf.export_state();
        snap.tokens_seen = 5;
        assert!(NativeTfSession::import_state(&snap).is_err());
    }

    #[test]
    fn tf_cap_rederivation_matches_live_growth() {
        // drive a live session across every rung of the ladder and the
        // first geometric doublings; the restore-time capacity rule must
        // reproduce the live capacity exactly at every t
        let mut live = NativeTfSession::new(1);
        assert_eq!(NativeTfSession::cap_for_tokens(0), live.cap_tokens);
        for t in 1..=(4 * TF_BUCKETS[TF_BUCKETS.len() - 1] + 3) {
            live.step(&[0.5]).unwrap();
            assert_eq!(
                NativeTfSession::cap_for_tokens(t),
                live.cap_tokens,
                "capacity diverged at t={t}"
            );
        }
    }

    #[test]
    fn sessions_unify_behind_the_stream_session_trait() {
        let mut sessions: Vec<Box<dyn StreamSession>> =
            vec![Box::new(NativeAarenSession::new(3)), Box::new(NativeTfSession::new(3))];
        for s in sessions.iter_mut() {
            for t in 0..5 {
                let y = s.step(&[0.1, -0.2, 0.3]).unwrap();
                assert_eq!(y.len(), 3);
                assert_eq!(s.tokens_seen(), t + 1);
            }
            assert!(s.state_bytes() > 0);
        }
    }

    #[test]
    fn resident_session_is_bitwise_equal_to_its_boxed_twin() {
        // the tentpole invariant at the session layer: adopting a session
        // into a lane, streaming, and reading outputs/observables must be
        // indistinguishable — bit for bit — from the self-contained form
        prop::check("resident == boxed (bitwise)", 24, |rng| {
            let d = 1 + rng.below(8);
            let warm = rng.below(20);
            let n = 1 + rng.below(30);
            let mut boxed = NativeAarenSession::new(d);
            let mut seed = NativeAarenSession::new(d);
            for _ in 0..warm {
                let x: Vec<f32> = (0..d).map(|_| rng.gaussian() as f32).collect();
                boxed.step(&x).map_err(|e| e.to_string())?;
                seed.step(&x).map_err(|e| e.to_string())?;
            }
            let mut lanes = LaneSet::new(d);
            let mut resident = ResidentAarenSession::adopt(&mut seed, &mut lanes);
            if resident.state_bytes() != boxed.state_bytes()
                || resident.tokens_seen() != boxed.tokens_seen()
                || resident.channels() != d
            {
                return Err("adopted observables diverged".to_string());
            }
            let xs: Vec<f32> = (0..n * d).map(|_| rng.gaussian() as f32).collect();
            let (mut want, mut got) = (Vec::new(), Vec::new());
            boxed.step_many(&xs, &mut want).map_err(|e| e.to_string())?;
            resident.step_many(&mut lanes, &xs, &mut got).map_err(|e| e.to_string())?;
            prop::assert_close(&got, &want, 0.0)?;
            if resident.tokens_seen() != boxed.tokens_seen() {
                return Err("t diverged".to_string());
            }
            // the spill blob must be byte-identical too
            let a = StreamSession::snapshot(&boxed).map_err(|e| e.to_string())?;
            let b = resident.snapshot(&lanes).map_err(|e| e.to_string())?;
            if a != b {
                return Err("snapshot blobs diverged".to_string());
            }
            Ok(())
        });
    }

    #[test]
    fn resident_matches_boxed_for_every_kernel_incl_snapshot_bytes() {
        // satellite 3 at the session layer: for EVERY fold kernel,
        // resident == boxed bitwise (outputs, observables, snapshot
        // bytes), and spill → restore → resume continues bit-for-bit
        // against the never-spilled control
        prop::check("kernel resident == boxed (bitwise)", 12, |rng| {
            for kind in KernelKind::ALL {
                let d = 1 + rng.below(6);
                let mut boxed = NativeScanSession::new_kernel(kind, d);
                let mut seed = NativeScanSession::new_kernel(kind, d);
                let mut lanes = LaneSet::new_kernel(kind, d);
                for _ in 0..rng.below(12) {
                    let x: Vec<f32> = (0..d).map(|_| rng.gaussian() as f32).collect();
                    boxed.step(&x).map_err(|e| e.to_string())?;
                    seed.step(&x).map_err(|e| e.to_string())?;
                }
                let mut resident = ResidentScanSession::adopt(&mut seed, &mut lanes);
                if resident.state_bytes() != boxed.state_bytes()
                    || resident.tokens_seen() != boxed.tokens_seen()
                    || resident.kernel() != kind
                {
                    return Err(format!("{kind:?}: adopted observables diverged"));
                }
                let n = 1 + rng.below(20);
                let xs: Vec<f32> = (0..n * d).map(|_| rng.gaussian() as f32).collect();
                let (mut want, mut got) = (Vec::new(), Vec::new());
                boxed.step_many(&xs, &mut want).map_err(|e| e.to_string())?;
                resident.step_many(&mut lanes, &xs, &mut got).map_err(|e| e.to_string())?;
                prop::assert_close(&got, &want, 0.0).map_err(|e| format!("{kind:?}: {e}"))?;
                let blob = StreamSession::snapshot(&boxed).map_err(|e| e.to_string())?;
                if blob != resident.snapshot(&lanes).map_err(|e| e.to_string())? {
                    return Err(format!("{kind:?}: snapshot blobs diverged"));
                }
                // spill: state leaves the lane, the lane is released,
                // then the blob re-enters a fresh lane
                let snap = codec::decode(&blob).map_err(|e| e.to_string())?;
                resident.release(&mut lanes);
                let mut revived = ResidentScanSession::from_snapshot(&snap, &mut lanes)
                    .map_err(|e| e.to_string())?;
                for s in 0..5 {
                    let x: Vec<f32> = (0..d).map(|_| rng.gaussian() as f32).collect();
                    let a = boxed.step(&x).map_err(|e| e.to_string())?;
                    let b = revived.step(&mut lanes, &x).map_err(|e| e.to_string())?;
                    if a.iter().zip(&b).any(|(p, q)| p.to_bits() != q.to_bits()) {
                        return Err(format!("{kind:?}: tail step {s} diverged after spill"));
                    }
                }
                // a kernel-mismatched restore is refused before touching a lane
                if kind != KernelKind::Aaren {
                    let mut other = LaneSet::new(d);
                    if ResidentScanSession::from_snapshot(&snap, &mut other).is_ok() {
                        return Err(format!("{kind:?} snapshot restored into aaren lanes"));
                    }
                    if other.live() != 0 {
                        return Err("refused restore leaked a lane".to_string());
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn resident_restore_resumes_bitwise_and_reuses_lanes() {
        // spill → restore through the codec blob, into a RE-USED lane (a
        // prior session released it), then stream the tail: bitwise the
        // uninterrupted control's outputs
        let d = 3;
        let mut rng = Rng::new(21);
        let mut lanes = LaneSet::new(d);
        // occupy two lanes, then free lane 0 so the restore lands on it
        let mut pad0 = NativeAarenSession::new(d);
        let mut pad1 = NativeAarenSession::new(d);
        let pad0 = ResidentAarenSession::adopt(&mut pad0, &mut lanes);
        let _pad1 = ResidentAarenSession::adopt(&mut pad1, &mut lanes);
        let freed = pad0.lane();
        pad0.release(&mut lanes);

        let mut control = NativeAarenSession::new(d);
        let mut seed = NativeAarenSession::new(d);
        for _ in 0..13 {
            let x: Vec<f32> = (0..d).map(|_| rng.gaussian() as f32).collect();
            control.step(&x).unwrap();
            seed.step(&x).unwrap();
        }
        let blob = StreamSession::snapshot(&seed).unwrap();
        let snap = codec::decode(&blob).unwrap();
        let mut restored = ResidentAarenSession::from_snapshot(&snap, &mut lanes).unwrap();
        assert_eq!(restored.lane(), freed, "restore must reuse the released lane");
        assert_eq!(restored.tokens_seen(), control.tokens_seen());
        for _ in 0..9 {
            let x: Vec<f32> = (0..d).map(|_| rng.gaussian() as f32).collect();
            let a = control.step(&x).unwrap();
            let b = restored.step(&mut lanes, &x).unwrap();
            for (ya, yb) in a.iter().zip(b.iter()) {
                assert_eq!(ya.to_bits(), yb.to_bits(), "restored resident stream diverged");
            }
        }
        // wrong-width snapshots are refused before any lane is touched
        let mut narrow = LaneSet::new(d + 1);
        assert!(ResidentAarenSession::from_snapshot(&snap, &mut narrow).is_err());
        assert_eq!(narrow.live(), 0);
    }

    #[test]
    fn step_many_resident_is_bitwise_equal_to_sequential_step_many() {
        // the resident drain engine vs per-session streaming: random lane
        // counts, ragged (possibly empty) token blocks
        prop::check("resident drain == per-session step_many", 24, |rng| {
            let nb = 1 + rng.below(6);
            let d = 1 + rng.below(8);
            let blocks: Vec<Vec<f32>> = (0..nb)
                .map(|_| {
                    let n = rng.below(9);
                    (0..n * d).map(|_| rng.gaussian() as f32).collect()
                })
                .collect();
            let mut lanes_a = LaneSet::new(d);
            let mut lanes_b = LaneSet::new(d);
            let mut batched: Vec<ResidentAarenSession> = Vec::new();
            let mut sequential: Vec<ResidentAarenSession> = Vec::new();
            for _ in 0..nb {
                // pre-warm both sides identically so drains start from a
                // non-identity state
                let x: Vec<f32> = (0..d).map(|_| rng.gaussian() as f32).collect();
                let mut seed_a = NativeAarenSession::new(d);
                let mut seed_b = NativeAarenSession::new(d);
                let mut a = ResidentAarenSession::adopt(&mut seed_a, &mut lanes_a);
                let mut b = ResidentAarenSession::adopt(&mut seed_b, &mut lanes_b);
                a.step(&mut lanes_a, &x).map_err(|e| e.to_string())?;
                b.step(&mut lanes_b, &x).map_err(|e| e.to_string())?;
                batched.push(a);
                sequential.push(b);
            }
            let mut units: Vec<ResidentLane<'_>> = batched
                .iter_mut()
                .zip(blocks.iter())
                .map(|(s, xs)| (s, xs.as_slice()))
                .collect();
            let mut outs: Vec<Vec<f32>> = vec![Vec::new(); nb];
            step_many_resident(&mut units, &mut lanes_a, &mut outs)
                .map_err(|e| e.to_string())?;
            for b in 0..nb {
                let mut want = Vec::new();
                sequential[b]
                    .step_many(&mut lanes_b, &blocks[b], &mut want)
                    .map_err(|e| e.to_string())?;
                prop::assert_close(&outs[b], &want, 0.0)
                    .map_err(|e| format!("unit {b}: {e}"))?;
                if batched[b].tokens_seen() != sequential[b].tokens_seen() {
                    return Err(format!("unit {b}: t diverged"));
                }
                let (am, au, aw) = lanes_a.row(batched[b].lane());
                let (bm, bu, bw) = lanes_b.row(sequential[b].lane());
                if am.to_bits() != bm.to_bits() || au.to_bits() != bu.to_bits() {
                    return Err(format!("unit {b}: lane m/u diverged"));
                }
                for (x, y) in aw.iter().zip(bw.iter()) {
                    if x.to_bits() != y.to_bits() {
                        return Err(format!("unit {b}: lane w diverged"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn sorted_drain_is_bitwise_on_fragmented_lanes_and_shuffled_units() {
        // the fold_all engine sorts units by lane id once per drain; lane
        // holes (released pads) and arbitrary unit arrival order must not
        // change a bit vs the per-session path, for every kernel
        prop::check("sorted resident drain on fragmented lanes", 24, |rng| {
            let kind = KernelKind::ALL[rng.below(KernelKind::ALL.len())];
            let nb = 2 + rng.below(5);
            let d = 1 + rng.below(6);
            let mut lanes_a = LaneSet::new_kernel(kind, d);
            let mut lanes_b = LaneSet::new_kernel(kind, d);
            let mut batched: Vec<ResidentScanSession> = Vec::new();
            let mut sequential: Vec<ResidentScanSession> = Vec::new();
            let mut pads: Vec<ResidentScanSession> = Vec::new();
            let mut blocks: Vec<Vec<f32>> = Vec::new();
            for _ in 0..nb {
                // a pad lane before every live session; releasing the
                // pads below leaves interior holes in lanes_a only
                let mut pad_seed = NativeScanSession::new_kernel(kind, d);
                pads.push(ResidentScanSession::adopt(&mut pad_seed, &mut lanes_a));
                let x: Vec<f32> = (0..d).map(|_| rng.gaussian() as f32).collect();
                let mut seed_a = NativeScanSession::new_kernel(kind, d);
                let mut seed_b = NativeScanSession::new_kernel(kind, d);
                let mut a = ResidentScanSession::adopt(&mut seed_a, &mut lanes_a);
                let mut b = ResidentScanSession::adopt(&mut seed_b, &mut lanes_b);
                a.step(&mut lanes_a, &x).map_err(|e| e.to_string())?;
                b.step(&mut lanes_b, &x).map_err(|e| e.to_string())?;
                batched.push(a);
                sequential.push(b);
                let n = rng.below(9);
                blocks.push((0..n * d).map(|_| rng.gaussian() as f32).collect());
            }
            for pad in pads {
                pad.release(&mut lanes_a);
            }
            if lanes_a.frag() == 0 {
                return Err("setup failed to fragment the lane set".to_string());
            }
            // one shuffle applied to (unit, oracle, block) triples keeps
            // the pairing while randomizing the drain's unit order
            let mut triples: Vec<(ResidentScanSession, ResidentScanSession, Vec<f32>)> = batched
                .into_iter()
                .zip(sequential)
                .zip(blocks)
                .map(|((a, b), xs)| (a, b, xs))
                .collect();
            rng.shuffle(&mut triples);
            let lane_ids: Vec<(usize, usize)> =
                triples.iter().map(|(a, b, _)| (a.lane(), b.lane())).collect();
            let mut units: Vec<ResidentLane<'_>> = Vec::with_capacity(nb);
            let mut oracle: Vec<(&mut ResidentScanSession, &[f32])> = Vec::with_capacity(nb);
            for (a, b, xs) in triples.iter_mut() {
                units.push((a, xs.as_slice()));
                oracle.push((b, xs.as_slice()));
            }
            let mut outs: Vec<Vec<f32>> = vec![Vec::new(); nb];
            step_many_resident(&mut units, &mut lanes_a, &mut outs)
                .map_err(|e| e.to_string())?;
            for (i, (b, xs)) in oracle.iter_mut().enumerate() {
                let mut want = Vec::new();
                b.step_many(&mut lanes_b, xs, &mut want).map_err(|e| e.to_string())?;
                if outs[i].len() != want.len() {
                    return Err(format!("unit {i}: output length diverged"));
                }
                for (ya, yb) in outs[i].iter().zip(want.iter()) {
                    if ya.to_bits() != yb.to_bits() {
                        return Err(format!("unit {i}: output diverged"));
                    }
                }
            }
            drop(units);
            drop(oracle);
            for (i, &(la, lb)) in lane_ids.iter().enumerate() {
                if triples[i].0.tokens_seen() != triples[i].1.tokens_seen() {
                    return Err(format!("unit {i}: t diverged"));
                }
                for (x, y) in lanes_a.state(la).iter().zip(lanes_b.state(lb)) {
                    if x.to_bits() != y.to_bits() {
                        return Err(format!("unit {i}: lane state diverged"));
                    }
                }
            }
            Ok(())
        });
    }
}
