//! Streaming inference sessions — the paper's efficiency claims made
//! executable (§3.3, §4.5, Figure 5).
//!
//! * `AarenSession`: per-token state is the (a, c, m) tuple per
//!   (layer, head) — **constant memory**, one fixed-cost HLO step per
//!   token.
//! * `TfSession`: the KV-cache baseline — **linear memory**, per-token
//!   cost proportional to the current cache bucket; buckets grow
//!   (32 → 64 → … → 512) with cache migration, the standard serving
//!   practice, so cumulative time is quadratic.
//!
//! State is kept as device-side literals returned by the previous step —
//! the hot loop never round-trips state through host Vec<f32>.

use std::rc::Rc;

use anyhow::{bail, Context, Result};

use crate::runtime::exec::{literal_to_f32, Engine, HostTensor, Module};
use crate::runtime::manifest::Role;
use crate::runtime::params::ParamStore;

/// Buckets must mirror aot.py FIG5_BUCKETS.
pub const TF_BUCKETS: [usize; 5] = [32, 64, 128, 256, 512];

/// Cached per-model assets shared by all sessions of one variant.
///
/// Parameters are marshalled to literals ONCE and borrowed per step.
/// (A device-resident PjRtBuffer variant via `execute_b` was measured
/// during the perf pass but segfaults in the published xla 0.1.6 crate
/// after ~70 repeated tuple-output executions — see EXPERIMENTS.md
/// §Perf L3 for the analysis; the literal path is stable at 512+ tokens.)
pub struct StreamModel {
    /// step module(s): aaren has one; tf has one per bucket
    modules: Vec<Rc<Module>>,
    /// parameter literals in manifest order (built once)
    param_literals: Vec<xla::Literal>,
    pub channels: usize,
}

impl StreamModel {
    pub fn load_aaren(engine: &mut Engine) -> Result<StreamModel> {
        let module = engine.load("stream_aaren_step")?;
        Self::build(vec![module])
    }

    pub fn load_tf(engine: &mut Engine) -> Result<StreamModel> {
        let mut modules = Vec::new();
        for b in TF_BUCKETS {
            modules.push(engine.load(&format!("stream_tf_step_c{b}"))?);
        }
        Self::build(modules)
    }

    fn build(modules: Vec<Rc<Module>>) -> Result<StreamModel> {
        let manifest = &modules[0].manifest;
        let store = ParamStore::load(manifest)?;
        let channels = manifest.meta_usize("channels", 8);
        let mut model = StreamModel { modules, param_literals: Vec::new(), channels };
        model.set_params(&store)?;
        Ok(model)
    }

    /// Marshal (trained) weights once (same params_key layout).
    pub fn set_params(&mut self, store: &ParamStore) -> Result<()> {
        let manifest = &self.modules[0].manifest;
        let mut literals = Vec::new();
        let mut pi = 0usize;
        for arg in &manifest.args {
            if arg.role == Role::Param {
                literals.push(
                    HostTensor::F32(arg.shape.clone(), store.params[pi].clone())
                        .to_literal()?,
                );
                pi += 1;
            }
        }
        self.param_literals = literals;
        Ok(())
    }

    fn module_for_bucket(&self, bucket_idx: usize) -> &Rc<Module> {
        &self.modules[bucket_idx.min(self.modules.len() - 1)]
    }
}

/// A live streaming session: constant-state Aaren or KV-cache Transformer.
pub enum Session {
    Aaren {
        /// state literals in manifest state order (a, c, m)
        state: Vec<xla::Literal>,
        t: i32,
    },
    Tf {
        state: Vec<xla::Literal>, // (k_cache, v_cache) for current bucket
        t: i32,
        bucket_idx: usize,
    },
}

impl Session {
    /// Fresh Aaren session: zero state per the §3.1 init (a=c=0, m=MASK_FILL).
    pub fn new_aaren(model: &StreamModel) -> Result<Session> {
        let manifest = &model.modules[0].manifest;
        let mut state = Vec::new();
        for arg in &manifest.args {
            if arg.role == Role::State {
                let n: usize = arg.elements();
                // m is initialised to MASK_FILL, a and c to zero
                let fill = if arg.name.ends_with(":m") { crate::scan::MASK_FILL } else { 0.0 };
                state.push(HostTensor::F32(arg.shape.clone(), vec![fill; n]).to_literal()?);
            }
        }
        Ok(Session::Aaren { state, t: 0 })
    }

    pub fn new_tf(model: &StreamModel) -> Result<Session> {
        let manifest = &model.modules[0].manifest;
        let mut state = Vec::new();
        for arg in &manifest.args {
            if arg.role == Role::State {
                state.push(
                    HostTensor::F32(arg.shape.clone(), vec![0.0; arg.elements()])
                        .to_literal()?,
                );
            }
        }
        Ok(Session::Tf { state, t: 0, bucket_idx: 0 })
    }

    pub fn tokens_seen(&self) -> i32 {
        match self {
            Session::Aaren { t, .. } | Session::Tf { t, .. } => *t,
        }
    }

    /// Bytes of per-session state currently held — the Figure-5 (left)
    /// measurement, taken from the live literals.
    pub fn state_bytes(&self) -> usize {
        match self {
            Session::Aaren { state, .. } | Session::Tf { state, .. } => {
                state.iter().map(|l| l.size_bytes()).sum()
            }
        }
    }

    /// Feed one token; returns the model's next-value prediction.
    pub fn step(&mut self, model: &StreamModel, x: &[f32]) -> Result<Vec<f32>> {
        if x.len() != model.channels {
            bail!("token has {} channels, model expects {}", x.len(), model.channels);
        }
        match self {
            Session::Aaren { state, t } => {
                let module = &model.modules[0];
                let y = run_step(module, model, state, *t, x)?;
                *t += 1;
                Ok(y)
            }
            Session::Tf { state, t, bucket_idx } => {
                // migrate to the next bucket when the cache is full
                let cur_bucket = TF_BUCKETS[*bucket_idx];
                if *t as usize >= cur_bucket {
                    if *bucket_idx + 1 >= TF_BUCKETS.len() {
                        bail!("tf session exceeded the largest cache bucket");
                    }
                    migrate_kv(state, model, *bucket_idx, *bucket_idx + 1)
                        .context("kv bucket migration")?;
                    *bucket_idx += 1;
                }
                let module = model.module_for_bucket(*bucket_idx);
                let y = run_step(module, model, state, *t, x)?;
                *t += 1;
                Ok(y)
            }
        }
    }
}

/// Execute a step module: args = params…, state…, t, x. Parameters are
/// device-resident buffers (uploaded once); per-step we upload only the
/// state + token tensors. Mutates `state` in place with the returned
/// state literals and yields the prediction.
fn run_step(
    module: &Rc<Module>,
    model: &StreamModel,
    state: &mut [xla::Literal],
    t: i32,
    x: &[f32],
) -> Result<Vec<f32>> {
    let manifest = &module.manifest;
    let t_lit = HostTensor::scalar_i32(t).to_literal()?;
    let x_lit = HostTensor::F32(vec![x.len()], x.to_vec()).to_literal()?;
    let mut args: Vec<&xla::Literal> = Vec::with_capacity(manifest.args.len());
    let (mut pi, mut si, mut ii) = (0usize, 0usize, 0usize);
    for arg in &manifest.args {
        match arg.role {
            Role::Param => {
                args.push(&model.param_literals[pi]);
                pi += 1;
            }
            Role::State => {
                args.push(&state[si]);
                si += 1;
            }
            Role::Input => {
                args.push(if ii == 0 { &t_lit } else { &x_lit });
                ii += 1;
            }
            other => bail!("unexpected role {other:?} in step module"),
        }
    }
    let outputs = module.execute_refs(&args)?;
    // outputs: state… then aux y
    let mut y = Vec::new();
    let mut si = 0usize;
    for (spec, lit) in manifest.outputs.iter().zip(outputs.into_iter()) {
        match spec.role {
            Role::State => {
                state[si] = lit;
                si += 1;
            }
            Role::Aux => y = literal_to_f32(&lit)?,
            _ => {}
        }
    }
    Ok(y)
}

/// Copy a full (L, H, old, dh) cache into the prefix of a zeroed
/// (L, H, new, dh) cache — validated against the JAX model in
/// python/tests/test_model.py::test_kv_bucket_migration_preserves_outputs.
fn migrate_kv(
    state: &mut [xla::Literal],
    model: &StreamModel,
    old_idx: usize,
    new_idx: usize,
) -> Result<()> {
    let old_manifest = &model.modules[old_idx].manifest;
    let new_manifest = &model.modules[new_idx].manifest;
    let old_specs: Vec<_> = old_manifest.args.iter().filter(|a| a.role == Role::State).collect();
    let new_specs: Vec<_> = new_manifest.args.iter().filter(|a| a.role == Role::State).collect();
    for (i, (os, ns)) in old_specs.iter().zip(new_specs.iter()).enumerate() {
        // shapes (L, H, ctx, dh)
        let (l, h, octx, dh) = (os.shape[0], os.shape[1], os.shape[2], os.shape[3]);
        let nctx = ns.shape[2];
        let old_data = literal_to_f32(&state[i])?;
        let mut new_data = vec![0.0f32; l * h * nctx * dh];
        for li in 0..l {
            for hi in 0..h {
                for ci in 0..octx {
                    let src = ((li * h + hi) * octx + ci) * dh;
                    let dst = ((li * h + hi) * nctx + ci) * dh;
                    new_data[dst..dst + dh].copy_from_slice(&old_data[src..src + dh]);
                }
            }
        }
        state[i] = HostTensor::F32(ns.shape.clone(), new_data).to_literal()?;
    }
    Ok(())
}
