//! Streaming inference service — the paper's §3.3 constant-memory serving
//! claim as a runnable stack, with no XLA required.
//!
//! * [`session`] defines the [`StreamSession`] trait (step / state_bytes /
//!   tokens_seen) and its implementations: the always-available rust-native
//!   sessions ([`NativeAarenSession`] — one O(1) `Muw` fold per token — and
//!   [`NativeTfSession`] — a KV cache walking [`TF_BUCKETS`] then doubling
//!   geometrically) plus, with the `pjrt` feature, the model-bound
//!   compiled-HLO session.
//! * [`server`] exposes a line-delimited JSON TCP protocol over trait
//!   objects. `create` picks the backend per session: `"backend":"native"`
//!   (default, pure Rust) or `"backend":"hlo"` (`pjrt` builds started with
//!   artifacts). Native sessions are served by a **sharded executor pool**
//!   — N worker threads with sessions pinned by id — while HLO sessions,
//!   whose PJRT handles are not `Send`, stay on one dedicated executor
//!   thread.
//!
//! # Wire protocol
//!
//! One JSON object per line, one reply line per request, over plain TCP:
//!
//! ```text
//! -> {"op":"create","kind":"aaren"|"tf"[,"backend":"native"|"hlo"][,"id":N]} <- {"id":N}
//! -> {"op":"step","id":N,"x":[f32;channels]}       <- {"y":[...],"state_bytes":B,"t":T}
//! -> {"op":"steps","id":N,"xs":[[f32;channels];n]} <- {"ys":[[...];n],"state_bytes":B,"t":T}
//!                                        (partial lines first when n > 512)
//! -> {"op":"snapshot","id":N}   <- {"state":"<base64>","kind":K,"channels":D,"t":T,"bytes":B}
//! -> {"op":"restore","state":"<base64>"[,"id":M]}  <- {"id":M,"kind":K,"channels":D,"t":T}
//! -> {"op":"close","id":N}                         <- {"ok":true}
//! -> {"op":"stats"}                 <- {"sessions":K,"total_state_bytes":B,"spilled":S}
//! -> {"op":"shutdown"}                             <- {"ok":true}
//! ```
//!
//! * `create` — allocate a session. `kind` selects the model family
//!   (`"aaren"`: O(1)-state prefix attention; `"tf"`: KV-cache
//!   Transformer baseline); the optional `backend` field selects the
//!   executor tier (`"native"` is the default; `"hlo"` needs a `pjrt`
//!   build started with `--artifacts`). The reply's `id` routes every
//!   later request — ids are pinned to one executor shard, so a
//!   session's requests always serialize in order. An optional explicit
//!   `id` (native tier only) claims that id instead of an assigned one;
//!   an id that already exists — resident OR spilled — is refused with a
//!   structured `{"error":"session N already exists"}` reply, never
//!   silently clobbered.
//! * `step` — fold one token (used as key and value); the reply carries
//!   the step's output `y`, the session's current `state_bytes` (the
//!   Figure-5 observable) and `t`, the number of tokens folded so far.
//!   Token values must be finite in f32; anything else is rejected
//!   rather than poisoning the (m, u, w) state.
//! * `steps` — the batch form of `step`: n tokens in one message,
//!   amortizing the TCP + executor round-trip (see
//!   `benches/serve_loopback.rs` for the measured effect). Rows must
//!   share one width, and n is capped at
//!   [`server::MAX_STEPS_TOKENS`] (absurd blocks get a clean error, not
//!   an allocation attempt). Up to
//!   [`server::STEPS_REPLY_BLOCK`] tokens the reply is one line; above
//!   it the outputs STREAM back in fixed-size blocks — every line but
//!   the last carries `"partial":true`, each line's `ys`/`t`/
//!   `state_bytes` describe the stream after that block, and reply
//!   memory is bounded by the block size instead of n. An error line is
//!   always final (the stream keeps the prefix that executed, exactly
//!   like a mid-block `step` failure). Blocks are separate executor
//!   dispatches, so another connection's ops on the same session may
//!   interleave between them — same-session cross-connection use
//!   already required client-side coordination.
//! * `snapshot` — serialize the session's full live state through the
//!   versioned `persist::codec` framing; the reply carries the blob
//!   (base64) plus its metadata. Works on resident and spilled sessions
//!   alike (a spilled one is answered from the store without restoring
//!   it). Restoring the blob yields a session whose outputs continue
//!   bitwise where this one's stream stood.
//! * `restore` — create a NEW session (native tier) from a `snapshot`
//!   blob — the client-driven migration path: snapshot on server A,
//!   restore on server B, keep streaming. By default the server assigns
//!   a fresh id; an optional explicit `id` claims that id instead (a
//!   migration that keeps its session naming), refused with a structured
//!   `{"error":"session N already exists"}` when the id is already live
//!   — resident or spilled — exactly like a duplicate `create`. Corrupt,
//!   truncated or wrong-version blobs are refused by the codec's
//!   magic/version/CRC checks.
//! * `close` — free the session (resident or spilled; a spilled
//!   session's snapshot file is deleted). Sessions can also expire: with
//!   `--session-ttl-secs N` (ServeConfig::session_ttl), executor drains
//!   sweep sessions idle longer than the TTL — DESTROYING them without a
//!   spill tier, SPILLING them with one (see below).
//! * `stats` — resident session count, their total state bytes, and the
//!   spilled-session count, aggregated across every executor shard.
//! * `shutdown` — stop all executors and the accept loop. Executors
//!   acknowledge with a first-class `Response::ShuttingDown` reply (the
//!   wire sees `{"ok":true}`); requests that race a shutdown fail with
//!   an error rather than hanging.
//!
//! Any request-level failure (unknown op, bad JSON, unknown session,
//! width mismatch) is replied as `{"error":"…"}` on the same
//! connection, which stays usable.
//!
//! # Session persistence (spill tier)
//!
//! With `--spill-dir DIR` (ServeConfig::spill_dir), TTL eviction spills
//! idle native sessions into `persist::DirStore` snapshot files instead
//! of dropping them, and the next `step`/`steps` touching a spilled id
//! transparently restores it on its owning shard. With
//! `--max-resident-sessions N` the coldest resident sessions are
//! LRU-spilled after each drain, bounding resident count independent of
//! total session count — the paper's fixed-bytes-per-stream guarantee
//! (§3.3) turned into a more-sessions-than-RAM capability. Spilled
//! sessions survive a server restart (ids are re-seeded past surviving
//! snapshots). Spill/restore round-trips are bitwise exact; HLO-tier
//! sessions cannot snapshot and keep plain TTL eviction.
//!
//! # Coalescing and resident lanes
//!
//! Executor shards drain their whole queue per iteration and serve every
//! pending `step`/`steps` as one batch. Native Aaren sessions are
//! **resident**: each shard owns a long-lived
//! [`crate::scan::LaneSet`] (a single-row-block
//! [`crate::scan::BatchScanBuffer`] with a lane free-list), every
//! session's (m, u, w) accumulator lives in a stable lane of it, and
//! drain work folds tokens into the lanes in place
//! ([`session::step_many_resident`]) — the buffer owns the state, the
//! session is a lane view, and a drain copies **no** accumulator state
//! in or out (the gather/scatter overhead of the PR 3 design). Lanes are
//! released on close/evict/spill and compacted (with the moved sessions
//! re-pointed) once released lanes outnumber both the live count and a
//! floor of 8 (hysteresis for small shards).
//! `ServeConfig::resident_lanes = false` (CLI `--scatter-drain`) keeps
//! the old gather/scatter batching ([`session::step_many_batched`]) for
//! A/B benchmarking — `BENCH_serve.json`'s `resident_vs_scatter`
//! records track the two against each other. Numerics are unchanged
//! either way — batched outputs and `t` are bitwise those of sequential
//! per-request stepping, and both drain engines are bitwise equal to
//! each other.
//! One observable coarsens: when several requests for the SAME session
//! land in one drain, each reply's `state_bytes` reflects the session
//! after the whole drain (per-request `t` stays exact). A request that
//! fails mid-block may have partially advanced the stream — exactly as
//! with individual `step` calls — and its error reply names the
//! session's current `t` so clients can resync.

pub mod server;
pub mod session;

pub use server::{
    Client, ServeConfig, Server, SessionFactory, SpillTier, MAX_STEPS_TOKENS, STEPS_REPLY_BLOCK,
};
pub use session::{
    step_many_batched, step_many_resident, NativeAarenSession, NativeTfSession, PendingLane,
    ResidentAarenSession, ResidentLane, StreamSession, TF_BUCKETS,
};

#[cfg(feature = "pjrt")]
pub use session::{BoundSession, Session, StreamModel};
