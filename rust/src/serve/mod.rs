//! Streaming inference service — the paper's §3.3 constant-memory serving
//! claim as a runnable stack, with no XLA required.
//!
//! * [`session`] defines the [`StreamSession`] trait (step / state_bytes /
//!   tokens_seen) and its implementations: the always-available rust-native
//!   sessions ([`NativeAarenSession`] — one O(1) `Muw` fold per token — and
//!   [`NativeTfSession`] — a KV cache walking [`TF_BUCKETS`] then doubling
//!   geometrically) plus, with the `pjrt` feature, the model-bound
//!   compiled-HLO session.
//! * [`server`] exposes a line-delimited JSON TCP protocol over trait
//!   objects. `create` picks the backend per session: `"backend":"native"`
//!   (default, pure Rust) or `"backend":"hlo"` (`pjrt` builds started with
//!   artifacts). Native sessions are served by a **sharded executor pool**
//!   — N worker threads with sessions pinned by id — while HLO sessions,
//!   whose PJRT handles are not `Send`, stay on one dedicated executor
//!   thread.
//!
//! # Wire protocol
//!
//! One JSON object per line, one reply line per request, over plain TCP:
//!
//! ```text
//! -> {"op":"create","kind":"aaren"|"tf"[,"backend":"native"|"hlo"]} <- {"id":N}
//! -> {"op":"step","id":N,"x":[f32;channels]}       <- {"y":[...],"state_bytes":B,"t":T}
//! -> {"op":"steps","id":N,"xs":[[f32;channels];n]} <- {"ys":[[...];n],"state_bytes":B,"t":T}
//! -> {"op":"close","id":N}                         <- {"ok":true}
//! -> {"op":"stats"}                                <- {"sessions":K,"total_state_bytes":B}
//! -> {"op":"shutdown"}                             <- {"ok":true}
//! ```
//!
//! * `create` — allocate a session. `kind` selects the model family
//!   (`"aaren"`: O(1)-state prefix attention; `"tf"`: KV-cache
//!   Transformer baseline); the optional `backend` field selects the
//!   executor tier (`"native"` is the default; `"hlo"` needs a `pjrt`
//!   build started with `--artifacts`). The reply's `id` routes every
//!   later request — ids are pinned to one executor shard, so a
//!   session's requests always serialize in order.
//! * `step` — fold one token (used as key and value); the reply carries
//!   the step's output `y`, the session's current `state_bytes` (the
//!   Figure-5 observable) and `t`, the number of tokens folded so far.
//!   Token values must be finite in f32; anything else is rejected
//!   rather than poisoning the (m, u, w) state.
//! * `steps` — the batch form of `step`: n tokens in one message, n
//!   outputs in one reply, amortizing the TCP + executor round-trip
//!   (see `benches/serve_loopback.rs` for the measured effect). `t` and
//!   `state_bytes` describe the session after the whole block. Rows
//!   must share one width.
//! * `close` — free the session. Sessions can also expire: with
//!   `--session-ttl-secs N` (ServeConfig::session_ttl), executor drains
//!   sweep out sessions idle longer than the TTL, so disconnected
//!   clients cannot leak state.
//! * `stats` — live session count and total state bytes, aggregated
//!   across every executor shard.
//! * `shutdown` — stop all executors and the accept loop. Executors
//!   acknowledge with a first-class `Response::ShuttingDown` reply (the
//!   wire sees `{"ok":true}`); requests that race a shutdown fail with
//!   an error rather than hanging.
//!
//! Any request-level failure (unknown op, bad JSON, unknown session,
//! width mismatch) is replied as `{"error":"…"}` on the same
//! connection, which stays usable.
//!
//! # Coalescing
//!
//! Executor shards drain their whole queue per iteration and serve every
//! pending `step`/`steps` as one batch: all native Aaren sessions with
//! pending tokens advance together as lanes of a single flat
//! [`crate::scan::BatchScanBuffer`] fold per token round
//! ([`session::step_many_batched`]), instead of paying a map lookup and
//! accumulator walk per request. Numerics are unchanged — batched
//! outputs and `t` are bitwise those of sequential per-request stepping.
//! One observable coarsens: when several requests for the SAME session
//! land in one drain, each reply's `state_bytes` reflects the session
//! after the whole drain (per-request `t` stays exact). A request that
//! fails mid-block may have partially advanced the stream — exactly as
//! with individual `step` calls — and its error reply names the
//! session's current `t` so clients can resync.

pub mod server;
pub mod session;

pub use server::{Client, ServeConfig, Server};
pub use session::{
    step_many_batched, NativeAarenSession, NativeTfSession, PendingLane, StreamSession, TF_BUCKETS,
};

#[cfg(feature = "pjrt")]
pub use session::{BoundSession, Session, StreamModel};
