//! Streaming inference service — the paper's §3.3 constant-memory serving
//! claim as a runnable stack, with no XLA required.
//!
//! * [`session`] defines the [`StreamSession`] trait (step / state_bytes /
//!   tokens_seen) and its implementations: the always-available rust-native
//!   sessions ([`NativeAarenSession`] — one O(1) `Muw` fold per token — and
//!   [`NativeTfSession`] — a KV cache walking [`TF_BUCKETS`] then doubling
//!   geometrically) plus, with the `pjrt` feature, the model-bound
//!   compiled-HLO session.
//! * [`server`] exposes a line-delimited JSON TCP protocol over trait
//!   objects. `create` picks the backend per session: `"backend":"native"`
//!   (default, pure Rust) or `"backend":"hlo"` (`pjrt` builds started with
//!   artifacts). Native sessions are served by a **sharded executor pool**
//!   — N worker threads with sessions pinned by id — while HLO sessions,
//!   whose PJRT handles are not `Send`, stay on one dedicated executor
//!   thread.
//!
//! Protocol (one JSON object per line):
//! ```text
//! -> {"op":"create","kind":"aaren"|"tf"[,"backend":"native"|"hlo"]} <- {"id":N}
//! -> {"op":"step","id":N,"x":[f32;channels]}   <- {"y":[...],"state_bytes":B,"t":T}
//! -> {"op":"close","id":N}                     <- {"ok":true}
//! -> {"op":"stats"}                            <- {"sessions":K,"total_state_bytes":B}
//! -> {"op":"shutdown"}                         <- {"ok":true}
//! ```

pub mod server;
pub mod session;

pub use server::{Client, ServeConfig, Server};
pub use session::{NativeAarenSession, NativeTfSession, StreamSession, TF_BUCKETS};

#[cfg(feature = "pjrt")]
pub use session::{BoundSession, Session, StreamModel};
