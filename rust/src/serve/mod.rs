//! Streaming inference service — the paper's §3.3 constant-memory serving
//! claim as a runnable stack, with no XLA required.
//!
//! * [`session`] defines the [`StreamSession`] trait (step / state_bytes /
//!   tokens_seen) and its implementations: the always-available rust-native
//!   sessions ([`NativeScanSession`] — one O(1) [`crate::scan::FoldKernel`]
//!   fold per token, over any of the `aaren` / `mingru` / `minlstm` /
//!   `avg_attn` kernels — and [`NativeTfSession`] — a KV cache walking
//!   [`TF_BUCKETS`] then doubling geometrically) plus, with the `pjrt`
//!   feature, the model-bound compiled-HLO session.
//! * [`server`] exposes a line-delimited JSON TCP protocol over trait
//!   objects. `create` picks the backend per session: `"backend":"native"`
//!   (default, pure Rust), `"backend":"hlo"` (`pjrt` builds started with
//!   artifacts), or a kernel name (`"aaren"`/`"mingru"`/`"minlstm"`/
//!   `"avg_attn"` — shorthand for the native tier running that kernel).
//!   Native sessions are served by a **sharded executor pool**
//!   — N worker threads with sessions pinned by id — while HLO sessions,
//!   whose PJRT handles are not `Send`, stay on one dedicated executor
//!   thread.
//! * The fleet router ([`crate::fleet`], `aaren fleet`) speaks this same
//!   protocol in front of N servers: consistent-hash routing, heartbeat
//!   failure detection, and failover replay from a shared spill dir.
//!
//! # Wire protocol
//!
//! One JSON object per line, one reply line per request, over plain TCP:
//!
//! ```text
//! -> {"op":"create","kind":"aaren"|"mingru"|"minlstm"|"avg_attn"|"tf"
//!                   [,"backend":"native"|"hlo"|<kernel name>][,"id":N]}      <- {"id":N}
//! -> {"op":"step","id":N,"x":[f32;channels]}       <- {"y":[...],"state_bytes":B,"t":T}
//! -> {"op":"steps","id":N,"xs":[[f32;channels];n]} <- {"ys":[[...];n],"state_bytes":B,"t":T}
//!                                        (partial lines first when n > 512)
//! -> {"op":"snapshot","id":N}   <- {"state":"<base64>","kind":K,"channels":D,"t":T,"bytes":B}
//! -> {"op":"restore","state":"<base64>"[,"id":M]}  <- {"id":M,"kind":K,"channels":D,"t":T}
//! -> {"op":"close","id":N}                         <- {"ok":true}
//! -> {"op":"drain","id":N}                         <- {"ok":true,"spilled":true|false}
//! -> {"op":"ping"}                                 <- {"ok":true}
//! -> {"op":"stats"}                 <- {"sessions":K,"total_state_bytes":B,"spilled":S}
//! -> {"op":"metrics"}               <- {"histograms":{...},"counters":{...},"events":[...]}
//! -> {"op":"shutdown"}                             <- {"ok":true}
//! ```
//!
//! * `create` — allocate a session. `kind` selects the model family
//!   (`"aaren"`: O(1)-state prefix attention; `"mingru"` / `"minlstm"`:
//!   the minimal gated RNNs of arXiv 2410.01201 as diagonal-affine fold
//!   kernels; `"avg_attn"`: the cumulative-average attention baseline of
//!   arXiv 1805.00631; `"tf"`: KV-cache Transformer baseline); the
//!   optional `backend` field selects the executor tier (`"native"` is
//!   the default; `"hlo"` needs a `pjrt` build started with
//!   `--artifacts`; a kernel name is native-tier shorthand that also
//!   implies `kind`, which may then be omitted). The reply's `id` routes every
//!   later request — ids are pinned to one executor shard, so a
//!   session's requests always serialize in order. An optional explicit
//!   `id` (native tier only) claims that id instead of an assigned one;
//!   an id that already exists — resident OR spilled — is refused with a
//!   structured `{"error":"session N already exists"}` reply, never
//!   silently clobbered.
//! * `step` — fold one token (used as key and value); the reply carries
//!   the step's output `y`, the session's current `state_bytes` (the
//!   Figure-5 observable) and `t`, the number of tokens folded so far.
//!   Token values must be finite in f32; anything else is rejected
//!   rather than poisoning the (m, u, w) state.
//! * `steps` — the batch form of `step`: n tokens in one message,
//!   amortizing the TCP + executor round-trip (see
//!   `benches/serve_loopback.rs` for the measured effect). Rows must
//!   share one width, and n is capped at
//!   [`server::MAX_STEPS_TOKENS`] (absurd blocks get a clean error, not
//!   an allocation attempt). Up to
//!   [`server::STEPS_REPLY_BLOCK`] tokens the reply is one line; above
//!   it the outputs STREAM back in fixed-size blocks — every line but
//!   the last carries `"partial":true`, each line's `ys`/`t`/
//!   `state_bytes` describe the stream after that block, and reply
//!   memory is bounded by the block size instead of n. An error line is
//!   always final (the stream keeps the prefix that executed, exactly
//!   like a mid-block `step` failure). Blocks are separate executor
//!   dispatches, so another connection's ops on the same session may
//!   interleave between them — same-session cross-connection use
//!   already required client-side coordination.
//! * `snapshot` — serialize the session's full live state through the
//!   versioned `persist::codec` framing; the reply carries the blob
//!   (base64) plus its metadata. Works on resident and spilled sessions
//!   alike (a spilled one is answered from the store without restoring
//!   it). Restoring the blob yields a session whose outputs continue
//!   bitwise where this one's stream stood.
//! * `restore` — create a NEW session (native tier) from a `snapshot`
//!   blob — the client-driven migration path: snapshot on server A,
//!   restore on server B, keep streaming. By default the server assigns
//!   a fresh id; an optional explicit `id` claims that id instead (a
//!   migration that keeps its session naming), refused with a structured
//!   `{"error":"session N already exists"}` when the id is already live
//!   — resident or spilled — exactly like a duplicate `create`. Corrupt,
//!   truncated or wrong-version blobs are refused by the codec's
//!   magic/version/CRC checks.
//! * `close` — free the session (resident or spilled; a spilled
//!   session's snapshot file is deleted). Sessions can also expire: with
//!   `--session-ttl-secs N` (ServeConfig::session_ttl), executor drains
//!   sweep sessions idle longer than the TTL — DESTROYING them without a
//!   spill tier, SPILLING them with one (see below).
//! * `drain` — spill the session to the store and release its residency
//!   NOW: the same spill a TTL eviction performs, but on demand and
//!   with a structured reply (`"spilled":true`; `false` when the
//!   session was already spilled — idempotent). Refused without a
//!   spill tier (the session keeps serving). Because the drain runs on
//!   the session's own executor shard it also acts as an ordering
//!   barrier after every in-flight op — the fleet migrator's first leg.
//! * `ping` — liveness probe answered by the router thread itself,
//!   never dispatched to an executor: a server with every queue full
//!   still answers `ping`, so heartbeats measure liveness, not load.
//! * `stats` — resident session count, their total state bytes, and the
//!   spilled-session count, aggregated across every executor shard, plus
//!   the containment counters (all cumulative since server start):
//!   `quarantined` (sessions condemned by a panic, poisoned output or
//!   corrupt snapshot), `corrupt_snapshots` (spilled blobs that failed
//!   verification), `spills` / `restores` (cumulative spill-tier
//!   traffic: sessions spilled by the TTL sweep / LRU cap / `drain` /
//!   shutdown, and sessions lazily restored on a touch — what the
//!   capacity harness turns into spill/restore rates),
//!   `overloaded_rejects` (requests/connections shed by
//!   backpressure or the connection cap) and `accept_errors`. The
//!   `backends` object breaks sessions down per backend name (`aaren`,
//!   `mingru`, `minlstm`, `avg_attn`, `tf`, `hlo`) as
//!   `{"resident":R,"spilled":S}`; spilled counts are read from each
//!   blob's codec header.
//! * `metrics` — the telemetry dump (see [`crate::obs`] and
//!   ARCHITECTURE.md § Observability), answered by the router from
//!   shared handles like `ping` — never shed by a full queue. The
//!   `histograms` object maps every non-empty stage — per-op wire
//!   latency (`op_step`, `op_steps`, …) and internal legs (`queue_wait`,
//!   `exec_drain`, `kernel_fold`, `spill_encode`/`spill_write`,
//!   `restore_read`/`restore_decode`) — to its log2-bucketed latency
//!   histogram: `count`, `sum_ns`, `max_ns`, derived `p50_ns` /
//!   `p90_ns` / `p99_ns`, and the sparse raw `buckets` so downstreams
//!   (the fleet router) merge bucket-wise and re-derive percentiles
//!   instead of averaging them. `counters` carries
//!   `overloaded_rejects` / `accept_errors` plus flight-recorder totals
//!   (`events_logged` / `events_dropped`); `events` holds the newest
//!   lifecycle events (create / spill / restore / evict / quarantine)
//!   across all shards, each stamped with its `shard`, capped at
//!   [`server::METRICS_MAX_EVENTS`]. `--metrics-interval-secs N` prints
//!   a compact per-op digest of the same data to stderr every N
//!   seconds; `--no-telemetry` (or the `obs-noop` cargo feature) turns
//!   every recording site into a no-op and leaves `histograms` empty.
//! * `shutdown` — stop all executors and the accept loop. Executors
//!   acknowledge with a first-class `Response::ShuttingDown` reply (the
//!   wire sees `{"ok":true}`); requests that race a shutdown fail with
//!   an error rather than hanging.
//!
//! # Errors and fault containment
//!
//! Any failure is replied as a structured object on the same connection:
//!
//! ```text
//! {"error":{"kind":K,"message":M[,"retry_after_ms":N]}}
//! ```
//!
//! `kind` lets clients branch without parsing prose:
//!
//! * `"quarantined"` — the session was condemned (its step work
//!   panicked, it produced a non-finite output, or its spilled snapshot
//!   was corrupt). Its lane/state is gone; every later op on the id
//!   returns this kind until `close` frees the id for reuse. Other
//!   sessions on the same shard are unaffected — this is the panic
//!   isolation boundary.
//! * `"overloaded"` — the target shard's bounded queue
//!   (`--queue-depth`) was full, or the server is at `--max-conns`
//!   concurrent connections (in that case the error is the connection's
//!   only line before close). Carries `retry_after_ms`, a back-off hint
//!   ([`server::RETRY_AFTER_MS`]). The request did NOT execute; resend
//!   after the hint.
//! * `"corrupt_snapshot"` — a spilled blob failed the codec's
//!   magic/version/CRC verification. `DirStore` quarantines the file
//!   aside as `sess-<id>.snap.corrupt` for post-mortem and the id is
//!   tombstoned as `"quarantined"` thereafter; `close` heals the id.
//! * `"frame_too_large"` — the request line crossed `--max-frame-bytes`
//!   (default 16 MiB). The rest of the frame is unread so there is no
//!   way back to a frame boundary: the error line is final and the
//!   connection closes. Other connections are unaffected.
//! * `"no_session"` — the id names nothing resident or spilled.
//! * `"error"` — everything else (bad JSON, unknown op, width mismatch,
//!   duplicate create, …). The connection stays usable.
//!
//! Connection hygiene: `--io-timeout-secs` bounds every per-connection
//! read/write so a stalled peer releases its handler thread, and the
//! accept loop backs off (and counts `accept_errors`) on accept
//! failures such as EMFILE instead of busy-spinning. Crash safety:
//! `DirStore` writes spill files tmp-then-rename with file AND directory
//! `sync_all`, sweeps stale `.tmp` files at startup, and a kill at any
//! point leaves every snapshot either absent or bitwise complete — the
//! chaos suite (`tests/chaos.rs`) kills a loaded server and asserts
//! every stream resumes bitwise from spill or gets a structured error.
//! Deterministic fault injection for that suite is wired through
//! `--fault-plan` / [`crate::fault::FaultPlan`].
//!
//! # Session persistence (spill tier)
//!
//! With `--spill-dir DIR` (ServeConfig::spill_dir), TTL eviction spills
//! idle native sessions into `persist::DirStore` snapshot files instead
//! of dropping them, and the next `step`/`steps` touching a spilled id
//! transparently restores it on its owning shard. With
//! `--max-resident-sessions N` the coldest resident sessions are
//! LRU-spilled after each drain, bounding resident count independent of
//! total session count — the paper's fixed-bytes-per-stream guarantee
//! (§3.3) turned into a more-sessions-than-RAM capability. Spilled
//! sessions survive a server restart (ids are re-seeded past surviving
//! snapshots). Spill/restore round-trips are bitwise exact; HLO-tier
//! sessions cannot snapshot and keep plain TTL eviction.
//!
//! # Coalescing and resident lanes
//!
//! Executor shards drain their whole queue per iteration and serve every
//! pending `step`/`steps` as one batch. Native scan sessions — every
//! fold-kernel backend — are **resident**: each shard owns a map of
//! long-lived [`crate::scan::LaneSet`]s keyed by (kernel, channel
//! width), every session's kernel state lives in a stable lane of its
//! set, and drain work folds tokens into the lanes in place
//! ([`ResidentScanSession::step_many`], one isolated `catch_unwind`
//! unit per session so a panic condemns only its own session) — the
//! set owns the state, the session is a lane view, and a drain copies
//! **no** accumulator state in or out (the gather/scatter overhead of
//! the PR 3 design). A restored blob with a foreign kernel or width
//! gets its own set rather than staying boxed. Lanes are released on
//! close/evict/spill/quarantine and each set is compacted (with the
//! moved sessions re-pointed) once its released lanes outnumber both
//! its live count and a floor of 8 (hysteresis for small shards).
//! `ServeConfig::resident_lanes = false` (CLI `--scatter-drain`) keeps
//! the PR 3 self-contained sessions (no lane residency) for A/B
//! benchmarking — `BENCH_serve.json`'s `resident_vs_scatter` records
//! track the two against each other, and the round-major batch engines
//! ([`session::step_many_resident`] / [`session::step_many_batched`])
//! remain exported for the benches. Numerics are unchanged either way —
//! batched outputs and `t` are bitwise those of sequential per-request
//! stepping, and both drain modes are bitwise equal to each other.
//! One observable coarsens: when several requests for the SAME session
//! land in one drain, each reply's `state_bytes` reflects the session
//! after the whole drain (per-request `t` stays exact). A request that
//! fails mid-block may have partially advanced the stream — exactly as
//! with individual `step` calls — and its error reply names the
//! session's current `t` so clients can resync.

pub mod server;
pub mod session;

pub use server::{
    wire_error, Client, ExecutorOpts, ServeConfig, ServeStats, Server, SessionFactory, SpillTier,
    MAX_STEPS_TOKENS, METRICS_MAX_EVENTS, RETRY_AFTER_CAP_MS, RETRY_AFTER_MS, STEPS_REPLY_BLOCK,
};
pub use session::{
    backend_tag, kernel_of_tag, step_many_batched, step_many_resident, NativeAarenSession,
    NativeScanSession, NativeTfSession, PendingLane, ResidentAarenSession, ResidentLane,
    ResidentScanSession, StreamSession, TF_BUCKETS,
};

#[cfg(feature = "pjrt")]
pub use session::{BoundSession, Session, StreamModel};
