//! Streaming inference service: the session manager (`session`) holds
//! per-client RNN state — constant-size for Aaren, bucketed KV cache for
//! the Transformer baseline — and the TCP server (`server`, `pjrt`
//! feature) exposes a line-delimited JSON protocol over it. PJRT handles
//! are not Sync, so a single executor thread owns all sessions and
//! connection threads talk to it over channels (a router in front of one
//! model replica).
//!
//! Builds without the `pjrt` feature still get the rust-native streaming
//! sessions ([`NativeAarenSession`], [`NativeTfSession`]) — the O(1)
//! `Muw`-fold fallback over the SoA scan engine.

#[cfg(feature = "pjrt")]
pub mod server;
pub mod session;

pub use session::{NativeAarenSession, NativeTfSession, TF_BUCKETS};

#[cfg(feature = "pjrt")]
pub use session::{Session, StreamModel};
