//! Streaming inference service: the session manager (`session`) holds
//! per-client RNN state — constant-size for Aaren, bucketed KV cache for
//! the Transformer baseline — and the TCP server (`server`) exposes a
//! line-delimited JSON protocol over it. PJRT handles are not Sync, so a
//! single executor thread owns all sessions and connection threads talk
//! to it over channels (a router in front of one model replica).

pub mod server;
pub mod session;

pub use session::{Session, StreamModel, TF_BUCKETS};
