//! Fleet membership: who the backends are, how healthy they look, and
//! where every session lives.
//!
//! Health is a one-way escalator per member: `Alive` → (missed
//! heartbeat or data-path failure) → `Suspect` → (misses reach the
//! configured threshold) → `Dead`, which is terminal — a backend that
//! comes back must `fleet_join` as a new member rather than silently
//! resurrect with an empty session table. `Leaving` is the planned
//! variant: the member stays healthy and reachable but is out of the
//! ring, so the budgeted migrator drains it session by session.

use std::collections::HashMap;
use std::time::Instant;

use super::ring::{hash_str, Ring, RingEntry};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Health {
    Alive,
    /// missed at least one heartbeat (or failed a proxied request) but
    /// not enough to condemn; still routable — most blips heal
    Suspect,
    /// terminal: out of the ring, sessions failed over
    Dead,
    /// planned exit: out of the ring, still serving while the migrator
    /// drains it
    Leaving,
}

impl Health {
    pub fn wire_name(self) -> &'static str {
        match self {
            Health::Alive => "alive",
            Health::Suspect => "suspect",
            Health::Dead => "dead",
            Health::Leaving => "leaving",
        }
    }

    /// Routable = a proxied request may be sent there.
    pub fn routable(self) -> bool {
        matches!(self, Health::Alive | Health::Suspect | Health::Leaving)
    }

    /// In-ring = new sessions may be placed there.
    pub fn in_ring(self) -> bool {
        matches!(self, Health::Alive | Health::Suspect)
    }
}

#[derive(Debug, Clone)]
pub struct Member {
    /// backend address, e.g. `"127.0.0.1:7878"` — also the identity the
    /// ring key is derived from
    pub addr: String,
    /// stable ring key: [`hash_str`] of the address
    pub key: u64,
    pub weight: u32,
    pub health: Health,
    /// consecutive failed probes/requests since the last success
    pub misses: u32,
    /// when the last successful probe/request landed — `None` until the
    /// first success. `fleet_stats` reports its age as
    /// `last_heartbeat_ms`, the operator's staleness-at-a-glance signal.
    pub last_ok: Option<Instant>,
}

impl Member {
    pub fn new(addr: String, weight: u32) -> Member {
        let key = hash_str(&addr);
        Member {
            addr,
            key,
            weight: weight.max(1),
            health: Health::Alive,
            misses: 0,
            last_ok: None,
        }
    }
}

/// Where one session lives.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Placement {
    /// pinned to members\[idx\]
    Assigned(usize),
    /// mid-migration (rebalance or failover replay): the proxy sheds
    /// ops on it with `overloaded` + a retry hint until the move
    /// commits — the guard against serving a stale pre-move snapshot
    Moving,
}

/// The mutable routing state, shared under one mutex: the member table
/// (append-only, so indices stay stable), the ring over its in-ring
/// subset, and the session placement map.
#[derive(Debug, Default)]
pub struct FleetState {
    pub members: Vec<Member>,
    pub ring: Ring,
    pub placement: HashMap<u64, Placement>,
    vnodes_per_weight: usize,
}

impl FleetState {
    pub fn new(addrs: &[String], weights: &[u32], vnodes_per_weight: usize) -> FleetState {
        let members = addrs
            .iter()
            .enumerate()
            .map(|(i, a)| Member::new(a.clone(), weights.get(i).copied().unwrap_or(1)))
            .collect();
        let mut state = FleetState {
            members,
            ring: Ring::default(),
            placement: HashMap::new(),
            vnodes_per_weight: vnodes_per_weight.max(1),
        };
        state.rebuild_ring();
        state
    }

    /// Rebuild the ring over the in-ring members ([`Health::in_ring`]).
    pub fn rebuild_ring(&mut self) {
        let entries: Vec<RingEntry> = self
            .members
            .iter()
            .enumerate()
            .filter(|(_, m)| m.health.in_ring())
            .map(|(idx, m)| RingEntry { key: m.key, weight: m.weight, idx })
            .collect();
        self.ring = Ring::build(&entries, self.vnodes_per_weight);
    }

    /// Record one failed probe or proxied request against a member.
    /// Returns `true` when this failure crossed the death threshold —
    /// the caller owes a failover. Dead members never transition;
    /// Leaving members accumulate misses (and can die — a drain target
    /// that gets SIGKILLed still needs failover) but never regress to
    /// Suspect.
    pub fn note_failure(&mut self, idx: usize, death_threshold: u32) -> bool {
        let Some(m) = self.members.get_mut(idx) else { return false };
        if m.health == Health::Dead {
            return false;
        }
        m.misses = m.misses.saturating_add(1);
        if m.misses >= death_threshold.max(1) {
            m.health = Health::Dead;
            self.rebuild_ring();
            true
        } else {
            if m.health != Health::Leaving {
                m.health = Health::Suspect;
            }
            false
        }
    }

    /// Record one successful probe or proxied request: misses reset and
    /// a Suspect member heals to Alive. Dead stays dead.
    pub fn note_success(&mut self, idx: usize) {
        if let Some(m) = self.members.get_mut(idx) {
            m.misses = 0;
            m.last_ok = Some(Instant::now());
            if m.health == Health::Suspect {
                m.health = Health::Alive;
            }
        }
    }

    /// Add a member (or revive the slot of a dead one re-joining at the
    /// same address — it gets a fresh health record but keeps its index
    /// and ring key, so its old keyspace share comes back to it).
    pub fn join(&mut self, addr: &str, weight: u32) -> usize {
        let idx = match self.members.iter().position(|m| m.addr == addr) {
            Some(i) => {
                let m = &mut self.members[i];
                m.weight = weight.max(1);
                m.health = Health::Alive;
                m.misses = 0;
                i
            }
            None => {
                self.members.push(Member::new(addr.to_string(), weight));
                self.members.len() - 1
            }
        };
        self.rebuild_ring();
        idx
    }

    /// Mark a member Leaving: out of the ring immediately (new sessions
    /// avoid it), drained live by the migrator. Returns its index.
    pub fn leave(&mut self, addr: &str) -> Option<usize> {
        let idx = self.members.iter().position(|m| m.addr == addr)?;
        if self.members[idx].health != Health::Dead {
            self.members[idx].health = Health::Leaving;
            self.rebuild_ring();
        }
        Some(idx)
    }

    /// Sessions currently assigned to members\[idx\].
    pub fn sessions_of(&self, idx: usize) -> Vec<u64> {
        let mut ids: Vec<u64> = self
            .placement
            .iter()
            .filter(|&(_, p)| *p == Placement::Assigned(idx))
            .map(|(&id, _)| id)
            .collect();
        ids.sort_unstable();
        ids
    }

    /// Per-member assigned-session counts (the placement view `stats`
    /// reports).
    pub fn session_counts(&self) -> Vec<usize> {
        let mut counts = vec![0usize; self.members.len()];
        for p in self.placement.values() {
            if let Placement::Assigned(idx) = p {
                if let Some(c) = counts.get_mut(*idx) {
                    *c += 1;
                }
            }
        }
        counts
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn three() -> FleetState {
        FleetState::new(
            &["127.0.0.1:9001".into(), "127.0.0.1:9002".into(), "127.0.0.1:9003".into()],
            &[1, 1, 1],
            8,
        )
    }

    #[test]
    fn failure_escalates_alive_suspect_dead_and_success_heals_suspect() {
        let mut s = three();
        assert!(s.members[0].last_ok.is_none(), "no success recorded yet");
        assert!(!s.note_failure(0, 3));
        assert_eq!(s.members[0].health, Health::Suspect);
        assert!(s.members[0].last_ok.is_none(), "failures must not stamp last_ok");
        s.note_success(0);
        assert_eq!(s.members[0].health, Health::Alive);
        assert_eq!(s.members[0].misses, 0);
        assert!(s.members[0].last_ok.is_some(), "success stamps last_ok");
        assert!(!s.note_failure(0, 3));
        assert!(!s.note_failure(0, 3));
        assert!(s.note_failure(0, 3), "third miss must cross the threshold");
        assert_eq!(s.members[0].health, Health::Dead);
        // dead is terminal: neither failures nor successes move it
        assert!(!s.note_failure(0, 3));
        s.note_success(0);
        assert_eq!(s.members[0].health, Health::Dead);
    }

    #[test]
    fn death_and_leaving_drop_the_member_from_the_ring() {
        let mut s = three();
        let full = s.ring.len();
        s.note_failure(1, 1);
        assert_eq!(s.members[1].health, Health::Dead);
        assert!(s.ring.len() < full);
        for id in 1..200u64 {
            assert_ne!(s.ring.lookup(id), Some(1), "ring still routes to the dead member");
        }
        s.leave("127.0.0.1:9003");
        for id in 1..200u64 {
            assert_eq!(s.ring.lookup(id), Some(0), "only member 0 is left in the ring");
        }
        // leaving members are routable (still draining) but not in-ring
        assert!(s.members[2].health.routable());
        assert!(!s.members[2].health.in_ring());
    }

    #[test]
    fn join_revives_a_dead_slot_in_place() {
        let mut s = three();
        s.note_failure(2, 1);
        assert_eq!(s.members[2].health, Health::Dead);
        let idx = s.join("127.0.0.1:9003", 2);
        assert_eq!(idx, 2, "same address re-joins its old slot");
        assert_eq!(s.members.len(), 3);
        assert_eq!(s.members[2].health, Health::Alive);
        assert_eq!(s.members[2].weight, 2);
        let idx = s.join("127.0.0.1:9004", 1);
        assert_eq!(idx, 3, "new address appends");
    }

    #[test]
    fn placement_views_count_assigned_sessions_only() {
        let mut s = three();
        s.placement.insert(10, Placement::Assigned(0));
        s.placement.insert(11, Placement::Assigned(0));
        s.placement.insert(12, Placement::Assigned(2));
        s.placement.insert(13, Placement::Moving);
        assert_eq!(s.sessions_of(0), vec![10, 11]);
        assert_eq!(s.session_counts(), vec![2, 0, 1]);
    }
}
