//! The fleet data plane: per-client-connection handlers that speak the
//! single-server wire protocol and relay each request to the backend
//! the ring (or the placement map) says owns it.
//!
//! Design rules:
//!
//! * **The router never holds the routing lock across network IO** —
//!   routing decisions snapshot `(member idx, addr)` under the lock and
//!   release it before touching a socket.
//! * **Session ids are fleet-assigned.** Backends share one spill dir,
//!   so backend-local auto-assignment would collide across processes;
//!   the proxy injects a fleet-unique `id` into every `create`/
//!   `restore` before forwarding (explicit client ids pass through and
//!   reserve the assigner past themselves).
//! * **Failures shed, never hang.** An unreachable backend, a
//!   mid-migration (`Moving`) session or an empty ring answers the
//!   structured `overloaded` + `retry_after_ms` envelope — the same
//!   shape a single overloaded server uses, so existing client retry
//!   loops ride out a failover with no new code. Every data-path
//!   failure also feeds the health state machine (miss accounting),
//!   sharpening the heartbeat detector.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream, ToSocketAddrs};
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

use anyhow::{anyhow, bail, Result};

use crate::fault::{FaultSite, Kinded};
use crate::obs::{self, Stage};
use crate::serve::server::{
    drain_frame_tail, error_body, obj, read_frame, wire_error, Frame, METRICS_MAX_EVENTS,
    RETRY_AFTER_CAP_MS, RETRY_AFTER_MS,
};
use crate::util::json::Json;

use super::member::Placement;
use super::{wake_listener, Shared};

/// One cached line-JSON connection to a backend. Also used by the
/// maintenance loop for heartbeats and migration legs.
pub(crate) struct BackendConn {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl BackendConn {
    /// Connect with `timeout` bounding the connect itself and every
    /// later read/write — a wedged backend must cost one timeout, not a
    /// hung router thread.
    pub fn connect(addr: &str, timeout: Option<Duration>) -> Result<BackendConn> {
        let stream = match timeout {
            None => TcpStream::connect(addr)?,
            Some(t) => {
                let mut last: Option<std::io::Error> = None;
                let mut stream = None;
                for sa in addr.to_socket_addrs()? {
                    match TcpStream::connect_timeout(&sa, t) {
                        Ok(s) => {
                            stream = Some(s);
                            break;
                        }
                        Err(e) => last = Some(e),
                    }
                }
                stream.ok_or_else(|| match last {
                    Some(e) => anyhow!("connect {addr}: {e}"),
                    None => anyhow!("connect {addr}: no resolvable address"),
                })?
            }
        };
        stream.set_read_timeout(timeout)?;
        stream.set_write_timeout(timeout)?;
        let writer = stream.try_clone()?;
        Ok(BackendConn { reader: BufReader::new(stream), writer })
    }

    pub fn send(&mut self, line: &str) -> Result<()> {
        self.writer.write_all(line.as_bytes())?;
        self.writer.write_all(b"\n")?;
        Ok(())
    }

    /// Read one reply line (trailing newline stripped).
    pub fn recv(&mut self) -> Result<String> {
        let mut buf = String::new();
        if self.reader.read_line(&mut buf)? == 0 {
            bail!("backend closed the connection");
        }
        while buf.ends_with('\n') || buf.ends_with('\r') {
            buf.pop();
        }
        Ok(buf)
    }

    /// One request line → one reply line.
    pub fn call_line(&mut self, line: &str) -> Result<String> {
        self.send(line)?;
        self.recv()
    }

    /// One request line → one parsed reply, with error replies turned
    /// into `Err` (the shape the maintenance loop wants).
    pub fn call(&mut self, line: &str) -> Result<Json> {
        let reply = self.call_line(line)?;
        let j = Json::parse(&reply).map_err(|e| anyhow!("bad backend reply {reply:?}: {e}"))?;
        if let Some((kind, msg)) = wire_error(&j) {
            bail!("backend error ({kind}): {msg}");
        }
        Ok(j)
    }
}

/// The per-handler backend connection cache: connections are created
/// lazily and dropped on any failure (the next request reconnects).
pub(crate) type ConnCache = HashMap<String, BackendConn>;

pub(crate) fn backend<'a>(
    conns: &'a mut ConnCache,
    addr: &str,
    timeout: Option<Duration>,
) -> Result<&'a mut BackendConn> {
    match conns.entry(addr.to_string()) {
        std::collections::hash_map::Entry::Occupied(e) => Ok(e.into_mut()),
        std::collections::hash_map::Entry::Vacant(e) => {
            Ok(e.insert(BackendConn::connect(addr, timeout)?))
        }
    }
}

/// The retry hint the router attaches to its own sheds: long enough to
/// cover a detection + replay cycle (two heartbeat intervals), capped
/// like the server's own occupancy-derived hints.
fn shed_hint(shared: &Shared) -> u64 {
    let two_ticks = (shared.cfg.hb_interval.as_millis() as u64).saturating_mul(2);
    two_ticks.clamp(RETRY_AFTER_MS, RETRY_AFTER_CAP_MS)
}

fn write_line(w: &mut TcpStream, body: &str) -> bool {
    w.write_all(body.as_bytes()).is_ok() && w.write_all(b"\n").is_ok()
}

fn write_json(w: &mut TcpStream, j: &Json) -> bool {
    write_line(w, &j.to_string())
}

fn write_shed(w: &mut TcpStream, shared: &Shared, msg: &str) -> bool {
    shared.stats.routed_sheds.fetch_add(1, Ordering::Relaxed);
    write_json(w, &error_body(&Kinded::overloaded(msg, shed_hint(shared))))
}

/// Record a data-path failure against a member. The proxy only ever
/// escalates to Suspect — declaring death (and running failover) is the
/// heartbeat loop's job, so there is exactly one replay driver — but
/// the misses it adds make the next failed probe cross the threshold
/// sooner.
fn note_data_path_failure(shared: &Shared, idx: usize) {
    let mut state = shared.state.lock().expect("fleet state lock");
    state.note_failure(idx, u32::MAX);
}

/// Where an id-bearing request should go right now.
enum Route {
    To(usize, String),
    /// shed with `overloaded`: the reason goes in the message
    Shed(&'static str),
}

fn route_id(shared: &Shared, id: u64) -> Route {
    let state = shared.state.lock().expect("fleet state lock");
    match state.placement.get(&id) {
        Some(Placement::Moving) => Route::Shed("session is migrating — back off and retry"),
        Some(Placement::Assigned(m)) => {
            let member = &state.members[*m];
            if member.health.routable() {
                Route::To(*m, member.addr.clone())
            } else {
                // owner died and failover has not replayed it yet
                Route::Shed("session's backend failed — failover in progress")
            }
        }
        None => match state.ring.lookup(id) {
            Some(m) if state.members[m].health.routable() => {
                Route::To(m, state.members[m].addr.clone())
            }
            Some(_) => Route::Shed("ring owner unreachable — back off and retry"),
            None => Route::Shed("fleet has no live members"),
        },
    }
}

/// Forward one already-serialized request to `addr`, relaying every
/// reply line to the client until the final one (the first without
/// `"partial":true` — the `steps` streaming contract). Returns Err on
/// backend-side failure (the caller sheds and notes the miss) and
/// Ok(client_alive) otherwise.
fn relay(
    conns: &mut ConnCache,
    addr: &str,
    timeout: Option<Duration>,
    line: &str,
    client: &mut TcpStream,
) -> Result<(bool, Option<Json>)> {
    let conn = backend(conns, addr, timeout)?;
    conn.send(line)?;
    let mut last = None;
    loop {
        let reply = conn.recv()?;
        let parsed = Json::parse(&reply).map_err(|e| anyhow!("bad backend reply: {e}"))?;
        let partial = matches!(parsed.get("partial"), Some(Json::Bool(true)));
        if !write_line(client, &reply) {
            // client went away mid-stream; drain the backend's
            // remaining lines so the cached connection stays framed
            if partial {
                loop {
                    let tail = conn.recv()?;
                    let t = Json::parse(&tail).map_err(|e| anyhow!("bad backend reply: {e}"))?;
                    if !matches!(t.get("partial"), Some(Json::Bool(true))) {
                        break;
                    }
                }
            }
            return Ok((false, None));
        }
        if !partial {
            last = Some(parsed);
            break;
        }
    }
    Ok((true, last))
}

/// Aggregate `stats` across every routable member: numeric top-level
/// fields sum, the per-backend breakdown merges field-wise, and a
/// `fleet` section carries the router's own counters and member table.
fn aggregate_stats(shared: &Shared, conns: &mut ConnCache) -> Json {
    let members: Vec<(usize, String)> = {
        let state = shared.state.lock().expect("fleet state lock");
        state
            .members
            .iter()
            .enumerate()
            .filter(|(_, m)| m.health.routable())
            .map(|(i, m)| (i, m.addr.clone()))
            .collect()
    };
    let mut totals: std::collections::BTreeMap<String, f64> = Default::default();
    let mut backends: std::collections::BTreeMap<String, (f64, f64)> = Default::default();
    for (idx, addr) in members {
        let reply = backend(conns, &addr, shared.cfg.io_timeout)
            .and_then(|c| c.call(r#"{"op":"stats"}"#));
        let j = match reply {
            Ok(j) => j,
            Err(_) => {
                conns.remove(&addr);
                note_data_path_failure(shared, idx);
                continue;
            }
        };
        if let Json::Obj(map) = &j {
            for (k, v) in map {
                match (k.as_str(), v) {
                    ("backends", Json::Obj(per)) => {
                        for (name, counts) in per {
                            let slot = backends.entry(name.clone()).or_default();
                            slot.0 +=
                                counts.get("resident").and_then(Json::as_f64).unwrap_or(0.0);
                            slot.1 += counts.get("spilled").and_then(Json::as_f64).unwrap_or(0.0);
                        }
                    }
                    (_, Json::Num(n)) => *totals.entry(k.clone()).or_default() += n,
                    _ => {}
                }
            }
        }
    }
    let mut out: std::collections::BTreeMap<String, Json> =
        totals.into_iter().map(|(k, v)| (k, Json::Num(v))).collect();
    out.insert(
        "backends".to_string(),
        Json::Obj(
            backends
                .into_iter()
                .map(|(name, (r, s))| {
                    (name, obj(vec![("resident", Json::Num(r)), ("spilled", Json::Num(s))]))
                })
                .collect(),
        ),
    );
    out.insert("fleet".to_string(), fleet_stats_json(shared));
    Json::Obj(out)
}

/// The `fleet_stats` reply body: the member table with health and
/// per-member session counts, plus the cumulative router counters.
pub(crate) fn fleet_stats_json(shared: &Shared) -> Json {
    let state = shared.state.lock().expect("fleet state lock");
    let counts = state.session_counts();
    let members = Json::Arr(
        state
            .members
            .iter()
            .enumerate()
            .map(|(i, m)| {
                // age of the last successful probe/request, or null
                // before the first success — reachability staleness at
                // a glance next to the Alive/Suspect/Leaving state
                let last_hb = m
                    .last_ok
                    .map_or(Json::Null, |t| Json::Num(t.elapsed().as_millis() as f64));
                obj(vec![
                    ("addr", Json::Str(m.addr.clone())),
                    ("health", Json::Str(m.health.wire_name().to_string())),
                    ("weight", Json::Num(m.weight as f64)),
                    ("misses", Json::Num(m.misses as f64)),
                    ("last_heartbeat_ms", last_hb),
                    ("sessions", Json::Num(counts[i] as f64)),
                ])
            })
            .collect(),
    );
    let s = &shared.stats;
    obj(vec![
        ("members", members),
        ("placements", Json::Num(state.placement.len() as f64)),
        ("heartbeats", Json::Num(s.heartbeats.load(Ordering::Relaxed) as f64)),
        ("heartbeat_misses", Json::Num(s.heartbeat_misses.load(Ordering::Relaxed) as f64)),
        ("failovers", Json::Num(s.failovers.load(Ordering::Relaxed) as f64)),
        ("failed_over_sessions", Json::Num(s.failed_over_sessions.load(Ordering::Relaxed) as f64)),
        ("failover_resumed", Json::Num(s.failover_resumed.load(Ordering::Relaxed) as f64)),
        ("migrations", Json::Num(s.migrations.load(Ordering::Relaxed) as f64)),
        ("proxied_requests", Json::Num(s.proxied_requests.load(Ordering::Relaxed) as f64)),
        ("routed_sheds", Json::Num(s.routed_sheds.load(Ordering::Relaxed) as f64)),
    ])
}

/// Aggregate `metrics` across every routable member, the fleet way:
/// the log2-bucket histograms merge **bucket-wise** and percentiles are
/// re-derived from the merged buckets — summing or averaging a
/// member's p50/p99 fields would be statistically meaningless.
/// Counters sum. Flight-recorder events are tagged with the member
/// address (each process has its own monotonic epoch, so cross-member
/// timestamps are not comparable — events keep member order rather
/// than pretending to a global clock). The router appends its own
/// proxy/heartbeat/migration histograms and fleet lifecycle events,
/// tagged `"member":"fleet"`.
fn aggregate_metrics(shared: &Shared, conns: &mut ConnCache) -> Json {
    let members: Vec<(usize, String)> = {
        let state = shared.state.lock().expect("fleet state lock");
        state
            .members
            .iter()
            .enumerate()
            .filter(|(_, m)| m.health.routable())
            .map(|(i, m)| (i, m.addr.clone()))
            .collect()
    };
    let mut maps = Vec::new();
    let mut counters: std::collections::BTreeMap<String, f64> = Default::default();
    let mut events: Vec<Json> = Vec::new();
    for (idx, addr) in members {
        let reply = backend(conns, &addr, shared.cfg.io_timeout)
            .and_then(|c| c.call(r#"{"op":"metrics"}"#));
        let j = match reply {
            Ok(j) => j,
            Err(_) => {
                conns.remove(&addr);
                note_data_path_failure(shared, idx);
                continue;
            }
        };
        maps.push(obs::parse_histograms(&j));
        if let Some(Json::Obj(cs)) = j.get("counters") {
            for (k, v) in cs {
                if let Json::Num(n) = v {
                    *counters.entry(k.clone()).or_default() += n;
                }
            }
        }
        if let Some(Json::Arr(evs)) = j.get("events") {
            for e in evs {
                if let Json::Obj(map) = e {
                    let mut map = map.clone();
                    map.insert("member".to_string(), Json::Str(addr.clone()));
                    events.push(Json::Obj(map));
                }
            }
        }
    }
    maps.push(shared.tel.snapshots());
    for e in shared.tel.recorder().recent() {
        if let Json::Obj(mut map) = e.to_json() {
            map.insert("member".to_string(), Json::Str("fleet".to_string()));
            events.push(Json::Obj(map));
        }
    }
    *counters.entry("events_logged".to_string()).or_default() +=
        shared.tel.recorder().logged() as f64;
    *counters.entry("events_dropped".to_string()).or_default() +=
        shared.tel.recorder().dropped() as f64;
    if events.len() > METRICS_MAX_EVENTS {
        events.drain(..events.len() - METRICS_MAX_EVENTS);
    }
    obj(vec![
        ("histograms", obs::histograms_json(&obs::merge_named(maps))),
        ("counters", Json::Obj(counters.into_iter().map(|(k, v)| (k, Json::Num(v))).collect())),
        ("events", Json::Arr(events)),
    ])
}

pub(crate) fn handle_conn(stream: TcpStream, shared: &Arc<Shared>, wake_addr: Option<SocketAddr>) {
    let _ = stream.set_read_timeout(shared.cfg.io_timeout);
    let _ = stream.set_write_timeout(shared.cfg.io_timeout);
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let mut reader = BufReader::new(stream);
    let mut conns: ConnCache = HashMap::new();
    // per-handler injected-failure site: deterministic per (seed, tag),
    // so every connection replays the same drop pattern — the chaos
    // tests rely on that, and real deployments never set the rate
    let mut conn_faults: Option<FaultSite> = shared
        .cfg
        .fault
        .as_ref()
        .filter(|p| p.conn_drop_rate > 0.0)
        .map(|p| p.site("fleet-conn"));
    let max_frame = shared.cfg.max_frame_bytes.max(1);
    loop {
        if shared.shutdown.load(Ordering::Acquire) {
            break;
        }
        let line = match read_frame(&mut reader, max_frame) {
            Frame::Line(l) => l,
            Frame::Eof => break,
            Frame::TooLong => {
                let e = Kinded::frame_too_large(format!(
                    "request frame exceeds the {max_frame}-byte limit"
                ));
                let _ = write_json(&mut writer, &error_body(&e));
                drain_frame_tail(&mut reader);
                break;
            }
        };
        if line.trim().is_empty() {
            continue;
        }
        let alive = handle_line(&line, shared, &mut conns, &mut conn_faults, &mut writer);
        if shared.shutdown.load(Ordering::Acquire) {
            wake_listener(wake_addr);
            break;
        }
        if !alive {
            break;
        }
    }
}

/// Serve one request line; returns whether the connection stays open.
fn handle_line(
    line: &str,
    shared: &Arc<Shared>,
    conns: &mut ConnCache,
    conn_faults: &mut Option<FaultSite>,
    writer: &mut TcpStream,
) -> bool {
    let mut j = match Json::parse(line) {
        Ok(j) => j,
        Err(e) => return write_json(writer, &error_body(&anyhow!("bad request JSON: {e}"))),
    };
    let op = match j.get("op").and_then(Json::as_str) {
        Some(op) => op.to_string(),
        None => return write_json(writer, &error_body(&anyhow!("request needs an \"op\" field"))),
    };
    match op.as_str() {
        // liveness probe: answered by the router itself so health
        // checks of the router never depend on backend health
        "ping" => write_json(writer, &obj(vec![("ok", Json::Bool(true))])),
        "fleet_stats" => write_json(writer, &fleet_stats_json(shared)),
        "fleet_join" => {
            let (addr, weight) = match j.get("addr").and_then(Json::as_str) {
                Some(a) => (
                    a.to_string(),
                    j.get("weight").and_then(Json::as_f64).map_or(1, |w| w.max(1.0) as u32),
                ),
                None => {
                    return write_json(
                        writer,
                        &error_body(&anyhow!("fleet_join needs an \"addr\" field")),
                    )
                }
            };
            let members = {
                let mut state = shared.state.lock().expect("fleet state lock");
                state.join(&addr, weight);
                state.members.len()
            };
            write_json(
                writer,
                &obj(vec![("ok", Json::Bool(true)), ("members", Json::Num(members as f64))]),
            )
        }
        "fleet_leave" => {
            let addr = match j.get("addr").and_then(Json::as_str) {
                Some(a) => a.to_string(),
                None => {
                    return write_json(
                        writer,
                        &error_body(&anyhow!("fleet_leave needs an \"addr\" field")),
                    )
                }
            };
            let draining = {
                let mut state = shared.state.lock().expect("fleet state lock");
                let idx = state.leave(&addr);
                idx.map(|i| state.sessions_of(i).len())
            };
            match draining {
                Some(k) => write_json(
                    writer,
                    &obj(vec![("ok", Json::Bool(true)), ("draining", Json::Num(k as f64))]),
                ),
                None => write_json(writer, &error_body(&anyhow!("no fleet member at {addr}"))),
            }
        }
        "stats" => {
            let agg = aggregate_stats(shared, conns);
            write_json(writer, &agg)
        }
        // fleet-aware like `stats`: fan out, merge buckets, re-derive
        // percentiles (must be an explicit arm — the id-routed default
        // below would reject it for lacking an "id")
        "metrics" => {
            let agg = aggregate_metrics(shared, conns);
            write_json(writer, &agg)
        }
        "shutdown" => {
            // best-effort fan-out so `shutdown` through the fleet means
            // what it means against a single server: everything stops
            let members: Vec<String> = {
                let state = shared.state.lock().expect("fleet state lock");
                state
                    .members
                    .iter()
                    .filter(|m| m.health.routable())
                    .map(|m| m.addr.clone())
                    .collect()
            };
            for addr in members {
                if let Ok(conn) = backend(conns, &addr, shared.cfg.io_timeout) {
                    let _ = conn.call_line(r#"{"op":"shutdown"}"#);
                }
            }
            shared.shutdown.store(true, Ordering::Release);
            write_json(writer, &obj(vec![("ok", Json::Bool(true))]));
            false
        }
        "create" | "restore" => {
            // fleet-unique id: inject one unless the client chose its own
            let id = match j.get("id").and_then(Json::as_f64) {
                Some(n) => {
                    let id = n as u64;
                    shared.reserve_id(id);
                    id
                }
                None => {
                    let id = shared.assign_id();
                    if let Json::Obj(map) = &mut j {
                        map.insert("id".to_string(), Json::Num(id as f64));
                    }
                    id
                }
            };
            {
                let state = shared.state.lock().expect("fleet state lock");
                if state.placement.contains_key(&id) {
                    return write_json(
                        writer,
                        &error_body(&anyhow!("session {id} already exists")),
                    );
                }
            }
            let (idx, addr) = {
                let state = shared.state.lock().expect("fleet state lock");
                match state.ring.lookup(id) {
                    Some(m) if state.members[m].health.routable() => {
                        (m, state.members[m].addr.clone())
                    }
                    _ => return write_shed(writer, shared, "fleet has no live members"),
                }
            };
            forward(shared, conns, conn_faults, writer, (idx, &addr), &j.to_string(), |ok| {
                if ok {
                    let mut state = shared.state.lock().expect("fleet state lock");
                    state.placement.insert(id, Placement::Assigned(idx));
                }
            })
        }
        // every id-bearing data op (step/steps/snapshot/close/drain/…)
        // routes by id — unknown ops forward too, so backend protocol
        // growth does not require fleet releases
        _ => {
            let Some(id) = j.get("id").and_then(Json::as_f64).map(|n| n as u64) else {
                return write_json(
                    writer,
                    &error_body(&anyhow!("unknown fleet op {op:?} without an \"id\" to route by")),
                );
            };
            let (idx, addr) = match route_id(shared, id) {
                Route::To(idx, addr) => (idx, addr),
                Route::Shed(msg) => return write_shed(writer, shared, msg),
            };
            let closing = op == "close";
            forward(shared, conns, conn_faults, writer, (idx, &addr), line, |ok| {
                if ok && closing {
                    let mut state = shared.state.lock().expect("fleet state lock");
                    state.placement.remove(&id);
                }
            })
        }
    }
}

/// Forward one request to a backend, relay the reply (streamed lines
/// included), run `on_done(reply_was_ok)` and translate backend-side
/// transport failures into a shed + health miss.
fn forward(
    shared: &Arc<Shared>,
    conns: &mut ConnCache,
    conn_faults: &mut Option<FaultSite>,
    writer: &mut TcpStream,
    (idx, addr): (usize, &str),
    line: &str,
    on_done: impl FnOnce(bool),
) -> bool {
    shared.stats.proxied_requests.fetch_add(1, Ordering::Relaxed);
    let dropped = conn_faults.as_mut().is_some_and(|site| site.maybe_drop_conn());
    let outcome = if dropped {
        conns.remove(addr);
        Err(anyhow!("injected fault: backend connection dropped"))
    } else {
        // the proxy hop: connect-or-reuse + forward + full reply relay
        crate::obs::span!(shared.tel, Stage::FleetProxy);
        relay(conns, addr, shared.cfg.io_timeout, line, writer)
    };
    match outcome {
        Ok((client_alive, last)) => {
            let ok = last.as_ref().is_some_and(|r| wire_error(r).is_none());
            on_done(ok);
            client_alive
        }
        Err(_) => {
            conns.remove(addr);
            note_data_path_failure(shared, idx);
            on_done(false);
            write_shed(writer, shared, &format!("backend {addr} unreachable — retry"))
        }
    }
}
