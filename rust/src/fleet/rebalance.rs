//! The fleet control plane: one maintenance thread that probes member
//! health, replays a dead member's sessions onto survivors, and drains
//! planned membership changes under a bounded per-tick budget.
//!
//! ## Failover replay (unplanned death)
//!
//! The spill tier makes this possible without any cooperation from the
//! dead process: every TTL/LRU eviction (and every graceful shutdown)
//! already wrote the session's snapshot to the shared `--spill-dir`
//! with crash-safe tmp-then-rename discipline. But a subtlety of
//! `DirStore` shapes the design: each store instance mirrors the
//! directory into an in-memory index **at open time** and never
//! re-scans, so a file spilled by process A is invisible to process
//! B's already-open store. Survivors therefore cannot lazily restore a
//! victim's sessions — the router must replay them actively. On death
//! it opens a **fresh** `DirStore` view (fresh index = sees every
//! file), reads each affected session's blob, and issues an
//! explicit-id `restore` to the session's new ring owner. The
//! survivor's duplicate check is index-based too, so the restore is
//! accepted. The source file is deliberately left in place — deleting
//! it would race the survivor's own later re-spill of the same id.
//!
//! While a session is being replayed its placement is `Moving`, so the
//! proxy sheds requests on it with `overloaded` + a retry hint instead
//! of racing the replay to a stale answer. Sessions with no snapshot
//! on disk (never idle long enough to spill, or their blob was torn)
//! lose their placement: later requests route by ring to a backend
//! that answers a structured `no_session`/`corrupt_snapshot` — the
//! "dies with a structured kind" half of the acceptance dichotomy.
//!
//! ## Budgeted migration (planned change)
//!
//! One rule covers join, leave and weight changes alike: each tick,
//! migrate up to `migrate_budget` sessions whose current placement
//! disagrees with the ring (`drain` → `snapshot` → `restore` →
//! `close`, with the `Moving` marker shed-guarding the whole leg). The
//! drain-first ordering matters: `drain` executes on the source's own
//! executor queue, **after** any in-flight ops on the session, so the
//! snapshot that follows can never miss a token that was already
//! acknowledged to a client.

use std::sync::atomic::Ordering;
use std::sync::Arc;

use crate::fault::FaultSite;
use crate::obs::Stage;
use crate::persist::{DirStore, SnapshotStore};
use crate::util::b64;

use super::member::Placement;
use super::proxy::{backend, BackendConn, ConnCache};
use super::ring::Ring;
use super::Shared;

pub(crate) fn maintenance_loop(shared: &Arc<Shared>) {
    let mut hb_faults: Option<FaultSite> = shared
        .cfg
        .fault
        .as_ref()
        .filter(|p| p.heartbeat_drop_rate > 0.0)
        .map(|p| p.site("fleet-hb"));
    loop {
        std::thread::sleep(shared.cfg.hb_interval);
        if shared.shutdown.load(Ordering::Acquire) {
            return;
        }
        heartbeat_tick(shared, &mut hb_faults);
        if shared.shutdown.load(Ordering::Acquire) {
            return;
        }
        migrate_tick(shared);
    }
}

/// Probe every non-dead member with a `ping` on a fresh connection
/// (a fresh connect is itself part of the liveness evidence). Probe
/// failures feed the `Alive → Suspect → Dead` escalator; crossing the
/// death threshold triggers failover replay.
fn heartbeat_tick(shared: &Arc<Shared>, hb_faults: &mut Option<FaultSite>) {
    let probes: Vec<(usize, String)> = {
        let state = shared.state.lock().expect("fleet state lock");
        state
            .members
            .iter()
            .enumerate()
            .filter(|(_, m)| m.health.routable())
            .map(|(i, m)| (i, m.addr.clone()))
            .collect()
    };
    for (idx, addr) in probes {
        shared.stats.heartbeats.fetch_add(1, Ordering::Relaxed);
        let dropped = hb_faults.as_mut().is_some_and(|site| site.maybe_drop_heartbeat());
        let ok = !dropped && {
            // a dropped probe never reaches the wire, so it does not
            // belong in the heartbeat latency histogram
            crate::obs::span!(shared.tel, Stage::FleetHeartbeat);
            BackendConn::connect(&addr, Some(shared.cfg.hb_timeout))
                .and_then(|mut c| c.call(r#"{"op":"ping"}"#))
                .is_ok()
        };
        let died = {
            let mut state = shared.state.lock().expect("fleet state lock");
            if ok {
                state.note_success(idx);
                false
            } else {
                shared.stats.heartbeat_misses.fetch_add(1, Ordering::Relaxed);
                state.note_failure(idx, shared.cfg.hb_misses)
            }
        };
        if died {
            // the event id is the member INDEX (stable across the
            // append-only member table), not a session id
            shared.tel.event("member_dead", idx as u64);
            eprintln!("[fleet] member {addr} declared dead after {} misses", shared.cfg.hb_misses);
            failover(shared, idx);
        }
    }
}

/// The new ring owner's address for `id`, if it is routable right now.
fn replay_target(shared: &Shared, id: u64, ring: &Ring) -> Option<(usize, String)> {
    let state = shared.state.lock().expect("fleet state lock");
    let idx = ring.lookup(id)?;
    let m = state.members.get(idx)?;
    m.health.routable().then(|| (idx, m.addr.clone()))
}

/// Replay every session the dead member owned from the shared spill
/// dir onto its new ring owner. Never budget-limited: until a session
/// is replayed it answers sheds, so dragging the replay out would
/// trade correctness pressure for smoothness nobody gets.
fn failover(shared: &Arc<Shared>, dead_idx: usize) {
    shared.stats.failovers.fetch_add(1, Ordering::Relaxed);
    let (ids, ring) = {
        let mut state = shared.state.lock().expect("fleet state lock");
        let ids = state.sessions_of(dead_idx);
        for &id in &ids {
            state.placement.insert(id, Placement::Moving);
        }
        (ids, state.ring.clone())
    };
    shared.stats.failed_over_sessions.fetch_add(ids.len() as u64, Ordering::Relaxed);
    if ids.is_empty() {
        return;
    }
    // a FRESH store view: the dead member's spill files landed after
    // any longer-lived index was mirrored, so only a fresh open sees
    // them (see the module docs)
    let mut store = match shared.cfg.spill_dir.as_deref().map(DirStore::open) {
        Some(Ok(store)) => Some(store),
        Some(Err(e)) => {
            eprintln!("[fleet] failover cannot open spill dir: {e:#}");
            None
        }
        None => None,
    };
    let mut conns: std::collections::HashMap<String, BackendConn> = Default::default();
    let mut resumed = 0usize;
    for id in &ids {
        let replayed = store
            .as_mut()
            .and_then(|s| s.get(*id).ok().flatten())
            .and_then(|blob| {
                let (target, addr) = replay_target(shared, *id, &ring)?;
                let line =
                    format!(r#"{{"op":"restore","id":{id},"state":"{}"}}"#, b64::encode(&blob));
                let conn = match conns.entry(addr.clone()) {
                    std::collections::hash_map::Entry::Occupied(e) => e.into_mut(),
                    std::collections::hash_map::Entry::Vacant(e) => e.insert(
                        BackendConn::connect(&addr, Some(shared.cfg.hb_timeout)).ok()?,
                    ),
                };
                match conn.call(&line) {
                    Ok(_) => Some(target),
                    Err(e) => {
                        eprintln!("[fleet] failover restore of session {id} on {addr}: {e:#}");
                        conns.remove(&addr);
                        None
                    }
                }
            });
        let mut state = shared.state.lock().expect("fleet state lock");
        match replayed {
            Some(target) => {
                state.placement.insert(*id, Placement::Assigned(target));
                shared.tel.event("failover", *id);
                resumed += 1;
            }
            // no snapshot (or no survivor): the id's future requests
            // ring-route to a backend that answers a structured kind
            None => {
                state.placement.remove(id);
            }
        }
    }
    shared.stats.failover_resumed.fetch_add(resumed as u64, Ordering::Relaxed);
    eprintln!("[fleet] failover: resumed {resumed}/{} sessions from spill", ids.len());
}

/// One migration candidate chosen under the lock.
struct Move {
    id: u64,
    src_idx: usize,
    src: String,
    dst_idx: usize,
    dst: String,
}

/// Migrate up to `migrate_budget` sessions whose placement disagrees
/// with the ring — the single rule that serves join, leave and weight
/// changes. The budget bounds how much foreground capacity one tick
/// of rebalancing may consume.
fn migrate_tick(shared: &Arc<Shared>) {
    let moves: Vec<Move> = {
        let mut state = shared.state.lock().expect("fleet state lock");
        let budget = shared.cfg.migrate_budget.max(1);
        let mut picked = Vec::new();
        for (&id, p) in &state.placement {
            if picked.len() >= budget {
                break;
            }
            let Placement::Assigned(src_idx) = *p else { continue };
            let Some(src) = state.members.get(src_idx) else { continue };
            // dead owners are failover's job, unreachable ones heal or die
            if !src.health.routable() {
                continue;
            }
            let Some(dst_idx) = state.ring.lookup(id) else { continue };
            if dst_idx == src_idx || !state.members[dst_idx].health.in_ring() {
                continue;
            }
            picked.push(Move {
                id,
                src_idx,
                src: src.addr.clone(),
                dst_idx,
                dst: state.members[dst_idx].addr.clone(),
            });
        }
        for m in &picked {
            state.placement.insert(m.id, Placement::Moving);
        }
        picked
    };
    if moves.is_empty() {
        return;
    }
    let mut conns: std::collections::HashMap<String, BackendConn> = Default::default();
    for mv in moves {
        let moved = migrate_one(shared, &mut conns, &mv);
        let mut state = shared.state.lock().expect("fleet state lock");
        match moved {
            Ok(()) => {
                state.placement.insert(mv.id, Placement::Assigned(mv.dst_idx));
                shared.stats.migrations.fetch_add(1, Ordering::Relaxed);
                shared.tel.event("migrate", mv.id);
            }
            Err(e) => {
                eprintln!("[fleet] migration of session {} {}→{}: {e:#}", mv.id, mv.src, mv.dst);
                // revert: the source still owns a perfectly good copy;
                // a later tick retries
                state.placement.insert(mv.id, Placement::Assigned(mv.src_idx));
            }
        }
    }
}

/// One session's migration leg: drain (order barrier + spill), then
/// snapshot from the source, restore onto the target, close the
/// source's copy.
fn migrate_one(shared: &Arc<Shared>, conns: &mut ConnCache, mv: &Move) -> anyhow::Result<()> {
    crate::obs::span!(shared.tel, Stage::FleetMigrate);
    let timeout = shared.cfg.io_timeout.or(Some(shared.cfg.hb_timeout));
    let src = backend(conns, &mv.src, timeout)?;
    // the drain doubles as an ordering barrier: it runs on the source's
    // executor after every in-flight op on this session. A server
    // without a spill tier refuses the spill but still provides the
    // barrier, and the snapshot below works either way.
    let _ = src.call(&format!(r#"{{"op":"drain","id":{}}}"#, mv.id));
    let snap = src.call(&format!(r#"{{"op":"snapshot","id":{}}}"#, mv.id))?;
    let state = snap
        .get("state")
        .and_then(crate::util::json::Json::as_str)
        .ok_or_else(|| anyhow::anyhow!("snapshot reply without a state field"))?
        .to_string();
    let dst = backend(conns, &mv.dst, timeout)?;
    dst.call(&format!(r#"{{"op":"restore","id":{},"state":"{state}"}}"#, mv.id))?;
    // free the source's copy (resident or spilled) — best effort; a
    // leaked spilled blob is re-spilled over by the new owner later
    let src = backend(conns, &mv.src, timeout)?;
    let _ = src.call(&format!(r#"{{"op":"close","id":{}}}"#, mv.id));
    Ok(())
}
