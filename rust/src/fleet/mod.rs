//! Fleet mode — consistent-hash routing over N `aaren serve` backends
//! with failure detection and bitwise failover.
//!
//! The paper's constant-memory serving claim (§3.3) makes a session
//! cheap to *move*: its whole state is a tiny versioned blob that the
//! `snapshot`/`restore` wire ops already migrate bitwise between live
//! processes, and the spill tier already persists it crash-safely. The
//! fleet router is the thin layer that turns those primitives into
//! process-loss tolerance:
//!
//! * [`ring`] — a deterministic weighted-vnode consistent-hash ring
//!   assigns every session id to one backend; removing a member moves
//!   only that member's sessions.
//! * [`member`] — the membership table with per-member health
//!   (`Alive` → `Suspect` → `Dead`, one way) and the session placement
//!   map.
//! * [`proxy`] — per-connection handlers speak the *same* line-JSON
//!   wire protocol as a single server and relay each request to the
//!   owning backend (injecting fleet-unique session ids into
//!   `create`/`restore` so backends sharing one spill dir never
//!   collide). Backend failures answer as structured `overloaded`
//!   + `retry_after_ms` — the client's existing back-off loop rides
//!   out a failover without new client code.
//! * [`rebalance`] — the maintenance loop: heartbeat (`ping`) probes
//!   feed the health state machine; a death triggers **failover
//!   replay** (the dead member's sessions are re-read from the shared
//!   `--spill-dir` and `restore`d onto the surviving ring owners); a
//!   planned `fleet_join`/`fleet_leave` triggers **live rebalancing**
//!   (drain → snapshot → restore → close per session) under a bounded
//!   per-tick migration budget so rebalancing never starves foreground
//!   traffic.
//!
//! The acceptance bar (ROADMAP item 6, `tests/chaos.rs`): three
//! backends under concurrent multi-kernel load, SIGKILL one, and every
//! stream either resumes bitwise on a survivor or answers a structured
//! error kind — never silent corruption.
//!
//! Fleet-specific wire ops (everything else proxies through):
//!
//! ```text
//! -> {"op":"ping"}                              <- {"ok":true}        (answered locally)
//! -> {"op":"fleet_stats"}                       <- {"members":[...],"failovers":F,...}
//! -> {"op":"fleet_join","addr":A[,"weight":W]}  <- {"ok":true,"members":N}
//! -> {"op":"fleet_leave","addr":A}              <- {"ok":true,"draining":K}
//! -> {"op":"metrics"}                           <- {"histograms":{...},"counters":{...},...}
//! ```
//!
//! `metrics` is fleet-aware like `stats`: the router fans it out to
//! every routable member, merges the log2-bucket histograms
//! **bucket-wise** (percentiles re-derived from the merged buckets,
//! never averaged), and appends its own `fleet_proxy` /
//! `fleet_heartbeat` / `fleet_migrate` timings and flight-recorder
//! events.
//!
//! Caveat (documented, not defended): the placement map lives in the
//! router, so a router restart forgets which backend spilled which
//! session. Ring routing still finds every session the ring owner
//! itself spilled; a session spilled by a *different* backend before
//! the restart answers `no_session` (structured) until re-created.

pub mod member;
pub mod proxy;
pub mod rebalance;
pub mod ring;

use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{bail, Result};

use crate::fault::FaultPlan;
use crate::obs::Telemetry;
use crate::persist::{DirStore, SnapshotStore};
use crate::serve::server::accept_backoff;
use crate::util::rng::Rng;

pub use member::{FleetState, Health, Member, Placement};
pub use ring::{hash64, hash_str, Ring, RingEntry, DEFAULT_VNODES_PER_WEIGHT};

#[derive(Debug, Clone)]
pub struct FleetConfig {
    /// router listen address
    pub addr: String,
    /// backend addresses (`aaren serve` processes)
    pub members: Vec<String>,
    /// per-member ring weights, parallel to `members`; missing entries
    /// default to 1
    pub weights: Vec<u32>,
    /// the spill directory SHARED with every backend — the failover
    /// replay source. Without it a dead member's sessions are lost
    /// (structured `no_session`), not resumed.
    pub spill_dir: Option<PathBuf>,
    /// heartbeat probe period
    pub hb_interval: Duration,
    /// per-probe connect/read/write timeout
    pub hb_timeout: Duration,
    /// consecutive misses before a member is declared dead
    pub hb_misses: u32,
    /// max sessions migrated per maintenance tick (planned rebalancing
    /// only; failover replay is never budget-limited)
    pub migrate_budget: usize,
    /// ring points per unit of member weight
    pub vnodes_per_weight: usize,
    /// request-line size cap on client connections
    pub max_frame_bytes: usize,
    /// per-connection read/write timeout on client connections; also
    /// applied to proxied backend connections
    pub io_timeout: Option<Duration>,
    /// seeded fault injection (`hb-drop` / `conn-drop` sites)
    pub fault: Option<FaultPlan>,
}

impl Default for FleetConfig {
    fn default() -> FleetConfig {
        FleetConfig {
            addr: "127.0.0.1:7979".to_string(),
            members: Vec::new(),
            weights: Vec::new(),
            spill_dir: None,
            hb_interval: Duration::from_millis(500),
            hb_timeout: Duration::from_millis(1000),
            hb_misses: 3,
            migrate_budget: 8,
            vnodes_per_weight: DEFAULT_VNODES_PER_WEIGHT,
            max_frame_bytes: 16 << 20,
            io_timeout: None,
            fault: None,
        }
    }
}

/// Cumulative fleet counters, reported by `fleet_stats` (and the
/// `fleet` section of an aggregated `stats` reply).
#[derive(Debug, Default)]
pub struct FleetStats {
    /// heartbeat probes sent (dropped-by-fault probes count as sent)
    pub heartbeats: AtomicU64,
    /// probes that failed or were dropped
    pub heartbeat_misses: AtomicU64,
    /// members declared dead
    pub failovers: AtomicU64,
    /// sessions owned by dead members at their death
    pub failed_over_sessions: AtomicU64,
    /// of those, sessions successfully replayed onto a survivor
    pub failover_resumed: AtomicU64,
    /// sessions moved by planned rebalancing
    pub migrations: AtomicU64,
    /// client requests relayed to a backend
    pub proxied_requests: AtomicU64,
    /// client requests answered `overloaded` by the router itself
    /// (unreachable backend, mid-migration session, empty ring)
    pub routed_sheds: AtomicU64,
}

/// Everything the proxy handlers and the maintenance thread share.
pub(crate) struct Shared {
    pub cfg: FleetConfig,
    pub state: Mutex<FleetState>,
    pub stats: FleetStats,
    /// fleet-assigned session ids: globally unique across every backend
    /// sharing the spill dir (seeded past any surviving snapshot files)
    pub next_id: AtomicU64,
    pub shutdown: AtomicBool,
    /// the router's own telemetry domain: the proxy hop, heartbeat and
    /// migration-leg histograms plus the fleet flight recorder. The
    /// `metrics` op merges this with every member's reply.
    pub tel: Arc<Telemetry>,
}

impl Shared {
    /// Claim a fresh fleet-unique session id.
    pub fn assign_id(&self) -> u64 {
        self.next_id.fetch_add(1, Ordering::Relaxed)
    }

    /// An explicit client-chosen id passed through: keep the assigner
    /// ahead of it so later assignments never collide.
    pub fn reserve_id(&self, id: u64) {
        self.next_id.fetch_max(id.saturating_add(1), Ordering::Relaxed);
    }
}

/// A bound fleet router: the listener plus the shared routing state.
/// `run` serves until a `shutdown` request arrives (which is also
/// forwarded to every routable backend).
pub struct Fleet {
    listener: TcpListener,
    shared: Arc<Shared>,
}

impl Fleet {
    pub fn bind(cfg: &FleetConfig) -> Result<Fleet> {
        if cfg.members.is_empty() {
            bail!("fleet needs at least one --members backend address");
        }
        let listener = TcpListener::bind(cfg.addr.as_str())?;
        let state = FleetState::new(&cfg.members, &cfg.weights, cfg.vnodes_per_weight);
        // seed the id assigner past every snapshot already on disk so a
        // router restart cannot hand out an id that collides with a
        // live spilled session
        let mut next = 1u64;
        if let Some(dir) = &cfg.spill_dir {
            if let Ok(store) = DirStore::open(dir) {
                next = store.ids().into_iter().max().map_or(1, |m| m + 1);
            }
        }
        Ok(Fleet {
            listener,
            shared: Arc::new(Shared {
                cfg: cfg.clone(),
                state: Mutex::new(state),
                stats: FleetStats::default(),
                next_id: AtomicU64::new(next),
                shutdown: AtomicBool::new(false),
                tel: Arc::new(Telemetry::new(true)),
            }),
        })
    }

    pub fn local_addr(&self) -> Result<SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Accept client connections (one handler thread each) and run the
    /// maintenance loop (heartbeats, failover, migration) until
    /// shutdown.
    pub fn run(&self) -> Result<()> {
        let wake_addr = self.listener.local_addr().ok();
        let maint = {
            let shared = Arc::clone(&self.shared);
            std::thread::spawn(move || rebalance::maintenance_loop(&shared))
        };
        let mut backoff_rng = Rng::new(0x0F1E_E7AC);
        let mut consecutive_errors = 0u32;
        for stream in self.listener.incoming() {
            if self.shared.shutdown.load(Ordering::Acquire) {
                break;
            }
            match stream {
                Ok(s) => {
                    consecutive_errors = 0;
                    let shared = Arc::clone(&self.shared);
                    std::thread::spawn(move || proxy::handle_conn(s, &shared, wake_addr));
                }
                Err(e) => {
                    consecutive_errors = consecutive_errors.saturating_add(1);
                    eprintln!("[fleet] accept error: {e}");
                    std::thread::sleep(accept_backoff(consecutive_errors, &mut backoff_rng));
                }
            }
        }
        let _ = maint.join();
        Ok(())
    }
}

/// Wake a blocked accept loop after the shutdown flag is set: the
/// listener's own address is connectable unless bound to the
/// unspecified address, which rewrites to its loopback.
pub(crate) fn wake_listener(addr: Option<SocketAddr>) {
    if let Some(mut addr) = addr {
        if addr.ip().is_unspecified() {
            addr.set_ip(match addr.ip() {
                std::net::IpAddr::V4(_) => std::net::IpAddr::V4(std::net::Ipv4Addr::LOCALHOST),
                std::net::IpAddr::V6(_) => std::net::IpAddr::V6(std::net::Ipv6Addr::LOCALHOST),
            });
        }
        let _ = TcpStream::connect(addr);
    }
}

/// Serve forever on `cfg.addr`, with the standard banner.
pub fn serve_fleet(cfg: &FleetConfig) -> Result<()> {
    let fleet = Fleet::bind(cfg)?;
    let spill = match &cfg.spill_dir {
        Some(dir) => format!("failover replay from {}", dir.display()),
        None => "NO spill dir — dead members lose their sessions".to_string(),
    };
    let fault = match &cfg.fault {
        Some(p) if p.is_active() => format!("; FAULT INJECTION ACTIVE (seed {})", p.seed),
        _ => String::new(),
    };
    println!(
        "[fleet] listening on {} ({} member(s); heartbeat every {}ms, timeout {}ms, \
         dead after {} misses; {spill}; migrate budget {}/tick{fault}; \
         line-delimited JSON; extra ops: ping/fleet_stats/fleet_join/fleet_leave/metrics)",
        fleet.local_addr()?,
        cfg.members.len(),
        cfg.hb_interval.as_millis(),
        cfg.hb_timeout.as_millis(),
        cfg.hb_misses.max(1),
        cfg.migrate_budget.max(1),
    );
    fleet.run()
}
