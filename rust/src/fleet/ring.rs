//! Consistent-hash ring with weighted virtual nodes.
//!
//! The ring is a sorted list of hash points; each fleet member
//! contributes `weight × vnodes_per_weight` points derived from its
//! stable key, and a session id is owned by the member whose point is
//! the first at-or-after `hash64(id)` (wrapping). The properties the
//! fleet leans on, each pinned by a test below:
//!
//! * **deterministic** — the ring is a pure function of the member set,
//!   so every router (and every restart) routes identically;
//! * **balanced** — vnodes smear each member over the keyspace, so
//!   equal weights get roughly equal session shares;
//! * **weighted** — a weight-2 member draws roughly twice the sessions
//!   of a weight-1 member;
//! * **minimally disruptive** — removing a member reassigns only the
//!   sessions it owned; everyone else's placement is untouched (the
//!   property that makes failover replay O(victim), not O(fleet)).

/// SplitMix64-style avalanche over one u64 — the same mixer the seeded
/// [`crate::util::rng::Rng`] stream uses, applied here as a stateless
/// hash. The pre-add breaks the `hash64(0) == 0` fixed point of the
/// bare finalizer.
pub fn hash64(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// FNV-1a over a string — the member key for an address like
/// `"10.0.0.7:7878"`. Stable across processes and restarts.
pub fn hash_str(s: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// How many ring points one unit of member weight contributes. 64
/// points per weight keeps the expected share within a few percent of
/// proportional for single-digit fleets without making ring rebuilds
/// (a binary-searchable sort of members × weight × 64 points) costly.
pub const DEFAULT_VNODES_PER_WEIGHT: usize = 64;

/// One ring entry for [`Ring::build`]: the member's stable hash key
/// (from [`hash_str`] of its address), its weight, and the caller's
/// member index returned by lookups.
#[derive(Debug, Clone, Copy)]
pub struct RingEntry {
    pub key: u64,
    pub weight: u32,
    pub idx: usize,
}

/// The immutable ring: rebuilt from scratch on every membership change
/// (membership changes are rare and fleets are small; determinism and
/// simplicity beat incremental updates here).
#[derive(Debug, Clone, Default)]
pub struct Ring {
    /// (point, member idx), sorted by point; ties (cosmically unlikely)
    /// break by idx so the ring is still a pure function of its input
    points: Vec<(u64, usize)>,
}

impl Ring {
    pub fn build(entries: &[RingEntry], vnodes_per_weight: usize) -> Ring {
        let per_weight = vnodes_per_weight.max(1);
        let mut points = Vec::new();
        for e in entries {
            for v in 0..(e.weight.max(1) as usize * per_weight) {
                // mix the vnode ordinal into the member key so a
                // member's points scatter instead of clustering
                points.push((hash64(e.key ^ hash64(v as u64)), e.idx));
            }
        }
        points.sort_unstable();
        Ring { points }
    }

    /// The member owning session `id`, or `None` on an empty ring.
    pub fn lookup(&self, id: u64) -> Option<usize> {
        if self.points.is_empty() {
            return None;
        }
        let h = hash64(id);
        let at = self.points.partition_point(|&(p, _)| p < h);
        // past the last point wraps to the first — it's a ring
        Some(self.points[at % self.points.len()].1)
    }

    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    pub fn len(&self) -> usize {
        self.points.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entries(weights: &[u32]) -> Vec<RingEntry> {
        weights
            .iter()
            .enumerate()
            .map(|(i, &w)| RingEntry {
                key: hash_str(&format!("127.0.0.1:{}", 9000 + i)),
                weight: w,
                idx: i,
            })
            .collect()
    }

    fn shares(ring: &Ring, members: usize, ids: u64) -> Vec<usize> {
        let mut counts = vec![0usize; members];
        for id in 1..=ids {
            counts[ring.lookup(id).unwrap()] += 1;
        }
        counts
    }

    #[test]
    fn empty_ring_routes_nothing() {
        let ring = Ring::build(&[], DEFAULT_VNODES_PER_WEIGHT);
        assert!(ring.is_empty());
        assert_eq!(ring.lookup(7), None);
    }

    #[test]
    fn lookups_are_deterministic_across_builds() {
        let a = Ring::build(&entries(&[1, 1, 1]), DEFAULT_VNODES_PER_WEIGHT);
        let b = Ring::build(&entries(&[1, 1, 1]), DEFAULT_VNODES_PER_WEIGHT);
        assert_eq!(a.len(), 3 * DEFAULT_VNODES_PER_WEIGHT);
        for id in 1..2000u64 {
            assert_eq!(a.lookup(id), b.lookup(id));
        }
    }

    #[test]
    fn equal_weights_share_the_keyspace_roughly_equally() {
        let ring = Ring::build(&entries(&[1, 1, 1]), DEFAULT_VNODES_PER_WEIGHT);
        let counts = shares(&ring, 3, 10_000);
        for (i, &c) in counts.iter().enumerate() {
            // perfect balance is ~3333 each; vnode smearing keeps every
            // member within a generous band of it
            assert!((1800..=5200).contains(&c), "member {i} got {c} of 10000");
        }
    }

    #[test]
    fn weight_two_draws_roughly_twice_the_sessions() {
        let ring = Ring::build(&entries(&[2, 1, 1]), DEFAULT_VNODES_PER_WEIGHT);
        let counts = shares(&ring, 3, 10_000);
        let heavy = counts[0] as f64;
        let light = (counts[1] + counts[2]) as f64 / 2.0;
        let ratio = heavy / light;
        assert!((1.3..=3.0).contains(&ratio), "weight-2/weight-1 ratio {ratio:.2}");
    }

    #[test]
    fn removing_a_member_moves_only_its_own_keys() {
        let all = entries(&[1, 1, 1]);
        let full = Ring::build(&all, DEFAULT_VNODES_PER_WEIGHT);
        let without_2 = Ring::build(&all[..2], DEFAULT_VNODES_PER_WEIGHT);
        let mut moved_foreign = 0;
        for id in 1..=10_000u64 {
            let before = full.lookup(id).unwrap();
            let after = without_2.lookup(id).unwrap();
            if before != 2 {
                // a key the dead member never owned must not move
                if before != after {
                    moved_foreign += 1;
                }
            } else {
                // the dead member's keys all land on a survivor
                assert!(after < 2, "orphaned key {id} routed to the removed member");
            }
        }
        assert_eq!(moved_foreign, 0, "{moved_foreign} keys moved without their owner dying");
    }
}
