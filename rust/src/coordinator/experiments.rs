//! Per-domain experiment drivers: each function trains one model variant
//! on one synthetic dataset and returns the paper's metrics for that
//! table cell. The bench harnesses (rust/benches/, `aaren bench …`) sweep
//! these over datasets × models × seeds to regenerate Tables 1–5.

use anyhow::Result;

use crate::coordinator::{Evaluator, Trainer};
use crate::data::{events, rl, tsc, tsf};
use crate::metrics::{self, SumMetric};
use crate::runtime::exec::{Engine, HostTensor};
use crate::util::rng::Rng;

/// Model variant under comparison ("aaren" | "tf"), used in artifact names.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kind {
    Aaren,
    Tf,
}

impl Kind {
    pub fn tag(self) -> &'static str {
        match self {
            Kind::Aaren => "aaren",
            Kind::Tf => "tf",
        }
    }

    pub fn display(self) -> &'static str {
        match self {
            Kind::Aaren => "Aaren",
            Kind::Tf => "Transformer",
        }
    }
}

pub const BOTH: [Kind; 2] = [Kind::Aaren, Kind::Tf];

fn f32s(shape: &[usize], data: Vec<f32>) -> HostTensor {
    HostTensor::F32(shape.to_vec(), data)
}

fn i32s(shape: &[usize], data: Vec<i32>) -> HostTensor {
    HostTensor::I32(shape.to_vec(), data)
}

// ---------------------------------------------------------------------------
// Table 3 / Table 5: time-series forecasting

pub struct TsfResult {
    pub mse: f64,
    pub mae: f64,
    pub final_train_loss: f32,
}

pub fn run_tsf(
    engine: &mut Engine,
    kind: Kind,
    ds: tsf::TsfDataset,
    horizon: usize,
    train_steps: usize,
    seed: u64,
) -> Result<TsfResult> {
    let train_mod = engine.load(&format!("tsf_{}_train_T{horizon}", kind.tag()))?;
    let eval_mod = engine.load(&format!("tsf_{}_eval_T{horizon}", kind.tag()))?;
    let b = train_mod.manifest.meta_usize("batch", 16);
    let c = tsf::CHANNELS;

    let series = tsf::generate(ds, 6000, seed);
    let sampler = tsf::WindowSampler::new(series, horizon);
    let mut rng = Rng::new(seed ^ 0x75F0);

    let mut trainer = Trainer::new(train_mod)?;
    for _ in 0..train_steps {
        let (xs, ys) = sampler.train_batch(&mut rng, b);
        trainer.step(&[
            f32s(&[b, tsf::LOOKBACK, c], xs),
            f32s(&[b, horizon, c], ys),
        ])?;
    }

    let trained = trainer.sync_store()?;
    let evaluator = Evaluator::with_trained(
        eval_mod,
        &trainer.module.manifest.params_key,
        &trained,
    )?;
    let mut mse = SumMetric::default();
    let mut mae = SumMetric::default();
    // 4 test batches of b windows each
    let windows = sampler.test_windows(4 * b);
    for chunk in windows.chunks(b) {
        if chunk.len() < b {
            break;
        }
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        for w in chunk {
            xs.extend_from_slice(&w.x);
            ys.extend_from_slice(&w.y);
        }
        let out = evaluator.run_scalars(&[
            f32s(&[b, tsf::LOOKBACK, c], xs),
            f32s(&[b, horizon, c], ys),
        ])?;
        let n = (b * horizon * c) as f64;
        mse.add(out[0] as f64, n);
        mae.add(out[1] as f64, n);
    }
    Ok(TsfResult {
        mse: mse.mean(),
        mae: mae.mean(),
        final_train_loss: trainer.recent_loss(20),
    })
}

// ---------------------------------------------------------------------------
// Table 4: time-series classification

pub struct TscResult {
    pub acc: f64,
    pub final_train_loss: f32,
}

pub fn run_tsc(
    engine: &mut Engine,
    kind: Kind,
    ds: tsc::TscDataset,
    train_steps: usize,
    seed: u64,
) -> Result<TscResult> {
    let train_mod = engine.load(&format!("tsc_{}_train", kind.tag()))?;
    let eval_mod = engine.load(&format!("tsc_{}_eval", kind.tag()))?;
    let b = train_mod.manifest.meta_usize("batch", 16);
    let (n, c) = (tsc::SEQ_LEN, tsc::CHANNELS);

    let gen = tsc::TscGenerator::new(ds, seed);
    let mut rng = Rng::new(seed ^ 0x75C0);

    let mut trainer = Trainer::new(train_mod)?;
    for _ in 0..train_steps {
        let (xs, labels) = gen.batch(&mut rng, b);
        trainer.step(&[f32s(&[b, n, c], xs), i32s(&[b], labels)])?;
    }

    let trained = trainer.sync_store()?;
    let evaluator = Evaluator::with_trained(
        eval_mod,
        &trainer.module.manifest.params_key,
        &trained,
    )?;
    let mut correct = 0.0f64;
    let mut total = 0.0f64;
    let mut test_rng = Rng::new(seed ^ 0xEEE);
    for _ in 0..8 {
        let (xs, labels) = gen.batch(&mut test_rng, b);
        let out = evaluator.run_scalars(&[f32s(&[b, n, c], xs), i32s(&[b], labels)])?;
        correct += out[0] as f64;
        total += b as f64;
    }
    Ok(TscResult {
        acc: 100.0 * correct / total,
        final_train_loss: trainer.recent_loss(20),
    })
}

// ---------------------------------------------------------------------------
// Table 2: event forecasting

pub struct EfResult {
    pub nll: f64,
    pub rmse: f64,
    /// mark accuracy in percent; None for unmarked datasets (Sin/Uber/Taxi)
    pub acc: Option<f64>,
    pub final_train_loss: f32,
}

pub fn run_ef(
    engine: &mut Engine,
    kind: Kind,
    ds: events::EfDataset,
    train_steps: usize,
    seed: u64,
) -> Result<EfResult> {
    let train_mod = engine.load(&format!("ef_{}_train", kind.tag()))?;
    let eval_mod = engine.load(&format!("ef_{}_eval", kind.tag()))?;
    let b = train_mod.manifest.meta_usize("batch", 16);
    let n = events::SEQ_LEN;

    let mut rng = Rng::new(seed ^ 0xEF10);
    let mut trainer = Trainer::new(train_mod)?;
    for _ in 0..train_steps {
        let (times, marks) = events::batch(ds, &mut rng, b);
        trainer.step(&[f32s(&[b, n], times), i32s(&[b, n], marks)])?;
    }

    let trained = trainer.sync_store()?;
    let evaluator = Evaluator::with_trained(
        eval_mod,
        &trainer.module.manifest.params_key,
        &trained,
    )?;
    let mut nll = SumMetric::default();
    let mut se = SumMetric::default();
    let mut correct = SumMetric::default();
    let mut test_rng = Rng::new(seed ^ 0xFFF1);
    for _ in 0..8 {
        let (times, marks) = events::batch(ds, &mut test_rng, b);
        let out = evaluator.run_scalars(&[f32s(&[b, n], times), i32s(&[b, n], marks)])?;
        // outputs: nll_sum, sq_err_sum, correct_marks, n_events
        let cnt = out[3] as f64;
        nll.add(out[0] as f64, cnt);
        se.add(out[1] as f64, cnt);
        correct.add(out[2] as f64, cnt);
    }
    Ok(EfResult {
        nll: nll.mean(),
        rmse: se.rmse(),
        acc: if ds.has_marks() { Some(100.0 * correct.mean()) } else { None },
        final_train_loss: trainer.recent_loss(20),
    })
}

// ---------------------------------------------------------------------------
// Table 1: offline RL (Decision Transformer protocol)

pub struct RlResult {
    pub normalised_score: f64,
    pub raw_return: f64,
    pub final_train_loss: f32,
}

pub fn run_rl(
    engine: &mut Engine,
    kind: Kind,
    env_id: rl::EnvId,
    tier: rl::Tier,
    train_steps: usize,
    episodes: usize,
    eval_rollouts: usize,
    seed: u64,
) -> Result<RlResult> {
    let train_mod = engine.load(&format!("rl_{}_train", kind.tag()))?;
    let act_mod = engine.load(&format!("rl_{}_act", kind.tag()))?;
    let b = train_mod.manifest.meta_usize("batch", 16);
    let (t, s, a) = (rl::CTX, rl::STATE_DIM, rl::ACT_DIM);

    let dataset = rl::generate_dataset(env_id, tier, episodes, seed);
    let mut rng = Rng::new(seed ^ 0x4170);

    let mut trainer = Trainer::new(train_mod)?;
    for _ in 0..train_steps {
        let batch = dataset.sample_batch(&mut rng, b);
        trainer.step(&[
            f32s(&[b, t, 1], batch.rtg),
            f32s(&[b, t, s], batch.states),
            f32s(&[b, t, a], batch.actions),
            i32s(&[b, t], batch.timesteps),
            f32s(&[b, t], batch.mask),
        ])?;
    }

    // Online evaluation: condition on an expert-level return-to-go and
    // roll out in the live environment (Decision Transformer protocol).
    let trained = trainer.sync_store()?;
    let actor = Evaluator::with_trained(
        act_mod,
        &trainer.module.manifest.params_key,
        &trained,
    )?;
    let mut returns = Vec::with_capacity(eval_rollouts);
    for ep in 0..eval_rollouts {
        let ret = rollout_with_model(&actor, env_id, &dataset, seed ^ (0xE0 + ep as u64))?;
        returns.push(ret);
    }
    let mean_return = returns.iter().sum::<f64>() / returns.len().max(1) as f64;
    Ok(RlResult {
        normalised_score: metrics::d4rl_normalised(
            mean_return,
            dataset.random_return,
            dataset.expert_return,
        ),
        raw_return: mean_return,
        final_train_loss: trainer.recent_loss(20),
    })
}

/// One online episode driven by the trained model (context window of the
/// last CTX steps, right-aligned with left padding, rtg-conditioned).
fn rollout_with_model(
    actor: &Evaluator,
    env_id: rl::EnvId,
    dataset: &rl::OfflineDataset,
    seed: u64,
) -> Result<f64> {
    let (t, sdim, adim) = (rl::CTX, rl::STATE_DIM, rl::ACT_DIM);
    let mut env = rl::Env::new(env_id, seed);
    let mut state = env.reset(seed ^ 0x5EED);
    // condition on an expert-level return (the DT evaluation convention)
    let mut rtg = dataset.expert_return;

    let mut hist_states: Vec<Vec<f32>> = Vec::new();
    let mut hist_actions: Vec<Vec<f32>> = Vec::new();
    let mut hist_rtg: Vec<f64> = Vec::new();
    let mut total = 0.0f64;

    for step in 0..rl::EPISODE_LEN {
        hist_states.push(state.clone());
        hist_actions.push(vec![0.0; adim]); // current action unknown (causal)
        hist_rtg.push(rtg);

        // right-aligned context window
        let n = hist_states.len().min(t);
        let start = hist_states.len() - n;
        let pad = t - n;
        let mut rtg_in = vec![0.0f32; t];
        let mut states_in = vec![0.0f32; t * sdim];
        let mut actions_in = vec![0.0f32; t * adim];
        let mut ts_in = vec![0i32; t];
        let mut mask_in = vec![0.0f32; t];
        for i in 0..n {
            let src = start + i;
            let dst = pad + i;
            rtg_in[dst] = (hist_rtg[src] / dataset.rtg_scale) as f32;
            states_in[dst * sdim..(dst + 1) * sdim].copy_from_slice(&hist_states[src]);
            actions_in[dst * adim..(dst + 1) * adim].copy_from_slice(&hist_actions[src]);
            ts_in[dst] = src as i32;
            mask_in[dst] = 1.0;
        }
        let out = actor.run(&[
            f32s(&[1, t, 1], rtg_in),
            f32s(&[1, t, sdim], states_in),
            f32s(&[1, t, adim], actions_in),
            i32s(&[1, t], ts_in),
            f32s(&[1, t], mask_in),
        ])?;
        let action = &out[0]; // (1, ACT_DIM)
        *hist_actions.last_mut().unwrap() = action.clone();

        let (next, reward, done) = env.step(action);
        total += reward;
        rtg -= reward;
        state = next;
        let _ = step;
        if done {
            break;
        }
    }
    Ok(total)
}
