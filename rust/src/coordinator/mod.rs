//! L3 coordination: the training loop (`trainer`), evaluation loop
//! (`evaluator`) and the per-domain experiment drivers (`experiments`)
//! that tie data substrates + AOT artifacts together into the paper's
//! table rows. The streaming-session counterpart lives in `crate::serve`.

pub mod evaluator;
pub mod experiments;
pub mod trainer;

pub use evaluator::Evaluator;
pub use trainer::Trainer;
