//! Training coordinator: owns the (params, adam-m, adam-v, step) buffers
//! and drives an AOT `train` artifact — forward+backward+Adam are a single
//! compiled HLO module; rust only marshals buffers and feeds outputs back
//! into the next step's inputs (DESIGN.md §7).
//!
//! Hot-path note (EXPERIMENTS.md §Perf): the live training state is kept
//! as xla `Literal`s and each step's *output* literals become the next
//! step's *input* literals directly. The per-step host work is just the
//! batch-input upload — params/moments never round-trip through Vec<f32>
//! except at checkpoint/eval boundaries (`sync_store`).

use std::rc::Rc;

use anyhow::{bail, Result};

use crate::runtime::exec::{literal_scalar_f32, literal_to_f32, HostTensor, Module};
use crate::runtime::manifest::Role;
use crate::runtime::params::ParamStore;

pub struct Trainer {
    pub module: Rc<Module>,
    /// live training state, as literals in manifest order
    params_lit: Vec<xla::Literal>,
    opt_m_lit: Vec<xla::Literal>,
    opt_v_lit: Vec<xla::Literal>,
    step_lit: xla::Literal,
    n_steps: f32,
    /// loss history, one entry per step
    pub losses: Vec<f32>,
}

impl Trainer {
    pub fn new(module: Rc<Module>) -> Result<Trainer> {
        let store = ParamStore::load(&module.manifest)?;
        Self::with_store(module, store)
    }

    /// With explicit (possibly pre-trained) parameters.
    pub fn with_store(module: Rc<Module>, store: ParamStore) -> Result<Trainer> {
        if module.manifest.kind != "train" {
            bail!("{} is not a train artifact", module.manifest.name);
        }
        let mut params_lit = Vec::new();
        let mut opt_m_lit = Vec::new();
        let mut opt_v_lit = Vec::new();
        let mut pi = 0usize;
        for arg in &module.manifest.args {
            match arg.role {
                Role::Param => {
                    params_lit.push(
                        HostTensor::F32(arg.shape.clone(), store.params[pi].clone())
                            .to_literal()?,
                    );
                    opt_m_lit.push(
                        HostTensor::F32(arg.shape.clone(), store.opt_m[pi].clone())
                            .to_literal()?,
                    );
                    opt_v_lit.push(
                        HostTensor::F32(arg.shape.clone(), store.opt_v[pi].clone())
                            .to_literal()?,
                    );
                    pi += 1;
                }
                _ => {}
            }
        }
        let step_lit = HostTensor::scalar_f32(store.step).to_literal()?;
        Ok(Trainer {
            module,
            params_lit,
            opt_m_lit,
            opt_v_lit,
            step_lit,
            n_steps: store.step,
            losses: Vec::new(),
        })
    }

    /// Run one optimisation step. `inputs` must match the manifest's
    /// input-role arguments in order. Returns the loss.
    pub fn step(&mut self, inputs: &[HostTensor]) -> Result<f32> {
        let manifest = &self.module.manifest;
        let input_idx = manifest.input_indices();
        if inputs.len() != input_idx.len() {
            bail!(
                "{}: expected {} batch inputs, got {}",
                manifest.name,
                input_idx.len(),
                inputs.len()
            );
        }
        // upload the batch, borrow everything else
        let mut input_lits = Vec::with_capacity(inputs.len());
        for (t, (_, arg)) in inputs.iter().zip(manifest.args_with_role(Role::Input)) {
            if t.elements() != arg.elements() || t.dtype() != arg.dtype {
                bail!(
                    "{}: input {} shape/dtype mismatch (got {} elems {:?}, want {} {:?})",
                    manifest.name,
                    arg.name,
                    t.elements(),
                    t.dtype(),
                    arg.elements(),
                    arg.dtype
                );
            }
            input_lits.push(t.to_literal()?);
        }
        let mut args: Vec<&xla::Literal> = Vec::with_capacity(manifest.args.len());
        let (mut pi, mut mi, mut vi, mut ii) = (0usize, 0usize, 0usize, 0usize);
        for arg in &manifest.args {
            match arg.role {
                Role::Param => {
                    args.push(&self.params_lit[pi]);
                    pi += 1;
                }
                Role::OptM => {
                    args.push(&self.opt_m_lit[mi]);
                    mi += 1;
                }
                Role::OptV => {
                    args.push(&self.opt_v_lit[vi]);
                    vi += 1;
                }
                Role::OptStep => args.push(&self.step_lit),
                Role::Input => {
                    args.push(&input_lits[ii]);
                    ii += 1;
                }
                Role::State | Role::Aux => bail!("unexpected role in train args"),
            }
        }

        let outputs = self.module.execute_refs(&args)?;
        if outputs.len() != manifest.outputs.len() {
            bail!(
                "{}: got {} outputs, manifest says {}",
                manifest.name,
                outputs.len(),
                manifest.outputs.len()
            );
        }
        // feed output literals straight back into the live state
        let (mut pi, mut mi, mut vi) = (0usize, 0usize, 0usize);
        let mut loss = f32::NAN;
        for (spec, lit) in manifest.outputs.iter().zip(outputs.into_iter()) {
            match spec.role {
                Role::Param => {
                    self.params_lit[pi] = lit;
                    pi += 1;
                }
                Role::OptM => {
                    self.opt_m_lit[mi] = lit;
                    mi += 1;
                }
                Role::OptV => {
                    self.opt_v_lit[vi] = lit;
                    vi += 1;
                }
                Role::OptStep => {
                    self.n_steps = literal_scalar_f32(&lit)?;
                    self.step_lit = lit;
                }
                Role::Aux => loss = literal_scalar_f32(&lit)?,
                _ => {}
            }
        }
        if !loss.is_finite() {
            bail!("{}: non-finite loss at step {}", manifest.name, self.n_steps);
        }
        self.losses.push(loss);
        Ok(loss)
    }

    /// Materialise the live literals into a ParamStore (checkpoint / eval
    /// handoff). Cost: one host copy per tensor; called once per run, not
    /// per step.
    pub fn sync_store(&self) -> Result<ParamStore> {
        let mut params = Vec::with_capacity(self.params_lit.len());
        let mut opt_m = Vec::with_capacity(self.opt_m_lit.len());
        let mut opt_v = Vec::with_capacity(self.opt_v_lit.len());
        for lit in &self.params_lit {
            params.push(literal_to_f32(lit)?);
        }
        for lit in &self.opt_m_lit {
            opt_m.push(literal_to_f32(lit)?);
        }
        for lit in &self.opt_v_lit {
            opt_v.push(literal_to_f32(lit)?);
        }
        Ok(ParamStore { params, opt_m, opt_v, step: self.n_steps })
    }

    /// Mean loss over the trailing `n` steps (training-curve reporting).
    pub fn recent_loss(&self, n: usize) -> f32 {
        if self.losses.is_empty() {
            return f32::NAN;
        }
        let tail = &self.losses[self.losses.len().saturating_sub(n)..];
        tail.iter().sum::<f32>() / tail.len() as f32
    }
}
