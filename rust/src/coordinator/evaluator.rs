//! Evaluation coordinator: runs `eval`/`fwd` artifacts with trained
//! parameters and collects their auxiliary outputs (metric sums or
//! predictions). Shares the ParamStore layout with the trainer via the
//! common params_key.

use std::rc::Rc;

use anyhow::{bail, Result};

use crate::runtime::exec::{literal_to_f32, HostTensor, Module};
use crate::runtime::manifest::Role;
use crate::runtime::params::ParamStore;

pub struct Evaluator {
    pub module: Rc<Module>,
    pub store: ParamStore,
}

impl Evaluator {
    /// Evaluate with freshly-initialised params (baseline sanity runs).
    pub fn new(module: Rc<Module>) -> Result<Evaluator> {
        let store = ParamStore::load(&module.manifest)?;
        Ok(Evaluator { module, store })
    }

    /// Evaluate with trained parameters from a Trainer's store. The two
    /// modules must share a params_key (same model) — asserted here.
    pub fn with_trained(
        module: Rc<Module>,
        trained_key: &str,
        trained: &ParamStore,
    ) -> Result<Evaluator> {
        if module.manifest.params_key != trained_key {
            bail!(
                "params_key mismatch: eval {} vs trained {}",
                module.manifest.params_key,
                trained_key
            );
        }
        let mut store = ParamStore::load(&module.manifest)?;
        store.copy_params_from(trained);
        Ok(Evaluator { module, store })
    }

    /// Run once; returns every aux output flattened to f32 vectors.
    pub fn run(&self, inputs: &[HostTensor]) -> Result<Vec<Vec<f32>>> {
        let manifest = &self.module.manifest;
        let input_idx = manifest.input_indices();
        if inputs.len() != input_idx.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                manifest.name,
                input_idx.len(),
                inputs.len()
            );
        }
        let mut literals = Vec::with_capacity(manifest.args.len());
        let mut pi = 0usize;
        let mut ii = 0usize;
        for arg in &manifest.args {
            let lit = match arg.role {
                Role::Param => {
                    let t = HostTensor::F32(arg.shape.clone(), self.store.params[pi].clone());
                    pi += 1;
                    t.to_literal()?
                }
                Role::Input => {
                    let t = &inputs[ii];
                    ii += 1;
                    if t.elements() != arg.elements() || t.dtype() != arg.dtype {
                        bail!("{}: input {} mismatch", manifest.name, arg.name);
                    }
                    t.to_literal()?
                }
                other => bail!("{}: unexpected arg role {other:?}", manifest.name),
            };
            literals.push(lit);
        }
        let outputs = self.module.execute(&literals)?;
        let mut aux = Vec::new();
        for (spec, lit) in manifest.outputs.iter().zip(outputs.iter()) {
            if spec.role == Role::Aux {
                aux.push(literal_to_f32(lit)?);
            }
        }
        Ok(aux)
    }

    /// Run and return each aux output's first element (the common
    /// "scalar metric sums" case).
    pub fn run_scalars(&self, inputs: &[HostTensor]) -> Result<Vec<f32>> {
        Ok(self.run(inputs)?.into_iter().map(|v| v[0]).collect())
    }
}
