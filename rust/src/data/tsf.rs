//! Time-series forecasting substrate (paper §4.3, Tables 3 and 5).
//!
//! The paper uses 8 real datasets from the Time Series Library. We build
//! seeded synthetic generators whose presets mirror each dataset's
//! temporal structure — sampling period, dominant seasonalities,
//! trend/random-walk behaviour and noise — so the forecasting task
//! exercises the same model path (96-step lookback, {96,192,336,720}-step
//! horizons, channel-coupled multivariate series, dataset-level
//! z-scoring).

use crate::util::rng::Rng;

pub const CHANNELS: usize = 7; // matches aot.py TSF preset
pub const LOOKBACK: usize = 96;
pub const HORIZONS: [usize; 4] = [96, 192, 336, 720];

/// One synthetic series preset ≈ one paper dataset.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum TsfDataset {
    Weather,
    Exchange,
    Traffic,
    Ecl,
    Etth1,
    Etth2,
    Ettm1,
    Ettm2,
}

pub const ALL: [TsfDataset; 8] = [
    TsfDataset::Weather,
    TsfDataset::Exchange,
    TsfDataset::Traffic,
    TsfDataset::Ecl,
    TsfDataset::Etth1,
    TsfDataset::Etth2,
    TsfDataset::Ettm1,
    TsfDataset::Ettm2,
];

impl TsfDataset {
    pub fn name(self) -> &'static str {
        match self {
            TsfDataset::Weather => "Weather",
            TsfDataset::Exchange => "Exchange",
            TsfDataset::Traffic => "Traffic",
            TsfDataset::Ecl => "ECL",
            TsfDataset::Etth1 => "ETTh1",
            TsfDataset::Etth2 => "ETTh2",
            TsfDataset::Ettm1 => "ETTm1",
            TsfDataset::Ettm2 => "ETTm2",
        }
    }

    fn params(self) -> SeriesParams {
        // (periods, amps) chosen to echo each dataset's sampling structure:
        // Weather 10-min (daily=144), Traffic/ECL hourly (daily=24,
        // weekly=168), ETTh hourly (24), ETTm 15-min (96); Exchange is a
        // near-pure random walk (daily FX rates).
        match self {
            TsfDataset::Weather => SeriesParams {
                periods: vec![(144.0, 1.0), (1008.0, 0.5)],
                trend: 0.0002,
                ar: 0.75,
                noise: 0.35,
                walk: 0.0,
                coupling: 0.5,
            },
            TsfDataset::Exchange => SeriesParams {
                periods: vec![],
                trend: 0.0,
                ar: 0.0,
                noise: 0.02,
                walk: 1.0,
                coupling: 0.3,
            },
            TsfDataset::Traffic => SeriesParams {
                periods: vec![(24.0, 1.2), (168.0, 0.6)],
                trend: 0.0,
                ar: 0.5,
                noise: 0.45,
                walk: 0.0,
                coupling: 0.7,
            },
            TsfDataset::Ecl => SeriesParams {
                periods: vec![(24.0, 1.0), (168.0, 0.4)],
                trend: 0.0004,
                ar: 0.6,
                noise: 0.3,
                walk: 0.0,
                coupling: 0.6,
            },
            TsfDataset::Etth1 => SeriesParams {
                periods: vec![(24.0, 0.9)],
                trend: -0.0003,
                ar: 0.85,
                noise: 0.5,
                walk: 0.0,
                coupling: 0.4,
            },
            TsfDataset::Etth2 => SeriesParams {
                periods: vec![(24.0, 0.7)],
                trend: 0.0,
                ar: 0.9,
                noise: 0.6,
                walk: 0.1,
                coupling: 0.4,
            },
            TsfDataset::Ettm1 => SeriesParams {
                periods: vec![(96.0, 0.9), (672.0, 0.3)],
                trend: -0.0001,
                ar: 0.8,
                noise: 0.4,
                walk: 0.0,
                coupling: 0.4,
            },
            TsfDataset::Ettm2 => SeriesParams {
                periods: vec![(96.0, 0.6), (672.0, 0.4)],
                trend: 0.0,
                ar: 0.85,
                noise: 0.55,
                walk: 0.05,
                coupling: 0.4,
            },
        }
    }
}

struct SeriesParams {
    /// (period in steps, amplitude)
    periods: Vec<(f64, f64)>,
    trend: f64,
    /// AR(1) coefficient of the noise process
    ar: f64,
    noise: f64,
    /// random-walk innovation scale (Exchange-like)
    walk: f64,
    /// cross-channel coupling strength to a shared latent factor
    coupling: f64,
}

/// A generated multivariate series, time-major: `values[t * CHANNELS + c]`,
/// z-scored per channel over the whole series (the TSL convention the
/// paper's MSE/MAE numbers are computed under).
pub struct Series {
    pub len: usize,
    pub values: Vec<f32>,
}

impl Series {
    pub fn at(&self, t: usize) -> &[f32] {
        &self.values[t * CHANNELS..(t + 1) * CHANNELS]
    }
}

/// Generate `len` steps of the given dataset preset.
pub fn generate(ds: TsfDataset, len: usize, seed: u64) -> Series {
    let p = ds.params();
    let mut rng = Rng::new(seed ^ (ds as u64).wrapping_mul(0x51ED_270F));
    // per-channel phases / scales / AR state
    let phases: Vec<Vec<f64>> = (0..CHANNELS)
        .map(|_| p.periods.iter().map(|_| rng.range(0.0, std::f64::consts::TAU)).collect())
        .collect();
    let chan_scale: Vec<f64> = (0..CHANNELS).map(|_| rng.range(0.5, 1.5)).collect();
    let mut ar_state = vec![0.0f64; CHANNELS];
    let mut walk_state = vec![0.0f64; CHANNELS];
    let mut latent = 0.0f64; // shared cross-channel factor (AR(1))

    let mut values = vec![0.0f32; len * CHANNELS];
    for t in 0..len {
        latent = 0.9 * latent + 0.3 * rng.gaussian();
        for c in 0..CHANNELS {
            let mut x = p.trend * t as f64 * chan_scale[c];
            for (j, (period, amp)) in p.periods.iter().enumerate() {
                x += amp
                    * chan_scale[c]
                    * (std::f64::consts::TAU * t as f64 / period + phases[c][j]).sin();
            }
            ar_state[c] = p.ar * ar_state[c] + p.noise * rng.gaussian();
            walk_state[c] += p.walk * 0.05 * rng.gaussian();
            x += ar_state[c] + walk_state[c] + p.coupling * latent;
            values[t * CHANNELS + c] = x as f32;
        }
    }
    // dataset-level z-score per channel
    for c in 0..CHANNELS {
        let mut mean = 0.0f64;
        for t in 0..len {
            mean += values[t * CHANNELS + c] as f64;
        }
        mean /= len as f64;
        let mut var = 0.0f64;
        for t in 0..len {
            let d = values[t * CHANNELS + c] as f64 - mean;
            var += d * d;
        }
        let std = (var / len as f64).sqrt().max(1e-6);
        for t in 0..len {
            let v = &mut values[t * CHANNELS + c];
            *v = ((*v as f64 - mean) / std) as f32;
        }
    }
    Series { len, values }
}

/// One (lookback, horizon) training window, flattened row-major.
pub struct Window {
    pub x: Vec<f32>, // (LOOKBACK, CHANNELS)
    pub y: Vec<f32>, // (horizon, CHANNELS)
}

/// Train/test split helpers mirroring TSL: windows from the first 70% of
/// the series train, the last 30% test.
pub struct WindowSampler {
    series: Series,
    horizon: usize,
    train_end: usize,
}

impl WindowSampler {
    pub fn new(series: Series, horizon: usize) -> WindowSampler {
        let train_end = (series.len as f64 * 0.7) as usize;
        WindowSampler { series, horizon, train_end }
    }

    fn window_at(&self, start: usize) -> Window {
        let c = CHANNELS;
        let x = self.series.values[start * c..(start + LOOKBACK) * c].to_vec();
        let ys = start + LOOKBACK;
        let y = self.series.values[ys * c..(ys + self.horizon) * c].to_vec();
        Window { x, y }
    }

    /// Random training window.
    pub fn sample_train(&self, rng: &mut Rng) -> Window {
        let max_start = self.train_end.saturating_sub(LOOKBACK + self.horizon);
        self.window_at(rng.below(max_start.max(1)))
    }

    /// Deterministic, non-overlapping-ish test windows.
    pub fn test_windows(&self, count: usize) -> Vec<Window> {
        let lo = self.train_end;
        let hi = self.series.len.saturating_sub(LOOKBACK + self.horizon);
        assert!(hi > lo, "series too short for test split");
        let stride = ((hi - lo) / count.max(1)).max(1);
        (0..count)
            .map(|i| self.window_at((lo + i * stride).min(hi - 1)))
            .collect()
    }

    /// Batch of training windows, flattened for the AOT artifact:
    /// returns (x: (b, LOOKBACK, C), y: (b, horizon, C)).
    pub fn train_batch(&self, rng: &mut Rng, b: usize) -> (Vec<f32>, Vec<f32>) {
        let mut xs = Vec::with_capacity(b * LOOKBACK * CHANNELS);
        let mut ys = Vec::with_capacity(b * self.horizon * CHANNELS);
        for _ in 0..b {
            let w = self.sample_train(rng);
            xs.extend_from_slice(&w.x);
            ys.extend_from_slice(&w.y);
        }
        (xs, ys)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let a = generate(TsfDataset::Weather, 500, 7);
        let b = generate(TsfDataset::Weather, 500, 7);
        assert_eq!(a.values, b.values);
        let c = generate(TsfDataset::Weather, 500, 8);
        assert_ne!(a.values, c.values);
    }

    #[test]
    fn zscored_per_channel() {
        let s = generate(TsfDataset::Traffic, 2000, 1);
        for c in 0..CHANNELS {
            let xs: Vec<f64> = (0..s.len).map(|t| s.at(t)[c] as f64).collect();
            let mean = xs.iter().sum::<f64>() / xs.len() as f64;
            let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
                / xs.len() as f64;
            assert!(mean.abs() < 1e-3, "mean {mean}");
            assert!((var - 1.0).abs() < 1e-2, "var {var}");
        }
    }

    #[test]
    fn seasonal_presets_have_autocorrelation_at_period() {
        // Traffic has a 24-step season: autocorr at lag 24 should beat lag 13.
        let s = generate(TsfDataset::Traffic, 4000, 3);
        let ac = |lag: usize| {
            let mut num = 0.0f64;
            for t in 0..s.len - lag {
                num += (s.at(t)[0] * s.at(t + lag)[0]) as f64;
            }
            num / (s.len - lag) as f64
        };
        assert!(ac(24) > ac(13) + 0.05, "ac24 {} ac13 {}", ac(24), ac(13));
    }

    #[test]
    fn exchange_is_walk_like() {
        // random walk: variance of increments much smaller than of levels
        // (levels z-scored to ~1)
        let s = generate(TsfDataset::Exchange, 3000, 5);
        let mut inc_var = 0.0f64;
        for t in 1..s.len {
            let d = (s.at(t)[0] - s.at(t - 1)[0]) as f64;
            inc_var += d * d;
        }
        inc_var /= (s.len - 1) as f64;
        assert!(inc_var < 0.05, "increment var {inc_var}");
    }

    #[test]
    fn windows_have_expected_shapes() {
        let s = generate(TsfDataset::Etth1, 3000, 2);
        let sampler = WindowSampler::new(s, 192);
        let mut rng = Rng::new(0);
        let w = sampler.sample_train(&mut rng);
        assert_eq!(w.x.len(), LOOKBACK * CHANNELS);
        assert_eq!(w.y.len(), 192 * CHANNELS);
        let tests = sampler.test_windows(8);
        assert_eq!(tests.len(), 8);
        let (xs, ys) = sampler.train_batch(&mut rng, 4);
        assert_eq!(xs.len(), 4 * LOOKBACK * CHANNELS);
        assert_eq!(ys.len(), 4 * 192 * CHANNELS);
    }

    #[test]
    fn test_windows_come_from_heldout_region() {
        let s = generate(TsfDataset::Ecl, 3000, 2);
        let sampler = WindowSampler::new(s, 96);
        // all test windows start at or after the 70% boundary
        let tw = sampler.test_windows(5);
        assert_eq!(tw.len(), 5);
        // train windows never reach the test region
        let mut rng = Rng::new(1);
        for _ in 0..50 {
            let _ = sampler.sample_train(&mut rng); // would panic on OOB
        }
    }

    #[test]
    fn all_presets_generate() {
        for ds in ALL {
            let s = generate(ds, 1500, 11);
            assert_eq!(s.values.len(), 1500 * CHANNELS);
            assert!(s.values.iter().all(|v| v.is_finite()));
        }
    }
}
