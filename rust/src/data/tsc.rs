//! Time-series classification substrate (paper §4.4, Table 4).
//!
//! The paper uses 10 UEA archive datasets. We build class-conditional
//! generators: each class is a distinct spectral/shape signature
//! (frequency, chirp rate, envelope, phase coherence across channels) and
//! each preset controls class count, noise floor and signature separation
//! to land in the paper's difficulty range (e.g. Handwriting ≈ 27% acc vs
//! ArabicDigits ≈ 99%).

use crate::util::rng::Rng;

pub const CHANNELS: usize = 8; // matches aot.py TSC preset
pub const SEQ_LEN: usize = 96;
pub const MAX_CLASSES: usize = 16; // AOT head width; presets use <= this

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum TscDataset {
    EthanolConcentration,
    FaceDetection,
    Handwriting,
    Heartbeat,
    JapaneseVowels,
    PemsSf,
    SelfRegulationScp1,
    SelfRegulationScp2,
    ArabicDigits,
    UWaveGesture,
}

pub const ALL: [TscDataset; 10] = [
    TscDataset::EthanolConcentration,
    TscDataset::FaceDetection,
    TscDataset::Handwriting,
    TscDataset::Heartbeat,
    TscDataset::JapaneseVowels,
    TscDataset::PemsSf,
    TscDataset::SelfRegulationScp1,
    TscDataset::SelfRegulationScp2,
    TscDataset::ArabicDigits,
    TscDataset::UWaveGesture,
];

struct TscParams {
    classes: usize,
    /// additive noise sigma — the difficulty knob
    noise: f64,
    /// how far apart class frequencies are
    freq_sep: f64,
    /// fraction of channels carrying signal (rest pure noise)
    informative: f64,
}

impl TscDataset {
    pub fn name(self) -> &'static str {
        match self {
            TscDataset::EthanolConcentration => "EthanolConc.",
            TscDataset::FaceDetection => "FaceDetection",
            TscDataset::Handwriting => "Handwriting",
            TscDataset::Heartbeat => "Heartbeat",
            TscDataset::JapaneseVowels => "Jap. Vowels",
            TscDataset::PemsSf => "PEMS-SF",
            TscDataset::SelfRegulationScp1 => "SelfReg. SCP1",
            TscDataset::SelfRegulationScp2 => "SelfReg. SCP2",
            TscDataset::ArabicDigits => "ArabicDigits",
            TscDataset::UWaveGesture => "UWaveGesture",
        }
    }

    pub fn n_classes(self) -> usize {
        self.params().classes
    }

    fn params(self) -> TscParams {
        // class counts follow the real UEA datasets (capped at the AOT
        // head width of 16 for Handwriting's 26 letters); noise/separation
        // tuned so model accuracy lands near the paper's per-dataset range.
        match self {
            TscDataset::EthanolConcentration => TscParams {
                classes: 4, noise: 3.2, freq_sep: 0.25, informative: 0.4,
            },
            TscDataset::FaceDetection => TscParams {
                classes: 2, noise: 1.7, freq_sep: 0.5, informative: 0.5,
            },
            TscDataset::Handwriting => TscParams {
                classes: 16, noise: 2.6, freq_sep: 0.3, informative: 0.5,
            },
            TscDataset::Heartbeat => TscParams {
                classes: 2, noise: 1.3, freq_sep: 0.6, informative: 0.6,
            },
            TscDataset::JapaneseVowels => TscParams {
                classes: 9, noise: 0.45, freq_sep: 1.0, informative: 0.9,
            },
            TscDataset::PemsSf => TscParams {
                classes: 7, noise: 0.8, freq_sep: 0.8, informative: 0.7,
            },
            TscDataset::SelfRegulationScp1 => TscParams {
                classes: 2, noise: 0.85, freq_sep: 0.8, informative: 0.7,
            },
            TscDataset::SelfRegulationScp2 => TscParams {
                classes: 2, noise: 2.1, freq_sep: 0.4, informative: 0.4,
            },
            TscDataset::ArabicDigits => TscParams {
                classes: 10, noise: 0.3, freq_sep: 1.2, informative: 0.95,
            },
            TscDataset::UWaveGesture => TscParams {
                classes: 8, noise: 0.75, freq_sep: 0.9, informative: 0.75,
            },
        }
    }
}

/// One labelled example: x is (SEQ_LEN, CHANNELS) row-major.
pub struct Example {
    pub x: Vec<f32>,
    pub label: i32,
}

/// Class-conditional generator. Class y's signature: base frequency
/// f_y = f0 + y·sep, a chirp term, a class-specific envelope peak, and
/// per-channel phase offsets drawn once per dataset (shared across
/// examples, so the class structure is learnable).
pub struct TscGenerator {
    params: TscParams,
    /// per (class, channel): phase offset
    phases: Vec<f64>,
    /// per channel: is it informative?
    informative: Vec<bool>,
    ds: TscDataset,
}

impl TscGenerator {
    pub fn new(ds: TscDataset, seed: u64) -> TscGenerator {
        let params = ds.params();
        let mut rng = Rng::new(seed ^ (ds as u64).wrapping_mul(0xC0FF_EE11));
        let phases = (0..params.classes * CHANNELS)
            .map(|_| rng.range(0.0, std::f64::consts::TAU))
            .collect();
        let informative = (0..CHANNELS)
            .map(|_| rng.uniform() < params.informative)
            .collect::<Vec<_>>();
        // guarantee at least one informative channel
        let mut informative = informative;
        if !informative.iter().any(|&b| b) {
            informative[0] = true;
        }
        TscGenerator { params, phases, informative, ds }
    }

    pub fn dataset(&self) -> TscDataset {
        self.ds
    }

    pub fn sample(&self, rng: &mut Rng) -> Example {
        let y = rng.below(self.params.classes);
        self.sample_class(rng, y)
    }

    pub fn sample_class(&self, rng: &mut Rng, y: usize) -> Example {
        let p = &self.params;
        let f0 = 2.0 + y as f64 * p.freq_sep; // cycles per window
        let chirp = 0.3 * (y % 3) as f64;
        let env_peak = (y as f64 + 0.5) / p.classes as f64; // envelope centre
        let mut x = vec![0.0f32; SEQ_LEN * CHANNELS];
        let jitter = rng.range(-0.05, 0.05); // per-example frequency jitter
        for c in 0..CHANNELS {
            let phase = self.phases[y * CHANNELS + c];
            for t in 0..SEQ_LEN {
                let tt = t as f64 / SEQ_LEN as f64;
                let mut v = p.noise * rng.gaussian();
                if self.informative[c] {
                    let f = f0 * (1.0 + jitter) + chirp * tt;
                    let env = (-8.0 * (tt - env_peak) * (tt - env_peak)).exp();
                    v += (std::f64::consts::TAU * f * tt + phase).sin()
                        + 0.6 * env * (std::f64::consts::TAU * 2.0 * f * tt).cos();
                }
                x[t * CHANNELS + c] = v as f32;
            }
        }
        Example { x, label: y as i32 }
    }

    /// Flattened batch for the AOT artifact: (x: (b, SEQ_LEN, C), labels: (b,)).
    pub fn batch(&self, rng: &mut Rng, b: usize) -> (Vec<f32>, Vec<i32>) {
        let mut xs = Vec::with_capacity(b * SEQ_LEN * CHANNELS);
        let mut labels = Vec::with_capacity(b);
        for _ in 0..b {
            let e = self.sample(rng);
            xs.extend_from_slice(&e.x);
            labels.push(e.label);
        }
        (xs, labels)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn labels_in_range_for_all_presets() {
        for ds in ALL {
            let g = TscGenerator::new(ds, 1);
            let mut rng = Rng::new(2);
            for _ in 0..64 {
                let e = g.sample(&mut rng);
                assert!((e.label as usize) < ds.n_classes());
                assert!(ds.n_classes() <= MAX_CLASSES);
                assert_eq!(e.x.len(), SEQ_LEN * CHANNELS);
            }
        }
    }

    #[test]
    fn classes_are_separable_by_simple_statistic() {
        // On an easy preset, a nearest-class-mean classifier over the raw
        // series should beat chance comfortably — i.e. the labels carry
        // signal a model can learn.
        let g = TscGenerator::new(TscDataset::ArabicDigits, 3);
        let ncls = TscDataset::ArabicDigits.n_classes();
        let mut rng = Rng::new(4);
        let mut means = vec![vec![0.0f64; SEQ_LEN * CHANNELS]; ncls];
        let per_class = 12;
        for y in 0..ncls {
            for _ in 0..per_class {
                let e = g.sample_class(&mut rng, y);
                for (m, v) in means[y].iter_mut().zip(e.x.iter()) {
                    *m += *v as f64 / per_class as f64;
                }
            }
        }
        let mut correct = 0;
        let trials = 100;
        for _ in 0..trials {
            let e = g.sample(&mut rng);
            let mut best = (f64::MAX, 0usize);
            for (y, m) in means.iter().enumerate() {
                let d: f64 = m
                    .iter()
                    .zip(e.x.iter())
                    .map(|(a, b)| (a - *b as f64) * (a - *b as f64))
                    .sum();
                if d < best.0 {
                    best = (d, y);
                }
            }
            if best.1 == e.label as usize {
                correct += 1;
            }
        }
        let acc = correct as f64 / trials as f64;
        assert!(acc > 0.6, "nearest-mean acc {acc} (chance {})", 1.0 / ncls as f64);
    }

    #[test]
    fn hard_presets_are_harder_than_easy_ones() {
        // noise knob sanity: EthanolConcentration sigma >> ArabicDigits
        let hard = TscDataset::EthanolConcentration.params();
        let easy = TscDataset::ArabicDigits.params();
        assert!(hard.noise > 2.0 * easy.noise);
    }

    #[test]
    fn deterministic_given_seed() {
        let g1 = TscGenerator::new(TscDataset::Heartbeat, 9);
        let g2 = TscGenerator::new(TscDataset::Heartbeat, 9);
        let mut r1 = Rng::new(5);
        let mut r2 = Rng::new(5);
        let e1 = g1.sample(&mut r1);
        let e2 = g2.sample(&mut r2);
        assert_eq!(e1.x, e2.x);
        assert_eq!(e1.label, e2.label);
    }

    #[test]
    fn batch_shapes() {
        let g = TscGenerator::new(TscDataset::UWaveGesture, 1);
        let mut rng = Rng::new(0);
        let (xs, labels) = g.batch(&mut rng, 5);
        assert_eq!(xs.len(), 5 * SEQ_LEN * CHANNELS);
        assert_eq!(labels.len(), 5);
    }
}
