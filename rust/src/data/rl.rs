//! Offline-RL substrate (paper §4.1, Table 1): four simulated
//! locomotion-style environments with three D4RL-like dataset tiers each.
//!
//! The paper evaluates Decision Transformers on MuJoCo HalfCheetah / Ant /
//! Hopper / Walker with Medium / Medium-Replay / Medium-Expert datasets.
//! We build gait-tracking environments: each env hides a reference gait
//! (per-joint sinusoids); reward is velocity-alignment with the gait minus
//! control cost. A PD controller tracking the gait is the *expert*; a
//! detuned, noisy PD controller is the *medium* policy; uniform actions
//! are *random*. This reproduces the experimental object — return-
//! conditioned sequence modelling over (rtg, state, action) streams with
//! demonstrator-quality tiers — without MuJoCo (DESIGN.md §3).

use crate::util::rng::Rng;

pub const STATE_DIM: usize = 12; // matches aot.py RL preset
pub const ACT_DIM: usize = 6;
pub const CTX: usize = 20;
pub const EPISODE_LEN: usize = 200;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum EnvId {
    HalfCheetah,
    Ant,
    Hopper,
    Walker,
}

pub const ALL_ENVS: [EnvId; 4] = [EnvId::HalfCheetah, EnvId::Ant, EnvId::Hopper, EnvId::Walker];

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Tier {
    Medium,
    MediumReplay,
    MediumExpert,
}

pub const ALL_TIERS: [Tier; 3] = [Tier::Medium, Tier::MediumReplay, Tier::MediumExpert];

impl EnvId {
    pub fn name(self) -> &'static str {
        match self {
            EnvId::HalfCheetah => "HalfCheetah",
            EnvId::Ant => "Ant",
            EnvId::Hopper => "Hopper",
            EnvId::Walker => "Walker",
        }
    }

    fn spec(self) -> EnvSpec {
        match self {
            // joints / gait frequency / actuator gain / damping / noise
            EnvId::HalfCheetah => EnvSpec { joints: 5, omega: 2.2, gain: 5.0, damping: 1.2, dyn_noise: 0.01 },
            EnvId::Ant => EnvSpec { joints: 4, omega: 1.4, gain: 4.0, damping: 1.6, dyn_noise: 0.02 },
            EnvId::Hopper => EnvSpec { joints: 3, omega: 2.8, gain: 6.0, damping: 1.0, dyn_noise: 0.015 },
            EnvId::Walker => EnvSpec { joints: 5, omega: 1.8, gain: 4.5, damping: 1.4, dyn_noise: 0.02 },
        }
    }
}

impl Tier {
    pub fn name(self) -> &'static str {
        match self {
            Tier::Medium => "Medium",
            Tier::MediumReplay => "Med-Replay",
            Tier::MediumExpert => "Med-Expert",
        }
    }
}

struct EnvSpec {
    joints: usize,
    omega: f64,
    gain: f64,
    damping: f64,
    dyn_noise: f64,
}

/// Gait-tracking environment. State layout (STATE_DIM = 12):
/// [cos(ωt), sin(ωt), qpos[0..5] (zero-padded), qvel[0..5] (zero-padded)].
pub struct Env {
    pub id: EnvId,
    spec: EnvSpec,
    qpos: Vec<f64>,
    qvel: Vec<f64>,
    t: usize,
    phases: Vec<f64>,
    rng: Rng,
}

pub const DT: f64 = 0.05;

impl Env {
    pub fn new(id: EnvId, seed: u64) -> Env {
        let spec = id.spec();
        let rng = Rng::new(seed ^ (id as u64).wrapping_mul(0xEC0_10D5));
        // fixed gait phase offsets per joint (the "morphology")
        let phases: Vec<f64> = (0..spec.joints)
            .map(|j| j as f64 * std::f64::consts::TAU / spec.joints as f64)
            .collect();
        let mut env = Env {
            id,
            qpos: vec![0.0; spec.joints],
            qvel: vec![0.0; spec.joints],
            t: 0,
            phases,
            spec,
            rng,
        };
        env.reset_with(&mut Rng::new(seed));
        env.rng = Rng::new(seed.wrapping_mul(0x9E37));
        env
    }

    pub fn reset(&mut self, seed: u64) -> Vec<f32> {
        let mut r = Rng::new(seed);
        self.reset_with(&mut r)
    }

    fn reset_with(&mut self, rng: &mut Rng) -> Vec<f32> {
        for q in self.qpos.iter_mut() {
            *q = rng.range(-0.1, 0.1);
        }
        for q in self.qvel.iter_mut() {
            *q = rng.range(-0.1, 0.1);
        }
        self.t = 0;
        self.observe()
    }

    /// Reference gait: target joint positions/velocities at the current time.
    fn gait(&self) -> (Vec<f64>, Vec<f64>) {
        let w = self.spec.omega;
        let time = self.t as f64 * DT;
        let pos = self
            .phases
            .iter()
            .map(|p| (w * time + p).sin())
            .collect::<Vec<_>>();
        let vel = self
            .phases
            .iter()
            .map(|p| w * (w * time + p).cos())
            .collect::<Vec<_>>();
        (pos, vel)
    }

    pub fn observe(&self) -> Vec<f32> {
        let w = self.spec.omega;
        let time = self.t as f64 * DT;
        let mut s = vec![0.0f32; STATE_DIM];
        s[0] = (w * time).cos() as f32;
        s[1] = (w * time).sin() as f32;
        for j in 0..self.spec.joints {
            s[2 + j] = self.qpos[j] as f32;
            s[7 + j] = self.qvel[j] as f32;
        }
        s
    }

    /// Apply `action` (clipped to [-1, 1], entries past `joints` ignored),
    /// return (next_state, reward, done).
    pub fn step(&mut self, action: &[f32]) -> (Vec<f32>, f64, bool) {
        let spec = &self.spec;
        let (_, gait_vel) = self.gait();
        let mut ctrl_cost = 0.0;
        for j in 0..spec.joints {
            let a = (action[j] as f64).clamp(-1.0, 1.0);
            ctrl_cost += 0.01 * a * a;
            let acc = spec.gain * a
                - spec.damping * self.qvel[j]
                - 1.0 * self.qpos[j]
                + spec.dyn_noise * self.rng.gaussian() / DT.sqrt();
            self.qvel[j] += DT * acc;
            self.qpos[j] += DT * self.qvel[j];
        }
        self.t += 1;
        // "forward progress": joint velocities aligned with the gait's
        // velocity profile (a perfect tracker maximises this), normalised
        // per joint so rewards are comparable across morphologies.
        let mut align = 0.0;
        for j in 0..spec.joints {
            align += self.qvel[j] * gait_vel[j];
        }
        align /= spec.joints as f64 * spec.omega;
        let reward = align - ctrl_cost;
        let done = self.t >= EPISODE_LEN;
        (self.observe(), reward, done)
    }
}

// ---------------------------------------------------------------------------
// scripted policies (demonstrators)

/// Demonstrator: PD controller tracking the hidden gait, with quality
/// knobs. `quality` = 1.0 → expert; ~0.45 → medium; 0.0 → random.
pub struct ScriptedPolicy {
    pub quality: f64,
    pub noise: f64,
}

impl ScriptedPolicy {
    pub fn expert() -> Self {
        ScriptedPolicy { quality: 1.0, noise: 0.05 }
    }

    pub fn medium() -> Self {
        ScriptedPolicy { quality: 0.45, noise: 0.35 }
    }

    pub fn random() -> Self {
        ScriptedPolicy { quality: 0.0, noise: 1.0 }
    }

    pub fn act(&self, env: &Env, rng: &mut Rng) -> Vec<f32> {
        let (gait_pos, gait_vel) = env.gait();
        let spec = &env.spec;
        let mut a = vec![0.0f32; ACT_DIM];
        for j in 0..spec.joints {
            let pd = 2.0 * (gait_pos[j] - env.qpos[j]) + 0.8 * (gait_vel[j] - env.qvel[j]);
            let u = self.quality * pd + self.noise * rng.gaussian();
            a[j] = (u.clamp(-1.0, 1.0)) as f32;
        }
        a
    }
}

// ---------------------------------------------------------------------------
// offline datasets (D4RL-style tiers)

/// One trajectory: time-major flat buffers.
pub struct Trajectory {
    pub states: Vec<f32>,  // (T, STATE_DIM)
    pub actions: Vec<f32>, // (T, ACT_DIM)
    pub rewards: Vec<f64>, // (T,)
}

impl Trajectory {
    pub fn len(&self) -> usize {
        self.rewards.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rewards.is_empty()
    }

    pub fn total_return(&self) -> f64 {
        self.rewards.iter().sum()
    }

    /// Return-to-go at each step.
    pub fn rtg(&self) -> Vec<f64> {
        let mut out = vec![0.0; self.len()];
        let mut acc = 0.0;
        for t in (0..self.len()).rev() {
            acc += self.rewards[t];
            out[t] = acc;
        }
        out
    }
}

pub fn rollout(env: &mut Env, policy: &ScriptedPolicy, seed: u64) -> Trajectory {
    let mut rng = Rng::new(seed);
    let mut state = env.reset(seed ^ 0xABCD);
    let mut traj = Trajectory { states: Vec::new(), actions: Vec::new(), rewards: Vec::new() };
    loop {
        let a = policy.act(env, &mut rng);
        traj.states.extend_from_slice(&state);
        traj.actions.extend_from_slice(&a);
        let (next, r, done) = env.step(&a);
        traj.rewards.push(r);
        state = next;
        if done {
            break;
        }
    }
    traj
}

/// An offline dataset: trajectories plus normalisation references.
pub struct OfflineDataset {
    pub env: EnvId,
    pub tier: Tier,
    pub trajectories: Vec<Trajectory>,
    /// mean return of the random / expert reference policies (for the
    /// D4RL normalised score).
    pub random_return: f64,
    pub expert_return: f64,
    /// rtg scale used to normalise return-to-go model inputs
    pub rtg_scale: f64,
}

/// Generate a D4RL-style dataset for (env, tier).
pub fn generate_dataset(env_id: EnvId, tier: Tier, episodes: usize, seed: u64) -> OfflineDataset {
    let mut rng = Rng::new(seed ^ 0xD4D4);
    let mut trajectories = Vec::with_capacity(episodes);
    for e in 0..episodes {
        let mut env = Env::new(env_id, rng.next_u64());
        let policy = match tier {
            Tier::Medium => ScriptedPolicy::medium(),
            // replay buffer of medium training: a progression random→medium
            Tier::MediumReplay => {
                let frac = e as f64 / episodes.max(1) as f64;
                ScriptedPolicy { quality: 0.45 * frac, noise: 1.0 - 0.65 * frac }
            }
            // half medium, half expert
            Tier::MediumExpert => {
                if e % 2 == 0 {
                    ScriptedPolicy::medium()
                } else {
                    ScriptedPolicy::expert()
                }
            }
        };
        trajectories.push(rollout(&mut env, &policy, rng.next_u64()));
    }
    // reference returns for the normalised score (10 episodes each)
    let reference = |p: ScriptedPolicy, tag: u64| -> f64 {
        let mut total = 0.0;
        for i in 0..10 {
            let mut env = Env::new(env_id, seed ^ tag ^ i);
            total += rollout(&mut env, &p, seed ^ tag ^ (100 + i)).total_return();
        }
        total / 10.0
    };
    let random_return = reference(ScriptedPolicy::random(), 0x11);
    let expert_return = reference(ScriptedPolicy::expert(), 0x22);
    let rtg_scale = expert_return.abs().max(1.0);
    OfflineDataset { env: env_id, tier, trajectories, random_return, expert_return, rtg_scale }
}

/// One Decision-Transformer training batch in the AOT artifact layout:
/// rtg (b, CTX, 1), states (b, CTX, STATE_DIM), actions (b, CTX, ACT_DIM),
/// timesteps (b, CTX) i32, mask (b, CTX).
pub struct RlBatch {
    pub rtg: Vec<f32>,
    pub states: Vec<f32>,
    pub actions: Vec<f32>,
    pub timesteps: Vec<i32>,
    pub mask: Vec<f32>,
}

impl OfflineDataset {
    pub fn sample_batch(&self, rng: &mut Rng, b: usize) -> RlBatch {
        let mut batch = RlBatch {
            rtg: Vec::with_capacity(b * CTX),
            states: Vec::with_capacity(b * CTX * STATE_DIM),
            actions: Vec::with_capacity(b * CTX * ACT_DIM),
            timesteps: Vec::with_capacity(b * CTX),
            mask: Vec::with_capacity(b * CTX),
        };
        for _ in 0..b {
            let traj = &self.trajectories[rng.below(self.trajectories.len())];
            let rtg = traj.rtg();
            let t_len = traj.len();
            // random window end (inclusive), left-padded to CTX
            let end = rng.below(t_len) + 1; // 1..=t_len
            let start = end.saturating_sub(CTX);
            let n = end - start;
            let pad = CTX - n;
            for _ in 0..pad {
                batch.rtg.push(0.0);
                batch.states.extend(std::iter::repeat(0.0).take(STATE_DIM));
                batch.actions.extend(std::iter::repeat(0.0).take(ACT_DIM));
                batch.timesteps.push(0);
                batch.mask.push(0.0);
            }
            for t in start..end {
                batch.rtg.push((rtg[t] / self.rtg_scale) as f32);
                batch
                    .states
                    .extend_from_slice(&traj.states[t * STATE_DIM..(t + 1) * STATE_DIM]);
                batch
                    .actions
                    .extend_from_slice(&traj.actions[t * ACT_DIM..(t + 1) * ACT_DIM]);
                batch.timesteps.push(t as i32);
                batch.mask.push(1.0);
            }
        }
        batch
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn expert_beats_medium_beats_random_in_all_envs() {
        for env_id in ALL_ENVS {
            let ret = |p: ScriptedPolicy| {
                let mut total = 0.0;
                for s in 0..5u64 {
                    let mut env = Env::new(env_id, 1000 + s);
                    total += rollout(&mut env, &p, 2000 + s).total_return();
                }
                total / 5.0
            };
            let (e, m, r) = (
                ret(ScriptedPolicy::expert()),
                ret(ScriptedPolicy::medium()),
                ret(ScriptedPolicy::random()),
            );
            assert!(e > m + 1.0, "{}: expert {e} !>> medium {m}", env_id.name());
            assert!(m > r, "{}: medium {m} !> random {r}", env_id.name());
        }
    }

    #[test]
    fn episode_fixed_length_and_shapes() {
        let mut env = Env::new(EnvId::Hopper, 3);
        let traj = rollout(&mut env, &ScriptedPolicy::medium(), 4);
        assert_eq!(traj.len(), EPISODE_LEN);
        assert_eq!(traj.states.len(), EPISODE_LEN * STATE_DIM);
        assert_eq!(traj.actions.len(), EPISODE_LEN * ACT_DIM);
    }

    #[test]
    fn rtg_is_suffix_sum() {
        let traj = Trajectory {
            states: vec![],
            actions: vec![],
            rewards: vec![1.0, 2.0, 3.0],
        };
        assert_eq!(traj.rtg(), vec![6.0, 5.0, 3.0]);
    }

    #[test]
    fn dataset_tiers_have_ordered_mean_returns() {
        let env_id = EnvId::HalfCheetah;
        let mean_ret = |tier: Tier| {
            let ds = generate_dataset(env_id, tier, 12, 9);
            ds.trajectories.iter().map(Trajectory::total_return).sum::<f64>() / 12.0
        };
        let m = mean_ret(Tier::Medium);
        let me = mean_ret(Tier::MediumExpert);
        assert!(me > m, "med-expert {me} !> medium {m}");
    }

    #[test]
    fn batch_layout_and_padding() {
        let ds = generate_dataset(EnvId::Walker, Tier::Medium, 4, 5);
        let mut rng = Rng::new(1);
        let b = 8;
        let batch = ds.sample_batch(&mut rng, b);
        assert_eq!(batch.rtg.len(), b * CTX);
        assert_eq!(batch.states.len(), b * CTX * STATE_DIM);
        assert_eq!(batch.actions.len(), b * CTX * ACT_DIM);
        assert_eq!(batch.mask.len(), b * CTX);
        // masked slots must be zeroed
        for i in 0..b * CTX {
            if batch.mask[i] == 0.0 {
                assert_eq!(batch.rtg[i], 0.0);
                assert!(batch.states[i * STATE_DIM..(i + 1) * STATE_DIM]
                    .iter()
                    .all(|&x| x == 0.0));
            }
        }
        // every row ends with a live slot (right-aligned windows)
        for row in 0..b {
            assert_eq!(batch.mask[row * CTX + CTX - 1], 1.0);
        }
    }

    #[test]
    fn actions_clipped_to_unit_box() {
        let mut env = Env::new(EnvId::Ant, 7);
        let mut rng = Rng::new(8);
        let p = ScriptedPolicy::expert();
        for _ in 0..50 {
            let a = p.act(&env, &mut rng);
            assert!(a.iter().all(|x| x.abs() <= 1.0));
            let (_, _, done) = env.step(&a);
            if done {
                break;
            }
        }
    }

    #[test]
    fn normalised_score_reference_sane() {
        let ds = generate_dataset(EnvId::Hopper, Tier::Medium, 6, 13);
        assert!(
            ds.expert_return > ds.random_return + 1.0,
            "expert {} vs random {}",
            ds.expert_return,
            ds.random_return
        );
    }
}
