//! Event-forecasting substrate (paper §4.2, Table 2): marked temporal
//! point processes.
//!
//! The paper's 8 datasets (MIMIC, Wiki, Reddit, Mooc, StackOverflow, Sin,
//! Uber, Taxi) are event streams with irregular times and (for 5 of them)
//! categorical marks. We simulate them with a multivariate Hawkes process
//! (Ogata thinning) whose presets control mark cardinality, base rate,
//! self/cross-excitation (burstiness) and decay — plus a sine-modulated
//! inhomogeneous Poisson process for the paper's synthetic "Sin" dataset
//! and daily-periodic variants for Uber/Taxi.

use crate::util::rng::Rng;

pub const SEQ_LEN: usize = 64; // matches aot.py EF preset
pub const MAX_MARKS: usize = 16;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum EfDataset {
    Mimic,
    Wiki,
    Reddit,
    Mooc,
    StackOverflow,
    Sin,
    Uber,
    Taxi,
}

pub const ALL: [EfDataset; 8] = [
    EfDataset::Mimic,
    EfDataset::Wiki,
    EfDataset::Reddit,
    EfDataset::Mooc,
    EfDataset::StackOverflow,
    EfDataset::Sin,
    EfDataset::Uber,
    EfDataset::Taxi,
];

impl EfDataset {
    pub fn name(self) -> &'static str {
        match self {
            EfDataset::Mimic => "MIMIC",
            EfDataset::Wiki => "Wiki",
            EfDataset::Reddit => "Reddit",
            EfDataset::Mooc => "Mooc",
            EfDataset::StackOverflow => "StackOverflow",
            EfDataset::Sin => "Sin",
            EfDataset::Uber => "Uber",
            EfDataset::Taxi => "Taxi",
        }
    }

    /// Marked datasets get a real mark distribution; the paper's Sin,
    /// Uber and Taxi have no marks (we emit mark 0 and skip Acc).
    pub fn has_marks(self) -> bool {
        !matches!(self, EfDataset::Sin | EfDataset::Uber | EfDataset::Taxi)
    }

    pub fn n_marks(self) -> usize {
        match self {
            EfDataset::Mimic => 8,      // diagnosis codes
            EfDataset::Wiki => 6,       // edit action types
            EfDataset::Reddit => 12,    // subreddit-ish categories
            EfDataset::Mooc => 10,      // course actions
            EfDataset::StackOverflow => 14, // badge types
            _ => 1,
        }
    }

    fn params(self) -> EfParams {
        match self {
            // bursty clinical visits, strong self-excitation
            EfDataset::Mimic => EfParams { mu: 0.4, alpha: 0.55, beta: 2.0, sin_amp: 0.0, sin_period: 0.0 },
            // edit storms on hot pages
            EfDataset::Wiki => EfParams { mu: 0.6, alpha: 0.7, beta: 4.0, sin_amp: 0.0, sin_period: 0.0 },
            // heavy-traffic social stream
            EfDataset::Reddit => EfParams { mu: 1.2, alpha: 0.5, beta: 3.0, sin_amp: 0.0, sin_period: 0.0 },
            // session-structured course activity
            EfDataset::Mooc => EfParams { mu: 0.8, alpha: 0.65, beta: 5.0, sin_amp: 0.0, sin_period: 0.0 },
            // slower, weakly-excited award stream
            EfDataset::StackOverflow => EfParams { mu: 0.5, alpha: 0.3, beta: 1.0, sin_amp: 0.0, sin_period: 0.0 },
            // the paper's synthetic: sine-modulated Poisson, period 4π
            EfDataset::Sin => EfParams { mu: 1.0, alpha: 0.0, beta: 1.0, sin_amp: 0.9, sin_period: 4.0 * std::f64::consts::PI },
            // daily-periodic pickups with mild clustering
            EfDataset::Uber => EfParams { mu: 0.9, alpha: 0.25, beta: 2.0, sin_amp: 0.6, sin_period: 8.0 },
            EfDataset::Taxi => EfParams { mu: 1.4, alpha: 0.2, beta: 3.0, sin_amp: 0.5, sin_period: 6.0 },
        }
    }
}

struct EfParams {
    /// base intensity per mark
    mu: f64,
    /// total branching ratio (self+cross excitation), < 1 for stability
    alpha: f64,
    /// exponential kernel decay
    beta: f64,
    /// sinusoidal modulation of the base rate (Sin/Uber/Taxi)
    sin_amp: f64,
    sin_period: f64,
}

/// One event sequence: absolute times (strictly increasing) and marks.
pub struct EventSeq {
    pub times: Vec<f32>,
    pub marks: Vec<i32>,
}

/// Simulate one sequence of exactly SEQ_LEN events via Ogata thinning.
pub fn simulate(ds: EfDataset, seed: u64) -> EventSeq {
    let p = ds.params();
    let m = ds.n_marks();
    let mut rng = Rng::new(seed ^ (ds as u64).wrapping_mul(0xE7E1_1ED5));

    // per-mark excitation matrix: alpha distributed with a dominant
    // diagonal (events of a type mostly excite their own type)
    let mut excite = vec![0.0f64; m * m];
    for i in 0..m {
        for j in 0..m {
            let w = if i == j { 0.7 } else { 0.3 / (m.max(2) - 1) as f64 };
            excite[i * m + j] = p.alpha * w * p.beta; // kernel: a·exp(-beta t)
        }
    }

    let mut times = Vec::with_capacity(SEQ_LEN);
    let mut marks = Vec::with_capacity(SEQ_LEN);
    // exponentially-decaying per-mark excitation state
    let mut state = vec![0.0f64; m];
    let mut t = 0.0f64;

    // base intensity of one mark at time t (sine-modulated for Sin/Uber/Taxi)
    let base = |t: f64, p: &EfParams| -> f64 {
        let modulation = if p.sin_amp > 0.0 {
            1.0 + p.sin_amp * (std::f64::consts::TAU * t / p.sin_period).sin()
        } else {
            1.0
        };
        p.mu / m as f64 * modulation.max(0.05)
    };

    while times.len() < SEQ_LEN {
        // upper bound on total intensity (state only decays between events)
        let total_state: f64 = state.iter().sum();
        let lambda_bar = p.mu * (1.0 + p.sin_amp) + total_state;
        let dt = rng.exponential(lambda_bar.max(1e-9));
        t += dt;
        // decay state to time t
        let decay = (-p.beta * dt).exp();
        for s in state.iter_mut() {
            *s *= decay;
        }
        // intensity per mark at t
        let lam: Vec<f64> = (0..m).map(|mk| base(t, &p) + state[mk]).collect();
        let lam_total: f64 = lam.iter().sum();
        if rng.uniform() < lam_total / lambda_bar {
            let mk = rng.categorical(&lam);
            times.push(t as f32);
            marks.push(mk as i32);
            // excite
            for (j, s) in state.iter_mut().enumerate() {
                *s += excite[mk * m + j];
            }
        }
    }
    EventSeq { times, marks }
}

/// Flattened batch for the AOT artifact:
/// (times: (b, SEQ_LEN), marks: (b, SEQ_LEN)).
pub fn batch(ds: EfDataset, rng: &mut Rng, b: usize) -> (Vec<f32>, Vec<i32>) {
    let mut times = Vec::with_capacity(b * SEQ_LEN);
    let mut marks = Vec::with_capacity(b * SEQ_LEN);
    for _ in 0..b {
        let seq = simulate(ds, rng.next_u64());
        times.extend_from_slice(&seq.times);
        marks.extend_from_slice(&seq.marks);
    }
    (times, marks)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn times_strictly_increasing_all_presets() {
        for ds in ALL {
            let s = simulate(ds, 1);
            assert_eq!(s.times.len(), SEQ_LEN);
            for w in s.times.windows(2) {
                assert!(w[1] > w[0], "{}: times not increasing", ds.name());
            }
        }
    }

    #[test]
    fn marks_in_range() {
        for ds in ALL {
            let s = simulate(ds, 2);
            let m = ds.n_marks() as i32;
            assert!(m as usize <= MAX_MARKS);
            for mk in &s.marks {
                assert!(*mk >= 0 && *mk < m);
            }
            if !ds.has_marks() {
                assert!(s.marks.iter().all(|&x| x == 0));
            }
        }
    }

    #[test]
    fn hawkes_is_burstier_than_poisson() {
        // coefficient of variation of inter-event gaps: > 1 for a
        // self-exciting process, ≈ 1 for Poisson-like Sin (per window).
        let cv = |ds: EfDataset| {
            let mut gaps = Vec::new();
            for seed in 0..24 {
                let s = simulate(ds, 100 + seed);
                for w in s.times.windows(2) {
                    gaps.push((w[1] - w[0]) as f64);
                }
            }
            let n = gaps.len() as f64;
            let mean = gaps.iter().sum::<f64>() / n;
            let var = gaps.iter().map(|g| (g - mean) * (g - mean)).sum::<f64>() / n;
            var.sqrt() / mean
        };
        let cv_wiki = cv(EfDataset::Wiki);
        let cv_sin = cv(EfDataset::Sin);
        assert!(
            cv_wiki > cv_sin + 0.15,
            "wiki cv {cv_wiki} should exceed sin cv {cv_sin}"
        );
        assert!(cv_wiki > 1.1, "hawkes cv {cv_wiki} should be > 1");
    }

    #[test]
    fn marked_datasets_use_multiple_marks() {
        let s = simulate(EfDataset::Reddit, 7);
        let distinct: std::collections::BTreeSet<i32> = s.marks.iter().cloned().collect();
        assert!(distinct.len() >= 3, "expected mark diversity, got {distinct:?}");
    }

    #[test]
    fn deterministic_per_seed() {
        let a = simulate(EfDataset::Mooc, 42);
        let b = simulate(EfDataset::Mooc, 42);
        assert_eq!(a.times, b.times);
        assert_eq!(a.marks, b.marks);
    }

    #[test]
    fn batch_shapes() {
        let mut rng = Rng::new(3);
        let (t, m) = batch(EfDataset::Taxi, &mut rng, 4);
        assert_eq!(t.len(), 4 * SEQ_LEN);
        assert_eq!(m.len(), 4 * SEQ_LEN);
    }
}
