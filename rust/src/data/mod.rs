//! Synthetic dataset substrates for all 38 paper datasets (DESIGN.md §3
//! documents each substitution):
//!
//! * `tsf`    — 8 forecasting series (Weather/Exchange/Traffic/ECL/ETT*)
//! * `tsc`    — 10 UEA-style classification datasets
//! * `events` — 8 marked temporal point processes (Hawkes simulator)
//! * `rl`     — 4 locomotion-style environments × 3 D4RL-style dataset
//!              tiers (Medium / Medium-Replay / Medium-Expert)
//!
//! Every generator is seeded and deterministic; dimensions mirror the AOT
//! presets in python/compile/aot.py (asserted against manifest meta at
//! load time by the coordinator).

pub mod events;
pub mod rl;
pub mod tsc;
pub mod tsf;
