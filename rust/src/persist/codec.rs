//! Versioned binary codec for [`crate::serve::StreamSession`] state.
//!
//! The paper's constant-memory claim makes a live session a small flat
//! blob; this codec is the ONE wire/disk framing for that blob, shared by
//! the executor spill tier, the `snapshot`/`restore` wire ops and the
//! `aaren state` CLI. Layout (all integers little-endian):
//!
//! ```text
//!   offset  size  field
//!   0       4     magic  "AAS1"
//!   4       2     version (u16)            — 1 = raw, 2 = compressed
//!   6       1     backend tag (u8)         — see BackendTag
//!   7       1     reserved (must be 0)
//!   8       4     channels (u32)
//!   12      8     tokens_seen (u64)
//!   20      4     state length (u32)       — COUNT of f32s, not bytes
//!   24      …     state payload            — see below
//!   end−4   4     crc32 (IEEE) of every byte before it
//! ```
//!
//! **Version 1** payload: raw little-endian f32 bit patterns, 4·n bytes.
//! **Version 2** payload: the same bit patterns XOR-delta'd against the
//! previous f32 (lag-1) and LEB128-varint encoded — runs of repeated
//! values (tf KV cache padding, zero-heavy states) shrink to one byte per
//! f32. Both framings are **bitwise exact** on decode (NaNs, −0.0 and
//! subnormals included), which is what makes a restored session resume
//! with outputs bitwise identical to a never-snapshotted twin.
//! [`encode`] always writes version 1 (so existing blob byte-equality
//! guarantees hold); [`encode_auto`] writes version 2 only when it is
//! strictly smaller. Decoders accept both.
//!
//! # Version policy
//!
//! `VERSION` is bumped on ANY layout change; decoders reject unknown
//! versions (and unknown backend tags) outright rather than guessing —
//! migration across versions is an explicit offline conversion, never a
//! silent reinterpretation. The magic makes a truncated/foreign file fail
//! fast; the trailing CRC catches payload corruption that the header
//! checks cannot.

use anyhow::{bail, ensure, Result};

/// File/wire magic: Attention-As-an-rnn Session state, layout family 1.
pub const MAGIC: [u8; 4] = *b"AAS1";

/// Raw-payload codec version — what [`encode`] writes.
pub const VERSION: u16 = 1;

/// Compressed-payload codec version (XOR-delta + varint) — what
/// [`encode_auto`] writes when it wins.
pub const VERSION_COMPRESSED: u16 = 2;

/// Fixed header length in bytes (everything before the payload).
pub const HEADER_LEN: usize = 24;

/// Which session family a snapshot captures. The tag is part of the wire
/// format — variants must keep their discriminants forever.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendTag {
    /// `NativeScanSession` on the Aaren kernel: q, then (m, u, w).
    Aaren = 0,
    /// `NativeTfSession`: the live k rows then the live v rows.
    Tf = 1,
    /// `NativeScanSession` on the minGRU kernel: the (a, b) row.
    MinGru = 2,
    /// `NativeScanSession` on the minLSTM kernel: the (a, b) row.
    MinLstm = 3,
    /// `NativeScanSession` on the average-attention kernel: (n, sum).
    AvgAttn = 4,
}

impl BackendTag {
    pub fn from_u8(tag: u8) -> Result<BackendTag> {
        match tag {
            0 => Ok(BackendTag::Aaren),
            1 => Ok(BackendTag::Tf),
            2 => Ok(BackendTag::MinGru),
            3 => Ok(BackendTag::MinLstm),
            4 => Ok(BackendTag::AvgAttn),
            other => bail!("unknown session backend tag {other}"),
        }
    }

    /// The wire `kind` string this tag corresponds to.
    pub fn kind(self) -> &'static str {
        match self {
            BackendTag::Aaren => "aaren",
            BackendTag::Tf => "tf",
            BackendTag::MinGru => "mingru",
            BackendTag::MinLstm => "minlstm",
            BackendTag::AvgAttn => "avg_attn",
        }
    }
}

/// A decoded session snapshot: the session-family tag, its shape
/// metadata and the flat f32 state the owning session type knows how to
/// reinterpret (`export_state` / `import_state`).
#[derive(Clone, Debug, PartialEq)]
pub struct Snapshot {
    pub backend: BackendTag,
    pub channels: usize,
    pub tokens_seen: u64,
    pub state: Vec<f32>,
}

/// Snapshot metadata without the payload — what `snapshot` replies and
/// `aaren state inspect` print, decodable from the header alone.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Meta {
    pub backend: BackendTag,
    pub channels: usize,
    pub tokens_seen: u64,
    /// payload length in f32 elements
    pub state_len: usize,
}

/// CRC-32 (IEEE 802.3, reflected, init/xorout 0xFFFFFFFF) — the classic
/// zlib polynomial, computed bitwise (blobs are small; no table needed).
pub fn crc32(data: &[u8]) -> u32 {
    let mut crc = 0xFFFF_FFFFu32;
    for &b in data {
        crc ^= u32::from(b);
        for _ in 0..8 {
            let mask = (crc & 1).wrapping_neg();
            crc = (crc >> 1) ^ (0xEDB8_8320 & mask);
        }
    }
    !crc
}

fn encode_with(snap: &Snapshot, version: u16, payload: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(HEADER_LEN + payload.len() + 4);
    out.extend_from_slice(&MAGIC);
    out.extend_from_slice(&version.to_le_bytes());
    out.push(snap.backend as u8);
    out.push(0); // reserved
    out.extend_from_slice(&(snap.channels as u32).to_le_bytes());
    out.extend_from_slice(&snap.tokens_seen.to_le_bytes());
    out.extend_from_slice(&(snap.state.len() as u32).to_le_bytes());
    out.extend_from_slice(payload);
    let crc = crc32(&out);
    out.extend_from_slice(&crc.to_le_bytes());
    out
}

/// Encode a snapshot into the version-1 (raw payload) framing. Stable:
/// the bytes this produces for a given snapshot never change, which is
/// what the resident==boxed and cross-process migration byte-equality
/// guarantees lean on.
pub fn encode(snap: &Snapshot) -> Vec<u8> {
    let mut payload = Vec::with_capacity(snap.state.len() * 4);
    for &x in &snap.state {
        payload.extend_from_slice(&x.to_le_bytes());
    }
    encode_with(snap, VERSION, &payload)
}

/// LEB128 varint for one u32.
fn push_varint(out: &mut Vec<u8>, mut v: u32) {
    while v >= 0x80 {
        out.push((v as u8 & 0x7F) | 0x80);
        v >>= 7;
    }
    out.push(v as u8);
}

/// Version-2 payload: each f32's bit pattern XORed with the previous
/// one's (lag-1 delta, seed 0), varint encoded. Repeated values — the
/// dominant redundancy in padded tf KV snapshots — cost one byte each.
fn compress_state(state: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(state.len());
    let mut prev = 0u32;
    for &x in state {
        let bits = x.to_bits();
        push_varint(&mut out, bits ^ prev);
        prev = bits;
    }
    out
}

/// Bitwise inverse of [`compress_state`]; must consume the payload
/// exactly and yield exactly `state_len` f32s.
fn decompress_state(payload: &[u8], state_len: usize) -> Result<Vec<f32>> {
    let mut state = Vec::with_capacity(state_len.min(payload.len() + 1));
    let mut prev = 0u32;
    let mut i = 0;
    for n in 0..state_len {
        let mut v = 0u32;
        let mut shift = 0u32;
        loop {
            ensure!(i < payload.len(), "compressed payload truncated at f32 {n}");
            ensure!(shift < 32, "compressed payload varint overruns 32 bits at f32 {n}");
            let b = payload[i];
            i += 1;
            v |= u32::from(b & 0x7F) << shift;
            if b & 0x80 == 0 {
                break;
            }
            shift += 7;
        }
        prev ^= v;
        state.push(f32::from_bits(prev));
    }
    ensure!(i == payload.len(), "compressed payload has trailing bytes");
    Ok(state)
}

/// Encode with whichever framing is smaller: version 2 (compressed) when
/// it beats the raw payload, else version 1 byte-identical to [`encode`].
/// The spill tier uses this for tf KV snapshots, whose padded caches
/// compress well; incompressible states pay zero size or decode cost.
pub fn encode_auto(snap: &Snapshot) -> Vec<u8> {
    let compressed = compress_state(&snap.state);
    if compressed.len() < snap.state.len() * 4 {
        encode_with(snap, VERSION_COMPRESSED, &compressed)
    } else {
        encode(snap)
    }
}

fn le_u32(b: &[u8]) -> u32 {
    u32::from_le_bytes([b[0], b[1], b[2], b[3]])
}

/// Validate the header + CRC and return the metadata. Rejects truncated
/// blobs, foreign magic, unknown versions/tags, length mismatches and
/// payload corruption — everything `decode` would reject, without
/// materializing the payload.
pub fn meta(blob: &[u8]) -> Result<Meta> {
    ensure!(
        blob.len() >= HEADER_LEN + 4,
        "snapshot blob of {} bytes is shorter than the {}-byte header + crc",
        blob.len(),
        HEADER_LEN
    );
    ensure!(blob[0..4] == MAGIC, "bad snapshot magic (not an aaren session blob)");
    let version = u16::from_le_bytes([blob[4], blob[5]]);
    ensure!(
        version == VERSION || version == VERSION_COMPRESSED,
        "unsupported snapshot version {version} (this build reads versions {VERSION} and {VERSION_COMPRESSED})"
    );
    let backend = BackendTag::from_u8(blob[6])?;
    ensure!(blob[7] == 0, "nonzero reserved byte in snapshot header");
    let channels = le_u32(&blob[8..12]) as usize;
    let tokens_seen = u64::from_le_bytes(blob[12..20].try_into().expect("length checked"));
    let state_len = le_u32(&blob[20..24]) as usize;
    if version == VERSION {
        let want = HEADER_LEN + state_len * 4 + 4;
        ensure!(
            blob.len() == want,
            "snapshot blob is {} bytes, header promises {want}",
            blob.len()
        );
    } else {
        // version 2: the payload is variable-length; an upper bound
        // (5 varint bytes per f32) still catches grossly wrong headers,
        // and decode enforces exact consumption
        let payload = blob.len() - HEADER_LEN - 4;
        ensure!(
            payload <= state_len * 5,
            "compressed snapshot payload of {payload} bytes exceeds the {} f32s promised",
            state_len
        );
    }
    let crc_stored = le_u32(&blob[blob.len() - 4..]);
    let crc_actual = crc32(&blob[..blob.len() - 4]);
    ensure!(
        crc_stored == crc_actual,
        "snapshot crc mismatch (stored {crc_stored:08x}, computed {crc_actual:08x}) — blob is corrupt"
    );
    Ok(Meta { backend, channels, tokens_seen, state_len })
}

/// Decode a blob produced by [`encode`] or [`encode_auto`]. Bitwise
/// inverse of both: the returned f32s carry exactly the bit patterns
/// that were encoded, whichever payload framing carried them.
pub fn decode(blob: &[u8]) -> Result<Snapshot> {
    let meta = meta(blob)?;
    let payload = &blob[HEADER_LEN..blob.len() - 4];
    let state = if u16::from_le_bytes([blob[4], blob[5]]) == VERSION {
        let mut state = Vec::with_capacity(meta.state_len);
        for chunk in payload.chunks_exact(4) {
            state.push(f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]));
        }
        state
    } else {
        decompress_state(payload, meta.state_len)?
    };
    Ok(Snapshot {
        backend: meta.backend,
        channels: meta.channels,
        tokens_seen: meta.tokens_seen,
        state,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_snapshot(rng: &mut Rng) -> Snapshot {
        let channels = rng.below(16);
        let state_len = rng.below(64);
        Snapshot {
            backend: if rng.below(2) == 0 { BackendTag::Aaren } else { BackendTag::Tf },
            channels,
            tokens_seen: rng.below(1 << 40) as u64,
            // arbitrary BIT PATTERNS, not arbitrary values: NaNs, infs,
            // -0.0 and subnormals must all survive the round-trip
            state: (0..state_len).map(|_| f32::from_bits(rng.below(1 << 32) as u32)).collect(),
        }
    }

    #[test]
    fn roundtrip_preserves_every_bit() {
        let mut rng = Rng::new(7);
        for _ in 0..100 {
            let snap = random_snapshot(&mut rng);
            let blob = encode(&snap);
            let back = decode(&blob).unwrap();
            assert_eq!(back.backend, snap.backend);
            assert_eq!(back.channels, snap.channels);
            assert_eq!(back.tokens_seen, snap.tokens_seen);
            assert_eq!(back.state.len(), snap.state.len());
            for (a, b) in back.state.iter().zip(snap.state.iter()) {
                assert_eq!(a.to_bits(), b.to_bits(), "f32 bit pattern changed in roundtrip");
            }
        }
    }

    #[test]
    fn meta_matches_decode() {
        let mut rng = Rng::new(8);
        let snap = random_snapshot(&mut rng);
        let blob = encode(&snap);
        let m = meta(&blob).unwrap();
        assert_eq!(m.backend, snap.backend);
        assert_eq!(m.channels, snap.channels);
        assert_eq!(m.tokens_seen, snap.tokens_seen);
        assert_eq!(m.state_len, snap.state.len());
    }

    #[test]
    fn rejects_truncated_blobs() {
        let blob = encode(&Snapshot {
            backend: BackendTag::Aaren,
            channels: 4,
            tokens_seen: 9,
            state: vec![1.0; 10],
        });
        for cut in [0, 3, HEADER_LEN - 1, HEADER_LEN + 5, blob.len() - 1] {
            assert!(decode(&blob[..cut]).is_err(), "truncation to {cut} bytes must be rejected");
        }
        // ...and an over-long blob too
        let mut long = blob.clone();
        long.push(0);
        assert!(decode(&long).is_err());
    }

    #[test]
    fn rejects_corruption_anywhere() {
        let blob = encode(&Snapshot {
            backend: BackendTag::Tf,
            channels: 3,
            tokens_seen: 17,
            state: (0..12).map(|i| i as f32 * 0.5).collect(),
        });
        // flip one bit at every byte position: header corruption trips a
        // header check, payload corruption trips the crc — never silence
        for i in 0..blob.len() {
            let mut bad = blob.clone();
            bad[i] ^= 0x40;
            assert!(decode(&bad).is_err(), "flipped byte {i} must be rejected");
        }
    }

    #[test]
    fn rejects_wrong_version_and_tag() {
        let blob = encode(&Snapshot {
            backend: BackendTag::Aaren,
            channels: 2,
            tokens_seen: 1,
            state: vec![0.5, -0.5],
        });
        let refresh_crc = |mut b: Vec<u8>| -> Vec<u8> {
            let n = b.len();
            let crc = crc32(&b[..n - 4]);
            b[n - 4..].copy_from_slice(&crc.to_le_bytes());
            b
        };
        let mut wrong_version = blob.clone();
        wrong_version[4] = 99;
        let err = decode(&refresh_crc(wrong_version)).unwrap_err();
        assert!(format!("{err}").contains("version"), "got: {err}");
        let mut wrong_tag = blob.clone();
        wrong_tag[6] = 7;
        let err = decode(&refresh_crc(wrong_tag)).unwrap_err();
        assert!(format!("{err}").contains("backend tag"), "got: {err}");
    }

    #[test]
    fn crc32_known_vector() {
        // the classic zlib check value
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn every_backend_tag_round_trips() {
        for tag in
            [BackendTag::Aaren, BackendTag::Tf, BackendTag::MinGru, BackendTag::MinLstm, BackendTag::AvgAttn]
        {
            assert_eq!(BackendTag::from_u8(tag as u8).unwrap(), tag);
            let snap =
                Snapshot { backend: tag, channels: 3, tokens_seen: 5, state: vec![0.25; 7] };
            assert_eq!(decode(&encode(&snap)).unwrap().backend, tag);
        }
        assert!(BackendTag::from_u8(5).is_err());
    }

    #[test]
    fn compressed_roundtrip_preserves_every_bit() {
        // same property as the raw framing, through the XOR-delta +
        // varint payload: arbitrary bit patterns survive exactly
        let mut rng = Rng::new(21);
        for _ in 0..100 {
            let snap = random_snapshot(&mut rng);
            let blob = encode_with(&snap, VERSION_COMPRESSED, &compress_state(&snap.state));
            let back = decode(&blob).unwrap();
            assert_eq!(back.backend, snap.backend);
            assert_eq!(back.channels, snap.channels);
            assert_eq!(back.tokens_seen, snap.tokens_seen);
            assert_eq!(back.state.len(), snap.state.len());
            for (a, b) in back.state.iter().zip(snap.state.iter()) {
                assert_eq!(a.to_bits(), b.to_bits(), "bit pattern changed in compressed roundtrip");
            }
            assert_eq!(meta(&blob).unwrap().state_len, snap.state.len());
        }
    }

    #[test]
    fn encode_auto_compresses_repetitive_states_and_falls_back_otherwise() {
        // a padded-KV-shaped state (long runs of repeated values)
        // shrinks; random bit patterns don't, and fall back to the raw
        // framing byte-identically
        let mut state = vec![0.0f32; 400];
        state[..32].fill(1.5);
        let snap = Snapshot { backend: BackendTag::Tf, channels: 8, tokens_seen: 4, state };
        let auto = encode_auto(&snap);
        let raw = encode(&snap);
        assert!(auto.len() < raw.len() / 2, "{} vs {}", auto.len(), raw.len());
        assert_eq!(decode(&auto).unwrap(), decode(&raw).unwrap());

        let mut rng = Rng::new(33);
        let noisy = Snapshot {
            backend: BackendTag::Tf,
            channels: 8,
            tokens_seen: 4,
            state: (0..100).map(|_| f32::from_bits(rng.below(1 << 32) as u32)).collect(),
        };
        assert_eq!(encode_auto(&noisy), encode(&noisy), "incompressible must stay raw");
    }

    #[test]
    fn compressed_rejects_corruption_and_length_lies() {
        let snap = Snapshot {
            backend: BackendTag::Tf,
            channels: 2,
            tokens_seen: 3,
            state: vec![0.5; 64],
        };
        let blob = encode_auto(&snap);
        assert_eq!(u16::from_le_bytes([blob[4], blob[5]]), VERSION_COMPRESSED);
        // flip one bit at every byte position — header checks or CRC
        // must catch all of them
        for i in 0..blob.len() {
            let mut bad = blob.clone();
            bad[i] ^= 0x40;
            assert!(decode(&bad).is_err(), "flipped byte {i} must be rejected");
        }
        // a payload that decodes to the wrong f32 count (CRC-valid) is
        // still refused: state_len says 64, payload carries 63
        let short = Snapshot { tokens_seen: 3, state: vec![0.5; 63], ..snap.clone() };
        let mut lied = encode_with(&short, VERSION_COMPRESSED, &compress_state(&short.state));
        lied[20..24].copy_from_slice(&64u32.to_le_bytes());
        let n = lied.len();
        let crc = crc32(&lied[..n - 4]);
        lied[n - 4..].copy_from_slice(&crc.to_le_bytes());
        let err = decode(&lied).unwrap_err();
        assert!(format!("{err}").contains("truncated"), "got: {err}");
    }
}
