//! Versioned binary codec for [`crate::serve::StreamSession`] state.
//!
//! The paper's constant-memory claim makes a live session a small flat
//! blob; this codec is the ONE wire/disk framing for that blob, shared by
//! the executor spill tier, the `snapshot`/`restore` wire ops and the
//! `aaren state` CLI. Layout (all integers little-endian):
//!
//! ```text
//!   offset  size  field
//!   0       4     magic  "AAS1"
//!   4       2     version (u16)            — currently 1
//!   6       1     backend tag (u8)         — 0 = aaren, 1 = tf
//!   7       1     reserved (must be 0)
//!   8       4     channels (u32)
//!   12      8     tokens_seen (u64)
//!   20      4     state length (u32)       — COUNT of f32s, not bytes
//!   24      4·n   state payload            — raw little-endian f32 bits
//!   24+4·n  4     crc32 (IEEE) of bytes [0, 24+4·n)
//! ```
//!
//! The payload is raw f32 **bit patterns** — encode → decode is bitwise
//! exact (NaNs, −0.0 and subnormals included), which is what makes a
//! restored session resume with outputs bitwise identical to a
//! never-snapshotted twin.
//!
//! # Version policy
//!
//! `VERSION` is bumped on ANY layout change; decoders reject unknown
//! versions (and unknown backend tags) outright rather than guessing —
//! migration across versions is an explicit offline conversion, never a
//! silent reinterpretation. The magic makes a truncated/foreign file fail
//! fast; the trailing CRC catches payload corruption that the header
//! checks cannot.

use anyhow::{bail, ensure, Result};

/// File/wire magic: Attention-As-an-rnn Session state, layout family 1.
pub const MAGIC: [u8; 4] = *b"AAS1";

/// Current codec version; bumped on any layout change.
pub const VERSION: u16 = 1;

/// Fixed header length in bytes (everything before the payload).
pub const HEADER_LEN: usize = 24;

/// Which session family a snapshot captures. The tag is part of the wire
/// format — variants must keep their discriminants forever.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendTag {
    /// `NativeAarenSession`: q, then the (m, u, w) accumulator.
    Aaren = 0,
    /// `NativeTfSession`: the live k rows then the live v rows.
    Tf = 1,
}

impl BackendTag {
    pub fn from_u8(tag: u8) -> Result<BackendTag> {
        match tag {
            0 => Ok(BackendTag::Aaren),
            1 => Ok(BackendTag::Tf),
            other => bail!("unknown session backend tag {other}"),
        }
    }

    /// The wire `kind` string this tag corresponds to.
    pub fn kind(self) -> &'static str {
        match self {
            BackendTag::Aaren => "aaren",
            BackendTag::Tf => "tf",
        }
    }
}

/// A decoded session snapshot: the session-family tag, its shape
/// metadata and the flat f32 state the owning session type knows how to
/// reinterpret (`export_state` / `import_state`).
#[derive(Clone, Debug, PartialEq)]
pub struct Snapshot {
    pub backend: BackendTag,
    pub channels: usize,
    pub tokens_seen: u64,
    pub state: Vec<f32>,
}

/// Snapshot metadata without the payload — what `snapshot` replies and
/// `aaren state inspect` print, decodable from the header alone.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Meta {
    pub backend: BackendTag,
    pub channels: usize,
    pub tokens_seen: u64,
    /// payload length in f32 elements
    pub state_len: usize,
}

/// CRC-32 (IEEE 802.3, reflected, init/xorout 0xFFFFFFFF) — the classic
/// zlib polynomial, computed bitwise (blobs are small; no table needed).
pub fn crc32(data: &[u8]) -> u32 {
    let mut crc = 0xFFFF_FFFFu32;
    for &b in data {
        crc ^= u32::from(b);
        for _ in 0..8 {
            let mask = (crc & 1).wrapping_neg();
            crc = (crc >> 1) ^ (0xEDB8_8320 & mask);
        }
    }
    !crc
}

/// Encode a snapshot into the versioned length-prefixed framing above.
pub fn encode(snap: &Snapshot) -> Vec<u8> {
    let mut out = Vec::with_capacity(HEADER_LEN + snap.state.len() * 4 + 4);
    out.extend_from_slice(&MAGIC);
    out.extend_from_slice(&VERSION.to_le_bytes());
    out.push(snap.backend as u8);
    out.push(0); // reserved
    out.extend_from_slice(&(snap.channels as u32).to_le_bytes());
    out.extend_from_slice(&snap.tokens_seen.to_le_bytes());
    out.extend_from_slice(&(snap.state.len() as u32).to_le_bytes());
    for &x in &snap.state {
        out.extend_from_slice(&x.to_le_bytes());
    }
    let crc = crc32(&out);
    out.extend_from_slice(&crc.to_le_bytes());
    out
}

fn le_u32(b: &[u8]) -> u32 {
    u32::from_le_bytes([b[0], b[1], b[2], b[3]])
}

/// Validate the header + CRC and return the metadata. Rejects truncated
/// blobs, foreign magic, unknown versions/tags, length mismatches and
/// payload corruption — everything `decode` would reject, without
/// materializing the payload.
pub fn meta(blob: &[u8]) -> Result<Meta> {
    ensure!(
        blob.len() >= HEADER_LEN + 4,
        "snapshot blob of {} bytes is shorter than the {}-byte header + crc",
        blob.len(),
        HEADER_LEN
    );
    ensure!(blob[0..4] == MAGIC, "bad snapshot magic (not an aaren session blob)");
    let version = u16::from_le_bytes([blob[4], blob[5]]);
    ensure!(
        version == VERSION,
        "unsupported snapshot version {version} (this build reads version {VERSION})"
    );
    let backend = BackendTag::from_u8(blob[6])?;
    ensure!(blob[7] == 0, "nonzero reserved byte in snapshot header");
    let channels = le_u32(&blob[8..12]) as usize;
    let tokens_seen = u64::from_le_bytes(blob[12..20].try_into().expect("length checked"));
    let state_len = le_u32(&blob[20..24]) as usize;
    let want = HEADER_LEN + state_len * 4 + 4;
    ensure!(
        blob.len() == want,
        "snapshot blob is {} bytes, header promises {want}",
        blob.len()
    );
    let crc_stored = le_u32(&blob[blob.len() - 4..]);
    let crc_actual = crc32(&blob[..blob.len() - 4]);
    ensure!(
        crc_stored == crc_actual,
        "snapshot crc mismatch (stored {crc_stored:08x}, computed {crc_actual:08x}) — blob is corrupt"
    );
    Ok(Meta { backend, channels, tokens_seen, state_len })
}

/// Decode a blob produced by [`encode`]. Bitwise inverse of `encode`:
/// the returned f32s carry exactly the bit patterns that were encoded.
pub fn decode(blob: &[u8]) -> Result<Snapshot> {
    let meta = meta(blob)?;
    let mut state = Vec::with_capacity(meta.state_len);
    for chunk in blob[HEADER_LEN..HEADER_LEN + meta.state_len * 4].chunks_exact(4) {
        state.push(f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]));
    }
    Ok(Snapshot {
        backend: meta.backend,
        channels: meta.channels,
        tokens_seen: meta.tokens_seen,
        state,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_snapshot(rng: &mut Rng) -> Snapshot {
        let channels = rng.below(16);
        let state_len = rng.below(64);
        Snapshot {
            backend: if rng.below(2) == 0 { BackendTag::Aaren } else { BackendTag::Tf },
            channels,
            tokens_seen: rng.below(1 << 40) as u64,
            // arbitrary BIT PATTERNS, not arbitrary values: NaNs, infs,
            // -0.0 and subnormals must all survive the round-trip
            state: (0..state_len).map(|_| f32::from_bits(rng.below(1 << 32) as u32)).collect(),
        }
    }

    #[test]
    fn roundtrip_preserves_every_bit() {
        let mut rng = Rng::new(7);
        for _ in 0..100 {
            let snap = random_snapshot(&mut rng);
            let blob = encode(&snap);
            let back = decode(&blob).unwrap();
            assert_eq!(back.backend, snap.backend);
            assert_eq!(back.channels, snap.channels);
            assert_eq!(back.tokens_seen, snap.tokens_seen);
            assert_eq!(back.state.len(), snap.state.len());
            for (a, b) in back.state.iter().zip(snap.state.iter()) {
                assert_eq!(a.to_bits(), b.to_bits(), "f32 bit pattern changed in roundtrip");
            }
        }
    }

    #[test]
    fn meta_matches_decode() {
        let mut rng = Rng::new(8);
        let snap = random_snapshot(&mut rng);
        let blob = encode(&snap);
        let m = meta(&blob).unwrap();
        assert_eq!(m.backend, snap.backend);
        assert_eq!(m.channels, snap.channels);
        assert_eq!(m.tokens_seen, snap.tokens_seen);
        assert_eq!(m.state_len, snap.state.len());
    }

    #[test]
    fn rejects_truncated_blobs() {
        let blob = encode(&Snapshot {
            backend: BackendTag::Aaren,
            channels: 4,
            tokens_seen: 9,
            state: vec![1.0; 10],
        });
        for cut in [0, 3, HEADER_LEN - 1, HEADER_LEN + 5, blob.len() - 1] {
            assert!(decode(&blob[..cut]).is_err(), "truncation to {cut} bytes must be rejected");
        }
        // ...and an over-long blob too
        let mut long = blob.clone();
        long.push(0);
        assert!(decode(&long).is_err());
    }

    #[test]
    fn rejects_corruption_anywhere() {
        let blob = encode(&Snapshot {
            backend: BackendTag::Tf,
            channels: 3,
            tokens_seen: 17,
            state: (0..12).map(|i| i as f32 * 0.5).collect(),
        });
        // flip one bit at every byte position: header corruption trips a
        // header check, payload corruption trips the crc — never silence
        for i in 0..blob.len() {
            let mut bad = blob.clone();
            bad[i] ^= 0x40;
            assert!(decode(&bad).is_err(), "flipped byte {i} must be rejected");
        }
    }

    #[test]
    fn rejects_wrong_version_and_tag() {
        let blob = encode(&Snapshot {
            backend: BackendTag::Aaren,
            channels: 2,
            tokens_seen: 1,
            state: vec![0.5, -0.5],
        });
        let refresh_crc = |mut b: Vec<u8>| -> Vec<u8> {
            let n = b.len();
            let crc = crc32(&b[..n - 4]);
            b[n - 4..].copy_from_slice(&crc.to_le_bytes());
            b
        };
        let mut wrong_version = blob.clone();
        wrong_version[4] = 99;
        let err = decode(&refresh_crc(wrong_version)).unwrap_err();
        assert!(format!("{err}").contains("version"), "got: {err}");
        let mut wrong_tag = blob.clone();
        wrong_tag[6] = 7;
        let err = decode(&refresh_crc(wrong_tag)).unwrap_err();
        assert!(format!("{err}").contains("backend tag"), "got: {err}");
    }

    #[test]
    fn crc32_known_vector() {
        // the classic zlib check value
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }
}
