//! Session state persistence — the paper's constant-memory guarantee
//! turned into an operational capability.
//!
//! §3.3's point is that an Aaren stream's entire live state is a small
//! fixed-size blob (one (m, u, w) accumulator plus the query); even the
//! tf baseline's KV cache is a flat, self-describing buffer. This module
//! makes that blob a first-class artifact:
//!
//! * [`codec`] — the ONE versioned, length-prefixed, CRC-checked binary
//!   framing for session state (magic + version + backend tag + channels
//!   + tokens_seen + raw little-endian f32 payload). Encode → decode is
//!   bitwise exact, so a restored session resumes with outputs bitwise
//!   identical to a never-snapshotted twin.
//! * [`store`] — [`SnapshotStore`]: where spilled sessions live while
//!   not resident ([`MemStore`] in RAM, [`DirStore`] as atomic
//!   write-then-rename files, integrity-checked on load).
//!
//! Three consumers share these pieces (see `crate::serve`):
//!
//! * the **executor spill tier** — with `--spill-dir`, the TTL sweep
//!   snapshots idle native sessions to the store instead of destroying
//!   them, and `--max-resident-sessions` LRU-spills the coldest resident
//!   sessions, so resident count is bounded independent of total session
//!   count; a touched session is restored lazily on its next request;
//! * the **wire ops** `snapshot` / `restore` — a client can pull a
//!   session's state as a base64 blob and recreate it on another server
//!   (client-driven migration across shards/hosts, crash recovery);
//! * the **CLI** `aaren state export|import|inspect` — offline snapshot
//!   handling.

pub mod codec;
pub mod store;

pub use codec::{BackendTag, Meta, Snapshot};
pub use store::{DirStore, MemStore, SnapshotStore};
