//! Snapshot storage behind the executor spill tier: where evicted
//! sessions' codec blobs live while they are not resident in RAM.
//!
//! [`SnapshotStore`] is the narrow contract the serve executors program
//! against; two implementations ship:
//!
//! * [`MemStore`] — a HashMap. Spill-to-memory sounds pointless until you
//!   remember an Aaren blob is ~40 bytes while a resident tf session can
//!   hold megabytes of KV cache; it is also the deterministic store the
//!   tests and the LRU-cap logic run against.
//! * [`DirStore`] — one file per session (`sess-<id>.snap`) under a spill
//!   directory. Writes go to `sess-<id>.snap.tmp` (fsync'd), then
//!   `rename(2)` into place, then the DIRECTORY is fsync'd — a crash or
//!   power cut at any point leaves either the old complete blob or the
//!   new one under the live name, never a torn file, and a published
//!   rename is durable. Loads verify the codec framing + CRC; a corrupt
//!   file is QUARANTINED (renamed to `sess-<id>.snap.corrupt`, kept for
//!   forensics, dropped from the index) and reported as a structured
//!   `corrupt_snapshot` error — one structured failure, never a
//!   resurrected-garbage session and never a permanently wedged id.
//!   Opening a store sweeps stale `.tmp` files a crashed save left in
//!   its partition.
//!
//! Sharding: every executor shard opens the SAME directory with its own
//! `(shard, nshards)` partition, indexing only ids it routes
//! (`id % nshards == shard`). File names embed the id, ids are unique
//! across shards, so shards never contend on a file, and a restart with
//! a different shard count simply re-partitions the same files.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::fault::Kinded;
use crate::persist::codec;

/// Blob storage for spilled sessions, keyed by session id. Blobs are
/// `persist::codec` framings; implementations may verify integrity on
/// load and must never return a corrupt blob as if it were valid.
///
/// ```
/// use aaren::persist::{MemStore, SnapshotStore};
///
/// let mut store = MemStore::new();
/// store.put(7, b"blob").unwrap();
/// assert_eq!(store.get(7).unwrap().as_deref(), Some(&b"blob"[..]));
/// assert!(store.contains(7));
/// assert!(store.remove(7).unwrap());
/// assert!(store.get(7).unwrap().is_none());
/// ```
pub trait SnapshotStore: Send {
    /// Persist `blob` under `id`, replacing any previous snapshot.
    fn put(&mut self, id: u64, blob: &[u8]) -> Result<()>;
    /// Load the snapshot for `id`; `None` if absent. Corrupt stored data
    /// is an `Err`, not a `None` — the caller must be able to tell "never
    /// spilled" from "spilled and damaged".
    fn get(&mut self, id: u64) -> Result<Option<Vec<u8>>>;
    /// Drop the snapshot for `id`; returns whether one existed.
    fn remove(&mut self, id: u64) -> Result<bool>;
    /// Whether a snapshot for `id` exists.
    fn contains(&self, id: u64) -> bool;
    /// Number of snapshots held.
    fn len(&self) -> usize;
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
    /// All held ids (unordered).
    fn ids(&self) -> Vec<u64>;
}

/// In-memory store: the deterministic test double and the zero-IO tier.
#[derive(Default)]
pub struct MemStore {
    blobs: HashMap<u64, Vec<u8>>,
}

impl MemStore {
    pub fn new() -> MemStore {
        MemStore::default()
    }
}

impl SnapshotStore for MemStore {
    fn put(&mut self, id: u64, blob: &[u8]) -> Result<()> {
        self.blobs.insert(id, blob.to_vec());
        Ok(())
    }

    fn get(&mut self, id: u64) -> Result<Option<Vec<u8>>> {
        Ok(self.blobs.get(&id).cloned())
    }

    fn remove(&mut self, id: u64) -> Result<bool> {
        Ok(self.blobs.remove(&id).is_some())
    }

    fn contains(&self, id: u64) -> bool {
        self.blobs.contains_key(&id)
    }

    fn len(&self) -> usize {
        self.blobs.len()
    }

    fn ids(&self) -> Vec<u64> {
        self.blobs.keys().copied().collect()
    }
}

const SNAP_PREFIX: &str = "sess-";
const SNAP_SUFFIX: &str = ".snap";
const TMP_SUFFIX: &str = ".tmp";
const CORRUPT_SUFFIX: &str = ".corrupt";

fn id_of_file(name: &str) -> Option<u64> {
    name.strip_prefix(SNAP_PREFIX)?.strip_suffix(SNAP_SUFFIX)?.parse().ok()
}

/// Directory-backed store: `sess-<id>.snap` files, written atomically
/// (tmp + rename) and CRC-verified on load via the codec framing.
pub struct DirStore {
    dir: PathBuf,
    /// ids this partition owns, mirrored from the directory at open time
    /// and kept in sync by put/remove — `contains`/`len` never touch the
    /// filesystem on the executor hot path.
    index: std::collections::HashSet<u64>,
}

impl DirStore {
    /// Open (creating if needed) a store over `dir`, indexing every
    /// snapshot present.
    pub fn open(dir: &Path) -> Result<DirStore> {
        Self::open_partition(dir, 0, 1)
    }

    /// Open `dir` indexing only ids with `id % nshards == shard` — the
    /// form each executor shard uses so per-shard spill counts do not
    /// multiply by the shard count.
    pub fn open_partition(dir: &Path, shard: u64, nshards: u64) -> Result<DirStore> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating spill dir {}", dir.display()))?;
        let nshards = nshards.max(1);
        let mut index = std::collections::HashSet::new();
        for entry in std::fs::read_dir(dir)
            .with_context(|| format!("reading spill dir {}", dir.display()))?
        {
            let entry = entry?;
            let name = entry.file_name();
            let Some(name) = name.to_str() else { continue };
            // a `.tmp` is a save that crashed before publishing: its live
            // name (if any) still holds the last complete blob, so the
            // leftover is pure disk leak — swept here, by the partition
            // that owns the id (foreign-partition tmps belong to another
            // shard's sweep)
            if let Some(id) = name.strip_suffix(TMP_SUFFIX).and_then(id_of_file) {
                if id % nshards == shard {
                    let _ = std::fs::remove_file(entry.path());
                }
                continue;
            }
            if let Some(id) = id_of_file(name) {
                if id % nshards == shard {
                    index.insert(id);
                }
            }
        }
        Ok(DirStore { dir: dir.to_path_buf(), index })
    }

    fn path_of(&self, id: u64) -> PathBuf {
        self.dir.join(format!("{SNAP_PREFIX}{id}{SNAP_SUFFIX}"))
    }
}

impl SnapshotStore for DirStore {
    fn put(&mut self, id: u64, blob: &[u8]) -> Result<()> {
        use std::io::Write as _;
        let live = self.path_of(id);
        // crash-safe publish: write + fsync the tmp so its bytes are on
        // disk BEFORE the rename can make them visible, rename into the
        // live name, then fsync the directory so the rename itself
        // survives a power cut — at every point the live name holds
        // either the previous complete blob or the new one, never a torn
        // file
        let tmp = self.dir.join(format!("{SNAP_PREFIX}{id}{SNAP_SUFFIX}{TMP_SUFFIX}"));
        {
            let mut f = std::fs::File::create(&tmp)
                .with_context(|| format!("creating spill tmp {}", tmp.display()))?;
            f.write_all(blob)
                .with_context(|| format!("writing spill tmp {}", tmp.display()))?;
            f.sync_all()
                .with_context(|| format!("syncing spill tmp {}", tmp.display()))?;
        }
        std::fs::rename(&tmp, &live)
            .with_context(|| format!("publishing spill file {}", live.display()))?;
        std::fs::File::open(&self.dir)
            .and_then(|d| d.sync_all())
            .with_context(|| format!("syncing spill dir {}", self.dir.display()))?;
        self.index.insert(id);
        Ok(())
    }

    fn get(&mut self, id: u64) -> Result<Option<Vec<u8>>> {
        if !self.index.contains(&id) {
            return Ok(None);
        }
        let path = self.path_of(id);
        let blob = match std::fs::read(&path) {
            Ok(b) => b,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                self.index.remove(&id);
                return Ok(None);
            }
            Err(e) => return Err(e).with_context(|| format!("reading {}", path.display())),
        };
        // integrity gate: a damaged file is an error, never a session —
        // and the error is terminal for the FILE, not for the id: the
        // blob is quarantined to `.corrupt` (kept for forensics) and
        // dropped from the index, so the caller gets ONE structured
        // corrupt_snapshot failure instead of a restore that fails
        // forever
        if let Err(e) = codec::meta(&blob) {
            self.index.remove(&id);
            let corrupt = self.dir.join(format!("{SNAP_PREFIX}{id}{SNAP_SUFFIX}{CORRUPT_SUFFIX}"));
            let note = match std::fs::rename(&path, &corrupt) {
                Ok(()) => format!(" (quarantined to {})", corrupt.display()),
                Err(_) => String::new(),
            };
            return Err(Kinded::corrupt_snapshot(format!(
                "snapshot {} failed verification: {e:#}{note}",
                path.display()
            )));
        }
        Ok(Some(blob))
    }

    fn remove(&mut self, id: u64) -> Result<bool> {
        let existed = self.index.remove(&id);
        match std::fs::remove_file(self.path_of(id)) {
            Ok(()) => Ok(true),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(existed),
            Err(e) => Err(e.into()),
        }
    }

    fn contains(&self, id: u64) -> bool {
        self.index.contains(&id)
    }

    fn len(&self) -> usize {
        self.index.len()
    }

    fn ids(&self) -> Vec<u64> {
        self.index.iter().copied().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::persist::codec::{encode, BackendTag, Snapshot};
    use std::sync::atomic::{AtomicU64, Ordering};

    /// Unique per-test scratch directory (std has no tempdir crate).
    pub(crate) fn scratch_dir(tag: &str) -> PathBuf {
        static SEQ: AtomicU64 = AtomicU64::new(0);
        let dir = std::env::temp_dir().join(format!(
            "aaren-{tag}-{}-{}",
            std::process::id(),
            SEQ.fetch_add(1, Ordering::Relaxed)
        ));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn blob(tokens: u64) -> Vec<u8> {
        encode(&Snapshot {
            backend: BackendTag::Aaren,
            channels: 2,
            tokens_seen: tokens,
            state: vec![1.0, 2.0, 0.5, -0.25],
        })
    }

    fn exercise(store: &mut dyn SnapshotStore) {
        assert!(store.is_empty());
        assert_eq!(store.get(1).unwrap(), None);
        store.put(1, &blob(5)).unwrap();
        store.put(9, &blob(7)).unwrap();
        assert_eq!(store.len(), 2);
        assert!(store.contains(1) && store.contains(9) && !store.contains(2));
        assert_eq!(store.get(1).unwrap().unwrap(), blob(5));
        // overwrite replaces
        store.put(1, &blob(6)).unwrap();
        assert_eq!(store.get(1).unwrap().unwrap(), blob(6));
        let mut ids = store.ids();
        ids.sort_unstable();
        assert_eq!(ids, vec![1, 9]);
        assert!(store.remove(1).unwrap());
        assert!(!store.remove(1).unwrap());
        assert_eq!(store.get(1).unwrap(), None);
        assert_eq!(store.len(), 1);
    }

    #[test]
    fn mem_store_contract() {
        exercise(&mut MemStore::new());
    }

    #[test]
    fn dir_store_contract_and_reopen() {
        let dir = scratch_dir("dirstore");
        {
            let mut store = DirStore::open(&dir).unwrap();
            exercise(&mut store);
        }
        // reopen: the surviving id (9) is re-indexed from disk
        let mut store = DirStore::open(&dir).unwrap();
        assert_eq!(store.ids(), vec![9]);
        assert_eq!(store.get(9).unwrap().unwrap(), blob(7));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn dir_store_partitions_split_ids() {
        let dir = scratch_dir("dirstore-part");
        {
            let mut store = DirStore::open(&dir).unwrap();
            for id in [1u64, 2, 3, 4, 5, 6] {
                store.put(id, &blob(id)).unwrap();
            }
        }
        let even = DirStore::open_partition(&dir, 0, 2).unwrap();
        let odd = DirStore::open_partition(&dir, 1, 2).unwrap();
        let mut e = even.ids();
        let mut o = odd.ids();
        e.sort_unstable();
        o.sort_unstable();
        assert_eq!(e, vec![2, 4, 6]);
        assert_eq!(o, vec![1, 3, 5]);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn dir_store_quarantines_corrupt_files_with_a_structured_error() {
        let dir = scratch_dir("dirstore-corrupt");
        let mut store = DirStore::open(&dir).unwrap();
        store.put(3, &blob(3)).unwrap();
        // corrupt the live file in place
        let path = dir.join("sess-3.snap");
        let mut bytes = std::fs::read(&path).unwrap();
        let n = bytes.len();
        bytes[n - 6] ^= 0xFF; // payload corruption, caught by the crc
        std::fs::write(&path, &bytes).unwrap();
        let err = store.get(3).unwrap_err();
        assert!(err.to_string().contains("sess-3.snap"), "got: {err}");
        assert_eq!(
            crate::fault::Kinded::kind_of(&err),
            crate::fault::KIND_CORRUPT_SNAPSHOT,
            "corruption must carry its structured kind"
        );
        // the damaged file moved aside (forensics), the id is free again:
        // one structured failure, not a permanently wedged restore
        assert!(!path.exists(), "corrupt file must leave the live name");
        assert!(dir.join("sess-3.snap.corrupt").exists(), "quarantine file missing");
        assert!(!store.contains(3));
        assert_eq!(store.get(3).unwrap(), None, "after quarantine the id reads as absent");
        // foreign names are not indexed on reopen; the quarantined blob
        // stays out of the index too
        std::fs::write(dir.join("notes.txt"), b"hi").unwrap();
        let reopened = DirStore::open(&dir).unwrap();
        assert_eq!(reopened.ids(), Vec::<u64>::new());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn open_sweeps_stale_tmp_files_in_its_partition_only() {
        let dir = scratch_dir("dirstore-tmpsweep");
        {
            let mut store = DirStore::open(&dir).unwrap();
            store.put(4, &blob(4)).unwrap();
        }
        // a crashed save leaves `.tmp` files behind; ids 8 (even) and 5
        // (odd) let the partition split show
        std::fs::write(dir.join("sess-8.snap.tmp"), b"half").unwrap();
        std::fs::write(dir.join("sess-5.snap.tmp"), b"half").unwrap();
        let even = DirStore::open_partition(&dir, 0, 2).unwrap();
        assert_eq!(even.ids(), vec![4], "tmp files must not be indexed");
        assert!(!dir.join("sess-8.snap.tmp").exists(), "own-partition tmp must be swept");
        assert!(dir.join("sess-5.snap.tmp").exists(), "foreign-partition tmp is not ours");
        let _ = DirStore::open_partition(&dir, 1, 2).unwrap();
        assert!(!dir.join("sess-5.snap.tmp").exists(), "owning partition sweeps its tmp");
        // the published blob is untouched by the sweeps
        let mut store = DirStore::open(&dir).unwrap();
        assert_eq!(store.get(4).unwrap().unwrap(), blob(4));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn put_leaves_no_tmp_behind() {
        let dir = scratch_dir("dirstore-fsync");
        let mut store = DirStore::open(&dir).unwrap();
        store.put(2, &blob(1)).unwrap();
        store.put(2, &blob(2)).unwrap(); // overwrite takes the same path
        let names: Vec<String> = std::fs::read_dir(&dir)
            .unwrap()
            .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
            .collect();
        assert_eq!(names, vec!["sess-2.snap".to_string()]);
        assert_eq!(store.get(2).unwrap().unwrap(), blob(2));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
