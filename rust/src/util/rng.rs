//! Deterministic, dependency-free RNG: SplitMix64 core with Gaussian,
//! uniform, exponential and categorical helpers. Every experiment takes an
//! explicit `u64` seed so runs are exactly reproducible (the paper reports
//! mean ± std over seeds; so do we).

/// SplitMix64 — passes BigCrush, 8 bytes of state, trivially splittable.
#[derive(Clone, Debug)]
pub struct Rng(pub u64);

impl Rng {
    pub fn new(seed: u64) -> Self {
        // Avalanche the seed once so small seeds (0, 1, 2…) don't start in
        // correlated states.
        let mut r = Rng(seed ^ 0x9E37_79B9_7F4A_7C15);
        r.next_u64();
        r
    }

    /// Derive an independent child stream (for per-dataset / per-episode
    /// sub-generators).
    pub fn split(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0xA24B_AED4_963E_E407))
    }

    pub fn next_u64(&mut self) -> u64 {
        self.0 = self.0.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.0;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform in [lo, hi).
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn gaussian(&mut self) -> f64 {
        let u1 = self.uniform().max(1e-300);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Exponential with rate `lambda`.
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        -self.uniform().max(1e-300).ln() / lambda
    }

    /// Sample an index proportionally to non-negative `weights`.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut x = self.uniform() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fill a slice with N(0, sigma) f32 samples.
    pub fn fill_gaussian(&mut self, out: &mut [f32], sigma: f64) {
        for x in out.iter_mut() {
            *x = (self.gaussian() * sigma) as f32;
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::new(3);
        let mut counts = [0usize; 3];
        for _ in 0..30_000 {
            counts[r.categorical(&[1.0, 2.0, 7.0])] += 1;
        }
        assert!(counts[2] > counts[1] && counts[1] > counts[0]);
        let p2 = counts[2] as f64 / 30_000.0;
        assert!((p2 - 0.7).abs() < 0.03, "p2 {p2}");
    }

    #[test]
    fn split_streams_are_independent() {
        let mut root = Rng::new(5);
        let mut a = root.split(1);
        let mut b = root.split(2);
        let xa: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let xb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(xa, xb);
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(9);
        let n = 40_000;
        let mean: f64 = (0..n).map(|_| r.exponential(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }
}
