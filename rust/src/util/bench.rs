//! Micro-benchmark harness (no criterion in the offline crate set):
//! warmup + timed iterations with mean / p50 / p95 reporting, a simple
//! table printer shared by all paper-table benches, and a machine-readable
//! JSON emitter (`write_records`) so benches can leave `BENCH_*.json`
//! trails for cross-PR perf tracking.

use std::collections::BTreeMap;
use std::path::Path;
use std::time::Instant;

use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
}

impl BenchResult {
    pub fn mean_us(&self) -> f64 {
        self.mean_ns / 1e3
    }
    pub fn mean_ms(&self) -> f64 {
        self.mean_ns / 1e6
    }
}

/// Time `f` for `iters` iterations after `warmup` unmeasured runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let pct = |p: f64| samples[((samples.len() as f64 - 1.0) * p) as usize];
    BenchResult {
        name: name.to_string(),
        iters,
        mean_ns: mean,
        p50_ns: pct(0.50),
        p95_ns: pct(0.95),
    }
}

pub fn print_result(r: &BenchResult) {
    println!(
        "{:<44} {:>10.2} us/iter  (p50 {:>10.2}, p95 {:>10.2}, n={})",
        r.name,
        r.mean_us(),
        r.p50_ns / 1e3,
        r.p95_ns / 1e3,
        r.iters
    );
}

/// mean ± std over a sample (paper tables report "m ± s" over seeds).
pub fn mean_std(xs: &[f64]) -> (f64, f64) {
    let n = xs.len().max(1) as f64;
    let mean = xs.iter().sum::<f64>() / n;
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n.max(1.0);
    (mean, var.sqrt())
}

pub fn fmt_pm(mean: f64, std: f64, digits: usize) -> String {
    format!("{mean:.digits$} ± {std:.digits$}")
}

/// Render an aligned table: `header` then rows of equal arity.
pub fn print_table(title: &str, header: &[&str], rows: &[Vec<String>]) {
    println!("\n== {title} ==");
    let ncol = header.len();
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate().take(ncol) {
            widths[i] = widths[i].max(cell.len());
        }
    }
    let fmt_row = |cells: &[String]| {
        let mut line = String::new();
        for (i, c) in cells.iter().enumerate().take(ncol) {
            line.push_str(&format!("{:<w$}  ", c, w = widths[i]));
        }
        line.trim_end().to_string()
    };
    let hdr: Vec<String> = header.iter().map(|s| s.to_string()).collect();
    println!("{}", fmt_row(&hdr));
    println!("{}", "-".repeat(widths.iter().sum::<usize>() + 2 * ncol));
    for row in rows {
        println!("{}", fmt_row(row));
    }
}

/// One machine-readable benchmark record — the schema of the repo's
/// `BENCH_*.json` perf-trail files.
#[derive(Debug, Clone)]
pub struct BenchRecord {
    /// algorithm / variant name, e.g. "soa_sequential"
    pub name: String,
    /// problem size (sequence length)
    pub n: usize,
    /// value dimension
    pub d: usize,
    /// mean wall time per iteration
    pub ns_per_iter: f64,
    /// throughput relative to this run's reference variant at the same n
    /// (reference_ns / ns_per_iter; > 1 means faster than the reference)
    pub speedup_vs_sequential: f64,
}

impl BenchRecord {
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("name".to_string(), Json::Str(self.name.clone()));
        m.insert("n".to_string(), Json::Num(self.n as f64));
        m.insert("d".to_string(), Json::Num(self.d as f64));
        m.insert("ns_per_iter".to_string(), Json::Num(self.ns_per_iter));
        m.insert(
            "speedup_vs_sequential".to_string(),
            Json::Num(self.speedup_vs_sequential),
        );
        Json::Obj(m)
    }
}

/// Write bench records as a JSON array (one `BENCH_*.json` file).
pub fn write_records(path: &Path, records: &[BenchRecord]) -> std::io::Result<()> {
    let arr = Json::Arr(records.iter().map(BenchRecord::to_json).collect());
    std::fs::write(path, format!("{arr}\n"))
}

/// Read a `BENCH_*.json` trail back into records. A missing or
/// unparseable file reads as empty — the trail is advisory output, not
/// an input the caller should die on.
pub fn read_records(path: &Path) -> Vec<BenchRecord> {
    let Ok(text) = std::fs::read_to_string(path) else { return Vec::new() };
    let Ok(parsed) = Json::parse(text.trim()) else { return Vec::new() };
    let Some(arr) = parsed.as_arr() else { return Vec::new() };
    arr.iter()
        .filter_map(|r| {
            Some(BenchRecord {
                name: r.str_field("name").ok()?.to_string(),
                n: r.usize_field("n").ok()?,
                d: r.usize_field("d").ok()?,
                ns_per_iter: r.get("ns_per_iter")?.as_f64()?,
                speedup_vs_sequential: r.get("speedup_vs_sequential")?.as_f64()?,
            })
        })
        .collect()
}

/// Merge `records` into an existing trail file: keep every record whose
/// name does NOT start with `drop_prefix`, replace the rest. Lets two
/// producers (e.g. the serve_loopback bench and `aaren load`) share one
/// `BENCH_serve.json` without clobbering each other's records.
pub fn merge_records(
    path: &Path,
    drop_prefix: &str,
    records: &[BenchRecord],
) -> std::io::Result<()> {
    let mut kept = read_records(path);
    kept.retain(|r| !r.name.starts_with(drop_prefix));
    kept.extend(records.iter().cloned());
    write_records(path, &kept)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_records_roundtrip_as_json() {
        let recs = vec![BenchRecord {
            name: "soa_sequential".into(),
            n: 4096,
            d: 16,
            ns_per_iter: 1234.5,
            speedup_vs_sequential: 1.0,
        }];
        let tmp = std::env::temp_dir().join("aaren_bench_record_test.json");
        write_records(&tmp, &recs).unwrap();
        let parsed = Json::parse(std::fs::read_to_string(&tmp).unwrap().trim()).unwrap();
        let arr = parsed.as_arr().unwrap();
        assert_eq!(arr.len(), 1);
        assert_eq!(arr[0].str_field("name").unwrap(), "soa_sequential");
        assert_eq!(arr[0].usize_field("n").unwrap(), 4096);
        assert!(arr[0].get("ns_per_iter").unwrap().as_f64().unwrap() > 0.0);
        std::fs::remove_file(&tmp).ok();
    }

    #[test]
    fn merge_records_replaces_only_the_prefixed_family() {
        let tmp = std::env::temp_dir().join("aaren_bench_merge_test.json");
        let old = vec![
            BenchRecord {
                name: "batched_steps_b16".into(),
                n: 1,
                d: 8,
                ns_per_iter: 10.0,
                speedup_vs_sequential: 3.0,
            },
            BenchRecord {
                name: "capacity_population".into(),
                n: 2,
                d: 8,
                ns_per_iter: 20.0,
                speedup_vs_sequential: 0.0,
            },
        ];
        write_records(&tmp, &old).unwrap();
        let fresh = vec![BenchRecord {
            name: "capacity_sheds".into(),
            n: 9,
            d: 8,
            ns_per_iter: 30.0,
            speedup_vs_sequential: 0.0,
        }];
        merge_records(&tmp, "capacity_", &fresh).unwrap();
        let merged = read_records(&tmp);
        let names: Vec<&str> = merged.iter().map(|r| r.name.as_str()).collect();
        assert_eq!(names, vec!["batched_steps_b16", "capacity_sheds"]);
        assert_eq!(merged[0].speedup_vs_sequential, 3.0);
        std::fs::remove_file(&tmp).ok();
    }

    #[test]
    fn read_records_tolerates_missing_files() {
        let gone = std::env::temp_dir().join("aaren_bench_no_such_file.json");
        assert!(read_records(&gone).is_empty());
    }

    #[test]
    fn bench_reports_sane_stats() {
        let r = bench("noop-ish", 2, 16, || {
            std::hint::black_box((0..100).sum::<u64>());
        });
        assert!(r.mean_ns > 0.0);
        assert!(r.p50_ns <= r.p95_ns);
        assert_eq!(r.iters, 16);
    }

    #[test]
    fn mean_std_basics() {
        let (m, s) = mean_std(&[2.0, 4.0]);
        assert!((m - 3.0).abs() < 1e-12);
        assert!((s - 1.0).abs() < 1e-12);
        let (m1, s1) = mean_std(&[5.0]);
        assert_eq!(m1, 5.0);
        assert_eq!(s1, 0.0);
    }
}
