//! Tiny flag parser (no clap in the offline crate set): supports
//! `--key value`, `--key=value` and boolean `--flag` forms plus free
//! positional arguments, with typed accessors and defaults. Parsing
//! reports malformed input (e.g. an empty flag name like `--` or `--=v`)
//! as a proper error instead of panicking; a trailing valueless flag is
//! simply boolean `true`.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
}

impl Args {
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Args> {
        let mut out = Args::default();
        let mut iter = argv.into_iter().peekable();
        while let Some(a) = iter.next() {
            let Some(rest) = a.strip_prefix("--") else {
                out.positional.push(a);
                continue;
            };
            let (key, inline_value) = match rest.split_once('=') {
                Some((k, v)) => (k, Some(v.to_string())),
                None => (rest, None),
            };
            if key.is_empty() {
                bail!("malformed flag {a:?}: empty flag name");
            }
            let value = if let Some(v) = inline_value {
                v
            } else if iter.peek().is_some_and(|next| !next.starts_with("--")) {
                // `--key value`; the peek proved a next argument exists,
                // so a trailing valueless flag can never reach this branch
                iter.next().unwrap_or_default()
            } else {
                // boolean `--flag` (including as the final argument)
                "true".to_string()
            };
            out.flags.insert(key.to_string(), value);
        }
        Ok(out)
    }

    pub fn from_env() -> Result<Args> {
        Args::parse(std::env::args().skip(1))
    }

    pub fn str(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    pub fn usize(&self, key: &str, default: usize) -> usize {
        self.flags
            .get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn u64(&self, key: &str, default: u64) -> u64 {
        self.flags
            .get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn f64(&self, key: &str, default: f64) -> f64 {
        self.flags
            .get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn bool(&self, key: &str) -> bool {
        matches!(self.flags.get(key).map(String::as_str), Some("true" | "1" | "yes"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(s.iter().map(|x| x.to_string())).unwrap()
    }

    #[test]
    fn parses_key_value_pairs() {
        let a = parse(&["bench", "--seeds", "3", "--steps=200", "--verbose"]);
        assert_eq!(a.positional, vec!["bench"]);
        assert_eq!(a.usize("seeds", 1), 3);
        assert_eq!(a.usize("steps", 1), 200);
        assert!(a.bool("verbose"));
        assert!(!a.bool("quiet"));
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&[]);
        assert_eq!(a.str("artifacts", "artifacts"), "artifacts");
        assert_eq!(a.f64("lr", 0.5), 0.5);
    }

    #[test]
    fn negative_numbers_are_values_not_flags() {
        let a = parse(&["--offset", "-3.5"]);
        assert_eq!(a.f64("offset", 0.0), -3.5);
    }

    #[test]
    fn trailing_valueless_flag_is_boolean() {
        // `aaren serve --smoke` style argv ends on a bare flag
        let a = parse(&["serve", "--addr", "127.0.0.1:0", "--smoke"]);
        assert_eq!(a.positional, vec!["serve"]);
        assert_eq!(a.str("addr", ""), "127.0.0.1:0");
        assert!(a.bool("smoke"));
    }

    #[test]
    fn flag_followed_by_flag_is_boolean() {
        let a = parse(&["--verbose", "--seeds", "2"]);
        assert!(a.bool("verbose"));
        assert_eq!(a.u64("seeds", 0), 2);
    }

    #[test]
    fn empty_flag_names_are_reported_not_panicked() {
        assert!(Args::parse(["--".to_string()]).is_err());
        assert!(Args::parse(["--=3".to_string()]).is_err());
    }

    #[test]
    fn inline_empty_value_is_kept() {
        let a = parse(&["--name="]);
        assert_eq!(a.str("name", "default"), "");
    }
}
