//! Tiny flag parser (no clap in the offline crate set): supports
//! `--key value`, `--key=value` and boolean `--flag` forms plus free
//! positional arguments, with typed accessors and defaults.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
}

impl Args {
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let mut out = Args::default();
        let mut iter = argv.into_iter().peekable();
        while let Some(a) = iter.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    out.flags.insert(rest.to_string(), v);
                } else {
                    out.flags.insert(rest.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn str(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    pub fn usize(&self, key: &str, default: usize) -> usize {
        self.flags
            .get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn u64(&self, key: &str, default: u64) -> u64 {
        self.flags
            .get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn f64(&self, key: &str, default: f64) -> f64 {
        self.flags
            .get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn bool(&self, key: &str) -> bool {
        matches!(self.flags.get(key).map(String::as_str), Some("true" | "1" | "yes"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(s.iter().map(|x| x.to_string()))
    }

    #[test]
    fn parses_key_value_pairs() {
        let a = parse(&["bench", "--seeds", "3", "--steps=200", "--verbose"]);
        assert_eq!(a.positional, vec!["bench"]);
        assert_eq!(a.usize("seeds", 1), 3);
        assert_eq!(a.usize("steps", 1), 200);
        assert!(a.bool("verbose"));
        assert!(!a.bool("quiet"));
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&[]);
        assert_eq!(a.str("artifacts", "artifacts"), "artifacts");
        assert_eq!(a.f64("lr", 0.5), 0.5);
    }

    #[test]
    fn negative_numbers_are_values_not_flags() {
        let a = parse(&["--offset", "-3.5"]);
        assert_eq!(a.f64("offset", 0.0), -3.5);
    }
}
