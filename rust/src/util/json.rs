//! Minimal JSON parser/printer — the offline crate set has no serde_json,
//! so manifest parsing is implemented in-tree. Supports the full JSON
//! grammar the AOT exporter emits (objects, arrays, strings, numbers,
//! booleans, null); errors carry byte offsets for debuggability.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(src: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: src.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Convenience: `obj.str_field("name")?` with a descriptive error.
    pub fn str_field(&self, key: &str) -> anyhow::Result<&str> {
        self.get(key)
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("missing/invalid string field {key:?}"))
    }

    pub fn usize_field(&self, key: &str) -> anyhow::Result<usize> {
        self.get(key)
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow::anyhow!("missing/invalid numeric field {key:?}"))
    }
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", b as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected literal {s}")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(m)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(v)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (c as char).to_digit(16).ok_or_else(|| self.err("bad \\u"))?;
                        }
                        s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => s.push(c as char),
                Some(c) => {
                    // multi-byte utf-8: copy raw continuation bytes
                    let start = self.pos - 1;
                    let len = if c >= 0xF0 {
                        4
                    } else if c >= 0xE0 {
                        3
                    } else {
                        2
                    };
                    self.pos = start + len;
                    let chunk = self
                        .bytes
                        .get(start..start + len)
                        .ok_or_else(|| self.err("truncated utf-8"))?;
                    s.push_str(
                        std::str::from_utf8(chunk).map_err(|_| self.err("bad utf-8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

/// Serialize (used by the serve protocol and checkpoint metadata).
impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\r' => write!(f, "\\r")?,
                        '\t' => write!(f, "\\t")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{}", Json::Str(k.clone()), v)?;
                }
                write!(f, "}}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_document() {
        let src = r#"{
          "name": "tsf_aaren_train_T96",
          "args": [{"name": "param:backbone.blocks.0.ln1.b", "shape": [32], "dtype": "f32"}],
          "meta": {"lr": 0.001, "horizon": 96, "kind": "aaren"}
        }"#;
        let j = Json::parse(src).unwrap();
        assert_eq!(j.str_field("name").unwrap(), "tsf_aaren_train_T96");
        let args = j.get("args").unwrap().as_arr().unwrap();
        assert_eq!(args[0].get("shape").unwrap().as_arr().unwrap()[0].as_usize(), Some(32));
        assert_eq!(j.get("meta").unwrap().usize_field("horizon").unwrap(), 96);
        assert!((j.get("meta").unwrap().get("lr").unwrap().as_f64().unwrap() - 1e-3).abs() < 1e-12);
    }

    #[test]
    fn parses_scalars_and_specials() {
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse(r#""a\nb""#).unwrap(), Json::Str("a\nb".into()));
        assert_eq!(Json::parse(r#""A""#).unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn parses_nested_and_empty() {
        let j = Json::parse(r#"{"a": [], "b": {}, "c": [[1,2],[3]]}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 0);
        let c = j.get("c").unwrap().as_arr().unwrap();
        assert_eq!(c[0].as_arr().unwrap().len(), 2);
    }

    #[test]
    fn rejects_malformed() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("{\"a\":1} trailing").is_err());
    }

    #[test]
    fn roundtrips_through_display() {
        let src = r#"{"k":[1,2.5,"s",true,null],"m":{"x":-3}}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn handles_utf8_strings() {
        let j = Json::parse(r#""héllo ∑ 中""#).unwrap();
        assert_eq!(j, Json::Str("héllo ∑ 中".into()));
    }
}
