//! Property-testing harness (no proptest in the offline crate set):
//! runs a property over many seeded random cases and reports the first
//! failing seed so failures are exactly reproducible with
//! `check_with_seed`.

use super::rng::Rng;

pub const DEFAULT_CASES: usize = 128;

/// Run `prop(rng)` for `cases` independent seeds; panic with the failing
/// seed on the first failure (re-run that seed to shrink by hand).
pub fn check<F: Fn(&mut Rng) -> Result<(), String>>(name: &str, cases: usize, prop: F) {
    for case in 0..cases {
        let seed = 0x5EED_0000_0000_0000 ^ (case as u64).wrapping_mul(0x9E37_79B9);
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property {name:?} failed at case {case} (seed {seed:#x}): {msg}",);
        }
    }
}

/// Re-run a single failing case.
pub fn check_with_seed<F: Fn(&mut Rng) -> Result<(), String>>(name: &str, seed: u64, prop: F) {
    let mut rng = Rng::new(seed);
    if let Err(msg) = prop(&mut rng) {
        panic!("property {name:?} failed (seed {seed:#x}): {msg}");
    }
}

/// Assert two f32 slices are elementwise close.
pub fn assert_close(a: &[f32], b: &[f32], atol: f32) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length mismatch: {} vs {}", a.len(), b.len()));
    }
    for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
        if (x - y).abs() > atol {
            return Err(format!("element {i}: {x} vs {y} (atol {atol})"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("uniform is in range", 64, |rng| {
            let u = rng.uniform();
            if (0.0..1.0).contains(&u) {
                Ok(())
            } else {
                Err(format!("{u} out of range"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property")]
    fn failing_property_reports_seed() {
        check("always fails", 4, |_| Err("nope".to_string()));
    }

    #[test]
    fn assert_close_detects_mismatch() {
        assert!(assert_close(&[1.0, 2.0], &[1.0, 2.5], 0.1).is_err());
        assert!(assert_close(&[1.0, 2.0], &[1.0, 2.0001], 0.1).is_ok());
        assert!(assert_close(&[1.0], &[1.0, 2.0], 0.1).is_err());
    }
}
