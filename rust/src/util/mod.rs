//! Dependency-free substrates: RNG, JSON, CLI parsing, property testing,
//! micro-benchmarking. The offline build environment carries only the
//! `xla` crate's transitive closure, so these are implemented in-tree
//! (see DESIGN.md §Substrates).

pub mod b64;
pub mod bench;
pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
