//! Standard base64 (RFC 4648, padded) — the wire encoding for session
//! snapshot blobs. The offline crate set has no base64 crate, so the
//! codec is implemented in-tree like the JSON substrate.

use anyhow::{bail, Result};

const ALPHABET: &[u8; 64] = b"ABCDEFGHIJKLMNOPQRSTUVWXYZabcdefghijklmnopqrstuvwxyz0123456789+/";

/// Encode bytes as padded base64.
pub fn encode(data: &[u8]) -> String {
    let mut out = String::with_capacity(data.len().div_ceil(3) * 4);
    for chunk in data.chunks(3) {
        let b = [chunk[0], *chunk.get(1).unwrap_or(&0), *chunk.get(2).unwrap_or(&0)];
        let n = (u32::from(b[0]) << 16) | (u32::from(b[1]) << 8) | u32::from(b[2]);
        let sextets = [(n >> 18) & 63, (n >> 12) & 63, (n >> 6) & 63, n & 63];
        for (i, s) in sextets.into_iter().enumerate() {
            if i <= chunk.len() {
                out.push(ALPHABET[s as usize] as char);
            } else {
                out.push('=');
            }
        }
    }
    out
}

/// Decode padded base64 (whitespace is not tolerated — blobs travel as
/// single JSON string fields).
pub fn decode(text: &str) -> Result<Vec<u8>> {
    let bytes = text.as_bytes();
    if bytes.len() % 4 != 0 {
        bail!("base64 length {} is not a multiple of 4", bytes.len());
    }
    let mut out = Vec::with_capacity(bytes.len() / 4 * 3);
    for (ci, chunk) in bytes.chunks(4).enumerate() {
        let mut n = 0u32;
        let mut pad = 0usize;
        for (i, &c) in chunk.iter().enumerate() {
            let v = match c {
                b'A'..=b'Z' => c - b'A',
                b'a'..=b'z' => c - b'a' + 26,
                b'0'..=b'9' => c - b'0' + 52,
                b'+' => 62,
                b'/' => 63,
                b'=' if i >= 2 => {
                    pad += 1;
                    0
                }
                other => bail!("invalid base64 byte {:?} at offset {}", other as char, ci * 4 + i),
            };
            if pad > 0 && c != b'=' {
                bail!("base64 data after padding at offset {}", ci * 4 + i);
            }
            n = (n << 6) | u32::from(v);
        }
        if pad > 0 && ci != bytes.len() / 4 - 1 {
            bail!("base64 padding before the final group");
        }
        out.push((n >> 16) as u8);
        if pad < 2 {
            out.push((n >> 8) as u8);
        }
        if pad < 1 {
            out.push(n as u8);
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn known_vectors() {
        // RFC 4648 §10 test vectors
        for (plain, enc) in [
            ("", ""),
            ("f", "Zg=="),
            ("fo", "Zm8="),
            ("foo", "Zm9v"),
            ("foob", "Zm9vYg=="),
            ("fooba", "Zm9vYmE="),
            ("foobar", "Zm9vYmFy"),
        ] {
            assert_eq!(encode(plain.as_bytes()), enc);
            assert_eq!(decode(enc).unwrap(), plain.as_bytes());
        }
    }

    #[test]
    fn random_roundtrip() {
        let mut rng = Rng::new(11);
        for _ in 0..200 {
            let n = rng.below(120);
            let data: Vec<u8> = (0..n).map(|_| rng.below(256) as u8).collect();
            assert_eq!(decode(&encode(&data)).unwrap(), data);
        }
    }

    #[test]
    fn rejects_malformed() {
        assert!(decode("Zg=").is_err()); // bad length
        assert!(decode("Zm=v").is_err()); // data after padding
        assert!(decode("Zg==Zg==").is_err()); // padding before final group
        assert!(decode("Z!==").is_err()); // bad alphabet
        assert!(decode("=g==").is_err()); // padding in the first two slots
    }
}
