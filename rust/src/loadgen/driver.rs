//! Open-loop trace replay against a live server (or fleet router).
//!
//! The driver takes the pure arrival trace from [`crate::loadgen::trace`]
//! and replays it over the unchanged wire protocol: `workers` threads
//! each own one TCP connection and the slots with `slot % workers ==
//! worker` (the trace is globally time-sorted, so each worker sees its
//! slots' lifecycles in order). Replay is as fast as the server admits
//! — the virtual timestamps fix WHICH ops arrive in WHAT order, never
//! wall-clock pacing — and `overloaded` sheds are honored with a seeded
//! capped-exponential [`Backoff`] that treats the server's
//! `retry_after_ms` hint as a floor. Nothing about a reply ever feeds
//! back into the trace: that is the open-loop contract, and it is what
//! makes two runs with the same seed land the same ops (and therefore
//! bitwise-identical session states) on two different servers.
//!
//! With no `--addr` the driver self-spawns a loopback server tuned to
//! force the full residency cycle: a resident-session cap far below the
//! live population plus a short TTL, so sessions continuously spill to
//! the store and lazily restore on their next burst while the run
//! measures it (cumulative `spills`/`restores` from the `stats` op,
//! `op_steps` latency percentiles from the `metrics` op).

use std::collections::BTreeMap;
use std::net::SocketAddr;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context, Result};

use crate::loadgen::trace::{schedule, Arrival, ArrivalKind, OpKind, TokenBank, TraceConfig};
use crate::serve::{wire_error, Client, ServeConfig, Server};
use crate::util::bench::BenchRecord;
use crate::util::rng::Rng;

/// Deterministic capped-exponential backoff for `overloaded` sheds.
/// The schedule is a pure function of the seed and the attempt count;
/// a `retry_after_ms` hint from the server acts as a FLOOR on the next
/// delay (never ignored, even past the exponential cap).
pub struct Backoff {
    rng: Rng,
    attempt: u32,
}

/// First retry delay, ms.
pub const BACKOFF_FLOOR_MS: u64 = 1;
/// Ceiling of the exponential component, ms (hints may exceed it).
pub const BACKOFF_CAP_MS: u64 = 500;

impl Backoff {
    pub fn new(seed: u64) -> Backoff {
        Backoff { rng: Rng::new(seed), attempt: 0 }
    }

    /// Delay before the next retry. Doubling from the floor, capped,
    /// plus up to +50% seeded jitter; `hint_ms` (the server's
    /// `retry_after_ms`) floors the result.
    pub fn next_delay(&mut self, hint_ms: Option<u64>) -> Duration {
        let expo =
            BACKOFF_FLOOR_MS.saturating_mul(1u64 << self.attempt.min(16)).min(BACKOFF_CAP_MS);
        let base = expo.max(hint_ms.unwrap_or(0));
        let jitter = (self.rng.uniform() * base as f64 * 0.5) as u64;
        self.attempt = self.attempt.saturating_add(1);
        Duration::from_millis(base + jitter)
    }

    /// A delivered op ends the burst of sheds.
    pub fn reset(&mut self) {
        self.attempt = 0;
    }
}

/// One capacity run's shape. `trace()` derives the pure arrival trace;
/// everything else configures replay and (optionally) the self-spawned
/// loopback server.
#[derive(Clone, Debug)]
pub struct LoadConfig {
    /// target server; `None` self-spawns a loopback server with a
    /// spill tier and resident cap sized to force residency cycling
    pub addr: Option<String>,
    pub sessions: usize,
    pub workers: usize,
    /// `steps` bursts per session
    pub bursts: usize,
    /// tokens per burst
    pub batch: usize,
    pub channels: usize,
    pub kind: ArrivalKind,
    pub seed: u64,
    /// every `keep_every`-th slot stays open for post-run sampling
    pub keep_every: usize,
    /// resident-session cap for the self-spawned server (`None` →
    /// `max(sessions/16, 64)`); ignored with `--addr`
    pub max_resident: Option<usize>,
    /// merge `capacity_*` records into this `BENCH_*.json` trail
    pub out: Option<PathBuf>,
}

impl LoadConfig {
    /// CI smoke shape: a few thousand sessions, seconds of wall clock.
    pub fn quick() -> LoadConfig {
        LoadConfig { sessions: 2_000, ..LoadConfig::full() }
    }

    /// The capacity run the `capacity_*` records are defined over:
    /// 120k sessions cycling resident ↔ spilled.
    pub fn full() -> LoadConfig {
        LoadConfig {
            addr: None,
            sessions: 120_000,
            workers: 8,
            bursts: 3,
            batch: 16,
            channels: 8,
            kind: ArrivalKind::Poisson,
            seed: 42,
            keep_every: 97,
            max_resident: None,
            out: None,
        }
    }

    /// The arrival-trace parameters implied by this run shape. Think
    /// times are sized so ~60% of the population is mid-lifecycle at
    /// once — far above any sane resident cap, which is what drives
    /// the spill ↔ restore churn the harness exists to measure.
    pub fn trace(&self) -> TraceConfig {
        let interarrival = 50.0;
        let think = 0.6 * self.sessions as f64 * interarrival / self.bursts.max(1) as f64;
        TraceConfig {
            kind: self.kind,
            sessions: self.sessions,
            bursts: self.bursts,
            batch: self.batch,
            seed: self.seed,
            mean_interarrival_us: interarrival,
            mean_think_us: think,
            keep_every: self.keep_every,
        }
    }

    fn resident_cap(&self) -> usize {
        self.max_resident.unwrap_or_else(|| (self.sessions / 16).max(64))
    }
}

/// What a run delivered and what the server reported afterwards.
/// `created/steps_ops/tokens/closed` are deterministic for a given
/// `(seed, config)` — the replay test's invariant; `sheds/retries` and
/// the spill-tier counters depend on real timing and are excluded from
/// replay comparisons.
#[derive(Clone, Debug, Default)]
pub struct LoadReport {
    pub population: usize,
    pub channels: usize,
    pub created: u64,
    pub steps_ops: u64,
    pub tokens: u64,
    pub closed: u64,
    pub sheds: u64,
    pub retries: u64,
    /// structured non-`overloaded` error replies, by kind
    pub failures: BTreeMap<String, u64>,
    /// cumulative spill-tier writes (server `stats.spills`)
    pub spills: u64,
    /// cumulative lazy restores (server `stats.restores`)
    pub restores: u64,
    /// sessions on the spill store when the run ended
    pub spilled_now: u64,
    pub quarantined: u64,
    /// server-side `op_steps` wire-latency percentiles from the
    /// `metrics` op (0.0 when the target runs without telemetry)
    pub p50_ns: f64,
    pub p99_ns: f64,
    pub elapsed: Duration,
}

impl LoadReport {
    /// The `capacity_*` perf-trail records. Schema (documented in
    /// rust/README.md): `n` is the record's count, `ns_per_iter` the
    /// mean wall-clock between successive events of that record's kind
    /// (elapsed / count; the percentile itself for `_p50`/`_p99`), and
    /// `speedup_vs_sequential` is unused (0.0).
    pub fn capacity_records(&self) -> Vec<BenchRecord> {
        let elapsed_ns = self.elapsed.as_nanos() as f64;
        let per = |count: u64| if count == 0 { 0.0 } else { elapsed_ns / count as f64 };
        let rec = |name: &str, n: usize, ns: f64| BenchRecord {
            name: name.to_string(),
            n,
            d: self.channels,
            ns_per_iter: ns,
            speedup_vs_sequential: 0.0,
        };
        vec![
            rec("capacity_population", self.population, per(self.tokens)),
            rec("capacity_spills", self.spills as usize, per(self.spills)),
            rec("capacity_restores", self.restores as usize, per(self.restores)),
            rec("capacity_sheds", self.sheds as usize, per(self.sheds)),
            rec("capacity_steps_p50", self.steps_ops as usize, self.p50_ns),
            rec("capacity_steps_p99", self.steps_ops as usize, self.p99_ns),
        ]
    }

    pub fn print(&self) {
        let secs = self.elapsed.as_secs_f64().max(1e-9);
        println!(
            "aaren load: {} sessions  {} steps ops  {} tokens  in {:.2}s  ({:.0} tokens/s)",
            self.population,
            self.steps_ops,
            self.tokens,
            secs,
            self.tokens as f64 / secs
        );
        println!(
            "aaren load: spill tier  {} spills  {} restores  ({} spilled at end, {} quarantined)",
            self.spills, self.restores, self.spilled_now, self.quarantined
        );
        println!(
            "aaren load: admission  {} sheds  {} retries  {} structured failures",
            self.sheds,
            self.retries,
            self.failures.values().sum::<u64>()
        );
        for (kind, n) in &self.failures {
            println!("aaren load:   failure kind {kind}: {n}");
        }
        if self.p50_ns > 0.0 {
            println!(
                "aaren load: server op_steps latency  p50 {:.1} us  p99 {:.1} us",
                self.p50_ns / 1e3,
                self.p99_ns / 1e3
            );
        }
    }
}

#[derive(Default)]
struct WorkerTally {
    created: u64,
    steps_ops: u64,
    tokens: u64,
    closed: u64,
    sheds: u64,
    retries: u64,
    failures: BTreeMap<String, u64>,
}

/// Send one op, honoring `overloaded` sheds with seeded backoff.
/// Retries the SAME line — a shed never changes the op stream, only
/// when it lands. Gives up (recording the kind) after `MAX_TRIES`.
fn deliver(
    client: &mut Client,
    backoff: &mut Backoff,
    tally: &mut WorkerTally,
    line: &str,
) -> Result<bool> {
    const MAX_TRIES: usize = 200;
    for _ in 0..MAX_TRIES {
        let reply = client.call_raw(line).context("transport failure")?;
        match wire_error(&reply) {
            None => {
                backoff.reset();
                return Ok(true);
            }
            Some((kind, _)) if kind == "overloaded" => {
                tally.sheds += 1;
                tally.retries += 1;
                let hint = reply
                    .get("error")
                    .and_then(|e| e.usize_field("retry_after_ms").ok())
                    .map(|ms| ms as u64);
                std::thread::sleep(backoff.next_delay(hint));
            }
            Some((kind, _)) => {
                *tally.failures.entry(kind).or_default() += 1;
                backoff.reset();
                return Ok(false);
            }
        }
    }
    *tally.failures.entry("overloaded".to_string()).or_default() += 1;
    Ok(false)
}

/// Serialize a token block as the wire's `"xs":[[...],...]` rows.
/// `f32 → f64 → Display` is shortest-round-trip, so the server parses
/// back bitwise-identical values — the soak test's bitwise claims rest
/// on this.
fn xs_rows(tokens: &[f32], channels: usize) -> String {
    let mut out = String::with_capacity(tokens.len() * 8);
    for (i, row) in tokens.chunks_exact(channels).enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push('[');
        for (j, v) in row.iter().enumerate() {
            if j > 0 {
                out.push(',');
            }
            out.push_str(&format!("{}", *v as f64));
        }
        out.push(']');
    }
    out
}

/// The wire session id owning `slot` (explicit native ids start at 1).
pub fn slot_id(slot: usize) -> u64 {
    slot as u64 + 1
}

fn worker_loop(
    addr: SocketAddr,
    events: Vec<Arrival>,
    bank: Arc<TokenBank>,
    channels: usize,
    batch: usize,
    seed: u64,
    worker: usize,
) -> Result<WorkerTally> {
    let mut client = Client::connect(&addr).context("worker connect")?;
    client.set_io_timeout(Some(Duration::from_secs(60)))?;
    let mut backoff = Backoff::new(seed ^ 0x6c6f6164 ^ (worker as u64).wrapping_mul(0x9e37));
    let mut tally = WorkerTally::default();
    for a in events {
        let id = slot_id(a.slot);
        match a.op {
            OpKind::Create => {
                let kind = crate::loadgen::trace::slot_kind(a.slot).wire_name();
                let line = format!(r#"{{"op":"create","kind":"{kind}","id":{id}}}"#);
                if deliver(&mut client, &mut backoff, &mut tally, &line)? {
                    tally.created += 1;
                }
            }
            OpKind::Steps { burst } => {
                let tokens = bank.tokens(a.slot, burst, batch);
                let rows = xs_rows(&tokens, channels);
                let line = format!(r#"{{"op":"steps","id":{id},"xs":[{rows}]}}"#);
                if deliver(&mut client, &mut backoff, &mut tally, &line)? {
                    tally.steps_ops += 1;
                    tally.tokens += (tokens.len() / channels) as u64;
                }
            }
            OpKind::Close => {
                let line = format!(r#"{{"op":"close","id":{id}}}"#);
                if deliver(&mut client, &mut backoff, &mut tally, &line)? {
                    tally.closed += 1;
                }
            }
        }
    }
    Ok(tally)
}

/// Where the run's spill tier lives when self-spawning: tmpfs when the
/// platform offers it (a 100k-session run writes spill files by the
/// hundred thousand; fsync on disk would dominate the measurement),
/// else the system temp dir.
fn spill_root() -> PathBuf {
    let shm = PathBuf::from("/dev/shm");
    if shm.is_dir() {
        shm
    } else {
        std::env::temp_dir()
    }
}

/// Run the capacity harness: resolve or spawn the target server,
/// replay the trace across workers, then collect the server's own
/// counters into a [`LoadReport`].
pub fn run(cfg: &LoadConfig) -> Result<LoadReport> {
    let trace_cfg = cfg.trace();
    let trace = schedule(&trace_cfg);
    let bank = Arc::new(TokenBank::new(cfg.seed ^ 0x746f6b, cfg.channels));

    let (addr, spawned_spill) = match &cfg.addr {
        Some(a) => {
            let addr: SocketAddr = a.parse().map_err(|e| anyhow!("bad --addr {a:?}: {e}"))?;
            (addr, None)
        }
        None => {
            // pid + counter: two runs in one process (tests, replay
            // pairs) must never share or race a spill directory
            static SPAWN: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
            let n = SPAWN.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            let spill = spill_root().join(format!("aaren-load-{}-{n}", std::process::id()));
            let _ = std::fs::remove_dir_all(&spill);
            std::fs::create_dir_all(&spill).context("create spill dir")?;
            let server_cfg = ServeConfig {
                addr: "127.0.0.1:0".to_string(),
                channels: cfg.channels,
                shards: cfg.workers.clamp(2, 8),
                session_ttl: Some(Duration::from_millis(250)),
                spill_dir: Some(spill.clone()),
                max_resident_sessions: Some(cfg.resident_cap()),
                ..ServeConfig::default()
            };
            let server = Server::bind(&server_cfg).context("bind loopback server")?;
            let addr = server.local_addr().context("server addr")?;
            std::thread::spawn(move || server.run());
            (addr, Some(spill))
        }
    };

    // partition the time-sorted trace: slot % workers, order preserved
    let workers = cfg.workers.max(1);
    let mut per_worker: Vec<Vec<Arrival>> = (0..workers).map(|_| Vec::new()).collect();
    for a in &trace {
        per_worker[a.slot % workers].push(*a);
    }

    let t0 = Instant::now();
    let handles: Vec<_> = per_worker
        .into_iter()
        .enumerate()
        .map(|(w, events)| {
            let bank = Arc::clone(&bank);
            let (channels, batch, seed) = (cfg.channels, cfg.batch, cfg.seed);
            std::thread::spawn(move || worker_loop(addr, events, bank, channels, batch, seed, w))
        })
        .collect();
    let mut report = LoadReport {
        population: cfg.sessions,
        channels: cfg.channels,
        ..LoadReport::default()
    };
    let mut worker_err: Option<anyhow::Error> = None;
    for h in handles {
        match h.join() {
            Ok(Ok(t)) => {
                report.created += t.created;
                report.steps_ops += t.steps_ops;
                report.tokens += t.tokens;
                report.closed += t.closed;
                report.sheds += t.sheds;
                report.retries += t.retries;
                for (k, n) in t.failures {
                    *report.failures.entry(k).or_default() += n;
                }
            }
            Ok(Err(e)) => worker_err = Some(e),
            Err(_) => worker_err = Some(anyhow!("worker thread panicked")),
        }
    }
    report.elapsed = t0.elapsed();

    // server-side truth: spill-tier counters + wire-latency percentiles
    let mut control = Client::connect(&addr).context("control connect")?;
    let stats = control.call(r#"{"op":"stats"}"#).context("stats op")?;
    report.spills = stats.usize_field("spills").unwrap_or(0) as u64;
    report.restores = stats.usize_field("restores").unwrap_or(0) as u64;
    report.spilled_now = stats.usize_field("spilled").unwrap_or(0) as u64;
    report.quarantined = stats.usize_field("quarantined").unwrap_or(0) as u64;
    if let Ok(metrics) = control.call_raw(r#"{"op":"metrics"}"#) {
        if let Some(hist) = metrics.get("histograms").and_then(|h| h.get("op_steps")) {
            report.p50_ns = hist.usize_field("p50_ns").unwrap_or(0) as f64;
            report.p99_ns = hist.usize_field("p99_ns").unwrap_or(0) as f64;
        }
    }

    if let Some(spill) = spawned_spill {
        let _ = control.call(r#"{"op":"shutdown"}"#);
        let _ = std::fs::remove_dir_all(&spill);
    }
    if let Some(e) = worker_err {
        return Err(e.context("a load worker failed"));
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backoff_honors_retry_after_hint_as_floor() {
        for seed in [1u64, 7, 99] {
            let mut b = Backoff::new(seed);
            for hint in [1u64, 25, 120, 900, 2_000] {
                let d = b.next_delay(Some(hint));
                assert!(
                    d.as_millis() as u64 >= hint,
                    "seed {seed}: delay {}ms ignored retry_after_ms hint {hint}",
                    d.as_millis()
                );
            }
        }
    }

    #[test]
    fn backoff_is_a_seeded_capped_exponential() {
        let mut a = Backoff::new(5);
        let mut b = Backoff::new(5);
        let mut prev_floor = 0u64;
        for attempt in 0..16u32 {
            let da = a.next_delay(None);
            let db = b.next_delay(None);
            assert_eq!(da, db, "same seed must replay the same schedule");
            // the deterministic exponential component floors the delay
            // and is capped; jitter adds at most +50%
            let expo = BACKOFF_FLOOR_MS.saturating_mul(1 << attempt.min(16)).min(BACKOFF_CAP_MS);
            let ms = da.as_millis() as u64;
            assert!(ms >= expo, "attempt {attempt}: {ms}ms under the exponential floor {expo}ms");
            assert!(ms <= expo + expo / 2, "attempt {attempt}: {ms}ms over floor+jitter");
            assert!(expo >= prev_floor, "exponential component must not shrink");
            prev_floor = expo;
        }
        assert_eq!(prev_floor, BACKOFF_CAP_MS, "schedule never reached the cap");
        a.reset();
        let first = a.next_delay(None).as_millis() as u64;
        assert!(first <= BACKOFF_FLOOR_MS + BACKOFF_FLOOR_MS / 2 + 1, "reset must restart");
    }

    #[test]
    fn xs_rows_round_trip_bitwise_through_the_wire_grammar() {
        use crate::util::json::Json;
        let tokens: Vec<f32> = vec![0.125, -3.5, 1.0e-6, 7.625, 0.0, -0.0, 15.99, -15.99];
        let line = format!(r#"{{"xs":[{}]}}"#, xs_rows(&tokens, 4));
        let parsed = Json::parse(&line).unwrap();
        let rows = parsed.get("xs").and_then(Json::as_arr).unwrap();
        let mut got: Vec<f32> = Vec::new();
        for row in rows {
            for v in row.as_arr().unwrap() {
                got.push(v.as_f64().unwrap() as f32);
            }
        }
        assert_eq!(got.len(), tokens.len());
        for (g, t) in got.iter().zip(tokens.iter()) {
            assert_eq!(g.to_bits(), t.to_bits(), "token did not survive serialization");
        }
    }

    /// End-to-end smoke: a tiny population through a self-spawned
    /// server with an 8-session resident cap — every op delivered, the
    /// population forced through the spill ↔ restore cycle, nothing
    /// quarantined.
    #[test]
    fn tiny_run_cycles_sessions_through_residency() {
        let cfg = LoadConfig {
            sessions: 48,
            workers: 3,
            bursts: 2,
            batch: 4,
            channels: 4,
            keep_every: 7,
            max_resident: Some(8),
            ..LoadConfig::full()
        };
        let report = run(&cfg).expect("load run");
        assert_eq!(report.created, 48);
        assert_eq!(report.steps_ops, 96);
        assert_eq!(report.tokens, 96 * 4);
        // slots 0,7,…,42 are kept open for sampling; the rest close
        assert_eq!(report.closed, 48 - 7);
        assert!(report.failures.is_empty(), "structured failures: {:?}", report.failures);
        assert_eq!(report.quarantined, 0);
        assert!(report.spills > 0, "an 8-session cap must force spills");
        assert!(report.restores > 0, "spilled sessions must lazily restore on their next burst");
        let records = report.capacity_records();
        assert_eq!(records.len(), 6);
        assert!(records.iter().all(|r| r.name.starts_with("capacity_")));
        assert_eq!(records[0].n, 48);
        assert!(records[0].ns_per_iter > 0.0);
    }
}
