//! Million-session capacity harness: seeded open-loop traffic replay.
//!
//! `aaren load` answers the question the serving stack exists for: how
//! large a session population can one server (or fleet) cycle through
//! the resident ↔ spilled lifecycle while staying correct and
//! responsive? The paper's core claim — attention reformulated as an
//! RNN holds every stream in O(1) memory — only matters at scale if
//! the machinery around it (lane allocator, spill tier, admission
//! control) survives six-figure populations. This module generates
//! that population.
//!
//! Three properties anchor the design:
//!
//! - **Open-loop**: the arrival trace ([`trace::schedule`]) and every
//!   token block ([`trace::TokenBank`]) are pure functions of
//!   `(seed, config)`. Reply latency, sheds, and retries shift WHEN an
//!   op lands, never WHICH ops exist — so a saturated server is
//!   measured under the offered load, not a load that politely shrinks
//!   to match it (the closed-loop fallacy).
//! - **Deterministic replay**: same seed + config → the same ops with
//!   the same tokens, so two runs against two fresh servers must leave
//!   bitwise-identical session states. `tests/capacity.rs` holds the
//!   harness to that.
//! - **Sheds are honored, not fatal**: structured `overloaded` replies
//!   are retried with a seeded capped-exponential [`driver::Backoff`]
//!   that treats `retry_after_ms` as a floor; every other structured
//!   error kind is counted, never panicked on.
//!
//! Results land as `capacity_*` records merged into `BENCH_serve.json`
//! (see [`driver::LoadReport::capacity_records`]) next to the
//! serve_loopback bench's records.

pub mod driver;
pub mod trace;

pub use driver::{run, slot_id, Backoff, LoadConfig, LoadReport, BACKOFF_CAP_MS, BACKOFF_FLOOR_MS};
pub use trace::{
    completion_times, schedule, slot_kind, Arrival, ArrivalKind, OpKind, TokenBank, TraceConfig,
};
