//! Seeded open-loop arrival traces and workload token synthesis.
//!
//! Everything here is a PURE function of `(seed, config)`: the schedule
//! never reads a wall clock, service latency, or any reply — that is
//! what makes the generator **open-loop** (the op stream is fixed a
//! priori; a slow server shifts dispatch instants but can never change
//! which ops arrive, in what order, with which tokens) and what makes
//! capacity runs REPLAYABLE (same seed + config → bit-identical trace,
//! so two runs against two fresh servers must leave bitwise-identical
//! session states; `tests/capacity.rs` asserts exactly that).
//!
//! The virtual `at_us` timestamps exist to ORDER the trace — they
//! interleave many session lifecycles so a large population is alive at
//! once (which is what pressures the spill tier) — not to pace the
//! wall clock: the driver replays the sequence as fast as the server
//! admits it, honoring `overloaded` sheds with a seeded backoff.
//!
//! Tokens come from the four paper task suites
//! (`crate::data::{tsf,events,tsc,rl}`), so a capacity run streams the
//! same signal families the paper's tables are computed over instead of
//! white noise.

use crate::data::{events, rl, tsc, tsf};
use crate::scan::KernelKind;
use crate::util::rng::Rng;

/// The arrival process shaping session-start times.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ArrivalKind {
    /// Memoryless session starts: i.i.d. exponential inter-arrivals.
    Poisson,
    /// Bursty ON-OFF (interrupted Poisson): ON windows arrive 4× faster
    /// than the Poisson mean, separated by silent OFF gaps — the herd
    /// pattern that stresses admission control and the shed path.
    OnOff,
}

impl ArrivalKind {
    pub fn name(self) -> &'static str {
        match self {
            ArrivalKind::Poisson => "poisson",
            ArrivalKind::OnOff => "onoff",
        }
    }

    pub fn from_name(name: &str) -> Option<ArrivalKind> {
        match name {
            "poisson" => Some(ArrivalKind::Poisson),
            "onoff" | "on-off" | "bursty" => Some(ArrivalKind::OnOff),
            _ => None,
        }
    }
}

/// One session lifecycle op in the trace.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OpKind {
    Create,
    /// The `burst`-th `steps` block of this session.
    Steps { burst: usize },
    Close,
}

/// One scheduled arrival: virtual time, session slot, and the op. `seq`
/// is the op's index within its slot — the tiebreaker that keeps a
/// slot's lifecycle ordered even at equal timestamps.
#[derive(Clone, Copy, Debug)]
pub struct Arrival {
    pub at_us: u64,
    pub slot: usize,
    pub seq: u32,
    pub op: OpKind,
}

/// Everything the schedule is a function of. See [`schedule`].
#[derive(Clone, Debug)]
pub struct TraceConfig {
    pub kind: ArrivalKind,
    /// session population (one slot = one session over its lifetime)
    pub sessions: usize,
    /// `steps` bursts per session between create and close
    pub bursts: usize,
    /// tokens per `steps` burst
    pub batch: usize,
    pub seed: u64,
    /// mean virtual gap between session starts, µs
    pub mean_interarrival_us: f64,
    /// mean virtual think time between one session's bursts, µs — large
    /// relative to the inter-arrival mean so lifecycles overlap and the
    /// resident population grows into the spill tier's cap
    pub mean_think_us: f64,
    /// every `keep_every`-th slot skips its Close — the sample the soak
    /// and replay tests snapshot after the run (0 closes everything)
    pub keep_every: usize,
}

impl TraceConfig {
    /// Does `slot` keep its session open (no Close op) for post-run
    /// snapshot sampling?
    pub fn kept(&self, slot: usize) -> bool {
        self.keep_every != 0 && slot % self.keep_every == 0
    }
}

/// The kernel backend `slot`'s session is created with — the population
/// cycles through every fold-kernel backend so one capacity run
/// pressure-tests each kernel's constant-memory story at once.
pub fn slot_kind(slot: usize) -> KernelKind {
    KernelKind::ALL[slot % KernelKind::ALL.len()]
}

/// Build the full arrival trace: a pure function of `cfg` (fixed seed,
/// no wall-clock randomness). Session starts follow `cfg.kind`; each
/// session then runs create → `bursts`×steps → close with exponential
/// think times from its own split rng stream. The result is sorted by
/// `(at_us, slot, seq)`, and every slot's ops stay in lifecycle order
/// (its timestamps are strictly cumulative).
pub fn schedule(cfg: &TraceConfig) -> Vec<Arrival> {
    let mut rng = Rng::new(cfg.seed);
    let lambda = 1.0 / cfg.mean_interarrival_us.max(1.0);
    let mut out = Vec::with_capacity(cfg.sessions * (cfg.bursts + 2));
    let mut t = 0.0f64;
    // ON-OFF phase state (unused for Poisson)
    let mut on_left = 0.0f64;
    for slot in 0..cfg.sessions {
        t += match cfg.kind {
            ArrivalKind::Poisson => rng.exponential(lambda),
            ArrivalKind::OnOff => {
                // inside an ON window arrivals come 4× faster; when the
                // window is spent, jump over a silent OFF gap and open
                // the next window
                if on_left <= 0.0 {
                    let off_gap = rng.exponential(lambda / 40.0);
                    on_left = rng.exponential(lambda / 20.0);
                    t += off_gap;
                }
                let gap = rng.exponential(4.0 * lambda);
                on_left -= gap;
                gap
            }
        };
        let mut slot_rng = rng.split(slot as u64);
        let mut st = t;
        let mut seq = 0u32;
        out.push(Arrival { at_us: st as u64, slot, seq, op: OpKind::Create });
        for burst in 0..cfg.bursts {
            st += slot_rng.exponential(1.0 / cfg.mean_think_us.max(1.0));
            seq += 1;
            out.push(Arrival { at_us: st as u64, slot, seq, op: OpKind::Steps { burst } });
        }
        if !cfg.kept(slot) {
            st += slot_rng.exponential(1.0 / cfg.mean_think_us.max(1.0));
            seq += 1;
            out.push(Arrival { at_us: st as u64, slot, seq, op: OpKind::Close });
        }
    }
    out.sort_by_key(|a| (a.at_us, a.slot, a.seq));
    out
}

/// Pure replay helper for the open-loop property: given per-op service
/// latencies, compute when each op would COMPLETE on a
/// one-at-a-time server (dispatch = max(arrival, previous completion)).
/// Completion times move with the latencies; the arrival sequence — by
/// construction — cannot, which the loadgen unit tests assert.
pub fn completion_times(trace: &[Arrival], service_latency_us: &[u64]) -> Vec<u64> {
    let mut done = 0u64;
    trace
        .iter()
        .enumerate()
        .map(|(i, a)| {
            let svc = service_latency_us.get(i % service_latency_us.len().max(1)).unwrap_or(&0);
            done = done.max(a.at_us) + svc;
            done
        })
        .collect()
}

/// Fixed-size template streams drawn from the four task suites, ready
/// to serve as session token traffic. Construction and lookup are pure
/// functions of `(seed, channels)`, so a test can recompute any
/// session's full token history client-side and drive a boxed control
/// session to a bitwise-expected state.
pub struct TokenBank {
    channels: usize,
    /// flat (len × channels) streams, one per template
    templates: Vec<Vec<f32>>,
}

/// Cyclic width adaptation: suite rows (7-wide tsf, 8-wide tsc, …)
/// become `channels`-wide tokens by index wraparound — no information
/// is invented, every value is a real suite value.
fn resample(row: &[f32], channels: usize, out: &mut Vec<f32>) {
    for c in 0..channels {
        // clamp keeps scores tame over long streams; suite values are
        // z-scored or bounded already, so this is a safety rail
        out.push(row[c % row.len()].clamp(-16.0, 16.0));
    }
}

impl TokenBank {
    pub fn new(seed: u64, channels: usize) -> TokenBank {
        assert!(channels > 0, "token bank needs at least one channel");
        let mut templates = Vec::new();
        // two presets per suite: 8 templates, cycled over slots
        for (i, ds) in tsf::ALL.into_iter().take(2).enumerate() {
            let series = tsf::generate(ds, 256, seed ^ (i as u64 + 1));
            let mut tpl = Vec::with_capacity(series.len * channels);
            for ti in 0..series.len {
                resample(series.at(ti), channels, &mut tpl);
            }
            templates.push(tpl);
        }
        for (i, ds) in events::ALL.into_iter().take(2).enumerate() {
            let seq = events::simulate(ds, seed ^ (0x10 + i as u64));
            let mut tpl = Vec::with_capacity(seq.times.len() * channels);
            let mut prev = 0.0f32;
            for (k, &tk) in seq.times.iter().enumerate() {
                let row = [tk - prev, seq.marks[k] as f32];
                resample(&row, channels, &mut tpl);
                prev = tk;
            }
            templates.push(tpl);
        }
        for (i, ds) in tsc::ALL.into_iter().take(2).enumerate() {
            let gen = tsc::TscGenerator::new(ds, seed ^ (0x20 + i as u64));
            let mut rng = Rng::new(seed ^ (0x21 + i as u64));
            let ex = gen.sample(&mut rng);
            let mut tpl = Vec::with_capacity(tsc::SEQ_LEN * channels);
            for row in ex.x.chunks_exact(tsc::CHANNELS) {
                resample(row, channels, &mut tpl);
            }
            templates.push(tpl);
        }
        for (i, env_id) in rl::ALL_ENVS.into_iter().take(2).enumerate() {
            let mut env = rl::Env::new(env_id, seed ^ (0x30 + i as u64));
            let traj =
                rl::rollout(&mut env, &rl::ScriptedPolicy::medium(), seed ^ (0x31 + i as u64));
            let width = if traj.len() == 0 { 1 } else { traj.states.len() / traj.len() };
            let mut tpl = Vec::with_capacity(traj.len() * channels);
            for row in traj.states.chunks_exact(width.max(1)) {
                resample(row, channels, &mut tpl);
            }
            templates.push(tpl);
        }
        templates.retain(|t| !t.is_empty());
        assert!(!templates.is_empty(), "token bank built no templates");
        TokenBank { channels, templates }
    }

    pub fn channels(&self) -> usize {
        self.channels
    }

    /// The flat `(batch, channels)` token block for `slot`'s
    /// `burst`-th steps op — a pure lookup: the slot picks a template
    /// and a phase offset, bursts read consecutive (wrapping) rows.
    pub fn tokens(&self, slot: usize, burst: usize, batch: usize) -> Vec<f32> {
        let tpl = &self.templates[slot % self.templates.len()];
        let rows = tpl.len() / self.channels;
        let start = (slot / self.templates.len() + burst * batch) % rows;
        let mut out = Vec::with_capacity(batch * self.channels);
        for j in 0..batch {
            let r = (start + j) % rows;
            out.extend_from_slice(&tpl[r * self.channels..(r + 1) * self.channels]);
        }
        out
    }

    /// Every token `slot` has streamed after `bursts` bursts of `batch`
    /// tokens — the client-side replay the soak test feeds its boxed
    /// control sessions.
    pub fn history(&self, slot: usize, bursts: usize, batch: usize) -> Vec<f32> {
        let mut out = Vec::with_capacity(bursts * batch * self.channels);
        for b in 0..bursts {
            out.extend_from_slice(&self.tokens(slot, b, batch));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(kind: ArrivalKind) -> TraceConfig {
        TraceConfig {
            kind,
            sessions: 400,
            bursts: 3,
            batch: 8,
            seed: 11,
            mean_interarrival_us: 200.0,
            mean_think_us: 20_000.0,
            keep_every: 16,
        }
    }

    #[test]
    fn schedule_is_deterministic_and_lifecycle_ordered() {
        for kind in [ArrivalKind::Poisson, ArrivalKind::OnOff] {
            let a = schedule(&cfg(kind));
            let b = schedule(&cfg(kind));
            assert_eq!(a.len(), b.len(), "{kind:?}: replay changed length");
            for (x, y) in a.iter().zip(b.iter()) {
                assert_eq!(
                    (x.at_us, x.slot, x.seq),
                    (y.at_us, y.slot, y.seq),
                    "{kind:?}: replay diverged"
                );
                assert_eq!(x.op, y.op);
            }
            // per-slot lifecycle order: Create first, bursts in order,
            // Close last (when present)
            let mut last_seq = vec![None::<u32>; 400];
            for arr in &a {
                if let Some(prev) = last_seq[arr.slot] {
                    assert!(arr.seq > prev, "slot {} ops out of order", arr.slot);
                } else {
                    assert_eq!(arr.op, OpKind::Create, "slot {} must start with create", arr.slot);
                }
                last_seq[arr.slot] = Some(arr.seq);
            }
            let closes = a.iter().filter(|x| x.op == OpKind::Close).count();
            let kept = (0..400).filter(|&s| cfg(kind).kept(s)).count();
            assert_eq!(closes, 400 - kept, "{kind:?}: kept slots must skip close");
        }
    }

    #[test]
    fn onoff_is_burstier_than_poisson() {
        // squared coefficient of variation of inter-arrival gaps: the
        // interrupted-Poisson process must be markedly more variable
        let gaps = |kind| {
            let mut starts: Vec<u64> = schedule(&cfg(kind))
                .iter()
                .filter(|a| a.op == OpKind::Create)
                .map(|a| a.at_us)
                .collect();
            starts.sort_unstable();
            starts.windows(2).map(|w| (w[1] - w[0]) as f64).collect::<Vec<_>>()
        };
        let cv2 = |g: &[f64]| {
            let m = g.iter().sum::<f64>() / g.len() as f64;
            let v = g.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / g.len() as f64;
            v / (m * m)
        };
        let poisson = cv2(&gaps(ArrivalKind::Poisson));
        let onoff = cv2(&gaps(ArrivalKind::OnOff));
        assert!(
            onoff > poisson * 1.5,
            "ON-OFF should be burstier: cv² {onoff:.2} vs poisson {poisson:.2}"
        );
    }

    #[test]
    fn token_bank_is_pure_and_finite() {
        let a = TokenBank::new(7, 8);
        let b = TokenBank::new(7, 8);
        for slot in [0usize, 3, 17, 1000] {
            for burst in 0..3 {
                let xa = a.tokens(slot, burst, 16);
                let xb = b.tokens(slot, burst, 16);
                assert_eq!(xa.len(), 16 * 8);
                for (u, v) in xa.iter().zip(xb.iter()) {
                    assert_eq!(u.to_bits(), v.to_bits(), "token bank not pure");
                    assert!(u.is_finite());
                }
            }
        }
        // history is the burst concatenation, bitwise
        let h = a.history(3, 3, 16);
        let cat: Vec<f32> = (0..3).flat_map(|burst| a.tokens(3, burst, 16)).collect();
        assert_eq!(h.len(), cat.len());
        for (u, v) in h.iter().zip(cat.iter()) {
            assert_eq!(u.to_bits(), v.to_bits());
        }
    }

    #[test]
    fn completion_moves_with_latency_but_arrivals_do_not() {
        // the open-loop property made concrete: wildly different service
        // latencies shift completions, yet the arrival sequence (times,
        // slots, ops, tokens) is untouched because nothing in schedule()
        // or TokenBank reads a latency
        let trace = schedule(&cfg(ArrivalKind::Poisson));
        let fast = completion_times(&trace, &[10]);
        let slow = completion_times(&trace, &[10_000]);
        assert!(fast.last() < slow.last(), "latency must move completions");
        let again = schedule(&cfg(ArrivalKind::Poisson));
        for (x, y) in trace.iter().zip(again.iter()) {
            assert_eq!((x.at_us, x.slot, x.seq), (y.at_us, y.slot, y.seq));
        }
    }
}
