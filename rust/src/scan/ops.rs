//! The associative operator ⊕ on (m, u, w) tuples — Appendix B of the
//! paper, verbatim:
//!
//!   m_{A∪B} = max(m_A, m_B)
//!   u_{A∪B} = u_A·exp(m_A − m_{A∪B}) + u_B·exp(m_B − m_{A∪B})
//!   w_{A∪B} = w_A·exp(m_A − m_{A∪B}) + w_B·exp(m_B − m_{A∪B})
//!
//! A leaf for token i is (s_i, 1, v_i); after an inclusive scan, the k-th
//! tuple is (m_k, c_k, a_k) and attention's prefix output is o_k = a_k/c_k.

/// Finite "minus infinity": exp(MASK_FILL − m) underflows to exactly 0
/// while every intermediate stays finite (a true −∞ would yield NaN via
/// `−∞ − −∞` when combining two identities). Must match
/// python/compile/kernels/ref.py::MASK_FILL.
pub const MASK_FILL: f32 = -1e9;

/// One scan element: running max `m`, normaliser `u`, weighted value sum `w`.
#[derive(Clone, Debug, PartialEq)]
pub struct Muw {
    pub m: f32,
    pub u: f32,
    pub w: Vec<f32>,
}

impl Muw {
    /// Leaf tuple for a token with score `s` and value `v`: (s, 1, v).
    pub fn leaf(s: f32, v: &[f32]) -> Muw {
        Muw { m: s, u: 1.0, w: v.to_vec() }
    }

    /// Identity element: ⊕-neutral on both sides.
    pub fn identity(dim: usize) -> Muw {
        Muw { m: MASK_FILL, u: 0.0, w: vec![0.0; dim] }
    }

    /// The attention output this prefix represents: o = w / u.
    pub fn output(&self) -> Vec<f32> {
        self.w.iter().map(|w| w / self.u).collect()
    }
}

/// a ⊕ b, allocating the result.
pub fn combine(a: &Muw, b: &Muw) -> Muw {
    let mut out = Muw { m: 0.0, u: 0.0, w: vec![0.0; a.w.len()] };
    combine_into(a, b, &mut out);
    out
}

/// a ⊕ b into a preallocated tuple (the hot-path form: zero allocation).
pub fn combine_into(a: &Muw, b: &Muw, out: &mut Muw) {
    debug_assert_eq!(a.w.len(), b.w.len());
    let m = a.m.max(b.m);
    let ea = (a.m - m).exp();
    let eb = (b.m - m).exp();
    out.m = m;
    out.u = a.u * ea + b.u * eb;
    if out.w.len() != a.w.len() {
        out.w.resize(a.w.len(), 0.0);
    }
    for ((o, x), y) in out.w.iter_mut().zip(a.w.iter()).zip(b.w.iter()) {
        *o = x * ea + y * eb;
    }
}

/// In-place fold: `acc = acc ⊕ leaf(s, v)` — the §3.1 RNN cell update
/// (Figure 2), specialised to avoid allocating a leaf. This is the O(1)
/// streaming update rust-native sessions use.
pub fn fold_token(acc: &mut Muw, s: f32, v: &[f32]) {
    let m = acc.m.max(s);
    let ea = (acc.m - m).exp();
    let eb = (s - m).exp();
    acc.m = m;
    acc.u = acc.u * ea + eb;
    for (w, x) in acc.w.iter_mut().zip(v.iter()) {
        *w = *w * ea + x * eb;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn rand_tuple(rng: &mut crate::util::rng::Rng, d: usize, mag: f64) -> Muw {
        Muw {
            m: rng.range(-mag, mag) as f32,
            u: rng.range(0.1, 3.0) as f32,
            w: (0..d).map(|_| rng.gaussian() as f32).collect(),
        }
    }

    #[test]
    fn operator_is_associative() {
        // Appendix B.2 — including extreme magnitudes where a naive
        // (un-maxed) implementation overflows.
        prop::check("(a+b)+c == a+(b+c)", 256, |rng| {
            let d = 1 + rng.below(6);
            let mag = [1.0, 10.0, 80.0][rng.below(3)];
            let (a, b, c) = (
                rand_tuple(rng, d, mag),
                rand_tuple(rng, d, mag),
                rand_tuple(rng, d, mag),
            );
            let left = combine(&combine(&a, &b), &c);
            let right = combine(&a, &combine(&b, &c));
            if (left.m - right.m).abs() > 1e-5 {
                return Err(format!("m {} vs {}", left.m, right.m));
            }
            let rel = |x: f32, y: f32| (x - y).abs() / (1e-6 + x.abs().max(y.abs()));
            if rel(left.u, right.u) > 1e-4 {
                return Err(format!("u {} vs {}", left.u, right.u));
            }
            for (x, y) in left.w.iter().zip(right.w.iter()) {
                if rel(*x, *y) > 1e-3 && (x - y).abs() > 1e-4 {
                    return Err(format!("w {x} vs {y}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn identity_is_neutral() {
        prop::check("e+x == x+e == x", 64, |rng| {
            let x = rand_tuple(rng, 4, 20.0);
            let e = Muw::identity(4);
            for got in [combine(&e, &x), combine(&x, &e)] {
                if (got.m - x.m).abs() > 1e-6 || (got.u - x.u).abs() > 1e-5 {
                    return Err(format!("{got:?} != {x:?}"));
                }
                prop::assert_close(&got.w, &x.w, 1e-5)?;
            }
            Ok(())
        });
    }

    #[test]
    fn correctness_against_direct_softmax() {
        // Appendix B.1: folding leaves equals computing softmax directly.
        prop::check("scan == direct softmax", 64, |rng| {
            let n = 1 + rng.below(32);
            let d = 3;
            let scores: Vec<f32> = (0..n).map(|_| rng.range(-30.0, 30.0) as f32).collect();
            let values: Vec<Vec<f32>> = (0..n)
                .map(|_| (0..d).map(|_| rng.gaussian() as f32).collect())
                .collect();
            let mut acc = Muw::identity(d);
            for (s, v) in scores.iter().zip(values.iter()) {
                fold_token(&mut acc, *s, v);
            }
            // direct, numerically-stable softmax
            let mx = scores.iter().cloned().fold(f32::MIN, f32::max);
            let exps: Vec<f32> = scores.iter().map(|s| (s - mx).exp()).collect();
            let z: f32 = exps.iter().sum();
            let mut want = vec![0.0f32; d];
            for (e, v) in exps.iter().zip(values.iter()) {
                for (wd, vd) in want.iter_mut().zip(v.iter()) {
                    *wd += e / z * vd;
                }
            }
            prop::assert_close(&acc.output(), &want, 1e-5)
        });
    }

    #[test]
    fn fold_token_equals_combine_with_leaf() {
        prop::check("fold == combine(acc, leaf)", 64, |rng| {
            let d = 4;
            let mut acc = rand_tuple(rng, d, 10.0);
            let s = rng.range(-10.0, 10.0) as f32;
            let v: Vec<f32> = (0..d).map(|_| rng.gaussian() as f32).collect();
            let want = combine(&acc, &Muw::leaf(s, &v));
            fold_token(&mut acc, s, &v);
            if (acc.m - want.m).abs() > 1e-6 || (acc.u - want.u).abs() > 1e-5 {
                return Err("m/u mismatch".to_string());
            }
            prop::assert_close(&acc.w, &want.w, 1e-5)
        });
    }

    #[test]
    fn output_is_softmax_weighted_average() {
        let mut acc = Muw::identity(1);
        fold_token(&mut acc, 0.0, &[1.0]);
        fold_token(&mut acc, 0.0, &[3.0]);
        let o = acc.output();
        assert!((o[0] - 2.0).abs() < 1e-6, "equal scores average values");
    }
}
