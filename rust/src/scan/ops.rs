//! The associative operator ⊕ on (m, u, w) tuples — Appendix B of the
//! paper, verbatim:
//!
//!   m_{A∪B} = max(m_A, m_B)
//!   u_{A∪B} = u_A·exp(m_A − m_{A∪B}) + u_B·exp(m_B − m_{A∪B})
//!   w_{A∪B} = w_A·exp(m_A − m_{A∪B}) + w_B·exp(m_B − m_{A∪B})
//!
//! A leaf for token i is (s_i, 1, v_i); after an inclusive scan, the k-th
//! tuple is (m_k, c_k, a_k) and attention's prefix output is o_k = a_k/c_k.
//!
//! Two forms live here:
//!
//! * [`Muw`] — a single owned tuple. Since the SoA refactor this is only
//!   the O(1)-state view used by the streaming fold (`fold_token`) and by
//!   tests/interop; bulk scans operate on [`crate::scan::ScanBuffer`]
//!   instead and never allocate per element.
//! * slice kernels ([`combine_rows`], [`fold_row`], [`scan_rows_inplace`])
//!   — the allocation-free ⊕ over raw SoA components that every scan
//!   strategy is built from.

/// Finite "minus infinity": exp(MASK_FILL − m) underflows to exactly 0
/// while every intermediate stays finite (a true −∞ would yield NaN via
/// `−∞ − −∞` when combining two identities). Must match
/// python/compile/kernels/ref.py::MASK_FILL.
pub const MASK_FILL: f32 = -1e9;

/// Fixed width of the bounds-check-free inner kernels: eight f32 lanes is
/// one AVX2 register (two NEON ones). `chunks_exact` hands the optimizer
/// constant-length windows with no tail condition inside the loop, which
/// is what lets the `w`-row axpby autovectorize; the scalar remainder
/// handles `d % KERNEL_WIDTH` rows.
const KERNEL_WIDTH: usize = 8;

/// `wo = wa·ea + wb·eb` over three equal-length rows — the shared inner
/// kernel of every ⊕ (single-lane and batch): fixed-width chunks, no
/// per-element bounds checks. Product-then-sum order matches the scalar
/// loops it replaced, so results are bitwise identical.
#[inline(always)]
pub(crate) fn axpby_into(ea: f32, wa: &[f32], eb: f32, wb: &[f32], wo: &mut [f32]) {
    debug_assert_eq!(wa.len(), wo.len());
    debug_assert_eq!(wb.len(), wo.len());
    let mut oc = wo.chunks_exact_mut(KERNEL_WIDTH);
    let mut ac = wa.chunks_exact(KERNEL_WIDTH);
    let mut bc = wb.chunks_exact(KERNEL_WIDTH);
    for ((o, a), b) in (&mut oc).zip(&mut ac).zip(&mut bc) {
        for i in 0..KERNEL_WIDTH {
            o[i] = a[i] * ea + b[i] * eb;
        }
    }
    for ((o, a), b) in oc.into_remainder().iter_mut().zip(ac.remainder()).zip(bc.remainder()) {
        *o = a * ea + *b * eb;
    }
}

/// In-place form of [`axpby_into`]: `wb = wa·ea + wb·eb`. The broadcast /
/// fold kernel of the sequential scan, the chunked scan's carry phase and
/// the batched lane fold.
#[inline(always)]
pub(crate) fn axpby_inplace(ea: f32, wa: &[f32], eb: f32, wb: &mut [f32]) {
    debug_assert_eq!(wa.len(), wb.len());
    let mut bc = wb.chunks_exact_mut(KERNEL_WIDTH);
    let mut ac = wa.chunks_exact(KERNEL_WIDTH);
    for (b, a) in (&mut bc).zip(&mut ac) {
        for i in 0..KERNEL_WIDTH {
            b[i] = a[i] * ea + b[i] * eb;
        }
    }
    for (b, a) in bc.into_remainder().iter_mut().zip(ac.remainder()) {
        *b = a * ea + *b * eb;
    }
}

/// One scan element: running max `m`, normaliser `u`, weighted value sum `w`.
///
/// Kept as the single-tuple view for the O(1) streaming fold; the scan
/// strategies themselves work on the flat SoA `ScanBuffer`.
#[derive(Clone, Debug, PartialEq)]
pub struct Muw {
    pub m: f32,
    pub u: f32,
    pub w: Vec<f32>,
}

impl Muw {
    /// Leaf tuple for a token with score `s` and value `v`: (s, 1, v).
    pub fn leaf(s: f32, v: &[f32]) -> Muw {
        Muw { m: s, u: 1.0, w: v.to_vec() }
    }

    /// Identity element: ⊕-neutral on both sides.
    pub fn identity(dim: usize) -> Muw {
        Muw { m: MASK_FILL, u: 0.0, w: vec![0.0; dim] }
    }

    /// The attention output this prefix represents: o = w / u. The
    /// identity (u == 0, nothing folded in yet / a fully-masked prefix
    /// encoded as identity) yields zeros, not NaN.
    pub fn output(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.w.len()];
        self.output_into(&mut out);
        out
    }

    /// `output()` into a caller-provided slice — the hot-path form.
    pub fn output_into(&self, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.w.len());
        if self.u == 0.0 {
            out.fill(0.0);
            return;
        }
        for (o, w) in out.iter_mut().zip(self.w.iter()) {
            *o = w / self.u;
        }
    }
}

/// a ⊕ b, allocating the result.
pub fn combine(a: &Muw, b: &Muw) -> Muw {
    let mut out = Muw { m: 0.0, u: 0.0, w: vec![0.0; a.w.len()] };
    combine_into(a, b, &mut out);
    out
}

/// a ⊕ b into a preallocated tuple (zero allocation).
pub fn combine_into(a: &Muw, b: &Muw, out: &mut Muw) {
    debug_assert_eq!(a.w.len(), b.w.len());
    if out.w.len() != a.w.len() {
        out.w.resize(a.w.len(), 0.0);
    }
    combine_rows(a.m, a.u, &a.w, b.m, b.u, &b.w, &mut out.m, &mut out.u, &mut out.w);
}

/// In-place fold: `acc = acc ⊕ leaf(s, v)` — the §3.1 RNN cell update
/// (Figure 2), specialised to avoid allocating a leaf. This is the O(1)
/// streaming update rust-native sessions use.
pub fn fold_token(acc: &mut Muw, s: f32, v: &[f32]) {
    let m = acc.m.max(s);
    let ea = (acc.m - m).exp();
    let eb = (s - m).exp();
    acc.m = m;
    acc.u = acc.u * ea + eb;
    axpby_inplace(eb, v, ea, &mut acc.w);
}

/// ⊕ over raw SoA components: (ma, ua, wa) ⊕ (mb, ub, wb) → (mo, uo, wo).
/// All three `w` slices have the same length `d`; nothing allocates.
#[inline(always)]
#[allow(clippy::too_many_arguments)]
pub fn combine_rows(
    ma: f32,
    ua: f32,
    wa: &[f32],
    mb: f32,
    ub: f32,
    wb: &[f32],
    mo: &mut f32,
    uo: &mut f32,
    wo: &mut [f32],
) {
    let m = ma.max(mb);
    let ea = (ma - m).exp();
    let eb = (mb - m).exp();
    *mo = m;
    *uo = ua * ea + ub * eb;
    axpby_into(ea, wa, eb, wb, wo);
}

/// In-place right-fold over raw SoA components:
/// (mb, ub, wb) := (ma, ua, wa) ⊕ (mb, ub, wb). The broadcast kernel of
/// the chunked scan (`a` is a carry prefix shared across many rows).
#[inline(always)]
pub fn fold_row(ma: f32, ua: f32, wa: &[f32], mb: &mut f32, ub: &mut f32, wb: &mut [f32]) {
    let m = ma.max(*mb);
    let ea = (ma - m).exp();
    let eb = (*mb - m).exp();
    *mb = m;
    *ub = ua * ea + *ub * eb;
    axpby_inplace(ea, wa, eb, wb);
}

/// Sequential inclusive scan over raw SoA slices, in place:
/// row i := row i-1 ⊕ row i. `m`/`u` have n rows, `w` is (n, d) flat.
/// This is the single-pass kernel behind `scan::sequential` and each
/// per-chunk worker of `scan::chunked_parallel` — zero allocation, one
/// linear walk over three flat buffers.
pub fn scan_rows_inplace(m: &mut [f32], u: &mut [f32], w: &mut [f32], d: usize) {
    let n = m.len();
    debug_assert_eq!(u.len(), n);
    debug_assert_eq!(w.len(), n * d);
    for i in 1..n {
        let mm = m[i - 1].max(m[i]);
        let ea = (m[i - 1] - mm).exp();
        let eb = (m[i] - mm).exp();
        m[i] = mm;
        u[i] = u[i - 1] * ea + u[i] * eb;
        let (prev, cur) = w[(i - 1) * d..(i + 1) * d].split_at_mut(d);
        axpby_inplace(ea, prev, eb, cur);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn rand_tuple(rng: &mut crate::util::rng::Rng, d: usize, mag: f64) -> Muw {
        Muw {
            m: rng.range(-mag, mag) as f32,
            u: rng.range(0.1, 3.0) as f32,
            w: (0..d).map(|_| rng.gaussian() as f32).collect(),
        }
    }

    #[test]
    fn operator_is_associative() {
        // Appendix B.2 — including extreme magnitudes where a naive
        // (un-maxed) implementation overflows.
        prop::check("(a+b)+c == a+(b+c)", 256, |rng| {
            let d = 1 + rng.below(6);
            let mag = [1.0, 10.0, 80.0][rng.below(3)];
            let (a, b, c) = (
                rand_tuple(rng, d, mag),
                rand_tuple(rng, d, mag),
                rand_tuple(rng, d, mag),
            );
            let left = combine(&combine(&a, &b), &c);
            let right = combine(&a, &combine(&b, &c));
            if (left.m - right.m).abs() > 1e-5 {
                return Err(format!("m {} vs {}", left.m, right.m));
            }
            let rel = |x: f32, y: f32| (x - y).abs() / (1e-6 + x.abs().max(y.abs()));
            if rel(left.u, right.u) > 1e-4 {
                return Err(format!("u {} vs {}", left.u, right.u));
            }
            for (x, y) in left.w.iter().zip(right.w.iter()) {
                if rel(*x, *y) > 1e-3 && (x - y).abs() > 1e-4 {
                    return Err(format!("w {x} vs {y}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn identity_is_neutral() {
        prop::check("e+x == x+e == x", 64, |rng| {
            let x = rand_tuple(rng, 4, 20.0);
            let e = Muw::identity(4);
            for got in [combine(&e, &x), combine(&x, &e)] {
                if (got.m - x.m).abs() > 1e-6 || (got.u - x.u).abs() > 1e-5 {
                    return Err(format!("{got:?} != {x:?}"));
                }
                prop::assert_close(&got.w, &x.w, 1e-5)?;
            }
            Ok(())
        });
    }

    #[test]
    fn correctness_against_direct_softmax() {
        // Appendix B.1: folding leaves equals computing softmax directly.
        prop::check("scan == direct softmax", 64, |rng| {
            let n = 1 + rng.below(32);
            let d = 3;
            let scores: Vec<f32> = (0..n).map(|_| rng.range(-30.0, 30.0) as f32).collect();
            let values: Vec<Vec<f32>> = (0..n)
                .map(|_| (0..d).map(|_| rng.gaussian() as f32).collect())
                .collect();
            let mut acc = Muw::identity(d);
            for (s, v) in scores.iter().zip(values.iter()) {
                fold_token(&mut acc, *s, v);
            }
            // direct, numerically-stable softmax
            let mx = scores.iter().cloned().fold(f32::MIN, f32::max);
            let exps: Vec<f32> = scores.iter().map(|s| (s - mx).exp()).collect();
            let z: f32 = exps.iter().sum();
            let mut want = vec![0.0f32; d];
            for (e, v) in exps.iter().zip(values.iter()) {
                for (wd, vd) in want.iter_mut().zip(v.iter()) {
                    *wd += e / z * vd;
                }
            }
            prop::assert_close(&acc.output(), &want, 1e-5)
        });
    }

    #[test]
    fn fold_token_equals_combine_with_leaf() {
        prop::check("fold == combine(acc, leaf)", 64, |rng| {
            let d = 4;
            let mut acc = rand_tuple(rng, d, 10.0);
            let s = rng.range(-10.0, 10.0) as f32;
            let v: Vec<f32> = (0..d).map(|_| rng.gaussian() as f32).collect();
            let want = combine(&acc, &Muw::leaf(s, &v));
            fold_token(&mut acc, s, &v);
            if (acc.m - want.m).abs() > 1e-6 || (acc.u - want.u).abs() > 1e-5 {
                return Err("m/u mismatch".to_string());
            }
            prop::assert_close(&acc.w, &want.w, 1e-5)
        });
    }

    #[test]
    fn output_is_softmax_weighted_average() {
        let mut acc = Muw::identity(1);
        fold_token(&mut acc, 0.0, &[1.0]);
        fold_token(&mut acc, 0.0, &[3.0]);
        let o = acc.output();
        assert!((o[0] - 2.0).abs() < 1e-6, "equal scores average values");
    }

    #[test]
    fn identity_output_is_zero_not_nan() {
        // regression: the identity / fully-masked prefix has u == 0 and
        // used to emit NaN from the w/u division.
        let e = Muw::identity(3);
        assert_eq!(e.output(), vec![0.0, 0.0, 0.0]);
        let mut out = vec![f32::NAN; 3];
        e.output_into(&mut out);
        assert_eq!(out, vec![0.0, 0.0, 0.0]);
    }

    #[test]
    fn axpby_kernels_match_scalar_reference_at_every_width() {
        // widths straddling the fixed KERNEL_WIDTH chunking: empty, pure
        // remainder, exactly one chunk, chunk + remainder, several chunks
        let mut rng = crate::util::rng::Rng::new(11);
        for d in [0usize, 1, 3, 7, 8, 9, 15, 16, 23, 64] {
            let wa: Vec<f32> = (0..d).map(|_| rng.gaussian() as f32).collect();
            let wb: Vec<f32> = (0..d).map(|_| rng.gaussian() as f32).collect();
            let (ea, eb) = (0.37f32, 1.21f32);
            let want: Vec<f32> =
                wa.iter().zip(wb.iter()).map(|(a, b)| a * ea + b * eb).collect();
            let mut out = vec![f32::NAN; d];
            axpby_into(ea, &wa, eb, &wb, &mut out);
            assert_eq!(out, want, "axpby_into d={d}");
            let mut inout = wb.clone();
            axpby_inplace(ea, &wa, eb, &mut inout);
            assert_eq!(inout, want, "axpby_inplace d={d}");
        }
    }

    #[test]
    fn fold_row_equals_combine_rows() {
        prop::check("fold_row == combine_rows", 64, |rng| {
            let d = 5;
            let a = rand_tuple(rng, d, 40.0);
            let b = rand_tuple(rng, d, 40.0);
            let mut want = Muw::identity(d);
            combine_into(&a, &b, &mut want);
            let (mut mb, mut ub, mut wb) = (b.m, b.u, b.w.clone());
            fold_row(a.m, a.u, &a.w, &mut mb, &mut ub, &mut wb);
            if (mb - want.m).abs() > 1e-6 {
                return Err(format!("m {mb} vs {}", want.m));
            }
            if (ub - want.u).abs() > 1e-4 * want.u.abs().max(1.0) {
                return Err(format!("u {ub} vs {}", want.u));
            }
            prop::assert_close(&wb, &want.w, 1e-4)
        });
    }

    #[test]
    fn scan_rows_inplace_matches_repeated_fold() {
        prop::check("scan_rows_inplace == fold chain", 64, |rng| {
            let (n, d) = (1 + rng.below(40), 1 + rng.below(6));
            let tuples: Vec<Muw> = (0..n).map(|_| rand_tuple(rng, d, 30.0)).collect();
            let mut m: Vec<f32> = tuples.iter().map(|t| t.m).collect();
            let mut u: Vec<f32> = tuples.iter().map(|t| t.u).collect();
            let mut w: Vec<f32> = tuples.iter().flat_map(|t| t.w.clone()).collect();
            scan_rows_inplace(&mut m, &mut u, &mut w, d);
            let mut acc = tuples[0].clone();
            for (i, t) in tuples.iter().enumerate().skip(1) {
                acc = combine(&acc, t);
                if (m[i] - acc.m).abs() > 1e-5 {
                    return Err(format!("m[{i}] {} vs {}", m[i], acc.m));
                }
                let got: Vec<f32> = w[i * d..(i + 1) * d].iter().map(|x| x / u[i]).collect();
                prop::assert_close(&got, &acc.output(), 1e-4)?;
            }
            Ok(())
        });
    }
}
