//! Struct-of-arrays storage for (m, u, w) scan elements.
//!
//! The seed implementation stored each tuple as an owned `Muw` with a
//! heap-allocated `Vec<f32>` value row — an array-of-structs layout that
//! put an allocator round-trip and a pointer chase on every ⊕ of the hot
//! path. [`ScanBuffer`] flattens a whole sequence into three contiguous
//! buffers:
//!
//! ```text
//!   m: [f32; n]        running maxes
//!   u: [f32; n]        normalisers
//!   w: [f32; n * d]    value rows, row-major (row i = w[i*d .. (i+1)*d])
//! ```
//!
//! so a sweep is a linear walk over flat memory (SIMD/prefetch friendly),
//! buffers are reusable across sweeps (ping-pong instead of clone), and
//! chunked parallel scans can hand each worker a disjoint `&mut` window
//! of the same allocation. `Muw` remains only as the single-tuple view
//! for O(1) streaming state.

use crate::scan::ops::{axpby_inplace, Muw, MASK_FILL};

/// A sequence of (m, u, w) scan elements in flat SoA layout.
///
/// Push one leaf per token, run any `crate::scan` strategy over the
/// buffer, read outputs back:
///
/// ```
/// use aaren::scan::{sequential, ScanBuffer};
///
/// let mut buf = ScanBuffer::new(1);
/// buf.push_leaf(0.0, &[1.0]); // (score, value) leaf per token…
/// buf.push_leaf(0.0, &[3.0]);
/// let scanned = sequential(&buf); // …inclusive ⊕ prefix scan
/// // equal scores ⇒ outputs are running means of the values
/// assert_eq!(scanned.outputs(), vec![1.0, 2.0]);
/// ```
#[derive(Clone, Debug, PartialEq)]
pub struct ScanBuffer {
    d: usize,
    /// running max per element, length n
    pub m: Vec<f32>,
    /// normaliser per element, length n
    pub u: Vec<f32>,
    /// value rows, (n, d) row-major flat
    pub w: Vec<f32>,
}

impl ScanBuffer {
    /// Empty buffer for elements of value-dimension `d`.
    pub fn new(d: usize) -> ScanBuffer {
        ScanBuffer { d, m: Vec::new(), u: Vec::new(), w: Vec::new() }
    }

    /// Empty buffer with room for `n` elements (no reallocation while
    /// pushing up to `n` leaves).
    pub fn with_capacity(d: usize, n: usize) -> ScanBuffer {
        ScanBuffer {
            d,
            m: Vec::with_capacity(n),
            u: Vec::with_capacity(n),
            w: Vec::with_capacity(n * d),
        }
    }

    /// `n` identity elements (⊕-neutral): m = MASK_FILL, u = 0, w = 0.
    pub fn identities(n: usize, d: usize) -> ScanBuffer {
        ScanBuffer {
            d,
            m: vec![MASK_FILL; n],
            u: vec![0.0; n],
            w: vec![0.0; n * d],
        }
    }

    /// Number of elements.
    pub fn len(&self) -> usize {
        self.m.len()
    }

    pub fn is_empty(&self) -> bool {
        self.m.is_empty()
    }

    /// Value dimension `d` of each element.
    pub fn dim(&self) -> usize {
        self.d
    }

    /// Append a leaf (s, 1, v) — the tuple attention builds per token.
    pub fn push_leaf(&mut self, s: f32, v: &[f32]) {
        debug_assert_eq!(v.len(), self.d);
        self.m.push(s);
        self.u.push(1.0);
        self.w.extend_from_slice(v);
    }

    /// Append an arbitrary tuple (m, u, w).
    pub fn push_tuple(&mut self, m: f32, u: f32, w: &[f32]) {
        debug_assert_eq!(w.len(), self.d);
        self.m.push(m);
        self.u.push(u);
        self.w.extend_from_slice(w);
    }

    /// Append the identity element.
    pub fn push_identity(&mut self) {
        self.m.push(MASK_FILL);
        self.u.push(0.0);
        self.w.resize(self.w.len() + self.d, 0.0);
    }

    /// Grow (with identities) or shrink to exactly `n` elements.
    pub fn resize(&mut self, n: usize) {
        self.m.resize(n, MASK_FILL);
        self.u.resize(n, 0.0);
        self.w.resize(n * self.d, 0.0);
    }

    /// Borrow element `i` as (m, u, w-row).
    pub fn row(&self, i: usize) -> (f32, f32, &[f32]) {
        (self.m[i], self.u[i], &self.w[i * self.d..(i + 1) * self.d])
    }

    /// Copy element `i` out as an owned `Muw` (tests / streaming handoff).
    pub fn tuple(&self, i: usize) -> Muw {
        let (m, u, w) = self.row(i);
        Muw { m, u, w: w.to_vec() }
    }

    /// The attention output element `i` represents: o = w / u, with the
    /// u == 0 identity / fully-masked case yielding zeros (not NaN).
    pub fn output_into(&self, i: usize, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.d);
        let (_, u, w) = self.row(i);
        if u == 0.0 {
            out.fill(0.0);
            return;
        }
        for (o, x) in out.iter_mut().zip(w.iter()) {
            *o = x / u;
        }
    }

    /// All outputs as one (n, d) row-major vector — what the prefix
    /// attention consumers read back after a scan.
    pub fn outputs(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.len() * self.d];
        for (i, row) in out.chunks_exact_mut(self.d.max(1)).enumerate() {
            self.output_into(i, row);
        }
        out
    }

    /// Borrow the raw SoA state as three flat slices (m, u, w) — the view
    /// a persistence codec serializes: `persist::codec` payloads are raw
    /// f32 bit patterns, so exposing the buffers directly (rather than
    /// per-row copies) keeps snapshotting a pair of memcpys.
    pub fn state_views(&self) -> (&[f32], &[f32], &[f32]) {
        (&self.m, &self.u, &self.w)
    }

    /// Rebuild a buffer from raw state slices, the inverse of
    /// [`state_views`](Self::state_views). Lengths must describe the same
    /// `n` for dimension `d` (`w.len() == m.len() * d`); the f32s are
    /// adopted bit-for-bit, so `from_state(d, state_views(..))` is a
    /// bitwise round-trip.
    pub fn from_state(d: usize, m: &[f32], u: &[f32], w: &[f32]) -> Option<ScanBuffer> {
        if m.len() != u.len() || w.len() != m.len() * d {
            return None;
        }
        Some(ScanBuffer { d, m: m.to_vec(), u: u.to_vec(), w: w.to_vec() })
    }

    /// Build from owned tuples (interop / tests). All tuples must share
    /// one dimension; an empty slice yields an empty d = 0 buffer.
    pub fn from_leaves(leaves: &[Muw]) -> ScanBuffer {
        let d = leaves.first().map_or(0, |t| t.w.len());
        let mut buf = ScanBuffer::with_capacity(d, leaves.len());
        for t in leaves {
            buf.push_tuple(t.m, t.u, &t.w);
        }
        buf
    }

    /// Explode back into owned tuples (interop / tests).
    pub fn to_muws(&self) -> Vec<Muw> {
        (0..self.len()).map(|i| self.tuple(i)).collect()
    }

    /// In-place ⊕ between two rows of this buffer:
    /// row j := row i ⊕ row j. Requires i < j (disjointness).
    pub(crate) fn fold_left_into(&mut self, i: usize, j: usize) {
        debug_assert!(i < j);
        let d = self.d;
        let m = self.m[i].max(self.m[j]);
        let ea = (self.m[i] - m).exp();
        let eb = (self.m[j] - m).exp();
        self.m[j] = m;
        self.u[j] = self.u[i] * ea + self.u[j] * eb;
        let (left, right) = self.w.split_at_mut(j * d);
        let wa = &left[i * d..(i + 1) * d];
        let wo = &mut right[..d];
        axpby_inplace(ea, wa, eb, wo);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scan::ops::combine;

    #[test]
    fn push_and_row_roundtrip() {
        let mut buf = ScanBuffer::new(2);
        buf.push_leaf(0.5, &[1.0, -2.0]);
        buf.push_identity();
        buf.push_tuple(1.5, 2.0, &[4.0, 6.0]);
        assert_eq!(buf.len(), 3);
        assert_eq!(buf.row(0), (0.5, 1.0, &[1.0, -2.0][..]));
        assert_eq!(buf.row(1), (MASK_FILL, 0.0, &[0.0, 0.0][..]));
        assert_eq!(buf.tuple(2), Muw { m: 1.5, u: 2.0, w: vec![4.0, 6.0] });
    }

    #[test]
    fn from_to_muws_roundtrip() {
        let tuples = vec![
            Muw { m: 0.1, u: 1.0, w: vec![1.0, 2.0, 3.0] },
            Muw { m: -0.7, u: 0.5, w: vec![-1.0, 0.0, 4.0] },
        ];
        let buf = ScanBuffer::from_leaves(&tuples);
        assert_eq!(buf.dim(), 3);
        assert_eq!(buf.to_muws(), tuples);
    }

    #[test]
    fn outputs_guard_identity_rows() {
        let mut buf = ScanBuffer::new(2);
        buf.push_identity();
        buf.push_tuple(0.0, 2.0, &[4.0, -8.0]);
        let o = buf.outputs();
        assert_eq!(&o[..2], &[0.0, 0.0], "identity row must read as zeros");
        assert_eq!(&o[2..], &[2.0, -4.0]);
    }

    #[test]
    fn fold_left_into_matches_combine() {
        let a = Muw { m: 3.0, u: 1.2, w: vec![1.0, -1.0] };
        let b = Muw { m: -2.0, u: 0.7, w: vec![0.5, 2.0] };
        let want = combine(&a, &b);
        let mut buf = ScanBuffer::from_leaves(&[a, b]);
        buf.fold_left_into(0, 1);
        let got = buf.tuple(1);
        assert!((got.m - want.m).abs() < 1e-6);
        assert!((got.u - want.u).abs() < 1e-5);
        for (x, y) in got.w.iter().zip(want.w.iter()) {
            assert!((x - y).abs() < 1e-5);
        }
    }

    #[test]
    fn state_views_roundtrip_is_bitwise() {
        let mut rng = crate::util::rng::Rng::new(9);
        let d = 3;
        let mut buf = ScanBuffer::with_capacity(d, 5);
        for _ in 0..5 {
            // arbitrary bit patterns, -0.0 and NaN included: the state
            // view must round-trip bits, not values
            let bits = |rng: &mut crate::util::rng::Rng| f32::from_bits(rng.below(1 << 32) as u32);
            let v: Vec<f32> = (0..d).map(|_| bits(&mut rng)).collect();
            buf.push_tuple(bits(&mut rng), bits(&mut rng), &v);
        }
        let (m, u, w) = buf.state_views();
        let back = ScanBuffer::from_state(d, m, u, w).unwrap();
        assert_eq!(back.len(), buf.len());
        for (a, b) in back.m.iter().chain(&back.u).chain(&back.w).zip(
            buf.m.iter().chain(&buf.u).chain(&buf.w),
        ) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // mismatched lengths are refused, not truncated
        assert!(ScanBuffer::from_state(d, m, u, &w[..w.len() - 1]).is_none());
        assert!(ScanBuffer::from_state(d, &m[..m.len() - 1], u, w).is_none());
    }

    #[test]
    fn resize_pads_with_identities() {
        let mut buf = ScanBuffer::new(1);
        buf.push_leaf(1.0, &[2.0]);
        buf.resize(3);
        assert_eq!(buf.len(), 3);
        assert_eq!(buf.row(2), (MASK_FILL, 0.0, &[0.0][..]));
        buf.resize(1);
        assert_eq!(buf.len(), 1);
        assert_eq!(buf.w.len(), 1);
    }

    #[test]
    fn resize_grown_rows_are_scan_neutral() {
        // regression: growth must pad with identity tuples (m = MASK_FILL,
        // u = 0, w = 0). A zeroed m = 0.0 row is NOT ⊕-neutral — it lifts
        // the running max of any negative-scored prefix (max(m, 0) = 0),
        // which the Blelloch power-of-two padding would then propagate.
        // Scanning through grown rows must leave every real prefix
        // bitwise untouched and keep the padded tail equal to the last
        // real prefix.
        let mut rng = crate::util::rng::Rng::new(5);
        let d = 3;
        let mut real = ScanBuffer::with_capacity(d, 6);
        for _ in 0..6 {
            // negative scores: the case a zero-m pad would corrupt
            let s = rng.range(-9.0, -1.0) as f32;
            let v: Vec<f32> = (0..d).map(|_| rng.gaussian() as f32).collect();
            real.push_leaf(s, &v);
        }
        let mut grown = real.clone();
        grown.resize(9);
        let want = crate::scan::sequential(&real);
        let got = crate::scan::sequential(&grown);
        for i in 0..6 {
            assert_eq!(got.row(i), want.row(i), "real prefix {i} changed by padding");
        }
        let (lm, lu, lw) = want.row(5);
        for i in 6..9 {
            let (m, u, w) = got.row(i);
            assert_eq!((m, u, w), (lm, lu, lw), "padded row {i} is not ⊕-neutral");
        }
    }
}
