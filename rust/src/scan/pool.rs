//! Persistent worker pool for the chunked parallel scan.
//!
//! `scan::chunked_parallel` used to spawn a fresh `std::thread::scope`
//! worker set on every call; for n ≲ 10k the spawn cost capped the
//! speedup (ROADMAP follow-up). This pool spawns its workers once
//! (lazily, one per available core) and reuses them for every scan: a
//! scope is now a channel send per chunk instead of a thread spawn +
//! join per chunk.
//!
//! The API is intentionally scan-shaped: [`ScanPool::scope`] takes a
//! batch of jobs that may borrow the caller's stack (the disjoint `&mut`
//! chunk windows of one `ScanBuffer`) and blocks until every job has
//! run. That blocking is what makes the lifetime erasure sound: no job
//! can outlive the borrow it captured because `scope` does not return —
//! even on panic, a drop guard waits — until the last job finished.
//!
//! Do not call `scope` from inside a pool job: jobs queued by an inner
//! scope could wait on the very worker that is blocked inside it.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{mpsc, Arc, Condvar, Mutex, OnceLock};

/// A type-erased unit of work. Jobs submitted through [`ScanPool::scope`]
/// actually borrow the caller's stack; the latch protocol in `scope`
/// guarantees they finish before those borrows end.
type Job = Box<dyn FnOnce() + Send + 'static>;

/// Counts outstanding jobs of one `scope` call and records panics.
struct Latch {
    /// (pending jobs, any job panicked)
    state: Mutex<(usize, bool)>,
    done: Condvar,
}

impl Latch {
    fn new() -> Latch {
        Latch { state: Mutex::new((0, false)), done: Condvar::new() }
    }

    fn add(&self) {
        self.state.lock().unwrap().0 += 1;
    }

    fn complete(&self, panicked: bool) {
        let mut g = self.state.lock().unwrap();
        g.0 -= 1;
        g.1 |= panicked;
        if g.0 == 0 {
            self.done.notify_all();
        }
    }

    fn wait(&self) {
        let mut g = self.state.lock().unwrap();
        while g.0 > 0 {
            g = self.done.wait(g).unwrap();
        }
    }

    fn panicked(&self) -> bool {
        self.state.lock().unwrap().1
    }
}

/// Decrements the latch when a job finishes — including by unwinding, so
/// a panicking job can never leave `scope` waiting forever.
struct LatchGuard(Arc<Latch>);

impl LatchGuard {
    fn new(latch: &Arc<Latch>) -> LatchGuard {
        latch.add();
        LatchGuard(Arc::clone(latch))
    }
}

impl Drop for LatchGuard {
    fn drop(&mut self) {
        self.0.complete(std::thread::panicking());
    }
}

/// Waits for all submitted jobs even if the caller's inline job panics,
/// so borrowed chunk windows stay alive until every worker is done.
struct WaitGuard<'a>(&'a Latch);

impl Drop for WaitGuard<'_> {
    fn drop(&mut self) {
        self.0.wait();
    }
}

/// A fixed set of worker threads consuming jobs from one shared queue.
/// Workers live as long as the pool (forever, for [`ScanPool::global`]).
pub struct ScanPool {
    tx: mpsc::Sender<Job>,
    threads: usize,
}

impl ScanPool {
    /// Pool with exactly `threads` workers (tests use this; production
    /// code shares [`ScanPool::global`]).
    pub fn with_threads(threads: usize) -> ScanPool {
        let threads = threads.max(1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        for i in 0..threads {
            let rx = Arc::clone(&rx);
            std::thread::Builder::new()
                .name(format!("scan-pool-{i}"))
                .spawn(move || loop {
                    // hold the queue lock only while waiting for a job,
                    // never while running one
                    let job = match rx.lock().unwrap().recv() {
                        Ok(job) => job,
                        Err(_) => break, // pool dropped: workers drain out
                    };
                    // a panicking job must not kill the worker; the
                    // LatchGuard inside `job` records the panic for the
                    // waiting `scope` caller
                    let _ = catch_unwind(AssertUnwindSafe(job));
                })
                .expect("spawn scan pool worker");
        }
        ScanPool { tx, threads }
    }

    /// The process-wide pool, spawned on first use with one worker per
    /// available core.
    pub fn global() -> &'static ScanPool {
        static POOL: OnceLock<ScanPool> = OnceLock::new();
        POOL.get_or_init(|| {
            let t = std::thread::available_parallelism().map(|t| t.get()).unwrap_or(4);
            ScanPool::with_threads(t)
        })
    }

    /// Number of worker threads (the natural chunk count for a scan).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run every job on the pool and return once all of them completed.
    /// Jobs may borrow from the caller's stack (`'env`); the final job
    /// runs inline on the calling thread. Panics (after all jobs have
    /// finished) if any job panicked.
    pub fn scope<'env>(&self, mut jobs: Vec<Box<dyn FnOnce() + Send + 'env>>) {
        let latch = Arc::new(Latch::new());
        // run the last job on the caller: with C jobs on C busy cores
        // this saves one handoff, and a singleton batch never queues
        let inline = jobs.pop();
        // from here on, every exit path must wait for queued jobs first
        let wait = WaitGuard(&latch);
        for job in jobs {
            let guard = LatchGuard::new(&latch);
            let wrapped: Box<dyn FnOnce() + Send + 'env> = Box::new(move || {
                let _guard = guard;
                job();
            });
            // SAFETY: `wrapped` borrows at most 'env data. It is either
            // executed by a worker or handed back by a failed send and
            // run below — and `wait` (the WaitGuard) blocks this frame
            // from returning, normally or by unwind, until the latch
            // hits zero, i.e. until the job has run and dropped. The
            // erased borrow therefore never outlives 'env.
            let erased: Job = unsafe { std::mem::transmute(wrapped) };
            if let Err(send_err) = self.tx.send(erased) {
                // workers gone (cannot happen for the global pool): run
                // the job here so correctness never depends on the pool
                (send_err.0)();
            }
        }
        if let Some(job) = inline {
            job();
        }
        drop(wait); // blocks until all queued jobs completed
        if latch.panicked() {
            panic!("scan pool job panicked");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_all_jobs_and_reuses_workers() {
        let pool = ScanPool::with_threads(3);
        for round in 0..50 {
            let counter = AtomicUsize::new(0);
            let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = (0..8)
                .map(|_| {
                    Box::new(|| {
                        counter.fetch_add(1, Ordering::SeqCst);
                    }) as Box<dyn FnOnce() + Send + '_>
                })
                .collect();
            pool.scope(jobs);
            assert_eq!(counter.load(Ordering::SeqCst), 8, "round {round}");
        }
    }

    #[test]
    fn jobs_mutate_disjoint_borrowed_windows() {
        let pool = ScanPool::with_threads(4);
        let mut data = vec![0usize; 64];
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = data
            .chunks_mut(16)
            .enumerate()
            .map(|(k, chunk)| {
                Box::new(move || {
                    for x in chunk.iter_mut() {
                        *x = k + 1;
                    }
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool.scope(jobs);
        for (i, x) in data.iter().enumerate() {
            assert_eq!(*x, i / 16 + 1);
        }
    }

    #[test]
    #[should_panic(expected = "scan pool job panicked")]
    fn propagates_job_panics_after_draining() {
        let pool = ScanPool::with_threads(2);
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = (0..4)
            .map(|k| {
                Box::new(move || {
                    if k == 1 {
                        panic!("boom");
                    }
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool.scope(jobs);
    }

    #[test]
    fn empty_scope_is_a_no_op() {
        ScanPool::with_threads(2).scope(Vec::new());
    }

    #[test]
    fn global_pool_is_shared_and_sized() {
        assert!(ScanPool::global().threads() >= 1);
        assert!(std::ptr::eq(ScanPool::global(), ScanPool::global()));
    }
}
