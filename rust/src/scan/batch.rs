//! Multi-lane struct-of-arrays storage for batched (m, u, w) scans — the
//! lane-parallel engine behind request coalescing in `crate::serve` and
//! the batched multi-query prefix consumers in `crate::attention`.
//!
//! [`super::ScanBuffer`] holds ONE sequence; serving B streams (or B
//! query heads) with it means B separate allocations and B separate
//! sweeps — the per-head allocation hotspot named in ROADMAP. A
//! [`BatchScanBuffer`] flattens B independent lanes of shared value
//! dimension `d` into one allocation, laid out **time-major**:
//!
//! ```text
//!   element (t, b)  at flat index  i = t·B + b
//!   m: [f32; n·B]        running maxes
//!   u: [f32; n·B]        normalisers
//!   w: [f32; n·B·d]      value rows (row i = w[i·d .. (i+1)·d])
//! ```
//!
//! so one time step is a contiguous B-wide row block. That makes the two
//! hot operations linear walks over flat memory:
//!
//! * [`fold_all`](BatchScanBuffer::fold_all) — fold one leaf into every
//!   lane's accumulator (the coalesced-serving step: B sessions advance
//!   one token in a single pass over a B×d block);
//! * [`scan_inplace`](BatchScanBuffer::scan_inplace) /
//!   [`scan_chunked`](BatchScanBuffer::scan_chunked) — inclusive prefix
//!   scan of all B lanes at once, `row-block t := row-block t−1 ⊕
//!   row-block t` with per-lane coefficients; the chunked form splits
//!   the time axis across the shared [`ScanPool`] exactly like
//!   `scan::chunked_parallel` does for one lane.
//!
//! Per lane, both scans perform the identical ⊕ sequence (and share the
//! fixed-width `axpby` inner kernels of `scan::ops`) as the single-lane
//! `ScanBuffer` strategies, so outputs are **bitwise equal** to scanning
//! each lane on its own — the batch engine changes memory layout and
//! parallelism, never numerics.

use crate::scan::ops::{axpby_inplace, fold_row, MASK_FILL};
use crate::scan::pool::ScanPool;
use crate::scan::soa::ScanBuffer;

/// B independent (m, u, w) lanes of shared dim `d` in one flat, reusable
/// time-major SoA allocation.
#[derive(Clone, Debug, PartialEq)]
pub struct BatchScanBuffer {
    lanes: usize,
    d: usize,
    /// lanes of the trailing step already filled by `push_leaf_lane`
    /// (0 = no step is partially staged)
    staged: usize,
    m: Vec<f32>,
    u: Vec<f32>,
    w: Vec<f32>,
}

impl BatchScanBuffer {
    /// Empty buffer for `lanes` lanes of value-dimension `d`.
    pub fn new(lanes: usize, d: usize) -> BatchScanBuffer {
        BatchScanBuffer { lanes, d, staged: 0, m: Vec::new(), u: Vec::new(), w: Vec::new() }
    }

    /// Empty buffer with room for `steps` time steps per lane.
    pub fn with_capacity(lanes: usize, d: usize, steps: usize) -> BatchScanBuffer {
        BatchScanBuffer {
            lanes,
            d,
            staged: 0,
            m: Vec::with_capacity(steps * lanes),
            u: Vec::with_capacity(steps * lanes),
            w: Vec::with_capacity(steps * lanes * d),
        }
    }

    /// Number of lanes B.
    pub fn lanes(&self) -> usize {
        self.lanes
    }

    /// Value dimension `d` of each element.
    pub fn dim(&self) -> usize {
        self.d
    }

    /// Time steps held per lane (a partially staged trailing step counts:
    /// its unfilled lanes are identities).
    pub fn steps(&self) -> usize {
        if self.lanes == 0 {
            0
        } else {
            self.m.len() / self.lanes
        }
    }

    pub fn is_empty(&self) -> bool {
        self.m.is_empty()
    }

    /// Re-shape for reuse (executor scratch): keeps the allocations,
    /// drops the contents.
    pub fn reset(&mut self, lanes: usize, d: usize) {
        self.lanes = lanes;
        self.d = d;
        self.staged = 0;
        self.m.clear();
        self.u.clear();
        self.w.clear();
    }

    /// Append one identity row block (every lane gets an ⊕-neutral
    /// element: m = MASK_FILL, u = 0, w = 0).
    pub fn push_identity_row(&mut self) {
        assert_eq!(self.staged, 0, "cannot start a new step mid-way through a staged one");
        self.m.resize(self.m.len() + self.lanes, MASK_FILL);
        self.u.resize(self.u.len() + self.lanes, 0.0);
        self.w.resize(self.w.len() + self.lanes * self.d, 0.0);
    }

    /// Append the leaf (s, 1, v) for `lane` in the current time step.
    /// Lanes must be pushed in round-robin order (0, 1, …, B−1, 0, …);
    /// the first lane of a step appends a fresh identity row block, so a
    /// step left partially pushed is still well-formed (identity lanes).
    pub fn push_leaf_lane(&mut self, lane: usize, s: f32, v: &[f32]) {
        assert!(self.lanes > 0, "push_leaf_lane on a zero-lane buffer");
        assert_eq!(lane, self.staged, "lanes must be pushed in order 0..B per step");
        debug_assert_eq!(v.len(), self.d);
        if self.staged == 0 {
            self.push_identity_row();
        }
        let i = (self.steps() - 1) * self.lanes + lane;
        self.m[i] = s;
        self.u[i] = 1.0;
        self.w[i * self.d..(i + 1) * self.d].copy_from_slice(v);
        self.staged = (self.staged + 1) % self.lanes;
    }

    /// Borrow element (t, lane) as (m, u, w-row).
    pub fn row(&self, t: usize, lane: usize) -> (f32, f32, &[f32]) {
        let i = t * self.lanes + lane;
        (self.m[i], self.u[i], &self.w[i * self.d..(i + 1) * self.d])
    }

    /// Overwrite element (t, lane) — the state-gather path of the serve
    /// executor (sessions load their accumulators into lanes).
    pub fn set_row(&mut self, t: usize, lane: usize, m: f32, u: f32, w: &[f32]) {
        debug_assert_eq!(w.len(), self.d);
        let i = t * self.lanes + lane;
        self.m[i] = m;
        self.u[i] = u;
        self.w[i * self.d..(i + 1) * self.d].copy_from_slice(w);
    }

    /// Fold one leaf (scores[b], 1, tokens[b·d..(b+1)·d]) into the LAST
    /// row of every lane, in place — the batched §3.1 RNN cell update: B
    /// streams advance one token in a single linear pass over the flat
    /// row block. Per lane this is exactly `ops::fold_token`.
    pub fn fold_all(&mut self, scores: &[f32], tokens: &[f32]) {
        let (lanes, d) = (self.lanes, self.d);
        assert_eq!(scores.len(), lanes, "one score per lane");
        assert_eq!(tokens.len(), lanes * d, "one d-dim token per lane");
        for b in 0..lanes {
            self.fold_lane(b, scores[b], &tokens[b * d..(b + 1) * d]);
        }
    }

    /// [`fold_all`](Self::fold_all) for a single lane — the straggler
    /// path when lanes carry different numbers of pending tokens.
    pub fn fold_lane(&mut self, lane: usize, s: f32, x: &[f32]) {
        let d = self.d;
        debug_assert_eq!(x.len(), d);
        assert!(self.staged == 0 && self.steps() > 0, "fold_lane needs a committed row block");
        let i = (self.steps() - 1) * self.lanes + lane;
        let mm = self.m[i].max(s);
        let ea = (self.m[i] - mm).exp();
        let eb = (s - mm).exp();
        self.m[i] = mm;
        self.u[i] = self.u[i] * ea + eb;
        axpby_inplace(eb, x, ea, &mut self.w[i * d..(i + 1) * d]);
    }

    /// The attention output element (t, lane) represents: o = w / u, with
    /// the u == 0 identity / fully-masked case yielding zeros (not NaN).
    pub fn lane_output_into(&self, t: usize, lane: usize, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.d);
        let (_, u, w) = self.row(t, lane);
        if u == 0.0 {
            out.fill(0.0);
            return;
        }
        for (o, x) in out.iter_mut().zip(w.iter()) {
            *o = x / u;
        }
    }

    /// All lane outputs at time step `t` as one contiguous (B, d) block —
    /// what the coalesced serve executor writes straight into replies.
    pub fn outputs_into(&self, t: usize, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.lanes * self.d);
        for (b, row) in out.chunks_exact_mut(self.d.max(1)).enumerate() {
            self.lane_output_into(t, b, row);
        }
    }

    /// Copy lane `lane` out as a single-sequence [`ScanBuffer`]
    /// (tests / interop with the single-lane strategies).
    pub fn lane_buffer(&self, lane: usize) -> ScanBuffer {
        let mut buf = ScanBuffer::with_capacity(self.d, self.steps());
        for t in 0..self.steps() {
            let (m, u, w) = self.row(t, lane);
            buf.push_tuple(m, u, w);
        }
        buf
    }

    /// Sequential inclusive prefix scan of every lane at once, in place:
    /// row-block t := row-block t−1 ⊕ row-block t, per-lane coefficients.
    /// One linear walk; per lane bitwise equal to
    /// `ops::scan_rows_inplace` on that lane alone.
    pub fn scan_inplace(&mut self) {
        scan_block(&mut self.m, &mut self.u, &mut self.w, self.lanes, self.d);
    }

    /// Multi-threaded chunked inclusive scan of every lane: the time axis
    /// is split into `num_chunks` contiguous chunks scanned independently
    /// on the shared [`ScanPool`], the per-chunk carry row-blocks are
    /// scanned serially, then each carry is broadcast into the next
    /// chunk — the same three phases (and, per lane, the same chunk
    /// boundaries, hence bitwise the same result) as
    /// `scan::chunked_parallel` with the same `num_chunks`.
    pub fn scan_chunked(&mut self, num_chunks: usize) {
        let steps = self.steps();
        assert_eq!(self.staged, 0, "cannot scan a partially staged step");
        if steps == 0 {
            return;
        }
        let chunk = steps.div_ceil(num_chunks.clamp(1, steps));
        let nchunks = steps.div_ceil(chunk);
        if nchunks == 1 {
            self.scan_inplace();
            return;
        }
        let (lanes, d) = (self.lanes, self.d);
        let pool = ScanPool::global();

        // phase 1: independent scan of each time chunk (all lanes), on
        // disjoint &mut windows of the one allocation
        pool.scope(
            block_views(&mut self.m, &mut self.u, &mut self.w, lanes, d, chunk, 0)
                .into_iter()
                .map(|(ms, us, ws)| {
                    Box::new(move || scan_block(ms, us, ws, lanes, d))
                        as Box<dyn FnOnce() + Send + '_>
                })
                .collect(),
        );

        // phase 2: scan the chunk-final carry row blocks (nchunks blocks
        // — serial, tiny)
        let mut carries = BatchScanBuffer::with_capacity(lanes, d, nchunks);
        for kc in 0..nchunks {
            let last = ((kc + 1) * chunk).min(steps) - 1;
            carries.push_identity_row();
            for b in 0..lanes {
                let (m, u, w) = self.row(last, b);
                carries.set_row(kc, b, m, u, w);
            }
        }
        carries.scan_inplace();

        // phase 3: broadcast carry block kc−1 into every row of chunk kc
        let carries = &carries;
        pool.scope(
            block_views(&mut self.m, &mut self.u, &mut self.w, lanes, d, chunk, 1)
                .into_iter()
                .enumerate()
                .map(|(kc, (ms, us, ws))| {
                    Box::new(move || {
                        let rows = if lanes == 0 { 0 } else { ms.len() / lanes };
                        for t in 0..rows {
                            for b in 0..lanes {
                                let (cm, cu, cw) = carries.row(kc, b);
                                let i = t * lanes + b;
                                fold_row(
                                    cm,
                                    cu,
                                    cw,
                                    &mut ms[i],
                                    &mut us[i],
                                    &mut ws[i * d..(i + 1) * d],
                                );
                            }
                        }
                    }) as Box<dyn FnOnce() + Send + '_>
                })
                .collect(),
        );
    }
}

/// The batched sequential scan kernel over raw time-major SoA windows:
/// `m`/`u` hold k·lanes elements (k complete row blocks), `w` is
/// (k·lanes, d) flat. Shared by `scan_inplace` and each phase-1 worker of
/// `scan_chunked`.
fn scan_block(m: &mut [f32], u: &mut [f32], w: &mut [f32], lanes: usize, d: usize) {
    let steps = if lanes == 0 { 0 } else { m.len() / lanes };
    debug_assert_eq!(u.len(), m.len());
    debug_assert_eq!(w.len(), m.len() * d);
    let rw = lanes * d;
    for t in 1..steps {
        let (mp, mc) = m[(t - 1) * lanes..(t + 1) * lanes].split_at_mut(lanes);
        let (up, uc) = u[(t - 1) * lanes..(t + 1) * lanes].split_at_mut(lanes);
        let (wp, wc) = w[(t - 1) * rw..(t + 1) * rw].split_at_mut(rw);
        for b in 0..lanes {
            let mm = mp[b].max(mc[b]);
            let ea = (mp[b] - mm).exp();
            let eb = (mc[b] - mm).exp();
            mc[b] = mm;
            uc[b] = up[b] * ea + uc[b] * eb;
            axpby_inplace(ea, &wp[b * d..(b + 1) * d], eb, &mut wc[b * d..(b + 1) * d]);
        }
    }
}

/// Split time-major SoA buffers into per-chunk disjoint
/// (&mut m, &mut u, &mut w) windows of `chunk` row blocks, skipping the
/// first `skip` chunks — the batch analogue of `scan::chunk_views`.
#[allow(clippy::type_complexity)]
fn block_views<'a>(
    m: &'a mut [f32],
    u: &'a mut [f32],
    w: &'a mut [f32],
    lanes: usize,
    d: usize,
    chunk: usize,
    skip: usize,
) -> Vec<(&'a mut [f32], &'a mut [f32], &'a mut [f32])> {
    let start = (chunk * skip * lanes).min(m.len());
    let mut ms = &mut m[start..];
    let mut us = &mut u[start..];
    let mut ws = &mut w[start * d..];
    let mut views = Vec::new();
    while !ms.is_empty() {
        let take = (chunk * lanes).min(ms.len());
        let (mh, mt) = ms.split_at_mut(take);
        let (uh, ut) = us.split_at_mut(take);
        let (wh, wt) = ws.split_at_mut(take * d);
        ms = mt;
        us = ut;
        ws = wt;
        views.push((mh, uh, wh));
    }
    views
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scan::ops::{fold_token, Muw};
    use crate::scan::{chunked_parallel, sequential_inplace};
    use crate::util::prop;
    use crate::util::rng::Rng;

    /// Random (B, n, d) leaves, materialized both as one batch buffer and
    /// as B independent single-lane buffers with identical rows.
    fn random_batch(
        rng: &mut Rng,
        lanes: usize,
        steps: usize,
        d: usize,
    ) -> (BatchScanBuffer, Vec<ScanBuffer>) {
        let mut batch = BatchScanBuffer::with_capacity(lanes, d, steps);
        let mut singles: Vec<ScanBuffer> =
            (0..lanes).map(|_| ScanBuffer::with_capacity(d, steps)).collect();
        for _ in 0..steps {
            for (b, single) in singles.iter_mut().enumerate() {
                let s = rng.range(-30.0, 30.0) as f32;
                let v: Vec<f32> = (0..d).map(|_| rng.gaussian() as f32).collect();
                batch.push_leaf_lane(b, s, &v);
                single.push_leaf(s, &v);
            }
        }
        (batch, singles)
    }

    fn assert_lane_bitwise(batch: &BatchScanBuffer, lane: usize, single: &ScanBuffer) {
        assert_eq!(batch.steps(), single.len());
        for t in 0..single.len() {
            let (bm, bu, bw) = batch.row(t, lane);
            let (sm, su, sw) = single.row(t);
            assert_eq!(bm.to_bits(), sm.to_bits(), "m lane {lane} t {t}: {bm} vs {sm}");
            assert_eq!(bu.to_bits(), su.to_bits(), "u lane {lane} t {t}: {bu} vs {su}");
            for (i, (x, y)) in bw.iter().zip(sw.iter()).enumerate() {
                assert_eq!(x.to_bits(), y.to_bits(), "w lane {lane} t {t} [{i}]: {x} vs {y}");
            }
        }
    }

    #[test]
    fn push_and_row_roundtrip() {
        let mut buf = BatchScanBuffer::new(2, 2);
        buf.push_leaf_lane(0, 0.5, &[1.0, -2.0]);
        // lane 1 of step 0 left staged: reads as the identity
        assert_eq!(buf.steps(), 1);
        assert_eq!(buf.row(0, 0), (0.5, 1.0, &[1.0, -2.0][..]));
        assert_eq!(buf.row(0, 1), (MASK_FILL, 0.0, &[0.0, 0.0][..]));
        buf.push_leaf_lane(1, 1.5, &[4.0, 6.0]);
        buf.push_leaf_lane(0, -0.5, &[0.0, 9.0]);
        assert_eq!(buf.steps(), 2);
        assert_eq!(buf.row(0, 1), (1.5, 1.0, &[4.0, 6.0][..]));
        assert_eq!(buf.row(1, 0), (-0.5, 1.0, &[0.0, 9.0][..]));
    }

    #[test]
    #[should_panic(expected = "in order")]
    fn out_of_order_push_is_rejected() {
        let mut buf = BatchScanBuffer::new(3, 1);
        buf.push_leaf_lane(1, 0.0, &[0.0]);
    }

    #[test]
    fn batch_sequential_scan_is_bitwise_equal_to_per_lane_scans() {
        // satellite property: random B, d, n — the batch engine must not
        // change numerics, only layout.
        prop::check("batch scan == per-lane scan (bitwise)", 48, |rng| {
            let lanes = 1 + rng.below(6);
            let steps = 1 + rng.below(40);
            let d = 1 + rng.below(7);
            let (mut batch, mut singles) = random_batch(rng, lanes, steps, d);
            batch.scan_inplace();
            for (b, single) in singles.iter_mut().enumerate() {
                sequential_inplace(single);
                assert_lane_bitwise(&batch, b, single);
            }
            Ok(())
        });
    }

    #[test]
    fn batch_chunked_scan_is_bitwise_equal_to_per_lane_chunked_scans() {
        // same chunk count → same per-lane chunk boundaries → the exact
        // same ⊕ sequence per lane, pool-parallel or not.
        prop::check("batch chunked == per-lane chunked (bitwise)", 32, |rng| {
            let lanes = 1 + rng.below(5);
            let steps = 1 + rng.below(120);
            let d = 1 + rng.below(5);
            let chunks = 1 + rng.below(9);
            let (mut batch, singles) = random_batch(rng, lanes, steps, d);
            batch.scan_chunked(chunks);
            for (b, single) in singles.iter().enumerate() {
                let want = chunked_parallel(single, chunks);
                assert_lane_bitwise(&batch, b, &want);
            }
            Ok(())
        });
    }

    #[test]
    fn fold_all_is_bitwise_equal_to_per_lane_fold_token() {
        prop::check("fold_all == fold_token per lane", 48, |rng| {
            let lanes = 1 + rng.below(8);
            let d = 1 + rng.below(9);
            let rounds = 1 + rng.below(12);
            let mut batch = BatchScanBuffer::new(lanes, d);
            batch.push_identity_row();
            let mut accs: Vec<Muw> = (0..lanes).map(|_| Muw::identity(d)).collect();
            for _ in 0..rounds {
                let scores: Vec<f32> = (0..lanes).map(|_| rng.range(-40.0, 40.0) as f32).collect();
                let tokens: Vec<f32> = (0..lanes * d).map(|_| rng.gaussian() as f32).collect();
                batch.fold_all(&scores, &tokens);
                for (b, acc) in accs.iter_mut().enumerate() {
                    fold_token(acc, scores[b], &tokens[b * d..(b + 1) * d]);
                }
            }
            let mut got = vec![0.0f32; lanes * d];
            batch.outputs_into(0, &mut got);
            for (b, acc) in accs.iter().enumerate() {
                let (m, u, w) = batch.row(0, b);
                if m.to_bits() != acc.m.to_bits() || u.to_bits() != acc.u.to_bits() {
                    return Err(format!("lane {b} m/u diverged: ({m},{u}) vs {acc:?}"));
                }
                for (x, y) in w.iter().zip(acc.w.iter()) {
                    if x.to_bits() != y.to_bits() {
                        return Err(format!("lane {b} w diverged: {x} vs {y}"));
                    }
                }
                prop::assert_close(&got[b * d..(b + 1) * d], &acc.output(), 0.0)
                    .map_err(|e| format!("lane {b} output: {e}"))?;
            }
            Ok(())
        });
    }

    #[test]
    fn fold_lane_matches_fold_all_on_that_lane() {
        let d = 4;
        let mut rng = Rng::new(9);
        let mut a = BatchScanBuffer::new(3, d);
        let mut b = BatchScanBuffer::new(3, d);
        a.push_identity_row();
        b.push_identity_row();
        for _ in 0..6 {
            let scores: Vec<f32> = (0..3).map(|_| rng.range(-5.0, 5.0) as f32).collect();
            let tokens: Vec<f32> = (0..3 * d).map(|_| rng.gaussian() as f32).collect();
            a.fold_all(&scores, &tokens);
            for lane in 0..3 {
                b.fold_lane(lane, scores[lane], &tokens[lane * d..(lane + 1) * d]);
            }
        }
        assert_eq!(a, b);
    }

    #[test]
    fn outputs_into_writes_lane_major_blocks() {
        let mut buf = BatchScanBuffer::new(2, 2);
        buf.push_identity_row();
        // lane 0: u=2, w=(4,-8) → (2,-4); lane 1 identity → zeros
        buf.set_row(0, 0, 0.0, 2.0, &[4.0, -8.0]);
        let mut out = vec![f32::NAN; 4];
        buf.outputs_into(0, &mut out);
        assert_eq!(out, vec![2.0, -4.0, 0.0, 0.0]);
    }

    #[test]
    fn lane_buffer_roundtrips_rows() {
        let mut rng = Rng::new(3);
        let (batch, singles) = random_batch(&mut rng, 3, 5, 2);
        for (b, single) in singles.iter().enumerate() {
            assert_eq!(&batch.lane_buffer(b), single);
        }
    }

    #[test]
    fn reset_reuses_the_allocation_across_shapes() {
        let mut rng = Rng::new(4);
        let (mut buf, _) = random_batch(&mut rng, 4, 8, 3);
        buf.scan_inplace();
        buf.reset(2, 5);
        assert_eq!((buf.lanes(), buf.dim(), buf.steps()), (2, 5, 0));
        buf.push_identity_row();
        buf.fold_all(&[1.0, -1.0], &[0.5; 10]);
        let mut out = vec![0.0f32; 10];
        buf.outputs_into(0, &mut out);
        assert!(out.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn single_lane_batch_degenerates_to_scan_buffer() {
        let mut rng = Rng::new(6);
        let (mut batch, mut singles) = random_batch(&mut rng, 1, 33, 4);
        batch.scan_chunked(4);
        let want = chunked_parallel(&singles.remove(0), 4);
        assert_lane_bitwise(&batch, 0, &want);
    }

    #[test]
    fn empty_batch_scans_are_no_ops() {
        let mut buf = BatchScanBuffer::new(3, 2);
        buf.scan_inplace();
        buf.scan_chunked(4);
        assert_eq!(buf.steps(), 0);
    }
}
