//! Multi-lane struct-of-arrays storage for batched (m, u, w) scans — the
//! lane-parallel engine behind request coalescing in `crate::serve` and
//! the batched multi-query prefix consumers in `crate::attention`.
//!
//! [`super::ScanBuffer`] holds ONE sequence; serving B streams (or B
//! query heads) with it means B separate allocations and B separate
//! sweeps — the per-head allocation hotspot named in ROADMAP. A
//! [`BatchScanBuffer`] flattens B independent lanes of shared value
//! dimension `d` into one allocation, laid out **time-major**:
//!
//! ```text
//!   element (t, b)  at flat index  i = t·B + b
//!   m: [f32; n·B]        running maxes
//!   u: [f32; n·B]        normalisers
//!   w: [f32; n·B·d]      value rows (row i = w[i·d .. (i+1)·d])
//! ```
//!
//! so one time step is a contiguous B-wide row block. That makes the two
//! hot operations linear walks over flat memory:
//!
//! * [`fold_all`](BatchScanBuffer::fold_all) — fold one leaf into every
//!   lane's accumulator (the coalesced-serving step: B sessions advance
//!   one token in a single pass over a B×d block);
//! * [`scan_inplace`](BatchScanBuffer::scan_inplace) /
//!   [`scan_chunked`](BatchScanBuffer::scan_chunked) — inclusive prefix
//!   scan of all B lanes at once, `row-block t := row-block t−1 ⊕
//!   row-block t` with per-lane coefficients; the chunked form splits
//!   the time axis across the shared [`ScanPool`] exactly like
//!   `scan::chunked_parallel` does for one lane.
//!
//! Per lane, both scans perform the identical ⊕ sequence (and share the
//! fixed-width `axpby` inner kernels of `scan::ops`) as the single-lane
//! `ScanBuffer` strategies, so outputs are **bitwise equal** to scanning
//! each lane on its own — the batch engine changes memory layout and
//! parallelism, never numerics.
//!
//! [`LaneSet`] — the executor-resident lane allocator — layers on flat
//! [`FoldKernel`] state rows instead: one homogeneous `(kernel, width)`
//! set per map entry, each lane one kernel state row folded in place.
//! For Aaren lanes its folds delegate to the same `ops::fold_token`
//! float sequence, so the kernel-generic storage is bitwise identical to
//! the pre-refactor (m, u, w) lanes.

use crate::scan::kernel::{FoldKernel, KernelKind};
use crate::scan::ops::{axpby_inplace, fold_row, MASK_FILL};
use crate::scan::pool::ScanPool;
use crate::scan::soa::ScanBuffer;

/// B independent (m, u, w) lanes of shared dim `d` in one flat, reusable
/// time-major SoA allocation.
///
/// ```
/// use aaren::scan::BatchScanBuffer;
///
/// let mut batch = BatchScanBuffer::new(2, 1); // B = 2 lanes, d = 1
/// batch.push_leaf_lane(0, 0.0, &[2.0]); // step 0: lane 0…
/// batch.push_leaf_lane(1, 0.0, &[6.0]); // …then lane 1 (round-robin)
/// batch.push_leaf_lane(0, 0.0, &[4.0]); // step 1
/// batch.push_leaf_lane(1, 0.0, &[0.0]);
/// batch.scan_inplace(); // both lanes prefix-scanned in one walk
/// let mut out = [0.0f32; 2];
/// batch.outputs_into(1, &mut out); // (B, d) outputs at step 1
/// assert_eq!(out, [3.0, 3.0]); // per-lane running means
/// ```
#[derive(Clone, Debug, PartialEq)]
pub struct BatchScanBuffer {
    lanes: usize,
    d: usize,
    /// lanes of the trailing step already filled by `push_leaf_lane`
    /// (0 = no step is partially staged)
    staged: usize,
    m: Vec<f32>,
    u: Vec<f32>,
    w: Vec<f32>,
}

impl BatchScanBuffer {
    /// Empty buffer for `lanes` lanes of value-dimension `d`.
    pub fn new(lanes: usize, d: usize) -> BatchScanBuffer {
        BatchScanBuffer { lanes, d, staged: 0, m: Vec::new(), u: Vec::new(), w: Vec::new() }
    }

    /// Empty buffer with room for `steps` time steps per lane.
    pub fn with_capacity(lanes: usize, d: usize, steps: usize) -> BatchScanBuffer {
        BatchScanBuffer {
            lanes,
            d,
            staged: 0,
            m: Vec::with_capacity(steps * lanes),
            u: Vec::with_capacity(steps * lanes),
            w: Vec::with_capacity(steps * lanes * d),
        }
    }

    /// Number of lanes B.
    pub fn lanes(&self) -> usize {
        self.lanes
    }

    /// Value dimension `d` of each element.
    pub fn dim(&self) -> usize {
        self.d
    }

    /// Time steps held per lane (a partially staged trailing step counts:
    /// its unfilled lanes are identities).
    pub fn steps(&self) -> usize {
        if self.lanes == 0 {
            0
        } else {
            self.m.len() / self.lanes
        }
    }

    pub fn is_empty(&self) -> bool {
        self.m.is_empty()
    }

    /// Re-shape for reuse (executor scratch): keeps the allocations,
    /// drops the contents.
    pub fn reset(&mut self, lanes: usize, d: usize) {
        self.lanes = lanes;
        self.d = d;
        self.staged = 0;
        self.m.clear();
        self.u.clear();
        self.w.clear();
    }

    /// Append one identity row block (every lane gets an ⊕-neutral
    /// element: m = MASK_FILL, u = 0, w = 0).
    pub fn push_identity_row(&mut self) {
        assert_eq!(self.staged, 0, "cannot start a new step mid-way through a staged one");
        self.m.resize(self.m.len() + self.lanes, MASK_FILL);
        self.u.resize(self.u.len() + self.lanes, 0.0);
        self.w.resize(self.w.len() + self.lanes * self.d, 0.0);
    }

    /// Append the leaf (s, 1, v) for `lane` in the current time step.
    /// Lanes must be pushed in round-robin order (0, 1, …, B−1, 0, …);
    /// the first lane of a step appends a fresh identity row block, so a
    /// step left partially pushed is still well-formed (identity lanes).
    pub fn push_leaf_lane(&mut self, lane: usize, s: f32, v: &[f32]) {
        assert!(self.lanes > 0, "push_leaf_lane on a zero-lane buffer");
        assert_eq!(lane, self.staged, "lanes must be pushed in order 0..B per step");
        debug_assert_eq!(v.len(), self.d);
        if self.staged == 0 {
            self.push_identity_row();
        }
        let i = (self.steps() - 1) * self.lanes + lane;
        self.m[i] = s;
        self.u[i] = 1.0;
        self.w[i * self.d..(i + 1) * self.d].copy_from_slice(v);
        self.staged = (self.staged + 1) % self.lanes;
    }

    /// Borrow element (t, lane) as (m, u, w-row).
    pub fn row(&self, t: usize, lane: usize) -> (f32, f32, &[f32]) {
        let i = t * self.lanes + lane;
        (self.m[i], self.u[i], &self.w[i * self.d..(i + 1) * self.d])
    }

    /// Overwrite element (t, lane) — the state-gather path of the serve
    /// executor (sessions load their accumulators into lanes).
    pub fn set_row(&mut self, t: usize, lane: usize, m: f32, u: f32, w: &[f32]) {
        debug_assert_eq!(w.len(), self.d);
        let i = t * self.lanes + lane;
        self.m[i] = m;
        self.u[i] = u;
        self.w[i * self.d..(i + 1) * self.d].copy_from_slice(w);
    }

    /// Fold one leaf (scores[b], 1, tokens[b·d..(b+1)·d]) into the LAST
    /// row of every lane, in place — the batched §3.1 RNN cell update: B
    /// streams advance one token in a single linear pass over the flat
    /// row block. Per lane this is exactly `ops::fold_token`.
    pub fn fold_all(&mut self, scores: &[f32], tokens: &[f32]) {
        let (lanes, d) = (self.lanes, self.d);
        assert_eq!(scores.len(), lanes, "one score per lane");
        assert_eq!(tokens.len(), lanes * d, "one d-dim token per lane");
        for b in 0..lanes {
            self.fold_lane(b, scores[b], &tokens[b * d..(b + 1) * d]);
        }
    }

    /// [`fold_all`](Self::fold_all) for a single lane — the straggler
    /// path when lanes carry different numbers of pending tokens.
    pub fn fold_lane(&mut self, lane: usize, s: f32, x: &[f32]) {
        let d = self.d;
        debug_assert_eq!(x.len(), d);
        assert!(self.staged == 0 && self.steps() > 0, "fold_lane needs a committed row block");
        let i = (self.steps() - 1) * self.lanes + lane;
        let mm = self.m[i].max(s);
        let ea = (self.m[i] - mm).exp();
        let eb = (s - mm).exp();
        self.m[i] = mm;
        self.u[i] = self.u[i] * ea + eb;
        axpby_inplace(eb, x, ea, &mut self.w[i * d..(i + 1) * d]);
    }

    /// The attention output element (t, lane) represents: o = w / u, with
    /// the u == 0 identity / fully-masked case yielding zeros (not NaN).
    pub fn lane_output_into(&self, t: usize, lane: usize, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.d);
        let (_, u, w) = self.row(t, lane);
        if u == 0.0 {
            out.fill(0.0);
            return;
        }
        for (o, x) in out.iter_mut().zip(w.iter()) {
            *o = x / u;
        }
    }

    /// All lane outputs at time step `t` as one contiguous (B, d) block —
    /// what the coalesced serve executor writes straight into replies.
    pub fn outputs_into(&self, t: usize, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.lanes * self.d);
        for (b, row) in out.chunks_exact_mut(self.d.max(1)).enumerate() {
            self.lane_output_into(t, b, row);
        }
    }

    /// Append one lane (initialised to the identity in any committed row
    /// block) and return its index — the growth path of the resident-lane
    /// executor ([`LaneSet`]). Only meaningful while the buffer holds at
    /// most ONE time step: with a single row block the time-major layout
    /// degenerates to lane-major, so growth is a push instead of a
    /// restride.
    pub fn grow_lane(&mut self) -> usize {
        assert_eq!(self.staged, 0, "cannot grow lanes mid-way through a staged step");
        let had_row = self.steps() == 1;
        assert!(self.steps() <= 1, "lane growth needs at most one committed row block");
        let lane = self.lanes;
        self.lanes += 1;
        if had_row {
            self.m.push(MASK_FILL);
            self.u.push(0.0);
            self.w.resize(self.w.len() + self.d, 0.0);
        }
        lane
    }

    /// Overwrite lane `dst` of the single row block with lane `src` — the
    /// move primitive of [`LaneSet::compact`].
    pub fn copy_lane(&mut self, src: usize, dst: usize) {
        assert_eq!(self.steps(), 1, "lane copies operate on the single-row-block form");
        if src == dst {
            return;
        }
        self.m[dst] = self.m[src];
        self.u[dst] = self.u[src];
        let d = self.d;
        let (lo, hi) = (src.min(dst), src.max(dst));
        let (left, right) = self.w.split_at_mut(hi * d);
        let (a, b) = (&mut left[lo * d..(lo + 1) * d], &mut right[..d]);
        if src < dst {
            b.copy_from_slice(a);
        } else {
            a.copy_from_slice(b);
        }
    }

    /// Reset lane `lane` of the single row block to the ⊕ identity
    /// (m = MASK_FILL, u = 0, w = 0) — a released lane must read as
    /// neutral until it is reused.
    pub fn clear_lane(&mut self, lane: usize) {
        assert_eq!(self.steps(), 1, "lane clears operate on the single-row-block form");
        self.m[lane] = MASK_FILL;
        self.u[lane] = 0.0;
        self.w[lane * self.d..(lane + 1) * self.d].fill(0.0);
    }

    /// Shrink to the first `n` lanes — the tail-trim of
    /// [`LaneSet::compact`]. Only valid while at most one row block is
    /// committed.
    pub fn truncate_lanes(&mut self, n: usize) {
        assert_eq!(self.staged, 0, "cannot truncate lanes mid-way through a staged step");
        assert!(self.steps() <= 1, "lane truncation needs at most one committed row block");
        assert!(n <= self.lanes, "cannot truncate {} lanes to {n}", self.lanes);
        if self.steps() == 1 {
            self.m.truncate(n);
            self.u.truncate(n);
            self.w.truncate(n * self.d);
        }
        self.lanes = n;
    }

    /// Copy lane `lane` out as a single-sequence [`ScanBuffer`]
    /// (tests / interop with the single-lane strategies).
    pub fn lane_buffer(&self, lane: usize) -> ScanBuffer {
        let mut buf = ScanBuffer::with_capacity(self.d, self.steps());
        for t in 0..self.steps() {
            let (m, u, w) = self.row(t, lane);
            buf.push_tuple(m, u, w);
        }
        buf
    }

    /// Sequential inclusive prefix scan of every lane at once, in place:
    /// row-block t := row-block t−1 ⊕ row-block t, per-lane coefficients.
    /// One linear walk; per lane bitwise equal to
    /// `ops::scan_rows_inplace` on that lane alone.
    pub fn scan_inplace(&mut self) {
        scan_block(&mut self.m, &mut self.u, &mut self.w, self.lanes, self.d);
    }

    /// Multi-threaded chunked inclusive scan of every lane: the time axis
    /// is split into `num_chunks` contiguous chunks scanned independently
    /// on the shared [`ScanPool`], the per-chunk carry row-blocks are
    /// scanned serially, then each carry is broadcast into the next
    /// chunk — the same three phases (and, per lane, the same chunk
    /// boundaries, hence bitwise the same result) as
    /// `scan::chunked_parallel` with the same `num_chunks`.
    pub fn scan_chunked(&mut self, num_chunks: usize) {
        let steps = self.steps();
        assert_eq!(self.staged, 0, "cannot scan a partially staged step");
        if steps == 0 {
            return;
        }
        let chunk = steps.div_ceil(num_chunks.clamp(1, steps));
        let nchunks = steps.div_ceil(chunk);
        if nchunks == 1 {
            self.scan_inplace();
            return;
        }
        let (lanes, d) = (self.lanes, self.d);
        let pool = ScanPool::global();

        // phase 1: independent scan of each time chunk (all lanes), on
        // disjoint &mut windows of the one allocation
        pool.scope(
            block_views(&mut self.m, &mut self.u, &mut self.w, lanes, d, chunk, 0)
                .into_iter()
                .map(|(ms, us, ws)| {
                    Box::new(move || scan_block(ms, us, ws, lanes, d))
                        as Box<dyn FnOnce() + Send + '_>
                })
                .collect(),
        );

        // phase 2: scan the chunk-final carry row blocks (nchunks blocks
        // — serial, tiny)
        let mut carries = BatchScanBuffer::with_capacity(lanes, d, nchunks);
        for kc in 0..nchunks {
            let last = ((kc + 1) * chunk).min(steps) - 1;
            carries.push_identity_row();
            for b in 0..lanes {
                let (m, u, w) = self.row(last, b);
                carries.set_row(kc, b, m, u, w);
            }
        }
        carries.scan_inplace();

        // phase 3: broadcast carry block kc−1 into every row of chunk kc
        let carries = &carries;
        pool.scope(
            block_views(&mut self.m, &mut self.u, &mut self.w, lanes, d, chunk, 1)
                .into_iter()
                .enumerate()
                .map(|(kc, (ms, us, ws))| {
                    Box::new(move || {
                        let rows = if lanes == 0 { 0 } else { ms.len() / lanes };
                        for t in 0..rows {
                            for b in 0..lanes {
                                let (cm, cu, cw) = carries.row(kc, b);
                                let i = t * lanes + b;
                                fold_row(
                                    cm,
                                    cu,
                                    cw,
                                    &mut ms[i],
                                    &mut us[i],
                                    &mut ws[i * d..(i + 1) * d],
                                );
                            }
                        }
                    }) as Box<dyn FnOnce() + Send + '_>
                })
                .collect(),
        );
    }
}

/// The batched sequential scan kernel over raw time-major SoA windows:
/// `m`/`u` hold k·lanes elements (k complete row blocks), `w` is
/// (k·lanes, d) flat. Shared by `scan_inplace` and each phase-1 worker of
/// `scan_chunked`.
fn scan_block(m: &mut [f32], u: &mut [f32], w: &mut [f32], lanes: usize, d: usize) {
    let steps = if lanes == 0 { 0 } else { m.len() / lanes };
    debug_assert_eq!(u.len(), m.len());
    debug_assert_eq!(w.len(), m.len() * d);
    let rw = lanes * d;
    for t in 1..steps {
        let (mp, mc) = m[(t - 1) * lanes..(t + 1) * lanes].split_at_mut(lanes);
        let (up, uc) = u[(t - 1) * lanes..(t + 1) * lanes].split_at_mut(lanes);
        let (wp, wc) = w[(t - 1) * rw..(t + 1) * rw].split_at_mut(rw);
        for b in 0..lanes {
            let mm = mp[b].max(mc[b]);
            let ea = (mp[b] - mm).exp();
            let eb = (mc[b] - mm).exp();
            mc[b] = mm;
            uc[b] = up[b] * ea + uc[b] * eb;
            axpby_inplace(ea, &wp[b * d..(b + 1) * d], eb, &mut wc[b * d..(b + 1) * d]);
        }
    }
}

/// Split time-major SoA buffers into per-chunk disjoint
/// (&mut m, &mut u, &mut w) windows of `chunk` row blocks, skipping the
/// first `skip` chunks — the batch analogue of `scan::chunk_views`.
#[allow(clippy::type_complexity)]
fn block_views<'a>(
    m: &'a mut [f32],
    u: &'a mut [f32],
    w: &'a mut [f32],
    lanes: usize,
    d: usize,
    chunk: usize,
    skip: usize,
) -> Vec<(&'a mut [f32], &'a mut [f32], &'a mut [f32])> {
    let start = (chunk * skip * lanes).min(m.len());
    let mut ms = &mut m[start..];
    let mut us = &mut u[start..];
    let mut ws = &mut w[start * d..];
    let mut views = Vec::new();
    while !ms.is_empty() {
        let take = (chunk * lanes).min(ms.len());
        let (mh, mt) = ms.split_at_mut(take);
        let (uh, ut) = us.split_at_mut(take);
        let (wh, wt) = ws.split_at_mut(take * d);
        ms = mt;
        us = ut;
        ws = wt;
        views.push((mh, uh, wh));
    }
    views
}

/// Long-lived lane allocator over flat [`FoldKernel`] state rows — the
/// storage an executor shard keeps its **resident** native sessions in
/// (see `crate::serve`). Each live session owns one lane holding its
/// kernel state row; `steps` work folds tokens into the lane **in
/// place**, so a drain never gathers or scatters session state. A set is
/// homogeneous: one kernel, one channel width (the executor keys its
/// sets by `(KernelKind, width)`).
///
/// Lifecycle: [`alloc`](LaneSet::alloc) hands out a stable lane id
/// (reusing released lanes LIFO before growing the buffer),
/// [`release`](LaneSet::release) clears a lane back to the ⊕ identity
/// and recycles it, and [`compact`](LaneSet::compact) moves the highest
/// live lanes into released holes and trims the tail — returning the
/// (old, new) moves so the owner can re-point its sessions.
///
/// ```
/// use aaren::scan::LaneSet;
///
/// let mut lanes = LaneSet::new(2); // Aaren lanes, d = 2
/// let a = lanes.alloc();
/// let b = lanes.alloc();
/// lanes.fold(a, 0.0, &[1.0, 3.0]); // lane a folds a token…
/// let mut out = [0.0f32; 2];
/// lanes.output_into(a, &mut out);
/// assert_eq!(out, [1.0, 3.0]);
/// lanes.output_into(b, &mut out); // …lane b is untouched (identity)
/// assert_eq!(out, [0.0, 0.0]);
/// lanes.release(a);
/// assert_eq!(lanes.alloc(), a, "released lanes are reused");
/// ```
#[derive(Debug)]
pub struct LaneSet {
    kind: KernelKind,
    /// channel width d of every lane's stream
    d: usize,
    /// f32s per state row (`kind.state_width(d)`)
    width: usize,
    /// total lanes allocated (live + released)
    lanes: usize,
    /// `lanes` state rows of `width` f32s, lane-major
    rows: Vec<f32>,
    /// released lane indices, reused LIFO by `alloc`
    free: Vec<usize>,
}

impl LaneSet {
    /// Empty set of Aaren lanes for streams of channel width `d`.
    pub fn new(d: usize) -> LaneSet {
        LaneSet::new_kernel(KernelKind::Aaren, d)
    }

    /// Empty set of `kind` lanes for streams of channel width `d`.
    pub fn new_kernel(kind: KernelKind, d: usize) -> LaneSet {
        LaneSet { kind, d, width: kind.state_width(d), lanes: 0, rows: Vec::new(), free: Vec::new() }
    }

    fn k(&self) -> &'static dyn FoldKernel {
        self.kind.kernel()
    }

    /// The kernel every lane of this set runs.
    pub fn kind(&self) -> KernelKind {
        self.kind
    }

    /// Channel width `d` of every lane's stream.
    pub fn dim(&self) -> usize {
        self.d
    }

    /// f32s per lane state row.
    pub fn width(&self) -> usize {
        self.width
    }

    /// Total lanes currently allocated in the buffer (live + released).
    pub fn lanes(&self) -> usize {
        self.lanes
    }

    /// Lanes currently owned by a session.
    pub fn live(&self) -> usize {
        self.lanes - self.free.len()
    }

    /// Released-but-not-yet-compacted lanes.
    pub fn frag(&self) -> usize {
        self.free.len()
    }

    /// Re-dimension an EMPTY set (no live lanes) for a different `d`,
    /// keeping the allocation and kernel.
    pub fn reset_dim(&mut self, d: usize) {
        assert_eq!(self.live(), 0, "cannot re-dimension a set with live lanes");
        self.d = d;
        self.width = self.kind.state_width(d);
        self.lanes = 0;
        self.rows.clear();
        self.free.clear();
    }

    fn clear_lane(&mut self, lane: usize) {
        let (d, w) = (self.d, self.width);
        self.k().identity_into(d, &mut self.rows[lane * w..(lane + 1) * w]);
    }

    /// Claim a lane, initialised to the ⊕ identity: a released lane if
    /// one is free (LIFO), a freshly grown one otherwise. The returned id
    /// is stable until `release` or a `compact` move.
    pub fn alloc(&mut self) -> usize {
        if let Some(lane) = self.free.pop() {
            return lane; // cleared back to the identity on release
        }
        let lane = self.lanes;
        self.lanes += 1;
        self.rows.resize(self.lanes * self.width, 0.0);
        self.clear_lane(lane);
        lane
    }

    /// Return `lane` to the pool: its state is cleared to the identity
    /// and the id becomes reusable. Trailing released lanes are trimmed
    /// immediately (no remap needed); interior holes wait for `compact`.
    pub fn release(&mut self, lane: usize) {
        debug_assert!(!self.free.contains(&lane), "double release of lane {lane}");
        self.clear_lane(lane);
        if lane + 1 == self.lanes {
            // cheap tail trim: drop the released lane and any released
            // run directly below it
            let mut top = lane;
            loop {
                self.lanes = top;
                self.rows.truncate(top * self.width);
                match self.free.iter().position(|&f| f + 1 == top) {
                    Some(i) => {
                        self.free.swap_remove(i);
                        top -= 1;
                    }
                    None => break,
                }
            }
        } else {
            self.free.push(lane);
        }
    }

    /// Fold the leaf for (score `s`, token `x`) into `lane` in place —
    /// the resident serving hot path. For Aaren lanes this is bitwise
    /// identical to `ops::fold_token` on that lane's accumulator alone;
    /// kernels whose leaves ignore the score take only `x`.
    pub fn fold(&mut self, lane: usize, s: f32, x: &[f32]) {
        let (d, w) = (self.d, self.width);
        self.k().fold_leaf(d, s, x, &mut self.rows[lane * w..(lane + 1) * w]);
    }

    /// Fold one leaf into each of several lanes in a single forward walk
    /// over the row buffer — the vectorized resident-drain round. Entries
    /// are `(lane, score, token)` and MUST be sorted strictly ascending by
    /// lane id (the drain sorts its pending sessions once per drain, so
    /// every round walks the state rows in address order instead of
    /// hopping around the buffer in session-arrival order). Bitwise
    /// identical to calling [`fold`](LaneSet::fold) per entry in any
    /// order: each fold reads and writes only its own lane row.
    pub fn fold_all(&mut self, entries: &[(usize, f32, &[f32])]) {
        let k = self.k();
        let (d, w) = (self.d, self.width);
        // One pass of disjoint `&mut` row borrows out of the flat buffer:
        // repeatedly split the remaining tail at the next entry's lane.
        let mut rest: &mut [f32] = &mut self.rows;
        let mut base = 0usize;
        for &(lane, s, x) in entries {
            assert!(
                lane >= base,
                "fold_all needs strictly ascending lane ids (lane {lane} after {base})"
            );
            let tail = std::mem::take(&mut rest);
            let (row, tail) = tail[(lane - base) * w..].split_at_mut(w);
            k.fold_leaf(d, s, x, row);
            rest = tail;
            base = lane + 1;
        }
    }

    /// The d-channel output `lane`'s state represents (zeros for the
    /// nothing-folded-yet identity, never NaN).
    pub fn output_into(&self, lane: usize, out: &mut [f32]) {
        let w = self.width;
        self.k().output_into(self.d, &self.rows[lane * w..(lane + 1) * w], out);
    }

    /// Borrow `lane`'s full state row — what a resident session's
    /// snapshot serializes, straight from the lane.
    pub fn state(&self, lane: usize) -> &[f32] {
        &self.rows[lane * self.width..(lane + 1) * self.width]
    }

    /// Overwrite `lane`'s state row — the restore path (a snapshot's
    /// state adopted bit-for-bit into a fresh lane).
    pub fn set_state(&mut self, lane: usize, state: &[f32]) {
        assert_eq!(state.len(), self.width, "state row width mismatch");
        self.rows[lane * self.width..(lane + 1) * self.width].copy_from_slice(state);
    }

    /// Borrow an Aaren `lane`'s accumulator as (m, u, w-row) — the
    /// (m, u, w)-shaped view predating kernel-generic lanes.
    pub fn row(&self, lane: usize) -> (f32, f32, &[f32]) {
        assert_eq!(self.kind, KernelKind::Aaren, "row() reads the Aaren (m, u, w) layout");
        let s = self.state(lane);
        (s[0], s[1], &s[2..])
    }

    /// Overwrite an Aaren `lane`'s accumulator from (m, u, w) parts.
    pub fn set_row(&mut self, lane: usize, m: f32, u: f32, w: &[f32]) {
        assert_eq!(self.kind, KernelKind::Aaren, "set_row() writes the Aaren (m, u, w) layout");
        let i = lane * self.width;
        self.rows[i] = m;
        self.rows[i + 1] = u;
        self.rows[i + 2..i + 2 + w.len()].copy_from_slice(w);
    }

    /// Close interior holes: the highest live lanes move down into
    /// released slots, the tail is trimmed to exactly [`live`](Self::live)
    /// lanes, and the performed moves are returned as (old, new) pairs so
    /// the owner can re-point its sessions. States move bit-for-bit; no
    /// accumulator is recomputed.
    pub fn compact(&mut self) -> Vec<(usize, usize)> {
        if self.free.is_empty() {
            return Vec::new();
        }
        let live = self.live();
        let mut holes: Vec<usize> = self.free.iter().copied().filter(|&f| f < live).collect();
        holes.sort_unstable();
        // O(1) membership for the source scan below: a linear `contains`
        // per probed lane would go quadratic after a mass release
        let freed: std::collections::HashSet<usize> = self.free.iter().copied().collect();
        let mut moves = Vec::with_capacity(holes.len());
        let mut src = self.lanes;
        let w = self.width;
        for hole in holes {
            // the highest not-yet-moved live lane fills the lowest hole
            loop {
                src -= 1;
                if !freed.contains(&src) {
                    break;
                }
            }
            self.rows.copy_within(src * w..(src + 1) * w, hole * w);
            moves.push((src, hole));
        }
        self.lanes = live;
        self.rows.truncate(live * w);
        self.free.clear();
        moves
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scan::ops::{fold_token, Muw};
    use crate::scan::{chunked_parallel, sequential_inplace};
    use crate::util::prop;
    use crate::util::rng::Rng;

    /// Random (B, n, d) leaves, materialized both as one batch buffer and
    /// as B independent single-lane buffers with identical rows.
    fn random_batch(
        rng: &mut Rng,
        lanes: usize,
        steps: usize,
        d: usize,
    ) -> (BatchScanBuffer, Vec<ScanBuffer>) {
        let mut batch = BatchScanBuffer::with_capacity(lanes, d, steps);
        let mut singles: Vec<ScanBuffer> =
            (0..lanes).map(|_| ScanBuffer::with_capacity(d, steps)).collect();
        for _ in 0..steps {
            for (b, single) in singles.iter_mut().enumerate() {
                let s = rng.range(-30.0, 30.0) as f32;
                let v: Vec<f32> = (0..d).map(|_| rng.gaussian() as f32).collect();
                batch.push_leaf_lane(b, s, &v);
                single.push_leaf(s, &v);
            }
        }
        (batch, singles)
    }

    fn assert_lane_bitwise(batch: &BatchScanBuffer, lane: usize, single: &ScanBuffer) {
        assert_eq!(batch.steps(), single.len());
        for t in 0..single.len() {
            let (bm, bu, bw) = batch.row(t, lane);
            let (sm, su, sw) = single.row(t);
            assert_eq!(bm.to_bits(), sm.to_bits(), "m lane {lane} t {t}: {bm} vs {sm}");
            assert_eq!(bu.to_bits(), su.to_bits(), "u lane {lane} t {t}: {bu} vs {su}");
            for (i, (x, y)) in bw.iter().zip(sw.iter()).enumerate() {
                assert_eq!(x.to_bits(), y.to_bits(), "w lane {lane} t {t} [{i}]: {x} vs {y}");
            }
        }
    }

    #[test]
    fn push_and_row_roundtrip() {
        let mut buf = BatchScanBuffer::new(2, 2);
        buf.push_leaf_lane(0, 0.5, &[1.0, -2.0]);
        // lane 1 of step 0 left staged: reads as the identity
        assert_eq!(buf.steps(), 1);
        assert_eq!(buf.row(0, 0), (0.5, 1.0, &[1.0, -2.0][..]));
        assert_eq!(buf.row(0, 1), (MASK_FILL, 0.0, &[0.0, 0.0][..]));
        buf.push_leaf_lane(1, 1.5, &[4.0, 6.0]);
        buf.push_leaf_lane(0, -0.5, &[0.0, 9.0]);
        assert_eq!(buf.steps(), 2);
        assert_eq!(buf.row(0, 1), (1.5, 1.0, &[4.0, 6.0][..]));
        assert_eq!(buf.row(1, 0), (-0.5, 1.0, &[0.0, 9.0][..]));
    }

    #[test]
    #[should_panic(expected = "in order")]
    fn out_of_order_push_is_rejected() {
        let mut buf = BatchScanBuffer::new(3, 1);
        buf.push_leaf_lane(1, 0.0, &[0.0]);
    }

    #[test]
    fn batch_sequential_scan_is_bitwise_equal_to_per_lane_scans() {
        // satellite property: random B, d, n — the batch engine must not
        // change numerics, only layout.
        prop::check("batch scan == per-lane scan (bitwise)", 48, |rng| {
            let lanes = 1 + rng.below(6);
            let steps = 1 + rng.below(40);
            let d = 1 + rng.below(7);
            let (mut batch, mut singles) = random_batch(rng, lanes, steps, d);
            batch.scan_inplace();
            for (b, single) in singles.iter_mut().enumerate() {
                sequential_inplace(single);
                assert_lane_bitwise(&batch, b, single);
            }
            Ok(())
        });
    }

    #[test]
    fn batch_chunked_scan_is_bitwise_equal_to_per_lane_chunked_scans() {
        // same chunk count → same per-lane chunk boundaries → the exact
        // same ⊕ sequence per lane, pool-parallel or not.
        prop::check("batch chunked == per-lane chunked (bitwise)", 32, |rng| {
            let lanes = 1 + rng.below(5);
            let steps = 1 + rng.below(120);
            let d = 1 + rng.below(5);
            let chunks = 1 + rng.below(9);
            let (mut batch, singles) = random_batch(rng, lanes, steps, d);
            batch.scan_chunked(chunks);
            for (b, single) in singles.iter().enumerate() {
                let want = chunked_parallel(single, chunks);
                assert_lane_bitwise(&batch, b, &want);
            }
            Ok(())
        });
    }

    #[test]
    fn fold_all_is_bitwise_equal_to_per_lane_fold_token() {
        prop::check("fold_all == fold_token per lane", 48, |rng| {
            let lanes = 1 + rng.below(8);
            let d = 1 + rng.below(9);
            let rounds = 1 + rng.below(12);
            let mut batch = BatchScanBuffer::new(lanes, d);
            batch.push_identity_row();
            let mut accs: Vec<Muw> = (0..lanes).map(|_| Muw::identity(d)).collect();
            for _ in 0..rounds {
                let scores: Vec<f32> = (0..lanes).map(|_| rng.range(-40.0, 40.0) as f32).collect();
                let tokens: Vec<f32> = (0..lanes * d).map(|_| rng.gaussian() as f32).collect();
                batch.fold_all(&scores, &tokens);
                for (b, acc) in accs.iter_mut().enumerate() {
                    fold_token(acc, scores[b], &tokens[b * d..(b + 1) * d]);
                }
            }
            let mut got = vec![0.0f32; lanes * d];
            batch.outputs_into(0, &mut got);
            for (b, acc) in accs.iter().enumerate() {
                let (m, u, w) = batch.row(0, b);
                if m.to_bits() != acc.m.to_bits() || u.to_bits() != acc.u.to_bits() {
                    return Err(format!("lane {b} m/u diverged: ({m},{u}) vs {acc:?}"));
                }
                for (x, y) in w.iter().zip(acc.w.iter()) {
                    if x.to_bits() != y.to_bits() {
                        return Err(format!("lane {b} w diverged: {x} vs {y}"));
                    }
                }
                prop::assert_close(&got[b * d..(b + 1) * d], &acc.output(), 0.0)
                    .map_err(|e| format!("lane {b} output: {e}"))?;
            }
            Ok(())
        });
    }

    #[test]
    fn fold_lane_matches_fold_all_on_that_lane() {
        let d = 4;
        let mut rng = Rng::new(9);
        let mut a = BatchScanBuffer::new(3, d);
        let mut b = BatchScanBuffer::new(3, d);
        a.push_identity_row();
        b.push_identity_row();
        for _ in 0..6 {
            let scores: Vec<f32> = (0..3).map(|_| rng.range(-5.0, 5.0) as f32).collect();
            let tokens: Vec<f32> = (0..3 * d).map(|_| rng.gaussian() as f32).collect();
            a.fold_all(&scores, &tokens);
            for lane in 0..3 {
                b.fold_lane(lane, scores[lane], &tokens[lane * d..(lane + 1) * d]);
            }
        }
        assert_eq!(a, b);
    }

    #[test]
    fn outputs_into_writes_lane_major_blocks() {
        let mut buf = BatchScanBuffer::new(2, 2);
        buf.push_identity_row();
        // lane 0: u=2, w=(4,-8) → (2,-4); lane 1 identity → zeros
        buf.set_row(0, 0, 0.0, 2.0, &[4.0, -8.0]);
        let mut out = vec![f32::NAN; 4];
        buf.outputs_into(0, &mut out);
        assert_eq!(out, vec![2.0, -4.0, 0.0, 0.0]);
    }

    #[test]
    fn lane_buffer_roundtrips_rows() {
        let mut rng = Rng::new(3);
        let (batch, singles) = random_batch(&mut rng, 3, 5, 2);
        for (b, single) in singles.iter().enumerate() {
            assert_eq!(&batch.lane_buffer(b), single);
        }
    }

    #[test]
    fn reset_reuses_the_allocation_across_shapes() {
        let mut rng = Rng::new(4);
        let (mut buf, _) = random_batch(&mut rng, 4, 8, 3);
        buf.scan_inplace();
        buf.reset(2, 5);
        assert_eq!((buf.lanes(), buf.dim(), buf.steps()), (2, 5, 0));
        buf.push_identity_row();
        buf.fold_all(&[1.0, -1.0], &[0.5; 10]);
        let mut out = vec![0.0f32; 10];
        buf.outputs_into(0, &mut out);
        assert!(out.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn single_lane_batch_degenerates_to_scan_buffer() {
        let mut rng = Rng::new(6);
        let (mut batch, mut singles) = random_batch(&mut rng, 1, 33, 4);
        batch.scan_chunked(4);
        let want = chunked_parallel(&singles.remove(0), 4);
        assert_lane_bitwise(&batch, 0, &want);
    }

    #[test]
    fn empty_batch_scans_are_no_ops() {
        let mut buf = BatchScanBuffer::new(3, 2);
        buf.scan_inplace();
        buf.scan_chunked(4);
        assert_eq!(buf.steps(), 0);
    }

    #[test]
    fn grow_copy_truncate_lane_primitives() {
        let mut buf = BatchScanBuffer::new(0, 2);
        assert_eq!(buf.grow_lane(), 0);
        buf.push_identity_row();
        assert_eq!(buf.grow_lane(), 1);
        assert_eq!(buf.grow_lane(), 2);
        assert_eq!((buf.lanes(), buf.steps()), (3, 1));
        buf.set_row(0, 0, 1.5, 2.0, &[4.0, -6.0]);
        // grown lanes read as identities
        assert_eq!(buf.row(0, 1), (MASK_FILL, 0.0, &[0.0, 0.0][..]));
        buf.copy_lane(0, 2);
        assert_eq!(buf.row(0, 2), (1.5, 2.0, &[4.0, -6.0][..]));
        buf.copy_lane(2, 1); // backwards copy
        assert_eq!(buf.row(0, 1), (1.5, 2.0, &[4.0, -6.0][..]));
        buf.clear_lane(0);
        assert_eq!(buf.row(0, 0), (MASK_FILL, 0.0, &[0.0, 0.0][..]));
        buf.truncate_lanes(1);
        assert_eq!((buf.lanes(), buf.steps()), (1, 1));
        assert_eq!(buf.row(0, 0), (MASK_FILL, 0.0, &[0.0, 0.0][..]));
    }

    #[test]
    fn lane_set_allocates_reuses_and_trims() {
        let mut lanes = LaneSet::new(1);
        let (a, b, c) = (lanes.alloc(), lanes.alloc(), lanes.alloc());
        assert_eq!((a, b, c), (0, 1, 2));
        assert_eq!((lanes.live(), lanes.lanes()), (3, 3));
        // interior release: the lane becomes a reusable hole
        lanes.release(b);
        assert_eq!((lanes.live(), lanes.frag()), (2, 1));
        assert_eq!(lanes.alloc(), b, "released interior lanes are reused LIFO");
        // tail release trims the buffer, no hole left behind
        lanes.release(c);
        assert_eq!((lanes.live(), lanes.lanes(), lanes.frag()), (2, 2, 0));
        // releasing the rest trims all the way to empty…
        lanes.release(b);
        lanes.release(a);
        assert_eq!((lanes.live(), lanes.lanes(), lanes.frag()), (0, 0, 0));
        // …and the set keeps working afterwards
        let d = lanes.alloc();
        lanes.fold(d, 0.0, &[2.0]);
        let mut out = [0.0f32];
        lanes.output_into(d, &mut out);
        assert_eq!(out, [2.0]);
    }

    #[test]
    fn lane_set_release_trims_released_runs_below_the_tail() {
        let mut lanes = LaneSet::new(1);
        for _ in 0..4 {
            lanes.alloc();
        }
        lanes.release(2);
        lanes.release(1);
        assert_eq!((lanes.lanes(), lanes.frag()), (4, 2));
        // releasing the tail lane absorbs the released run 1..=2 too
        lanes.release(3);
        assert_eq!((lanes.lanes(), lanes.live(), lanes.frag()), (1, 1, 0));
    }

    #[test]
    fn compact_moves_high_lanes_into_holes_bitwise() {
        let mut lanes = LaneSet::new(2);
        for _ in 0..5 {
            lanes.alloc();
        }
        for lane in 0..5 {
            lanes.set_row(lane, lane as f32, 1.0 + lane as f32, &[2.0 * lane as f32, -1.0]);
        }
        lanes.release(1);
        lanes.release(3);
        let moves = lanes.compact();
        assert_eq!(moves, vec![(4, 1)], "one interior hole is fillable from above");
        assert_eq!((lanes.lanes(), lanes.live(), lanes.frag()), (3, 3, 0));
        assert_eq!(lanes.row(0), (0.0, 1.0, &[0.0, -1.0][..]));
        assert_eq!(lanes.row(1), (4.0, 5.0, &[8.0, -1.0][..]), "lane 4 moved into the hole");
        assert_eq!(lanes.row(2), (2.0, 3.0, &[4.0, -1.0][..]));
        // a full set compacts to nothing
        assert!(lanes.compact().is_empty());
    }

    #[test]
    fn reset_dim_requires_an_empty_set() {
        let mut lanes = LaneSet::new(3);
        let a = lanes.alloc();
        lanes.release(a);
        lanes.reset_dim(5);
        assert_eq!((lanes.dim(), lanes.lanes()), (5, 0));
        let b = lanes.alloc();
        lanes.fold(b, 1.0, &[0.5; 5]);
        let mut out = [0.0f32; 5];
        lanes.output_into(b, &mut out);
        assert_eq!(out, [0.5; 5]);
    }

    /// The satellite property: an arbitrary interleaving of lane
    /// alloc / fold / release / spill-restore / compact must leave every
    /// surviving lane's accumulator BITWISE identical to (a) a fold_token
    /// chain over that stream's tokens and (b) the last row of a fresh
    /// single-lane [`ScanBuffer`] replay of the same leaves.
    #[test]
    fn lane_lifecycle_stays_bitwise_equal_to_single_lane_replay() {
        struct Stream {
            lane: usize,
            history: Vec<(f32, Vec<f32>)>,
        }
        prop::check("lane lifecycle == single-lane replay (bitwise)", 32, |rng| {
            let d = 1 + rng.below(6);
            let mut lanes = LaneSet::new(d);
            let mut streams: Vec<Stream> = Vec::new();
            let ops = 30 + rng.below(60);
            for _ in 0..ops {
                match rng.below(10) {
                    // create (always possible)
                    0 | 1 => streams.push(Stream { lane: lanes.alloc(), history: Vec::new() }),
                    // close a random stream
                    2 if !streams.is_empty() => {
                        let s = streams.swap_remove(rng.below(streams.len()));
                        lanes.release(s.lane);
                    }
                    // spill + restore: state leaves the lane bit-for-bit
                    // and re-enters a freshly allocated one
                    3 if !streams.is_empty() => {
                        let s = &mut streams[rng.below(streams.len())];
                        let (m, u, w) = lanes.row(s.lane);
                        let (m, u, w) = (m, u, w.to_vec());
                        lanes.release(s.lane);
                        s.lane = lanes.alloc();
                        lanes.set_row(s.lane, m, u, &w);
                    }
                    // compact + remap
                    4 => {
                        let moves = lanes.compact();
                        for (old, new) in moves {
                            for s in streams.iter_mut() {
                                if s.lane == old {
                                    s.lane = new;
                                }
                            }
                        }
                    }
                    // fold a token into a random stream
                    _ if !streams.is_empty() => {
                        let s = &mut streams[rng.below(streams.len())];
                        let score = rng.range(-30.0, 30.0) as f32;
                        let v: Vec<f32> = (0..d).map(|_| rng.gaussian() as f32).collect();
                        lanes.fold(s.lane, score, &v);
                        s.history.push((score, v));
                    }
                    _ => {}
                }
            }
            if lanes.live() != streams.len() {
                return Err(format!(
                    "{} live lanes for {} streams",
                    lanes.live(),
                    streams.len()
                ));
            }
            for (si, s) in streams.iter().enumerate() {
                let (gm, gu, gw) = lanes.row(s.lane);
                // oracle (a): the O(1) streaming fold
                let mut acc = Muw::identity(d);
                for (score, v) in &s.history {
                    fold_token(&mut acc, *score, v);
                }
                // oracle (b): a fresh single-lane ScanBuffer replay
                let mut replay = ScanBuffer::with_capacity(d, s.history.len());
                for (score, v) in &s.history {
                    replay.push_leaf(*score, v);
                }
                sequential_inplace(&mut replay);
                let (rm, ru, rw) = if replay.is_empty() {
                    (MASK_FILL, 0.0, vec![0.0; d])
                } else {
                    let (m, u, w) = replay.row(replay.len() - 1);
                    (m, u, w.to_vec())
                };
                for (tag, (wm, wu, ww)) in [
                    ("fold_token", (acc.m, acc.u, acc.w.as_slice())),
                    ("ScanBuffer replay", (rm, ru, rw.as_slice())),
                ] {
                    if gm.to_bits() != wm.to_bits() || gu.to_bits() != wu.to_bits() {
                        return Err(format!("stream {si} vs {tag}: m/u diverged"));
                    }
                    for (i, (x, y)) in gw.iter().zip(ww.iter()).enumerate() {
                        if x.to_bits() != y.to_bits() {
                            return Err(format!("stream {si} vs {tag}: w[{i}] {x} vs {y}"));
                        }
                    }
                }
            }
            Ok(())
        });
    }

    /// Kernel-generic twin of the lifecycle property: for EVERY backend
    /// kernel, an arbitrary interleaving of alloc / fold / release /
    /// spill-restore (via `state`/`set_state`) / compact leaves each
    /// surviving lane bitwise identical to a `fold_leaf` chain over that
    /// stream's tokens.
    #[test]
    fn kernel_lane_lifecycle_stays_bitwise_equal_to_fold_chain() {
        struct Stream {
            lane: usize,
            history: Vec<(f32, Vec<f32>)>,
        }
        for kind in KernelKind::ALL {
            let k = kind.kernel();
            prop::check("kernel lane lifecycle == fold chain (bitwise)", 16, |rng| {
                let d = 1 + rng.below(6);
                let mut lanes = LaneSet::new_kernel(kind, d);
                assert_eq!((lanes.kind(), lanes.width()), (kind, kind.state_width(d)));
                let mut streams: Vec<Stream> = Vec::new();
                for _ in 0..30 + rng.below(60) {
                    match rng.below(10) {
                        0 | 1 => streams.push(Stream { lane: lanes.alloc(), history: Vec::new() }),
                        2 if !streams.is_empty() => {
                            let s = streams.swap_remove(rng.below(streams.len()));
                            lanes.release(s.lane);
                        }
                        3 if !streams.is_empty() => {
                            let s = &mut streams[rng.below(streams.len())];
                            let state = lanes.state(s.lane).to_vec();
                            lanes.release(s.lane);
                            s.lane = lanes.alloc();
                            lanes.set_state(s.lane, &state);
                        }
                        4 => {
                            for (old, new) in lanes.compact() {
                                for s in streams.iter_mut() {
                                    if s.lane == old {
                                        s.lane = new;
                                    }
                                }
                            }
                        }
                        _ if !streams.is_empty() => {
                            let s = &mut streams[rng.below(streams.len())];
                            let score = rng.range(-30.0, 30.0) as f32;
                            let v: Vec<f32> = (0..d).map(|_| rng.gaussian() as f32).collect();
                            lanes.fold(s.lane, score, &v);
                            s.history.push((score, v));
                        }
                        _ => {}
                    }
                }
                for (si, s) in streams.iter().enumerate() {
                    let mut acc = vec![0.0f32; kind.state_width(d)];
                    k.identity_into(d, &mut acc);
                    for (score, v) in &s.history {
                        k.fold_leaf(d, *score, v, &mut acc);
                    }
                    for (i, (x, y)) in lanes.state(s.lane).iter().zip(acc.iter()).enumerate() {
                        if x.to_bits() != y.to_bits() {
                            return Err(format!("{kind:?} stream {si}: state[{i}] {x} vs {y}"));
                        }
                    }
                    let (mut got, mut want) = (vec![0.0f32; d], vec![0.0f32; d]);
                    lanes.output_into(s.lane, &mut got);
                    k.output_into(d, &acc, &mut want);
                    if got.iter().zip(&want).any(|(x, y)| x.to_bits() != y.to_bits()) {
                        return Err(format!("{kind:?} stream {si}: outputs diverged"));
                    }
                }
                Ok(())
            });
        }
    }

    /// The sorted-drain round primitive: one `fold_all` walk over an
    /// ascending subset of lanes (holes included) must be bitwise
    /// identical to per-lane `fold` calls, for every kernel.
    #[test]
    fn lane_set_fold_all_is_bitwise_equal_to_per_lane_folds() {
        prop::check("LaneSet::fold_all == per-lane fold (bitwise)", 32, |rng| {
            let kind = KernelKind::ALL[rng.below(KernelKind::ALL.len())];
            let d = 1 + rng.below(8);
            let n_lanes = 1 + rng.below(10);
            let mut a = LaneSet::new_kernel(kind, d);
            let mut b = LaneSet::new_kernel(kind, d);
            for _ in 0..n_lanes {
                a.alloc();
                b.alloc();
            }
            // pre-warm every lane identically so the round starts from
            // non-identity states
            for lane in 0..n_lanes {
                let s = rng.range(-30.0, 30.0) as f32;
                let x: Vec<f32> = (0..d).map(|_| rng.gaussian() as f32).collect();
                a.fold(lane, s, &x);
                b.fold(lane, s, &x);
            }
            // a random ascending subset gets a leaf this round — the
            // skipped lanes are the "session has no token r" holes
            let chosen: Vec<usize> = (0..n_lanes).filter(|_| rng.uniform() < 0.6).collect();
            let leaves: Vec<(f32, Vec<f32>)> = chosen
                .iter()
                .map(|_| {
                    let s = rng.range(-30.0, 30.0) as f32;
                    let x: Vec<f32> = (0..d).map(|_| rng.gaussian() as f32).collect();
                    (s, x)
                })
                .collect();
            let entries: Vec<(usize, f32, &[f32])> = chosen
                .iter()
                .zip(leaves.iter())
                .map(|(&lane, (s, x))| (lane, *s, x.as_slice()))
                .collect();
            a.fold_all(&entries);
            for &(lane, s, x) in &entries {
                b.fold(lane, s, x);
            }
            for lane in 0..n_lanes {
                for (x, y) in a.state(lane).iter().zip(b.state(lane)) {
                    if x.to_bits() != y.to_bits() {
                        return Err(format!("{kind:?} lane {lane}: state diverged"));
                    }
                }
            }
            Ok(())
        });
    }
}
