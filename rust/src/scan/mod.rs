//! The paper's algorithmic core in pure Rust: the associative operator ⊕
//! over (m, u, w) tuples (Appendix B) and four prefix-scan strategies over
//! the flat SoA [`ScanBuffer`] — sequential (the §3.1 RNN view),
//! Hillis–Steele (Algorithm 1), Blelloch (Ladner–Fischer style) and a
//! multi-threaded chunked scan (the CPU analogue of the paper's claim
//! that any parallel prefix-scan algorithm computes Aaren's outputs, §5).
//!
//! # SoA layout
//!
//! Every strategy operates on [`ScanBuffer`]: three contiguous buffers
//! `m: [f32; n]`, `u: [f32; n]`, `w: [f32; n*d]` (row-major). No strategy
//! allocates per element — sweeps are linear walks over flat memory,
//! Hillis–Steele ping-pongs two preallocated buffers, Blelloch mutates one
//! padded buffer in place, and the chunked scan hands each worker thread a
//! disjoint `&mut` window of a single allocation. The owned [`Muw`] tuple
//! survives only as the O(1)-state view for streaming folds
//! ([`fold_token`]).
//!
//! # Choosing a strategy
//!
//! | strategy            | work       | depth        | when it wins                          |
//! |---------------------|------------|--------------|---------------------------------------|
//! | [`sequential`]      | O(N)       | O(N)         | single core; small N; lowest constant |
//! | [`hillis_steele`]   | O(N log N) | O(log N)     | wide SIMD/SIMT hardware (the paper's Algorithm 1); on CPU its extra work loses to `sequential` |
//! | [`blelloch`]        | O(N)       | O(2 log N)   | work-optimal tree scan; on CPU the strided access pattern still trails `sequential` — kept as the executable spec the accelerator kernels mirror |
//! | [`chunked_parallel`]| O(N)       | O(N/C + C)   | multi-core CPU: near-linear speedup once chunks amortise the pool handoff (a few hundred elements) |
//!
//! The chunked scan is the classic three-phase decomposition:
//!
//! 1. split the sequence into C contiguous chunks and sequentially scan
//!    each chunk on a persistent [`ScanPool`] worker (no sharing — each
//!    worker owns a disjoint window of the output buffer; the pool is
//!    spawned once per process and reused across calls, so no scan pays
//!    a thread-spawn cost);
//! 2. sequentially scan the C chunk-final tuples ("carries") — C is tiny,
//!    so this serial step is negligible;
//! 3. broadcast-combine carry k−1 into every element of chunk k (again one
//!    worker per chunk, reading the shared carry row).
//!
//! Phases 1 and 3 touch each element exactly once ⇒ O(N) total work like
//! `sequential`, but spread over C cores. These pure-Rust scans are the
//! executable specification the AOT Pallas kernels are tested against,
//! and the engine behind the rust-native streaming fallback in
//! `crate::serve`.
//!
//! # Batched lanes
//!
//! [`batch::BatchScanBuffer`] extends the SoA layout to B independent
//! lanes in ONE time-major allocation: `fold_all` advances every lane by
//! one token in a single linear pass (the coalesced-serving hot path) and
//! `scan_inplace`/`scan_chunked` run the inclusive scan of all lanes at
//! once, per lane bitwise equal to the single-lane strategies here. The
//! ⊕ inner loops of every path — single-lane and batch — share the
//! fixed-width, bounds-check-free `axpby` kernels in [`ops`].
//!
//! [`batch::LaneSet`] layers a lane **lifecycle** on top of flat kernel
//! state rows: stable lane ids with a free-list (alloc / release /
//! compact-with-remap), so long-lived streaming sessions can live
//! *inside* one contiguous buffer and fold tokens in place — the storage
//! behind `crate::serve`'s resident-lane executors.
//!
//! # Fold kernels
//!
//! [`kernel::FoldKernel`] generalises the recurrence itself: a kernel is
//! an associative combine over flat f32 state rows plus a per-token leaf
//! and an output projection, and the (m, u, w) operator above is its
//! [`kernel::KernelKind::Aaren`] instance (bitwise — the Aaren kernel
//! delegates to [`ops`]). minGRU, minLSTM (arxiv 2410.01201) and the
//! average attention network (arxiv 1805.00631) ship as further
//! instances; lanes, sessions and the wire protocol are generic over
//! [`kernel::KernelKind`].

pub mod batch;
pub mod kernel;
pub mod ops;
pub mod pool;
pub mod soa;

pub use batch::{BatchScanBuffer, LaneSet};
pub use kernel::{FoldKernel, KernelKind};
pub use ops::{
    combine, combine_into, combine_rows, fold_row, fold_token, scan_rows_inplace, Muw, MASK_FILL,
};
pub use pool::ScanPool;
pub use soa::ScanBuffer;

/// Sequential left-fold inclusive prefix scan — the ground truth. One
/// linear pass, one output allocation, zero per-element allocation.
pub fn sequential(src: &ScanBuffer) -> ScanBuffer {
    let mut out = src.clone();
    sequential_inplace(&mut out);
    out
}

/// Sequential scan in place: row i := row i−1 ⊕ row i. The zero-copy form
/// consumers use when they own the leaf buffer (and the per-chunk kernel
/// of [`chunked_parallel`]).
pub fn sequential_inplace(buf: &mut ScanBuffer) {
    let d = buf.dim();
    scan_rows_inplace(&mut buf.m, &mut buf.u, &mut buf.w, d);
}

/// Hillis–Steele inclusive scan (the paper's Algorithm 1): ceil(log2 N)
/// sweeps, each combining element j with element j − 2^i. O(N log N) work
/// but only log N dependent steps — the variant the paper presents because
/// it maps directly onto wide SIMD/SIMT hardware. Ping-pongs two
/// preallocated SoA buffers; no sweep allocates or clones tuples.
pub fn hillis_steele(src: &ScanBuffer) -> ScanBuffer {
    let n = src.len();
    let d = src.dim();
    let mut z = src.clone();
    let mut z_next = src.clone();
    let mut off = 1usize;
    while off < n {
        // rows < off are already final for this sweep: bulk-copy them
        z_next.m[..off].copy_from_slice(&z.m[..off]);
        z_next.u[..off].copy_from_slice(&z.u[..off]);
        z_next.w[..off * d].copy_from_slice(&z.w[..off * d]);
        for j in off..n {
            let (wa, wb) = (&z.w[(j - off) * d..(j - off + 1) * d], &z.w[j * d..(j + 1) * d]);
            let (mo, rest_u) = (&mut z_next.m[j], &mut z_next.u[j]);
            combine_rows(
                z.m[j - off],
                z.u[j - off],
                wa,
                z.m[j],
                z.u[j],
                wb,
                mo,
                rest_u,
                &mut z_next.w[j * d..(j + 1) * d],
            );
        }
        std::mem::swap(&mut z, &mut z_next);
        off <<= 1;
    }
    z
}

/// Blelloch two-phase (up-sweep / down-sweep) inclusive scan: O(N) work,
/// 2·log2(N) − 2 dependent steps (Ladner & Fischer, 1980). Pads to a
/// power of two with identity elements and mutates a single SoA buffer in
/// place — no per-step clones.
pub fn blelloch(src: &ScanBuffer) -> ScanBuffer {
    let n = src.len();
    if n == 0 {
        return ScanBuffer::new(src.dim());
    }
    let np = n.next_power_of_two();
    let mut tree = src.clone();
    tree.resize(np);

    // up-sweep: tree[j] at stride s accumulates its left sibling
    let mut s = 1usize;
    while s < np {
        let mut j = 2 * s - 1;
        while j < np {
            tree.fold_left_into(j - s, j);
            j += 2 * s;
        }
        s <<= 1;
    }
    // down-sweep for an *inclusive* scan: push prefixes to right children
    let mut s = np / 4;
    while s >= 1 {
        let mut j = 3 * s - 1;
        while j < np {
            tree.fold_left_into(j - s, j);
            j += 2 * s;
        }
        if s == 1 {
            break;
        }
        s >>= 1;
    }
    tree.resize(n);
    tree
}

/// Multi-threaded chunked inclusive scan: split into `num_chunks`
/// contiguous chunks, sequentially scan each on a persistent
/// [`ScanPool`] worker, scan the chunk carries, then broadcast-combine
/// each carry into the next chunk (again on the pool). O(N) work,
/// ~N/C + C depth — near-linear speedup on C cores, without paying a
/// thread spawn per call (the pool is process-wide and lazily spawned).
///
/// Any `num_chunks` is valid: it is clamped to [1, n], and n need not be
/// divisible by it (the final chunk is short).
pub fn chunked_parallel(src: &ScanBuffer, num_chunks: usize) -> ScanBuffer {
    let n = src.len();
    let d = src.dim();
    if n == 0 {
        return ScanBuffer::new(d);
    }
    let chunk = n.div_ceil(num_chunks.clamp(1, n));
    let nchunks = n.div_ceil(chunk);
    let mut out = src.clone();
    if nchunks == 1 {
        sequential_inplace(&mut out);
        return out;
    }
    let pool = ScanPool::global();

    // phase 1: independent sequential scan of each chunk, in place on
    // disjoint &mut windows of the one output allocation
    pool.scope(
        chunk_views(&mut out, chunk, d, 0)
            .into_iter()
            .map(|(ms, us, ws)| {
                Box::new(move || scan_rows_inplace(ms, us, ws, d)) as Box<dyn FnOnce() + Send + '_>
            })
            .collect(),
    );

    // phase 2: scan the chunk-final carries (nchunks elements — serial)
    let mut carries = ScanBuffer::with_capacity(d, nchunks);
    for k in 0..nchunks {
        let last = ((k + 1) * chunk).min(n) - 1;
        let (m, u, w) = out.row(last);
        carries.push_tuple(m, u, w);
    }
    sequential_inplace(&mut carries);

    // phase 3: broadcast carry k−1 into every element of chunk k
    let carries = &carries;
    pool.scope(
        chunk_views(&mut out, chunk, d, 1)
            .into_iter()
            .enumerate()
            .map(|(k, (ms, us, ws))| {
                Box::new(move || {
                    let (cm, cu, cw) = carries.row(k);
                    for i in 0..ms.len() {
                        fold_row(cm, cu, cw, &mut ms[i], &mut us[i], &mut ws[i * d..(i + 1) * d]);
                    }
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect(),
    );
    out
}

/// [`chunked_parallel`] with one chunk per pool worker (one per core).
pub fn chunked_parallel_auto(src: &ScanBuffer) -> ScanBuffer {
    chunked_parallel(src, ScanPool::global().threads())
}

/// Split `buf` into per-chunk disjoint (&mut m, &mut u, &mut w) windows of
/// `chunk` rows, skipping the first `skip` chunks.
#[allow(clippy::type_complexity)]
fn chunk_views<'a>(
    buf: &'a mut ScanBuffer,
    chunk: usize,
    d: usize,
    skip: usize,
) -> Vec<(&'a mut [f32], &'a mut [f32], &'a mut [f32])> {
    let start = (chunk * skip).min(buf.len());
    let mut ms = &mut buf.m[start..];
    let mut us = &mut buf.u[start..];
    let mut ws = &mut buf.w[start * d..];
    let mut views = Vec::new();
    while !ms.is_empty() {
        let take = chunk.min(ms.len());
        let (mh, mt) = ms.split_at_mut(take);
        let (uh, ut) = us.split_at_mut(take);
        let (wh, wt) = ws.split_at_mut(take * d);
        ms = mt;
        us = ut;
        ws = wt;
        views.push((mh, uh, wh));
    }
    views
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn random_buffer(rng: &mut Rng, n: usize, d: usize, mag: f64) -> ScanBuffer {
        let mut buf = ScanBuffer::with_capacity(d, n);
        for _ in 0..n {
            let s = rng.range(-mag, mag) as f32;
            let v: Vec<f32> = (0..d).map(|_| rng.gaussian() as f32).collect();
            buf.push_leaf(s, &v);
        }
        buf
    }

    fn close(a: &ScanBuffer, b: &ScanBuffer, i: usize, atol: f32) -> Result<(), String> {
        // compare normalised outputs (w/u) and the max — that is what
        // attention consumes; u and w individually may differ by a common
        // exp() factor between algorithms (both are valid representations).
        if (a.m[i] - b.m[i]).abs() > atol {
            return Err(format!("m[{i}]: {} vs {}", a.m[i], b.m[i]));
        }
        let d = a.dim();
        let mut oa = vec![0.0f32; d];
        let mut ob = vec![0.0f32; d];
        a.output_into(i, &mut oa);
        b.output_into(i, &mut ob);
        prop::assert_close(&oa, &ob, atol).map_err(|e| format!("row {i}: {e}"))
    }

    fn assert_matches_sequential(
        algo: impl Fn(&ScanBuffer) -> ScanBuffer,
        leaves: &ScanBuffer,
        atol: f32,
    ) -> Result<(), String> {
        let a = sequential(leaves);
        let b = algo(leaves);
        if a.len() != b.len() {
            return Err(format!("length {} vs {}", a.len(), b.len()));
        }
        for i in 0..a.len() {
            close(&a, &b, i, atol)?;
        }
        Ok(())
    }

    #[test]
    fn hillis_steele_matches_sequential() {
        prop::check("hillis_steele == sequential", 64, |rng| {
            let n = 1 + rng.below(200);
            let leaves = random_buffer(rng, n, 4, 5.0);
            assert_matches_sequential(hillis_steele, &leaves, 1e-4)
        });
    }

    #[test]
    fn blelloch_matches_sequential() {
        prop::check("blelloch == sequential", 64, |rng| {
            let n = 1 + rng.below(200);
            let leaves = random_buffer(rng, n, 4, 5.0);
            assert_matches_sequential(blelloch, &leaves, 1e-4)
        });
    }

    #[test]
    fn chunked_parallel_matches_sequential() {
        // satellite property: random n (divisible or not), random chunk
        // counts — including chunks > n and chunks == 1.
        prop::check("chunked_parallel == sequential", 64, |rng| {
            let n = 1 + rng.below(300);
            let chunks = 1 + rng.below(17);
            let leaves = random_buffer(rng, n, 1 + rng.below(6), 5.0);
            assert_matches_sequential(|b| chunked_parallel(b, chunks), &leaves, 1e-4)
                .map_err(|e| format!("n={n} chunks={chunks}: {e}"))
        });
    }

    #[test]
    fn chunked_parallel_with_more_chunks_than_items() {
        prop::check("chunked n < C", 32, |rng| {
            let n = 1 + rng.below(7);
            let chunks = 8 + rng.below(8);
            let leaves = random_buffer(rng, n, 3, 5.0);
            assert_matches_sequential(|b| chunked_parallel(b, chunks), &leaves, 1e-4)
                .map_err(|e| format!("n={n} chunks={chunks}: {e}"))
        });
    }

    #[test]
    fn chunked_parallel_auto_matches_sequential() {
        let mut rng = Rng::new(17);
        let leaves = random_buffer(&mut rng, 257, 5, 5.0);
        assert_matches_sequential(chunked_parallel_auto, &leaves, 1e-4).unwrap();
    }

    #[test]
    fn scans_stable_under_extreme_scores() {
        // the cumulative-max trick: |s| up to 80 would overflow exp in f32
        prop::check("scan stable at |m|<=80", 32, |rng| {
            let n = 1 + rng.below(64);
            let leaves = random_buffer(rng, n, 3, 80.0);
            let algos: [fn(&ScanBuffer) -> ScanBuffer; 3] =
                [hillis_steele, blelloch, |b| chunked_parallel(b, 5)];
            for algo in algos {
                let out = algo(&leaves);
                for i in 0..out.len() {
                    let (m, u, w) = out.row(i);
                    if !m.is_finite() || !u.is_finite() || u <= 0.0 {
                        return Err(format!("non-finite tuple at {i}: m={m} u={u}"));
                    }
                    if w.iter().any(|x| !x.is_finite()) {
                        return Err(format!("non-finite w at {i}"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn chunked_parallel_extreme_scores_match_sequential() {
        prop::check("chunked stable+correct at |m|<=80", 32, |rng| {
            let n = 1 + rng.below(128);
            let chunks = 1 + rng.below(9);
            let leaves = random_buffer(rng, n, 3, 80.0);
            assert_matches_sequential(|b| chunked_parallel(b, chunks), &leaves, 1e-4)
        });
    }

    #[test]
    fn single_element_scan_is_identity() {
        let mut leaves = ScanBuffer::new(2);
        leaves.push_leaf(0.5, &[1.0, -2.0]);
        let algos: [fn(&ScanBuffer) -> ScanBuffer; 4] =
            [sequential, hillis_steele, blelloch, |b| chunked_parallel(b, 4)];
        for algo in algos {
            let out = algo(&leaves);
            assert_eq!(out.len(), 1);
            assert_eq!(out.m[0], 0.5);
        }
    }

    #[test]
    fn empty_scan() {
        let empty = ScanBuffer::new(3);
        assert!(sequential(&empty).is_empty());
        assert!(hillis_steele(&empty).is_empty());
        assert!(blelloch(&empty).is_empty());
        assert!(chunked_parallel(&empty, 4).is_empty());
    }

    #[test]
    fn non_power_of_two_lengths() {
        for n in [3usize, 5, 7, 9, 17, 31, 100] {
            let mut rng = Rng::new(n as u64);
            let leaves = random_buffer(&mut rng, n, 2, 3.0);
            let algos: [fn(&ScanBuffer) -> ScanBuffer; 3] =
                [hillis_steele, blelloch, |b| chunked_parallel(b, 3)];
            for algo in algos {
                assert_matches_sequential(algo, &leaves, 1e-4).unwrap();
            }
        }
    }

    #[test]
    fn soa_agrees_with_aos_streaming_fold() {
        // the SoA scans and the Muw streaming view are the same operator
        prop::check("scan == fold chain", 48, |rng| {
            let (n, d) = (1 + rng.below(64), 4);
            let leaves = random_buffer(rng, n, d, 10.0);
            let scanned = sequential(&leaves);
            let mut acc = Muw::identity(d);
            let mut out = vec![0.0f32; d];
            for i in 0..n {
                let (s, _, v) = leaves.row(i);
                fold_token(&mut acc, s, v);
                scanned.output_into(i, &mut out);
                prop::assert_close(&out, &acc.output(), 1e-4).map_err(|e| format!("row {i}: {e}"))?;
            }
            Ok(())
        });
    }
}
