//! The paper's algorithmic core in pure Rust: the associative operator ⊕
//! over (m, u, w) tuples (Appendix B) and three prefix-scan strategies —
//! sequential (the §3.1 RNN view), Hillis–Steele (Algorithm 1,
//! O(N log N) work / log N depth) and Blelloch (Ladner–Fischer style,
//! O(N) work / 2 log N depth; §5 discusses the trade-off).
//!
//! These are the executable specification the AOT kernels are tested
//! against, and the engine behind the rust-native streaming oracle in
//! `crate::attention`.

pub mod ops;

pub use ops::{combine, combine_into, fold_token, Muw, MASK_FILL};

/// Sequential left-fold prefix scan — the ground truth.
pub fn sequential(leaves: &[Muw]) -> Vec<Muw> {
    let mut out = Vec::with_capacity(leaves.len());
    let mut acc: Option<Muw> = None;
    for leaf in leaves {
        let next = match &acc {
            None => leaf.clone(),
            Some(a) => combine(a, leaf),
        };
        out.push(next.clone());
        acc = Some(next);
    }
    out
}

/// Hillis–Steele inclusive scan (the paper's Algorithm 1): log2(N) sweeps,
/// each combining element j with element j - 2^i. O(N log N) work but only
/// ceil(log2 N) dependent steps — the variant the paper presents because it
/// maps directly onto wide SIMD/SIMT hardware.
pub fn hillis_steele(leaves: &[Muw]) -> Vec<Muw> {
    let n = leaves.len();
    let mut z: Vec<Muw> = leaves.to_vec();
    let mut z_next: Vec<Muw> = z.clone();
    let mut off = 1usize;
    while off < n {
        for j in 0..n {
            if j < off {
                z_next[j] = z[j].clone();
            } else {
                combine_into(&z[j - off], &z[j], &mut z_next[j]);
            }
        }
        std::mem::swap(&mut z, &mut z_next);
        off <<= 1;
    }
    z
}

/// Blelloch two-phase (up-sweep / down-sweep) inclusive scan: O(N) work,
/// 2·log2(N) − 2 dependent steps (Ladner & Fischer, 1980). The paper notes
/// (§5) any prefix-scan algorithm computes Aaren's outputs; we carry both
/// to benchmark the work/depth trade-off (bench `scan_micro`).
pub fn blelloch(leaves: &[Muw]) -> Vec<Muw> {
    let n = leaves.len();
    if n == 0 {
        return Vec::new();
    }
    // pad to a power of two with identity elements
    let np = n.next_power_of_two();
    let dim = leaves[0].w.len();
    let mut tree: Vec<Muw> = leaves.to_vec();
    tree.resize(np, Muw::identity(dim));

    // up-sweep: tree[j] at stride s accumulates its left sibling
    let mut s = 1usize;
    while s < np {
        let mut j = 2 * s - 1;
        while j < np {
            let left = tree[j - s].clone();
            let cur = tree[j].clone();
            combine_into(&left, &cur, &mut tree[j]);
            j += 2 * s;
        }
        s <<= 1;
    }
    // down-sweep for an *inclusive* scan: push prefixes to right children
    let mut s = np / 4;
    while s >= 1 {
        let mut j = 3 * s - 1;
        while j < np {
            let left = tree[j - s].clone();
            let cur = tree[j].clone();
            combine_into(&left, &cur, &mut tree[j]);
            j += 2 * s;
        }
        if s == 1 {
            break;
        }
        s >>= 1;
    }
    tree.truncate(n);
    tree
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn random_leaves(rng: &mut Rng, n: usize, d: usize, mag: f64) -> Vec<Muw> {
        (0..n)
            .map(|_| {
                let m = rng.range(-mag, mag) as f32;
                let w: Vec<f32> = (0..d).map(|_| rng.gaussian() as f32).collect();
                Muw { m, u: 1.0, w }
            })
            .collect()
    }

    fn close(a: &Muw, b: &Muw, atol: f32) -> Result<(), String> {
        // compare normalised outputs (w/u) and the max — that is what
        // attention consumes; u and w individually may differ by a common
        // exp() factor between algorithms (both are valid representations).
        if (a.m - b.m).abs() > atol {
            return Err(format!("m: {} vs {}", a.m, b.m));
        }
        for (i, (x, y)) in a.w.iter().zip(b.w.iter()).enumerate() {
            let (ox, oy) = (x / a.u, y / b.u);
            if (ox - oy).abs() > atol {
                return Err(format!("o[{i}]: {ox} vs {oy}"));
            }
        }
        Ok(())
    }

    #[test]
    fn hillis_steele_matches_sequential() {
        prop::check("hillis_steele == sequential", 64, |rng| {
            let n = 1 + rng.below(200);
            let leaves = random_leaves(rng, n, 4, 5.0);
            let a = sequential(&leaves);
            let b = hillis_steele(&leaves);
            for (x, y) in a.iter().zip(b.iter()) {
                close(x, y, 1e-4)?;
            }
            Ok(())
        });
    }

    #[test]
    fn blelloch_matches_sequential() {
        prop::check("blelloch == sequential", 64, |rng| {
            let n = 1 + rng.below(200);
            let leaves = random_leaves(rng, n, 4, 5.0);
            let a = sequential(&leaves);
            let b = blelloch(&leaves);
            for (x, y) in a.iter().zip(b.iter()) {
                close(x, y, 1e-4)?;
            }
            Ok(())
        });
    }

    #[test]
    fn scans_stable_under_extreme_scores() {
        // the cumulative-max trick: |s| up to 80 would overflow exp in f32
        prop::check("scan stable at |m|<=80", 32, |rng| {
            let n = 1 + rng.below(64);
            let leaves = random_leaves(rng, n, 3, 80.0);
            for algo in [hillis_steele, blelloch] {
                let out = algo(&leaves);
                for t in &out {
                    if !t.m.is_finite() || !t.u.is_finite() || t.u <= 0.0 {
                        return Err(format!("non-finite tuple {t:?}"));
                    }
                    for w in &t.w {
                        if !w.is_finite() {
                            return Err("non-finite w".to_string());
                        }
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn single_element_scan_is_identity() {
        let leaves = vec![Muw { m: 0.5, u: 1.0, w: vec![1.0, -2.0] }];
        for algo in [sequential, hillis_steele, blelloch] {
            let out = algo(&leaves);
            assert_eq!(out.len(), 1);
            assert_eq!(out[0].m, 0.5);
        }
    }

    #[test]
    fn empty_scan() {
        assert!(sequential(&[]).is_empty());
        assert!(hillis_steele(&[]).is_empty());
        assert!(blelloch(&[]).is_empty());
    }

    #[test]
    fn non_power_of_two_lengths() {
        for n in [3usize, 5, 7, 9, 17, 31, 100] {
            let mut rng = Rng::new(n as u64);
            let leaves = random_leaves(&mut rng, n, 2, 3.0);
            let a = sequential(&leaves);
            for algo in [hillis_steele, blelloch] {
                let b = algo(&leaves);
                assert_eq!(a.len(), b.len());
                for (x, y) in a.iter().zip(b.iter()) {
                    close(x, y, 1e-4).unwrap();
                }
            }
        }
    }
}
