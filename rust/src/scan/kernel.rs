//! Kernel-generic fold core: the [`FoldKernel`] abstraction every
//! associative-recurrence backend plugs into.
//!
//! The paper's central observation (§3) is that attention is one instance
//! of a recurrence computable by parallel prefix scan over an associative
//! operator. This module factors that observation into code: a kernel is
//! an associative `combine` over flat f32 state rows, a per-token `leaf`,
//! a state-layout width, and an `output` projection — and the rest of the
//! stack (lanes, sessions, spill codec, wire protocol) is generic over it.
//!
//! Four kernels ship today:
//!
//! | kind      | row layout (width)       | recurrence |
//! |-----------|--------------------------|------------|
//! | `Aaren`   | `[m, u, w[0..d]]` (d+2)  | softmax attention via the log-sum-exp ⊕ of Appendix B ([`crate::scan::ops`]) |
//! | `MinGru`  | `[a[0..d], b[0..d]]` (2d)| minGRU (arxiv 2410.01201): `h = (1−z)⊙h + z⊙x`, `z = σ(x)` |
//! | `MinLstm` | `[a[0..d], b[0..d]]` (2d)| minLSTM (arxiv 2410.01201): `h = f'⊙h + i'⊙x`, normalised σ gates |
//! | `AvgAttn` | `[n, s[0..d]]` (d+1)     | average attention network (arxiv 1805.00631): cumulative mean |
//!
//! minGRU/minLSTM here use fixed identity input weights (gates read the
//! raw token), which keeps the serving stack parameter-free like the
//! Aaren path; both are the *diagonal affine* scan element `(a, b)` with
//! `h = a⊙h_prev + b` and composition `(a₂·a₁, a₂·b₁ + b₂)`. Since every
//! `a ∈ (0,1)`, products only shrink — the recurrence is stable in linear
//! space (the Aaren kernel is the one that needs log-space max-shifting,
//! and it delegates to the shared `ops::axpby` kernels bit-for-bit).
//!
//! The generic scan strategies at the bottom ([`scan_kernel_sequential`]
//! & friends) are the reference/property-test machinery: the hot serving
//! paths run the streaming [`FoldKernel::fold_leaf`] via
//! [`crate::scan::LaneSet`], and the Aaren bulk paths keep the tuned SoA
//! code in [`crate::scan::soa`].

use super::ops::{self, MASK_FILL};

/// Enumeration of the shipped kernels — the hashable identity that keys
/// lane sets, snapshot backend tags and the wire `"backend"` names.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum KernelKind {
    /// Softmax attention as an RNN — the paper's (m, u, w) recurrence.
    Aaren,
    /// minGRU with identity input weights.
    MinGru,
    /// minLSTM with identity input weights.
    MinLstm,
    /// Average attention network: cumulative mean over the stream.
    AvgAttn,
}

impl KernelKind {
    pub const ALL: [KernelKind; 4] =
        [KernelKind::Aaren, KernelKind::MinGru, KernelKind::MinLstm, KernelKind::AvgAttn];

    /// The static kernel instance. Kernels are stateless, so one shared
    /// `&'static` serves every lane set and session.
    pub fn kernel(self) -> &'static dyn FoldKernel {
        match self {
            KernelKind::Aaren => &AarenKernel,
            KernelKind::MinGru => &MinGruKernel,
            KernelKind::MinLstm => &MinLstmKernel,
            KernelKind::AvgAttn => &AvgAttnKernel,
        }
    }

    /// The wire `kind`/`backend` string (matches
    /// `persist::codec::BackendTag::kind()` for snapshot blobs).
    pub fn wire_name(self) -> &'static str {
        match self {
            KernelKind::Aaren => "aaren",
            KernelKind::MinGru => "mingru",
            KernelKind::MinLstm => "minlstm",
            KernelKind::AvgAttn => "avg_attn",
        }
    }

    /// Parse a wire `kind`/`backend` string.
    pub fn from_wire(name: &str) -> Option<KernelKind> {
        KernelKind::ALL.into_iter().find(|k| k.wire_name() == name)
    }

    /// Width of one state row at `d` channels (delegates to the kernel).
    pub fn state_width(self, d: usize) -> usize {
        self.kernel().state_width(d)
    }
}

/// One associative-recurrence backend over flat f32 state rows.
///
/// A row is `state_width(d)` contiguous f32s; `combine_rows` must be
/// associative (up to float rounding) with `identity_into` as its neutral
/// element. `fold_leaf` is the streaming hot path — it MUST compute the
/// exact same float operations (same order) as
/// `combine_rows(acc, leaf_into(s, x))` so resident lanes, boxed sessions
/// and bulk scans all agree bitwise along identical ⊕ orderings.
///
/// `s` is the Aaren attention score for the token; kernels whose leaves
/// depend only on the token itself ignore it.
pub trait FoldKernel: Sync {
    fn kind(&self) -> KernelKind;

    /// f32s per state row at `d` channels.
    fn state_width(&self, d: usize) -> usize;

    /// Write the ⊕-neutral element into `row`.
    fn identity_into(&self, d: usize, row: &mut [f32]);

    /// Write the leaf element for a token with score `s`, value `x`.
    fn leaf_into(&self, d: usize, s: f32, x: &[f32], row: &mut [f32]);

    /// `out = a ⊕ b` (a is the earlier prefix).
    fn combine_rows(&self, d: usize, a: &[f32], b: &[f32], out: &mut [f32]);

    /// In-place right-fold: `b := a ⊕ b` (a is the earlier prefix).
    fn fold_row(&self, d: usize, a: &[f32], b: &mut [f32]);

    /// Streaming update: `acc := acc ⊕ leaf(s, x)` without materializing
    /// the leaf — the O(1) per-token step every session runs.
    fn fold_leaf(&self, d: usize, s: f32, x: &[f32], acc: &mut [f32]);

    /// The d-channel output this prefix represents. An identity prefix
    /// (nothing folded yet) yields zeros, never NaN.
    fn output_into(&self, d: usize, row: &[f32], out: &mut [f32]);
}

// ---------------------------------------------------------------- Aaren

/// The paper's (m, u, w) log-sum-exp recurrence, row `[m, u, w[0..d]]`.
/// Every method delegates to [`crate::scan::ops`] so the generic path is
/// bitwise identical to the legacy Aaren-specific one.
pub struct AarenKernel;

impl FoldKernel for AarenKernel {
    fn kind(&self) -> KernelKind {
        KernelKind::Aaren
    }

    fn state_width(&self, d: usize) -> usize {
        d + 2
    }

    fn identity_into(&self, _d: usize, row: &mut [f32]) {
        row[0] = MASK_FILL;
        row[1] = 0.0;
        row[2..].fill(0.0);
    }

    fn leaf_into(&self, d: usize, s: f32, x: &[f32], row: &mut [f32]) {
        debug_assert_eq!(x.len(), d);
        row[0] = s;
        row[1] = 1.0;
        row[2..].copy_from_slice(x);
    }

    fn combine_rows(&self, _d: usize, a: &[f32], b: &[f32], out: &mut [f32]) {
        let (head, wo) = out.split_at_mut(2);
        let (mo, uo) = head.split_at_mut(1);
        ops::combine_rows(a[0], a[1], &a[2..], b[0], b[1], &b[2..], &mut mo[0], &mut uo[0], wo);
    }

    fn fold_row(&self, _d: usize, a: &[f32], b: &mut [f32]) {
        let (head, wb) = b.split_at_mut(2);
        let (mb, ub) = head.split_at_mut(1);
        ops::fold_row(a[0], a[1], &a[2..], &mut mb[0], &mut ub[0], wb);
    }

    fn fold_leaf(&self, _d: usize, s: f32, x: &[f32], acc: &mut [f32]) {
        // exact float-op order of ops::fold_token / the lane fold
        let (head, w) = acc.split_at_mut(2);
        let m = head[0].max(s);
        let ea = (head[0] - m).exp();
        let eb = (s - m).exp();
        head[0] = m;
        head[1] = head[1] * ea + eb;
        ops::axpby_inplace(eb, x, ea, w);
    }

    fn output_into(&self, _d: usize, row: &[f32], out: &mut [f32]) {
        let u = row[1];
        if u == 0.0 {
            out.fill(0.0);
            return;
        }
        for (o, w) in out.iter_mut().zip(row[2..].iter()) {
            *o = w / u;
        }
    }
}

// ------------------------------------------- diagonal affine (min*) core

/// Shared ⊕ of the minGRU/minLSTM element `(a, b)`: `h = a⊙h_prev + b`
/// per channel, so (earlier) ⊕ (later) = `(a_l·a_e, a_l·b_e + b_l)`.
fn diag_combine(d: usize, a: &[f32], b: &[f32], out: &mut [f32]) {
    let (oa, ob) = out.split_at_mut(d);
    for i in 0..d {
        oa[i] = b[i] * a[i];
        ob[i] = b[i] * a[d + i] + b[d + i];
    }
}

/// In-place `b := a ⊕ b` for the diagonal affine element.
fn diag_fold_row(d: usize, a: &[f32], b: &mut [f32]) {
    for i in 0..d {
        let bl = b[i];
        b[d + i] = bl * a[d + i] + b[d + i];
        b[i] = bl * a[i];
    }
}

/// In-place `acc := acc ⊕ (al, bl)` given the later element's channels.
#[inline(always)]
fn diag_fold_leaf_channel(acc_a: &mut f32, acc_b: &mut f32, al: f32, bl: f32) {
    *acc_a = al * *acc_a;
    *acc_b = al * *acc_b + bl;
}

/// Numerically-stable logistic function.
fn sigmoid(x: f32) -> f32 {
    if x >= 0.0 {
        1.0 / (1.0 + (-x).exp())
    } else {
        let e = x.exp();
        e / (1.0 + e)
    }
}

// --------------------------------------------------------------- minGRU

/// minGRU (arxiv 2410.01201) with identity input weights:
/// `z = σ(x)`, `h = (1−z)⊙h_prev + z⊙x` — leaf `(1−z, z·x)`.
pub struct MinGruKernel;

/// The minGRU leaf gates for one channel: `(a, b) = (1−z, z·x)`.
#[inline(always)]
fn mingru_gates(x: f32) -> (f32, f32) {
    let z = sigmoid(x);
    (1.0 - z, z * x)
}

impl FoldKernel for MinGruKernel {
    fn kind(&self) -> KernelKind {
        KernelKind::MinGru
    }

    fn state_width(&self, d: usize) -> usize {
        2 * d
    }

    fn identity_into(&self, d: usize, row: &mut [f32]) {
        row[..d].fill(1.0);
        row[d..].fill(0.0);
    }

    fn leaf_into(&self, d: usize, _s: f32, x: &[f32], row: &mut [f32]) {
        for i in 0..d {
            let (a, b) = mingru_gates(x[i]);
            row[i] = a;
            row[d + i] = b;
        }
    }

    fn combine_rows(&self, d: usize, a: &[f32], b: &[f32], out: &mut [f32]) {
        diag_combine(d, a, b, out);
    }

    fn fold_row(&self, d: usize, a: &[f32], b: &mut [f32]) {
        diag_fold_row(d, a, b);
    }

    fn fold_leaf(&self, d: usize, _s: f32, x: &[f32], acc: &mut [f32]) {
        let (aa, ab) = acc.split_at_mut(d);
        for i in 0..d {
            let (al, bl) = mingru_gates(x[i]);
            diag_fold_leaf_channel(&mut aa[i], &mut ab[i], al, bl);
        }
    }

    fn output_into(&self, d: usize, row: &[f32], out: &mut [f32]) {
        out.copy_from_slice(&row[d..2 * d]);
    }
}

// -------------------------------------------------------------- minLSTM

/// minLSTM (arxiv 2410.01201) with identity input weights and the
/// paper's normalised gates: `f = σ(x+1)`, `i = σ(x−1)`,
/// `f' = f/(f+i)`, `i' = i/(f+i)`, `h = f'⊙h_prev + i'⊙x` — leaf
/// `(f', i'·x)`. The ±1 biases break the f = i symmetry that would
/// otherwise make this minGRU with a constant gate.
pub struct MinLstmKernel;

/// The minLSTM leaf gates for one channel: `(a, b) = (f', i'·x)`.
#[inline(always)]
fn minlstm_gates(x: f32) -> (f32, f32) {
    let f = sigmoid(x + 1.0);
    let i = sigmoid(x - 1.0);
    let sum = f + i;
    let (fp, ip) = if sum > 0.0 {
        (f / sum, i / sum)
    } else {
        // both gates underflowed (x below ~−104): use the analytic tail
        // limit f/(f+i) → σ(2) instead of 0/0
        let fp = sigmoid(2.0);
        (fp, 1.0 - fp)
    };
    (fp, ip * x)
}

impl FoldKernel for MinLstmKernel {
    fn kind(&self) -> KernelKind {
        KernelKind::MinLstm
    }

    fn state_width(&self, d: usize) -> usize {
        2 * d
    }

    fn identity_into(&self, d: usize, row: &mut [f32]) {
        row[..d].fill(1.0);
        row[d..].fill(0.0);
    }

    fn leaf_into(&self, d: usize, _s: f32, x: &[f32], row: &mut [f32]) {
        for i in 0..d {
            let (a, b) = minlstm_gates(x[i]);
            row[i] = a;
            row[d + i] = b;
        }
    }

    fn combine_rows(&self, d: usize, a: &[f32], b: &[f32], out: &mut [f32]) {
        diag_combine(d, a, b, out);
    }

    fn fold_row(&self, d: usize, a: &[f32], b: &mut [f32]) {
        diag_fold_row(d, a, b);
    }

    fn fold_leaf(&self, d: usize, _s: f32, x: &[f32], acc: &mut [f32]) {
        let (aa, ab) = acc.split_at_mut(d);
        for i in 0..d {
            let (al, bl) = minlstm_gates(x[i]);
            diag_fold_leaf_channel(&mut aa[i], &mut ab[i], al, bl);
        }
    }

    fn output_into(&self, d: usize, row: &[f32], out: &mut [f32]) {
        out.copy_from_slice(&row[d..2 * d]);
    }
}

// -------------------------------------------------------------- avgattn

/// Average attention network (arxiv 1805.00631): the O(1)-state
/// cumulative mean `g_t = (1/t)·Σ x_i`, row `[n, s[0..d]]`, ⊕ is
/// componentwise addition.
pub struct AvgAttnKernel;

impl FoldKernel for AvgAttnKernel {
    fn kind(&self) -> KernelKind {
        KernelKind::AvgAttn
    }

    fn state_width(&self, d: usize) -> usize {
        d + 1
    }

    fn identity_into(&self, _d: usize, row: &mut [f32]) {
        row.fill(0.0);
    }

    fn leaf_into(&self, d: usize, _s: f32, x: &[f32], row: &mut [f32]) {
        debug_assert_eq!(x.len(), d);
        row[0] = 1.0;
        row[1..].copy_from_slice(x);
    }

    fn combine_rows(&self, _d: usize, a: &[f32], b: &[f32], out: &mut [f32]) {
        for ((o, a), b) in out.iter_mut().zip(a.iter()).zip(b.iter()) {
            *o = a + b;
        }
    }

    fn fold_row(&self, _d: usize, a: &[f32], b: &mut [f32]) {
        for (b, a) in b.iter_mut().zip(a.iter()) {
            *b = a + *b;
        }
    }

    fn fold_leaf(&self, _d: usize, _s: f32, x: &[f32], acc: &mut [f32]) {
        acc[0] += 1.0;
        for (s, x) in acc[1..].iter_mut().zip(x.iter()) {
            *s += x;
        }
    }

    fn output_into(&self, _d: usize, row: &[f32], out: &mut [f32]) {
        let n = row[0];
        if n == 0.0 {
            out.fill(0.0);
            return;
        }
        for (o, s) in out.iter_mut().zip(row[1..].iter()) {
            *o = s / n;
        }
    }
}

// -------------------------------------------- generic flat-row scan ops

/// Inclusive sequential scan over flat kernel rows, in place:
/// `row[i] := row[i−1] ⊕ row[i]`. For the Aaren kernel this performs the
/// exact float ops of [`ops::scan_rows_inplace`], so results are bitwise
/// identical to the tuned SoA path along the same ⊕ ordering.
pub fn scan_kernel_sequential(k: &dyn FoldKernel, d: usize, rows: &mut [f32]) {
    let w = k.state_width(d);
    if w == 0 {
        return;
    }
    debug_assert_eq!(rows.len() % w, 0);
    let n = rows.len() / w;
    for i in 1..n {
        let (prev, cur) = rows[(i - 1) * w..(i + 1) * w].split_at_mut(w);
        k.fold_row(d, prev, cur);
    }
}

/// Hillis–Steele (offset-doubling) inclusive scan, double-buffered.
/// Tree scans reassociate ⊕, so results match the sequential scan only
/// up to float rounding — never bitwise (see the strategy tests).
pub fn scan_kernel_hillis_steele(k: &dyn FoldKernel, d: usize, rows: &mut [f32]) {
    let w = k.state_width(d);
    if w == 0 {
        return;
    }
    let n = rows.len() / w;
    if n <= 1 {
        return;
    }
    let mut cur = rows.to_vec();
    let mut next = vec![0.0f32; rows.len()];
    let mut off = 1;
    while off < n {
        for i in 0..n {
            if i >= off {
                let (lo, hi) = cur.split_at(i * w);
                let a = &lo[(i - off) * w..(i - off + 1) * w];
                k.combine_rows(d, a, &hi[..w], &mut next[i * w..(i + 1) * w]);
            } else {
                next[i * w..(i + 1) * w].copy_from_slice(&cur[i * w..(i + 1) * w]);
            }
        }
        std::mem::swap(&mut cur, &mut next);
        off *= 2;
    }
    rows.copy_from_slice(&cur);
}

/// Blelloch (work-efficient upsweep/downsweep) inclusive scan over a
/// power-of-two-padded copy; the exclusive result is folded back with
/// the original leaves. Same rounding caveat as Hillis–Steele.
pub fn scan_kernel_blelloch(k: &dyn FoldKernel, d: usize, rows: &mut [f32]) {
    let w = k.state_width(d);
    if w == 0 {
        return;
    }
    let n = rows.len() / w;
    if n <= 1 {
        return;
    }
    let p = n.next_power_of_two();
    let mut buf = vec![0.0f32; p * w];
    buf[..n * w].copy_from_slice(rows);
    for i in n..p {
        k.identity_into(d, &mut buf[i * w..(i + 1) * w]);
    }
    let mut gap = 1;
    while gap < p {
        let step = gap * 2;
        let mut i = step - 1;
        while i < p {
            let (lo, hi) = buf.split_at_mut(i * w);
            k.fold_row(d, &lo[(i - gap) * w..(i - gap + 1) * w], &mut hi[..w]);
            i += step;
        }
        gap = step;
    }
    k.identity_into(d, &mut buf[(p - 1) * w..]);
    let mut tmp = vec![0.0f32; w];
    gap = p / 2;
    while gap > 0 {
        let step = gap * 2;
        let mut i = step - 1;
        while i < p {
            // t = left; left = right; right = t ⊕ right
            tmp.copy_from_slice(&buf[(i - gap) * w..(i - gap + 1) * w]);
            let (lo, hi) = buf.split_at_mut(i * w);
            lo[(i - gap) * w..(i - gap + 1) * w].copy_from_slice(&hi[..w]);
            k.fold_row(d, &tmp, &mut hi[..w]);
            i += step;
        }
        gap /= 2;
    }
    // buf[i] is now the exclusive prefix; inclusive = exclusive ⊕ leaf
    for i in 0..n {
        k.fold_row(d, &buf[i * w..(i + 1) * w], &mut rows[i * w..(i + 1) * w]);
    }
}

/// Three-phase chunked scan (per-chunk sequential scans, then a carry
/// fold into every later chunk) — the single-threaded shape of the
/// pool-chunked SoA strategy, generic over kernels.
pub fn scan_kernel_chunked(k: &dyn FoldKernel, d: usize, rows: &mut [f32], chunk: usize) {
    let w = k.state_width(d);
    if w == 0 || chunk == 0 {
        return scan_kernel_sequential(k, d, rows);
    }
    let cw = chunk * w;
    for c in rows.chunks_mut(cw) {
        scan_kernel_sequential(k, d, c);
    }
    let nchunks = rows.len().div_ceil(cw);
    if nchunks <= 1 {
        return;
    }
    let mut carry = rows[cw - w..cw].to_vec();
    for j in 1..nchunks {
        let start = j * cw;
        let end = (start + cw).min(rows.len());
        for r in rows[start..end].chunks_exact_mut(w) {
            k.fold_row(d, &carry, r);
        }
        carry.copy_from_slice(&rows[end - w..end]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scan::ops::{fold_token, Muw};
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn rand_token(rng: &mut Rng, d: usize) -> (f32, Vec<f32>) {
        let s = rng.range(-20.0, 20.0) as f32;
        let x: Vec<f32> = (0..d).map(|_| rng.gaussian() as f32).collect();
        (s, x)
    }

    fn bits(v: &[f32]) -> Vec<u32> {
        v.iter().map(|x| x.to_bits()).collect()
    }

    #[test]
    fn wire_names_round_trip() {
        for kind in KernelKind::ALL {
            assert_eq!(KernelKind::from_wire(kind.wire_name()), Some(kind));
            assert_eq!(kind.kernel().kind(), kind);
        }
        assert_eq!(KernelKind::from_wire("mamba"), None);
    }

    #[test]
    fn state_widths() {
        for d in [1usize, 3, 8] {
            assert_eq!(KernelKind::Aaren.state_width(d), d + 2);
            assert_eq!(KernelKind::MinGru.state_width(d), 2 * d);
            assert_eq!(KernelKind::MinLstm.state_width(d), 2 * d);
            assert_eq!(KernelKind::AvgAttn.state_width(d), d + 1);
        }
    }

    #[test]
    fn aaren_kernel_is_bitwise_the_legacy_ops_path() {
        // the refactor's ground rule: the generic Aaren kernel performs
        // the exact float ops of scan::ops, so existing sessions, lanes
        // and snapshots are bit-for-bit unchanged
        prop::check("kernel fold == fold_token", 64, |rng| {
            let d = 1 + rng.below(12);
            let k = KernelKind::Aaren.kernel();
            let mut row = vec![f32::NAN; k.state_width(d)];
            k.identity_into(d, &mut row);
            let mut acc = Muw::identity(d);
            let mut out = vec![0.0f32; d];
            let mut want = vec![0.0f32; d];
            for _ in 0..1 + rng.below(24) {
                let (s, x) = rand_token(rng, d);
                k.fold_leaf(d, s, &x, &mut row);
                fold_token(&mut acc, s, &x);
                if row[0].to_bits() != acc.m.to_bits() || row[1].to_bits() != acc.u.to_bits() {
                    return Err(format!("m/u diverged: {:?} vs ({}, {})", &row[..2], acc.m, acc.u));
                }
                if bits(&row[2..]) != bits(&acc.w) {
                    return Err("w diverged".into());
                }
                k.output_into(d, &row, &mut out);
                acc.output_into(&mut want);
                if bits(&out) != bits(&want) {
                    return Err("output diverged".into());
                }
            }
            // leaf_into / combine_rows against the Muw forms
            let (s, x) = rand_token(rng, d);
            let mut leaf = vec![0.0f32; d + 2];
            k.leaf_into(d, s, &x, &mut leaf);
            let lw = Muw::leaf(s, &x);
            if leaf[0].to_bits() != lw.m.to_bits()
                || leaf[1].to_bits() != lw.u.to_bits()
                || bits(&leaf[2..]) != bits(&lw.w)
            {
                return Err("leaf diverged".into());
            }
            let mut combined = vec![0.0f32; d + 2];
            k.combine_rows(d, &row, &leaf, &mut combined);
            let cw = crate::scan::ops::combine(&acc, &lw);
            if combined[0].to_bits() != cw.m.to_bits() || bits(&combined[2..]) != bits(&cw.w) {
                return Err("combine diverged".into());
            }
            Ok(())
        });
    }

    #[test]
    fn aaren_generic_sequential_scan_matches_soa_scan_bitwise() {
        prop::check("kernel seq scan == scan_rows_inplace", 32, |rng| {
            let (n, d) = (1 + rng.below(40), 1 + rng.below(6));
            let k = KernelKind::Aaren.kernel();
            let w = k.state_width(d);
            let mut rows = vec![0.0f32; n * w];
            let mut m = vec![0.0f32; n];
            let mut u = vec![0.0f32; n];
            let mut wv = vec![0.0f32; n * d];
            for i in 0..n {
                let (s, x) = rand_token(rng, d);
                k.leaf_into(d, s, &x, &mut rows[i * w..(i + 1) * w]);
                m[i] = s;
                u[i] = 1.0;
                wv[i * d..(i + 1) * d].copy_from_slice(&x);
            }
            scan_kernel_sequential(k, d, &mut rows);
            ops::scan_rows_inplace(&mut m, &mut u, &mut wv, d);
            for i in 0..n {
                let row = &rows[i * w..(i + 1) * w];
                if row[0].to_bits() != m[i].to_bits()
                    || row[1].to_bits() != u[i].to_bits()
                    || bits(&row[2..]) != bits(&wv[i * d..(i + 1) * d])
                {
                    return Err(format!("row {i} diverged from the SoA scan"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn mingru_fold_matches_scalar_reference_bitwise() {
        // scalar reference recurrence, computed with the same per-channel
        // expressions: z = σ(x); h = (1−z)·h + z·x
        prop::check("mingru == scalar recurrence", 64, |rng| {
            let d = 1 + rng.below(12);
            let k = KernelKind::MinGru.kernel();
            let mut row = vec![f32::NAN; k.state_width(d)];
            k.identity_into(d, &mut row);
            let mut h = vec![0.0f32; d];
            let mut out = vec![0.0f32; d];
            for _ in 0..1 + rng.below(32) {
                let (s, x) = rand_token(rng, d);
                k.fold_leaf(d, s, &x, &mut row);
                for i in 0..d {
                    let z = sigmoid(x[i]);
                    h[i] = (1.0 - z) * h[i] + z * x[i];
                }
                k.output_into(d, &row, &mut out);
                if bits(&out) != bits(&h) {
                    return Err(format!("h diverged: {out:?} vs {h:?}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn minlstm_fold_matches_scalar_reference_bitwise() {
        // scalar reference: f = σ(x+1), i = σ(x−1), normalised gates,
        // h = f'·h + i'·x
        prop::check("minlstm == scalar recurrence", 64, |rng| {
            let d = 1 + rng.below(12);
            let k = KernelKind::MinLstm.kernel();
            let mut row = vec![f32::NAN; k.state_width(d)];
            k.identity_into(d, &mut row);
            let mut h = vec![0.0f32; d];
            let mut out = vec![0.0f32; d];
            for _ in 0..1 + rng.below(32) {
                let (s, x) = rand_token(rng, d);
                k.fold_leaf(d, s, &x, &mut row);
                for i in 0..d {
                    let f = sigmoid(x[i] + 1.0);
                    let ii = sigmoid(x[i] - 1.0);
                    let sum = f + ii;
                    let (fp, ip) = (f / sum, ii / sum);
                    h[i] = fp * h[i] + ip * x[i];
                }
                k.output_into(d, &row, &mut out);
                if bits(&out) != bits(&h) {
                    return Err(format!("h diverged: {out:?} vs {h:?}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn minlstm_gates_survive_the_deep_negative_tail() {
        // x < −104 underflows both σ gates to 0.0; the kernel must fall
        // back to the analytic tail limit, not emit 0/0 = NaN
        let (a, b) = minlstm_gates(-3.0e38);
        assert!(a.is_finite() && b.is_finite(), "got ({a}, {b})");
        assert!((a - sigmoid(2.0)).abs() < 1e-6);
    }

    #[test]
    fn avg_attn_fold_matches_scalar_reference_bitwise() {
        // scalar reference: running sum and count, output = sum / count
        prop::check("avg_attn == scalar recurrence", 64, |rng| {
            let d = 1 + rng.below(12);
            let k = KernelKind::AvgAttn.kernel();
            let mut row = vec![f32::NAN; k.state_width(d)];
            k.identity_into(d, &mut row);
            let mut sum = vec![0.0f32; d];
            let mut count = 0.0f32;
            let mut out = vec![0.0f32; d];
            for _ in 0..1 + rng.below(32) {
                let (s, x) = rand_token(rng, d);
                k.fold_leaf(d, s, &x, &mut row);
                count += 1.0;
                for i in 0..d {
                    sum[i] += x[i];
                }
                k.output_into(d, &row, &mut out);
                let want: Vec<f32> = sum.iter().map(|s| s / count).collect();
                if bits(&out) != bits(&want) {
                    return Err(format!("mean diverged: {out:?} vs {want:?}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn identity_is_neutral_and_outputs_zeros_for_every_kernel() {
        let mut rng = Rng::new(13);
        for kind in KernelKind::ALL {
            let k = kind.kernel();
            let d = 5;
            let w = k.state_width(d);
            let mut e = vec![f32::NAN; w];
            k.identity_into(d, &mut e);
            let mut out = vec![f32::NAN; d];
            k.output_into(d, &e, &mut out);
            assert_eq!(out, vec![0.0; d], "{kind:?}: identity output must be zeros, not NaN");
            // a non-trivial prefix x: e⊕x == x⊕e == x (value-exact: the
            // neutral element contributes exp-underflow zeros / exact
            // 1·v and v+0 terms)
            let mut x = vec![0.0f32; w];
            k.identity_into(d, &mut x);
            for _ in 0..3 {
                let (s, v) = rand_token(&mut rng, d);
                k.fold_leaf(d, s, &v, &mut x);
            }
            let mut got = vec![0.0f32; w];
            k.combine_rows(d, &e, &x, &mut got);
            assert_eq!(got, x, "{kind:?}: e ⊕ x != x");
            k.combine_rows(d, &x, &e, &mut got);
            assert_eq!(got, x, "{kind:?}: x ⊕ e != x");
        }
    }

    #[test]
    fn fold_leaf_equals_combine_with_leaf_for_every_kernel() {
        prop::check("fold_leaf == combine(acc, leaf)", 64, |rng| {
            for kind in KernelKind::ALL {
                let k = kind.kernel();
                let d = 1 + rng.below(8);
                let w = k.state_width(d);
                let mut acc = vec![0.0f32; w];
                k.identity_into(d, &mut acc);
                for _ in 0..rng.below(6) {
                    let (s, x) = rand_token(rng, d);
                    k.fold_leaf(d, s, &x, &mut acc);
                }
                let (s, x) = rand_token(rng, d);
                let mut leaf = vec![0.0f32; w];
                k.leaf_into(d, s, &x, &mut leaf);
                let mut want = vec![0.0f32; w];
                k.combine_rows(d, &acc, &leaf, &mut want);
                k.fold_leaf(d, s, &x, &mut acc);
                if bits(&acc) != bits(&want) {
                    return Err(format!("{kind:?}: fold_leaf != combine(acc, leaf)"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn scan_strategies_match_sequential_for_every_kernel() {
        // the sequential generic scan is bitwise the fold chain (same ⊕
        // ordering); the tree/chunked strategies REASSOCIATE ⊕, which
        // float arithmetic does not preserve bitwise — they get the same
        // tolerance the SoA strategy tests use
        prop::check("strategies == sequential", 24, |rng| {
            for kind in KernelKind::ALL {
                let k = kind.kernel();
                let d = 1 + rng.below(5);
                let w = k.state_width(d);
                let n = 1 + rng.below(33);
                let mut leaves = vec![0.0f32; n * w];
                let mut tokens = Vec::new();
                for i in 0..n {
                    let (s, x) = rand_token(rng, d);
                    k.leaf_into(d, s, &x, &mut leaves[i * w..(i + 1) * w]);
                    tokens.push((s, x));
                }
                // sequential scan == streaming fold chain, bitwise
                let mut seq = leaves.clone();
                scan_kernel_sequential(k, d, &mut seq);
                let mut acc = vec![0.0f32; w];
                k.identity_into(d, &mut acc);
                let mut out = vec![0.0f32; d];
                let mut want = vec![0.0f32; d];
                for (i, (s, x)) in tokens.iter().enumerate() {
                    k.fold_leaf(d, *s, x, &mut acc);
                    k.output_into(d, &acc, &mut want);
                    k.output_into(d, &seq[i * w..(i + 1) * w], &mut out);
                    if out != want {
                        return Err(format!(
                            "{kind:?}: sequential scan row {i} != fold chain: {out:?} vs {want:?}"
                        ));
                    }
                }
                // tree + chunked strategies: tolerance on outputs
                let mut variants: Vec<(&str, Vec<f32>)> = Vec::new();
                let mut hs = leaves.clone();
                scan_kernel_hillis_steele(k, d, &mut hs);
                variants.push(("hillis_steele", hs));
                let mut bl = leaves.clone();
                scan_kernel_blelloch(k, d, &mut bl);
                variants.push(("blelloch", bl));
                for chunk in [1usize, 3, 8, n] {
                    let mut ch = leaves.clone();
                    scan_kernel_chunked(k, d, &mut ch, chunk);
                    variants.push(("chunked", ch));
                }
                for (name, rows) in &variants {
                    for i in 0..n {
                        k.output_into(d, &seq[i * w..(i + 1) * w], &mut want);
                        k.output_into(d, &rows[i * w..(i + 1) * w], &mut out);
                        prop::assert_close(&out, &want, 1e-4)
                            .map_err(|e| format!("{kind:?}/{name} row {i}: {e}"))?;
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn chunked_scan_with_tail_chunks_is_exact_vs_sequential_outputs() {
        // chunk == 1 degenerates to the sequential ordering exactly; the
        // carry fold then IS the fold chain, so outputs agree bitwise
        let mut rng = Rng::new(29);
        for kind in KernelKind::ALL {
            let k = kind.kernel();
            let (n, d) = (17, 3);
            let w = k.state_width(d);
            let mut leaves = vec![0.0f32; n * w];
            for i in 0..n {
                let (s, x) = rand_token(&mut rng, d);
                k.leaf_into(d, s, &x, &mut leaves[i * w..(i + 1) * w]);
            }
            let mut seq = leaves.clone();
            scan_kernel_sequential(k, d, &mut seq);
            let mut ch = leaves.clone();
            scan_kernel_chunked(k, d, &mut ch, 1);
            assert_eq!(bits(&ch), bits(&seq), "{kind:?}: chunk=1 must match sequential bitwise");
        }
    }
}
