//! Fault containment primitives for the serving stack: the structured
//! error taxonomy every wire reply uses, and the deterministic
//! fault-injection harness the chaos tests drive.
//!
//! # Structured errors
//!
//! [`Kinded`] is the machine-readable classification a serving error
//! carries through `anyhow`: the wire layer renders any reply error as
//! `{"error":{"kind":K,"message":M[,"retry_after_ms":R]}}`, where `K`
//! defaults to `"error"` unless a [`Kinded`] is found in the error chain.
//! The kinds the stack emits:
//!
//! * [`KIND_QUARANTINED`] — the session panicked or poisoned its state
//!   (non-finite outputs) and was isolated; `close` frees the id.
//! * [`KIND_OVERLOADED`] — admission control shed the request (full
//!   executor queue or the `--max-conns` cap); `retry_after_ms` is the
//!   client's backoff hint.
//! * [`KIND_CORRUPT_SNAPSHOT`] — a spilled blob failed its integrity
//!   check; the blob is quarantined on disk, the id tombstoned.
//! * [`KIND_FRAME_TOO_LARGE`] — a request line exceeded
//!   `--max-frame-bytes`; the connection is closed after the reply.
//! * [`KIND_NO_SESSION`] — the id names no live or spilled session.
//!
//! # Fault injection
//!
//! A [`FaultPlan`] is a seeded description of what to break and how
//! often: IO errors and torn (truncated-but-reported-ok) writes at the
//! snapshot store, forced or random panics in the executor step path,
//! and injected delays. [`FaultPlan::site`] derives an independent
//! deterministic [`FaultSite`] per consumer (per shard executor, per
//! shard store), so cross-thread interleaving cannot perturb any site's
//! decision sequence — the harness is replayable by seed.
//! [`FaultingStore`] wraps any [`SnapshotStore`] with the IO fault
//! sites; the executor rolls its step-panic site inside the same
//! `catch_unwind` boundary a real bug would hit. Production servers
//! simply run with no plan: every hook is `Option` and costs nothing
//! when absent.

use std::collections::BTreeSet;
use std::fmt;
use std::sync::Once;
use std::time::Duration;

use anyhow::{bail, Result};

use crate::persist::store::SnapshotStore;
use crate::util::rng::Rng;

/// Error kind: the session was quarantined after a panic or poisoned
/// (non-finite) output.
pub const KIND_QUARANTINED: &str = "quarantined";
/// Error kind: admission control shed the request; retry after the hint.
pub const KIND_OVERLOADED: &str = "overloaded";
/// Error kind: a stored snapshot failed its integrity check.
pub const KIND_CORRUPT_SNAPSHOT: &str = "corrupt_snapshot";
/// Error kind: a request frame exceeded the configured byte limit.
pub const KIND_FRAME_TOO_LARGE: &str = "frame_too_large";
/// Error kind: no session exists under the requested id.
pub const KIND_NO_SESSION: &str = "no_session";
/// The catch-all kind for errors carrying no [`Kinded`] classification.
pub const KIND_ERROR: &str = "error";

/// A classified serving error: the `kind` the wire reply's error object
/// carries, the human-readable message, and (for overload shedding) a
/// Retry-After-style hint in milliseconds.
#[derive(Debug, Clone)]
pub struct Kinded {
    pub kind: &'static str,
    pub message: String,
    pub retry_after_ms: Option<u64>,
}

impl Kinded {
    fn err(kind: &'static str, message: String, retry_after_ms: Option<u64>) -> anyhow::Error {
        anyhow::Error::new(Kinded { kind, message, retry_after_ms })
    }

    pub fn quarantined(message: impl Into<String>) -> anyhow::Error {
        Kinded::err(KIND_QUARANTINED, message.into(), None)
    }

    pub fn overloaded(message: impl Into<String>, retry_after_ms: u64) -> anyhow::Error {
        Kinded::err(KIND_OVERLOADED, message.into(), Some(retry_after_ms))
    }

    pub fn corrupt_snapshot(message: impl Into<String>) -> anyhow::Error {
        Kinded::err(KIND_CORRUPT_SNAPSHOT, message.into(), None)
    }

    pub fn frame_too_large(message: impl Into<String>) -> anyhow::Error {
        Kinded::err(KIND_FRAME_TOO_LARGE, message.into(), None)
    }

    pub fn no_session(id: u64) -> anyhow::Error {
        Kinded::err(KIND_NO_SESSION, format!("no session {id}"), None)
    }

    /// The classification of `err`, if any link of its chain carries one.
    pub fn of(err: &anyhow::Error) -> Option<&Kinded> {
        err.downcast_ref::<Kinded>()
    }

    /// The kind the wire layer reports for `err` ([`KIND_ERROR`] when
    /// unclassified).
    pub fn kind_of(err: &anyhow::Error) -> &'static str {
        Kinded::of(err).map_or(KIND_ERROR, |k| k.kind)
    }
}

impl fmt::Display for Kinded {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.message)
    }
}

impl std::error::Error for Kinded {}

/// Injected panics carry this payload prefix so the process-wide panic
/// hook can stay quiet about them (they are expected test noise) while
/// real panics keep their full report.
pub const INJECTED_PANIC_PREFIX: &str = "injected fault:";

/// Install (once) a panic hook that suppresses the default report for
/// panics whose payload starts with [`INJECTED_PANIC_PREFIX`]. Real
/// panics pass through to the previous hook untouched. Called by
/// [`FaultPlan::site`] whenever the plan can inject panics.
pub fn silence_injected_panics() {
    static HOOK: Once = Once::new();
    HOOK.call_once(|| {
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            let injected = info
                .payload()
                .downcast_ref::<String>()
                .map(|s| s.starts_with(INJECTED_PANIC_PREFIX))
                .or_else(|| {
                    info.payload()
                        .downcast_ref::<&str>()
                        .map(|s| s.starts_with(INJECTED_PANIC_PREFIX))
                })
                .unwrap_or(false);
            if !injected {
                prev(info);
            }
        }));
    });
}

/// A seeded description of the faults to inject: rates in [0, 1] per
/// opportunity, plus a set of session ids whose next step panics
/// unconditionally (the deterministic trigger the isolation tests use).
#[derive(Debug, Clone, Default)]
pub struct FaultPlan {
    pub seed: u64,
    /// probability a store put/get fails with an injected IO error
    pub io_error_rate: f64,
    /// probability a store put writes a truncated blob yet reports Ok —
    /// the lying-disk scenario the corrupt-snapshot machinery must absorb
    pub torn_write_rate: f64,
    /// probability one session's drain work panics mid-step
    pub step_panic_rate: f64,
    /// probability an injected delay fires at a delay point
    pub delay_rate: f64,
    /// duration of one injected delay
    pub delay: Duration,
    /// session ids whose next step panics regardless of rates
    pub panic_step_ids: BTreeSet<u64>,
    /// probability the fleet router drops one heartbeat probe on the
    /// floor (exercises the suspect→dead detector without killing
    /// anything)
    pub heartbeat_drop_rate: f64,
    /// probability the fleet proxy treats a backend connection as
    /// unreachable for one request — the injected form of a killed
    /// member, driving the overloaded-shed + failover path
    pub conn_drop_rate: f64,
}

impl FaultPlan {
    pub fn new(seed: u64) -> FaultPlan {
        FaultPlan { seed, ..FaultPlan::default() }
    }

    pub fn io_errors(mut self, rate: f64) -> FaultPlan {
        self.io_error_rate = rate;
        self
    }

    pub fn torn_writes(mut self, rate: f64) -> FaultPlan {
        self.torn_write_rate = rate;
        self
    }

    pub fn step_panics(mut self, rate: f64) -> FaultPlan {
        self.step_panic_rate = rate;
        self
    }

    pub fn delays(mut self, rate: f64, delay: Duration) -> FaultPlan {
        self.delay_rate = rate;
        self.delay = delay;
        self
    }

    /// Force the next step of session `id` to panic (consumed by the
    /// first roll; rates keep applying afterwards).
    pub fn panic_on_step(mut self, id: u64) -> FaultPlan {
        self.panic_step_ids.insert(id);
        self
    }

    pub fn heartbeat_drops(mut self, rate: f64) -> FaultPlan {
        self.heartbeat_drop_rate = rate;
        self
    }

    pub fn conn_drops(mut self, rate: f64) -> FaultPlan {
        self.conn_drop_rate = rate;
        self
    }

    /// Parse the `--fault-plan` CLI spec: comma-separated `key=value`
    /// pairs from `seed=N`, `io=RATE`, `torn=RATE`, `panic=RATE`,
    /// `delay=RATE`, `delay-ms=N`, `panic-id=N` (repeatable),
    /// `hb-drop=RATE`, `conn-drop=RATE`, e.g.
    /// `seed=7,io=0.05,torn=0.1,delay=0.2,delay-ms=2`.
    pub fn parse(spec: &str) -> Result<FaultPlan> {
        let mut plan = FaultPlan::default();
        for part in spec.split(',').filter(|p| !p.trim().is_empty()) {
            let (key, value) = part
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("fault-plan entry {part:?} is not key=value"))?;
            let rate = || -> Result<f64> {
                let r: f64 = value.parse()?;
                if !(0.0..=1.0).contains(&r) {
                    bail!("fault-plan rate {key}={value} is outside [0, 1]");
                }
                Ok(r)
            };
            match key.trim() {
                "seed" => plan.seed = value.parse()?,
                "io" => plan.io_error_rate = rate()?,
                "torn" => plan.torn_write_rate = rate()?,
                "panic" => plan.step_panic_rate = rate()?,
                "delay" => plan.delay_rate = rate()?,
                "delay-ms" => plan.delay = Duration::from_millis(value.parse()?),
                "panic-id" => {
                    plan.panic_step_ids.insert(value.parse()?);
                }
                "hb-drop" => plan.heartbeat_drop_rate = rate()?,
                "conn-drop" => plan.conn_drop_rate = rate()?,
                other => bail!(
                    "unknown fault-plan key {other:?} \
                     (seed|io|torn|panic|delay|delay-ms|panic-id|hb-drop|conn-drop)"
                ),
            }
        }
        Ok(plan)
    }

    /// Whether any fault can ever fire under this plan.
    pub fn is_active(&self) -> bool {
        self.io_error_rate > 0.0
            || self.torn_write_rate > 0.0
            || self.step_panic_rate > 0.0
            || self.delay_rate > 0.0
            || self.heartbeat_drop_rate > 0.0
            || self.conn_drop_rate > 0.0
            || !self.panic_step_ids.is_empty()
    }

    /// Derive the independent deterministic fault site named `tag`: its
    /// decision stream depends only on `(seed, tag)`, never on what other
    /// sites (threads) rolled — the property that keeps a multi-threaded
    /// chaos run replayable.
    pub fn site(&self, tag: &str) -> FaultSite {
        if self.step_panic_rate > 0.0 || !self.panic_step_ids.is_empty() {
            silence_injected_panics();
        }
        // FNV-1a over the tag, folded into the seed
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for b in tag.bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
        FaultSite { plan: self.clone(), rng: Rng::new(self.seed ^ h) }
    }
}

/// One consumer's view of a [`FaultPlan`]: the plan plus a private
/// deterministic decision stream.
#[derive(Debug, Clone)]
pub struct FaultSite {
    plan: FaultPlan,
    rng: Rng,
}

impl FaultSite {
    fn roll(&mut self, rate: f64) -> bool {
        rate > 0.0 && self.rng.uniform() < rate
    }

    /// Roll the IO-error fault for the store operation named `what`.
    pub fn maybe_io_error(&mut self, what: &str) -> Result<()> {
        if self.roll(self.plan.io_error_rate) {
            bail!("injected IO error during {what}");
        }
        Ok(())
    }

    /// Roll the torn-write fault: `Some(truncated)` means the store
    /// should persist the truncation yet report success.
    pub fn torn_write(&mut self, blob: &[u8]) -> Option<Vec<u8>> {
        if self.roll(self.plan.torn_write_rate) {
            Some(blob[..blob.len() / 2].to_vec())
        } else {
            None
        }
    }

    /// Roll the injected-delay fault (sleeps inline when it fires).
    pub fn maybe_delay(&mut self) {
        if self.roll(self.plan.delay_rate) && !self.plan.delay.is_zero() {
            std::thread::sleep(self.plan.delay);
        }
    }

    /// Roll the dropped-heartbeat fault: `true` means the router should
    /// discard this probe unsent and count it as a miss.
    pub fn maybe_drop_heartbeat(&mut self) -> bool {
        self.roll(self.plan.heartbeat_drop_rate)
    }

    /// Roll the dropped-connection fault: `true` means the proxy should
    /// treat the backend as unreachable for this one request.
    pub fn maybe_drop_conn(&mut self) -> bool {
        self.roll(self.plan.conn_drop_rate)
    }

    /// Roll the step-panic fault for session `id`; a forced id
    /// ([`FaultPlan::panic_on_step`]) fires once, rates fire forever.
    /// Panics (with the [`INJECTED_PANIC_PREFIX`] payload) when the
    /// fault fires — always call inside the isolation boundary.
    pub fn maybe_step_panic(&mut self, id: u64) {
        if self.plan.panic_step_ids.remove(&id) || self.roll(self.plan.step_panic_rate) {
            panic!("{INJECTED_PANIC_PREFIX} step panic for session {id}");
        }
    }
}

/// A [`SnapshotStore`] wrapper that injects the plan's IO faults: puts
/// and gets can fail with injected errors, and a torn put persists a
/// truncated blob while reporting success — surfacing later as the
/// corrupt-snapshot path, exactly like a lying disk.
pub struct FaultingStore {
    inner: Box<dyn SnapshotStore>,
    site: FaultSite,
}

impl FaultingStore {
    pub fn new(inner: Box<dyn SnapshotStore>, site: FaultSite) -> FaultingStore {
        FaultingStore { inner, site }
    }
}

impl SnapshotStore for FaultingStore {
    fn put(&mut self, id: u64, blob: &[u8]) -> Result<()> {
        self.site.maybe_delay();
        self.site.maybe_io_error("spill put")?;
        match self.site.torn_write(blob) {
            Some(torn) => self.inner.put(id, &torn), // lies: Ok on damage
            None => self.inner.put(id, blob),
        }
    }

    fn get(&mut self, id: u64) -> Result<Option<Vec<u8>>> {
        self.site.maybe_delay();
        self.site.maybe_io_error("spill get")?;
        self.inner.get(id)
    }

    fn remove(&mut self, id: u64) -> Result<bool> {
        self.inner.remove(id)
    }

    fn contains(&self, id: u64) -> bool {
        self.inner.contains(id)
    }

    fn len(&self) -> usize {
        self.inner.len()
    }

    fn ids(&self) -> Vec<u64> {
        self.inner.ids()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::persist::MemStore;

    #[test]
    fn kinded_errors_survive_context_chains() {
        use anyhow::Context;
        let e = Kinded::overloaded("queue full", 25).context("dispatching step");
        let k = Kinded::of(&e).expect("kind lost through context");
        assert_eq!(k.kind, KIND_OVERLOADED);
        assert_eq!(k.retry_after_ms, Some(25));
        assert_eq!(Kinded::kind_of(&e), KIND_OVERLOADED);
        // unclassified errors report the catch-all kind
        assert_eq!(Kinded::kind_of(&anyhow::anyhow!("plain")), KIND_ERROR);
        // the message is the display, so wire replies stay readable
        assert!(format!("{e:#}").contains("queue full"));
    }

    #[test]
    fn parse_round_trips_the_cli_spec() {
        let plan =
            FaultPlan::parse("seed=7,io=0.05,torn=0.5,panic=0.01,delay=0.2,delay-ms=2,panic-id=9")
                .unwrap();
        assert_eq!(plan.seed, 7);
        assert_eq!(plan.io_error_rate, 0.05);
        assert_eq!(plan.torn_write_rate, 0.5);
        assert_eq!(plan.step_panic_rate, 0.01);
        assert_eq!(plan.delay_rate, 0.2);
        assert_eq!(plan.delay, Duration::from_millis(2));
        assert!(plan.panic_step_ids.contains(&9));
        assert!(plan.is_active());
        assert!(!FaultPlan::parse("seed=3").unwrap().is_active());
        assert!(FaultPlan::parse("io=2.0").is_err());
        assert!(FaultPlan::parse("bogus=1").is_err());
        assert!(FaultPlan::parse("io").is_err());
        // fleet-side sites ride the same spec
        let fleet = FaultPlan::parse("hb-drop=0.25,conn-drop=0.1").unwrap();
        assert_eq!(fleet.heartbeat_drop_rate, 0.25);
        assert_eq!(fleet.conn_drop_rate, 0.1);
        assert!(fleet.is_active());
        assert!(FaultPlan::parse("hb-drop=1.5").is_err());
    }

    #[test]
    fn fleet_fault_rolls_follow_their_rates() {
        let plan = FaultPlan::new(11).heartbeat_drops(1.0);
        let mut site = plan.site("hb");
        assert!(site.maybe_drop_heartbeat());
        assert!(!site.maybe_drop_conn(), "conn rate is 0 — must never fire");
        let mut quiet = FaultPlan::new(11).site("hb");
        assert!(!quiet.maybe_drop_heartbeat(), "inactive plan drops nothing");
    }

    #[test]
    fn sites_are_deterministic_and_independent() {
        let plan = FaultPlan::new(42).io_errors(0.5);
        let decisions = |tag: &str| -> Vec<bool> {
            let mut site = plan.site(tag);
            (0..64).map(|_| site.maybe_io_error("x").is_err()).collect()
        };
        // same (seed, tag) → same stream, replayed in any order
        assert_eq!(decisions("store-0"), decisions("store-0"));
        // different tags → different streams (the cross-thread
        // independence that keeps multi-threaded chaos runs replayable)
        assert_ne!(decisions("store-0"), decisions("store-1"));
        let both = plan.io_error_rate;
        assert!(both > 0.0, "plan must stay active for this test");
    }

    #[test]
    fn forced_step_panic_fires_once_then_rates_apply() {
        let plan = FaultPlan::new(1).panic_on_step(5);
        let mut site = plan.site("exec");
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            site.maybe_step_panic(5)
        }))
        .unwrap_err();
        let msg = err.downcast_ref::<String>().unwrap();
        assert!(msg.starts_with(INJECTED_PANIC_PREFIX), "got: {msg}");
        // consumed: the same id steps fine afterwards (rate is 0)
        site.maybe_step_panic(5);
        site.maybe_step_panic(6);
    }

    #[test]
    fn faulting_store_tears_writes_but_reports_ok() {
        // torn rate 1: every put persists half the blob and lies about it
        let plan = FaultPlan::new(3).torn_writes(1.0);
        let mut store = FaultingStore::new(Box::new(MemStore::new()), plan.site("store"));
        let blob: Vec<u8> = (0..64).collect();
        store.put(4, &blob).unwrap();
        let stored = store.get(4).unwrap().expect("torn blob still stored");
        assert_eq!(stored, &blob[..32], "torn write must persist the truncated prefix");
        // plain forwarding still behaves like a store
        assert!(store.contains(4));
        assert_eq!(store.len(), 1);
        assert!(store.remove(4).unwrap());
        assert!(store.get(4).unwrap().is_none());
    }

    #[test]
    fn io_error_rate_one_fails_every_op() {
        let plan = FaultPlan::new(8).io_errors(1.0);
        let mut store = FaultingStore::new(Box::new(MemStore::new()), plan.site("store"));
        let err = store.put(1, b"blob").unwrap_err();
        assert!(format!("{err}").contains("injected IO error"), "got: {err}");
        assert!(store.get(1).is_err());
    }
}
