//! Metric accumulation shared by all experiment harnesses: the paper
//! reports MSE/MAE (TSF), NLL/RMSE/Acc (EF), Acc (TSC), D4RL normalised
//! score (RL), plus the Figure-5 memory/time accounting.

/// Streaming mean/variance (Welford) — used for dataset standardisation
/// and for aggregating per-seed results.
#[derive(Clone, Debug, Default)]
pub struct OnlineStats {
    pub n: u64,
    mean: f64,
    m2: f64,
}

impl OnlineStats {
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Sample variance (`m2 / (n − 1)`): these stats aggregate per-seed
    /// results drawn from a larger population, so the population
    /// divisor `n` would bias the spread low.
    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }
}

/// Sum-based metric accumulator for eval loops that stream (sum, count)
/// pairs out of the AOT eval artifacts.
#[derive(Clone, Debug, Default)]
pub struct SumMetric {
    pub sum: f64,
    pub count: f64,
}

impl SumMetric {
    pub fn add(&mut self, sum: f64, count: f64) {
        self.sum += sum;
        self.count += count;
    }

    pub fn mean(&self) -> f64 {
        if self.count > 0.0 {
            self.sum / self.count
        } else {
            f64::NAN
        }
    }

    pub fn rmse(&self) -> f64 {
        self.mean().sqrt()
    }
}

/// D4RL-style normalised score: 100 · (score − random) / (expert − random)
/// (Fu et al., 2020). `random` and `expert` are the per-environment
/// reference returns measured from our scripted policies.
pub fn d4rl_normalised(score: f64, random: f64, expert: f64) -> f64 {
    100.0 * (score - random) / (expert - random).max(1e-9)
}

/// Figure-5 (left) memory accounting, in bytes, for a streaming session at
/// context length `n` — computed analytically from the state layouts.
pub mod memory {
    /// Aaren: (a, c, m) per (layer, head): L·H·(dh + 2) f32 — CONSTANT in n.
    pub fn aaren_state_bytes(layers: usize, heads: usize, d_head: usize) -> usize {
        layers * heads * (d_head + 2) * 4
    }

    /// Transformer KV cache: 2·L·H·n·dh f32 — LINEAR in n.
    pub fn kv_cache_bytes(layers: usize, heads: usize, d_head: usize, n: usize) -> usize {
        2 * layers * heads * n * d_head * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn online_stats_match_closed_form() {
        // m2 = Σ(x − x̄)² = 32 over n = 8 samples: the SAMPLE variance
        // is 32/7 (the population variance would be 32/8 = 4)
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut s = OnlineStats::default();
        for x in xs {
            s.push(x);
        }
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.var() - 32.0 / 7.0).abs() < 1e-12);
        assert!((s.std() - (32.0f64 / 7.0).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn online_stats_degenerate_counts_have_zero_variance() {
        let mut s = OnlineStats::default();
        assert_eq!(s.var(), 0.0);
        s.push(3.0);
        assert_eq!(s.var(), 0.0, "a single sample has no spread estimate");
        assert!((s.mean() - 3.0).abs() < 1e-12);
    }

    #[test]
    fn sum_metric_mean_and_rmse() {
        let mut m = SumMetric::default();
        m.add(8.0, 2.0);
        m.add(10.0, 2.0);
        assert!((m.mean() - 4.5).abs() < 1e-12);
        assert!((m.rmse() - 4.5f64.sqrt()).abs() < 1e-12);
    }

    #[test]
    fn d4rl_score_endpoints() {
        assert!((d4rl_normalised(10.0, 10.0, 110.0) - 0.0).abs() < 1e-9);
        assert!((d4rl_normalised(110.0, 10.0, 110.0) - 100.0).abs() < 1e-9);
        assert!(d4rl_normalised(60.0, 10.0, 110.0) > 0.0);
    }

    #[test]
    fn memory_shapes() {
        // Aaren state independent of n; KV linear in n.
        let a = memory::aaren_state_bytes(2, 4, 16);
        assert_eq!(a, 2 * 4 * 18 * 4);
        assert_eq!(
            memory::kv_cache_bytes(2, 4, 16, 200),
            2 * memory::kv_cache_bytes(2, 4, 16, 100)
        );
    }
}
