//! PJRT execution engine: loads AOT HLO-text artifacts, compiles them on
//! the CPU PJRT client, and executes them with manifest-driven argument
//! marshalling. Adapted from /opt/xla-example/load_hlo (HLO *text* is the
//! interchange format — see aot.py's header for why).

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::runtime::manifest::{Dtype, Manifest};

/// Host-side tensor for inputs (shape + typed data).
#[derive(Clone, Debug)]
pub enum HostTensor {
    F32(Vec<usize>, Vec<f32>),
    I32(Vec<usize>, Vec<i32>),
}

impl HostTensor {
    pub fn scalar_f32(x: f32) -> HostTensor {
        HostTensor::F32(vec![], vec![x])
    }

    pub fn scalar_i32(x: i32) -> HostTensor {
        HostTensor::I32(vec![], vec![x])
    }

    pub fn elements(&self) -> usize {
        match self {
            HostTensor::F32(s, _) | HostTensor::I32(s, _) => s.iter().product(),
        }
    }

    pub fn dtype(&self) -> Dtype {
        match self {
            HostTensor::F32(..) => Dtype::F32,
            HostTensor::I32(..) => Dtype::I32,
        }
    }

    pub fn to_literal(&self) -> Result<xla::Literal> {
        let lit = match self {
            HostTensor::F32(shape, data) => {
                anyhow::ensure!(
                    data.len() == shape.iter().product::<usize>(),
                    "f32 tensor data/shape mismatch"
                );
                let bytes: &[u8] = unsafe {
                    std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
                };
                xla::Literal::create_from_shape_and_untyped_data(
                    xla::ElementType::F32,
                    shape,
                    bytes,
                )?
            }
            HostTensor::I32(shape, data) => {
                anyhow::ensure!(
                    data.len() == shape.iter().product::<usize>(),
                    "i32 tensor data/shape mismatch"
                );
                let bytes: &[u8] = unsafe {
                    std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
                };
                xla::Literal::create_from_shape_and_untyped_data(
                    xla::ElementType::S32,
                    shape,
                    bytes,
                )?
            }
        };
        Ok(lit)
    }
}

/// A compiled artifact: manifest + PJRT executable.
pub struct Module {
    pub manifest: Manifest,
    exe: xla::PjRtLoadedExecutable,
}

impl Module {
    /// Execute with fully-marshalled literals (order must match
    /// `manifest.args`). Returns the decomposed output tuple.
    pub fn execute(&self, args: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        anyhow::ensure!(
            args.len() == self.manifest.args.len(),
            "{}: expected {} args, got {}",
            self.manifest.name,
            self.manifest.args.len(),
            args.len()
        );
        let result = self.exe.execute::<xla::Literal>(args)?;
        let lit = result[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: always a tuple
        Ok(lit.to_tuple()?)
    }

    /// Execute with borrowed literals (avoids cloning cached arguments —
    /// the streaming hot path keeps params/state as literals and passes
    /// references).
    pub fn execute_refs(&self, args: &[&xla::Literal]) -> Result<Vec<xla::Literal>> {
        anyhow::ensure!(
            args.len() == self.manifest.args.len(),
            "{}: expected {} args, got {}",
            self.manifest.name,
            self.manifest.args.len(),
            args.len()
        );
        let result = self.exe.execute::<&xla::Literal>(args)?;
        let lit = result[0][0].to_literal_sync()?;
        Ok(lit.to_tuple()?)
    }

    /// Execute with device-resident buffers (hot path: avoids re-uploading
    /// parameters every call). `args` must follow manifest order.
    pub fn execute_buffers(&self, args: &[&xla::PjRtBuffer]) -> Result<Vec<xla::Literal>> {
        let result = self.exe.execute_b::<&xla::PjRtBuffer>(args)?;
        let lit = result[0][0].to_literal_sync()?;
        Ok(lit.to_tuple()?)
    }

    /// Execute with buffers, returning output *buffers* (kept on device —
    /// for chaining steps without host round-trips).
    pub fn execute_buffers_raw(
        &self,
        args: &[&xla::PjRtBuffer],
    ) -> Result<Vec<Vec<xla::PjRtBuffer>>> {
        Ok(self.exe.execute_b::<&xla::PjRtBuffer>(args)?)
    }
}

/// Read an f32 tensor out of a result literal.
pub fn literal_to_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

pub fn literal_scalar_f32(lit: &xla::Literal) -> Result<f32> {
    Ok(lit.get_first_element::<f32>()?)
}

/// The engine owns the PJRT client and a compile cache.
pub struct Engine {
    pub client: xla::PjRtClient,
    dir: PathBuf,
    cache: HashMap<String, std::rc::Rc<Module>>,
}

impl Engine {
    /// `dir` is the artifacts directory produced by `make artifacts`.
    pub fn new(dir: &Path) -> Result<Engine> {
        if !dir.is_dir() {
            bail!("artifacts dir {dir:?} missing — run `make artifacts` first");
        }
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine { client, dir: dir.to_path_buf(), cache: HashMap::new() })
    }

    /// Load + compile `<name>` (cached after the first call).
    pub fn load(&mut self, name: &str) -> Result<std::rc::Rc<Module>> {
        if let Some(m) = self.cache.get(name) {
            return Ok(m.clone());
        }
        let manifest = Manifest::load(&self.dir, name)?;
        let hlo_text_path = manifest
            .hlo_path
            .to_str()
            .context("non-utf8 artifact path")?
            .to_string();
        let proto = xla::HloModuleProto::from_text_file(&hlo_text_path)
            .with_context(|| format!("parsing HLO text {hlo_text_path}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("XLA compile of {name}"))?;
        let module = std::rc::Rc::new(Module { manifest, exe });
        self.cache.insert(name.to_string(), module.clone());
        Ok(module)
    }

    /// Upload a host tensor to the device (for persistent buffers).
    pub fn upload(&self, t: &HostTensor) -> Result<xla::PjRtBuffer> {
        let lit = t.to_literal()?;
        Ok(self.client.buffer_from_host_literal(None, &lit)?)
    }

    pub fn upload_f32(&self, shape: &[usize], data: &[f32]) -> Result<xla::PjRtBuffer> {
        self.upload(&HostTensor::F32(shape.to_vec(), data.to_vec()))
    }

    pub fn artifacts_dir(&self) -> &Path {
        &self.dir
    }
}
