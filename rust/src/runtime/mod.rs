//! Runtime layer: PJRT client wrapper (`exec`), artifact manifests
//! (`manifest`) and the parameter store (`params`). The rust hot path
//! loads `artifacts/*.hlo.txt` once and then executes compiled modules —
//! python never runs at request time.

pub mod exec;
pub mod manifest;
pub mod params;

pub use exec::{Engine, HostTensor, Module};
pub use manifest::{ArgSpec, Dtype, Manifest, OutSpec, Role};
pub use params::ParamStore;
