//! Parameter store: loads the AOT exporter's `<key>.params.bin` (f32 LE,
//! concatenated in manifest order), owns the live training buffers
//! (params + Adam moments + step), and checkpoints back to the same
//! format so trained weights flow train -> eval -> serve.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::runtime::manifest::{Manifest, Role};

#[derive(Clone, Debug)]
pub struct ParamStore {
    /// one buffer per param-role argument, in manifest order
    pub params: Vec<Vec<f32>>,
    pub opt_m: Vec<Vec<f32>>,
    pub opt_v: Vec<Vec<f32>>,
    pub step: f32,
}

impl ParamStore {
    /// Load initial parameters for `manifest` from its params.bin.
    pub fn load(manifest: &Manifest) -> Result<ParamStore> {
        let bytes = std::fs::read(&manifest.params_bin)
            .with_context(|| format!("reading {:?} (run `make artifacts`?)", manifest.params_bin))?;
        Self::from_bytes(manifest, &bytes)
    }

    /// Load from an explicit checkpoint path (same binary format).
    pub fn load_from(manifest: &Manifest, path: &Path) -> Result<ParamStore> {
        let bytes = std::fs::read(path).with_context(|| format!("reading {path:?}"))?;
        Self::from_bytes(manifest, &bytes)
    }

    fn from_bytes(manifest: &Manifest, bytes: &[u8]) -> Result<ParamStore> {
        let total: usize = manifest.param_elements();
        if bytes.len() != total * 4 {
            bail!(
                "params.bin for {} has {} bytes; manifest expects {} f32s ({} bytes)",
                manifest.name,
                bytes.len(),
                total,
                total * 4
            );
        }
        let mut params = Vec::with_capacity(manifest.n_params());
        let mut off = 0usize;
        for (_, spec) in manifest.args_with_role(Role::Param) {
            let n = spec.elements();
            let mut buf = vec![0.0f32; n];
            for (i, x) in buf.iter_mut().enumerate() {
                let b = &bytes[off + i * 4..off + i * 4 + 4];
                *x = f32::from_le_bytes([b[0], b[1], b[2], b[3]]);
            }
            off += n * 4;
            params.push(buf);
        }
        let opt_m = params.iter().map(|p| vec![0.0f32; p.len()]).collect();
        let opt_v = params.iter().map(|p| vec![0.0f32; p.len()]).collect();
        Ok(ParamStore { params, opt_m, opt_v, step: 0.0 })
    }

    /// Serialize current params (not optimiser state) to the binary format.
    pub fn save(&self, path: &Path) -> Result<()> {
        let mut out = Vec::with_capacity(self.n_elements() * 4);
        for p in &self.params {
            for x in p {
                out.extend_from_slice(&x.to_le_bytes());
            }
        }
        std::fs::write(path, out).with_context(|| format!("writing {path:?}"))
    }

    pub fn n_elements(&self) -> usize {
        self.params.iter().map(Vec::len).sum()
    }

    /// Copy trained parameters into another store (e.g. the eval module's
    /// store — same params_key, same layout).
    pub fn copy_params_from(&mut self, other: &ParamStore) {
        assert_eq!(self.params.len(), other.params.len(), "param layout mismatch");
        for (dst, src) in self.params.iter_mut().zip(other.params.iter()) {
            dst.copy_from_slice(src);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::Manifest;
    use std::io::Write;

    fn toy_manifest(dir: &Path) -> Manifest {
        std::fs::create_dir_all(dir).unwrap();
        let mut f =
            std::fs::File::create(dir.join("toy.manifest.json")).unwrap();
        f.write_all(
            br#"{
              "name": "toy", "kind": "train", "hlo": "toy.hlo.txt",
              "params_key": "toy", "params_bin": "toy.params.bin",
              "args": [
                {"name": "param:a", "role": "param", "shape": [2], "dtype": "f32"},
                {"name": "param:b", "role": "param", "shape": [3], "dtype": "f32"},
                {"name": "input:x", "role": "input", "shape": [1], "dtype": "f32"}
              ],
              "outputs": [], "meta": {}
            }"#,
        )
        .unwrap();
        Manifest::load(dir, "toy").unwrap()
    }

    #[test]
    fn roundtrips_binary_format() {
        let dir = std::env::temp_dir().join("aaren_params_test");
        let m = toy_manifest(&dir);
        let vals: Vec<f32> = vec![1.0, -2.0, 3.5, 0.25, 1e-7];
        let mut bytes = Vec::new();
        for v in &vals {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        std::fs::write(&m.params_bin, &bytes).unwrap();

        let store = ParamStore::load(&m).unwrap();
        assert_eq!(store.params.len(), 2);
        assert_eq!(store.params[0], vec![1.0, -2.0]);
        assert_eq!(store.params[1], vec![3.5, 0.25, 1e-7]);
        assert_eq!(store.opt_m[1], vec![0.0; 3]);

        let ckpt = dir.join("ckpt.bin");
        store.save(&ckpt).unwrap();
        let store2 = ParamStore::load_from(&m, &ckpt).unwrap();
        assert_eq!(store.params, store2.params);
    }

    #[test]
    fn size_mismatch_is_rejected() {
        let dir = std::env::temp_dir().join("aaren_params_test2");
        let m = toy_manifest(&dir);
        std::fs::write(&m.params_bin, [0u8; 12]).unwrap();
        let err = ParamStore::load(&m).unwrap_err();
        assert!(format!("{err}").contains("expects"));
    }
}
