//! Artifact manifests: the contract between the AOT exporter
//! (python/compile/aot.py) and the rust runtime. A manifest lists every
//! executable argument and output in order, with role / shape / dtype, so
//! the runtime is fully generic over model variants — adding a new model
//! requires zero rust changes.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

/// Where an argument/output slots into the training/streaming loop.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Role {
    /// model parameter (loaded from params.bin, updated by train steps)
    Param,
    /// Adam first/second moment (initialised to zero, threaded through)
    OptM,
    OptV,
    /// float32 scalar step counter
    OptStep,
    /// streaming state (threaded output -> next input by the session)
    State,
    /// per-call input (batch data / token / position)
    Input,
    /// auxiliary output (loss, metric sums, predictions)
    Aux,
}

impl Role {
    fn parse(s: &str) -> Result<Role> {
        Ok(match s {
            "param" => Role::Param,
            "opt_m" => Role::OptM,
            "opt_v" => Role::OptV,
            "opt_step" => Role::OptStep,
            "state" => Role::State,
            "input" => Role::Input,
            "aux" => Role::Aux,
            other => bail!("unknown role {other:?}"),
        })
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

impl Dtype {
    fn parse(s: &str) -> Result<Dtype> {
        Ok(match s {
            "f32" => Dtype::F32,
            "i32" => Dtype::I32,
            other => bail!("unknown dtype {other:?}"),
        })
    }

    pub fn size_bytes(self) -> usize {
        4
    }
}

#[derive(Clone, Debug)]
pub struct ArgSpec {
    pub name: String,
    pub role: Role,
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

impl ArgSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Clone, Debug)]
pub struct OutSpec {
    pub role: Role,
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

impl OutSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub name: String,
    pub kind: String,
    pub hlo_path: PathBuf,
    pub params_key: String,
    pub params_bin: PathBuf,
    pub args: Vec<ArgSpec>,
    pub outputs: Vec<OutSpec>,
    pub meta: Json,
}

impl Manifest {
    /// Load `<dir>/<name>.manifest.json`.
    pub fn load(dir: &Path, name: &str) -> Result<Manifest> {
        let path = dir.join(format!("{name}.manifest.json"));
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading manifest {path:?} (run `make artifacts`?)"))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("{path:?}: {e}"))?;

        let parse_shape = |v: &Json| -> Result<Vec<usize>> {
            v.as_arr()
                .ok_or_else(|| anyhow!("shape not an array"))?
                .iter()
                .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim")))
                .collect()
        };

        let mut args = Vec::new();
        for a in j
            .get("args")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("missing args"))?
        {
            args.push(ArgSpec {
                name: a.str_field("name")?.to_string(),
                role: Role::parse(a.str_field("role")?)?,
                shape: parse_shape(a.get("shape").ok_or_else(|| anyhow!("missing shape"))?)?,
                dtype: Dtype::parse(a.str_field("dtype")?)?,
            });
        }
        let mut outputs = Vec::new();
        for o in j
            .get("outputs")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("missing outputs"))?
        {
            outputs.push(OutSpec {
                role: Role::parse(o.str_field("role")?)?,
                shape: parse_shape(o.get("shape").ok_or_else(|| anyhow!("missing shape"))?)?,
                dtype: Dtype::parse(o.str_field("dtype")?)?,
            });
        }

        Ok(Manifest {
            name: j.str_field("name")?.to_string(),
            kind: j.str_field("kind")?.to_string(),
            hlo_path: dir.join(j.str_field("hlo")?),
            params_key: j.str_field("params_key")?.to_string(),
            params_bin: dir.join(j.str_field("params_bin")?),
            args,
            outputs,
            meta: j.get("meta").cloned().unwrap_or(Json::Null),
        })
    }

    pub fn args_with_role(&self, role: Role) -> impl Iterator<Item = (usize, &ArgSpec)> {
        self.args
            .iter()
            .enumerate()
            .filter(move |(_, a)| a.role == role)
    }

    pub fn n_params(&self) -> usize {
        self.args_with_role(Role::Param).count()
    }

    /// Total parameter scalars (the §4.5 count).
    pub fn param_elements(&self) -> usize {
        self.args_with_role(Role::Param).map(|(_, a)| a.elements()).sum()
    }

    /// Index of the `idx`-th input-role argument.
    pub fn input_indices(&self) -> Vec<usize> {
        self.args_with_role(Role::Input).map(|(i, _)| i).collect()
    }

    pub fn state_indices(&self) -> Vec<usize> {
        self.args_with_role(Role::State).map(|(i, _)| i).collect()
    }

    pub fn meta_usize(&self, key: &str, default: usize) -> usize {
        self.meta.get(key).and_then(Json::as_usize).unwrap_or(default)
    }

    pub fn meta_f64(&self, key: &str, default: f64) -> f64 {
        self.meta.get(key).and_then(Json::as_f64).unwrap_or(default)
    }

    /// Bytes of streaming state this module carries per session — the
    /// Figure-5 (left) memory accounting.
    pub fn state_bytes(&self) -> usize {
        self.args
            .iter()
            .filter(|a| a.role == Role::State)
            .map(|a| a.elements() * a.dtype.size_bytes())
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn write_manifest(dir: &Path, name: &str, body: &str) {
        let mut f = std::fs::File::create(dir.join(format!("{name}.manifest.json"))).unwrap();
        f.write_all(body.as_bytes()).unwrap();
    }

    #[test]
    fn parses_roles_shapes_and_meta() {
        let dir = std::env::temp_dir().join("aaren_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        write_manifest(
            &dir,
            "toy",
            r#"{
              "name": "toy", "kind": "train", "hlo": "toy.hlo.txt",
              "params_key": "toy", "params_bin": "toy.params.bin",
              "args": [
                {"name": "param:w", "role": "param", "shape": [2, 3], "dtype": "f32"},
                {"name": "opt_m:w", "role": "opt_m", "shape": [2, 3], "dtype": "f32"},
                {"name": "opt_step:s", "role": "opt_step", "shape": [], "dtype": "f32"},
                {"name": "input:x", "role": "input", "shape": [4], "dtype": "i32"}
              ],
              "outputs": [
                {"role": "param", "shape": [2, 3], "dtype": "f32"},
                {"role": "aux", "shape": [], "dtype": "f32"}
              ],
              "meta": {"lr": 0.001, "horizon": 96}
            }"#,
        );
        let m = Manifest::load(&dir, "toy").unwrap();
        assert_eq!(m.n_params(), 1);
        assert_eq!(m.param_elements(), 6);
        assert_eq!(m.args[3].dtype, Dtype::I32);
        assert_eq!(m.input_indices(), vec![3]);
        assert_eq!(m.meta_usize("horizon", 0), 96);
        assert!(m.state_bytes() == 0);
        assert_eq!(m.outputs.len(), 2);
    }

    #[test]
    fn missing_file_is_helpful() {
        let err = Manifest::load(Path::new("/nonexistent"), "nope").unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }

    #[test]
    fn state_bytes_accounting() {
        let dir = std::env::temp_dir().join("aaren_manifest_test2");
        std::fs::create_dir_all(&dir).unwrap();
        write_manifest(
            &dir,
            "step",
            r#"{
              "name": "step", "kind": "step", "hlo": "s.hlo.txt",
              "params_key": "s", "params_bin": "s.params.bin",
              "args": [
                {"name": "state:a", "role": "state", "shape": [2, 4, 16], "dtype": "f32"},
                {"name": "state:c", "role": "state", "shape": [2, 4], "dtype": "f32"},
                {"name": "input:x", "role": "input", "shape": [8], "dtype": "f32"}
              ],
              "outputs": [], "meta": {}
            }"#,
        );
        let m = Manifest::load(&dir, "step").unwrap();
        assert_eq!(m.state_bytes(), (2 * 4 * 16 + 2 * 4) * 4);
    }
}
