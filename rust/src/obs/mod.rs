//! Observability core: latency histograms, span timing, and the flight
//! recorder — zero dependencies, zero allocation on the fast path.
//!
//! Three layers (see ARCHITECTURE.md, "Observability"):
//!
//! * [`hist`] — lock-free log2-bucketed latency [`Histogram`]s with
//!   mergeable [`HistSnapshot`]s and derived p50/p90/p99/max.
//! * spans — [`Telemetry::span`] returns a scoped-timer guard that
//!   records its elapsed time into the stage's histogram on drop; the
//!   [`crate::obs::span!`](crate::obs_span) macro is the one-line form.
//!   When telemetry is disabled (runtime flag or the compiled-in
//!   `obs-noop` feature) a span takes no clock reading at all.
//! * [`recorder`] — a fixed-capacity ring of structured lifecycle
//!   [`Event`](recorder::Event)s with monotonic timestamps.
//!
//! One [`Telemetry`] instance is owned per executor shard (plus one by
//! the router and one by the fleet): recording never crosses a core,
//! and the `metrics` wire op merges the per-shard snapshots on read.
//! [`Stage`] names every instrumented site — per-op wire latency plus
//! the internal stages of a request (queue wait, executor drain,
//! kernel fold, spill encode/write, restore read/decode, and the
//! fleet's proxy hop / heartbeat / migration legs).

pub mod hist;
pub mod recorder;

use std::collections::BTreeMap;
use std::sync::OnceLock;
use std::time::{Duration, Instant};

pub use hist::{HistSnapshot, Histogram, BUCKETS};
pub use recorder::{Event, Recorder};

use crate::util::json::Json;

/// Milliseconds since the process's monotonic epoch (first use).
pub fn monotonic_ms() -> u64 {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    EPOCH.get_or_init(Instant::now).elapsed().as_millis() as u64
}

/// Every instrumented site. `Op*` stages record whole-request wire
/// latency at the connection handler; the rest time internal legs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Stage {
    OpCreate,
    OpStep,
    OpSteps,
    OpSnapshot,
    OpRestore,
    OpClose,
    OpDrain,
    OpPing,
    OpStats,
    OpMetrics,
    OpShutdown,
    QueueWait,
    ExecDrain,
    KernelFold,
    SpillEncode,
    SpillWrite,
    RestoreRead,
    RestoreDecode,
    FleetProxy,
    FleetHeartbeat,
    FleetMigrate,
}

impl Stage {
    pub const ALL: [Stage; 21] = [
        Stage::OpCreate,
        Stage::OpStep,
        Stage::OpSteps,
        Stage::OpSnapshot,
        Stage::OpRestore,
        Stage::OpClose,
        Stage::OpDrain,
        Stage::OpPing,
        Stage::OpStats,
        Stage::OpMetrics,
        Stage::OpShutdown,
        Stage::QueueWait,
        Stage::ExecDrain,
        Stage::KernelFold,
        Stage::SpillEncode,
        Stage::SpillWrite,
        Stage::RestoreRead,
        Stage::RestoreDecode,
        Stage::FleetProxy,
        Stage::FleetHeartbeat,
        Stage::FleetMigrate,
    ];

    /// The histogram name this stage reports under (wire-stable).
    pub fn name(self) -> &'static str {
        match self {
            Stage::OpCreate => "op_create",
            Stage::OpStep => "op_step",
            Stage::OpSteps => "op_steps",
            Stage::OpSnapshot => "op_snapshot",
            Stage::OpRestore => "op_restore",
            Stage::OpClose => "op_close",
            Stage::OpDrain => "op_drain",
            Stage::OpPing => "op_ping",
            Stage::OpStats => "op_stats",
            Stage::OpMetrics => "op_metrics",
            Stage::OpShutdown => "op_shutdown",
            Stage::QueueWait => "queue_wait",
            Stage::ExecDrain => "exec_drain",
            Stage::KernelFold => "kernel_fold",
            Stage::SpillEncode => "spill_encode",
            Stage::SpillWrite => "spill_write",
            Stage::RestoreRead => "restore_read",
            Stage::RestoreDecode => "restore_decode",
            Stage::FleetProxy => "fleet_proxy",
            Stage::FleetHeartbeat => "fleet_heartbeat",
            Stage::FleetMigrate => "fleet_migrate",
        }
    }
}

/// One telemetry domain: a histogram per [`Stage`] plus a flight
/// recorder. Shared behind an `Arc`; every method takes `&self`.
pub struct Telemetry {
    enabled: bool,
    stages: Vec<Histogram>,
    recorder: Recorder,
}

impl Default for Telemetry {
    fn default() -> Self {
        Telemetry::new(true)
    }
}

impl Telemetry {
    pub fn new(enabled: bool) -> Telemetry {
        Telemetry {
            enabled,
            stages: (0..Stage::ALL.len()).map(|_| Histogram::new()).collect(),
            recorder: Recorder::default(),
        }
    }

    /// A permanently-off instance: spans skip the clock, events are
    /// dropped — the runtime form of the `obs-noop` build.
    pub fn disabled() -> Telemetry {
        Telemetry::new(false)
    }

    /// False when disabled at runtime OR compiled out (`obs-noop`).
    /// The feature check is a constant, so `obs-noop` builds fold every
    /// instrumentation branch to a no-op at compile time.
    #[inline]
    pub fn is_enabled(&self) -> bool {
        !cfg!(feature = "obs-noop") && self.enabled
    }

    /// Record one duration into a stage's histogram.
    #[inline]
    pub fn record(&self, stage: Stage, d: Duration) {
        if self.is_enabled() {
            self.stages[stage as usize].record(d.as_nanos().min(u64::MAX as u128) as u64);
        }
    }

    /// A scoped timer: the guard records its lifetime into `stage` on
    /// drop. Disabled telemetry never reads the clock.
    #[inline]
    pub fn span(&self, stage: Stage) -> Span<'_> {
        Span { tel: self, stage, start: self.is_enabled().then(Instant::now) }
    }

    /// Append a flight-recorder event (dropped when disabled).
    #[inline]
    pub fn event(&self, kind: &'static str, id: u64) {
        if self.is_enabled() {
            self.recorder.push(kind, id);
        }
    }

    pub fn recorder(&self) -> &Recorder {
        &self.recorder
    }

    /// Snapshot every non-empty stage histogram, keyed by stage name.
    pub fn snapshots(&self) -> BTreeMap<String, HistSnapshot> {
        let mut out = BTreeMap::new();
        for stage in Stage::ALL {
            let snap = self.stages[stage as usize].snapshot();
            if !snap.is_empty() {
                out.insert(stage.name().to_string(), snap);
            }
        }
        out
    }
}

/// The guard returned by [`Telemetry::span`]. Holds no allocation;
/// dropping it records the elapsed time (if telemetry was enabled at
/// creation).
pub struct Span<'a> {
    tel: &'a Telemetry,
    stage: Stage,
    start: Option<Instant>,
}

impl Drop for Span<'_> {
    fn drop(&mut self) {
        if let Some(start) = self.start {
            self.tel.record(self.stage, start.elapsed());
        }
    }
}

/// `obs::span!(telemetry, Stage::ExecDrain)` — time the rest of the
/// enclosing scope into the stage's histogram.
#[macro_export]
macro_rules! obs_span {
    ($tel:expr, $stage:expr) => {
        let _obs_span_guard = $tel.span($stage);
    };
}

pub use crate::obs_span as span;

/// Merge any number of per-stage snapshot maps (per-shard, or parsed
/// from fleet members' `metrics` replies) into one rollup.
pub fn merge_named<I>(maps: I) -> BTreeMap<String, HistSnapshot>
where
    I: IntoIterator<Item = BTreeMap<String, HistSnapshot>>,
{
    let mut out: BTreeMap<String, HistSnapshot> = BTreeMap::new();
    for map in maps {
        for (name, snap) in map {
            out.entry(name).or_default().merge(&snap);
        }
    }
    out
}

/// Serialize a merged snapshot map as the `metrics` op's `histograms`
/// object.
pub fn histograms_json(merged: &BTreeMap<String, HistSnapshot>) -> Json {
    Json::Obj(merged.iter().map(|(name, s)| (name.clone(), s.to_json())).collect())
}

/// Parse a `metrics` reply's `histograms` object back into snapshots
/// (unknown or malformed entries are skipped — a newer member's extra
/// stages must not break an older router's rollup).
pub fn parse_histograms(j: &Json) -> BTreeMap<String, HistSnapshot> {
    let mut out = BTreeMap::new();
    if let Some(Json::Obj(map)) = j.get("histograms") {
        for (name, h) in map {
            if let Some(snap) = HistSnapshot::from_json(h) {
                out.insert(name.clone(), snap);
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stage_names_are_unique_and_indexed_consistently() {
        let mut seen = std::collections::HashSet::new();
        for (i, stage) in Stage::ALL.iter().enumerate() {
            assert_eq!(*stage as usize, i, "Stage::ALL order must match discriminants");
            assert!(seen.insert(stage.name()), "duplicate stage name {}", stage.name());
        }
    }

    #[test]
    fn spans_record_into_their_stage() {
        let tel = Telemetry::new(true);
        {
            crate::obs::span!(tel, Stage::ExecDrain);
            std::thread::sleep(Duration::from_millis(1));
        }
        tel.record(Stage::QueueWait, Duration::from_micros(3));
        let snaps = tel.snapshots();
        assert_eq!(snaps["exec_drain"].count(), 1);
        assert!(snaps["exec_drain"].max_ns >= 1_000_000, "span under-measured");
        assert_eq!(snaps["queue_wait"].count(), 1);
        assert!(!snaps.contains_key("kernel_fold"), "untouched stages must be omitted");
    }

    #[test]
    fn disabled_telemetry_records_nothing() {
        let tel = Telemetry::disabled();
        {
            let _s = tel.span(Stage::KernelFold);
        }
        tel.record(Stage::QueueWait, Duration::from_secs(1));
        tel.event("create", 1);
        assert!(tel.snapshots().is_empty());
        assert_eq!(tel.recorder().logged(), 0);
    }

    #[test]
    fn merge_named_rolls_up_across_domains() {
        let a = Telemetry::new(true);
        let b = Telemetry::new(true);
        a.record(Stage::OpStep, Duration::from_nanos(100));
        a.record(Stage::OpStep, Duration::from_nanos(200));
        b.record(Stage::OpStep, Duration::from_nanos(1000));
        b.record(Stage::KernelFold, Duration::from_nanos(50));
        let merged = merge_named([a.snapshots(), b.snapshots()]);
        assert_eq!(merged["op_step"].count(), 3);
        assert_eq!(merged["op_step"].max_ns, 1000);
        assert_eq!(merged["kernel_fold"].count(), 1);
    }

    #[test]
    fn histograms_json_round_trips_through_parse() {
        let tel = Telemetry::new(true);
        for ns in [10u64, 100, 1000, 10_000] {
            tel.record(Stage::OpSteps, Duration::from_nanos(ns));
        }
        let merged = merge_named([tel.snapshots()]);
        let wire = Json::Obj(
            [("histograms".to_string(), histograms_json(&merged))].into_iter().collect(),
        );
        let parsed = Json::parse(&wire.to_string()).unwrap();
        let back = parse_histograms(&parsed);
        assert_eq!(back, merged);
    }
}
