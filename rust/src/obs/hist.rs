//! Lock-free log2-bucketed latency histograms.
//!
//! A [`Histogram`] is a fixed array of atomic `u64` counters — bucket
//! `b ≥ 1` covers durations in `[2^(b-1), 2^b - 1]` nanoseconds, bucket
//! 0 holds exact zeros, and the last bucket absorbs everything past the
//! top boundary (2^39 ns ≈ 9 minutes — far beyond any per-request
//! stage). Recording is three relaxed atomic ops (bucket increment,
//! sum add, max update): no locks, no allocation, safe from any thread.
//!
//! Reading happens through [`HistSnapshot`], a plain (non-atomic) copy
//! that is **mergeable** — bucketwise addition plus sum/max folding —
//! so per-shard histograms combine into a server view and per-member
//! views combine into a fleet view without ever sharing a cache line
//! on the hot path. Percentiles (p50/p90/p99) are derived from the
//! snapshot by a cumulative rank walk and answer with the bucket's
//! upper boundary clamped to the observed max, which keeps
//! `p50 ≤ p90 ≤ p99 ≤ max` by construction (the property tests pin
//! this down). Bucketing means a percentile is exact only to its
//! bucket's width (a factor of 2) — the right resolution for "where
//! does the wall-clock go", not for microbenchmarking.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::util::json::Json;

/// Bucket count: bucket 0 = exact zero, buckets 1..=39 cover
/// `[2^(b-1), 2^b)` ns, the last bucket absorbs the tail.
pub const BUCKETS: usize = 40;

/// Bucket index for a duration in nanoseconds.
#[inline]
pub fn bucket_index(ns: u64) -> usize {
    if ns == 0 {
        0
    } else {
        ((64 - ns.leading_zeros()) as usize).min(BUCKETS - 1)
    }
}

/// Inclusive lower boundary of bucket `b`, in nanoseconds.
pub fn bucket_floor(b: usize) -> u64 {
    if b == 0 {
        0
    } else {
        1u64 << (b - 1)
    }
}

/// Inclusive upper boundary of bucket `b`, in nanoseconds (the last
/// bucket is open-ended; its nominal boundary is still returned).
pub fn bucket_ceil(b: usize) -> u64 {
    if b == 0 {
        0
    } else {
        (1u64 << b) - 1
    }
}

/// A lock-free log2 latency histogram. All methods take `&self`.
#[derive(Debug)]
pub struct Histogram {
    buckets: [AtomicU64; BUCKETS],
    sum_ns: AtomicU64,
    max_ns: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            sum_ns: AtomicU64::new(0),
            max_ns: AtomicU64::new(0),
        }
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram::default()
    }

    /// Record one duration (nanoseconds). Three relaxed atomics; no
    /// allocation, no locks — safe on the hottest path.
    #[inline]
    pub fn record(&self, ns: u64) {
        self.buckets[bucket_index(ns)].fetch_add(1, Ordering::Relaxed);
        self.sum_ns.fetch_add(ns, Ordering::Relaxed);
        self.max_ns.fetch_max(ns, Ordering::Relaxed);
    }

    /// A plain copy for reading/merging. Concurrent recording may be
    /// mid-flight; each counter is individually consistent, which is
    /// all a latency histogram needs.
    pub fn snapshot(&self) -> HistSnapshot {
        HistSnapshot {
            buckets: std::array::from_fn(|b| self.buckets[b].load(Ordering::Relaxed)),
            sum_ns: self.sum_ns.load(Ordering::Relaxed),
            max_ns: self.max_ns.load(Ordering::Relaxed),
        }
    }
}

/// A non-atomic histogram snapshot: mergeable, serializable, and the
/// thing percentiles are derived from.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HistSnapshot {
    pub buckets: [u64; BUCKETS],
    pub sum_ns: u64,
    pub max_ns: u64,
}

impl Default for HistSnapshot {
    fn default() -> Self {
        HistSnapshot { buckets: [0; BUCKETS], sum_ns: 0, max_ns: 0 }
    }
}

impl HistSnapshot {
    /// Total recorded samples (derived from the buckets, so a merged
    /// snapshot can never disagree with its own bucket mass).
    pub fn count(&self) -> u64 {
        self.buckets.iter().sum()
    }

    pub fn is_empty(&self) -> bool {
        self.count() == 0
    }

    /// Merge `other` in: bucketwise add, sum add, max fold. Merging is
    /// commutative and associative, so shard → server → fleet rollups
    /// are order-independent (property-tested).
    pub fn merge(&mut self, other: &HistSnapshot) {
        for (b, v) in self.buckets.iter_mut().zip(other.buckets.iter()) {
            *b += v;
        }
        self.sum_ns += other.sum_ns;
        self.max_ns = self.max_ns.max(other.max_ns);
    }

    /// The q-quantile (q in (0, 1]) in nanoseconds: the upper boundary
    /// of the bucket holding the rank-⌈q·count⌉ sample, clamped to the
    /// observed max. Exact to a factor of 2; 0 when empty.
    pub fn percentile(&self, q: f64) -> u64 {
        let count = self.count();
        if count == 0 {
            return 0;
        }
        let rank = ((q * count as f64).ceil() as u64).clamp(1, count);
        let mut seen = 0u64;
        for (b, n) in self.buckets.iter().enumerate() {
            seen += n;
            if seen >= rank {
                return bucket_ceil(b).min(self.max_ns);
            }
        }
        self.max_ns
    }

    /// Wire form: counts, max, derived percentiles, and the non-empty
    /// buckets as a sparse `{index: count}` object (raw buckets travel
    /// so a reader — the fleet router — can re-merge and re-derive).
    pub fn to_json(&self) -> Json {
        let mut buckets = std::collections::BTreeMap::new();
        for (b, n) in self.buckets.iter().enumerate() {
            if *n > 0 {
                buckets.insert(format!("{b}"), Json::Num(*n as f64));
            }
        }
        let fields = vec![
            ("count".to_string(), Json::Num(self.count() as f64)),
            ("sum_ns".to_string(), Json::Num(self.sum_ns as f64)),
            ("max_ns".to_string(), Json::Num(self.max_ns as f64)),
            ("p50_ns".to_string(), Json::Num(self.percentile(0.50) as f64)),
            ("p90_ns".to_string(), Json::Num(self.percentile(0.90) as f64)),
            ("p99_ns".to_string(), Json::Num(self.percentile(0.99) as f64)),
            ("buckets".to_string(), Json::Obj(buckets)),
        ];
        Json::Obj(fields.into_iter().collect())
    }

    /// Parse the `to_json` form back (the fleet merge path). Percentile
    /// fields are ignored — they are derived, never merged — and the
    /// count is recomputed from the buckets.
    pub fn from_json(j: &Json) -> Option<HistSnapshot> {
        let mut snap = HistSnapshot::default();
        match j.get("buckets")? {
            Json::Obj(map) => {
                for (k, v) in map {
                    let b: usize = k.parse().ok()?;
                    if b >= BUCKETS {
                        return None;
                    }
                    snap.buckets[b] = v.as_f64()? as u64;
                }
            }
            _ => return None,
        }
        snap.sum_ns = j.get("sum_ns").and_then(Json::as_f64)? as u64;
        snap.max_ns = j.get("max_ns").and_then(Json::as_f64)? as u64;
        Some(snap)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn bucket_boundaries_cover_the_line_without_overlap() {
        // exhaustive at the seams: every boundary value lands in its own
        // bucket, its predecessor in the one below
        assert_eq!(bucket_index(0), 0);
        assert_eq!(bucket_index(1), 1);
        for b in 1..BUCKETS - 1 {
            let lo = bucket_floor(b);
            let hi = bucket_ceil(b);
            assert_eq!(bucket_index(lo), b, "floor of bucket {b}");
            assert_eq!(bucket_index(hi), b, "ceil of bucket {b}");
            assert_eq!(bucket_index(hi + 1), b + 1, "first value past bucket {b}");
        }
        // the tail bucket absorbs everything, u64::MAX included
        assert_eq!(bucket_index(bucket_floor(BUCKETS - 1)), BUCKETS - 1);
        assert_eq!(bucket_index(u64::MAX), BUCKETS - 1);
    }

    #[test]
    fn bucket_boundaries_bracket_every_recorded_value() {
        crate::util::prop::check("hist_bucket_brackets", 256, |rng| {
            // skew toward small magnitudes so every bucket gets traffic
            let v = rng.next_u64() >> (rng.next_u64() % 64) as u32;
            let b = bucket_index(v);
            if v < bucket_floor(b) {
                return Err(format!("{v} below its bucket {b} floor"));
            }
            if b < BUCKETS - 1 && v > bucket_ceil(b) {
                return Err(format!("{v} above its bucket {b} ceil"));
            }
            Ok(())
        });
    }

    fn random_snapshot(rng: &mut Rng, samples: usize) -> HistSnapshot {
        let h = Histogram::new();
        for _ in 0..samples {
            h.record(rng.next_u64() >> (32 + (rng.next_u64() % 28) as u32));
        }
        h.snapshot()
    }

    #[test]
    fn merge_is_associative_and_commutative() {
        crate::util::prop::check("hist_merge_assoc", 64, |rng| {
            let a = random_snapshot(rng, 1 + (rng.next_u64() % 40) as usize);
            let b = random_snapshot(rng, 1 + (rng.next_u64() % 40) as usize);
            let c = random_snapshot(rng, 1 + (rng.next_u64() % 40) as usize);
            let mut ab_c = a.clone();
            ab_c.merge(&b);
            ab_c.merge(&c);
            let mut bc = b.clone();
            bc.merge(&c);
            let mut a_bc = a.clone();
            a_bc.merge(&bc);
            if ab_c != a_bc {
                return Err("(a∪b)∪c != a∪(b∪c)".into());
            }
            let mut ba = b.clone();
            ba.merge(&a);
            let mut ab = a.clone();
            ab.merge(&b);
            if ab != ba {
                return Err("a∪b != b∪a".into());
            }
            Ok(())
        });
    }

    #[test]
    fn merge_of_shards_equals_record_into_one() {
        // the fleet-rollup guarantee: sharding the sample stream and
        // merging the shard histograms is indistinguishable from
        // recording everything into one histogram
        crate::util::prop::check("hist_shard_merge", 64, |rng| {
            let shards: Vec<Histogram> = (0..4).map(|_| Histogram::new()).collect();
            let whole = Histogram::new();
            for _ in 0..1 + (rng.next_u64() % 200) {
                let v = rng.next_u64() >> (24 + (rng.next_u64() % 40) as u32);
                shards[(rng.next_u64() % 4) as usize].record(v);
                whole.record(v);
            }
            let mut merged = HistSnapshot::default();
            for s in &shards {
                merged.merge(&s.snapshot());
            }
            if merged != whole.snapshot() {
                return Err("merged shard snapshots != single-histogram snapshot".into());
            }
            Ok(())
        });
    }

    #[test]
    fn percentiles_are_monotone_and_bounded_by_max() {
        crate::util::prop::check("hist_percentile_monotone", 128, |rng| {
            let snap = random_snapshot(rng, 1 + (rng.next_u64() % 300) as usize);
            let (p50, p90, p99) =
                (snap.percentile(0.50), snap.percentile(0.90), snap.percentile(0.99));
            if !(p50 <= p90 && p90 <= p99 && p99 <= snap.max_ns) {
                return Err(format!(
                    "monotonicity broken: p50={p50} p90={p90} p99={p99} max={}",
                    snap.max_ns
                ));
            }
            Ok(())
        });
    }

    #[test]
    fn percentile_walks_known_mass_correctly() {
        let h = Histogram::new();
        // 90 samples at ~100ns (bucket 7: 64..=127), 10 at ~1000ns
        // (bucket 10: 512..=1023)
        for _ in 0..90 {
            h.record(100);
        }
        for _ in 0..10 {
            h.record(1000);
        }
        let s = h.snapshot();
        assert_eq!(s.count(), 100);
        assert_eq!(s.percentile(0.50), 127);
        assert_eq!(s.percentile(0.90), 127);
        // rank 91 crosses into the 1000ns bucket, clamped to the max
        assert_eq!(s.percentile(0.99), 1000);
        assert_eq!(s.max_ns, 1000);
        assert_eq!(s.sum_ns, 90 * 100 + 10 * 1000);
    }

    #[test]
    fn json_round_trip_preserves_buckets_sum_and_max() {
        crate::util::prop::check("hist_json_roundtrip", 64, |rng| {
            let snap = random_snapshot(rng, (rng.next_u64() % 50) as usize);
            let j = snap.to_json();
            let parsed = Json::parse(&j.to_string()).map_err(|e| e.to_string())?;
            let back = HistSnapshot::from_json(&parsed).ok_or("from_json failed")?;
            if back != snap {
                return Err("snapshot changed across the JSON round-trip".into());
            }
            Ok(())
        });
    }

    #[test]
    fn malformed_json_is_refused_not_misread() {
        assert!(HistSnapshot::from_json(&Json::Null).is_none());
        let j = Json::parse(r#"{"buckets":{"99":1},"sum_ns":0,"max_ns":0}"#).unwrap();
        assert!(HistSnapshot::from_json(&j).is_none(), "out-of-range bucket index");
        let j = Json::parse(r#"{"buckets":3,"sum_ns":0,"max_ns":0}"#).unwrap();
        assert!(HistSnapshot::from_json(&j).is_none(), "non-object buckets");
        let j = Json::parse(r#"{"buckets":{}}"#).unwrap();
        assert!(HistSnapshot::from_json(&j).is_none(), "missing sum/max");
    }
}
