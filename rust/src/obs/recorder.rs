//! The flight recorder: a fixed-capacity ring buffer of structured
//! lifecycle events (session create / evict / spill / restore /
//! quarantine / migrate / failover …) with monotonic timestamps and a
//! global sequence number per recorder.
//!
//! Events are *rare* relative to token traffic — lifecycle edges, not
//! per-request records — so a mutex-guarded `VecDeque` is the right
//! trade: the histogram layer keeps the per-token path lock-free, and
//! the recorder buys bounded memory plus exact loss accounting (the
//! sequence counter keeps advancing when the ring wraps, so a dump can
//! always report how many events it no longer holds).

use std::collections::VecDeque;
use std::sync::Mutex;

use crate::util::json::Json;

/// Default ring capacity per recorder (per executor shard): enough to
/// hold the recent lifecycle history of a busy shard, small enough to
/// be dumped whole in one `metrics` reply.
pub const DEFAULT_CAPACITY: usize = 256;

/// One structured flight-recorder entry. `ts_ms` is milliseconds since
/// the process's monotonic epoch (comparable across recorders in one
/// process, never wall-clock), `seq` is this recorder's dense sequence.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Event {
    pub seq: u64,
    pub ts_ms: u64,
    pub kind: &'static str,
    pub id: u64,
}

impl Event {
    pub fn to_json(&self) -> Json {
        let fields = vec![
            ("seq".to_string(), Json::Num(self.seq as f64)),
            ("ts_ms".to_string(), Json::Num(self.ts_ms as f64)),
            ("kind".to_string(), Json::Str(self.kind.to_string())),
            ("id".to_string(), Json::Num(self.id as f64)),
        ];
        Json::Obj(fields.into_iter().collect())
    }
}

struct Ring {
    next_seq: u64,
    events: VecDeque<Event>,
}

/// A bounded ring of [`Event`]s. Push is O(1) amortized under a short
/// mutex hold; overflow drops the oldest entry and is accounted for.
pub struct Recorder {
    cap: usize,
    ring: Mutex<Ring>,
}

impl Default for Recorder {
    fn default() -> Self {
        Recorder::new(DEFAULT_CAPACITY)
    }
}

impl Recorder {
    pub fn new(cap: usize) -> Recorder {
        Recorder {
            cap: cap.max(1),
            ring: Mutex::new(Ring { next_seq: 0, events: VecDeque::new() }),
        }
    }

    /// Append one event, evicting the oldest past capacity.
    pub fn push(&self, kind: &'static str, id: u64) {
        let ts_ms = super::monotonic_ms();
        let mut ring = self.ring.lock().expect("recorder lock");
        let seq = ring.next_seq;
        ring.next_seq += 1;
        ring.events.push_back(Event { seq, ts_ms, kind, id });
        if ring.events.len() > self.cap {
            ring.events.pop_front();
        }
    }

    /// The retained events, oldest first.
    pub fn recent(&self) -> Vec<Event> {
        let ring = self.ring.lock().expect("recorder lock");
        ring.events.iter().cloned().collect()
    }

    /// Total events ever pushed (including ones the ring dropped).
    pub fn logged(&self) -> u64 {
        self.ring.lock().expect("recorder lock").next_seq
    }

    /// Events the ring no longer holds.
    pub fn dropped(&self) -> u64 {
        let ring = self.ring.lock().expect("recorder lock");
        ring.next_seq - ring.events.len() as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_keeps_the_newest_events_and_counts_drops() {
        let rec = Recorder::new(4);
        for id in 0..10u64 {
            rec.push("create", id);
        }
        let events = rec.recent();
        assert_eq!(events.len(), 4);
        assert_eq!(events.iter().map(|e| e.id).collect::<Vec<_>>(), vec![6, 7, 8, 9]);
        assert_eq!(rec.logged(), 10);
        assert_eq!(rec.dropped(), 6);
        // sequence numbers are dense and survive the wrap
        assert_eq!(events.iter().map(|e| e.seq).collect::<Vec<_>>(), vec![6, 7, 8, 9]);
    }

    #[test]
    fn timestamps_are_monotone_within_a_recorder() {
        let rec = Recorder::new(8);
        rec.push("spill", 1);
        rec.push("restore", 1);
        let events = rec.recent();
        assert!(events[0].ts_ms <= events[1].ts_ms);
        assert_eq!(events[0].kind, "spill");
    }

    #[test]
    fn event_json_carries_every_field() {
        let e = Event { seq: 3, ts_ms: 17, kind: "quarantine", id: 9 };
        let j = e.to_json();
        assert_eq!(j.usize_field("seq").unwrap(), 3);
        assert_eq!(j.usize_field("ts_ms").unwrap(), 17);
        assert_eq!(j.str_field("kind").unwrap(), "quarantine");
        assert_eq!(j.usize_field("id").unwrap(), 9);
    }
}
