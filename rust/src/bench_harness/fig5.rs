//! Figure 5: memory usage (left) and cumulative time (right) of Aaren vs
//! Transformer+KV-cache when processing a token stream.
//!
//! Memory is measured from the live session state (exact bytes held per
//! session); time is wall-clock per step. The paper's claim is about
//! *shape*: constant vs linear memory, linear vs quadratic cumulative
//! time. Both the compiled-HLO tier (`pjrt` feature) and the rust-native
//! session fallback reproduce it; the native path runs on any build.

use std::time::Instant;

use anyhow::Result;

use crate::serve::session::{NativeAarenSession, NativeTfSession};
use crate::util::bench::print_table;
use crate::util::rng::Rng;

pub struct Fig5Point {
    pub tokens: usize,
    pub aaren_bytes: usize,
    pub tf_bytes: usize,
    pub aaren_cum_ms: f64,
    pub tf_cum_ms: f64,
}

/// The sampling grid both tiers use, clipped to the stream length.
pub fn default_checkpoints(n_tokens: usize) -> Vec<usize> {
    [1, 2, 4, 8, 16, 32, 64, 96, 128, 192, 256, 384, 512]
        .into_iter()
        .filter(|&c| c <= n_tokens)
        .collect()
}

/// Shared measurement loop: stream `n_tokens` seeded-random tokens
/// through two sessions, timing each step and sampling (state bytes,
/// cumulative ms) at `checkpoints`. Each closure feeds its session one
/// token and returns the session's current state size in bytes.
fn measure_with(
    n_tokens: usize,
    channels: usize,
    checkpoints: &[usize],
    mut aaren_step: impl FnMut(&[f32]) -> Result<usize>,
    mut tf_step: impl FnMut(&[f32]) -> Result<usize>,
) -> Result<Vec<Fig5Point>> {
    let mut rng = Rng::new(5);
    let tokens: Vec<Vec<f32>> = (0..n_tokens)
        .map(|_| (0..channels).map(|_| rng.gaussian() as f32).collect())
        .collect();

    let mut points = Vec::new();
    let mut aaren_cum = 0.0f64;
    let mut tf_cum = 0.0f64;
    for (i, tok) in tokens.iter().enumerate() {
        let t0 = Instant::now();
        let aaren_bytes = aaren_step(tok)?;
        aaren_cum += t0.elapsed().as_secs_f64() * 1e3;

        let t0 = Instant::now();
        let tf_bytes = tf_step(tok)?;
        tf_cum += t0.elapsed().as_secs_f64() * 1e3;

        if checkpoints.contains(&(i + 1)) {
            points.push(Fig5Point {
                tokens: i + 1,
                aaren_bytes,
                tf_bytes,
                aaren_cum_ms: aaren_cum,
                tf_cum_ms: tf_cum,
            });
        }
    }
    Ok(points)
}

fn print_points(title: &str, points: &[Fig5Point]) {
    let rows: Vec<Vec<String>> = points
        .iter()
        .map(|p| {
            vec![
                p.tokens.to_string(),
                p.aaren_bytes.to_string(),
                p.tf_bytes.to_string(),
                format!("{:.2}", p.aaren_cum_ms),
                format!("{:.2}", p.tf_cum_ms),
            ]
        })
        .collect();
    print_table(
        title,
        &["tokens", "Aaren bytes", "TF(KV) bytes", "Aaren cum ms", "TF(KV) cum ms"],
        &rows,
    );
    // shape summary
    if points.len() >= 3 {
        let first = &points[0];
        let last = &points[points.len() - 1];
        let ratio_tokens = last.tokens as f64 / first.tokens as f64;
        println!(
            "\nshape check over {}x tokens: Aaren memory x{:.2} (paper: constant), \
             TF memory x{:.2} (paper: linear)",
            ratio_tokens,
            last.aaren_bytes as f64 / first.aaren_bytes as f64,
            last.tf_bytes as f64 / first.tf_bytes as f64,
        );
        // cumulative-time curvature: fit t_cum ~ n^p via log-log endpoints
        let mid = &points[points.len() / 2];
        let slope = |a: (f64, f64), b: (f64, f64)| (b.1.ln() - a.1.ln()) / (b.0.ln() - a.0.ln());
        let aaren_p = slope(
            (mid.tokens as f64, mid.aaren_cum_ms),
            (last.tokens as f64, last.aaren_cum_ms),
        );
        let tf_p = slope(
            (mid.tokens as f64, mid.tf_cum_ms),
            (last.tokens as f64, last.tf_cum_ms),
        );
        println!(
            "cumulative-time exponent (log-log slope, upper half): Aaren {aaren_p:.2} \
             (paper: ~1 linear), TF {tf_p:.2} (paper: ~2 quadratic)"
        );
    }
}

/// Stream `n_tokens` through the rust-native session pair (no XLA),
/// sampling at `checkpoints`. The Aaren side is the O(1) `Muw` fold; the
/// TF side recomputes attention over its growing KV cache.
pub fn measure_native(
    n_tokens: usize,
    channels: usize,
    checkpoints: &[usize],
) -> Result<Vec<Fig5Point>> {
    let mut aaren = NativeAarenSession::new(channels);
    let mut tf = NativeTfSession::new(channels);
    measure_with(
        n_tokens,
        channels,
        checkpoints,
        |tok| {
            aaren.step(tok)?;
            Ok(aaren.state_bytes())
        },
        |tok| {
            tf.step(tok)?;
            Ok(tf.state_bytes())
        },
    )
}

/// Rust-native Figure-5 run: measure, print the table + shape summary.
/// Streams longer than the largest KV bucket are clamped (with a notice)
/// so the columns stay comparable with the HLO tier, whose compiled
/// per-bucket step modules end at the largest bucket — the native tf
/// session itself now keeps growing geometrically and would survive
/// past it (see the serve loopback test for that regression).
pub fn run_fig5_native(n_tokens: usize, channels: usize) -> Result<Vec<Fig5Point>> {
    let max_tokens = crate::serve::TF_BUCKETS[crate::serve::TF_BUCKETS.len() - 1];
    if n_tokens > max_tokens {
        println!(
            "note: clamping stream length {n_tokens} -> {max_tokens} \
             (largest TF KV bucket, kept for HLO-tier comparability)"
        );
    }
    let n_tokens = n_tokens.min(max_tokens);
    let points = measure_native(n_tokens, channels, &default_checkpoints(n_tokens))?;
    print_points(
        "Figure 5 (rust-native sessions): streaming memory (bytes) and cumulative time (ms)",
        &points,
    );
    Ok(points)
}

#[cfg(feature = "pjrt")]
pub use hlo::{measure, run_fig5};

#[cfg(feature = "pjrt")]
mod hlo {
    use super::*;
    use crate::runtime::exec::Engine;
    use crate::serve::session::{Session, StreamModel};

    /// Stream `n_tokens` through both HLO session kinds, sampling at
    /// `checkpoints`.
    pub fn measure(
        engine: &mut Engine,
        n_tokens: usize,
        checkpoints: &[usize],
    ) -> Result<Vec<Fig5Point>> {
        let aaren_model = StreamModel::load_aaren(engine)?;
        let tf_model = StreamModel::load_tf(engine)?;
        let channels = aaren_model.channels;
        let mut aaren = Session::new_aaren(&aaren_model)?;
        let mut tf = Session::new_tf(&tf_model)?;
        measure_with(
            n_tokens,
            channels,
            checkpoints,
            |tok| {
                aaren.step(&aaren_model, tok)?;
                Ok(aaren.state_bytes())
            },
            |tok| {
                tf.step(&tf_model, tok)?;
                Ok(tf.state_bytes())
            },
        )
    }

    pub fn run_fig5(artifacts: &std::path::Path, n_tokens: usize) -> Result<Vec<Fig5Point>> {
        let mut engine = Engine::new(artifacts)?;
        let points = measure(&mut engine, n_tokens, &default_checkpoints(n_tokens))?;
        print_points(
            "Figure 5: streaming memory (bytes of session state) and cumulative time (ms)",
            &points,
        );
        Ok(points)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_fig5_reproduces_the_paper_shape() {
        let points = measure_native(48, 4, &[1, 16, 48]).unwrap();
        assert_eq!(points.len(), 3);
        // Aaren: constant memory
        assert_eq!(points[0].aaren_bytes, points[2].aaren_bytes);
        // TF: memory grows (48 tokens crosses the 32-token bucket)
        assert!(points[2].tf_bytes > points[0].tf_bytes);
        // cumulative times are monotone
        assert!(points[2].aaren_cum_ms >= points[1].aaren_cum_ms);
        assert!(points[2].tf_cum_ms >= points[1].tf_cum_ms);
    }

    #[test]
    fn native_fig5_clamps_overlong_streams() {
        let points = run_fig5_native(100_000, 2).unwrap();
        assert_eq!(points.last().unwrap().tokens, 512);
    }

    #[test]
    fn checkpoints_clip_to_stream_length() {
        assert_eq!(default_checkpoints(10), vec![1, 2, 4, 8]);
        assert!(default_checkpoints(512).contains(&512));
    }
}
