//! Figure 5: memory usage (left) and cumulative time (right) of Aaren vs
//! Transformer+KV-cache when processing a token stream.
//!
//! Memory is measured from the live session state literals (exact bytes
//! held per session); time is wall-clock over the compiled HLO steps. The
//! paper's claim is about *shape*: constant vs linear memory, linear vs
//! quadratic cumulative time — both reproduce on CPU PJRT.

use std::time::Instant;

use anyhow::Result;

use crate::serve::session::{Session, StreamModel};
use crate::runtime::exec::Engine;
use crate::util::bench::print_table;
use crate::util::rng::Rng;

pub struct Fig5Point {
    pub tokens: usize,
    pub aaren_bytes: usize,
    pub tf_bytes: usize,
    pub aaren_cum_ms: f64,
    pub tf_cum_ms: f64,
}

/// Stream `n_tokens` through both session kinds, sampling at `checkpoints`.
pub fn measure(
    engine: &mut Engine,
    n_tokens: usize,
    checkpoints: &[usize],
) -> Result<Vec<Fig5Point>> {
    let aaren_model = StreamModel::load_aaren(engine)?;
    let tf_model = StreamModel::load_tf(engine)?;
    let channels = aaren_model.channels;
    let mut rng = Rng::new(5);
    let tokens: Vec<Vec<f32>> = (0..n_tokens)
        .map(|_| (0..channels).map(|_| rng.gaussian() as f32).collect())
        .collect();

    let mut aaren = Session::new_aaren(&aaren_model)?;
    let mut tf = Session::new_tf(&tf_model)?;

    let mut points = Vec::new();
    let mut aaren_cum = 0.0f64;
    let mut tf_cum = 0.0f64;
    for (i, tok) in tokens.iter().enumerate() {
        let t0 = Instant::now();
        aaren.step(&aaren_model, tok)?;
        aaren_cum += t0.elapsed().as_secs_f64() * 1e3;

        let t0 = Instant::now();
        tf.step(&tf_model, tok)?;
        tf_cum += t0.elapsed().as_secs_f64() * 1e3;

        if checkpoints.contains(&(i + 1)) {
            points.push(Fig5Point {
                tokens: i + 1,
                aaren_bytes: aaren.state_bytes(),
                tf_bytes: tf.state_bytes(),
                aaren_cum_ms: aaren_cum,
                tf_cum_ms: tf_cum,
            });
        }
    }
    Ok(points)
}

pub fn run_fig5(artifacts: &std::path::Path, n_tokens: usize) -> Result<Vec<Fig5Point>> {
    let mut engine = Engine::new(artifacts)?;
    let checkpoints: Vec<usize> = [1, 2, 4, 8, 16, 32, 64, 96, 128, 192, 256, 384, 512]
        .into_iter()
        .filter(|&c| c <= n_tokens)
        .collect();
    let points = measure(&mut engine, n_tokens, &checkpoints)?;
    let rows: Vec<Vec<String>> = points
        .iter()
        .map(|p| {
            vec![
                p.tokens.to_string(),
                p.aaren_bytes.to_string(),
                p.tf_bytes.to_string(),
                format!("{:.2}", p.aaren_cum_ms),
                format!("{:.2}", p.tf_cum_ms),
            ]
        })
        .collect();
    print_table(
        "Figure 5: streaming memory (bytes of session state) and cumulative time (ms)",
        &["tokens", "Aaren bytes", "TF(KV) bytes", "Aaren cum ms", "TF(KV) cum ms"],
        &rows,
    );
    // shape summary
    if points.len() >= 3 {
        let first = &points[0];
        let last = &points[points.len() - 1];
        let ratio_tokens = last.tokens as f64 / first.tokens as f64;
        println!(
            "\nshape check over {}x tokens: Aaren memory x{:.2} (paper: constant), \
             TF memory x{:.2} (paper: linear)",
            ratio_tokens,
            last.aaren_bytes as f64 / first.aaren_bytes as f64,
            last.tf_bytes as f64 / first.tf_bytes as f64,
        );
        // cumulative-time curvature: fit t_cum ~ n^p via log-log endpoints
        let mid = &points[points.len() / 2];
        let slope = |a: (f64, f64), b: (f64, f64)| (b.1.ln() - a.1.ln()) / (b.0.ln() - a.0.ln());
        let aaren_p = slope(
            (mid.tokens as f64, mid.aaren_cum_ms),
            (last.tokens as f64, last.aaren_cum_ms),
        );
        let tf_p = slope(
            (mid.tokens as f64, mid.tf_cum_ms),
            (last.tokens as f64, last.tf_cum_ms),
        );
        println!(
            "cumulative-time exponent (log-log slope, upper half): Aaren {aaren_p:.2} \
             (paper: ~1 linear), TF {tf_p:.2} (paper: ~2 quadratic)"
        );
    }
    Ok(points)
}
