//! Paper-table regeneration harnesses. Each `run_*` sweeps the matching
//! experiment driver over datasets × {Transformer, Aaren} × seeds and
//! prints a table in the paper's layout (mean ± std). Shared by the
//! `aaren bench …` CLI and the `cargo bench` targets.

pub mod fig5;
pub mod tables;

pub use fig5::run_fig5;
pub use tables::{run_params, run_table1, run_table2, run_table3, run_table4, BenchOpts};
