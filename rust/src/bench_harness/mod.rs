//! Paper-table regeneration harnesses. Each `run_*` sweeps the matching
//! experiment driver over datasets × {Transformer, Aaren} × seeds and
//! prints a table in the paper's layout (mean ± std). Shared by the
//! `aaren bench …` CLI and the `cargo bench` targets.
//!
//! The table harnesses execute compiled HLO and need the `pjrt` feature;
//! `fig5` additionally carries a rust-native measurement path
//! ([`fig5::run_fig5_native`]) that reproduces the Figure-5 *shape*
//! (constant vs linear memory, linear vs quadratic cumulative time) on
//! any build.

pub mod fig5;
#[cfg(feature = "pjrt")]
pub mod tables;

pub use fig5::run_fig5_native;
#[cfg(feature = "pjrt")]
pub use fig5::run_fig5;
#[cfg(feature = "pjrt")]
pub use tables::{run_params, run_table1, run_table2, run_table3, run_table4, BenchOpts};
