//! Tables 1–4 (+5) of the paper, regenerated on the synthetic substrates.

use std::path::Path;

use anyhow::Result;

use crate::coordinator::experiments::{self, Kind, BOTH};
use crate::data::{events, rl, tsc, tsf};
use crate::runtime::exec::Engine;
use crate::util::bench::{fmt_pm, mean_std, print_table};
use crate::util::json::Json;

#[derive(Clone, Debug)]
pub struct BenchOpts {
    pub seeds: u64,
    pub train_steps: usize,
    /// restrict to the first k datasets (quick smoke runs); 0 = all
    pub limit: usize,
    pub artifacts: std::path::PathBuf,
}

impl Default for BenchOpts {
    fn default() -> Self {
        BenchOpts {
            seeds: 2,
            train_steps: 150,
            limit: 0,
            artifacts: std::path::PathBuf::from("artifacts"),
        }
    }
}

fn limited<T: Copy>(all: &[T], limit: usize) -> Vec<T> {
    if limit == 0 || limit >= all.len() {
        all.to_vec()
    } else {
        all[..limit].to_vec()
    }
}

/// Table 1: RL normalised scores over 4 envs × 3 tiers.
pub fn run_table1(opts: &BenchOpts) -> Result<()> {
    let mut engine = Engine::new(&opts.artifacts)?;
    let envs = limited(&rl::ALL_ENVS, opts.limit);
    let mut rows = Vec::new();
    for env in &envs {
        for tier in rl::ALL_TIERS {
            let mut cells = vec![format!("{} {}", env.name(), tier.name())];
            for kind in BOTH {
                let mut scores = Vec::new();
                for seed in 0..opts.seeds {
                    let r = experiments::run_rl(
                        &mut engine,
                        kind,
                        *env,
                        tier,
                        opts.train_steps,
                        40,
                        3,
                        1000 + seed,
                    )?;
                    scores.push(r.normalised_score);
                }
                let (m, s) = mean_std(&scores);
                cells.push(fmt_pm(m, s, 2));
            }
            println!(
                "  [table1] {} {} done",
                env.name(),
                tier.name()
            );
            rows.push(cells);
        }
    }
    print_table(
        "Table 1: Reinforcement Learning (D4RL-style normalised score, higher is better)",
        &["Dataset", "Transformer", "Aaren"],
        &rows,
    );
    Ok(())
}

/// Table 2: event forecasting NLL / RMSE / Acc over 8 datasets.
pub fn run_table2(opts: &BenchOpts) -> Result<()> {
    let mut engine = Engine::new(&opts.artifacts)?;
    let datasets = limited(&events::ALL, opts.limit);
    let mut rows = Vec::new();
    for ds in &datasets {
        for kind in BOTH {
            let mut nll = Vec::new();
            let mut rmse = Vec::new();
            let mut acc = Vec::new();
            for seed in 0..opts.seeds {
                let r = experiments::run_ef(&mut engine, kind, *ds, opts.train_steps, 2000 + seed)?;
                nll.push(r.nll);
                rmse.push(r.rmse);
                if let Some(a) = r.acc {
                    acc.push(a);
                }
            }
            let (nm, ns) = mean_std(&nll);
            let (rm, rs) = mean_std(&rmse);
            let acc_cell = if acc.is_empty() {
                "—".to_string()
            } else {
                let (am, asd) = mean_std(&acc);
                fmt_pm(am, asd, 2)
            };
            rows.push(vec![
                ds.name().to_string(),
                kind.display().to_string(),
                fmt_pm(nm, ns, 2),
                fmt_pm(rm, rs, 2),
                acc_cell,
            ]);
        }
        println!("  [table2] {} done", ds.name());
    }
    print_table(
        "Table 2: Event Forecasting (NLL ↓ / RMSE ↓ / Acc ↑)",
        &["Dataset", "Model", "NLL", "RMSE", "Acc %"],
        &rows,
    );
    Ok(())
}

/// Tables 3+5: TSF MSE/MAE over 8 datasets × horizons.
pub fn run_table3(opts: &BenchOpts, horizons: &[usize]) -> Result<()> {
    let mut engine = Engine::new(&opts.artifacts)?;
    let datasets = limited(&tsf::ALL, opts.limit);
    let mut rows = Vec::new();
    for ds in &datasets {
        for &horizon in horizons {
            for kind in BOTH {
                let mut mse = Vec::new();
                let mut mae = Vec::new();
                for seed in 0..opts.seeds {
                    let r = experiments::run_tsf(
                        &mut engine,
                        kind,
                        *ds,
                        horizon,
                        opts.train_steps,
                        3000 + seed,
                    )?;
                    mse.push(r.mse);
                    mae.push(r.mae);
                }
                let (mm, ms) = mean_std(&mse);
                let (am, asd) = mean_std(&mae);
                rows.push(vec![
                    ds.name().to_string(),
                    horizon.to_string(),
                    kind.display().to_string(),
                    fmt_pm(mm, ms, 2),
                    fmt_pm(am, asd, 2),
                ]);
            }
            println!("  [table3] {} T={horizon} done", ds.name());
        }
    }
    print_table(
        "Tables 3/5: Time Series Forecasting (MSE ↓ / MAE ↓)",
        &["Dataset", "T", "Model", "MSE", "MAE"],
        &rows,
    );
    Ok(())
}

/// Table 4: TSC accuracy over 10 datasets.
pub fn run_table4(opts: &BenchOpts) -> Result<()> {
    let mut engine = Engine::new(&opts.artifacts)?;
    let datasets = limited(&tsc::ALL, opts.limit);
    let mut rows = Vec::new();
    for ds in &datasets {
        let mut cells = vec![ds.name().to_string()];
        for kind in BOTH {
            let mut accs = Vec::new();
            for seed in 0..opts.seeds {
                let r =
                    experiments::run_tsc(&mut engine, kind, *ds, opts.train_steps, 4000 + seed)?;
                accs.push(r.acc);
            }
            let (m, s) = mean_std(&accs);
            cells.push(fmt_pm(m, s, 2));
        }
        println!("  [table4] {} done", ds.name());
        rows.push(cells);
    }
    print_table(
        "Table 4: Time Series Classification (Acc ↑, %)",
        &["Dataset", "Transformer", "Aaren"],
        &rows,
    );
    Ok(())
}

/// §4.5 parameter counts: paper-scale (from aot paramcount.json) plus the
/// live artifact manifests.
pub fn run_params(artifacts: &Path) -> Result<()> {
    let pc_path = artifacts.join("paramcount.json");
    let text = std::fs::read_to_string(&pc_path)?;
    let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
    let tf = j.usize_field("tf")?;
    let aaren = j.usize_field("aaren")?;
    let delta = aaren as f64 - tf as f64;
    let pct = 100.0 * delta / tf as f64;
    let mut rows = vec![
        vec![
            "paper-scale stream model".to_string(),
            format!("{tf}"),
            format!("{aaren}"),
            format!("+{delta:.0} ({pct:.3}%)"),
        ],
        vec![
            "paper (reported)".to_string(),
            "3,152,384".to_string(),
            "3,152,896".to_string(),
            "+512 (~0.016%)".to_string(),
        ],
    ];
    // also report the live small artifacts
    let mut engine = Engine::new(artifacts)?;
    for (name_tf, name_aa, label) in [
        ("stream_tf_train", "stream_aaren_train", "stream (live artifacts)"),
        ("tsc_tf_train", "tsc_aaren_train", "tsc (live artifacts)"),
    ] {
        let mt = engine.load(name_tf)?.manifest.param_elements();
        let ma = engine.load(name_aa)?.manifest.param_elements();
        rows.push(vec![
            label.to_string(),
            mt.to_string(),
            ma.to_string(),
            format!("+{} ({:.3}%)", ma - mt, 100.0 * (ma - mt) as f64 / mt as f64),
        ]);
    }
    print_table(
        "§4.5 Parameter counts (Aaren = Transformer + one learned query token per block)",
        &["Model pair", "Transformer", "Aaren", "delta"],
        &rows,
    );
    Ok(())
}

/// Run one quick cell of each table (CI smoke — exercises every artifact
/// family end to end).
pub fn run_smoke(opts: &BenchOpts) -> Result<()> {
    let mut engine = Engine::new(&opts.artifacts)?;
    let r = experiments::run_tsf(&mut engine, Kind::Aaren, tsf::TsfDataset::Etth1, 96, 30, 1)?;
    println!("smoke tsf: mse {:.3} mae {:.3}", r.mse, r.mae);
    let r = experiments::run_tsc(&mut engine, Kind::Tf, tsc::TscDataset::ArabicDigits, 30, 1)?;
    println!("smoke tsc: acc {:.1}%", r.acc);
    let r = experiments::run_ef(&mut engine, Kind::Aaren, events::EfDataset::Sin, 30, 1)?;
    println!("smoke ef: nll {:.3} rmse {:.3}", r.nll, r.rmse);
    let r = experiments::run_rl(
        &mut engine,
        Kind::Tf,
        rl::EnvId::Hopper,
        rl::Tier::Medium,
        30,
        10,
        1,
        1,
    )?;
    println!("smoke rl: norm score {:.1}", r.normalised_score);
    Ok(())
}
