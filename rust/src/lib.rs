//! # aaren-rs — *Attention as an RNN* (Feng et al., 2024) in Rust + JAX + Pallas
//!
//! Three-layer reproduction of the paper's Aaren module and its full
//! evaluation suite:
//!
//! * **L1** (build time): Pallas prefix-scan attention kernels, validated
//!   against pure-jnp oracles (`python/compile/kernels/`).
//! * **L2** (build time): JAX models per evaluation domain, AOT-lowered to
//!   HLO text (`python/compile/`, `make artifacts`).
//! * **L3** (this crate): the runtime/coordination layer — PJRT execution,
//!   training orchestration, synthetic dataset substrates for all 38 paper
//!   datasets, the constant-memory streaming session manager, and bench
//!   harnesses regenerating every paper table and figure.
//!
//! Python never runs on the request path: after `make artifacts` the rust
//! binary is self-contained.
//!
//! See DESIGN.md for the system inventory and EXPERIMENTS.md for
//! paper-vs-measured results.

pub mod attention;
pub mod bench_harness;
pub mod coordinator;
pub mod data;
pub mod metrics;
pub mod runtime;
pub mod scan;
pub mod serve;
pub mod util;
