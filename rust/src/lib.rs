//! # aaren-rs — *Attention as an RNN* (Feng et al., 2024) in Rust + JAX + Pallas
//!
//! Three-layer reproduction of the paper's Aaren module and its full
//! evaluation suite:
//!
//! * **L1** (build time): Pallas prefix-scan attention kernels, validated
//!   against pure-jnp oracles (`python/compile/kernels/`).
//! * **L2** (build time): JAX models per evaluation domain, AOT-lowered to
//!   HLO text (`python/compile/`, `make artifacts`).
//! * **L3** (this crate): the runtime/coordination layer — PJRT execution,
//!   training orchestration, synthetic dataset substrates for all 38 paper
//!   datasets, the constant-memory streaming session manager, and bench
//!   harnesses regenerating every paper table and figure.
//!
//! Python never runs on the request path: after `make artifacts` the rust
//! binary is self-contained.
//!
//! ## Features
//!
//! The XLA/PJRT execution tier (`runtime`, `coordinator`, the compiled-HLO
//! serve backend and the paper-table harnesses) requires a machine with
//! XLA installed and is gated behind the **`pjrt`** cargo feature. The
//! default feature set is pure Rust: the SoA scan engine (with its
//! persistent worker pool), attention oracles, rust-native streaming
//! sessions, the TCP serving stack behind the `StreamSession` trait, the
//! `aaren` CLI, data substrates and benches all build and test with
//! `cargo build --release && cargo test -q` alone.
//!
//! See DESIGN.md for the system inventory and EXPERIMENTS.md for
//! paper-vs-measured results.

// index-based loops here mostly drive multi-buffer slice windows, where
// iterator rewrites obscure the stride math the SoA layout is built on
#![allow(clippy::needless_range_loop)]

pub mod attention;
pub mod bench_harness;
#[cfg(feature = "pjrt")]
pub mod coordinator;
pub mod data;
pub mod fault;
pub mod fleet;
pub mod loadgen;
pub mod metrics;
pub mod obs;
pub mod persist;
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod scan;
pub mod serve;
pub mod util;
