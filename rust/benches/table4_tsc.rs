//! Regenerates paper Table 4 (TSC, 10 datasets × 2 models).
use aaren::bench_harness::{run_table4, BenchOpts};

fn opts() -> BenchOpts {
    let get = |k: &str, d: usize| std::env::var(k).ok().and_then(|v| v.parse().ok()).unwrap_or(d);
    BenchOpts {
        seeds: get("AAREN_SEEDS", 2) as u64,
        train_steps: get("AAREN_STEPS", 150),
        limit: get("AAREN_LIMIT", 4),
        artifacts: std::path::PathBuf::from("artifacts"),
    }
}

fn main() {
    run_table4(&opts()).expect("table4 failed");
}
