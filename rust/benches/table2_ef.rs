//! Regenerates paper Table 2 (event forecasting, 8 datasets × 2 models).
use aaren::bench_harness::{run_table2, BenchOpts};

fn opts() -> BenchOpts {
    let get = |k: &str, d: usize| std::env::var(k).ok().and_then(|v| v.parse().ok()).unwrap_or(d);
    BenchOpts {
        seeds: get("AAREN_SEEDS", 2) as u64,
        train_steps: get("AAREN_STEPS", 150),
        limit: get("AAREN_LIMIT", 3),
        artifacts: std::path::PathBuf::from("artifacts"),
    }
}

fn main() {
    run_table2(&opts()).expect("table2 failed");
}
