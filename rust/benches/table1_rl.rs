//! Regenerates paper Table 1 (RL, 12 datasets × 2 models). Scaled-down
//! defaults; env vars widen: AAREN_SEEDS, AAREN_STEPS, AAREN_LIMIT.
use aaren::bench_harness::{run_table1, BenchOpts};

fn opts() -> BenchOpts {
    let get = |k: &str, d: usize| std::env::var(k).ok().and_then(|v| v.parse().ok()).unwrap_or(d);
    BenchOpts {
        seeds: get("AAREN_SEEDS", 2) as u64,
        train_steps: get("AAREN_STEPS", 150),
        limit: get("AAREN_LIMIT", 2), // 2 envs × 3 tiers by default
        artifacts: std::path::PathBuf::from("artifacts"),
    }
}

fn main() {
    run_table1(&opts()).expect("table1 failed");
}
