//! Regenerates paper Tables 3+5 (TSF, 8 datasets × 4 horizons × 2 models).
//! AAREN_HORIZONS (comma-separated) picks horizons; default 96,192.
use aaren::bench_harness::{run_table3, BenchOpts};

fn opts() -> BenchOpts {
    let get = |k: &str, d: usize| std::env::var(k).ok().and_then(|v| v.parse().ok()).unwrap_or(d);
    BenchOpts {
        seeds: get("AAREN_SEEDS", 2) as u64,
        train_steps: get("AAREN_STEPS", 150),
        limit: get("AAREN_LIMIT", 3),
        artifacts: std::path::PathBuf::from("artifacts"),
    }
}

fn main() {
    let horizons: Vec<usize> = std::env::var("AAREN_HORIZONS")
        .unwrap_or_else(|_| "96,192".to_string())
        .split(',')
        .filter_map(|s| s.trim().parse().ok())
        .collect();
    run_table3(&opts(), &horizons).expect("table3 failed");
}
