//! Microbenchmarks for the paper's algorithmic core (ablation for
//! DESIGN.md: Hillis–Steele O(N log N) vs Blelloch O(N) work, vs the
//! sequential fold, plus the O(1) streaming update vs naive recompute —
//! the §3.1 "methods for computing attention" comparison, rust-native).
use aaren::attention;
use aaren::scan::{self, Muw};
use aaren::util::bench::{bench, print_result};
use aaren::util::rng::Rng;

fn leaves(rng: &mut Rng, n: usize, d: usize) -> Vec<Muw> {
    (0..n)
        .map(|_| Muw {
            m: rng.range(-5.0, 5.0) as f32,
            u: 1.0,
            w: (0..d).map(|_| rng.gaussian() as f32).collect(),
        })
        .collect()
}

fn main() {
    let d = 16;
    println!("prefix scan over (m,u,w) tuples, d={d}:");
    for n in [64usize, 256, 1024, 4096] {
        let mut rng = Rng::new(n as u64);
        let ls = leaves(&mut rng, n, d);
        for (name, algo) in [
            ("sequential", scan::sequential as fn(&[Muw]) -> Vec<Muw>),
            ("hillis_steele", scan::hillis_steele),
            ("blelloch", scan::blelloch),
        ] {
            let r = bench(&format!("{name:<14} n={n}"), 2, 12, || {
                std::hint::black_box(algo(&ls));
            });
            print_result(&r);
        }
    }

    println!("\nstreaming one new token at context n (the paper's O(1) vs O(n)):");
    for n in [64usize, 256, 1024, 4096] {
        let mut rng = Rng::new(7);
        let q: Vec<f32> = (0..d).map(|_| rng.gaussian() as f32).collect();
        let k: Vec<f32> = (0..n * d).map(|_| rng.gaussian() as f32).collect();
        let v: Vec<f32> = (0..n * d).map(|_| rng.gaussian() as f32).collect();
        // O(1): fold one token into the carried (a,c,m) state
        let mut acc = Muw::identity(d);
        let r = bench(&format!("{:<14} n={n}", "rnn_fold O(1)"), 8, 64, || {
            scan::fold_token(&mut acc, 0.3, &v[..d]);
            std::hint::black_box(&acc);
        });
        print_result(&r);
        // O(n): recompute attention over the full prefix (transformer view)
        let r = bench(&format!("{:<14} n={n}", "recompute O(n)"), 2, 16, || {
            std::hint::black_box(attention::many_to_one(&q, &k, &v, None));
        });
        print_result(&r);
    }
}
