//! Microbenchmarks for the paper's algorithmic core: the SoA prefix-scan
//! engine (sequential / Hillis–Steele / Blelloch / multi-threaded chunked)
//! against the seed's allocating AoS sequential scan, plus the O(1)
//! streaming update vs naive recompute (the §3.1 "methods for computing
//! attention" comparison, rust-native).
//!
//! Emits a machine-readable `BENCH_scan.json` (schema:
//! `util::bench::BenchRecord`) in the working directory so later PRs can
//! track the perf trajectory. `speedup_vs_sequential` is relative to the
//! SoA sequential scan at the same n — the acceptance bar is
//! soa_sequential ≥ 2× aos_sequential (i.e. the aos row ≤ 0.5) and
//! chunked_parallel > 1.0 on ≥ 4 threads at n = 4096.

use aaren::attention;
use aaren::scan::{self, BatchScanBuffer, Muw, ScanBuffer, ScanPool};
use aaren::util::bench::{bench, print_result, write_records, BenchRecord};
use aaren::util::rng::Rng;

/// The seed's array-of-structs sequential scan, kept verbatim as the
/// baseline the SoA engine is measured against: one `combine` allocation
/// plus one clone per element.
mod aos_baseline {
    use aaren::scan::{combine, Muw};

    pub fn sequential(leaves: &[Muw]) -> Vec<Muw> {
        let mut out = Vec::with_capacity(leaves.len());
        let mut acc: Option<Muw> = None;
        for leaf in leaves {
            let next = match &acc {
                None => leaf.clone(),
                Some(a) => combine(a, leaf),
            };
            out.push(next.clone());
            acc = Some(next);
        }
        out
    }
}

fn leaves(rng: &mut Rng, n: usize, d: usize) -> ScanBuffer {
    let mut buf = ScanBuffer::with_capacity(d, n);
    for _ in 0..n {
        let s = rng.range(-5.0, 5.0) as f32;
        let v: Vec<f32> = (0..d).map(|_| rng.gaussian() as f32).collect();
        buf.push_leaf(s, &v);
    }
    buf
}

fn main() {
    // --quick: the CI smoke shape — fewer sizes and iterations, same
    // record names so BENCH_scan.json deltas stay comparable across PRs
    let quick = std::env::args().any(|a| a == "--quick");
    let d = 16;
    let cores = std::thread::available_parallelism().map(|t| t.get()).unwrap_or(1);
    let mut records: Vec<BenchRecord> = Vec::new();
    let sizes: &[usize] = if quick { &[256, 4096] } else { &[64, 256, 1024, 4096] };
    let (warmup, iters) = if quick { (1, 6) } else { (2, 12) };

    println!("prefix scan over (m,u,w) tuples, d={d} ({cores} cores):");
    for &n in sizes {
        let mut rng = Rng::new(n as u64);
        let ls = leaves(&mut rng, n, d);
        let ls_aos = ls.to_muws();

        let mut variants: Vec<(String, Box<dyn FnMut() + '_>)> = vec![
            (
                "soa_sequential".into(),
                Box::new(|| {
                    std::hint::black_box(scan::sequential(&ls));
                }),
            ),
            (
                "aos_sequential".into(),
                Box::new(|| {
                    std::hint::black_box(aos_baseline::sequential(&ls_aos));
                }),
            ),
            (
                "soa_hillis_steele".into(),
                Box::new(|| {
                    std::hint::black_box(scan::hillis_steele(&ls));
                }),
            ),
            (
                "soa_blelloch".into(),
                Box::new(|| {
                    std::hint::black_box(scan::blelloch(&ls));
                }),
            ),
        ];
        for threads in [2usize, 4, 8] {
            if threads > cores.max(2) {
                continue;
            }
            let ls_ref = &ls;
            variants.push((
                format!("chunked_parallel_t{threads}"),
                Box::new(move || {
                    std::hint::black_box(scan::chunked_parallel(ls_ref, threads));
                }),
            ));
        }

        let mut seq_ns = 0.0f64;
        for (name, f) in variants.iter_mut() {
            let r = bench(&format!("{name:<22} n={n}"), warmup, iters, f);
            print_result(&r);
            if name.as_str() == "soa_sequential" {
                seq_ns = r.mean_ns;
            }
            records.push(BenchRecord {
                name: name.clone(),
                n,
                d,
                ns_per_iter: r.mean_ns,
                speedup_vs_sequential: if seq_ns > 0.0 { seq_ns / r.mean_ns } else { 1.0 },
            });
        }
    }

    // batched multi-lane scans: B query heads sharing one engine vs B
    // separate single-lane buffers (the serving-side allocation hotspot)
    println!("\nbatched lanes: 8 lanes of n steps each, d={d}:");
    for &n in sizes {
        let lanes = 8usize;
        let mut rng = Rng::new(n as u64 ^ 0xba7c);
        let mut batch = BatchScanBuffer::with_capacity(lanes, d, n);
        let mut singles: Vec<ScanBuffer> = Vec::new();
        for _ in 0..lanes {
            singles.push(leaves(&mut rng, n, d));
        }
        for t in 0..n {
            for (b, single) in singles.iter().enumerate() {
                let (m, _, w) = single.row(t);
                batch.push_leaf_lane(b, m, w);
            }
        }
        let r = bench(&format!("{:<22} n={n}", "lanes8_per_lane_seq"), warmup, iters, || {
            for single in &singles {
                std::hint::black_box(scan::sequential(single));
            }
        });
        print_result(&r);
        let base_ns = r.mean_ns;
        records.push(BenchRecord {
            name: "lanes8_per_lane_seq".into(),
            n,
            d,
            ns_per_iter: r.mean_ns,
            speedup_vs_sequential: 1.0,
        });
        let mut variants: Vec<(String, Box<dyn FnMut() + '_>)> = vec![(
            "lanes8_batch_seq".into(),
            Box::new(|| {
                let mut buf = batch.clone();
                buf.scan_inplace();
                std::hint::black_box(&buf);
            }),
        )];
        let threads = ScanPool::global().threads().min(8);
        if threads >= 2 {
            let batch_ref = &batch;
            variants.push((
                format!("lanes8_batch_chunked_t{threads}"),
                Box::new(move || {
                    let mut buf = batch_ref.clone();
                    buf.scan_chunked(threads);
                    std::hint::black_box(&buf);
                }),
            ));
        }
        for (name, f) in variants.iter_mut() {
            let r = bench(&format!("{name:<22} n={n}"), warmup, iters, f);
            print_result(&r);
            records.push(BenchRecord {
                name: name.clone(),
                n,
                d,
                ns_per_iter: r.mean_ns,
                speedup_vs_sequential: if r.mean_ns > 0.0 { base_ns / r.mean_ns } else { 1.0 },
            });
        }
    }

    println!("\nstreaming one new token at context n (the paper's O(1) vs O(n)):");
    for &n in sizes {
        let mut rng = Rng::new(7);
        let q: Vec<f32> = (0..d).map(|_| rng.gaussian() as f32).collect();
        let k: Vec<f32> = (0..n * d).map(|_| rng.gaussian() as f32).collect();
        let v: Vec<f32> = (0..n * d).map(|_| rng.gaussian() as f32).collect();
        // O(1): fold one token into the carried (a,c,m) state
        let mut acc = Muw::identity(d);
        let r = bench(&format!("{:<22} n={n}", "rnn_fold O(1)"), 8, 64, || {
            scan::fold_token(&mut acc, 0.3, &v[..d]);
            std::hint::black_box(&acc);
        });
        print_result(&r);
        // O(n): recompute attention over the full prefix (transformer view)
        let r = bench(&format!("{:<22} n={n}", "recompute O(n)"), 2, 16, || {
            std::hint::black_box(attention::many_to_one(&q, &k, &v, None));
        });
        print_result(&r);
    }

    let out = std::path::Path::new("BENCH_scan.json");
    match write_records(out, &records) {
        Ok(()) => println!("\nwrote {} records to {}", records.len(), out.display()),
        Err(e) => eprintln!("\nfailed to write {}: {e}", out.display()),
    }
}
