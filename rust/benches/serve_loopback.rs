//! Loopback serving throughput for the rust-native TCP stack (no XLA):
//! one client streams AAREN_TOKENS tokens through an aaren session, then
//! AAREN_CLIENTS concurrent clients stream through their own sessions to
//! exercise the sharded executor pool. Prints tokens/sec per phase.

use std::time::Instant;

use aaren::serve::server::{Client, ServeConfig, Server};

fn stream_one(addr: &std::net::SocketAddr, step_body: &str, tokens: usize) -> f64 {
    let mut client = Client::connect(addr).expect("connect");
    let id = client
        .call(r#"{"op":"create","kind":"aaren"}"#)
        .expect("create")
        .usize_field("id")
        .expect("id");
    let t0 = Instant::now();
    for _ in 0..tokens {
        client
            .call(&format!(r#"{{"op":"step","id":{id},"x":[{step_body}]}}"#))
            .expect("step");
    }
    tokens as f64 / t0.elapsed().as_secs_f64()
}

fn main() {
    let tokens: usize = std::env::var("AAREN_TOKENS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(2000);
    let clients: usize = std::env::var("AAREN_CLIENTS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(4);
    let channels = 8usize;

    let cfg = ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        channels,
        shards: clients.max(1),
        artifacts: None,
    };
    let server = Server::bind(&cfg).expect("bind");
    let addr = server.local_addr().expect("addr");
    std::thread::spawn(move || server.run());

    let xs: Vec<String> = (0..channels).map(|i| format!("0.{i}")).collect();
    let step_body = xs.join(",");

    // phase 1: single client, one session
    let rate = stream_one(&addr, &step_body, tokens);
    println!("serve_loopback: 1 client   {rate:>12.0} tokens/s");

    // phase 2: concurrent clients, one session each, across shards
    let t0 = Instant::now();
    let handles: Vec<_> = (0..clients)
        .map(|_| {
            let body = step_body.clone();
            std::thread::spawn(move || stream_one(&addr, &body, tokens))
        })
        .collect();
    for h in handles {
        h.join().expect("client thread");
    }
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "serve_loopback: {clients} clients  {:>12.0} tokens/s aggregate",
        (clients * tokens) as f64 / dt
    );

    let mut shutdown = Client::connect(&addr).expect("connect");
    let _ = shutdown.call(r#"{"op":"shutdown"}"#);
}
