//! Loopback serving throughput for the rust-native TCP stack (no XLA),
//! tracking the request-coalescing work: per-step streaming (one
//! round-trip per token) vs batched `steps` blocks (one round-trip per
//! BATCH tokens), single-client and with AAREN_CLIENTS concurrent
//! clients across the sharded executor pool.
//!
//! Emits a machine-readable `BENCH_serve.json` (schema:
//! `util::bench::BenchRecord`, `speedup_vs_sequential` relative to the
//! single-client per-step baseline) so the serving perf trajectory is
//! tracked across PRs alongside `BENCH_scan.json`. The acceptance bar
//! for the batched path is `batched_steps_b16 ≥ 3×` the per-step
//! baseline. The cross-backend A/B records (`aaren_steps_b16`,
//! `mingru_steps_b16`, `avg_attn_steps_b16`) rerun the single-client
//! batched scenario per fold kernel, with `speedup_vs_sequential`
//! carrying the kernel/aaren throughput ratio (transport held
//! constant). Also records the mixed aaren/tf coalescing scenario
//! (`mixed_kinds_steps_b16_*`) and the persistence tier's
//! snapshot→restore→close wire round-trip latency
//! (`snapshot_restore_roundtrip`), the fleet failover drill
//! (`fleet_failover_b16`: three backends behind the consistent-hash
//! router, one shut down — `ns_per_iter` is the total wall-clock from
//! the kill to every stream answering again through the router, and
//! `speedup_vs_sequential` carries the resumed/total stream fraction),
//! and the resident-lane executor work:
//! a second server runs with `resident_lanes: false` (the PR 4
//! gather/scatter drain) and the `resident_vs_scatter_*` records carry
//! the resident/scatter throughput ratio in `speedup_vs_sequential` —
//! the acceptance bar is ratio ≥ 1 at b=16. The `overload_shed_b16`
//! record runs 16 clients into a one-shard server with a 2-deep queue:
//! its `ns_per_iter` is delivered throughput under admission control
//! and its `speedup_vs_sequential` field carries the shed rate
//! (structured `overloaded` replies per delivered token) instead of a
//! speedup. The telemetry records: `steps_b16_p50` / `steps_b16_p99`
//! carry the server's own `metrics`-op wire-latency percentiles for
//! the batched scenario (ns_per_iter IS the percentile, speedup
//! unused), and `metrics_overhead_b16` re-runs the batched scenario
//! against a telemetry-on vs `--no-telemetry` server pair with
//! `speedup_vs_sequential` carrying the on/off throughput ratio
//! (acceptance: >= 0.95, instrumentation costs <= 5%). Pass `--quick`
//! (CI) for a shorter run; AAREN_TOKENS / AAREN_CLIENTS override the
//! workload size.

use std::net::SocketAddr;
use std::time::Instant;

use aaren::serve::server::{Client, ServeConfig, Server};
use aaren::util::bench::{write_records, BenchRecord};

/// Stream `tokens` tokens through one fresh session of `kind` and return
/// tokens/sec. `batch <= 1` uses one `step` request per token; larger
/// batches send `steps` blocks of up to `batch` tokens per round-trip.
fn stream_one_kind(
    addr: &SocketAddr,
    kind: &str,
    step_body: &str,
    tokens: usize,
    batch: usize,
) -> f64 {
    let mut client = Client::connect(addr).expect("connect");
    let id = client
        .call(&format!(r#"{{"op":"create","kind":"{kind}"}}"#))
        .expect("create")
        .usize_field("id")
        .expect("id");
    let t0 = Instant::now();
    if batch <= 1 {
        for _ in 0..tokens {
            client
                .call(&format!(r#"{{"op":"step","id":{id},"x":[{step_body}]}}"#))
                .expect("step");
        }
    } else {
        let row = format!("[{step_body}]");
        let mut sent = 0usize;
        while sent < tokens {
            let take = batch.min(tokens - sent);
            let xs = vec![row.as_str(); take].join(",");
            let reply = client
                .call(&format!(r#"{{"op":"steps","id":{id},"xs":[{xs}]}}"#))
                .expect("steps");
            assert_eq!(
                reply.get("ys").and_then(aaren::util::json::Json::as_arr).expect("ys").len(),
                take,
                "steps must return one output per token"
            );
            sent += take;
        }
    }
    let rate = tokens as f64 / t0.elapsed().as_secs_f64();
    let _ = client.call(&format!(r#"{{"op":"close","id":{id}}}"#));
    rate
}

fn stream_one(addr: &SocketAddr, step_body: &str, tokens: usize, batch: usize) -> f64 {
    stream_one_kind(addr, "aaren", step_body, tokens, batch)
}

/// `clients` concurrent streams; returns aggregate tokens/sec. `kinds`
/// is cycled across the clients (the mixed aaren/tf coalescing scenario
/// drives both session families through one executor drain).
fn stream_many_kinds(
    addr: &SocketAddr,
    kinds: &[&str],
    step_body: &str,
    tokens: usize,
    batch: usize,
    clients: usize,
) -> f64 {
    let t0 = Instant::now();
    let handles: Vec<_> = (0..clients)
        .map(|c| {
            let body = step_body.to_string();
            let kind = kinds[c % kinds.len()].to_string();
            let addr = *addr;
            std::thread::spawn(move || stream_one_kind(&addr, &kind, &body, tokens, batch))
        })
        .collect();
    for h in handles {
        h.join().expect("client thread");
    }
    (clients * tokens) as f64 / t0.elapsed().as_secs_f64()
}

fn stream_many(
    addr: &SocketAddr,
    step_body: &str,
    tokens: usize,
    batch: usize,
    clients: usize,
) -> f64 {
    stream_many_kinds(addr, &["aaren"], step_body, tokens, batch, clients)
}

/// Stream through a deliberately overloaded server (tiny queue depth),
/// backing off briefly and retrying whenever admission control sheds a
/// request with a structured `overloaded` reply. Returns the shed count
/// — the overload_shed_b16 record's proof that backpressure engaged.
fn stream_one_shedding(addr: &SocketAddr, step_body: &str, tokens: usize, batch: usize) -> u64 {
    use aaren::serve::wire_error;
    let mut client = Client::connect(addr).expect("connect");
    let mut sheds = 0u64;
    let mut call = |client: &mut Client, line: &str| loop {
        let reply = client.call_raw(line).expect("transport");
        match wire_error(&reply) {
            None => break reply,
            Some((kind, _)) if kind == "overloaded" => {
                sheds += 1;
                // a short fixed backoff instead of the server's
                // retry_after_ms hint: the bench wants sustained
                // pressure, not a polite client
                std::thread::sleep(std::time::Duration::from_millis(1));
            }
            Some((kind, msg)) => panic!("server error ({kind}): {msg}"),
        }
    };
    let id = call(&mut client, r#"{"op":"create","kind":"aaren"}"#).usize_field("id").expect("id");
    let row = format!("[{step_body}]");
    let mut sent = 0usize;
    while sent < tokens {
        let take = batch.min(tokens - sent);
        let xs = vec![row.as_str(); take].join(",");
        call(&mut client, &format!(r#"{{"op":"steps","id":{id},"xs":[{xs}]}}"#));
        sent += take;
    }
    let _ = client.call(&format!(r#"{{"op":"close","id":{id}}}"#));
    sheds
}

/// One snapshot → restore → close round-trip over the wire: the
/// spill/restore latency record. Returns round-trips/sec.
fn snapshot_restore_roundtrips(addr: &SocketAddr, step_body: &str, iters: usize) -> f64 {
    let mut client = Client::connect(addr).expect("connect");
    let id = client
        .call(r#"{"op":"create","kind":"aaren"}"#)
        .expect("create")
        .usize_field("id")
        .expect("id");
    // a warm stream so the blob captures non-trivial state
    for _ in 0..8 {
        client.call(&format!(r#"{{"op":"step","id":{id},"x":[{step_body}]}}"#)).expect("step");
    }
    let t0 = Instant::now();
    for _ in 0..iters {
        let snap = client
            .call(&format!(r#"{{"op":"snapshot","id":{id}}}"#))
            .expect("snapshot");
        let blob = snap.str_field("state").expect("state");
        let restored = client
            .call(&format!(r#"{{"op":"restore","state":"{blob}"}}"#))
            .expect("restore");
        let twin = restored.usize_field("id").expect("restored id");
        client.call(&format!(r#"{{"op":"close","id":{twin}}}"#)).expect("close");
    }
    let rate = iters as f64 / t0.elapsed().as_secs_f64();
    let _ = client.call(&format!(r#"{{"op":"close","id":{id}}}"#));
    rate
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let tokens: usize = std::env::var("AAREN_TOKENS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(if quick { 500 } else { 2000 });
    let clients: usize = std::env::var("AAREN_CLIENTS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(4)
        .max(1);
    let channels = 8usize;
    const BATCH: usize = 16;

    let cfg = ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        channels,
        shards: clients,
        ..ServeConfig::default()
    };
    let server = Server::bind(&cfg).expect("bind");
    let addr = server.local_addr().expect("addr");
    std::thread::spawn(move || server.run());

    let xs: Vec<String> = (0..channels).map(|i| format!("0.{i}")).collect();
    let step_body = xs.join(",");
    let mut records: Vec<BenchRecord> = Vec::new();
    let record = |records: &mut Vec<BenchRecord>, name: &str, n: usize, rate: f64, base: f64| {
        let ns = 1e9 / rate;
        records.push(BenchRecord {
            name: name.to_string(),
            n,
            d: channels,
            ns_per_iter: ns,
            speedup_vs_sequential: if base > 0.0 { rate / base } else { 1.0 },
        });
    };

    // phase 1: single client, one round-trip per token — the baseline
    let base_rate = stream_one(&addr, &step_body, tokens, 1);
    println!("serve_loopback: per_step        1 client   {base_rate:>12.0} tokens/s");
    record(&mut records, "per_step_1client", tokens, base_rate, base_rate);

    // phase 2: single client, BATCH tokens per round-trip (the `steps`
    // op) — the acceptance scenario: >= 3x the per-step baseline
    let resident_b16_1 = stream_one(&addr, &step_body, tokens, BATCH);
    let speedup = resident_b16_1 / base_rate;
    println!(
        "serve_loopback: steps b={BATCH}      1 client   {resident_b16_1:>12.0} tokens/s  \
         ({speedup:.1}x per-step{})",
        if speedup >= 3.0 { "" } else { "  ** below the 3x acceptance bar **" }
    );
    record(&mut records, "batched_steps_b16_1client", tokens, resident_b16_1, base_rate);

    // phase 2b: cross-backend A/B — the same single-client batched
    // scenario per fold kernel. speedup_vs_sequential carries the
    // kernel_rate / aaren_rate ratio: the kernel's fold cost relative to
    // the (m, u, w) fold with the transport held constant
    record(&mut records, "aaren_steps_b16", tokens, resident_b16_1, resident_b16_1);
    for kind in ["mingru", "avg_attn"] {
        let rate = stream_one_kind(&addr, kind, &step_body, tokens, BATCH);
        let ratio = rate / resident_b16_1;
        println!(
            "serve_loopback: {kind:<9} b={BATCH}   1 client   {rate:>12.0} tokens/s  \
             ({ratio:.2}x aaren)"
        );
        records.push(BenchRecord {
            name: format!("{kind}_steps_b16"),
            n: tokens,
            d: channels,
            ns_per_iter: 1e9 / rate,
            speedup_vs_sequential: ratio,
        });
    }

    // phase 2c: the server's own view of the batched scenario — the
    // `metrics` op's op_steps wire-latency histogram, populated while
    // phases 2/2b streamed (every op_steps round-trip so far was a
    // b=16 block). Both fields are OVERLOADED: ns_per_iter carries the
    // percentile's bucket ceiling in ns per round-trip, and
    // speedup_vs_sequential is unused (0.0)
    let mut probe = Client::connect(&addr).expect("connect");
    let m = probe.call(r#"{"op":"metrics"}"#).expect("metrics");
    let steps_hist = m
        .get("histograms")
        .and_then(|h| h.get("op_steps"))
        .cloned()
        .expect("metrics reply lacks an op_steps histogram");
    let p50 = steps_hist.usize_field("p50_ns").expect("p50_ns") as f64;
    let p99 = steps_hist.usize_field("p99_ns").expect("p99_ns") as f64;
    println!(
        "serve_loopback: steps b={BATCH} wire latency  p50 {:.1} us  p99 {:.1} us \
         (server-side histogram)",
        p50 / 1e3,
        p99 / 1e3
    );
    for (name, ns) in [("steps_b16_p50", p50), ("steps_b16_p99", p99)] {
        records.push(BenchRecord {
            name: name.to_string(),
            n: tokens,
            d: channels,
            ns_per_iter: ns,
            speedup_vs_sequential: 0.0,
        });
    }

    // phase 3: concurrent clients, per-step, one session each — shard
    // fan-out plus drain coalescing across sessions
    let rate = stream_many(&addr, &step_body, tokens, 1, clients);
    println!("serve_loopback: per_step        {clients} clients  {rate:>12.0} tokens/s aggregate");
    record(&mut records, &format!("per_step_{clients}clients"), clients * tokens, rate, base_rate);

    // phase 4: concurrent clients, batched steps
    let resident_b16_n = stream_many(&addr, &step_body, tokens, BATCH, clients);
    println!(
        "serve_loopback: steps b={BATCH}      {clients} clients  {resident_b16_n:>12.0} tokens/s \
         aggregate"
    );
    record(
        &mut records,
        &format!("batched_steps_b16_{clients}clients"),
        clients * tokens,
        resident_b16_n,
        base_rate,
    );

    // phase 5: mixed aaren/tf clients — the coalescing engine splits the
    // drain into the batched aaren lane fold and per-session tf paths,
    // so this tracks the mixed-kind drain overhead (ROADMAP follow-up)
    let rate = stream_many_kinds(&addr, &["aaren", "tf"], &step_body, tokens, BATCH, clients);
    println!(
        "serve_loopback: mixed a/tf b={BATCH} {clients} clients  {rate:>12.0} tokens/s aggregate"
    );
    record(
        &mut records,
        &format!("mixed_kinds_steps_b16_{clients}clients"),
        clients * tokens,
        rate,
        base_rate,
    );

    // phase 6: snapshot → restore → close wire round-trips — the
    // spill/restore latency trail for the persistence tier
    let iters = if quick { 50 } else { 300 };
    let rate = snapshot_restore_roundtrips(&addr, &step_body, iters);
    println!(
        "serve_loopback: snapshot+restore            {rate:>12.0} round-trips/s \
         ({:.1} us/round-trip)",
        1e6 / rate
    );
    record(&mut records, "snapshot_restore_roundtrip", iters, rate, 0.0);

    let mut shutdown = Client::connect(&addr).expect("connect");
    let _ = shutdown.call(r#"{"op":"shutdown"}"#);

    // phase 7: resident lanes vs the PR 4 gather/scatter drain — a
    // second server runs with resident_lanes disabled and re-measures
    // the batched scenarios; the resident_vs_scatter records carry
    // resident_rate / scatter_rate in speedup_vs_sequential (acceptance:
    // >= 1, residency must not lose to per-drain state copies)
    let mut scatter_cfg = cfg.clone();
    scatter_cfg.resident_lanes = false;
    let scatter_server = Server::bind(&scatter_cfg).expect("bind scatter");
    let scatter_addr = scatter_server.local_addr().expect("addr");
    std::thread::spawn(move || scatter_server.run());

    let scatter_b16_1 = stream_one(&scatter_addr, &step_body, tokens, BATCH);
    let ratio1 = resident_b16_1 / scatter_b16_1;
    println!(
        "serve_loopback: scatter b={BATCH}    1 client   {scatter_b16_1:>12.0} tokens/s  \
         (resident/scatter {ratio1:.2}x{})",
        if ratio1 >= 1.0 { "" } else { "  ** resident below the scatter baseline **" }
    );
    record(&mut records, "scatter_steps_b16_1client", tokens, scatter_b16_1, base_rate);
    records.push(BenchRecord {
        name: "resident_vs_scatter_steps_b16_1client".to_string(),
        n: tokens,
        d: channels,
        ns_per_iter: 1e9 / resident_b16_1,
        speedup_vs_sequential: ratio1,
    });

    let scatter_b16_n = stream_many(&scatter_addr, &step_body, tokens, BATCH, clients);
    let ratio_n = resident_b16_n / scatter_b16_n;
    println!(
        "serve_loopback: scatter b={BATCH}    {clients} clients  {scatter_b16_n:>12.0} tokens/s \
         aggregate  (resident/scatter {ratio_n:.2}x)"
    );
    record(
        &mut records,
        &format!("scatter_steps_b16_{clients}clients"),
        clients * tokens,
        scatter_b16_n,
        base_rate,
    );
    records.push(BenchRecord {
        name: format!("resident_vs_scatter_steps_b16_{clients}clients"),
        n: clients * tokens,
        d: channels,
        ns_per_iter: 1e9 / resident_b16_n,
        speedup_vs_sequential: ratio_n,
    });

    let mut shutdown = Client::connect(&scatter_addr).expect("connect");
    let _ = shutdown.call(r#"{"op":"shutdown"}"#);

    // phase 8: overload shedding under admission control — one shard
    // with a 2-deep queue against 16 clients, every shed answered with a
    // structured `overloaded` + retry. ns_per_iter tracks delivered
    // throughput under pressure; speedup_vs_sequential is OVERLOADED
    // here: it carries the shed rate (sheds per delivered token), the
    // number that must stay >0 for the record to prove backpressure ran
    let shed_cfg = ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        channels,
        shards: 1,
        queue_depth: 2,
        ..ServeConfig::default()
    };
    let shed_server = Server::bind(&shed_cfg).expect("bind shed");
    let shed_addr = shed_server.local_addr().expect("addr");
    std::thread::spawn(move || shed_server.run());

    let shed_clients = 16usize;
    let shed_tokens = (tokens / 4).max(BATCH);
    let t0 = Instant::now();
    let handles: Vec<_> = (0..shed_clients)
        .map(|_| {
            let body = step_body.clone();
            let addr = shed_addr;
            std::thread::spawn(move || stream_one_shedding(&addr, &body, shed_tokens, BATCH))
        })
        .collect();
    let sheds: u64 = handles.into_iter().map(|h| h.join().expect("shed client")).sum();
    let delivered = (shed_clients * shed_tokens) as f64;
    let shed_rate = delivered / t0.elapsed().as_secs_f64();
    println!(
        "serve_loopback: shed  b={BATCH}     {shed_clients} clients  {shed_rate:>12.0} tokens/s \
         aggregate  ({sheds} overloaded sheds, queue depth {})",
        shed_cfg.queue_depth
    );
    records.push(BenchRecord {
        name: "overload_shed_b16".to_string(),
        n: shed_clients * shed_tokens,
        d: channels,
        ns_per_iter: 1e9 / shed_rate,
        speedup_vs_sequential: sheds as f64 / delivered,
    });

    let mut shutdown = Client::connect(&shed_addr).expect("connect");
    let _ = shutdown.call(r#"{"op":"shutdown"}"#);

    // phase 9: fleet failover — three backends behind the consistent-hash
    // router share one spill directory; every stream drains its state to
    // disk, one backend shuts down, and the record measures the
    // wall-clock from the kill until every stream answers a `step`
    // through the router again (detection + spill replay + retries).
    // Both fields are OVERLOADED here: ns_per_iter is the TOTAL failover
    // wall-clock in ns (not a per-iteration cost) and
    // speedup_vs_sequential carries the resumed/total stream fraction —
    // the availability number that must stay 1.0 (bitwise resume
    // equality is asserted by the chaos suite, not re-checked here).
    {
        use aaren::fleet::{Fleet, FleetConfig};
        use aaren::serve::wire_error;
        use std::time::Duration;

        let spill =
            std::env::temp_dir().join(format!("aaren-bench-fleet-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&spill);
        std::fs::create_dir_all(&spill).expect("spill dir");

        let mut backend_addrs: Vec<SocketAddr> = Vec::new();
        for _ in 0..3 {
            let backend_cfg = ServeConfig {
                addr: "127.0.0.1:0".to_string(),
                channels,
                shards: 2,
                spill_dir: Some(spill.clone()),
                ..ServeConfig::default()
            };
            let server = Server::bind(&backend_cfg).expect("bind fleet backend");
            let baddr = server.local_addr().expect("addr");
            std::thread::spawn(move || server.run());
            backend_addrs.push(baddr);
        }

        let fleet_cfg = FleetConfig {
            addr: "127.0.0.1:0".to_string(),
            members: backend_addrs.iter().map(|a| a.to_string()).collect(),
            spill_dir: Some(spill.clone()),
            hb_interval: Duration::from_millis(50),
            hb_timeout: Duration::from_millis(250),
            hb_misses: 2,
            io_timeout: Some(Duration::from_secs(20)),
            ..FleetConfig::default()
        };
        let fleet = Fleet::bind(&fleet_cfg).expect("bind fleet");
        let fleet_addr = fleet.local_addr().expect("fleet addr");
        std::thread::spawn(move || {
            let _ = fleet.run();
        });

        // warm streams across every kernel kind, each drained so its
        // latest state is on the shared spill tier before the kill
        let fleet_streams = if quick { 12 } else { 24 };
        let kinds = aaren::scan::KernelKind::ALL;
        let row = format!("[{step_body}]");
        let mut streams: Vec<(Client, u64)> = Vec::new();
        for s in 0..fleet_streams {
            let mut client = Client::connect(&fleet_addr).expect("connect fleet");
            let kind = kinds[s % kinds.len()].wire_name();
            let id = client
                .call(&format!(r#"{{"op":"create","kind":"{kind}"}}"#))
                .expect("fleet create")
                .usize_field("id")
                .expect("id") as u64;
            let xs = vec![row.as_str(); BATCH].join(",");
            client
                .call(&format!(r#"{{"op":"steps","id":{id},"xs":[{xs}]}}"#))
                .expect("fleet steps");
            client.call(&format!(r#"{{"op":"drain","id":{id}}}"#)).expect("fleet drain");
            streams.push((client, id));
        }

        // graceful shutdown straight to one backend (bypassing the
        // router): its residents vanish, its spill files survive
        let mut victim = Client::connect(&backend_addrs[0]).expect("connect victim");
        let _ = victim.call(r#"{"op":"shutdown"}"#);

        let t0 = Instant::now();
        let deadline = t0 + Duration::from_secs(30);
        let mut resumed = 0usize;
        for (client, id) in &mut streams {
            let line = format!(r#"{{"op":"step","id":{id},"x":[{step_body}]}}"#);
            loop {
                if Instant::now() >= deadline {
                    break;
                }
                let reply = match client.call_raw(&line) {
                    Ok(r) => r,
                    Err(_) => break, // transport failure to the router itself
                };
                match wire_error(&reply) {
                    None => {
                        resumed += 1;
                        break;
                    }
                    Some((kind, _)) if kind == "overloaded" => {
                        let hint = reply
                            .get("error")
                            .and_then(|e| e.usize_field("retry_after_ms").ok())
                            .unwrap_or(5);
                        std::thread::sleep(Duration::from_millis(hint as u64));
                    }
                    Some(_) => break, // structured death — counts against resumed
                }
            }
        }
        let failover = t0.elapsed();
        let fraction = resumed as f64 / fleet_streams as f64;
        println!(
            "serve_loopback: fleet failover b={BATCH} {fleet_streams} streams  \
             {:>9.1} ms to full resume  ({resumed}/{fleet_streams} resumed{})",
            failover.as_secs_f64() * 1e3,
            if resumed == fleet_streams { "" } else { "  ** streams lost in failover **" }
        );
        records.push(BenchRecord {
            name: "fleet_failover_b16".to_string(),
            n: fleet_streams,
            d: channels,
            ns_per_iter: failover.as_nanos() as f64,
            speedup_vs_sequential: fraction,
        });

        let mut shutdown = Client::connect(&fleet_addr).expect("connect fleet");
        let _ = shutdown.call(r#"{"op":"shutdown"}"#);
        let _ = std::fs::remove_dir_all(&spill);
    }

    // phase 10: telemetry overhead — the single-client batched scenario
    // against a fresh default server (telemetry on) and a
    // `--no-telemetry` twin. speedup_vs_sequential carries the on/off
    // throughput ratio; the acceptance bar is >= 0.95 (instrumentation
    // must cost <= 5% at b=16)
    let on_cfg = ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        channels,
        shards: 1,
        ..ServeConfig::default()
    };
    let mut off_cfg = on_cfg.clone();
    off_cfg.telemetry = false;
    let on_server = Server::bind(&on_cfg).expect("bind telemetry-on");
    let on_addr = on_server.local_addr().expect("addr");
    std::thread::spawn(move || on_server.run());
    let off_server = Server::bind(&off_cfg).expect("bind telemetry-off");
    let off_addr = off_server.local_addr().expect("addr");
    std::thread::spawn(move || off_server.run());

    // a warmup pass each so neither server wins on cache warmth
    let _ = stream_one(&on_addr, &step_body, (tokens / 4).max(BATCH), BATCH);
    let _ = stream_one(&off_addr, &step_body, (tokens / 4).max(BATCH), BATCH);
    let rate_on = stream_one(&on_addr, &step_body, tokens, BATCH);
    let rate_off = stream_one(&off_addr, &step_body, tokens, BATCH);
    let ratio = rate_on / rate_off;
    println!(
        "serve_loopback: telemetry b={BATCH} on {rate_on:>12.0} / off {rate_off:>12.0} tokens/s  \
         ({ratio:.3}x{})",
        if ratio >= 0.95 { "" } else { "  ** telemetry overhead above the 5% budget **" }
    );
    records.push(BenchRecord {
        name: "metrics_overhead_b16".to_string(),
        n: tokens,
        d: channels,
        ns_per_iter: 1e9 / rate_on,
        speedup_vs_sequential: ratio,
    });
    let mut shutdown = Client::connect(&on_addr).expect("connect");
    let _ = shutdown.call(r#"{"op":"shutdown"}"#);
    let mut shutdown = Client::connect(&off_addr).expect("connect");
    let _ = shutdown.call(r#"{"op":"shutdown"}"#);

    let out = std::path::Path::new("BENCH_serve.json");
    match write_records(out, &records) {
        Ok(()) => println!("wrote {} records to {}", records.len(), out.display()),
        Err(e) => eprintln!("failed to write {}: {e}", out.display()),
    }
}
