//! Regenerates the paper's §4.5 parameter-count comparison.
fn main() {
    aaren::bench_harness::run_params(std::path::Path::new("artifacts"))
        .expect("params bench failed");
}
