//! Regenerates paper Figure 5: memory + cumulative time of streaming
//! inference, Aaren (O(1) state) vs Transformer (KV cache buckets).
//! AAREN_TOKENS sets the stream length (default 512).
fn main() {
    let tokens = std::env::var("AAREN_TOKENS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(512);
    aaren::bench_harness::run_fig5(std::path::Path::new("artifacts"), tokens)
        .expect("fig5 failed");
}
