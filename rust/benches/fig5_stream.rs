//! Regenerates paper Figure 5: memory + cumulative time of streaming
//! inference, Aaren (O(1) state) vs Transformer (KV cache buckets).
//! AAREN_TOKENS sets the stream length (default 512).
//!
//! With the `pjrt` feature this drives the compiled HLO sessions over
//! `artifacts/`; the default build measures the rust-native session pair
//! instead — same claim, no XLA required.
fn main() {
    let tokens = std::env::var("AAREN_TOKENS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(512);
    #[cfg(feature = "pjrt")]
    aaren::bench_harness::run_fig5(std::path::Path::new("artifacts"), tokens)
        .expect("fig5 failed");
    #[cfg(not(feature = "pjrt"))]
    aaren::bench_harness::run_fig5_native(tokens, 8).expect("fig5 (native) failed");
}
